//! Quickstart: the core ApHMM workflow in ~60 lines.
//!
//! 1. Build an error-correction pHMM for a reference sequence.
//! 2. Train it with noisy reads (Baum-Welch + histogram filter).
//! 3. Decode the Viterbi consensus.
//! 4. If `artifacts/` exists, score the same model through the
//!    AOT-compiled XLA path and check it agrees with the native engine.
//! 5. Serve the profile: register it with a streaming `Server` and
//!    score two requests — the second hits the cross-request
//!    Prepared-coefficient cache (no re-freeze).
//!
//! Run: `cargo run --release --example quickstart`

use std::path::Path;

use aphmm::baumwelch::{score_sparse, train, BandedEngine, FilterConfig, ForwardOptions, TrainConfig};
use aphmm::phmm::{EcDesignParams, Phmm};
use aphmm::runtime::{ArtifactStore, XlaBandedEngine};
use aphmm::server::{Request, ResponseBody, Server, ServerConfig};
use aphmm::sim::{generate_genome, simulate_read, ErrorProfile, XorShift};
use aphmm::viterbi::consensus;

fn main() -> aphmm::Result<()> {
    let mut rng = XorShift::new(2024);

    // 1. A 100-base reference and its pHMM (Apollo's modified design).
    let reference = generate_genome(&mut rng, 100);
    let mut graph = Phmm::error_correction(&reference, &EcDesignParams::default())?;
    println!(
        "pHMM: {} states, {} transitions, band width {}",
        graph.n_states(),
        graph.n_transitions(),
        graph.band_width()
    );

    // 2. Train with 8 noisy reads of the same region.
    let reads: Vec<_> = (0..8)
        .map(|i| simulate_read(&mut rng, &reference, 0, 100, &ErrorProfile::pacbio(), i).seq)
        .collect();
    let cfg = TrainConfig {
        max_iters: 3,
        tol: 1e-4,
        filter: FilterConfig::histogram_default(),
        ..Default::default()
    };
    let result = train(&mut graph, &reads, &cfg)?;
    println!("trained {} iterations, mean loglik history: {:?}", result.iters, result.loglik_history);
    if result.reads_skipped > 0 {
        println!("({} reads were skipped as numerically dead)", result.reads_skipped);
    }

    // 3. Decode the consensus.
    let decoded = consensus(&graph)?;
    let same = reference
        .data
        .iter()
        .zip(decoded.consensus.data.iter())
        .filter(|(a, b)| a == b)
        .count();
    println!(
        "consensus: {} bases, {}/{} identical to the reference",
        decoded.consensus.len(),
        same,
        reference.len()
    );

    // 4. Score a read through both engines: native banded vs PJRT/XLA.
    let banded = graph.to_banded()?;
    let native = BandedEngine::score(&banded, &reads[0])?;
    let sparse = score_sparse(&graph, &reads[0], &ForwardOptions::default())?;
    println!("log P(read | model): sparse {sparse:.4}, banded {native:.4}");
    let artifacts = Path::new("artifacts");
    if artifacts.join("manifest.txt").exists() {
        let store = ArtifactStore::load(artifacts)?;
        let engine =
            XlaBandedEngine::for_shape(&store, banded.n, banded.w, banded.sigma, reads[0].len())?;
        let xla = engine.score(&banded, &reads[0])?;
        println!("log P(read | model): XLA    {xla:.4}  (|Δ| = {:.2e})", (xla - native).abs());
    } else {
        println!("(artifacts/ missing — run `make artifacts` to exercise the XLA path)");
    }

    // 5. Serve the trained profile: requests stream through a bounded
    //    job queue, and repeated requests against one profile reuse a
    //    single frozen coefficient table (the cross-request cache).
    let mut server = Server::start(ServerConfig::default());
    server.register_profile("ref", graph.clone());
    for (i, read) in reads.iter().take(2).enumerate() {
        let resp = server
            .submit(None, Request::Score { profile: "ref".into(), read: read.clone() })?
            .wait();
        if let ResponseBody::Score { loglik, cache_hit, .. } = resp.body {
            println!(
                "serve: score request {i}: loglik {loglik:.4}, prepared cache {} \
                 ({} us)",
                if cache_hit { "hit" } else { "miss" },
                resp.latency_ns / 1_000
            );
        }
    }
    let cache = server.cache_stats();
    println!("serve: cache hits={} misses={} (second request skipped the freeze)", cache.hits, cache.misses);
    assert_eq!(cache.hits, 1, "second same-profile request must be a cache hit");
    server.shutdown(true);
    Ok(())
}
