//! Protein family search at database scale (hmmsearch / Pfam stand-in).
//!
//! Generates a Pfam-like database of protein families, searches member
//! and decoy queries, and reports classification quality, the Fig. 2
//! split, and the modeled accelerator gain for the scoring workload.
//!
//! Run: `cargo run --release --example protein_family_search`

use std::time::Instant;

use aphmm::accel::{AccelConfig, Baselines, CpuMeasurement, StepKind, Workload};
use aphmm::apps::{AppTimings, FamilyDb, SearchConfig};
use aphmm::seq::{Sequence, PROTEIN};
use aphmm::sim::{generate_families, ProteinSimParams, XorShift};
use aphmm::testutil;

fn main() -> aphmm::Result<()> {
    let mut rng = XorShift::new(777);
    println!("=== ApHMM: protein family search ===");

    // Pfam-like database: families of ~94-residue ancestors.
    let params = ProteinSimParams {
        n_families: 120,
        mean_len: 94,
        members_per_family: 6,
        divergence: 0.15,
    };
    let t_build = Instant::now();
    let families = generate_families(&mut rng, &params);
    let cfg = SearchConfig::default();
    let db = FamilyDb::build(&families, PROTEIN, &cfg)?;
    println!("database: {} family pHMMs (built in {:.2}s)", db.len(), t_build.elapsed().as_secs_f64());

    // Queries: held-out members + random decoys.
    let mut timings = AppTimings::default();
    let mut top1 = 0usize;
    let n_queries = 60usize;
    let t0 = Instant::now();
    for q in 0..n_queries {
        let fam = &families[q % families.len()];
        let query = &fam.members[q % fam.members.len()];
        let report = db.search(query, &cfg)?;
        timings.merge(&report.timings);
        if report.hits.first().map(|h| h.family.as_str()) == Some(fam.id.as_str()) {
            top1 += 1;
        }
    }
    let mut decoy_hits = 0usize;
    for d in 0..20 {
        let decoy = Sequence::from_symbols(
            format!("decoy{d}"),
            testutil::random_seq(&mut rng, 94, PROTEIN.size()),
        );
        let report = db.search(&decoy, &cfg)?;
        // A decoy "hits" if its best score looks like a real member's.
        if report.hits.first().map(|h| h.score > -0.5).unwrap_or(false) {
            decoy_hits += 1;
        }
    }
    let wall = t0.elapsed().as_secs_f64();
    println!("\n--- quality ---");
    println!("top-1 family accuracy: {top1}/{n_queries}");
    println!("decoys scoring like members: {decoy_hits}/20");

    println!("\n--- execution split (Fig. 2) ---");
    println!(
        "Baum-Welch (Forward scoring) fraction: {:.1}%  (forward {:.2}s, other {:.2}s; total {:.2}s)",
        timings.bw_fraction() * 100.0,
        timings.forward_ns as f64 / 1e9,
        timings.other_ns as f64 / 1e9,
        wall
    );

    // Accelerator projection: scoring workload, Σ=20 (partial LUT).
    let acfg = AccelConfig::default();
    let mut wl = Workload::protein_canonical();
    wl.total_steps = (n_queries * 94) as u64;
    let bw_s = (timings.forward_ns + timings.backward_update_ns) as f64 / 1e9;
    let b = Baselines::from_cpu_measurement(
        &acfg,
        &wl,
        &CpuMeasurement { seconds: bw_s, filter_fraction: 0.0 },
    );
    let (s_cpu, s_gpu, _) = b.speedups();
    println!("\n--- ApHMM projection ---");
    println!(
        "scoring speedup vs CPU-1: {s_cpu:.1}x (vs GPU model {s_gpu:.1}x); steps: {:?}",
        wl.steps
    );
    let _ = StepKind::ForwardBackward;
    println!("\nOK");
    Ok(())
}
