//! End-to-end driver (DESIGN.md: the full-system validation run).
//!
//! Simulates an E. coli-like workload scaled to laptop size — a 200 kb
//! genome, a 3 %-error draft assembly, 10× PacBio-like reads — then runs
//! the complete Apollo-style pipeline: minimizer mapping → chunked
//! EC-pHMM training (Baum-Welch + histogram filter) → Viterbi consensus.
//! Reports the paper's headline quantities: assembly identity
//! before/after, the Fig. 2 execution-time split, throughput, and the
//! modeled ApHMM speedup/energy gain for the measured Baum-Welch
//! workload.
//!
//! Run: `cargo run --release --example error_correction_e2e`
//! (Results recorded in EXPERIMENTS.md §End-to-end.)

use std::time::Instant;

use aphmm::accel::{cycles, energy, AccelConfig, Baselines, CpuMeasurement, StepKind, Workload};
use aphmm::apps::{correct_assembly, CorrectionConfig};
use aphmm::baumwelch::FilterConfig;
use aphmm::seq::Sequence;
use aphmm::sim::{generate_genome, simulate_reads, ErrorProfile, XorShift};

/// Banded edit distance (accuracy metric).
fn edit_distance(a: &[u8], b: &[u8], band: usize) -> usize {
    let (n, m) = (a.len(), b.len());
    if n == 0 {
        return m;
    }
    let inf = usize::MAX / 2;
    let mut prev: Vec<usize> = (0..=m).collect();
    let mut cur = vec![inf; m + 1];
    for i in 1..=n {
        cur.iter_mut().for_each(|x| *x = inf);
        let lo = i.saturating_sub(band).max(1);
        let hi = (i + band).min(m);
        if lo == 1 {
            cur[0] = i;
        }
        for j in lo..=hi {
            let cost = usize::from(a[i - 1] != b[j - 1]);
            cur[j] = (prev[j - 1] + cost).min(prev[j] + 1).min(cur[j - 1] + 1);
        }
        std::mem::swap(&mut prev, &mut cur);
    }
    prev[m]
}

fn corrupt(rng: &mut XorShift, seq: &Sequence, rate: f64) -> Sequence {
    let mut data = Vec::with_capacity(seq.len());
    for &b in &seq.data {
        if rng.chance(rate) {
            match rng.below(3) {
                0 => data.push((b + 1 + rng.below(3) as u8) % 4),
                1 => {
                    data.push(b);
                    data.push(rng.below(4) as u8);
                }
                _ => {}
            }
        } else {
            data.push(b);
        }
    }
    Sequence::from_symbols("draft_assembly", data)
}

fn main() -> aphmm::Result<()> {
    let mut rng = XorShift::new(12_345);
    println!("=== ApHMM end-to-end: error correction ===");

    // ---- Workload (laptop-scale stand-in for SAMN06173305) ----
    let genome_len = 200_000;
    let truth = generate_genome(&mut rng, genome_len);
    let assembly = corrupt(&mut rng, &truth, 0.03);
    let reads = simulate_reads(&mut rng, &truth, 10.0, 5128, &ErrorProfile::pacbio());
    let read_seqs: Vec<Sequence> = reads.into_iter().map(|r| r.seq).collect();
    let total_bases: usize = read_seqs.iter().map(|r| r.len()).sum();
    println!(
        "genome {genome_len} bases; draft assembly {} bases (3% errors); {} reads / {:.1} Mb (~10x)",
        assembly.len(),
        read_seqs.len(),
        total_bases as f64 / 1e6
    );

    // ---- Correction ----
    let cfg = CorrectionConfig {
        chunk_len: 650,
        max_iters: 2,
        filter: FilterConfig::histogram_default(),
        ..Default::default()
    };
    let t0 = Instant::now();
    let report = correct_assembly(&assembly, &read_seqs, &cfg)?;
    let wall = t0.elapsed().as_secs_f64();

    // ---- Accuracy ----
    let band = 4096;
    let before = edit_distance(&assembly.data, &truth.data, band);
    let after = edit_distance(&report.corrected.data, &truth.data, band);
    let idy = |d: usize| 100.0 * (1.0 - d as f64 / genome_len as f64);
    println!("\n--- accuracy ---");
    println!("identity before: {:.3}%  ({} edits)", idy(before), before);
    println!("identity after:  {:.3}%  ({} edits)", idy(after), after);
    println!("error reduction: {:.1}x", before as f64 / after.max(1) as f64);

    // ---- Fig. 2-style split ----
    let t = &report.timings;
    println!("\n--- execution split (Fig. 2) ---");
    println!("total {:.2}s; Baum-Welch fraction {:.2}%", wall, t.bw_fraction() * 100.0);
    println!(
        "  forward {:.2}s | backward+updates {:.2}s | maximize {:.2}s | other {:.2}s",
        t.forward_ns as f64 / 1e9,
        t.backward_update_ns as f64 / 1e9,
        t.maximize_ns as f64 / 1e9,
        t.other_ns as f64 / 1e9
    );
    println!(
        "chunks {}/{} trained; {} reads mapped; throughput {:.1} kbases/s",
        report.chunks_trained,
        report.chunks_total,
        report.reads_mapped,
        genome_len as f64 / wall / 1e3
    );

    // ---- Accelerator projection for the measured workload ----
    let acfg = AccelConfig::default();
    let wl = Workload {
        total_steps: report.timesteps,
        avg_active_states: report.states_processed as f64 / report.timesteps.max(1) as f64,
        avg_degree: report.edges_processed as f64 / report.states_processed.max(1) as f64,
        sigma: 4,
        n_states: (cfg.chunk_len * 4) as u64,
        chunk_len: cfg.chunk_len,
        steps: StepKind::Training,
        n_sequences: report.reads_mapped as u64,
        n_iterations: cfg.max_iters as u64,
    };
    let bw_measured_s = (t.forward_ns + t.backward_update_ns + t.maximize_ns) as f64 / 1e9;
    let cpu = CpuMeasurement { seconds: bw_measured_s, filter_fraction: 0.085 };
    let b = Baselines::from_cpu_measurement(&acfg, &wl, &cpu);
    let (s_cpu, s_gpu, s_fpga) = b.speedups();
    let (e_cpu, e_gpu) = b.energy_reductions();
    let bd = cycles(&acfg, &wl);
    let e = energy(&acfg, &wl, &Default::default());
    println!("\n--- ApHMM projection (1 core @1GHz, measured workload) ---");
    println!(
        "Baum-Welch: measured CPU {:.2}s -> modeled ApHMM {:.4}s ({:.0} Mcycles)",
        bw_measured_s,
        bd.seconds(&acfg),
        bd.total() / 1e6
    );
    println!("speedup vs CPU-1 {s_cpu:.1}x | vs GPU(model) {s_gpu:.1}x | vs FPGA(model) {s_fpga:.1}x");
    println!(
        "energy: CPU {:.1} J -> ApHMM {:.3} J ({e_cpu:.0}x less; {e_gpu:.0}x vs GPU); model {:.3} J",
        b.cpu_j,
        b.aphmm_j,
        e.total()
    );
    println!("\nOK");
    Ok(())
}
