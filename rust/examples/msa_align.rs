//! Multiple sequence alignment against a family profile (hmmalign
//! stand-in): posterior-decoding alignment of many member sequences,
//! with quality and timing reports.
//!
//! Run: `cargo run --release --example msa_align`

use std::time::Instant;

use aphmm::apps::{align_all, msa_identity, MsaConfig};
use aphmm::phmm::{Phmm, Profile, TraditionalParams};
use aphmm::seq::PROTEIN;
use aphmm::sim::{generate_families, ProteinSimParams, XorShift};

fn main() -> aphmm::Result<()> {
    let mut rng = XorShift::new(4242);
    println!("=== ApHMM: multiple sequence alignment ===");

    // One family, many members (the paper aligns 1.1M sequences to the
    // Mitochondrial-carrier profile; we scale to laptop size).
    let params = ProteinSimParams {
        n_families: 1,
        mean_len: 94,
        members_per_family: 200,
        divergence: 0.15,
    };
    let fam = generate_families(&mut rng, &params).remove(0);
    let profile = Profile::from_members(&fam.members, fam.ancestor.len(), PROTEIN, 0.5);
    let phmm = Phmm::traditional(&profile, &TraditionalParams::default())?.fold_silent(4)?;
    println!(
        "profile: {} columns -> folded pHMM with {} states (band W={})",
        profile.len(),
        phmm.n_states(),
        phmm.band_width()
    );

    let t0 = Instant::now();
    let report = align_all(&phmm, &fam.members, &MsaConfig::default())?;
    let wall = t0.elapsed().as_secs_f64();

    println!("\n--- alignment ---");
    println!(
        "aligned {}/{} sequences to {} columns ({} skipped) in {:.2}s",
        report.rows.len(),
        fam.members.len(),
        report.n_columns,
        report.skipped,
        wall
    );
    println!("mean pairwise column identity: {:.1}%", msa_identity(&report) * 100.0);
    let mean_ins: f64 =
        report.rows.iter().map(|r| r.insertions as f64).sum::<f64>() / report.rows.len() as f64;
    println!("mean insertions per sequence: {mean_ins:.1}");

    println!("\n--- execution split (Fig. 2) ---");
    println!(
        "Forward+Backward fraction: {:.1}% (forward {:.2}s, backward {:.2}s, other {:.2}s)",
        report.timings.bw_fraction() * 100.0,
        report.timings.forward_ns as f64 / 1e9,
        report.timings.backward_update_ns as f64 / 1e9,
        report.timings.other_ns as f64 / 1e9
    );

    // Render a small slice of the MSA as a sanity picture.
    println!("\n--- first 5 rows x 60 columns ---");
    for row in report.rows.iter().take(5) {
        let line: String = row
            .columns
            .iter()
            .take(60)
            .map(|c| match c {
                Some(sym) => PROTEIN.decode(*sym) as char,
                None => '-',
            })
            .collect();
        println!("{:<14} {}", row.id, line);
    }
    println!("\nOK");
    Ok(())
}
