//! Accelerator design-space explorer: interactive-style sweeps over the
//! ApHMM model — PEs, memory ports, chunk sizes, cores, optimization
//! toggles — printing the trade-off tables a hardware architect would
//! look at (the §4.4 methodology).
//!
//! Run: `cargo run --release --example accel_explorer`

use aphmm::accel::{
    area_power, cycles, energy, multicore_runtime, AccelConfig, AppSplit, OptToggles, StepKind,
    Workload,
};

fn main() {
    let wl = Workload::ec_canonical();
    println!("=== ApHMM design-space explorer (EC training workload) ===\n");

    // ---- PE scaling at fixed 8 ports (Fig. 8a methodology) ----
    println!("PE scaling (8 ports x 16 B/cycle):");
    println!("{:>6} {:>12} {:>10} {:>10} {:>12}", "PEs", "cycles", "speedup", "mem-bound", "area mm^2");
    let base = cycles(&AccelConfig::default().with_pes(8), &wl).total();
    for pes in [8, 16, 32, 64, 128, 256, 512] {
        let cfg = AccelConfig::default().with_pes(pes);
        let bd = cycles(&cfg, &wl);
        let ap = area_power(&cfg);
        println!(
            "{:>6} {:>12.0} {:>9.2}x {:>9.0}% {:>12.2}",
            pes,
            bd.total(),
            base / bd.total(),
            bd.mem_bound_fraction * 100.0,
            ap.core_area_mm2()
        );
    }

    // ---- Port scaling at 64 PEs ----
    println!("\nMemory-port scaling (64 PEs):");
    println!("{:>6} {:>12} {:>10}", "ports", "cycles", "mem-bound");
    for ports in [2, 4, 8, 16, 32] {
        let mut cfg = AccelConfig::default();
        cfg.mem_ports = ports;
        let bd = cycles(&cfg, &wl);
        println!("{:>6} {:>12.0} {:>9.0}%", ports, bd.total(), bd.mem_bound_fraction * 100.0);
    }

    // ---- Optimization toggles ----
    println!("\nOptimization ablation (cycles relative to all-on):");
    let all_on = cycles(&AccelConfig::default(), &wl).total();
    let show = |name: &str, opt: OptToggles| {
        let mut cfg = AccelConfig::default();
        cfg.opt = opt;
        let c = cycles(&cfg, &wl).total();
        println!("  without {:<22} {:>6.2}x slower", name, c / all_on);
    };
    show("LUTs", OptToggles { luts: false, ..OptToggles::all() });
    show("broadcast+partial", OptToggles { broadcast_partial: false, ..OptToggles::all() });
    show("memoization", OptToggles { memoization: false, ..OptToggles::all() });
    show("everything (naive HW)", OptToggles::none());

    // ---- Chunk-size pressure (Fig. 8c methodology) ----
    println!("\nChunk-size pressure (cycles per base, 128 KB L1):");
    println!("{:>7} {:>14} {:>10}", "chunk", "cycles/base", "vs 150");
    let per_base = |chunk: usize| {
        let w = Workload::synthetic(chunk as u64, 500.0, 7.0, 4, chunk, StepKind::Training);
        cycles(&AccelConfig::default(), &w).total() / chunk as f64
    };
    let b150 = per_base(150);
    for chunk in [150, 300, 500, 650, 800, 1000, 1500] {
        let pb = per_base(chunk);
        println!("{:>7} {:>14.1} {:>9.2}x", chunk, pb, pb / b150);
    }

    // ---- Multi-core end-to-end (Fig. 9 methodology) ----
    println!("\nMulti-core end-to-end (error-correction split, normalized to 1 core):");
    let cfg = AccelConfig::default();
    let single = cycles(&cfg, &wl).seconds(&cfg);
    let split = AppSplit { cpu_other_s: single * 40.0 * 0.0145, cpu_bw_s: single * 40.0 };
    let t1 = multicore_runtime(&cfg, &wl, &split, 1).total();
    println!("{:>7} {:>10} {:>10} {:>10} {:>10}", "cores", "total", "accel", "movement", "norm");
    for cores in [1, 2, 4, 8] {
        let r = multicore_runtime(&cfg, &wl, &split, cores);
        println!(
            "{:>7} {:>9.2}ms {:>9.2}ms {:>9.2}ms {:>10.3}",
            cores,
            r.total() * 1e3,
            r.accel_s * 1e3,
            r.movement_s * 1e3,
            r.total() / t1
        );
    }

    // ---- Energy ----
    println!("\nEnergy at the Table 1 design point:");
    let e = energy(&AccelConfig::default(), &wl, &Default::default());
    println!(
        "  total {:.3} mJ = compute {:.3} + sram {:.3} + dram {:.3} + static {:.3}",
        e.total() * 1e3,
        e.compute_j * 1e3,
        e.sram_j * 1e3,
        e.dram_j * 1e3,
        e.static_j * 1e3
    );
}
