//! Fig. 3 — effect of the filter size on runtime and accuracy of the
//! Baum-Welch algorithm (paper: runtime grows with filter size, accuracy
//! saturates around 500).
//!
//! Trains the same EC scenario at several best-n sizes (sort filter, the
//! software mechanism the figure evaluates) and reports wall time and
//! consensus accuracy vs the unfiltered run.

mod common;

use aphmm::baumwelch::{train, FilterConfig, TrainConfig};
use aphmm::phmm::{EcDesignParams, Phmm};
use aphmm::viterbi::consensus;

fn main() {
    common::banner("Fig. 3: filter size vs runtime and accuracy");
    let scenario = common::ec_scenario(42, 650, 10);

    println!("{:>10} {:>12} {:>14} {:>12}", "filter", "runtime (s)", "mean loglik", "consensus");
    let mut baseline_consensus: Option<Vec<u8>> = None;
    for filter in [
        Some(100usize),
        Some(200),
        Some(300),
        Some(500),
        Some(1000),
        Some(2000),
        None,
    ] {
        let cfg = TrainConfig {
            max_iters: 2,
            tol: 0.0,
            filter: match filter {
                Some(size) => FilterConfig::Sort { size },
                None => FilterConfig::None,
            },
            ..Default::default()
        };
        let mut graph = Phmm::error_correction(&scenario.reference, &EcDesignParams::default())
            .unwrap();
        let (res, secs) = common::time(|| train(&mut graph, &scenario.reads, &cfg).unwrap());
        let decoded = consensus(&graph).unwrap().consensus.data;
        if baseline_consensus.is_none() && filter.is_none() {
            baseline_consensus = Some(decoded.clone());
        }
        let acc = {
            let truth = &scenario.reference.data;
            let d = common::edit_distance(&decoded, truth, 64);
            100.0 * (1.0 - d as f64 / truth.len() as f64)
        };
        println!(
            "{:>10} {:>12.3} {:>14.2} {:>11.2}%",
            filter.map(|f| f.to_string()).unwrap_or_else(|| "none".into()),
            secs,
            res.loglik_history.last().unwrap(),
            acc
        );
    }
    println!("\npaper shape: runtime rises with filter size; accuracy saturates ~500");
}
