//! Fig. 11 — end-to-end application speedups over the single-threaded
//! CPU implementations (paper: EC 2.66–59.94×, protein search
//! 1.61–1.75×, MSA 1.95×).
//!
//! Each application is *run and measured* on CPU; the accelerated time
//! is Amdahl-combined: unaccelerated part (measured) + Baum-Welch part
//! divided by the modeled 4-core ApHMM speedup for that workload.

mod common;

use aphmm::accel::{cycles, multicore_runtime, AccelConfig, AppSplit, StepKind, Workload};
use aphmm::apps::{align_all, correct_assembly, CorrectionConfig, FamilyDb, MsaConfig, SearchConfig};
use aphmm::baumwelch::{ExpectationEngine, ForwardOptions, SparseEngine};
use aphmm::phmm::{Phmm, Profile, TraditionalParams};
use aphmm::seq::{Sequence, PROTEIN};
use aphmm::sim::{
    generate_families, generate_genome, simulate_reads, ErrorProfile, ProteinSimParams, XorShift,
};

fn report(name: &str, split: AppSplit, wl: &Workload, paper: &str, paper_bw_frac: f64) {
    let acfg = AccelConfig::default();
    let cpu_total = split.cpu_other_s + split.cpu_bw_s;
    let r = multicore_runtime(&acfg, wl, &split, acfg.n_cores);
    let accel_total = r.total();
    println!(
        "{:<22} {:>11.3}s {:>12.3}s {:>9.2}x   (paper {paper})",
        name,
        cpu_total,
        accel_total,
        cpu_total / accel_total
    );
    // Second row: project onto the PAPER's Fig. 2 split.  Our
    // reimplementations lack HMMER's heavy non-BW pipeline stages, so
    // the measured non-BW share is smaller than the paper's; holding
    // our modeled BW acceleration fixed and substituting the paper's
    // split shows how the end-to-end number depends on that share.
    let paper_split = AppSplit {
        cpu_bw_s: cpu_total * paper_bw_frac,
        cpu_other_s: cpu_total * (1.0 - paper_bw_frac),
    };
    let rp = multicore_runtime(&acfg, wl, &paper_split, acfg.n_cores);
    println!(
        "{:<22} {:>11} {:>13} {:>9.2}x   (with the paper's {:.1}% BW share)",
        "  └ paper-split proj.",
        "",
        "",
        cpu_total / rp.total(),
        paper_bw_frac * 100.0
    );
}

fn main() {
    common::banner("Fig. 11: end-to-end speedups over CPU-1 (4-core ApHMM)");
    println!("{:<22} {:>12} {:>13} {:>10}", "application", "CPU-1", "ApHMM-accel", "speedup");

    // --- Error correction ---
    let mut rng = XorShift::new(31);
    let truth = generate_genome(&mut rng, 25_000);
    let reads: Vec<Sequence> = simulate_reads(&mut rng, &truth, 8.0, 2500, &ErrorProfile::pacbio())
        .into_iter()
        .map(|r| r.seq)
        .collect();
    let rep = correct_assembly(&truth, &reads, &CorrectionConfig::default()).unwrap();
    let (bw_s, other_s) = rep.timings.split_seconds();
    let wl = Workload {
        total_steps: rep.timesteps,
        avg_active_states: rep.states_processed as f64 / rep.timesteps.max(1) as f64,
        avg_degree: rep.edges_processed as f64 / rep.states_processed.max(1) as f64,
        sigma: 4,
        n_states: 2600,
        chunk_len: 650,
        steps: StepKind::Training,
        n_sequences: rep.reads_mapped as u64,
        n_iterations: 2,
    };
    report(
        "error correction",
        AppSplit { cpu_other_s: other_s, cpu_bw_s: bw_s },
        &wl,
        "2.66-59.94x",
        0.9857,
    );

    // --- Protein family search ---
    let mut rng = XorShift::new(32);
    let families =
        generate_families(&mut rng, &ProteinSimParams { n_families: 48, ..Default::default() });
    let cfg = SearchConfig::default();
    let db = FamilyDb::build(&families, PROTEIN, &cfg).unwrap();
    let mut t = aphmm::apps::AppTimings::default();
    for q in 0..32 {
        let fam = &families[q % families.len()];
        let r = db.search(&fam.members[q % fam.members.len()], &cfg).unwrap();
        t.merge(&r.timings);
    }
    let (bw_s, other_s) = t.split_seconds();
    // Measured inference workload: score one representative query
    // through the engine trait and extract the descriptor from the
    // uniform ScoreResult counters (replaces the synthetic
    // protein_canonical stand-in).
    let wl_search = {
        let engine = SparseEngine;
        let entry = &db.entries[0];
        let prep = engine.prepare(&entry.phmm).unwrap();
        let mut scratch = engine.make_scratch(&entry.phmm);
        let query = &families[0].members[0];
        let score = engine
            .score(&entry.phmm, &prep, query, &ForwardOptions::default(), &mut scratch)
            .unwrap();
        Workload::from_score(
            &entry.phmm,
            &score,
            query.len() as u64,
            StepKind::ForwardBackward,
        )
    };
    report(
        "protein family search",
        AppSplit { cpu_other_s: other_s, cpu_bw_s: bw_s },
        &wl_search,
        "1.61-1.75x",
        0.4576,
    );

    // --- MSA ---
    let mut rng = XorShift::new(33);
    let fam = generate_families(
        &mut rng,
        &ProteinSimParams { n_families: 1, members_per_family: 64, ..Default::default() },
    )
    .remove(0);
    let profile = Profile::from_members(&fam.members, fam.ancestor.len(), PROTEIN, 0.5);
    let phmm = Phmm::traditional(&profile, &TraditionalParams::default())
        .unwrap()
        .fold_silent(4)
        .unwrap();
    let rep = align_all(&phmm, &fam.members, &MsaConfig::default()).unwrap();
    let (bw_s, other_s) = rep.timings.split_seconds();
    report(
        "MSA",
        AppSplit { cpu_other_s: other_s, cpu_bw_s: bw_s },
        &Workload::protein_canonical(),
        "1.95x",
        0.5144,
    );

    let _ = cycles(&AccelConfig::default(), &Workload::ec_canonical());
    println!("\npaper shape: EC >> search/MSA (Amdahl: EC is ~99% Baum-Welch)");
}
