//! Fig. 6b — effect of the histogram filter for different sequence
//! lengths (paper: filtering pays off increasingly for longer
//! sequences), plus the Fig. 4 locality statistic as a preamble.
//!
//! Uses *measured* active-state counts from the real engine (with and
//! without filtering) to drive the accelerator cycle model.

mod common;

use aphmm::accel::{cycles, AccelConfig, StepKind, Workload};
use aphmm::baumwelch::{forward_sparse, FilterConfig, ForwardOptions};
use aphmm::phmm::{EcDesignParams, Phmm};

fn main() {
    // ---- Fig. 4 preamble: pHMM band locality vs generic HMM ----
    common::banner("Fig. 4 (preamble): data-dependency locality");
    let scenario = common::ec_scenario(7, 300, 1);
    let g = Phmm::error_correction(&scenario.reference, &EcDesignParams::default()).unwrap();
    let banded = g.to_banded().unwrap();
    let n = g.n_states();
    println!(
        "EC pHMM: {} states; dependencies live in a band of W={} ({:.2}% of the N x N matrix a generic HMM must consider); band occupancy {:.1}%",
        n,
        banded.w,
        100.0 * banded.w as f64 / n as f64,
        banded.occupancy() * 100.0
    );

    // ---- Fig. 6b ----
    common::banner("Fig. 6b: histogram filter on/off vs sequence length");
    println!(
        "{:>8} {:>14} {:>14} {:>12} {:>12} {:>9}",
        "seq len", "states (off)", "states (on)", "cyc (off)", "cyc (on)", "speedup"
    );
    let acfg = AccelConfig::default();
    // A deletion-heavy design (slow off-diagonal decay) so the
    // unfiltered state space actually grows with sequence length — the
    // regime the paper's figure describes.
    let heavy = EcDesignParams {
        max_deletions: 8,
        t_del_total: 0.15,
        del_decay: 1.2,
        init_spread: 8,
        ..Default::default()
    };
    for len in [100usize, 250, 500, 1000, 2000, 3500, 5000] {
        let scenario = common::ec_scenario(100 + len as u64, len, 1);
        let graph = Phmm::error_correction(&scenario.reference, &heavy).unwrap();
        let read = &scenario.reads[0];
        let unfiltered = forward_sparse(
            &graph,
            read,
            &ForwardOptions { filter: FilterConfig::None, ..Default::default() },
        )
        .unwrap();
        let filtered = forward_sparse(
            &graph,
            read,
            &ForwardOptions { filter: FilterConfig::histogram_default(), ..Default::default() },
        )
        .unwrap();
        let wl = |f: &aphmm::baumwelch::ForwardResult| Workload {
            total_steps: f.rows.len() as u64,
            avg_active_states: f.states_processed as f64 / f.rows.len() as f64,
            avg_degree: f.edges_processed as f64 / f.states_processed.max(1) as f64,
            sigma: 4,
            n_states: graph.n_states() as u64,
            chunk_len: len.min(1000),
            steps: StepKind::Training,
            n_sequences: 1,
            n_iterations: 1,
        };
        let mut cfg_off = acfg;
        cfg_off.opt.histogram_filter = false;
        let c_off = cycles(&cfg_off, &wl(&unfiltered)).total();
        let c_on = cycles(&acfg, &wl(&filtered)).total();
        println!(
            "{:>8} {:>14.0} {:>14.0} {:>12.0} {:>12.0} {:>8.2}x",
            len,
            unfiltered.states_processed as f64 / unfiltered.rows.len() as f64,
            filtered.states_processed as f64 / filtered.rows.len() as f64,
            c_off,
            c_on,
            c_off / c_on
        );
    }
    println!("\npaper shape: benefit grows with sequence length (state space growth)");
}
