//! Fig. 2 — percentage of total execution time of the three Baum-Welch
//! steps in the three applications (paper: error correction 98.57 % BW,
//! protein search 45.76 %, MSA 51.44 %).
//!
//! Runs the *real* Rust applications on scaled workloads and prints the
//! measured split.

mod common;

use aphmm::apps::{align_all, correct_assembly, CorrectionConfig, FamilyDb, MsaConfig, SearchConfig};
use aphmm::phmm::{Phmm, Profile, TraditionalParams};
use aphmm::seq::{Sequence, PROTEIN};
use aphmm::sim::{
    generate_families, generate_genome, simulate_reads, ErrorProfile, ProteinSimParams, XorShift,
};

fn row(app: &str, fwd: u128, bwd: u128, max: u128, other: u128) {
    let total = (fwd + bwd + max + other).max(1) as f64;
    println!(
        "{:<22} {:>9.2}% {:>10.2}% {:>9.2}% {:>8.2}% | BW total {:>6.2}%",
        app,
        fwd as f64 / total * 100.0,
        bwd as f64 / total * 100.0,
        max as f64 / total * 100.0,
        other as f64 / total * 100.0,
        (fwd + bwd + max) as f64 / total * 100.0,
    );
}

fn main() {
    common::banner("Fig. 2: execution-time breakdown of the Baum-Welch steps");
    println!(
        "{:<22} {:>10} {:>11} {:>10} {:>9}",
        "application", "Forward", "Backwd+Upd", "Maximize", "other"
    );

    // --- Error correction (Apollo-like) ---
    let mut rng = XorShift::new(1);
    let truth = generate_genome(&mut rng, 30_000);
    let reads: Vec<Sequence> = simulate_reads(&mut rng, &truth, 8.0, 3000, &ErrorProfile::pacbio())
        .into_iter()
        .map(|r| r.seq)
        .collect();
    let report = correct_assembly(&truth, &reads, &CorrectionConfig::default()).unwrap();
    let t = report.timings;
    row("error correction", t.forward_ns, t.backward_update_ns, t.maximize_ns, t.other_ns);

    // --- Protein family search (hmmsearch-like) ---
    let mut rng = XorShift::new(2);
    let families =
        generate_families(&mut rng, &ProteinSimParams { n_families: 48, ..Default::default() });
    let cfg = SearchConfig::default();
    let db = FamilyDb::build(&families, PROTEIN, &cfg).unwrap();
    let mut t = aphmm::apps::AppTimings::default();
    for q in 0..32 {
        let fam = &families[q % families.len()];
        let r = db.search(&fam.members[q % fam.members.len()], &cfg).unwrap();
        t.merge(&r.timings);
    }
    row("protein family search", t.forward_ns, t.backward_update_ns, t.maximize_ns, t.other_ns);

    // --- MSA (hmmalign-like) ---
    let mut rng = XorShift::new(3);
    let fam = generate_families(
        &mut rng,
        &ProteinSimParams { n_families: 1, members_per_family: 64, ..Default::default() },
    )
    .remove(0);
    let profile = Profile::from_members(&fam.members, fam.ancestor.len(), PROTEIN, 0.5);
    let phmm = Phmm::traditional(&profile, &TraditionalParams::default())
        .unwrap()
        .fold_silent(4)
        .unwrap();
    let report = align_all(&phmm, &fam.members, &MsaConfig::default()).unwrap();
    let t = report.timings;
    row("MSA", t.forward_ns, t.backward_update_ns, t.maximize_ns, t.other_ns);

    println!("\npaper: EC 98.57% | search 45.76% | MSA 51.44% Baum-Welch share");
    println!("(shape check: EC ~= fully BW-bound; scoring apps partially BW-bound)");
}
