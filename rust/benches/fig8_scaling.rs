//! Fig. 8 — accelerator scaling: (a) speedup vs number of PEs (knee at
//! 64 with 8 memory ports), (b) transition-step compute scaling,
//! (c) execution time vs chunk size (linear to ~650, super-linear
//! beyond).

mod common;

use aphmm::accel::{cycles, AccelConfig, StepKind, Workload};

fn main() {
    let wl = Workload::ec_canonical();

    common::banner("Fig. 8a: acceleration scaling with the number of PEs");
    println!("{:>6} {:>12} {:>10} {:>11}", "PEs", "cycles", "speedup", "mem-bound");
    let base = cycles(&AccelConfig::default().with_pes(8), &wl).total();
    for pes in [8usize, 16, 32, 64, 128, 256, 512] {
        let bd = cycles(&AccelConfig::default().with_pes(pes), &wl);
        println!(
            "{:>6} {:>12.0} {:>9.2}x {:>10.0}%",
            pes,
            bd.total(),
            base / bd.total(),
            bd.mem_bound_fraction * 100.0
        );
    }
    println!("paper shape: ~linear to 64 PEs, then flattening (8-port limit)");

    common::banner("Fig. 8b: transition-update step scaling with PEs");
    println!("{:>6} {:>14} {:>10}", "PEs", "upd cycles", "speedup");
    let upd_base = cycles(&AccelConfig::default().with_pes(8), &wl).update;
    for pes in [8usize, 16, 32, 64, 128, 256, 512] {
        let bd = cycles(&AccelConfig::default().with_pes(pes), &wl);
        println!("{:>6} {:>14.0} {:>9.2}x", pes, bd.update, upd_base / bd.update);
    }
    println!("paper shape: transition step saturates first (memory-port bound)");

    common::banner("Fig. 8c: execution time vs chunk size");
    println!("{:>7} {:>12} {:>14} {:>12}", "chunk", "cycles", "linear proj", "real/linear");
    let c150 = cycles(
        &AccelConfig::default(),
        &Workload::synthetic(150, 500.0, 7.0, 4, 150, StepKind::Training),
    )
    .total();
    for chunk in [150usize, 350, 650, 800, 1000, 1300] {
        let w = Workload::synthetic(chunk as u64, 500.0, 7.0, 4, chunk, StepKind::Training);
        let real = cycles(&AccelConfig::default(), &w).total();
        let linear = c150 * chunk as f64 / 150.0;
        println!("{:>7} {:>12.0} {:>14.0} {:>11.2}x", chunk, real, linear, real / linear);
    }
    println!("paper shape: linear to ~650 bases, super-linear beyond (L1 capacity)");
}
