//! Shared helpers for the paper-reproduction benches.
//!
//! Criterion is not in the offline registry, so every bench is a
//! `harness = false` binary built on this tiny timing kit.  Each bench
//! prints the rows/series of the paper table or figure it regenerates
//! (see DESIGN.md per-experiment index) — machine-portable *shapes*, not
//! absolute numbers.

use std::time::Instant;

use aphmm::seq::Sequence;
use aphmm::sim::{simulate_read, ErrorProfile, XorShift};

/// Time one closure, returning (result, seconds).
#[allow(dead_code)]
pub fn time<T>(f: impl FnOnce() -> T) -> (T, f64) {
    let t0 = Instant::now();
    let out = f();
    (out, t0.elapsed().as_secs_f64())
}

/// Median-of-n timing for short closures.
#[allow(dead_code)]
pub fn time_median(n: usize, mut f: impl FnMut()) -> f64 {
    let mut samples: Vec<f64> = (0..n.max(1))
        .map(|_| {
            let t0 = Instant::now();
            f();
            t0.elapsed().as_secs_f64()
        })
        .collect();
    samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
    samples[samples.len() / 2]
}

/// A reproducible EC-training scenario: reference + mapped noisy reads.
#[allow(dead_code)] // benches use different subsets of the fields
pub struct EcScenario {
    pub reference: Sequence,
    pub reads: Vec<Sequence>,
}

/// Build a training scenario of `ref_len` bases with `n_reads` reads.
#[allow(dead_code)]
pub fn ec_scenario(seed: u64, ref_len: usize, n_reads: usize) -> EcScenario {
    let mut rng = XorShift::new(seed);
    let data: Vec<u8> = (0..ref_len).map(|_| rng.below(4) as u8).collect();
    let reference = Sequence::from_symbols("ref", data);
    let reads = (0..n_reads)
        .map(|i| simulate_read(&mut rng, &reference, 0, ref_len, &ErrorProfile::pacbio(), i).seq)
        .collect();
    EcScenario { reference, reads }
}

/// Banded edit distance (accuracy metric shared by fig3/fig11).
#[allow(dead_code)]
pub fn edit_distance(a: &[u8], b: &[u8], band: usize) -> usize {
    let (n, m) = (a.len(), b.len());
    if n == 0 {
        return m;
    }
    let inf = usize::MAX / 2;
    let mut prev: Vec<usize> = (0..=m).collect();
    let mut cur = vec![inf; m + 1];
    for i in 1..=n {
        cur.iter_mut().for_each(|x| *x = inf);
        let lo = i.saturating_sub(band).max(1);
        let hi = (i + band).min(m);
        if lo == 1 {
            cur[0] = i;
        }
        for j in lo..=hi {
            let cost = usize::from(a[i - 1] != b[j - 1]);
            cur[j] = (prev[j - 1] + cost).min(prev[j] + 1).min(cur[j - 1] + 1);
        }
        std::mem::swap(&mut prev, &mut cur);
    }
    prev[m]
}

/// Section banner.
#[allow(dead_code)]
pub fn banner(title: &str) {
    println!("\n=== {title} ===");
}
