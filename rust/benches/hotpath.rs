//! Hot-path microbenchmarks (the §Perf instrumentation): per-edge and
//! per-state throughput of the forward pass, the fused
//! backward+update pass, both filters, the in-window gather kernels
//! (CSR vs dense tile vs adaptive dispatch), the banded engine
//! (pre-refactor scan vs fused coefficient tables), and (when artifacts
//! exist) the XLA runtime path.  Used to drive and record the
//! optimization iterations in EXPERIMENTS.md §Perf.
//!
//! Besides the human-readable rows, every run writes
//! `BENCH_hotpath.json` (per-row `name`/`baseline_ns`/`new_ns`/
//! `speedup`) next to the working directory so CI can upload the
//! numbers as an artifact instead of someone scraping them out of the
//! log by hand (the ROADMAP perf-log re-anchor debt).
//!
//! Set `APHMM_BENCH_SHORT=1` for the CI smoke mode: a smaller workload
//! and fewer repetitions, exercising every measured kernel so
//! regressions fail loudly without burning CI minutes.

mod common;

use std::path::Path;

use aphmm::baumwelch::{
    forward_sparse, forward_sparse_with, reference, score_sparse_with, score_striped_with, train,
    BandedCoeffs, BandedEngine, BwAccumulators, FilterConfig, ForwardOptions, ForwardScratch,
    FusedCoeffs, GatherKind, ScratchMode, SimdPolicy, TrainConfig, MAX_STRIPE,
};
use aphmm::coordinator::StageSummary;
use aphmm::seq::Sequence;
use aphmm::phmm::{EcDesignParams, Phmm};
use aphmm::runtime::{ArtifactStore, XlaBandedEngine};
use aphmm::server::{Request, Server, ServerConfig};

/// One comparison row of the machine-readable bench report.
struct BenchRow {
    name: &'static str,
    baseline_s: f64,
    new_s: f64,
}

/// Serialize the rows as `BENCH_hotpath.json` (no serde: the crate is
/// dependency-free, and the schema is flat).
fn write_bench_json(rows: &[BenchRow], stages: &[StageSummary], short: bool, chunk: usize) {
    let mut s = String::from("{\n");
    s.push_str("  \"bench\": \"hotpath\",\n");
    s.push_str(&format!("  \"short_mode\": {short},\n"));
    s.push_str(&format!("  \"chunk_bases\": {chunk},\n"));
    s.push_str("  \"rows\": [\n");
    for (i, r) in rows.iter().enumerate() {
        let sep = if i + 1 == rows.len() { "" } else { "," };
        s.push_str(&format!(
            "    {{\"name\": \"{}\", \"baseline_ns\": {:.0}, \"new_ns\": {:.0}, \
             \"speedup\": {:.4}}}{sep}\n",
            r.name,
            r.baseline_s * 1e9,
            r.new_s * 1e9,
            r.baseline_s / r.new_s
        ));
    }
    s.push_str("  ],\n");
    // Serving-layer stage accounting (the observability PR): one entry
    // per `aphmm_stage_seconds{stage=...}` family member, from the same
    // MetricsSummary the `metrics` wire command renders.  CI greps for
    // these rows to pin the stage histograms end-to-end.
    s.push_str("  \"stages\": [\n");
    for (i, st) in stages.iter().enumerate() {
        let sep = if i + 1 == stages.len() { "" } else { "," };
        s.push_str(&format!(
            "    {{\"stage\": \"{}\", \"count\": {}, \"total_ns\": {:.0}, \
             \"p50_ms\": {:.4}, \"p99_ms\": {:.4}}}{sep}\n",
            st.stage,
            st.count,
            st.total_seconds * 1e9,
            st.p50_ms,
            st.p99_ms
        ));
    }
    s.push_str("  ]\n}\n");
    match std::fs::write("BENCH_hotpath.json", &s) {
        Ok(()) => println!("\nwrote BENCH_hotpath.json ({} rows)", rows.len()),
        Err(e) => println!("\nWARNING: could not write BENCH_hotpath.json: {e}"),
    }
}

fn main() {
    let short = std::env::var("APHMM_BENCH_SHORT").is_ok();
    let reps = if short { 2 } else { 7 };
    let reps_small = if short { 2 } else { 5 };
    let chunk = if short { 160 } else { 650 };
    let mut rows: Vec<BenchRow> = Vec::new();

    common::banner(if short {
        "hot paths (SHORT smoke mode)"
    } else {
        "hot paths (median of 5)"
    });
    let scenario = common::ec_scenario(3, chunk, 1);
    let graph =
        Phmm::error_correction(&scenario.reference, &EcDesignParams::default()).unwrap();
    let read = &scenario.reads[0];

    // === memoized fused-coefficient kernels vs the pre-memoization
    // === reference (paper §4.2–4.3; the acceptance metric of the
    // === optimization — see EXPERIMENTS.md §Perf / ROADMAP open items)
    common::banner("memoized kernels vs pre-memoization reference (EC workload)");
    let coeffs = FusedCoeffs::new(&graph);
    let mut scratch = ForwardScratch::new(&graph);
    let opts_m = ForwardOptions::default();

    let t_ref_f = common::time_median(reps, || {
        reference::forward_sparse_reference(&graph, read, &opts_m).unwrap();
    });
    let t_new_f = common::time_median(reps, || {
        let fwd = forward_sparse_with(&graph, &coeffs, read, &opts_m, &mut scratch).unwrap();
        scratch.recycle(fwd);
    });
    println!(
        "forward:          reference {:>9.3} ms -> memoized {:>9.3} ms  ({:.2}x)",
        t_ref_f * 1e3,
        t_new_f * 1e3,
        t_ref_f / t_new_f
    );
    rows.push(BenchRow { name: "forward", baseline_s: t_ref_f, new_s: t_new_f });

    let fwd_m = forward_sparse_with(&graph, &coeffs, read, &opts_m, &mut scratch).unwrap();
    let t_ref_b = common::time_median(reps, || {
        let mut acc = BwAccumulators::new(&graph);
        reference::accumulate_reference(&mut acc, &graph, read, &fwd_m).unwrap();
    });
    let t_new_b = common::time_median(reps, || {
        let mut acc = BwAccumulators::new(&graph);
        acc.accumulate_with(&graph, &coeffs, read, &fwd_m, &mut scratch, &opts_m).unwrap();
    });
    println!(
        "backward+update:  reference {:>9.3} ms -> memoized {:>9.3} ms  ({:.2}x)",
        t_ref_b * 1e3,
        t_new_b * 1e3,
        t_ref_b / t_new_b
    );
    rows.push(BenchRow { name: "backward+update", baseline_s: t_ref_b, new_s: t_new_b });
    println!(
        "combined fwd+bwd: {:.2}x speedup vs pre-memoization kernels",
        (t_ref_f + t_ref_b) / (t_new_f + t_new_b)
    );
    rows.push(BenchRow {
        name: "combined fwd+bwd",
        baseline_s: t_ref_f + t_ref_b,
        new_s: t_new_f + t_new_b,
    });

    // Fresh scratch so the row counter reflects the score kernel alone.
    let mut score_scratch = ForwardScratch::new(&graph);
    let t_score = common::time_median(reps, || {
        score_sparse_with(&graph, &coeffs, read, &opts_m, &mut score_scratch).unwrap();
    });
    println!(
        "score-only path:  {:>9.3} ms (O(active states) memory, {} fresh rows ever)",
        t_score * 1e3,
        score_scratch.fresh_rows_allocated()
    );
    scratch.recycle(fwd_m);

    // === in-window gather: CSR vs the dense-tile kernel of the
    // === lowering layer (bit-identical rows; see baumwelch::lowering).
    // === Adaptive dispatch must track the better of the two — it is
    // === the default, so a loss here is a production regression.
    common::banner("in-window gather: csr vs dense tile (lowering layer)");
    let opts_csr = ForwardOptions { gather: GatherKind::Csr, ..Default::default() };
    let opts_tile = ForwardOptions { gather: GatherKind::DenseTile, ..Default::default() };
    let opts_adapt = ForwardOptions { gather: GatherKind::Adaptive, ..Default::default() };
    // Warm the lazy tile tables outside the timed region: the build is
    // a once-per-freeze cost amortized over a whole batch, not part of
    // the per-read gather this row measures (in short mode the 2-rep
    // median would otherwise absorb it).
    let warm = forward_sparse_with(&graph, &coeffs, read, &opts_tile, &mut scratch).unwrap();
    scratch.recycle(warm);
    let t_g_csr = common::time_median(reps, || {
        let fwd = forward_sparse_with(&graph, &coeffs, read, &opts_csr, &mut scratch).unwrap();
        scratch.recycle(fwd);
    });
    let t_g_tile = common::time_median(reps, || {
        let fwd = forward_sparse_with(&graph, &coeffs, read, &opts_tile, &mut scratch).unwrap();
        scratch.recycle(fwd);
    });
    let t_g_adapt = common::time_median(reps, || {
        let fwd = forward_sparse_with(&graph, &coeffs, read, &opts_adapt, &mut scratch).unwrap();
        scratch.recycle(fwd);
    });
    println!(
        "window gather: csr {:>9.3} ms -> dense tile {:>9.3} ms  ({:.2}x)",
        t_g_csr * 1e3,
        t_g_tile * 1e3,
        t_g_csr / t_g_tile
    );
    println!(
        "window gather (adaptive):       {:>9.3} ms  ({:.2}x vs csr; loses if < 1.00)",
        t_g_adapt * 1e3,
        t_g_csr / t_g_adapt
    );
    rows.push(BenchRow { name: "window gather", baseline_s: t_g_csr, new_s: t_g_tile });
    rows.push(BenchRow {
        name: "window gather adaptive",
        baseline_s: t_g_csr,
        new_s: t_g_adapt,
    });

    // === the regime the tile kernel is built FOR: a structurally
    // === near-dense band (occupancy ≥ TILE_MIN_OCCUPANCY, like folded
    // === traditional profiles).  The EC rows above are occupancy-gated
    // === to CSR, so without this block the tile win — and any
    // === regression of it — would never be measured anywhere.
    common::banner("in-window gather on a near-dense band (tile regime)");
    let dense_graph = aphmm::testutil::dense_band_phmm(2 * chunk);
    let dense_coeffs = FusedCoeffs::new(&dense_graph);
    assert!(
        dense_coeffs.lowering().tile_eligible(),
        "dense-band bench graph must pass the occupancy gate"
    );
    let warm =
        forward_sparse_with(&dense_graph, &dense_coeffs, read, &opts_tile, &mut scratch).unwrap();
    scratch.recycle(warm);
    let warm =
        forward_sparse_with(&dense_graph, &dense_coeffs, read, &opts_adapt, &mut scratch).unwrap();
    assert!(
        warm.filter_stats.rows_dense_tile > 0,
        "adaptive dispatch must reach the tile kernel on the dense band"
    );
    scratch.recycle(warm);
    let t_d_csr = common::time_median(reps, || {
        let fwd =
            forward_sparse_with(&dense_graph, &dense_coeffs, read, &opts_csr, &mut scratch)
                .unwrap();
        scratch.recycle(fwd);
    });
    let t_d_tile = common::time_median(reps, || {
        let fwd =
            forward_sparse_with(&dense_graph, &dense_coeffs, read, &opts_tile, &mut scratch)
                .unwrap();
        scratch.recycle(fwd);
    });
    let t_d_adapt = common::time_median(reps, || {
        let fwd =
            forward_sparse_with(&dense_graph, &dense_coeffs, read, &opts_adapt, &mut scratch)
                .unwrap();
        scratch.recycle(fwd);
    });
    println!(
        "window gather (dense band): csr {:>9.3} ms -> dense tile {:>9.3} ms  ({:.2}x)",
        t_d_csr * 1e3,
        t_d_tile * 1e3,
        t_d_csr / t_d_tile
    );
    println!(
        "window gather (dense band, adaptive): {:>9.3} ms  ({:.2}x vs csr)",
        t_d_adapt * 1e3,
        t_d_csr / t_d_adapt
    );
    rows.push(BenchRow {
        name: "window gather dense-band",
        baseline_s: t_d_csr,
        new_s: t_d_tile,
    });
    rows.push(BenchRow {
        name: "window gather dense-band adaptive",
        baseline_s: t_d_csr,
        new_s: t_d_adapt,
    });

    // === explicit simd lanes over the dense-tile dot product: the
    // === scalar lane shim vs the widest lane width this host resolves
    // === (`SimdPolicy::Auto`; `APHMM_SIMD` overrides).  Measured in
    // === the tile regime — on occupancy-gated CSR rows the lane
    // === policy is a no-op by construction.
    common::banner("explicit simd lanes on the dense-tile kernel");
    let wide = SimdPolicy::Auto.resolve();
    let opts_lane_scalar = ForwardOptions {
        gather: GatherKind::DenseTile,
        simd: SimdPolicy::Scalar,
        ..Default::default()
    };
    let opts_lane_wide = ForwardOptions {
        gather: GatherKind::DenseTile,
        simd: SimdPolicy::Auto,
        ..Default::default()
    };
    let t_lane_scalar = common::time_median(reps, || {
        let fwd =
            forward_sparse_with(&dense_graph, &dense_coeffs, read, &opts_lane_scalar, &mut scratch)
                .unwrap();
        scratch.recycle(fwd);
    });
    let t_lane_wide = common::time_median(reps, || {
        let fwd =
            forward_sparse_with(&dense_graph, &dense_coeffs, read, &opts_lane_wide, &mut scratch)
                .unwrap();
        scratch.recycle(fwd);
    });
    println!(
        "simd lanes: scalar {:>9.3} ms -> {} {:>9.3} ms  ({:.2}x)",
        t_lane_scalar * 1e3,
        wide.name(),
        t_lane_wide * 1e3,
        t_lane_scalar / t_lane_wide
    );
    rows.push(BenchRow { name: "simd lanes", baseline_s: t_lane_scalar, new_s: t_lane_wide });

    // === striped multi-read batch kernel: K same-profile reads in one
    // === lock-step pass over the frozen tables vs scoring them one at
    // === a time (the server's Score micro-batch and the batch E-step
    // === inner loop).  Per-read results are asserted bit-identical to
    // === the one-at-a-time kernel before timing — a fast wrong answer
    // === must not make it into the perf log.
    common::banner("striped multi-read batch scoring (K same-profile reads)");
    let stripe_scn = common::ec_scenario(3, chunk, MAX_STRIPE);
    assert_eq!(
        stripe_scn.reference.data, scenario.reference.data,
        "stripe scenario must share the bench profile's reference"
    );
    let stripe_refs: Vec<&Sequence> = stripe_scn.reads.iter().collect();
    let solo_bits: Vec<u64> = stripe_refs
        .iter()
        .map(|r| {
            score_sparse_with(&graph, &coeffs, r, &opts_m, &mut scratch)
                .unwrap()
                .loglik
                .to_bits()
        })
        .collect();
    for (i, res) in score_striped_with(&graph, &coeffs, &stripe_refs, &opts_m, &mut scratch)
        .iter()
        .enumerate()
    {
        assert_eq!(
            res.as_ref().unwrap().loglik.to_bits(),
            solo_bits[i],
            "striped slot {i} diverged from the one-at-a-time kernel"
        );
    }
    let t_solo_batch = common::time_median(reps, || {
        for r in &stripe_refs {
            score_sparse_with(&graph, &coeffs, r, &opts_m, &mut scratch).unwrap();
        }
    });
    let t_striped_batch = common::time_median(reps, || {
        for res in score_striped_with(&graph, &coeffs, &stripe_refs, &opts_m, &mut scratch) {
            res.unwrap();
        }
    });
    println!(
        "striped batch: 1-read {:>9.3} ms -> {}-read {:>9.3} ms  ({:.2}x)",
        t_solo_batch * 1e3,
        stripe_refs.len(),
        t_striped_batch * 1e3,
        t_solo_batch / t_striped_batch
    );
    rows.push(BenchRow {
        name: "striped batch",
        baseline_s: t_solo_batch,
        new_s: t_striped_batch,
    });

    // --- sparse forward, unfiltered ---
    let opts = ForwardOptions::default();
    let fwd = forward_sparse(&graph, read, &opts).unwrap();
    let edges = fwd.edges_processed as f64;
    let t = common::time_median(reps_small, || {
        forward_sparse(&graph, read, &opts).unwrap();
    });
    println!(
        "forward_sparse (no filter):     {:>9.3} ms  {:>7.2} ns/edge  ({} edges)",
        t * 1e3,
        t * 1e9 / edges,
        edges as u64
    );

    // --- sparse forward, histogram filter ---
    let opts_h =
        ForwardOptions { filter: FilterConfig::histogram_default(), ..Default::default() };
    let fwd_h = forward_sparse(&graph, read, &opts_h).unwrap();
    let t = common::time_median(reps_small, || {
        forward_sparse(&graph, read, &opts_h).unwrap();
    });
    println!(
        "forward_sparse (histogram):     {:>9.3} ms  {:>7.2} ns/edge  ({} edges)",
        t * 1e3,
        t * 1e9 / fwd_h.edges_processed as f64,
        fwd_h.edges_processed
    );

    // --- sparse forward, sort filter ---
    let opts_s = ForwardOptions { filter: FilterConfig::Sort { size: 500 }, ..Default::default() };
    let fwd_s = forward_sparse(&graph, read, &opts_s).unwrap();
    let t = common::time_median(reps_small, || {
        forward_sparse(&graph, read, &opts_s).unwrap();
    });
    println!(
        "forward_sparse (sort):          {:>9.3} ms  {:>7.2} ns/edge  ({} edges)",
        t * 1e3,
        t * 1e9 / fwd_s.edges_processed as f64,
        fwd_s.edges_processed
    );

    // --- fused backward + update ---
    let t = common::time_median(reps_small, || {
        let mut acc = BwAccumulators::new(&graph);
        acc.accumulate(&graph, read, &fwd).unwrap();
    });
    println!(
        "backward+update (fused):        {:>9.3} ms  {:>7.2} ns/edge",
        t * 1e3,
        t * 1e9 / edges
    );

    // === banded engine: fused coefficient tables vs the pre-refactor
    // === scan (the ROADMAP "coefficient tables for the banded engine"
    // === candidate; parity pinned by tests/engine_matrix.rs)
    common::banner("banded engine: fused tables vs pre-refactor scan");
    let banded = graph.to_banded().unwrap();
    let bcoeffs = BandedCoeffs::new(&banded);
    let dense_ops = (banded.n * banded.w * read.len()) as f64;

    let t_band_f_old = common::time_median(reps_small, || {
        BandedEngine::forward(&banded, read).unwrap();
    });
    let t_band_f_new = common::time_median(reps_small, || {
        BandedEngine::forward_with(&banded, &bcoeffs, read).unwrap();
    });
    println!(
        "banded forward:   scan {:>9.3} ms -> fused {:>9.3} ms  ({:.2}x)",
        t_band_f_old * 1e3,
        t_band_f_new * 1e3,
        t_band_f_old / t_band_f_new
    );
    rows.push(BenchRow {
        name: "banded forward",
        baseline_s: t_band_f_old,
        new_s: t_band_f_new,
    });

    let t_band_s_old = common::time_median(reps_small, || {
        BandedEngine::bw_sums(&banded, read).unwrap();
    });
    let t_band_s_new = common::time_median(reps_small, || {
        BandedEngine::bw_sums_with(&banded, &bcoeffs, read).unwrap();
    });
    println!(
        "banded bw_sums:   scan {:>9.3} ms -> fused {:>9.3} ms  ({:.2}x)  {:>7.2} ns/band-op ({} ops)",
        t_band_s_old * 1e3,
        t_band_s_new * 1e3,
        t_band_s_old / t_band_s_new,
        t_band_s_new * 1e9 / dense_ops,
        dense_ops as u64
    );
    rows.push(BenchRow {
        name: "banded bw_sums",
        baseline_s: t_band_s_old,
        new_s: t_band_s_new,
    });

    // --- XLA runtime path (T=128 artifacts -> short read) ---
    let dir = Path::new("artifacts");
    if dir.join("manifest.txt").exists() {
        let store = ArtifactStore::load(dir).unwrap();
        let short_scn = common::ec_scenario(4, 100, 1);
        let g2 = Phmm::error_correction(&short_scn.reference, &EcDesignParams::default()).unwrap();
        let b2 = g2.to_banded().unwrap();
        let r2 = &short_scn.reads[0];
        let engine = XlaBandedEngine::for_shape(&store, b2.n, b2.w, b2.sigma, r2.len()).unwrap();
        engine.bw_sums(&b2, r2).unwrap(); // warm up
        let t = common::time_median(reps_small, || {
            engine.bw_sums(&b2, r2).unwrap();
        });
        let t_native = common::time_median(reps_small, || {
            BandedEngine::bw_sums(&b2, r2).unwrap();
        });
        println!(
            "xla bw_sums (N=512 artifact):   {:>9.3} ms  (native banded same shape: {:.3} ms)",
            t * 1e3,
            t_native * 1e3
        );
    } else {
        println!("xla bw_sums: skipped (run `make artifacts`)");
    }

    // === checkpointed scratch: full-matrix vs √T-checkpoint recompute
    // === on a long read (the linear-memory Baum-Welch mode).  Results
    // === are bit-identical by contract (pinned by
    // === tests/engine_matrix.rs); these rows record the time cost of
    // === recomputing each segment's forward rows and the peak-scratch
    // === reduction that pays for it.
    common::banner("checkpointed scratch: full matrix vs sqrt(T) recompute (long read)");
    let long_len = if short { 1_500 } else { 8_000 };
    let mut lr_rng = aphmm::sim::XorShift::new(41);
    let long_ref = aphmm::sim::generate_genome(&mut lr_rng, long_len);
    let long_read = aphmm::sim::simulate_ultralong_read(&mut lr_rng, &long_ref, 0, long_len, 0).seq;
    let long_graph = Phmm::error_correction(&long_ref, &EcDesignParams::default()).unwrap();
    let ckpt_cfg = TrainConfig {
        max_iters: 1,
        filter: FilterConfig::histogram_default(),
        ..Default::default()
    };
    let run_mode = |mode: ScratchMode| {
        let mut g = long_graph.clone();
        train(
            &mut g,
            std::slice::from_ref(&long_read),
            &TrainConfig { scratch_mode: mode, ..ckpt_cfg },
        )
        .unwrap()
    };
    let full_res = run_mode(ScratchMode::Full);
    let ckpt_res = run_mode(ScratchMode::Checkpointed);
    assert_eq!(
        full_res.loglik_history.iter().map(|l| l.to_bits()).collect::<Vec<_>>(),
        ckpt_res.loglik_history.iter().map(|l| l.to_bits()).collect::<Vec<_>>(),
        "checkpointed training diverged from the full matrix — a fast wrong answer \
         must not make it into the perf log"
    );
    let t_ckpt_full = common::time_median(reps_small, || {
        run_mode(ScratchMode::Full);
    });
    let t_ckpt_new = common::time_median(reps_small, || {
        run_mode(ScratchMode::Checkpointed);
    });
    println!(
        "checkpointed fwd+bwd: full {:>9.3} ms -> checkpointed {:>9.3} ms  ({:.2}x time, T={})",
        t_ckpt_full * 1e3,
        t_ckpt_new * 1e3,
        t_ckpt_full / t_ckpt_new,
        long_read.len()
    );
    println!(
        "checkpointed peak scratch: full {} B -> checkpointed {} B  ({:.1}x smaller)",
        full_res.peak_scratch_bytes,
        ckpt_res.peak_scratch_bytes,
        full_res.peak_scratch_bytes as f64 / ckpt_res.peak_scratch_bytes.max(1) as f64
    );
    rows.push(BenchRow {
        name: "checkpointed fwd+bwd",
        baseline_s: t_ckpt_full,
        new_s: t_ckpt_new,
    });
    // Bytes ride the ns fields (scaled so `*_ns` holds raw bytes); the
    // `speedup` field is the scratch-reduction factor CI tracks.
    rows.push(BenchRow {
        name: "checkpointed peak scratch bytes",
        baseline_s: full_res.peak_scratch_bytes as f64 * 1e-9,
        new_s: ckpt_res.peak_scratch_bytes.max(1) as f64 * 1e-9,
    });

    // === serving-layer stage accounting: drive a tiny in-process
    // === server through the striped Score path plus one training
    // === request, then report the per-stage timing rows the `metrics`
    // === wire command exposes (queue_wait / cache_freeze / forward /
    // === backward / update).  CI greps these out of the JSON.
    common::banner("serving stage accounting (per-stage histograms)");
    let stage_scn = common::ec_scenario(5, if short { 80 } else { 200 }, MAX_STRIPE);
    let profile =
        Phmm::error_correction(&stage_scn.reference, &EcDesignParams::default()).unwrap();
    let mut server = Server::start(ServerConfig {
        n_workers: 1,
        microbatch: MAX_STRIPE,
        ..Default::default()
    });
    server.register_profile("bench", profile);
    let tickets: Vec<_> = stage_scn
        .reads
        .iter()
        .map(|r| {
            server
                .submit(None, Request::Score { profile: "bench".into(), read: r.clone() })
                .unwrap()
        })
        .collect();
    let correct = server
        .submit(
            None,
            Request::Correct {
                reference: stage_scn.reference.clone(),
                reads: stage_scn.reads.clone(),
            },
        )
        .unwrap();
    for t in tickets {
        t.wait();
    }
    correct.wait();
    let summary = server.metrics_summary();
    server.shutdown(true);
    for st in &summary.stages {
        println!(
            "stage {:<13} count={:<4} total={:>9.3} ms  p50={:>8.3} ms  p99={:>8.3} ms",
            st.stage,
            st.count,
            st.total_seconds * 1e3,
            st.p50_ms,
            st.p99_ms
        );
    }

    write_bench_json(&rows, &summary.stages, short, chunk);
}
