//! Hot-path microbenchmarks (the §Perf instrumentation): per-edge and
//! per-state throughput of the forward pass, the fused
//! backward+update pass, both filters, the banded engine (pre-refactor
//! scan vs fused coefficient tables), and (when artifacts exist) the
//! XLA runtime path.  Used to drive and record the optimization
//! iterations in EXPERIMENTS.md §Perf.
//!
//! Set `APHMM_BENCH_SHORT=1` for the CI smoke mode: a smaller workload
//! and fewer repetitions, exercising every measured kernel so
//! regressions fail loudly without burning CI minutes.

mod common;

use std::path::Path;

use aphmm::baumwelch::{
    forward_sparse, forward_sparse_with, reference, score_sparse_with, BandedCoeffs,
    BandedEngine, BwAccumulators, FilterConfig, ForwardOptions, ForwardScratch, FusedCoeffs,
};
use aphmm::phmm::{EcDesignParams, Phmm};
use aphmm::runtime::{ArtifactStore, XlaBandedEngine};

fn main() {
    let short = std::env::var("APHMM_BENCH_SHORT").is_ok();
    let reps = if short { 2 } else { 7 };
    let reps_small = if short { 2 } else { 5 };
    let chunk = if short { 160 } else { 650 };

    common::banner(if short {
        "hot paths (SHORT smoke mode)"
    } else {
        "hot paths (median of 5)"
    });
    let scenario = common::ec_scenario(3, chunk, 1);
    let graph =
        Phmm::error_correction(&scenario.reference, &EcDesignParams::default()).unwrap();
    let read = &scenario.reads[0];

    // === memoized fused-coefficient kernels vs the pre-memoization
    // === reference (paper §4.2–4.3; the acceptance metric of the
    // === optimization — see EXPERIMENTS.md §Perf / ROADMAP open items)
    common::banner("memoized kernels vs pre-memoization reference (EC workload)");
    let coeffs = FusedCoeffs::new(&graph);
    let mut scratch = ForwardScratch::new(&graph);
    let opts_m = ForwardOptions::default();

    let t_ref_f = common::time_median(reps, || {
        reference::forward_sparse_reference(&graph, read, &opts_m).unwrap();
    });
    let t_new_f = common::time_median(reps, || {
        let fwd = forward_sparse_with(&graph, &coeffs, read, &opts_m, &mut scratch).unwrap();
        scratch.recycle(fwd);
    });
    println!(
        "forward:          reference {:>9.3} ms -> memoized {:>9.3} ms  ({:.2}x)",
        t_ref_f * 1e3,
        t_new_f * 1e3,
        t_ref_f / t_new_f
    );

    let fwd_m = forward_sparse_with(&graph, &coeffs, read, &opts_m, &mut scratch).unwrap();
    let t_ref_b = common::time_median(reps, || {
        let mut acc = BwAccumulators::new(&graph);
        reference::accumulate_reference(&mut acc, &graph, read, &fwd_m).unwrap();
    });
    let t_new_b = common::time_median(reps, || {
        let mut acc = BwAccumulators::new(&graph);
        acc.accumulate_with(&graph, &coeffs, read, &fwd_m, &mut scratch).unwrap();
    });
    println!(
        "backward+update:  reference {:>9.3} ms -> memoized {:>9.3} ms  ({:.2}x)",
        t_ref_b * 1e3,
        t_new_b * 1e3,
        t_ref_b / t_new_b
    );
    println!(
        "combined fwd+bwd: {:.2}x speedup vs pre-memoization kernels",
        (t_ref_f + t_ref_b) / (t_new_f + t_new_b)
    );

    // Fresh scratch so the row counter reflects the score kernel alone.
    let mut score_scratch = ForwardScratch::new(&graph);
    let t_score = common::time_median(reps, || {
        score_sparse_with(&graph, &coeffs, read, &opts_m, &mut score_scratch).unwrap();
    });
    println!(
        "score-only path:  {:>9.3} ms (O(active states) memory, {} fresh rows ever)",
        t_score * 1e3,
        score_scratch.fresh_rows_allocated()
    );
    scratch.recycle(fwd_m);

    // --- sparse forward, unfiltered ---
    let opts = ForwardOptions::default();
    let fwd = forward_sparse(&graph, read, &opts).unwrap();
    let edges = fwd.edges_processed as f64;
    let t = common::time_median(reps_small, || {
        forward_sparse(&graph, read, &opts).unwrap();
    });
    println!(
        "forward_sparse (no filter):     {:>9.3} ms  {:>7.2} ns/edge  ({} edges)",
        t * 1e3,
        t * 1e9 / edges,
        edges as u64
    );

    // --- sparse forward, histogram filter ---
    let opts_h = ForwardOptions { filter: FilterConfig::histogram_default() };
    let fwd_h = forward_sparse(&graph, read, &opts_h).unwrap();
    let t = common::time_median(reps_small, || {
        forward_sparse(&graph, read, &opts_h).unwrap();
    });
    println!(
        "forward_sparse (histogram):     {:>9.3} ms  {:>7.2} ns/edge  ({} edges)",
        t * 1e3,
        t * 1e9 / fwd_h.edges_processed as f64,
        fwd_h.edges_processed
    );

    // --- sparse forward, sort filter ---
    let opts_s = ForwardOptions { filter: FilterConfig::Sort { size: 500 } };
    let fwd_s = forward_sparse(&graph, read, &opts_s).unwrap();
    let t = common::time_median(reps_small, || {
        forward_sparse(&graph, read, &opts_s).unwrap();
    });
    println!(
        "forward_sparse (sort):          {:>9.3} ms  {:>7.2} ns/edge  ({} edges)",
        t * 1e3,
        t * 1e9 / fwd_s.edges_processed as f64,
        fwd_s.edges_processed
    );

    // --- fused backward + update ---
    let t = common::time_median(reps_small, || {
        let mut acc = BwAccumulators::new(&graph);
        acc.accumulate(&graph, read, &fwd).unwrap();
    });
    println!(
        "backward+update (fused):        {:>9.3} ms  {:>7.2} ns/edge",
        t * 1e3,
        t * 1e9 / edges
    );

    // === banded engine: fused coefficient tables vs the pre-refactor
    // === scan (the ROADMAP "coefficient tables for the banded engine"
    // === candidate; parity pinned by tests/engine_matrix.rs)
    common::banner("banded engine: fused tables vs pre-refactor scan");
    let banded = graph.to_banded().unwrap();
    let bcoeffs = BandedCoeffs::new(&banded);
    let dense_ops = (banded.n * banded.w * read.len()) as f64;

    let t_band_f_old = common::time_median(reps_small, || {
        BandedEngine::forward(&banded, read).unwrap();
    });
    let t_band_f_new = common::time_median(reps_small, || {
        BandedEngine::forward_with(&banded, &bcoeffs, read).unwrap();
    });
    println!(
        "banded forward:   scan {:>9.3} ms -> fused {:>9.3} ms  ({:.2}x)",
        t_band_f_old * 1e3,
        t_band_f_new * 1e3,
        t_band_f_old / t_band_f_new
    );

    let t_band_s_old = common::time_median(reps_small, || {
        BandedEngine::bw_sums(&banded, read).unwrap();
    });
    let t_band_s_new = common::time_median(reps_small, || {
        BandedEngine::bw_sums_with(&banded, &bcoeffs, read).unwrap();
    });
    println!(
        "banded bw_sums:   scan {:>9.3} ms -> fused {:>9.3} ms  ({:.2}x)  {:>7.2} ns/band-op ({} ops)",
        t_band_s_old * 1e3,
        t_band_s_new * 1e3,
        t_band_s_old / t_band_s_new,
        t_band_s_new * 1e9 / dense_ops,
        dense_ops as u64
    );

    // --- XLA runtime path (T=128 artifacts -> short read) ---
    let dir = Path::new("artifacts");
    if dir.join("manifest.txt").exists() {
        let store = ArtifactStore::load(dir).unwrap();
        let short_scn = common::ec_scenario(4, 100, 1);
        let g2 = Phmm::error_correction(&short_scn.reference, &EcDesignParams::default()).unwrap();
        let b2 = g2.to_banded().unwrap();
        let r2 = &short_scn.reads[0];
        let engine = XlaBandedEngine::for_shape(&store, b2.n, b2.w, b2.sigma, r2.len()).unwrap();
        engine.bw_sums(&b2, r2).unwrap(); // warm up
        let t = common::time_median(reps_small, || {
            engine.bw_sums(&b2, r2).unwrap();
        });
        let t_native = common::time_median(reps_small, || {
            BandedEngine::bw_sums(&b2, r2).unwrap();
        });
        println!(
            "xla bw_sums (N=512 artifact):   {:>9.3} ms  (native banded same shape: {:.3} ms)",
            t * 1e3,
            t_native * 1e3
        );
    } else {
        println!("xla bw_sums: skipped (run `make artifacts`)");
    }
}
