//! Fig. 10 — (a) speedups of each Baum-Welch step over the
//! single-threaded CPU baseline (CPU-1), for ApHMM / GPU / FPGA;
//! (b) energy reductions.  Paper: ApHMM 15.55–260× vs CPU, 1.83–5.34×
//! vs GPU, 27.97× vs FPGA; energy 2474× (CPU) / 896.7–2622.94× (GPU).
//!
//! CPU-1 is genuinely measured: the sparse engine's step timings on a
//! canonical EC training workload.  GPU/FPGA points are paper-calibrated
//! models (DESIGN.md substitution table).

mod common;

use aphmm::accel::{cycles, AccelConfig, Baselines, CpuMeasurement, StepKind, Workload};
use aphmm::baumwelch::{train, FilterConfig, TrainConfig};
use aphmm::phmm::{EcDesignParams, Phmm};

fn main() {
    common::banner("Fig. 10a: Baum-Welch step speedups over CPU-1");
    // Measured CPU-1 workload: one EC chunk trained with 10 reads.
    let scenario = common::ec_scenario(21, 650, 10);
    let mut graph =
        Phmm::error_correction(&scenario.reference, &EcDesignParams::default()).unwrap();
    let cfg = TrainConfig {
        max_iters: 2,
        tol: 0.0,
        filter: FilterConfig::Sort { size: 500 },
        ..Default::default()
    };
    let res = train(&mut graph, &scenario.reads, &cfg).unwrap();

    let wl_all = Workload::from_train_result(&graph, &res, scenario.reads.len() as u64);
    let acfg = AccelConfig::default();

    // Per-step CPU-1 seconds (measured) and ApHMM cycles (modeled).
    let cpu_fwd = res.forward_ns as f64 / 1e9;
    let cpu_bwd_upd = res.backward_update_ns as f64 / 1e9;
    let cpu_max = res.maximize_ns as f64 / 1e9;
    let bd = cycles(&acfg, &wl_all);
    let ap_fwd = acfg.cycles_to_seconds(bd.forward);
    let ap_bwd_upd = acfg.cycles_to_seconds(bd.backward + bd.update);

    println!("{:<22} {:>12} {:>12} {:>10}", "step", "CPU-1 (s)", "ApHMM (s)", "speedup");
    println!("{:<22} {:>12.4} {:>12.6} {:>9.1}x", "Forward", cpu_fwd, ap_fwd, cpu_fwd / ap_fwd);
    println!(
        "{:<22} {:>12.4} {:>12.6} {:>9.1}x",
        "Backward+Updates",
        cpu_bwd_upd + cpu_max,
        ap_bwd_upd,
        (cpu_bwd_upd + cpu_max) / ap_bwd_upd
    );
    let cpu_total = cpu_fwd + cpu_bwd_upd + cpu_max;
    let ap_total = bd.seconds(&acfg);
    println!(
        "{:<22} {:>12.4} {:>12.6} {:>9.1}x",
        "complete Baum-Welch", cpu_total, ap_total, cpu_total / ap_total
    );

    common::banner("Fig. 10a (platforms): complete Baum-Welch");
    let base = Baselines::from_cpu_measurement(
        &acfg,
        &wl_all,
        &CpuMeasurement { seconds: cpu_total, filter_fraction: 0.085 },
    );
    let (s_cpu, s_gpu, s_fpga) = base.speedups();
    println!("{:<14} {:>12} {:>10}", "platform", "time (s)", "vs ApHMM");
    println!("{:<14} {:>12.4} {:>9.1}x", "CPU-1", base.cpu_s, s_cpu);
    println!("{:<14} {:>12.6} {:>9.2}x", "GPU (model)", base.gpu_s, s_gpu);
    println!("{:<14} {:>12.6} {:>9.2}x", "FPGA (model)", base.fpga_s, s_fpga);
    println!("{:<14} {:>12.6} {:>9.2}x", "ApHMM", base.aphmm_s, 1.0);
    println!("paper: 15.55-260x (CPU), 1.83-5.34x (GPU), 27.97x (FPGA)");

    common::banner("Fig. 10b: energy reductions");
    let (e_cpu, e_gpu) = base.energy_reductions();
    println!("{:<14} {:>12} {:>12}", "platform", "energy (J)", "vs ApHMM");
    println!("{:<14} {:>12.3} {:>11.0}x", "CPU-1", base.cpu_j, e_cpu);
    println!("{:<14} {:>12.4} {:>11.0}x", "GPU (model)", base.gpu_j, e_gpu);
    println!("{:<14} {:>12.6} {:>11.1}x", "ApHMM", base.aphmm_j, 1.0);
    println!("paper: 2474x (CPU), 896.7-2622.94x (GPU)");

    // Forward-only contrast (paper's fifth observation: GPUs win there).
    common::banner("Forward-only contrast");
    let mut wl_fwd = wl_all;
    wl_fwd.steps = StepKind::Forward;
    let fo = Baselines::from_cpu_measurement(
        &acfg,
        &wl_fwd,
        &CpuMeasurement { seconds: cpu_fwd, filter_fraction: 0.0 },
    );
    println!(
        "forward-only: GPU(model) {:.6}s vs ApHMM {:.6}s -> GPU {}",
        fo.gpu_s,
        fo.aphmm_s,
        if fo.gpu_s < fo.aphmm_s { "wins (matches paper obs. 5)" } else { "loses" }
    );
}
