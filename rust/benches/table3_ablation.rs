//! Table 3 — speedup contributed by each ApHMM optimization (paper:
//! histogram filter 1.07×, LUTs 2.48×, broadcasting+partial compute
//! 3.39×, memoization 1.69×, overall 15.20× over CPU).
//!
//! Hardware-side factors come from the cycle model (disable one
//! optimization at a time); the histogram-filter factor is measured
//! from the real software engines (sort cost removed vs overshoot
//! added); the overall row combines the modeled ApHMM core against the
//! measured CPU-1 engine.

mod common;

use aphmm::accel::{cycles, AccelConfig, OptToggles, Workload};
use aphmm::baumwelch::{train, FilterConfig, TrainConfig};
use aphmm::phmm::{EcDesignParams, Phmm};

fn main() {
    common::banner("Table 3: speedup of each optimization");
    let wl = Workload::ec_canonical();
    let all_on = cycles(&AccelConfig::default(), &wl).total();
    let factor = |opt: OptToggles| {
        let mut cfg = AccelConfig::default();
        cfg.opt = opt;
        cycles(&cfg, &wl).total() / all_on
    };

    // Histogram filter: measured on the real engine — sort-filter train
    // time vs histogram-filter train time on a scenario whose state
    // space actually exceeds the filter size (deletion-heavy design, as
    // in fig6b; with the default design the active set stays under 500
    // and neither filter does real work).
    let heavy = EcDesignParams {
        max_deletions: 8,
        t_del_total: 0.15,
        del_decay: 1.2,
        init_spread: 8,
        ..EcDesignParams::default()
    };
    let scenario = common::ec_scenario(5, 650, 8);
    let t_sort = common::time_median(3, || {
        let mut g = Phmm::error_correction(&scenario.reference, &heavy).unwrap();
        train(
            &mut g,
            &scenario.reads,
            &TrainConfig {
                max_iters: 1,
                tol: 0.0,
                filter: FilterConfig::Sort { size: 500 },
                ..Default::default()
            },
        )
        .unwrap();
    });
    let t_hist = common::time_median(3, || {
        let mut g = Phmm::error_correction(&scenario.reference, &heavy).unwrap();
        train(
            &mut g,
            &scenario.reads,
            &TrainConfig {
                max_iters: 1,
                tol: 0.0,
                filter: FilterConfig::histogram_default(),
                ..Default::default()
            },
        )
        .unwrap();
    });

    println!("{:<36} {:>10} {:>10}", "optimization", "this repo", "paper");
    println!(
        "{:<36} {:>9.2}x {:>10}",
        "Histogram Filter (measured, sw)",
        t_sort / t_hist,
        "1.07x"
    );
    println!(
        "{:<36} {:>9.2}x {:>10}",
        "LUTs",
        factor(OptToggles { luts: false, ..OptToggles::all() }),
        "2.48x"
    );
    println!(
        "{:<36} {:>9.2}x {:>10}",
        "Broadcasting and Partial Compute",
        factor(OptToggles { broadcast_partial: false, ..OptToggles::all() }),
        "3.39x"
    );
    println!(
        "{:<36} {:>9.2}x {:>10}",
        "Memoization",
        factor(OptToggles { memoization: false, ..OptToggles::all() }),
        "1.69x"
    );

    // Overall: measured CPU-1 vs modeled single-core ApHMM.
    let mut g = Phmm::error_correction(&scenario.reference, &EcDesignParams::default()).unwrap();
    let cfg = TrainConfig {
        max_iters: 2,
        tol: 0.0,
        filter: FilterConfig::Sort { size: 500 },
        ..Default::default()
    };
    let res = train(&mut g, &scenario.reads, &cfg).unwrap();
    let cpu_s =
        (res.forward_ns + res.backward_update_ns + res.maximize_ns) as f64 / 1e9;
    let wl_meas = Workload::from_train_result(&g, &res, scenario.reads.len() as u64);
    let acfg = AccelConfig::default();
    let ap_s = cycles(&acfg, &wl_meas).seconds(&acfg);
    println!("{:<36} {:>9.2}x {:>10}", "Overall (vs measured CPU-1)", cpu_s / ap_s, "15.20x");
}
