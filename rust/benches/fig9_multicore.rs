//! Fig. 9 — normalized end-to-end runtimes of multi-core ApHMM (1/2/4/8
//! cores) for the three applications; 4 cores is the paper's optimum.
//!
//! Application splits (CPU-other vs Baum-Welch) are *measured* from the
//! real Rust apps (the same runs as fig2), then projected through the
//! multi-core model.

mod common;

use aphmm::accel::{
    best_core_count, cycles, multicore_runtime, AccelConfig, AppSplit, Workload,
};
use aphmm::apps::{align_all, correct_assembly, CorrectionConfig, FamilyDb, MsaConfig, SearchConfig};
use aphmm::phmm::{Phmm, Profile, TraditionalParams};
use aphmm::seq::{Sequence, PROTEIN};
use aphmm::sim::{
    generate_families, generate_genome, simulate_reads, ErrorProfile, ProteinSimParams, XorShift,
};

fn project(name: &str, split: AppSplit, wl: &Workload) {
    let cfg = AccelConfig::default();
    let t1 = multicore_runtime(&cfg, wl, &split, 1).total();
    print!("{name:<22}");
    for cores in [1usize, 2, 4, 8] {
        let r = multicore_runtime(&cfg, wl, &split, cores);
        print!(" {:>8.3}", r.total() / t1);
    }
    let best = best_core_count(&cfg, wl, &split, 8);
    println!("   best: {best} cores");
}

fn main() {
    common::banner("Fig. 9: multi-core ApHMM normalized end-to-end runtime");
    println!("{:<22} {:>8} {:>8} {:>8} {:>8}", "application", "1", "2", "4", "8");

    // --- Error correction split (measured) ---
    let mut rng = XorShift::new(11);
    let truth = generate_genome(&mut rng, 20_000);
    let reads: Vec<Sequence> = simulate_reads(&mut rng, &truth, 8.0, 2500, &ErrorProfile::pacbio())
        .into_iter()
        .map(|r| r.seq)
        .collect();
    let report = correct_assembly(&truth, &reads, &CorrectionConfig::default()).unwrap();
    let (bw_s, other_s) = report.timings.split_seconds();
    let wl_ec = Workload {
        total_steps: report.timesteps,
        avg_active_states: report.states_processed as f64 / report.timesteps.max(1) as f64,
        avg_degree: report.edges_processed as f64 / report.states_processed.max(1) as f64,
        sigma: 4,
        n_states: 2600,
        chunk_len: 650,
        steps: aphmm::accel::StepKind::Training,
        n_sequences: report.reads_mapped as u64,
        n_iterations: 2,
    };
    project("error correction", AppSplit { cpu_other_s: other_s, cpu_bw_s: bw_s }, &wl_ec);

    // --- Protein search split (measured) ---
    let mut rng = XorShift::new(12);
    let families =
        generate_families(&mut rng, &ProteinSimParams { n_families: 32, ..Default::default() });
    let cfg = SearchConfig::default();
    let db = FamilyDb::build(&families, PROTEIN, &cfg).unwrap();
    let mut t = aphmm::apps::AppTimings::default();
    for q in 0..24 {
        let fam = &families[q % families.len()];
        let r = db.search(&fam.members[q % fam.members.len()], &cfg).unwrap();
        t.merge(&r.timings);
    }
    let (bw_s, other_s) = t.split_seconds();
    let wl_pro = Workload::protein_canonical();
    project("protein family search", AppSplit { cpu_other_s: other_s, cpu_bw_s: bw_s }, &wl_pro);

    // --- MSA split (measured) ---
    let mut rng = XorShift::new(13);
    let fam = generate_families(
        &mut rng,
        &ProteinSimParams { n_families: 1, members_per_family: 48, ..Default::default() },
    )
    .remove(0);
    let profile = Profile::from_members(&fam.members, fam.ancestor.len(), PROTEIN, 0.5);
    let phmm = Phmm::traditional(&profile, &TraditionalParams::default())
        .unwrap()
        .fold_silent(4)
        .unwrap();
    let rep = align_all(&phmm, &fam.members, &MsaConfig::default()).unwrap();
    let (bw_s, other_s) = rep.timings.split_seconds();
    project("MSA", AppSplit { cpu_other_s: other_s, cpu_bw_s: bw_s }, &wl_pro);

    let _ = cycles(&AccelConfig::default(), &wl_ec);
    println!("\npaper shape: 4 cores optimal; beyond that data movement dominates");
}
