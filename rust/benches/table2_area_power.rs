//! Table 2 — area and power breakdown of the ApHMM core
//! (paper: overall 6.536 mm², 509.8 mW at 28 nm / 1 GHz; UTs dominate
//! area at 77.98 %; Control+PEs dominate power).

mod common;

use aphmm::accel::{area_power, AccelConfig};

fn main() {
    common::banner("Table 2: area and power breakdown (28 nm, 1 GHz)");
    let ap = area_power(&AccelConfig::default());
    println!("{:<30} {:>12} {:>12}", "module", "area (mm^2)", "power (mW)");
    println!("{:<30} {:>12.3} {:>12.1}", "Control Block", ap.control_area_mm2, ap.control_power_mw);
    println!("{:<30} {:>12.3} {:>12.1}", "64 Processing Engines (PEs)", ap.pe_area_mm2, ap.pe_power_mw);
    println!("{:<30} {:>12.3} {:>12.1}", "64 Update Transitions (UTs)", ap.ut_area_mm2, ap.ut_power_mw);
    println!("{:<30} {:>12.3} {:>12.1}", "4 Update Emissions (UEs)", ap.ue_area_mm2, ap.ue_power_mw);
    println!("{:<30} {:>12.3} {:>12.1}", "Overall (core)", ap.core_area_mm2(), ap.core_power_mw());
    println!("{:<30} {:>12.3} {:>12.1}", "128KB L1-Memory", ap.l1_area_mm2, ap.l1_power_mw);
    println!(
        "\nUT share of core area: {:.2}% (paper: 77.98%)",
        ap.ut_area_mm2 / ap.core_area_mm2() * 100.0
    );
    println!(
        "Control+PE share of power: {:.1}% (paper: ~86% incl. memory activity)",
        (ap.control_power_mw + ap.pe_power_mw + ap.l1_power_mw) / ap.core_power_mw() * 100.0
    );
    println!("\nScale-up (4 cores): {:.2} mm^2, {:.2} W", ap.chip_area_mm2(4), ap.chip_power_w(4));
}
