//! Integration: the PJRT-executed AOT artifacts must match the native
//! Rust banded engine (which itself is oracle-tested against log-space
//! references) — the end-to-end proof that L1/L2/L3 compose.
//!
//! Requires `make artifacts` to have produced `artifacts/`.

use std::path::Path;

use aphmm::baumwelch::BandedEngine;
use aphmm::phmm::{EcDesignParams, Phmm, Profile, TraditionalParams};
use aphmm::runtime::{ArtifactStore, XlaBandedEngine};
use aphmm::seq::Sequence;
use aphmm::sim::XorShift;
use aphmm::testutil;

fn artifacts_dir() -> Option<std::path::PathBuf> {
    let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    dir.join("manifest.txt").exists().then_some(dir)
}

fn ec_case(rng: &mut XorShift, ref_len: usize, obs_len: usize) -> (Phmm, Sequence) {
    let data = testutil::random_seq(rng, ref_len, 4);
    let g = Phmm::error_correction(&Sequence::from_symbols("r", data), &EcDesignParams::default())
        .unwrap();
    let obs = Sequence::from_symbols("o", testutil::random_seq(rng, obs_len, 4));
    (g, obs)
}

#[test]
fn artifacts_compile_on_pjrt_cpu() {
    let Some(dir) = artifacts_dir() else {
        eprintln!("skipping: run `make artifacts` first");
        return;
    };
    let store = ArtifactStore::load(&dir).unwrap();
    assert!(!store.names().is_empty());
    assert!(store.platform().to_lowercase().contains("cpu") || !store.platform().is_empty());
}

#[test]
fn xla_forward_score_matches_native_banded() {
    let Some(dir) = artifacts_dir() else {
        eprintln!("skipping: run `make artifacts` first");
        return;
    };
    let store = ArtifactStore::load(&dir).unwrap();
    let mut rng = XorShift::new(101);
    for case in 0..5 {
        let ref_len = 20 + case * 20; // up to 100 positions = 400 states
        let (g, obs) = ec_case(&mut rng, ref_len, 30 + case * 15);
        let banded = g.to_banded().unwrap();
        let engine =
            XlaBandedEngine::for_shape(&store, banded.n, banded.w, banded.sigma, obs.len())
                .unwrap();
        let native = BandedEngine::score(&banded, &obs).unwrap();
        let xla = engine.score(&banded, &obs).unwrap();
        testutil::assert_close(xla, native, 1e-3, 1e-3);
    }
}

#[test]
fn xla_bw_sums_match_native_banded() {
    let Some(dir) = artifacts_dir() else {
        eprintln!("skipping: run `make artifacts` first");
        return;
    };
    let store = ArtifactStore::load(&dir).unwrap();
    let mut rng = XorShift::new(202);
    for case in 0..3 {
        let (g, obs) = ec_case(&mut rng, 25 + case * 25, 20 + case * 30);
        let banded = g.to_banded().unwrap();
        let engine =
            XlaBandedEngine::for_shape(&store, banded.n, banded.w, banded.sigma, obs.len())
                .unwrap();
        let native = BandedEngine::bw_sums(&banded, &obs).unwrap();
        let xla = engine.bw_sums(&banded, &obs).unwrap();

        testutil::assert_close(xla.loglik as f64, native.loglik as f64, 1e-3, 1e-3);
        let to64 = |v: &[f32]| v.iter().map(|&x| x as f64).collect::<Vec<f64>>();
        testutil::assert_all_close(&to64(&xla.xi_band), &to64(&native.xi_band), 5e-3, 1e-4);
        testutil::assert_all_close(&to64(&xla.trans_den), &to64(&native.trans_den), 5e-3, 1e-4);
        testutil::assert_all_close(&to64(&xla.e_num), &to64(&native.e_num), 5e-3, 1e-4);
        testutil::assert_all_close(&to64(&xla.gamma_den), &to64(&native.gamma_den), 5e-3, 1e-4);
    }
}

#[test]
fn xla_em_step_improves_likelihood() {
    // Run one full EM step entirely through the XLA path and check the
    // Baum-Welch guarantee end-to-end.
    let Some(dir) = artifacts_dir() else {
        eprintln!("skipping: run `make artifacts` first");
        return;
    };
    let store = ArtifactStore::load(&dir).unwrap();
    let mut rng = XorShift::new(303);
    let (g, obs) = ec_case(&mut rng, 40, 50);
    let mut banded = g.to_banded().unwrap();
    let engine =
        XlaBandedEngine::for_shape(&store, banded.n, banded.w, banded.sigma, obs.len()).unwrap();
    let ll0 = engine.score(&banded, &obs).unwrap();
    let sums = engine.bw_sums(&banded, &obs).unwrap();
    sums.apply(&mut banded);
    let ll1 = engine.score(&banded, &obs).unwrap();
    assert!(ll1 >= ll0 - 1e-3, "EM via XLA decreased loglik: {ll0} -> {ll1}");
}

#[test]
fn xla_protein_scoring_matches_native() {
    let Some(dir) = artifacts_dir() else {
        eprintln!("skipping: run `make artifacts` first");
        return;
    };
    let store = ArtifactStore::load(&dir).unwrap();
    let mut rng = XorShift::new(404);
    let anc = Sequence::from_symbols("anc", testutil::random_seq(&mut rng, 90, 20));
    let profile = Profile::from_sequence(&anc, aphmm::seq::PROTEIN, 0.8);
    let g = Phmm::traditional(&profile, &TraditionalParams::default())
        .unwrap()
        .fold_silent(3)
        .unwrap();
    let banded = g.to_banded().unwrap();
    let query = Sequence::from_symbols("q", testutil::random_seq(&mut rng, 80, 20));
    let engine =
        XlaBandedEngine::for_shape(&store, banded.n, banded.w, banded.sigma, query.len()).unwrap();
    let native = BandedEngine::score(&banded, &query).unwrap();
    let xla = engine.score(&banded, &query).unwrap();
    testutil::assert_close(xla, native, 1e-3, 1e-3);
}
