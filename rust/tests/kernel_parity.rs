//! Parity suite for the memoized fused-coefficient kernels: the
//! optimized forward / fused backward+update must match both the
//! pre-memoization engine (`baumwelch::reference`, bit-for-bit-ish) and
//! the structurally independent log-space oracle, filters on and off;
//! the parallel batch E-step must be unobservable in the results; and
//! the score-only fast path must run in memory independent of sequence
//! length.

use aphmm::baumwelch::{
    forward_sparse, forward_sparse_with, log_likelihood, reference, score_sparse_with,
    train, BwAccumulators, FilterConfig, ForwardOptions, ForwardScratch, FusedCoeffs,
    SimdPolicy, TrainConfig,
};
use aphmm::phmm::{EcDesignParams, Phmm};
use aphmm::seq::Sequence;
use aphmm::sim::{simulate_read, ErrorProfile, XorShift};
use aphmm::testutil;

fn ec_graph(rng: &mut XorShift, len: usize) -> Phmm {
    let data = testutil::random_seq(rng, len, 4);
    Phmm::error_correction(&Sequence::from_symbols("r", data), &EcDesignParams::default())
        .unwrap()
}

fn to_dense(row: &aphmm::baumwelch::SparseRow, n: usize) -> Vec<f64> {
    let mut dense = vec![0.0f64; n];
    for (&i, &v) in row.idx.iter().zip(row.val.iter()) {
        dense[i as usize] = v as f64;
    }
    dense
}

// Scalar lanes throughout: this suite's contract is "bit-for-bit-ish
// vs the pre-memoization reference", whose sums are scalar.  Wider
// lane widths (and their reassociation tolerance tier) are covered by
// the lane parity matrix in `engine_matrix.rs` and the in-crate simd
// tests.
fn filter_cases() -> [ForwardOptions; 3] {
    [
        ForwardOptions {
            filter: FilterConfig::None,
            simd: SimdPolicy::Scalar,
            ..Default::default()
        },
        ForwardOptions {
            filter: FilterConfig::Sort { size: 40 },
            simd: SimdPolicy::Scalar,
            ..Default::default()
        },
        ForwardOptions {
            filter: FilterConfig::Histogram { size: 40, bins: 128 },
            simd: SimdPolicy::Scalar,
            ..Default::default()
        },
    ]
}

#[test]
fn memoized_forward_matches_reference_and_oracle() {
    testutil::check(25, |rng| {
        let ref_len = rng.range(5, 45);
        let g = ec_graph(rng, ref_len);
        let obs_len = rng.range(2, 30);
        let obs = Sequence::from_symbols("o", testutil::random_seq(rng, obs_len, 4));
        let coeffs = FusedCoeffs::new(&g);
        let mut scratch = ForwardScratch::new(&g);
        for opts in filter_cases() {
            let baseline = reference::forward_sparse_reference(&g, &obs, &opts).unwrap();
            let memoized = forward_sparse_with(&g, &coeffs, &obs, &opts, &mut scratch).unwrap();
            // Log-likelihood: bit-for-bit-ish (the fused product only
            // reassociates one f32 multiply per state).
            testutil::assert_close(memoized.loglik, baseline.loglik, 1e-5, 1e-9);
            assert_eq!(memoized.rows.len(), baseline.rows.len());
            if opts.filter == FilterConfig::None {
                // Unfiltered, the scaled rows agree elementwise within
                // reassociation noise (states that underflow to zero in
                // exactly one engine are covered by the absolute floor).
                for (a, b) in memoized.rows.iter().zip(baseline.rows.iter()) {
                    let dense_a = to_dense(a, g.n_states());
                    let dense_b = to_dense(b, g.n_states());
                    testutil::assert_all_close(&dense_a, &dense_b, 1e-5, 1e-9);
                }
                // And both agree with the independent log-space oracle.
                let want = log_likelihood(&g, &obs);
                testutil::assert_close(memoized.loglik, want, 1e-4, 1e-5);
            }
            scratch.recycle(memoized);
        }
    });
}

#[test]
fn memoized_accumulate_matches_reference() {
    // The fused product is pre-widened to f64 exactly as the reference
    // computes it, so the expectation sums agree to the last few bits.
    testutil::check(20, |rng| {
        let ref_len = rng.range(4, 30);
        let g = ec_graph(rng, ref_len);
        let obs_len = rng.range(2, 20);
        let obs = Sequence::from_symbols("o", testutil::random_seq(rng, obs_len, 4));
        let coeffs = FusedCoeffs::new(&g);
        let mut scratch = ForwardScratch::new(&g);
        for opts in filter_cases() {
            let fwd = forward_sparse(&g, &obs, &opts).unwrap();
            let mut acc_ref = BwAccumulators::new(&g);
            reference::accumulate_reference(&mut acc_ref, &g, &obs, &fwd).unwrap();
            let mut acc_new = BwAccumulators::new(&g);
            acc_new.accumulate_with(&g, &coeffs, &obs, &fwd, &mut scratch, &opts).unwrap();
            testutil::assert_all_close(&acc_new.xi, &acc_ref.xi, 1e-12, 1e-300);
            testutil::assert_all_close(&acc_new.trans_den, &acc_ref.trans_den, 1e-12, 1e-300);
            testutil::assert_all_close(&acc_new.e_num, &acc_ref.e_num, 1e-12, 1e-300);
            testutil::assert_all_close(&acc_new.gamma_den, &acc_ref.gamma_den, 1e-12, 1e-300);
            assert_eq!(acc_new.n_observations, acc_ref.n_observations);
        }
    });
}

#[test]
fn scratch_backward_buffers_self_clean() {
    // Reusing one scratch across many accumulations must not leak
    // backward mass between observations: the second accumulation of
    // the same read equals the first.
    let mut rng = XorShift::new(404);
    let g = ec_graph(&mut rng, 25);
    let coeffs = FusedCoeffs::new(&g);
    let mut scratch = ForwardScratch::new(&g);
    let opts = ForwardOptions::default();
    let reads: Vec<Sequence> = (0..4)
        .map(|i| Sequence::from_symbols(format!("o{i}"), testutil::random_seq(&mut rng, 12, 4)))
        .collect();
    let mut first: Vec<Vec<f64>> = Vec::new();
    for round in 0..2 {
        for (i, read) in reads.iter().enumerate() {
            let fwd = forward_sparse_with(&g, &coeffs, read, &opts, &mut scratch).unwrap();
            let mut acc = BwAccumulators::new(&g);
            acc.accumulate_with(&g, &coeffs, read, &fwd, &mut scratch, &opts).unwrap();
            scratch.recycle(fwd);
            if round == 0 {
                first.push(acc.xi.clone());
            } else {
                testutil::assert_all_close(&acc.xi, &first[i], 1e-15, 1e-300);
            }
        }
    }
}

#[test]
fn parallel_train_is_bit_identical_across_worker_counts_and_filters() {
    let mut rng = XorShift::new(808);
    let reference_seq = Sequence::from_symbols("r", testutil::random_seq(&mut rng, 120, 4));
    let reads: Vec<Sequence> = (0..19)
        .map(|i| {
            simulate_read(&mut rng, &reference_seq, 0, 120, &ErrorProfile::pacbio(), i).seq
        })
        .collect();
    for filter in [FilterConfig::None, FilterConfig::histogram_default()] {
        let mut histories: Vec<Vec<f64>> = Vec::new();
        let mut params: Vec<(Vec<f32>, Vec<f32>)> = Vec::new();
        for n_workers in [1usize, 2, 5] {
            let mut g = Phmm::error_correction(&reference_seq, &EcDesignParams::default())
                .unwrap();
            let cfg = TrainConfig { max_iters: 3, tol: 0.0, filter, n_workers, ..Default::default() };
            let res = train(&mut g, &reads, &cfg).unwrap();
            histories.push(res.loglik_history);
            params.push((g.out_prob, g.emissions));
        }
        assert_eq!(histories[0], histories[1], "filter {filter:?}");
        assert_eq!(histories[0], histories[2], "filter {filter:?}");
        assert_eq!(params[0], params[1], "filter {filter:?}");
        assert_eq!(params[0], params[2], "filter {filter:?}");
    }
}

#[test]
fn score_fast_path_memory_is_independent_of_sequence_length() {
    // A 2000-base EC graph and two reads that differ 20x in length: the
    // score-only kernel must not acquire any additional row buffers for
    // the long read (two rows total), while the full forward pass
    // materializes one row per timestep.
    let mut rng = XorShift::new(515);
    let reference_seq = Sequence::from_symbols("r", testutil::random_seq(&mut rng, 2000, 4));
    let g = Phmm::error_correction(&reference_seq, &EcDesignParams::default()).unwrap();
    let long_read =
        simulate_read(&mut rng, &reference_seq, 0, 2000, &ErrorProfile::pacbio(), 0).seq;
    let short_read = long_read.slice(0, 100);
    assert!(long_read.len() >= 15 * short_read.len());
    let coeffs = FusedCoeffs::new(&g);
    let opts = ForwardOptions { filter: FilterConfig::histogram_default(), ..Default::default() };

    let mut scratch = ForwardScratch::new(&g);
    score_sparse_with(&g, &coeffs, &short_read, &opts, &mut scratch).unwrap();
    let rows_after_short = scratch.fresh_rows_allocated();
    assert!(rows_after_short <= 2, "score path acquired {rows_after_short} rows");
    let long_score = score_sparse_with(&g, &coeffs, &long_read, &opts, &mut scratch).unwrap();
    assert_eq!(
        scratch.fresh_rows_allocated(),
        rows_after_short,
        "longer sequences must not allocate more row buffers"
    );
    // The dense state buffer is sized by the graph (states + the
    // dense-tile gather pad), not the sequence.
    assert_eq!(scratch.dense_len(), g.n_states() + coeffs.gather_pad());

    // Contrast: the row-materializing forward scales with T...
    let mut full_scratch = ForwardScratch::new(&g);
    let fwd = forward_sparse_with(&g, &coeffs, &long_read, &opts, &mut full_scratch).unwrap();
    assert_eq!(fwd.rows.len(), long_read.len());
    assert!(full_scratch.fresh_rows_allocated() as usize >= long_read.len());
    // ...and the two kernels agree exactly.
    assert_eq!(fwd.loglik.to_bits(), long_score.loglik.to_bits());
}
