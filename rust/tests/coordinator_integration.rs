//! Integration: the multi-worker coordinator with both backends,
//! including the XLA device thread serving AOT artifacts.

use std::path::Path;

use aphmm::baumwelch::{EngineKind, TrainConfig};
use aphmm::coordinator::{run_jobs, ChunkJob, CoordinatorConfig, Metrics};
use aphmm::seq::Sequence;
use aphmm::sim::{simulate_read, ErrorProfile, XorShift};
use aphmm::testutil;

fn artifacts_dir() -> Option<std::path::PathBuf> {
    let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    dir.join("manifest.txt").exists().then_some(dir)
}

fn make_jobs(rng: &mut XorShift, n_jobs: usize, ref_len: usize, n_reads: usize) -> Vec<ChunkJob> {
    (0..n_jobs)
        .map(|id| {
            let reference =
                Sequence::from_symbols(format!("c{id}"), testutil::random_seq(rng, ref_len, 4));
            let reads = (0..n_reads)
                .map(|i| {
                    simulate_read(
                        rng,
                        &reference,
                        0,
                        ref_len,
                        &ErrorProfile { sub: 0.03, ins: 0.03, del: 0.03, ins_ext: 0.2 },
                        i,
                    )
                    .seq
                })
                .collect();
            ChunkJob { id, reference, reads }
        })
        .collect()
}

#[test]
fn native_coordinator_corrects_chunks() {
    let mut rng = XorShift::new(61);
    let jobs = make_jobs(&mut rng, 8, 80, 6);
    let references: Vec<Vec<u8>> = jobs.iter().map(|j| j.reference.data.clone()).collect();
    let metrics = Metrics::default();
    let outcomes = run_jobs(jobs, &CoordinatorConfig::default(), &metrics).unwrap();
    assert_eq!(outcomes.len(), 8);
    // Consensus of a graph trained with low-noise reads stays close to
    // the reference it was built from.
    for (o, r) in outcomes.iter().zip(&references) {
        let n = o.consensus.len().min(r.len());
        let same = (0..n).filter(|&i| o.consensus.data[i] == r[i]).count();
        assert!(same as f64 / n as f64 > 0.8, "job {} diverged", o.id);
        assert!(o.latency_ns > 0, "job {} reported no latency", o.id);
    }
}

#[test]
fn xla_backend_runs_and_agrees_with_native() {
    let Some(dir) = artifacts_dir() else {
        eprintln!("skipping: run `make artifacts` first");
        return;
    };
    let mut rng = XorShift::new(62);
    // Artifact limits: N=512 states => ref_len <= 128 positions at
    // (k+1)=4 states/position; T=128 => reads <= 128 bases.
    let jobs = make_jobs(&mut rng, 4, 100, 5);
    let m_native = Metrics::default();
    let m_xla = Metrics::default();

    let native = run_jobs(
        jobs.clone(),
        &CoordinatorConfig { n_workers: 2, ..Default::default() },
        &m_native,
    )
    .unwrap();

    let cfg = CoordinatorConfig {
        n_workers: 2,
        train: TrainConfig { engine: EngineKind::Xla, ..Default::default() },
        artifacts_dir: Some(dir),
        xla_iters: 2,
        ..Default::default()
    };
    let xla = run_jobs(jobs, &cfg, &m_xla).unwrap();

    assert_eq!(native.len(), xla.len());
    for (a, b) in native.iter().zip(xla.iter()) {
        // Engines differ (filtering vs dense, f64 vs f32), so exact
        // consensus equality is not guaranteed — but both must stay
        // close to each other.
        let n = a.consensus.len().min(b.consensus.len());
        let same = (0..n).filter(|&i| a.consensus.data[i] == b.consensus.data[i]).count();
        assert!(
            same as f64 / n as f64 > 0.9,
            "job {}: native and XLA consensus diverge ({}%)",
            a.id,
            100 * same / n.max(1)
        );
    }
    assert_eq!(m_xla.summary().jobs_done, 4);
}

#[test]
fn xla_backend_rejects_oversized_reads() {
    let Some(dir) = artifacts_dir() else {
        eprintln!("skipping: run `make artifacts` first");
        return;
    };
    let mut rng = XorShift::new(63);
    // 200-base reads exceed the T=128 artifact: the device must refuse
    // (Runtime error) and the coordinator must surface it.
    let jobs = make_jobs(&mut rng, 1, 200, 2);
    let cfg = CoordinatorConfig {
        n_workers: 1,
        train: TrainConfig { engine: EngineKind::Xla, ..Default::default() },
        artifacts_dir: Some(dir),
        ..Default::default()
    };
    let metrics = Metrics::default();
    let result = run_jobs(jobs, &cfg, &metrics);
    assert!(result.is_err() || metrics.summary().jobs_failed > 0);
}
