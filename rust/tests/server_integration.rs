//! Integration: the streaming multi-tenant server — concurrent mixed
//! workloads vs a serial replay, cross-request Prepared-cache reuse,
//! and clean teardown (no leaked threads).

use std::time::Duration;

use aphmm::apps;
use aphmm::baumwelch::{EngineKind, ForwardOptions, PreparedAny, TrainConfig};
use aphmm::io::write_phmm_string;
use aphmm::phmm::{EcDesignParams, Phmm};
use aphmm::pool::WorkerPool;
use aphmm::seq::Sequence;
use aphmm::server::{
    AdmitError, FailureCause, Priority, PushError, Request, Response, ResponseBody, Server,
    ServerConfig, TenantQuota,
};
use aphmm::sim::{simulate_read, ErrorProfile, XorShift};
use aphmm::testutil;

fn dna(rng: &mut XorShift, id: &str, len: usize) -> Sequence {
    Sequence::from_symbols(id, testutil::random_seq(rng, len, 4))
}

fn reads_of(rng: &mut XorShift, reference: &Sequence, n: usize) -> Vec<Sequence> {
    (0..n)
        .map(|i| {
            simulate_read(rng, reference, 0, reference.len(), &ErrorProfile::pacbio(), i).seq
        })
        .collect()
}

/// The expected answer for one request, computed serially with the
/// library primitives (no queue, no cache, no worker pool fan-out).
#[derive(Debug, Clone, PartialEq)]
enum Expected {
    Score { loglik_bits: u64 },
    Correct { consensus: Vec<u8>, mean_loglik_bits: u64, iters: usize },
}

fn serial_replay(
    req: &Request,
    profiles: &[(String, Phmm)],
    train: &TrainConfig,
    design: &EcDesignParams,
) -> Expected {
    match req {
        Request::Score { profile, read } => {
            let (_, phmm) = profiles.iter().find(|(n, _)| n == profile).unwrap();
            let prepared = PreparedAny::freeze(EngineKind::Sparse, phmm).unwrap();
            let mut scratch = prepared.make_scratch(phmm);
            let res =
                prepared.score(phmm, read, &ForwardOptions::default(), &mut scratch).unwrap();
            Expected::Score { loglik_bits: res.loglik.to_bits() }
        }
        Request::Correct { reference, reads } => {
            let pool = WorkerPool::new(0);
            let out =
                apps::train_chunk(reference, reads, design, aphmm::seq::DNA, train, &pool)
                    .unwrap();
            Expected::Correct {
                consensus: out.consensus.data,
                mean_loglik_bits: out
                    .train
                    .loglik_history
                    .last()
                    .copied()
                    .unwrap_or(f64::NEG_INFINITY)
                    .to_bits(),
                iters: out.train.iters,
            }
        }
        other => panic!("no serial replay for {other:?}"),
    }
}

fn assert_matches_expected(resp: &Response, expected: &Expected, what: &str) {
    match (&resp.body, expected) {
        (ResponseBody::Score { loglik, .. }, Expected::Score { loglik_bits }) => {
            assert_eq!(loglik.to_bits(), *loglik_bits, "{what}: score diverged from serial run");
        }
        (
            ResponseBody::Correct { consensus, mean_loglik, iters },
            Expected::Correct { consensus: want, mean_loglik_bits, iters: want_iters },
        ) => {
            assert_eq!(&consensus.data, want, "{what}: consensus diverged from serial run");
            assert_eq!(
                mean_loglik.to_bits(),
                *mean_loglik_bits,
                "{what}: training loglik diverged from serial run"
            );
            assert_eq!(iters, want_iters, "{what}: iteration count diverged");
        }
        (body, expected) => panic!("{what}: response {body:?} does not match {expected:?}"),
    }
}

/// Acceptance: ≥ 64 concurrent requests from ≥ 4 producer threads with
/// `queue_depth = 8` complete without deadlock, and every result is
/// bit-identical to a serial replay of the same request.
#[test]
fn concurrent_mixed_requests_match_serial_replay() {
    let mut rng = XorShift::new(201);
    let ref_a = dna(&mut rng, "chrA", 60);
    let ref_b = dna(&mut rng, "chrB", 60);
    let profiles: Vec<(String, Phmm)> = [("pa", &ref_a), ("pb", &ref_b)]
        .into_iter()
        .map(|(name, r)| {
            (name.to_string(), Phmm::error_correction(r, &EcDesignParams::default()).unwrap())
        })
        .collect();

    // 4 producers × 16 requests, mixing cached scoring and training.
    const PRODUCERS: usize = 4;
    const PER_PRODUCER: usize = 16;
    let mut requests: Vec<Vec<Request>> = Vec::new();
    for p in 0..PRODUCERS {
        let mut mine = Vec::new();
        for i in 0..PER_PRODUCER {
            let which = (p + i) % 2;
            let (name, reference) =
                if which == 0 { ("pa", &ref_a) } else { ("pb", &ref_b) };
            if i % 4 == 3 {
                mine.push(Request::Correct {
                    reference: reference.clone(),
                    reads: reads_of(&mut rng, reference, 3),
                });
            } else {
                let read = simulate_read(
                    &mut rng,
                    reference,
                    0,
                    reference.len(),
                    &ErrorProfile::pacbio(),
                    p * PER_PRODUCER + i,
                )
                .seq;
                mine.push(Request::Score { profile: name.to_string(), read });
            }
        }
        requests.push(mine);
    }

    let cfg = ServerConfig { n_workers: 4, queue_depth: 8, ..Default::default() };
    let train = cfg.train;
    let design = cfg.design;
    let expected: Vec<Vec<Expected>> = requests
        .iter()
        .map(|mine| mine.iter().map(|r| serial_replay(r, &profiles, &train, &design)).collect())
        .collect();

    let mut server = Server::start(cfg);
    for (name, phmm) in &profiles {
        server.register_profile(name, phmm.clone());
    }
    let responses: Vec<Vec<Response>> = std::thread::scope(|scope| {
        let server = &server;
        let handles: Vec<_> = requests
            .iter()
            .map(|mine| {
                scope.spawn(move || {
                    // Submit the whole stream (blocking admission
                    // control), then collect in order.
                    let tickets: Vec<_> = mine
                        .iter()
                        .map(|req| server.submit(None, req.clone()).unwrap())
                        .collect();
                    tickets.into_iter().map(|t| t.wait()).collect::<Vec<_>>()
                })
            })
            .collect();
        handles.into_iter().map(|h| h.join().unwrap()).collect()
    });

    for (p, (resps, wants)) in responses.iter().zip(expected.iter()).enumerate() {
        assert_eq!(resps.len(), PER_PRODUCER);
        for (i, (resp, want)) in resps.iter().zip(wants.iter()).enumerate() {
            assert_matches_expected(resp, want, &format!("producer {p} request {i}"));
            assert!(resp.latency_ns > 0, "producer {p} request {i} has no latency");
        }
    }

    // The queue really was bounded, and the metrics saw every job.
    let q = server.queue_stats();
    assert!(q.high_water <= 8, "queue depth bound violated: {}", q.high_water);
    assert_eq!(q.pushed, (PRODUCERS * PER_PRODUCER) as u64);
    assert_eq!(q.pushed, q.popped);
    let m = server.metrics_summary();
    assert_eq!(m.jobs_done, (PRODUCERS * PER_PRODUCER) as u64);
    assert_eq!(m.jobs_failed, 0);
    assert!(m.latency_p99_ms >= m.latency_p50_ms);
    server.shutdown(true);
}

/// Acceptance: the second request for the same profile is a
/// Prepared-cache hit (hit counter == 1) — the freeze ran once.
#[test]
fn repeated_profile_requests_reuse_the_frozen_tables() {
    let mut rng = XorShift::new(202);
    let reference = dna(&mut rng, "chr1", 50);
    let phmm = Phmm::error_correction(&reference, &EcDesignParams::default()).unwrap();
    let mut server = Server::start(ServerConfig { n_workers: 2, ..Default::default() });
    server.register_profile("chr1", phmm);

    let reads = reads_of(&mut rng, &reference, 2);
    let first = server
        .submit(None, Request::Score { profile: "chr1".into(), read: reads[0].clone() })
        .unwrap()
        .wait();
    let second = server
        .submit(None, Request::Score { profile: "chr1".into(), read: reads[1].clone() })
        .unwrap()
        .wait();
    match (&first.body, &second.body) {
        (
            ResponseBody::Score { cache_hit: h1, .. },
            ResponseBody::Score { cache_hit: h2, .. },
        ) => {
            assert!(!*h1, "first request must freeze the tables");
            assert!(*h2, "second request must not re-freeze");
        }
        other => panic!("unexpected responses {other:?}"),
    }
    let c = server.cache_stats();
    assert_eq!(c.misses, 1, "exactly one freeze");
    assert_eq!(c.hits, 1, "exactly one reuse");
    assert_eq!(c.entries, 1);
    server.shutdown(true);
}

/// Satellite: dropping a server mid-stream leaks no threads — the
/// dispatcher and every pool helper are joined, and pending requests
/// fail explicitly instead of hanging their clients.
#[test]
fn dropping_a_server_mid_stream_leaks_no_threads() {
    let mut rng = XorShift::new(203);
    let reference = dna(&mut rng, "chr1", 80);
    let reads = reads_of(&mut rng, &reference, 6);
    let server = Server::start(ServerConfig {
        n_workers: 2,
        queue_depth: 16,
        ..Default::default()
    });
    let probe = server.pool_liveness();
    assert!(probe.upgrade().is_some());

    let tickets: Vec<_> = (0..10)
        .map(|_| {
            server
                .submit(
                    None,
                    Request::Correct { reference: reference.clone(), reads: reads.clone() },
                )
                .unwrap()
        })
        .collect();

    // Abort mid-stream.
    drop(server);
    assert!(
        probe.upgrade().is_none(),
        "pool helpers must be joined when the server is dropped"
    );
    let mut done = 0usize;
    let mut aborted = 0usize;
    for t in tickets {
        match t.wait().body {
            ResponseBody::Correct { .. } => done += 1,
            ResponseBody::Error { .. } => aborted += 1,
            other => panic!("unexpected response {other:?}"),
        }
    }
    assert_eq!(done + aborted, 10);
    assert!(aborted > 0, "a 10-deep backlog on 2 workers cannot fully drain on abort");
}

/// Busy admission control surfaces as a typed refusal, not a block,
/// on the non-blocking submit path.
#[test]
fn try_submit_refuses_when_the_queue_is_full() {
    let mut rng = XorShift::new(204);
    let reference = dna(&mut rng, "chr1", 80);
    let reads = reads_of(&mut rng, &reference, 8);
    // One worker, tiny queue: flood it with slow training jobs.
    let mut server = Server::start(ServerConfig {
        n_workers: 1,
        queue_depth: 2,
        ..Default::default()
    });
    let mut tickets = Vec::new();
    let mut refused = 0usize;
    for _ in 0..50 {
        match server.try_submit(
            None,
            Request::Correct { reference: reference.clone(), reads: reads.clone() },
        ) {
            Ok(t) => tickets.push(t),
            Err(PushError::Busy(_)) => refused += 1,
            Err(PushError::Closed(_)) => panic!("server closed unexpectedly"),
        }
    }
    assert!(refused > 0, "a depth-2 queue must refuse some of 50 instant submissions");
    for t in tickets {
        assert!(matches!(t.wait().body, ResponseBody::Correct { .. }));
    }
    let q = server.queue_stats();
    assert!(q.high_water <= 2);
    assert!(q.producer_blocks >= refused as u64);
    server.shutdown(true);
}

/// Acceptance (tenant-aware admission): a tenant at its quota gets a
/// typed `AtQuota` refusal while a second tenant's requests still
/// admit, and per-tenant gauges appear in `MetricsSummary`.
#[test]
fn tenant_at_quota_is_refused_while_others_admit() {
    let mut rng = XorShift::new(206);
    let reference = dna(&mut rng, "chr1", 80);
    let reads = reads_of(&mut rng, &reference, 8);
    let read = reads[0].clone();
    // One worker chewing slow training jobs; tenant "a" may queue at
    // most one request at a time.
    let mut server = Server::start(ServerConfig {
        n_workers: 1,
        queue_depth: 16,
        tenant_quota: TenantQuota { max_queued: 1, max_in_flight: 1 },
        ..Default::default()
    });
    server.register_profile(
        "chr1",
        Phmm::error_correction(&reference, &EcDesignParams::default()).unwrap(),
    );

    let mut tickets = Vec::new();
    let mut at_quota = 0usize;
    for _ in 0..8 {
        match server.try_submit_for(
            "a",
            Priority::Normal,
            None,
            Request::Correct { reference: reference.clone(), reads: reads.clone() },
        ) {
            Ok(t) => tickets.push(t),
            Err(AdmitError::AtQuota(_)) => at_quota += 1,
            Err(other) => panic!("unexpected admission result {other:?}"),
        }
    }
    assert!(
        at_quota > 0,
        "8 instant submissions against a max_queued=1 quota must hit AtQuota"
    );
    // Tenant "b" is unaffected by a's quota: its request admits (and
    // completes) even while a is being refused.
    let b_ticket = server
        .try_submit_for(
            "b",
            Priority::High,
            None,
            Request::Score { profile: "chr1".into(), read },
        )
        .expect("tenant b must admit while tenant a is at quota");
    tickets.push(b_ticket);
    server.shutdown(true);
    for t in tickets {
        match t.wait().body {
            ResponseBody::Correct { .. } | ResponseBody::Score { .. } => {}
            other => panic!("admitted request failed: {other:?}"),
        }
    }

    // Per-tenant gauges in the metrics summary.
    let m = server.metrics_summary();
    let find = |name: &str| {
        m.tenants
            .iter()
            .find(|t| t.tenant == name)
            .unwrap_or_else(|| panic!("tenant {name} missing from MetricsSummary"))
    };
    let a = find("a");
    assert!(a.quota_refusals >= at_quota as u64, "a.quota_refusals = {}", a.quota_refusals);
    assert!(a.admitted >= 1);
    assert!(a.completed >= 1);
    assert_eq!(a.queued, 0, "drained server must show empty tenant queues");
    assert_eq!(a.in_flight, 0);
    let b = find("b");
    assert_eq!(b.admitted, 1);
    assert_eq!(b.completed, 1);
    assert_eq!(b.quota_refusals, 0);
}

/// Acceptance (wire-format registration): a profile registered over
/// the wire via `register-profile` + `io::profile_fmt` text scores
/// bit-identically to the same profile registered in-process, and the
/// second registration shares the frozen tables (PreparedCache hit
/// counters prove the freeze ran once).
#[test]
fn wire_registered_profile_shares_frozen_tables_with_in_process_one() {
    let mut rng = XorShift::new(207);
    let reference = dna(&mut rng, "chr1", 50);
    // Canonicalize through one text round trip: the profile_fmt
    // write→read→write byte-identity property makes a parsed graph a
    // fixed point of the format, so the in-process registration and
    // the wire payload below describe bit-identical parameters.  (A
    // raw in-memory graph may carry f32s that 7-decimal text rounds.)
    let raw = Phmm::error_correction(&reference, &EcDesignParams::default()).unwrap();
    let phmm = aphmm::io::read_phmm_str(&write_phmm_string(&raw), "canon").unwrap();
    let read = simulate_read(&mut rng, &reference, 0, 50, &ErrorProfile::pacbio(), 0).seq;
    let ascii_read = read.to_ascii(aphmm::seq::DNA);

    let mut server = Server::start(ServerConfig { n_workers: 2, ..Default::default() });
    // Tenant 1 registers in-process and scores: this freezes the
    // tables (cache miss #1 — and the only freeze in this test).
    server.register_profile("native", phmm.clone());
    let native = server
        .submit(None, Request::Score { profile: "native".into(), read: read.clone() })
        .unwrap()
        .wait();
    let native_bits = match native.body {
        ResponseBody::Score { loglik, cache_hit, .. } => {
            assert!(!cache_hit);
            loglik.to_bits()
        }
        other => panic!("unexpected response {other:?}"),
    };

    // Tenant 2 uploads the same profile as .aphmm text over the wire
    // under a different name.  Content addressing maps it to the same
    // cache entry, so its first score is already a hit.
    let payload = write_phmm_string(&phmm);
    let script = format!(
        "tenant t2 high\nregister-profile wirep {}\n{payload}score wirep {ascii_read}\nquit\n",
        payload.len()
    );
    let mut out: Vec<u8> = Vec::new();
    let end = aphmm::server::serve_connection(&server, script.as_bytes(), &mut out).unwrap();
    assert_eq!(end, aphmm::server::SessionEnd::Quit);
    let text = String::from_utf8(out).unwrap();
    let lines: Vec<&str> = text.lines().collect();
    assert_eq!(lines.len(), 4, "one response per request:\n{text}");
    assert_eq!(lines[0], "ok tenant t2 priority=high");
    assert!(lines[1].starts_with("ok profile wirep states="), "{}", lines[1]);
    assert!(
        lines[2].starts_with("score wirep loglik=") && lines[2].contains("cache=hit"),
        "wire profile must reuse the in-process frozen tables: {}",
        lines[2]
    );

    // Same hash as the in-process registration (content addressing).
    let registry = server.registry();
    let native_entry = registry.get("native").unwrap();
    let wire_entry = registry.get("wirep").unwrap();
    assert_eq!(native_entry.hash, wire_entry.hash, "wire round trip changed the content hash");

    // And the wire-registered profile scores bit-identically through
    // the typed API too.
    let wire = server
        .submit(None, Request::Score { profile: "wirep".into(), read })
        .unwrap()
        .wait();
    match wire.body {
        ResponseBody::Score { loglik, cache_hit, .. } => {
            assert_eq!(loglik.to_bits(), native_bits, "wire profile diverged from in-process");
            assert!(cache_hit);
        }
        other => panic!("unexpected response {other:?}"),
    }
    let c = server.cache_stats();
    assert_eq!(c.misses, 1, "exactly one freeze across both registrations");
    assert!(c.hits >= 2, "both wire scores must hit, got {}", c.hits);
    // Tenant t2's activity shows up in the per-tenant gauges.
    let m = server.metrics_summary();
    assert!(m.tenants.iter().any(|t| t.tenant == "t2" && t.completed >= 1));
    server.shutdown(true);
}

/// Hostile `register-profile` payloads: truncated stream, oversized
/// length prefix, non-finite probabilities, garbage bytes — all are
/// clean `err` responses (or a clean session end for a truncated
/// stream), never panics, and the session/server stays usable.
#[test]
fn hostile_register_profile_payloads_are_rejected() {
    let mut rng = XorShift::new(208);
    let reference = dna(&mut rng, "chr1", 40);
    let phmm = Phmm::error_correction(&reference, &EcDesignParams::default()).unwrap();
    let valid = write_phmm_string(&phmm);

    let mut server = Server::start(ServerConfig {
        n_workers: 1,
        max_profile_bytes: 64 * 1024,
        ..Default::default()
    });

    // Oversized length prefix: refused before any byte is read or
    // allocated, and the session is closed — the client may already
    // have written the payload we are not going to read, so the stream
    // cannot be resynchronized (leaving it open would parse megabytes
    // of profile text as protocol commands).
    let script = "register-profile big 999999999\nstats\nquit\n".to_string();
    let mut out: Vec<u8> = Vec::new();
    let end = aphmm::server::serve_connection(&server, script.as_bytes(), &mut out).unwrap();
    assert_eq!(end, aphmm::server::SessionEnd::Eof, "over-cap must close the session");
    let text = String::from_utf8(out).unwrap();
    let lines: Vec<&str> = text.lines().collect();
    assert_eq!(lines.len(), 1, "no further command may be parsed from the stream:\n{text}");
    assert!(lines[0].starts_with("err register-profile:"), "{}", lines[0]);
    assert!(lines[0].contains("cap"), "{}", lines[0]);
    // The server itself survives; a fresh session works.
    let mut out: Vec<u8> = Vec::new();
    aphmm::server::serve_connection(&server, "stats\nquit\n".as_bytes(), &mut out).unwrap();
    assert!(String::from_utf8(out).unwrap().starts_with("stats "));

    // Truncated payload: the declared length exceeds what the stream
    // holds; the session answers an error and ends cleanly.
    let script = format!("register-profile cut {}\nAPHMM 1\n", 10_000);
    let mut out: Vec<u8> = Vec::new();
    let end = aphmm::server::serve_connection(&server, script.as_bytes(), &mut out).unwrap();
    assert_eq!(end, aphmm::server::SessionEnd::Eof);
    let text = String::from_utf8(out).unwrap();
    assert!(text.starts_with("err register-profile: truncated payload"), "{text}");

    // Non-finite probability in an otherwise valid payload.
    let first_trans = valid
        .lines()
        .find(|l| l.starts_with("trans "))
        .expect("fixture has a trans line")
        .to_string();
    let toks: Vec<&str> = first_trans.split_whitespace().collect();
    let hostile = valid.replacen(&first_trans, &format!("trans {} {} inf", toks[1], toks[2]), 1);
    let script = format!("register-profile nan {}\n{hostile}quit\n", hostile.len());
    let mut out: Vec<u8> = Vec::new();
    aphmm::server::serve_connection(&server, script.as_bytes(), &mut out).unwrap();
    let text = String::from_utf8(out).unwrap();
    assert!(text.starts_with("err "), "non-finite prob must be rejected: {text}");
    assert!(server.registry().get("nan").is_none());

    // Garbage bytes of the declared length: parse error, session lives.
    let garbage = "x".repeat(100);
    let script = format!("register-profile junk 100\n{garbage}quit\n");
    let mut out: Vec<u8> = Vec::new();
    let end = aphmm::server::serve_connection(&server, script.as_bytes(), &mut out).unwrap();
    assert_eq!(end, aphmm::server::SessionEnd::Quit);
    let text = String::from_utf8(out).unwrap();
    assert!(text.starts_with("err "), "{text}");
    assert!(server.registry().get("junk").is_none());

    // A malformed byte count also closes the session: the client may
    // have pipelined the payload right behind the bad command line,
    // and an open session would parse those bytes as commands.
    let script = "register-profile bad 54z1\nAPHMM 1\ndesign error_correction\nquit\n";
    let mut out: Vec<u8> = Vec::new();
    let end = aphmm::server::serve_connection(&server, script.as_bytes(), &mut out).unwrap();
    assert_eq!(end, aphmm::server::SessionEnd::Eof, "bad count must close the session");
    let text = String::from_utf8(out).unwrap();
    assert_eq!(text.lines().count(), 1, "payload lines must not be parsed:\n{text}");
    assert!(text.starts_with("err register-profile:"), "{text}");

    // A valid registration still works after all the hostility.
    let script = format!("register-profile good {}\n{valid}quit\n", valid.len());
    let mut out: Vec<u8> = Vec::new();
    aphmm::server::serve_connection(&server, script.as_bytes(), &mut out).unwrap();
    let text = String::from_utf8(out).unwrap();
    assert!(text.starts_with("ok profile good states="), "{text}");
    server.shutdown(true);
}

/// Wire registration is bounded: fresh names are refused past the
/// per-tenant and total registry caps (entries store full graphs —
/// unbounded untrusted registration is a memory/CPU DoS), while
/// same-content re-uploads and owner updates still succeed.
#[test]
fn wire_registration_is_bounded_by_registry_caps() {
    let mut rng = XorShift::new(210);
    let texts: Vec<String> = (0..3)
        .map(|i| {
            let r = dna(&mut rng, &format!("r{i}"), 30);
            write_phmm_string(&Phmm::error_correction(&r, &EcDesignParams::default()).unwrap())
        })
        .collect();
    let mut server = Server::start(ServerConfig {
        n_workers: 1,
        max_profiles: 64,
        max_profiles_per_tenant: 2,
        ..Default::default()
    });
    let run = |script: String| {
        let mut out: Vec<u8> = Vec::new();
        aphmm::server::serve_connection(&server, script.as_bytes(), &mut out).unwrap();
        String::from_utf8(out).unwrap()
    };
    // Two fresh names fit the per-tenant cap; the third is refused.
    for (i, text) in texts.iter().enumerate().take(2) {
        let out = run(format!("tenant t\nregister-profile p{i} {}\n{text}quit\n", text.len()));
        assert!(out.lines().nth(1).unwrap().starts_with("ok profile"), "{out}");
    }
    let out = run(format!("tenant t\nregister-profile p2 {}\n{}quit\n", texts[2].len(), texts[2]));
    let reply = out.lines().nth(1).unwrap();
    assert!(reply.starts_with("err ") && reply.contains("owns"), "{out}");
    assert!(server.registry().get("p2").is_none());
    // Same-content re-upload (cap-exempt) and owner update still work.
    let out = run(format!("tenant t\nregister-profile p0 {}\n{}quit\n", texts[0].len(), texts[0]));
    assert!(out.lines().nth(1).unwrap().starts_with("ok profile"), "{out}");
    let out = run(format!("tenant t\nregister-profile p0 {}\n{}quit\n", texts[2].len(), texts[2]));
    assert!(out.lines().nth(1).unwrap().starts_with("ok profile"), "{out}");
    // Another tenant still has its own budget.
    let out = run(format!("tenant u\nregister-profile q0 {}\n{}quit\n", texts[1].len(), texts[1]));
    let reply = out.lines().nth(1).unwrap();
    // texts[1] is already registered as "p1" with identical content by
    // tenant t under a different name, so this is a fresh name for u —
    // admitted within u's budget.
    assert!(reply.starts_with("ok profile q0"), "{out}");
    server.shutdown(true);
}

/// Wire registration is ownership-checked: one tenant cannot replace
/// another tenant's named profile with different content (which would
/// silently redirect the owner's requests onto foreign parameters),
/// while same-content re-uploads and owner updates still succeed.
#[test]
fn wire_registration_cannot_hijack_another_tenants_profile() {
    let mut rng = XorShift::new(209);
    let ref_a = dna(&mut rng, "ra", 40);
    let ref_b = dna(&mut rng, "rb", 40);
    let text_a = write_phmm_string(
        &Phmm::error_correction(&ref_a, &EcDesignParams::default()).unwrap(),
    );
    let text_b = write_phmm_string(
        &Phmm::error_correction(&ref_b, &EcDesignParams::default()).unwrap(),
    );
    let mut server = Server::start(ServerConfig { n_workers: 1, ..Default::default() });

    let run = |script: String| {
        let mut out: Vec<u8> = Vec::new();
        aphmm::server::serve_connection(&server, script.as_bytes(), &mut out).unwrap();
        String::from_utf8(out).unwrap()
    };

    // Tenant alice registers "fam".
    let text = run(format!(
        "tenant alice\nregister-profile fam {}\n{text_a}quit\n",
        text_a.len()
    ));
    assert!(text.lines().nth(1).unwrap().starts_with("ok profile fam"), "{text}");
    let owner_hash = server.registry().get("fam").unwrap().hash;

    // Tenant mallory tries to replace it with different content: err,
    // and the registry still holds alice's graph.
    let text = run(format!(
        "tenant mallory\nregister-profile fam {}\n{text_b}quit\n",
        text_b.len()
    ));
    let reply = text.lines().nth(1).unwrap();
    assert!(reply.starts_with("err ") && reply.contains("owned"), "{text}");
    assert_eq!(server.registry().get("fam").unwrap().hash, owner_hash);
    assert_eq!(server.registry().get("fam").unwrap().owner, "alice");

    // Same content under the same name from another tenant is an
    // idempotent no-op (content addressing — this is what lets tenants
    // share one frozen table), and ownership does not transfer.
    let text = run(format!(
        "tenant mallory\nregister-profile fam {}\n{text_a}quit\n",
        text_a.len()
    ));
    assert!(text.lines().nth(1).unwrap().starts_with("ok profile fam"), "{text}");
    assert_eq!(server.registry().get("fam").unwrap().owner, "alice");

    // The owner may replace their own profile with new content.
    let text = run(format!(
        "tenant alice\nregister-profile fam {}\n{text_b}quit\n",
        text_b.len()
    ));
    assert!(text.lines().nth(1).unwrap().starts_with("ok profile fam"), "{text}");
    assert_ne!(server.registry().get("fam").unwrap().hash, owner_hash);

    // Operator-registered profiles are owned by a reserved id no wire
    // session can assume: an anonymous session (default tenant, no
    // `tenant` command) cannot replace them either...
    server.register_profile(
        "opprof",
        Phmm::error_correction(&ref_a, &EcDesignParams::default()).unwrap(),
    );
    let op_hash = server.registry().get("opprof").unwrap().hash;
    let text = run(format!("register-profile opprof {}\n{text_b}quit\n", text_b.len()));
    let reply = text.lines().next().unwrap();
    assert!(reply.starts_with("err ") && reply.contains("owned"), "{text}");
    assert_eq!(server.registry().get("opprof").unwrap().hash, op_hash);

    // ...and the reserved `__` namespace is rejected outright at the
    // `tenant` command, so the operator id cannot be claimed.
    let text = run("tenant __operator__\nquit\n".to_string());
    assert!(text.lines().next().unwrap().starts_with("err tenant:"), "{text}");
    server.shutdown(true);
}

/// Tentpole (deadlines): a request whose deadline expired while it was
/// still queued is answered with a typed `Failure` **without ever
/// executing** — the Prepared cache shows zero freezes — and the
/// failure is attributed by cause in the aggregate and per-tenant
/// metrics.  A follow-up request on the same server succeeds normally.
#[test]
fn expired_deadline_fails_typed_without_executing() {
    let mut rng = XorShift::new(211);
    let reference = dna(&mut rng, "chr1", 40);
    let phmm = Phmm::error_correction(&reference, &EcDesignParams::default()).unwrap();
    let mut server = Server::start(ServerConfig { n_workers: 1, ..Default::default() });
    server.register_profile("chr1", phmm);
    let read = reads_of(&mut rng, &reference, 1).remove(0);

    // A zero budget is already expired at the queue-pop check.
    let resp = server
        .submit_with_deadline(
            "lat",
            Priority::Normal,
            None,
            Request::Score { profile: "chr1".into(), read: read.clone() },
            Some(Duration::ZERO),
        )
        .unwrap()
        .wait();
    match &resp.body {
        ResponseBody::Failure { cause, .. } => {
            assert_eq!(*cause, FailureCause::DeadlineExceeded);
        }
        other => panic!("expected a typed deadline failure, got {other:?}"),
    }
    assert_eq!(
        server.cache_stats().misses,
        0,
        "an expired-in-queue request must never start executing"
    );
    let m = server.metrics_summary();
    assert_eq!(m.jobs_failed, 1);
    assert_eq!(m.deadline_exceeded, 1);
    assert_eq!(m.cancelled, 0);
    assert_eq!(m.pool_panics, 0);
    let lat = m.tenants.iter().find(|t| t.tenant == "lat").expect("tenant gauges");
    assert_eq!(lat.failed, 1);
    assert_eq!(lat.deadline_exceeded, 1);
    assert!(
        server.tenants_line().contains("lat:admitted=1"),
        "wire tenants line missing the tenant: {}",
        server.tenants_line()
    );
    assert!(
        server.tenants_line().contains("deadline_exceeded=1"),
        "wire tenants line missing the cause counter: {}",
        server.tenants_line()
    );

    // The server is unharmed: the same request without a deadline (and
    // one with a generous deadline) complete normally and agree.
    let ok = server
        .submit(None, Request::Score { profile: "chr1".into(), read: read.clone() })
        .unwrap()
        .wait();
    let ok_budget = server
        .submit_with_deadline(
            "lat",
            Priority::Normal,
            None,
            Request::Score { profile: "chr1".into(), read },
            Some(Duration::from_secs(60)),
        )
        .unwrap()
        .wait();
    match (&ok.body, &ok_budget.body) {
        (ResponseBody::Score { loglik: a, .. }, ResponseBody::Score { loglik: b, .. }) => {
            assert_eq!(
                a.to_bits(),
                b.to_bits(),
                "a deadline that does not fire must not perturb results"
            );
        }
        other => panic!("follow-up requests failed: {other:?}"),
    }
    server.shutdown(true);
}

/// Tentpole (cancellation): cancelling a ticket makes the request
/// return a typed `Cancelled` failure — observed either at the
/// queue-pop boundary or at a per-read boundary mid-compute — and the
/// server keeps serving afterwards.
#[test]
fn cancelled_ticket_fails_typed_and_server_keeps_serving() {
    let mut rng = XorShift::new(212);
    let reference = dna(&mut rng, "chr1", 200);
    let reads = reads_of(&mut rng, &reference, 12);
    let phmm = Phmm::error_correction(&reference, &EcDesignParams::default()).unwrap();
    let mut server = Server::start(ServerConfig { n_workers: 1, ..Default::default() });
    server.register_profile("chr1", phmm);

    // Correct has per-read cancellation points, so the cancel lands
    // whether the job is still queued or already mid-E-step.
    let ticket = server
        .submit(None, Request::Correct { reference: reference.clone(), reads: reads.clone() })
        .unwrap();
    ticket.cancel();
    let resp = ticket.wait();
    match &resp.body {
        ResponseBody::Failure { cause, .. } => assert_eq!(*cause, FailureCause::Cancelled),
        other => panic!("expected a typed cancellation, got {other:?}"),
    }
    let m = server.metrics_summary();
    assert_eq!(m.cancelled, 1);
    assert_eq!(m.jobs_failed, 1);

    // Subsequent work is unaffected.
    let ok = server
        .submit(None, Request::Correct { reference, reads })
        .unwrap()
        .wait();
    assert!(matches!(ok.body, ResponseBody::Correct { .. }), "{:?}", ok.body);
    server.shutdown(true);
}

/// Tentpole (striped micro-batch): Score responses are bit-identical
/// whether the worker executes jobs one at a time (`microbatch = 1`)
/// or fuses same-profile jobs into one striped multi-read pass
/// (`microbatch = 8`), and both match a serial replay with the library
/// primitives — the per-read bit-identity contract of the striped
/// kernels carried through the whole serving stack.  Whatever mix of
/// singleton and batched executions the queue timing produces, exactly
/// one response reports a cache miss (the first executed request
/// freezes; every later slot — batched or not — reuses the tables).
#[test]
fn striped_microbatch_scoring_is_bit_identical_to_singleton_execution() {
    let mut rng = XorShift::new(213);
    let reference = dna(&mut rng, "chr1", 60);
    let phmm = Phmm::error_correction(&reference, &EcDesignParams::default()).unwrap();
    let reads = reads_of(&mut rng, &reference, 20);
    let expected: Vec<u64> = {
        let prepared = PreparedAny::freeze(EngineKind::Sparse, &phmm).unwrap();
        let mut scratch = prepared.make_scratch(&phmm);
        reads
            .iter()
            .map(|r| {
                prepared
                    .score(&phmm, r, &ForwardOptions::default(), &mut scratch)
                    .unwrap()
                    .loglik
                    .to_bits()
            })
            .collect()
    };
    for microbatch in [1usize, 8] {
        let mut server = Server::start(ServerConfig {
            n_workers: 1,
            queue_depth: 32,
            microbatch,
            ..Default::default()
        });
        server.register_profile("chr1", phmm.clone());
        let tickets: Vec<_> = reads
            .iter()
            .map(|r| {
                server
                    .submit(None, Request::Score { profile: "chr1".into(), read: r.clone() })
                    .unwrap()
            })
            .collect();
        let mut misses = 0usize;
        for (i, t) in tickets.into_iter().enumerate() {
            match t.wait().body {
                ResponseBody::Score { loglik, cache_hit, .. } => {
                    assert_eq!(
                        loglik.to_bits(),
                        expected[i],
                        "read {i} diverged from serial replay (microbatch={microbatch})"
                    );
                    if !cache_hit {
                        misses += 1;
                    }
                }
                other => panic!("read {i} failed (microbatch={microbatch}): {other:?}"),
            }
        }
        assert_eq!(misses, 1, "exactly one freeze (microbatch={microbatch})");
        assert_eq!(server.cache_stats().misses, 1);
        server.shutdown(true);
    }
}

/// The wire protocol end-to-end over an in-memory session: register,
/// score twice (second is a cache hit), stats, quit.
#[test]
fn line_protocol_round_trip() {
    let mut rng = XorShift::new(205);
    let reference = dna(&mut rng, "chr1", 40);
    let ascii_ref = reference.to_ascii(aphmm::seq::DNA);
    let read = simulate_read(&mut rng, &reference, 0, 40, &ErrorProfile::pacbio(), 0).seq;
    let ascii_read = read.to_ascii(aphmm::seq::DNA);

    let mut server = Server::start(ServerConfig { n_workers: 2, ..Default::default() });
    let script = format!(
        "register chr1 {ascii_ref}\nscore chr1 {ascii_read}\nscore chr1 {ascii_read}\n\
         bogus line\nstats\nquit\n"
    );
    let mut out: Vec<u8> = Vec::new();
    let end =
        aphmm::server::serve_connection(&server, script.as_bytes(), &mut out).unwrap();
    assert_eq!(end, aphmm::server::SessionEnd::Quit);
    server.shutdown(true);

    let text = String::from_utf8(out).unwrap();
    let lines: Vec<&str> = text.lines().collect();
    assert_eq!(lines.len(), 6, "one response per request line:\n{text}");
    assert!(lines[0].starts_with("ok profile chr1 states="), "{}", lines[0]);
    assert!(lines[1].starts_with("score chr1 loglik="), "{}", lines[1]);
    assert!(lines[1].contains("cache=miss"), "{}", lines[1]);
    assert!(lines[2].contains("cache=hit"), "{}", lines[2]);
    assert!(lines[3].starts_with("err "), "{}", lines[3]);
    assert!(lines[4].starts_with("stats "), "{}", lines[4]);
    assert!(lines[4].contains("cache_hits=1"), "{}", lines[4]);
    assert_eq!(lines[5], "ok bye");
    // Both scores agree bit-for-bit (same read, cached vs fresh tables).
    let ll = |line: &str| {
        line.split_whitespace()
            .find_map(|t| t.strip_prefix("loglik="))
            .unwrap()
            .to_string()
    };
    assert_eq!(ll(lines[1]), ll(lines[2]));
}

/// Tentpole contract (observability): span capture sits at stage
/// boundaries only, so tracing must not perturb a single result bit.
/// The same mixed Score / Align / Correct workload runs once untraced
/// and once traced; every response compares bit-for-bit, and only the
/// traced run retains timelines in the ring.
#[test]
fn tracing_on_vs_off_is_bit_identical() {
    let mut rng = XorShift::new(214);
    let reference = dna(&mut rng, "chr1", 60);
    let phmm = Phmm::error_correction(&reference, &EcDesignParams::default()).unwrap();
    let reads = reads_of(&mut rng, &reference, 6);

    let run = |traced: bool| -> Vec<String> {
        let mut server = Server::start(ServerConfig { n_workers: 2, ..Default::default() });
        server.register_profile("chr1", phmm.clone());
        let tickets: Vec<_> = reads
            .iter()
            .enumerate()
            .map(|(i, r)| {
                let req = match i % 3 {
                    0 => Request::Score { profile: "chr1".into(), read: r.clone() },
                    1 => Request::Align { profile: "chr1".into(), read: r.clone() },
                    _ => Request::Correct {
                        reference: reference.clone(),
                        reads: reads.clone(),
                    },
                };
                server
                    .submit_traced("bit", Priority::Normal, None, req, None, traced)
                    .unwrap()
            })
            .collect();
        // Render every response down to its raw bits so traced and
        // untraced runs compare exactly (f64s via to_bits).
        let keys: Vec<String> = tickets
            .into_iter()
            .map(|t| match t.wait().body {
                ResponseBody::Score { loglik, log_odds, .. } => format!(
                    "score:{:016x}:{:016x}",
                    loglik.to_bits(),
                    log_odds.to_bits()
                ),
                ResponseBody::Align { row, .. } => format!(
                    "align:{:?}:{}:{:016x}",
                    row.columns,
                    row.insertions,
                    row.loglik.to_bits()
                ),
                ResponseBody::Correct { consensus, mean_loglik, iters } => format!(
                    "correct:{:?}:{:016x}:{iters}",
                    consensus.data,
                    mean_loglik.to_bits()
                ),
                other => panic!("request failed (traced={traced}): {other:?}"),
            })
            .collect();
        let dump = server.trace_dump();
        if traced {
            assert_eq!(dump.len(), keys.len(), "every traced request must be retained");
            for line in &dump {
                assert!(line.contains("\"spans\""), "{line}");
                assert!(line.contains("\"ok\":true"), "{line}");
            }
        } else {
            assert!(dump.is_empty(), "untraced requests must never touch the ring");
        }
        server.shutdown(true);
        keys
    };

    assert_eq!(run(false), run(true), "tracing must not perturb any result bit");
}

/// Wire observability: `trace on` echoes trace ids on response lines,
/// `trace-dump` replays the retained timeline as one-line JSON with a
/// complete admission→respond span breakdown, and `metrics` emits a
/// Prometheus text block in which every line parses as exposition
/// format (`# HELP` / `# TYPE` / `# EOF` or `name{labels} value`).
#[test]
fn wire_trace_and_metrics_round_trip() {
    let mut rng = XorShift::new(215);
    let reference = dna(&mut rng, "chr1", 40);
    let ascii_ref = reference.to_ascii(aphmm::seq::DNA);
    let read = simulate_read(&mut rng, &reference, 0, 40, &ErrorProfile::pacbio(), 0).seq;
    let ascii_read = read.to_ascii(aphmm::seq::DNA);

    let mut server = Server::start(ServerConfig { n_workers: 1, ..Default::default() });
    let script = format!(
        "register chr1 {ascii_ref}\ntrace on\nscore chr1 {ascii_read}\n\
         trace-dump\nmetrics\ntrace off\nquit\n"
    );
    let mut out: Vec<u8> = Vec::new();
    let end = aphmm::server::serve_connection(&server, script.as_bytes(), &mut out).unwrap();
    assert_eq!(end, aphmm::server::SessionEnd::Quit);
    server.shutdown(true);

    let text = String::from_utf8(out).unwrap();
    let lines: Vec<&str> = text.lines().collect();
    assert!(lines[0].starts_with("ok profile chr1 states="), "{}", lines[0]);
    assert_eq!(lines[1], "ok trace on");
    assert!(lines[2].starts_with("score chr1 loglik="), "{}", lines[2]);
    let trace_id = lines[2]
        .split_whitespace()
        .find_map(|t| t.strip_prefix("trace="))
        .expect("traced score reply must echo its trace id");

    // trace-dump: one JSON timeline (the traced score, keyed by the
    // echoed id) covering every pipeline stage, then the summary line.
    assert!(
        lines[3].starts_with('{') && lines[3].contains(&format!("\"trace_id\":{trace_id}")),
        "{}",
        lines[3]
    );
    for stage in
        ["admission", "queue_wait", "cache_freeze", "forward", "backward", "update", "respond"]
    {
        assert!(lines[3].contains(&format!("\"{stage}\":")), "missing {stage}: {}", lines[3]);
    }
    assert!(lines[3].contains("\"kind\":\"score\""), "{}", lines[3]);
    assert_eq!(lines[4], "ok trace-dump n=1");

    // metrics: the block runs up to its `# EOF` terminator; the
    // session then keeps serving (`ok trace off`, `ok bye`).
    let eof = lines.iter().position(|l| *l == "# EOF").expect("metrics must end with # EOF");
    let block = &lines[5..eof];
    let is_sample = |line: &str| -> bool {
        let (series, value) = match line.rsplit_once(' ') {
            Some(pair) => pair,
            None => return false,
        };
        if value.parse::<f64>().is_err() {
            return false;
        }
        let name_end = series.find('{').unwrap_or(series.len());
        let name = &series[..name_end];
        (name_end == series.len() || series.ends_with('}'))
            && name.starts_with("aphmm_")
            && name.chars().all(|c| c.is_ascii_alphanumeric() || c == '_')
    };
    assert!(!block.is_empty());
    for line in block {
        assert!(
            line.starts_with("# HELP aphmm_") || line.starts_with("# TYPE aphmm_")
                || is_sample(line),
            "unparseable exposition line: {line:?}"
        );
    }
    // The families the paper's bottleneck breakdown cares about.
    let has = |needle: &str| block.iter().any(|l| l.contains(needle));
    assert!(has("# TYPE aphmm_stage_seconds histogram"), "{text}");
    assert!(has("aphmm_stage_seconds_bucket{stage=\"forward\",le=\"+Inf\"}"), "{text}");
    assert!(has("aphmm_stage_seconds_count{stage=\"queue_wait\"}"), "{text}");
    assert!(has("aphmm_requests_total{result=\"ok\"} 1"), "{text}");
    assert!(has("aphmm_cache_ops_total{op=\"miss\"} 1"), "{text}");
    // A solo score runs the one-read kernel, not a striped pass, so
    // the fill distribution is present but all-zero here (the batch
    // path is pinned by the bench's stage section and CI grep).
    assert!(has("aphmm_stripe_fill_passes_total{fill=\"1\"} 0"), "{text}");
    assert!(has("aphmm_stripe_fill_passes_total{fill=\"8\"} 0"), "{text}");
    assert!(has("aphmm_simd_lane_width"), "{text}");
    assert_eq!(lines[eof + 1], "ok trace off");
    assert_eq!(lines[eof + 2], "ok bye");
}

/// Satellite: `tenants` output (wire line and `MetricsSummary` alike)
/// is deterministically sorted by tenant id, independent of submission
/// order — diffable across scrapes.
#[test]
fn tenants_output_is_sorted_by_tenant_id() {
    let mut rng = XorShift::new(216);
    let reference = dna(&mut rng, "chr1", 40);
    let phmm = Phmm::error_correction(&reference, &EcDesignParams::default()).unwrap();
    let mut server = Server::start(ServerConfig { n_workers: 1, ..Default::default() });
    server.register_profile("chr1", phmm);
    let read = reads_of(&mut rng, &reference, 1).remove(0);

    // Deliberately submit in non-sorted order.
    for tenant in ["zeta", "alpha", "mid"] {
        let resp = server
            .submit_for(
                tenant,
                Priority::Normal,
                None,
                Request::Score { profile: "chr1".into(), read: read.clone() },
            )
            .unwrap()
            .wait();
        assert!(matches!(resp.body, ResponseBody::Score { .. }), "{:?}", resp.body);
    }
    let m = server.metrics_summary();
    let order: Vec<&str> = m.tenants.iter().map(|t| t.tenant.as_str()).collect();
    assert_eq!(order, vec!["alpha", "mid", "zeta"], "summary tenants must sort by id");

    let line = server.tenants_line();
    let pos = |needle: &str| {
        line.find(needle).unwrap_or_else(|| panic!("{needle} missing from {line}"))
    };
    assert!(pos("alpha:") < pos("mid:"), "{line}");
    assert!(pos("mid:") < pos("zeta:"), "{line}");
    server.shutdown(true);
}
