//! Integration: the streaming multi-tenant server — concurrent mixed
//! workloads vs a serial replay, cross-request Prepared-cache reuse,
//! and clean teardown (no leaked threads).

use aphmm::apps;
use aphmm::baumwelch::{EngineKind, ForwardOptions, PreparedAny, TrainConfig};
use aphmm::phmm::{EcDesignParams, Phmm};
use aphmm::pool::WorkerPool;
use aphmm::seq::Sequence;
use aphmm::server::{PushError, Request, Response, ResponseBody, Server, ServerConfig};
use aphmm::sim::{simulate_read, ErrorProfile, XorShift};
use aphmm::testutil;

fn dna(rng: &mut XorShift, id: &str, len: usize) -> Sequence {
    Sequence::from_symbols(id, testutil::random_seq(rng, len, 4))
}

fn reads_of(rng: &mut XorShift, reference: &Sequence, n: usize) -> Vec<Sequence> {
    (0..n)
        .map(|i| {
            simulate_read(rng, reference, 0, reference.len(), &ErrorProfile::pacbio(), i).seq
        })
        .collect()
}

/// The expected answer for one request, computed serially with the
/// library primitives (no queue, no cache, no worker pool fan-out).
#[derive(Debug, Clone, PartialEq)]
enum Expected {
    Score { loglik_bits: u64 },
    Correct { consensus: Vec<u8>, mean_loglik_bits: u64, iters: usize },
}

fn serial_replay(
    req: &Request,
    profiles: &[(String, Phmm)],
    train: &TrainConfig,
    design: &EcDesignParams,
) -> Expected {
    match req {
        Request::Score { profile, read } => {
            let (_, phmm) = profiles.iter().find(|(n, _)| n == profile).unwrap();
            let prepared = PreparedAny::freeze(EngineKind::Sparse, phmm).unwrap();
            let mut scratch = prepared.make_scratch(phmm);
            let res =
                prepared.score(phmm, read, &ForwardOptions::default(), &mut scratch).unwrap();
            Expected::Score { loglik_bits: res.loglik.to_bits() }
        }
        Request::Correct { reference, reads } => {
            let pool = WorkerPool::new(0);
            let out =
                apps::train_chunk(reference, reads, design, aphmm::seq::DNA, train, &pool)
                    .unwrap();
            Expected::Correct {
                consensus: out.consensus.data,
                mean_loglik_bits: out
                    .train
                    .loglik_history
                    .last()
                    .copied()
                    .unwrap_or(f64::NEG_INFINITY)
                    .to_bits(),
                iters: out.train.iters,
            }
        }
        other => panic!("no serial replay for {other:?}"),
    }
}

fn assert_matches_expected(resp: &Response, expected: &Expected, what: &str) {
    match (&resp.body, expected) {
        (ResponseBody::Score { loglik, .. }, Expected::Score { loglik_bits }) => {
            assert_eq!(loglik.to_bits(), *loglik_bits, "{what}: score diverged from serial run");
        }
        (
            ResponseBody::Correct { consensus, mean_loglik, iters },
            Expected::Correct { consensus: want, mean_loglik_bits, iters: want_iters },
        ) => {
            assert_eq!(&consensus.data, want, "{what}: consensus diverged from serial run");
            assert_eq!(
                mean_loglik.to_bits(),
                *mean_loglik_bits,
                "{what}: training loglik diverged from serial run"
            );
            assert_eq!(iters, want_iters, "{what}: iteration count diverged");
        }
        (body, expected) => panic!("{what}: response {body:?} does not match {expected:?}"),
    }
}

/// Acceptance: ≥ 64 concurrent requests from ≥ 4 producer threads with
/// `queue_depth = 8` complete without deadlock, and every result is
/// bit-identical to a serial replay of the same request.
#[test]
fn concurrent_mixed_requests_match_serial_replay() {
    let mut rng = XorShift::new(201);
    let ref_a = dna(&mut rng, "chrA", 60);
    let ref_b = dna(&mut rng, "chrB", 60);
    let profiles: Vec<(String, Phmm)> = [("pa", &ref_a), ("pb", &ref_b)]
        .into_iter()
        .map(|(name, r)| {
            (name.to_string(), Phmm::error_correction(r, &EcDesignParams::default()).unwrap())
        })
        .collect();

    // 4 producers × 16 requests, mixing cached scoring and training.
    const PRODUCERS: usize = 4;
    const PER_PRODUCER: usize = 16;
    let mut requests: Vec<Vec<Request>> = Vec::new();
    for p in 0..PRODUCERS {
        let mut mine = Vec::new();
        for i in 0..PER_PRODUCER {
            let which = (p + i) % 2;
            let (name, reference) =
                if which == 0 { ("pa", &ref_a) } else { ("pb", &ref_b) };
            if i % 4 == 3 {
                mine.push(Request::Correct {
                    reference: reference.clone(),
                    reads: reads_of(&mut rng, reference, 3),
                });
            } else {
                let read = simulate_read(
                    &mut rng,
                    reference,
                    0,
                    reference.len(),
                    &ErrorProfile::pacbio(),
                    p * PER_PRODUCER + i,
                )
                .seq;
                mine.push(Request::Score { profile: name.to_string(), read });
            }
        }
        requests.push(mine);
    }

    let cfg = ServerConfig { n_workers: 4, queue_depth: 8, ..Default::default() };
    let train = cfg.train;
    let design = cfg.design;
    let expected: Vec<Vec<Expected>> = requests
        .iter()
        .map(|mine| mine.iter().map(|r| serial_replay(r, &profiles, &train, &design)).collect())
        .collect();

    let mut server = Server::start(cfg);
    for (name, phmm) in &profiles {
        server.register_profile(name, phmm.clone());
    }
    let responses: Vec<Vec<Response>> = std::thread::scope(|scope| {
        let server = &server;
        let handles: Vec<_> = requests
            .iter()
            .map(|mine| {
                scope.spawn(move || {
                    // Submit the whole stream (blocking admission
                    // control), then collect in order.
                    let tickets: Vec<_> = mine
                        .iter()
                        .map(|req| server.submit(None, req.clone()).unwrap())
                        .collect();
                    tickets.into_iter().map(|t| t.wait()).collect::<Vec<_>>()
                })
            })
            .collect();
        handles.into_iter().map(|h| h.join().unwrap()).collect()
    });

    for (p, (resps, wants)) in responses.iter().zip(expected.iter()).enumerate() {
        assert_eq!(resps.len(), PER_PRODUCER);
        for (i, (resp, want)) in resps.iter().zip(wants.iter()).enumerate() {
            assert_matches_expected(resp, want, &format!("producer {p} request {i}"));
            assert!(resp.latency_ns > 0, "producer {p} request {i} has no latency");
        }
    }

    // The queue really was bounded, and the metrics saw every job.
    let q = server.queue_stats();
    assert!(q.high_water <= 8, "queue depth bound violated: {}", q.high_water);
    assert_eq!(q.pushed, (PRODUCERS * PER_PRODUCER) as u64);
    assert_eq!(q.pushed, q.popped);
    let m = server.metrics_summary();
    assert_eq!(m.jobs_done, (PRODUCERS * PER_PRODUCER) as u64);
    assert_eq!(m.jobs_failed, 0);
    assert!(m.latency_p99_ms >= m.latency_p50_ms);
    server.shutdown(true);
}

/// Acceptance: the second request for the same profile is a
/// Prepared-cache hit (hit counter == 1) — the freeze ran once.
#[test]
fn repeated_profile_requests_reuse_the_frozen_tables() {
    let mut rng = XorShift::new(202);
    let reference = dna(&mut rng, "chr1", 50);
    let phmm = Phmm::error_correction(&reference, &EcDesignParams::default()).unwrap();
    let mut server = Server::start(ServerConfig { n_workers: 2, ..Default::default() });
    server.register_profile("chr1", phmm);

    let reads = reads_of(&mut rng, &reference, 2);
    let first = server
        .submit(None, Request::Score { profile: "chr1".into(), read: reads[0].clone() })
        .unwrap()
        .wait();
    let second = server
        .submit(None, Request::Score { profile: "chr1".into(), read: reads[1].clone() })
        .unwrap()
        .wait();
    match (&first.body, &second.body) {
        (
            ResponseBody::Score { cache_hit: h1, .. },
            ResponseBody::Score { cache_hit: h2, .. },
        ) => {
            assert!(!*h1, "first request must freeze the tables");
            assert!(*h2, "second request must not re-freeze");
        }
        other => panic!("unexpected responses {other:?}"),
    }
    let c = server.cache_stats();
    assert_eq!(c.misses, 1, "exactly one freeze");
    assert_eq!(c.hits, 1, "exactly one reuse");
    assert_eq!(c.entries, 1);
    server.shutdown(true);
}

/// Satellite: dropping a server mid-stream leaks no threads — the
/// dispatcher and every pool helper are joined, and pending requests
/// fail explicitly instead of hanging their clients.
#[test]
fn dropping_a_server_mid_stream_leaks_no_threads() {
    let mut rng = XorShift::new(203);
    let reference = dna(&mut rng, "chr1", 80);
    let reads = reads_of(&mut rng, &reference, 6);
    let server = Server::start(ServerConfig {
        n_workers: 2,
        queue_depth: 16,
        ..Default::default()
    });
    let probe = server.pool_liveness();
    assert!(probe.upgrade().is_some());

    let tickets: Vec<_> = (0..10)
        .map(|_| {
            server
                .submit(
                    None,
                    Request::Correct { reference: reference.clone(), reads: reads.clone() },
                )
                .unwrap()
        })
        .collect();

    // Abort mid-stream.
    drop(server);
    assert!(
        probe.upgrade().is_none(),
        "pool helpers must be joined when the server is dropped"
    );
    let mut done = 0usize;
    let mut aborted = 0usize;
    for t in tickets {
        match t.wait().body {
            ResponseBody::Correct { .. } => done += 1,
            ResponseBody::Error { .. } => aborted += 1,
            other => panic!("unexpected response {other:?}"),
        }
    }
    assert_eq!(done + aborted, 10);
    assert!(aborted > 0, "a 10-deep backlog on 2 workers cannot fully drain on abort");
}

/// Busy admission control surfaces as a typed refusal, not a block,
/// on the non-blocking submit path.
#[test]
fn try_submit_refuses_when_the_queue_is_full() {
    let mut rng = XorShift::new(204);
    let reference = dna(&mut rng, "chr1", 80);
    let reads = reads_of(&mut rng, &reference, 8);
    // One worker, tiny queue: flood it with slow training jobs.
    let mut server = Server::start(ServerConfig {
        n_workers: 1,
        queue_depth: 2,
        ..Default::default()
    });
    let mut tickets = Vec::new();
    let mut refused = 0usize;
    for _ in 0..50 {
        match server.try_submit(
            None,
            Request::Correct { reference: reference.clone(), reads: reads.clone() },
        ) {
            Ok(t) => tickets.push(t),
            Err(PushError::Busy(_)) => refused += 1,
            Err(PushError::Closed(_)) => panic!("server closed unexpectedly"),
        }
    }
    assert!(refused > 0, "a depth-2 queue must refuse some of 50 instant submissions");
    for t in tickets {
        assert!(matches!(t.wait().body, ResponseBody::Correct { .. }));
    }
    let q = server.queue_stats();
    assert!(q.high_water <= 2);
    assert!(q.producer_blocks >= refused as u64);
    server.shutdown(true);
}

/// The wire protocol end-to-end over an in-memory session: register,
/// score twice (second is a cache hit), stats, quit.
#[test]
fn line_protocol_round_trip() {
    let mut rng = XorShift::new(205);
    let reference = dna(&mut rng, "chr1", 40);
    let ascii_ref = reference.to_ascii(aphmm::seq::DNA);
    let read = simulate_read(&mut rng, &reference, 0, 40, &ErrorProfile::pacbio(), 0).seq;
    let ascii_read = read.to_ascii(aphmm::seq::DNA);

    let mut server = Server::start(ServerConfig { n_workers: 2, ..Default::default() });
    let script = format!(
        "register chr1 {ascii_ref}\nscore chr1 {ascii_read}\nscore chr1 {ascii_read}\n\
         bogus line\nstats\nquit\n"
    );
    let mut out: Vec<u8> = Vec::new();
    let end =
        aphmm::server::serve_connection(&server, script.as_bytes(), &mut out).unwrap();
    assert_eq!(end, aphmm::server::SessionEnd::Quit);
    server.shutdown(true);

    let text = String::from_utf8(out).unwrap();
    let lines: Vec<&str> = text.lines().collect();
    assert_eq!(lines.len(), 6, "one response per request line:\n{text}");
    assert!(lines[0].starts_with("ok profile chr1 states="), "{}", lines[0]);
    assert!(lines[1].starts_with("score chr1 loglik="), "{}", lines[1]);
    assert!(lines[1].contains("cache=miss"), "{}", lines[1]);
    assert!(lines[2].contains("cache=hit"), "{}", lines[2]);
    assert!(lines[3].starts_with("err "), "{}", lines[3]);
    assert!(lines[4].starts_with("stats "), "{}", lines[4]);
    assert!(lines[4].contains("cache_hits=1"), "{}", lines[4]);
    assert_eq!(lines[5], "ok bye");
    // Both scores agree bit-for-bit (same read, cached vs fresh tables).
    let ll = |line: &str| {
        line.split_whitespace()
            .find_map(|t| t.strip_prefix("loglik="))
            .unwrap()
            .to_string()
    };
    assert_eq!(ll(lines[1]), ll(lines[2]));
}
