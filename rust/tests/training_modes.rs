//! Training-schedule integration tests over the public API: the
//! minibatch and Viterbi schedules must land where full-batch EM lands
//! (evaluated by one fixed forward scorer), seeded runs must be
//! bit-reproducible, and the streaming path must keep its memory bound.

use aphmm::baumwelch::{
    train, train_source, EngineKind, ExpectationEngine, FastaSource, ForwardOptions, SparseEngine,
    TrainConfig, TrainMode,
};
use aphmm::io::write_fasta;
use aphmm::phmm::{EcDesignParams, Phmm};
use aphmm::seq::{Sequence, DNA};
use aphmm::sim::{generate_genome, simulate_read, ErrorProfile, XorShift};

/// One training workload: an EC-design graph plus reads drawn from its
/// reference, the same shape the coordinator trains per chunk.
fn workload(seed: u64, ref_len: usize, n_reads: usize) -> (Phmm, Vec<Sequence>) {
    let mut rng = XorShift::new(seed);
    let reference = generate_genome(&mut rng, ref_len);
    let reads: Vec<Sequence> = (0..n_reads)
        .map(|i| simulate_read(&mut rng, &reference, 0, ref_len, &ErrorProfile::pacbio(), i).seq)
        .collect();
    let phmm = Phmm::error_correction(&reference, &EcDesignParams::default()).unwrap();
    (phmm, reads)
}

/// Mean forward log-likelihood of `reads` under `phmm` — the one fixed
/// evaluation every schedule is compared with, independent of what each
/// schedule reports in its own `loglik_history`.
fn mean_forward_ll(phmm: &Phmm, reads: &[Sequence]) -> f64 {
    let engine = SparseEngine;
    let prep = engine.prepare(phmm).unwrap();
    let mut scratch = engine.make_scratch(phmm);
    let opts = ForwardOptions::default();
    let mut sum = 0.0;
    let mut n = 0usize;
    for read in reads {
        if let Ok(score) = engine.score(phmm, &prep, read, &opts, &mut scratch) {
            sum += score.loglik;
            n += 1;
        }
    }
    assert!(n > 0, "no read scored");
    sum / n as f64
}

fn cfg(mode: TrainMode) -> TrainConfig {
    TrainConfig { max_iters: 6, tol: 0.0, mode, minibatch: 8, seed: 3, ..Default::default() }
}

#[test]
fn minibatch_converges_where_full_batch_does() {
    let (phmm, reads) = workload(101, 160, 24);

    let mut batch_phmm = phmm.clone();
    let batch = train(&mut batch_phmm, &reads, &cfg(TrainMode::Batch)).unwrap();
    let mut mb_phmm = phmm.clone();
    let mb = train(&mut mb_phmm, &reads, &cfg(TrainMode::Minibatch)).unwrap();

    assert!(batch.iters >= 1 && mb.iters >= 1);
    assert!(mb.minibatches >= mb.iters as u64 * 3, "24 reads / mb 8 = 3 per epoch");
    let ll_batch = mean_forward_ll(&batch_phmm, &reads);
    let ll_mb = mean_forward_ll(&mb_phmm, &reads);
    let tol = 0.05 * ll_batch.abs() + 1.0;
    assert!(
        (ll_batch - ll_mb).abs() <= tol,
        "minibatch landed at {ll_mb}, full batch at {ll_batch} (tol {tol})"
    );
}

#[test]
fn viterbi_training_converges_near_full_batch() {
    let (phmm, reads) = workload(102, 160, 24);

    let mut batch_phmm = phmm.clone();
    train(&mut batch_phmm, &reads, &cfg(TrainMode::Batch)).unwrap();
    let mut vit_phmm = phmm.clone();
    let vit = train(&mut vit_phmm, &reads, &cfg(TrainMode::Viterbi)).unwrap();

    assert!(vit.iters >= 1);
    // Hard counts approximate the soft posteriors: the Viterbi-trained
    // model must score the corpus in the same neighbourhood as EM
    // (looser tolerance — the dominant path is not the full sum).
    let ll_batch = mean_forward_ll(&batch_phmm, &reads);
    let ll_vit = mean_forward_ll(&vit_phmm, &reads);
    let tol = 0.15 * ll_batch.abs() + 2.0;
    assert!(
        (ll_batch - ll_vit).abs() <= tol,
        "viterbi landed at {ll_vit}, full batch at {ll_batch} (tol {tol})"
    );
    // And it must actually have climbed: better than the untrained model.
    let ll_init = mean_forward_ll(&phmm, &reads);
    assert!(ll_vit > ll_init, "viterbi training regressed: {ll_vit} <= {ll_init}");
}

#[test]
fn same_seed_is_bit_identical_different_seed_converges_alike() {
    let (phmm, reads) = workload(103, 120, 20);

    let mut a_phmm = phmm.clone();
    let a = train(&mut a_phmm, &reads, &cfg(TrainMode::Minibatch)).unwrap();
    let mut b_phmm = phmm.clone();
    let b = train(&mut b_phmm, &reads, &cfg(TrainMode::Minibatch)).unwrap();

    // Same seed: the whole run is a pure function of (graph, corpus,
    // config) — histories and parameters bit-identical.
    assert_eq!(a.loglik_history, b.loglik_history);
    assert_eq!(a.minibatches, b.minibatches);
    assert_eq!(a_phmm.out_prob, b_phmm.out_prob);
    assert_eq!(a_phmm.emissions, b_phmm.emissions);
    assert_eq!(a_phmm.f_init, b_phmm.f_init);

    // Different seed: a different sample path, the same destination.
    let mut c_phmm = phmm.clone();
    let ccfg = TrainConfig { seed: 99, ..cfg(TrainMode::Minibatch) };
    train(&mut c_phmm, &reads, &ccfg).unwrap();
    let ll_a = mean_forward_ll(&a_phmm, &reads);
    let ll_c = mean_forward_ll(&c_phmm, &reads);
    let tol = 0.05 * ll_a.abs() + 1.0;
    assert!(
        (ll_a - ll_c).abs() <= tol,
        "seeds diverged: {ll_a} vs {ll_c} (tol {tol})"
    );
}

#[test]
fn every_mode_runs_behind_every_in_process_engine() {
    let (phmm, reads) = workload(104, 100, 12);
    for engine in [EngineKind::Sparse, EngineKind::Banded, EngineKind::Reference] {
        for mode in [TrainMode::Batch, TrainMode::Minibatch, TrainMode::Viterbi, TrainMode::Auto] {
            let mut p = phmm.clone();
            let tcfg = TrainConfig { engine, max_iters: 2, tol: 0.0, mode, ..cfg(mode) };
            let res = train(&mut p, &reads, &tcfg)
                .unwrap_or_else(|e| panic!("{}/{} failed: {e}", engine.name(), mode.name()));
            assert!(res.iters >= 1, "{}/{} ran no iterations", engine.name(), mode.name());
            assert_eq!(res.epochs, res.iters as u64);
        }
    }
}

#[test]
fn streaming_ingestion_keeps_residency_bounded() {
    let mut rng = XorShift::new(105);
    let reference = generate_genome(&mut rng, 120);
    let n_reads = 160usize;
    let reads: Vec<Sequence> = (0..n_reads)
        .map(|i| simulate_read(&mut rng, &reference, 0, 120, &ErrorProfile::pacbio(), i).seq)
        .collect();

    let dir = std::env::temp_dir().join("aphmm_training_modes_test");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("corpus.fa");
    let mut buf = Vec::new();
    write_fasta(&mut buf, &reads, DNA).unwrap();
    std::fs::write(&path, buf).unwrap();

    let mut phmm = Phmm::error_correction(&reference, &EcDesignParams::default()).unwrap();
    let tcfg = TrainConfig {
        max_iters: 2,
        tol: 0.0,
        mode: TrainMode::Minibatch,
        minibatch: 16,
        seed: 7,
        ..Default::default()
    };
    let mut source = FastaSource::open(&path, DNA).unwrap();
    let res = train_source(&mut phmm, &mut source, &tcfg).unwrap();
    std::fs::remove_file(&path).ok();

    assert_eq!(res.iters, 2);
    // Every epoch streams the whole corpus exactly once...
    assert_eq!(res.sequences_streamed, (n_reads * res.iters) as u64);
    // ...but residency is bounded by the shuffle window (16 reads × the
    // window factor of 8 = 128), never the 160-read corpus.
    assert!(
        res.peak_resident_reads <= 128,
        "peak residency {} exceeds the shuffle window",
        res.peak_resident_reads
    );
    assert!(res.peak_resident_reads >= 16, "window never filled");
    // 160 reads / 16 per minibatch = 10 minibatches per epoch.
    assert_eq!(res.minibatches, (10 * res.iters) as u64);
}

#[test]
fn auto_mode_streams_as_minibatch() {
    // A streaming source has no len_hint, so Auto must resolve to the
    // minibatch schedule instead of materializing the corpus.
    let mut rng = XorShift::new(106);
    let reference = generate_genome(&mut rng, 100);
    let reads: Vec<Sequence> = (0..40)
        .map(|i| simulate_read(&mut rng, &reference, 0, 100, &ErrorProfile::pacbio(), i).seq)
        .collect();
    let dir = std::env::temp_dir().join("aphmm_training_modes_test");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("auto.fa");
    let mut buf = Vec::new();
    write_fasta(&mut buf, &reads, DNA).unwrap();
    std::fs::write(&path, buf).unwrap();

    let mut phmm = Phmm::error_correction(&reference, &EcDesignParams::default()).unwrap();
    let tcfg = TrainConfig {
        max_iters: 1,
        tol: 0.0,
        mode: TrainMode::Auto,
        minibatch: 4,
        ..Default::default()
    };
    let mut source = FastaSource::open(&path, DNA).unwrap();
    let res = train_source(&mut phmm, &mut source, &tcfg).unwrap();
    std::fs::remove_file(&path).ok();
    assert!(res.minibatches > 0, "Auto on a streaming source must pick minibatch");
    assert!(res.peak_resident_reads < 40, "Auto materialized the stream");
}
