//! Deterministic fault injection (requires `--features failpoints`):
//! every serving-layer failure path — deadline mid-compute, explicit
//! cancellation, contained panics, failed cache inserts, wire faults,
//! load shedding under a pinned backlog — is driven by an armed
//! failpoint, not by timing luck.  Each scenario holds the global
//! [`aphmm::failpoint::scenario`] guard so concurrently-running tests
//! never observe each other's armed sites.

#![cfg(feature = "failpoints")]

use std::time::Duration;

use aphmm::baumwelch::{ScratchMode, TrainConfig};
use aphmm::failpoint::{self, Action};
use aphmm::phmm::{EcDesignParams, Phmm};
use aphmm::seq::Sequence;
use aphmm::server::{
    AdmitError, FailureCause, Priority, Request, ResponseBody, Server, ServerConfig, TenantQuota,
};
use aphmm::sim::{simulate_read, ErrorProfile, XorShift};
use aphmm::testutil;

fn dna(rng: &mut XorShift, id: &str, len: usize) -> Sequence {
    Sequence::from_symbols(id, testutil::random_seq(rng, len, 4))
}

fn reads_of(rng: &mut XorShift, reference: &Sequence, n: usize) -> Vec<Sequence> {
    (0..n)
        .map(|i| {
            simulate_read(rng, reference, 0, reference.len(), &ErrorProfile::pacbio(), i).seq
        })
        .collect()
}

/// Tentpole (deadline mid-compute): a Sleep failpoint at the E-step's
/// per-read boundary holds the job long enough for its budget to
/// expire **while computing**; the next boundary check aborts the
/// whole request with a typed `DeadlineExceeded` failure — it never
/// runs to completion.
#[test]
fn deadline_fires_mid_compute_at_a_read_boundary() {
    let _s = failpoint::scenario();
    failpoint::configure("engine::accumulate", Action::Sleep(20));

    let mut rng = XorShift::new(301);
    let reference = dna(&mut rng, "chr1", 60);
    let reads = reads_of(&mut rng, &reference, 4);
    let mut server = Server::start(ServerConfig { n_workers: 1, ..Default::default() });
    let resp = server
        .submit_with_deadline(
            "slow",
            Priority::Normal,
            None,
            Request::Correct { reference, reads },
            Some(Duration::from_millis(5)),
        )
        .unwrap()
        .wait();
    match &resp.body {
        ResponseBody::Failure { cause, .. } => {
            assert_eq!(*cause, FailureCause::DeadlineExceeded);
        }
        other => panic!("expected DeadlineExceeded mid-compute, got {other:?}"),
    }
    let m = server.metrics_summary();
    assert_eq!(m.deadline_exceeded, 1);
    assert_eq!(m.jobs_failed, 1);
    let t = m.tenants.iter().find(|t| t.tenant == "slow").unwrap();
    assert_eq!(t.deadline_exceeded, 1);
    server.shutdown(true);
}

/// Tentpole (explicit cancel mid-compute): with every read boundary
/// slowed by a Sleep failpoint, a cancel issued after submission is
/// observed at the next boundary and aborts the request with a typed
/// `Cancelled` failure.
#[test]
fn cancel_fires_mid_compute_at_a_read_boundary() {
    let _s = failpoint::scenario();
    failpoint::configure("engine::accumulate", Action::Sleep(10));

    let mut rng = XorShift::new(302);
    let reference = dna(&mut rng, "chr1", 60);
    let reads = reads_of(&mut rng, &reference, 4);
    let mut server = Server::start(ServerConfig { n_workers: 1, ..Default::default() });
    let ticket = server
        .submit(None, Request::Correct { reference, reads })
        .unwrap();
    ticket.cancel();
    let resp = ticket.wait();
    match &resp.body {
        ResponseBody::Failure { cause, .. } => assert_eq!(*cause, FailureCause::Cancelled),
        other => panic!("expected Cancelled, got {other:?}"),
    }
    assert_eq!(server.metrics_summary().cancelled, 1);
    server.shutdown(true);
}

/// Checkpointed-scratch cancellation contract: during the backward
/// sweep's segment recomputes, cancellation is observed at **segment
/// boundaries only** — the `engine::segment` failpoint sits exactly at
/// that check, so with every boundary slowed by a Sleep, a cancel
/// issued after submission lands mid-recompute and aborts the request
/// with a typed `Cancelled` failure instead of running the remaining
/// segments.  (Inside a segment the kernels run to the next boundary
/// untouched; a reduction is never torn.)
#[test]
fn cancel_fires_mid_segment_recompute_at_a_segment_boundary() {
    let _s = failpoint::scenario();
    failpoint::configure("engine::segment", Action::Sleep(10));

    let mut rng = XorShift::new(309);
    let reference = dna(&mut rng, "chr1", 60);
    let reads = reads_of(&mut rng, &reference, 4);
    // Checkpointed forced on: every read's backward sweep recomputes
    // ~√T segments, each crossing the armed boundary failpoint.
    let mut server = Server::start(ServerConfig {
        n_workers: 1,
        train: TrainConfig { scratch_mode: ScratchMode::Checkpointed, ..Default::default() },
        ..Default::default()
    });
    let ticket = server
        .submit(None, Request::Correct { reference, reads })
        .unwrap();
    ticket.cancel();
    let resp = ticket.wait();
    match &resp.body {
        ResponseBody::Failure { cause, .. } => assert_eq!(*cause, FailureCause::Cancelled),
        other => panic!("expected Cancelled at a segment boundary, got {other:?}"),
    }
    assert_eq!(server.metrics_summary().cancelled, 1);
    server.shutdown(true);
}

/// Tentpole (panic containment + bit-identity): a panic injected into
/// the cache-insert path of one request yields a typed `Panicked`
/// failure carrying the original payload message; the worker, pool,
/// cache, and queue survive, and the *next* request on the same server
/// completes bit-identically to an undisturbed server.
#[test]
fn injected_panic_is_contained_and_later_results_are_bit_identical() {
    let _s = failpoint::scenario();

    let mut rng = XorShift::new(303);
    let reference = dna(&mut rng, "chr1", 50);
    let phmm = Phmm::error_correction(&reference, &EcDesignParams::default()).unwrap();
    let read = reads_of(&mut rng, &reference, 1).remove(0);
    let req = Request::Score { profile: "chr1".into(), read };

    // Undisturbed server: the reference answer.
    let mut clean = Server::start(ServerConfig { n_workers: 1, ..Default::default() });
    clean.register_profile("chr1", phmm.clone());
    let want_bits = match clean.submit(None, req.clone()).unwrap().wait().body {
        ResponseBody::Score { loglik, .. } => loglik.to_bits(),
        other => panic!("clean server failed: {other:?}"),
    };
    clean.shutdown(true);

    // Disturbed server: the first request panics inside the worker.
    let mut server = Server::start(ServerConfig { n_workers: 1, ..Default::default() });
    server.register_profile("chr1", phmm);
    let probe = server.pool_liveness();
    failpoint::configure_times("cache::insert", Action::Panic("injected-fault".into()), 1);
    let resp = server.submit(None, req.clone()).unwrap().wait();
    match &resp.body {
        ResponseBody::Failure { cause, message } => {
            assert_eq!(*cause, FailureCause::Panicked);
            assert!(message.contains("injected-fault"), "payload lost: {message}");
        }
        other => panic!("expected a contained panic, got {other:?}"),
    }

    // The failpoint disarmed itself after one firing: the same request
    // now completes, bit-identical to the undisturbed server.
    let resp = server.submit(None, req).unwrap().wait();
    match &resp.body {
        ResponseBody::Score { loglik, .. } => {
            assert_eq!(
                loglik.to_bits(),
                want_bits,
                "a contained panic must not perturb later results"
            );
        }
        other => panic!("server did not recover after a contained panic: {other:?}"),
    }
    let m = server.metrics_summary();
    assert_eq!(m.pool_panics, 1);
    assert_eq!(m.jobs_failed, 1);
    assert_eq!(m.jobs_done, 1);
    assert!(
        server.tenants_line().contains("panicked=1"),
        "tenants line missing the panic counter: {}",
        server.tenants_line()
    );
    server.shutdown(true);
    drop(server);
    assert!(probe.upgrade().is_none(), "pool helpers must survive the panic, then join");
}

/// A failed (erroring) cache insert is a clean per-request `Error`
/// response; the next request re-freezes successfully.
#[test]
fn cache_insert_error_is_a_clean_error_response() {
    let _s = failpoint::scenario();
    failpoint::configure_times("cache::insert", Action::Error("synthetic".into()), 1);

    let mut rng = XorShift::new(304);
    let reference = dna(&mut rng, "chr1", 40);
    let phmm = Phmm::error_correction(&reference, &EcDesignParams::default()).unwrap();
    let read = reads_of(&mut rng, &reference, 1).remove(0);
    let mut server = Server::start(ServerConfig { n_workers: 1, ..Default::default() });
    server.register_profile("chr1", phmm);

    let req = Request::Score { profile: "chr1".into(), read };
    let resp = server.submit(None, req.clone()).unwrap().wait();
    match &resp.body {
        ResponseBody::Error { message } => {
            assert!(message.contains("failpoint cache::insert"), "{message}");
        }
        other => panic!("expected an Error response, got {other:?}"),
    }
    let resp = server.submit(None, req).unwrap().wait();
    assert!(matches!(resp.body, ResponseBody::Score { .. }), "{:?}", resp.body);
    server.shutdown(true);
}

/// Tentpole (load shedding, deterministic backlog): with the one
/// worker pinned inside a Sleep failpoint, the backlog is exactly what
/// was pushed — at the high-water mark, low-priority non-blocking
/// submissions are refused with a typed `Shed` while high-priority
/// ones still admit, and the refusal shows up in the metrics.
#[test]
fn shed_at_high_water_refuses_low_priority_while_high_admits() {
    let _s = failpoint::scenario();
    failpoint::configure("engine::accumulate", Action::Sleep(30));

    let mut rng = XorShift::new(305);
    let reference = dna(&mut rng, "chr1", 40);
    let reads = reads_of(&mut rng, &reference, 2);
    let phmm = Phmm::error_correction(&reference, &EcDesignParams::default()).unwrap();
    // depth 4, shed_fraction 0.5 -> shed at 2 queued items.
    let mut server = Server::start(ServerConfig {
        n_workers: 1,
        queue_depth: 4,
        shed_fraction: 0.5,
        tenant_quota: TenantQuota { max_queued: 8, max_in_flight: 8 },
        ..Default::default()
    });
    server.register_profile("chr1", phmm);
    let read = reads_of(&mut rng, &reference, 1).remove(0);

    // Three slow jobs: at most one is in flight (held by the Sleep),
    // so at least two are queued — at/over the shed limit.
    let blockers: Vec<_> = (0..3)
        .map(|_| {
            server
                .submit(
                    None,
                    Request::Correct { reference: reference.clone(), reads: reads.clone() },
                )
                .unwrap()
        })
        .collect();

    match server.try_submit_for(
        "shedme",
        Priority::Low,
        None,
        Request::Score { profile: "chr1".into(), read: read.clone() },
    ) {
        Err(AdmitError::Shed(_)) => {}
        Ok(_) => panic!("low-priority work must shed at the high-water mark"),
        Err(other) => panic!("expected a Shed refusal, got {other:?}"),
    }
    let vip = server
        .try_submit_for(
            "vip",
            Priority::High,
            None,
            Request::Score { profile: "chr1".into(), read },
        )
        .expect("high-priority work must still admit at the shed mark");

    // Un-pin the worker and drain.
    failpoint::clear("engine::accumulate");
    for b in blockers {
        assert!(matches!(b.wait().body, ResponseBody::Correct { .. }));
    }
    assert!(matches!(vip.wait().body, ResponseBody::Score { .. }));
    let m = server.metrics_summary();
    assert!(m.shed >= 1, "aggregate shed counter must record the refusal");
    assert_eq!(m.jobs_failed, 0, "shed refusals are admission-side, not failed jobs");
    assert!(
        server.stats_line().contains("shed="),
        "stats line must surface the shed counter: {}",
        server.stats_line()
    );
    server.shutdown(true);
}

/// The `deadline` wire command applies a per-request budget to every
/// later submission of the session: with the E-step pinned by a Sleep
/// failpoint, `correct` answers a typed `err deadline_exceeded:` line,
/// and `deadline off` restores normal completion.
#[test]
fn wire_deadline_command_applies_and_clears() {
    let _s = failpoint::scenario();
    failpoint::configure_times("engine::accumulate", Action::Sleep(20), 4);

    let mut rng = XorShift::new(306);
    let reference = dna(&mut rng, "chr1", 40);
    let ascii_ref = reference.to_ascii(aphmm::seq::DNA);
    let reads = reads_of(&mut rng, &reference, 2);
    let ascii_reads: Vec<String> =
        reads.iter().map(|r| r.to_ascii(aphmm::seq::DNA)).collect();
    let joined = ascii_reads.join(",");

    let mut server = Server::start(ServerConfig { n_workers: 1, ..Default::default() });
    let script = format!(
        "deadline 5\ncorrect {ascii_ref} {joined}\ndeadline off\ncorrect {ascii_ref} {joined}\nquit\n"
    );
    let mut out: Vec<u8> = Vec::new();
    let end = aphmm::server::serve_connection(&server, script.as_bytes(), &mut out).unwrap();
    assert_eq!(end, aphmm::server::SessionEnd::Quit);
    server.shutdown(true);

    let text = String::from_utf8(out).unwrap();
    let lines: Vec<&str> = text.lines().collect();
    assert_eq!(lines.len(), 5, "one response per request line:\n{text}");
    assert_eq!(lines[0], "ok deadline 5ms");
    assert!(
        lines[1].starts_with("err deadline_exceeded:"),
        "budgeted correct must fail typed: {}",
        lines[1]
    );
    assert_eq!(lines[2], "ok deadline off");
    assert!(
        lines[3].starts_with("corrected len="),
        "after `deadline off` the request must complete: {}",
        lines[3]
    );
    assert_eq!(lines[4], "ok bye");
}

/// A wire-I/O fault surfaces as a session error (the session dies, the
/// server lives): the `wire::io` failpoint's `Error` action maps to a
/// typed error return from `serve_connection`.
#[test]
fn wire_io_fault_ends_the_session_not_the_server() {
    let _s = failpoint::scenario();

    let mut rng = XorShift::new(307);
    let reference = dna(&mut rng, "chr1", 40);
    let ascii_ref = reference.to_ascii(aphmm::seq::DNA);
    let mut server = Server::start(ServerConfig { n_workers: 1, ..Default::default() });

    failpoint::configure_times("wire::io", Action::Error("socket gremlin".into()), 1);
    let mut out: Vec<u8> = Vec::new();
    let err = aphmm::server::serve_connection(
        &server,
        format!("register chr1 {ascii_ref}\nquit\n").as_bytes(),
        &mut out,
    )
    .expect_err("an armed wire::io failpoint must fail the session");
    assert!(err.to_string().contains("failpoint wire::io"), "{err}");

    // The server survives: a fresh session completes normally.
    let mut out: Vec<u8> = Vec::new();
    let end = aphmm::server::serve_connection(
        &server,
        format!("register chr1 {ascii_ref}\nquit\n").as_bytes(),
        &mut out,
    )
    .unwrap();
    assert_eq!(end, aphmm::server::SessionEnd::Quit);
    assert!(String::from_utf8(out).unwrap().starts_with("ok profile chr1"));
    server.shutdown(true);
}

/// The queue::pop failpoint site is reachable: a Sleep armed there
/// delays (but does not corrupt) dispatch, and the request still
/// completes correctly.
#[test]
fn queue_pop_failpoint_site_is_wired() {
    let _s = failpoint::scenario();
    failpoint::configure_times("queue::pop", Action::Sleep(5), 1);

    let mut rng = XorShift::new(308);
    let reference = dna(&mut rng, "chr1", 40);
    let phmm = Phmm::error_correction(&reference, &EcDesignParams::default()).unwrap();
    let read = reads_of(&mut rng, &reference, 1).remove(0);
    let mut server = Server::start(ServerConfig { n_workers: 1, ..Default::default() });
    server.register_profile("chr1", phmm);
    let resp = server
        .submit(None, Request::Score { profile: "chr1".into(), read })
        .unwrap()
        .wait();
    assert!(matches!(resp.body, ResponseBody::Score { .. }), "{:?}", resp.body);
    server.shutdown(true);
}
