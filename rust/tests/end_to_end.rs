//! Full-pipeline integration tests over the public API: the three
//! applications run end-to-end on simulated workloads, including file
//! I/O round-trips — what a downstream user's first session looks like.

use aphmm::apps::{
    align_all, correct_assembly, msa_identity, CorrectionConfig, FamilyDb, MsaConfig, SearchConfig,
};
use aphmm::io::{read_fasta_str, write_fasta, write_phmm_string, read_phmm_str};
use aphmm::phmm::{Phmm, Profile, TraditionalParams};
use aphmm::seq::{Sequence, DNA, PROTEIN};
use aphmm::sim::{
    generate_families, generate_genome, simulate_reads, ErrorProfile, ProteinSimParams, XorShift,
};

fn edit_distance(a: &[u8], b: &[u8], band: usize) -> usize {
    let (n, m) = (a.len(), b.len());
    if n == 0 {
        return m;
    }
    let inf = usize::MAX / 2;
    let mut prev: Vec<usize> = (0..=m).collect();
    let mut cur = vec![inf; m + 1];
    for i in 1..=n {
        cur.iter_mut().for_each(|x| *x = inf);
        let lo = i.saturating_sub(band).max(1);
        let hi = (i + band).min(m);
        if lo == 1 {
            cur[0] = i;
        }
        for j in lo..=hi {
            let cost = usize::from(a[i - 1] != b[j - 1]);
            cur[j] = (prev[j - 1] + cost).min(prev[j] + 1).min(cur[j - 1] + 1);
        }
        std::mem::swap(&mut prev, &mut cur);
    }
    prev[m]
}

#[test]
fn error_correction_pipeline_with_fasta_roundtrip() {
    let mut rng = XorShift::new(71);
    let truth = generate_genome(&mut rng, 8_000);
    // Corrupt with substitutions only (keeps edit-distance banding cheap).
    let mut noisy = truth.data.clone();
    for b in noisy.iter_mut() {
        if rng.chance(0.04) {
            *b = (*b + 1 + rng.below(3) as u8) % 4;
        }
    }
    let assembly = Sequence::from_symbols("asm", noisy);
    let reads: Vec<Sequence> =
        simulate_reads(&mut rng, &truth, 10.0, 1500, &ErrorProfile::pacbio())
            .into_iter()
            .map(|r| r.seq)
            .collect();

    // Round-trip the inputs through FASTA (as the CLI would).
    let mut buf = Vec::new();
    write_fasta(&mut buf, &reads, DNA).unwrap();
    let reads2 = read_fasta_str(&String::from_utf8(buf).unwrap(), DNA, "mem").unwrap();
    assert_eq!(reads2.len(), reads.len());

    let cfg = CorrectionConfig { chunk_len: 500, ..Default::default() };
    let report = correct_assembly(&assembly, &reads2, &cfg).unwrap();
    let before = edit_distance(&assembly.data, &truth.data, 256);
    let after = edit_distance(&report.corrected.data, &truth.data, 256);
    assert!(
        (after as f64) < before as f64 * 0.6,
        "expected >40% error reduction: before={before} after={after}"
    );
    assert!(report.timings.bw_fraction() > 0.5);
}

#[test]
fn protein_search_pipeline_with_profile_roundtrip() {
    let mut rng = XorShift::new(72);
    let families = generate_families(
        &mut rng,
        &ProteinSimParams { n_families: 20, ..Default::default() },
    );
    let cfg = SearchConfig::default();
    let db = FamilyDb::build(&families, PROTEIN, &cfg).unwrap();

    // Round-trip one profile through the .aphmm format and verify the
    // score is unchanged.
    let entry = &db.entries[0];
    let text = write_phmm_string(&entry.phmm);
    let back = read_phmm_str(&text, "mem").unwrap();
    let query = &families[0].members[0];
    let opts = aphmm::baumwelch::ForwardOptions::default();
    let a = aphmm::baumwelch::score_sparse(&entry.phmm, query, &opts).unwrap();
    let b = aphmm::baumwelch::score_sparse(&back, query, &opts).unwrap();
    assert!((a - b).abs() < 1e-2, "{a} vs {b}");

    // Classification quality across several queries.
    let mut correct = 0;
    for q in 0..10 {
        let fam = &families[q % families.len()];
        let report = db.search(&fam.members[q % fam.members.len()], &cfg).unwrap();
        if report.hits.first().map(|h| h.family.as_str()) == Some(fam.id.as_str()) {
            correct += 1;
        }
        // Posterior stage must have produced Backward time (Fig. 2).
        assert!(report.timings.backward_update_ns > 0);
    }
    assert!(correct >= 8, "top-1 accuracy {correct}/10");
}

#[test]
fn msa_pipeline_quality() {
    let mut rng = XorShift::new(73);
    let fam = generate_families(
        &mut rng,
        &ProteinSimParams { n_families: 1, members_per_family: 16, ..Default::default() },
    )
    .remove(0);
    let profile = Profile::from_members(&fam.members, fam.ancestor.len(), PROTEIN, 0.5);
    let phmm = Phmm::traditional(&profile, &TraditionalParams::default())
        .unwrap()
        .fold_silent(4)
        .unwrap();
    let report = align_all(&phmm, &fam.members, &MsaConfig::default()).unwrap();
    assert_eq!(report.rows.len(), 16);
    assert!(msa_identity(&report) > 0.5);
}
