//! Engine-equivalence matrix and worker-pool determinism.
//!
//! The same workload trained under every in-process
//! `ExpectationEngine` must tell the same statistical story
//! (log-likelihood agreement within numeric-format tolerance), and the
//! shared `WorkerPool` E-step must be bit-identical to single-threaded
//! execution for any worker count and any pool instance — the guarantee
//! the pre-refactor scoped-thread implementation made.

use aphmm::baumwelch::{
    train, train_in, BandedCoeffs, BandedEngine, EngineKind, ExpectationEngine, FilterConfig,
    ForwardOptions, GatherKind, ReadStats, ScratchMode, SimdPolicy, SparseEngine, TrainConfig,
    SIMD_REASSOC_ATOL, SIMD_REASSOC_RTOL,
};
use aphmm::phmm::{EcDesignParams, Phmm};
use aphmm::pool::WorkerPool;
use aphmm::seq::Sequence;
use aphmm::sim::{simulate_read, ErrorProfile, XorShift};
use aphmm::testutil;

fn scenario(seed: u64, ref_len: usize, n_reads: usize) -> (Sequence, Vec<Sequence>) {
    let mut rng = XorShift::new(seed);
    let reference = Sequence::from_symbols("r", testutil::random_seq(&mut rng, ref_len, 4));
    let reads = (0..n_reads)
        .map(|i| {
            simulate_read(&mut rng, &reference, 0, ref_len, &ErrorProfile::pacbio(), i).seq
        })
        .collect();
    (reference, reads)
}

#[test]
fn engine_matrix_loglik_agreement() {
    // Sparse, banded and reference engines train the same workload to
    // mutually consistent log-likelihood trajectories: sparse vs
    // reference within f64 reassociation noise, banded within f32
    // accumulation noise.
    let (reference_seq, reads) = scenario(71, 80, 6);
    let mut histories: Vec<(EngineKind, Vec<f64>)> = Vec::new();
    for engine in [EngineKind::Sparse, EngineKind::Banded, EngineKind::Reference] {
        let mut g = Phmm::error_correction(&reference_seq, &EcDesignParams::default()).unwrap();
        let cfg = TrainConfig { max_iters: 3, tol: 0.0, engine, ..Default::default() };
        let res = train(&mut g, &reads, &cfg).unwrap();
        assert_eq!(res.iters, 3, "engine {engine:?} stopped early");
        g.validate().unwrap();
        histories.push((engine, res.loglik_history));
    }
    let sparse = &histories[0].1;
    let banded = &histories[1].1;
    let reference = &histories[2].1;
    for (i, (&s, &r)) in sparse.iter().zip(reference.iter()).enumerate() {
        testutil::assert_close(s, r, 1e-3, 1e-6);
        let b = banded[i];
        testutil::assert_close(s, b, 1e-2, 1e-4);
    }
}

#[test]
fn engine_matrix_trained_parameters_track_each_other() {
    // After one EM iteration from identical starting parameters, the
    // re-estimated emission rows of the three engines agree closely
    // (f32 banded accumulation is the loosest link).
    let (reference_seq, reads) = scenario(73, 50, 5);
    let mut trained: Vec<Vec<f32>> = Vec::new();
    for engine in [EngineKind::Sparse, EngineKind::Banded, EngineKind::Reference] {
        let mut g = Phmm::error_correction(&reference_seq, &EcDesignParams::default()).unwrap();
        let cfg = TrainConfig { max_iters: 1, tol: 0.0, engine, ..Default::default() };
        train(&mut g, &reads, &cfg).unwrap();
        trained.push(g.emissions.clone());
    }
    let to64 = |v: &Vec<f32>| -> Vec<f64> { v.iter().map(|&x| x as f64).collect() };
    testutil::assert_all_close(&to64(&trained[0]), &to64(&trained[2]), 1e-4, 1e-6);
    testutil::assert_all_close(&to64(&trained[0]), &to64(&trained[1]), 2e-2, 2e-3);
}

#[test]
fn banded_fused_coefficients_match_prerefactor_scan() {
    // The acceptance parity check: the banded engine's new fused
    // coefficient tables reproduce the pre-refactor banded scan — the
    // backward bit-for-bit (same association), the forward within one
    // f32 reassociation per band entry.
    let (reference_seq, reads) = scenario(79, 60, 3);
    let g = Phmm::error_correction(&reference_seq, &EcDesignParams::default()).unwrap();
    let banded = g.to_banded().unwrap();
    let coeffs = BandedCoeffs::new(&banded);
    for read in &reads {
        let (f_rows, scales, loglik) = BandedEngine::forward(&banded, read).unwrap();
        let old = BandedEngine::bw_sums(&banded, read).unwrap();
        // Same forward rows -> bit-identical backward/update sums.
        let new_bwd =
            BandedEngine::backward_sums_with(&banded, &coeffs, read, &f_rows, &scales, loglik)
                .unwrap();
        for (a, b) in old.xi_band.iter().zip(&new_bwd.xi_band) {
            assert_eq!(a.to_bits(), b.to_bits(), "xi diverged");
        }
        for (a, b) in old.gamma_den.iter().zip(&new_bwd.gamma_den) {
            assert_eq!(a.to_bits(), b.to_bits(), "gamma diverged");
        }
        // End-to-end fused pass: tolerance parity.
        let new_full = BandedEngine::bw_sums_with(&banded, &coeffs, read).unwrap();
        testutil::assert_close(new_full.loglik as f64, old.loglik as f64, 1e-4, 1e-6);
        let o: Vec<f64> = old.gamma_den.iter().map(|&x| x as f64).collect();
        let n: Vec<f64> = new_full.gamma_den.iter().map(|&x| x as f64).collect();
        testutil::assert_all_close(&n, &o, 5e-3, 1e-5);
    }
}

#[test]
fn gather_matrix_tile_vs_csr_bit_identical_merged_sums() {
    // The lowering-layer acceptance check: forced-dense, forced-sparse
    // and adaptive gather dispatch must produce identical
    // log-likelihoods and bit-identical merged expectation sums on the
    // EC workload — the tile kernel preserves the CSR gather's block
    // summation order exactly.
    let (reference_seq, reads) = scenario(97, 100, 9);
    let g = Phmm::error_correction(&reference_seq, &EcDesignParams::default()).unwrap();
    let engine = SparseEngine;
    let prep = engine.prepare(&g).unwrap();
    for filter in [FilterConfig::None, FilterConfig::histogram_default()] {
        let mut baseline: Option<(f64, Vec<u64>, Vec<u64>)> = None;
        for gather in [GatherKind::Csr, GatherKind::DenseTile, GatherKind::Adaptive] {
            // Scalar lanes: cross-gather bit-identity is a scalar-sum
            // guarantee; wider lane widths reassociate tile rows and
            // are covered by `lane_width_parity_matrix_for_training`.
            let opts =
                ForwardOptions { filter, gather, simd: SimdPolicy::Scalar, ..Default::default() };
            let mut scratch = engine.make_scratch(&g);
            let mut acc = engine.make_acc(&g);
            let mut stats = ReadStats::default();
            for read in &reads {
                let s = engine
                    .accumulate_read(&g, &prep, read, &opts, &mut scratch, &mut acc)
                    .unwrap();
                stats.merge(&s);
            }
            // The dispatch choice is instrumented per row.
            let rows = stats.timesteps - reads.len() as u64; // t=0 rows are not gathers
            assert_eq!(stats.filter_stats.rows_csr + stats.filter_stats.rows_dense_tile, rows);
            match gather {
                GatherKind::Csr => assert_eq!(stats.filter_stats.rows_dense_tile, 0),
                GatherKind::DenseTile => assert_eq!(stats.filter_stats.rows_csr, 0),
                // The default EC band is occupancy-gated (≈ 0.25 <
                // TILE_MIN_OCCUPANCY), so Adaptive must stay on CSR
                // here; the tile-firing side of the policy is pinned by
                // `sparse::tests::adaptive_dispatch_tiles_near_dense_bands`.
                GatherKind::Adaptive => assert_eq!(stats.filter_stats.rows_dense_tile, 0),
            }
            let (loglik, n) = engine.observations(&acc);
            assert_eq!(n, reads.len() as u64);
            let xi_bits: Vec<u64> = acc.xi.iter().map(|v| v.to_bits()).collect();
            let mut sum_bits: Vec<u64> = acc.gamma_den.iter().map(|v| v.to_bits()).collect();
            sum_bits.extend(acc.trans_den.iter().map(|v| v.to_bits()));
            sum_bits.extend(acc.e_num.iter().map(|v| v.to_bits()));
            match &baseline {
                None => baseline = Some((loglik, xi_bits, sum_bits)),
                Some((ll, xi, sums)) => {
                    assert_eq!(loglik.to_bits(), ll.to_bits(), "{gather:?}/{filter:?}");
                    assert_eq!(&xi_bits, xi, "xi diverged under {gather:?}/{filter:?}");
                    assert_eq!(&sum_bits, sums, "sums diverged under {gather:?}/{filter:?}");
                }
            }
        }
    }
}

#[test]
fn gather_matrix_training_is_bit_identical_end_to_end() {
    // Same property through the full parallel training loop: histories
    // and trained parameters must not depend on the gather kernel, for
    // any worker count.
    let (reference_seq, reads) = scenario(101, 80, 17);
    let mut baseline: Option<(Vec<f64>, Vec<f32>, Vec<f32>)> = None;
    for gather in [GatherKind::Csr, GatherKind::DenseTile, GatherKind::Adaptive] {
        for n_workers in [1usize, 4] {
            let cfg = TrainConfig {
                max_iters: 3,
                tol: 0.0,
                gather,
                n_workers,
                simd: SimdPolicy::Scalar,
                ..Default::default()
            };
            let mut g =
                Phmm::error_correction(&reference_seq, &EcDesignParams::default()).unwrap();
            let res = train(&mut g, &reads, &cfg).unwrap();
            match &baseline {
                None => baseline = Some((res.loglik_history, g.out_prob, g.emissions)),
                Some((hist, out_prob, emissions)) => {
                    assert_eq!(&res.loglik_history, hist, "{gather:?} x{n_workers}");
                    assert_eq!(&g.out_prob, out_prob, "{gather:?} x{n_workers}");
                    assert_eq!(&g.emissions, emissions, "{gather:?} x{n_workers}");
                }
            }
        }
    }
}

#[test]
fn lane_width_parity_matrix_for_training() {
    // The explicit-SIMD reproducibility contract through the full
    // training loop.  CSR-gather rows are summed scalar under EVERY
    // lane policy, so CSR training is bit-identical across
    // Scalar/F32x4/F32x8.  With the dense-tile kernel forced, wider
    // lanes reassociate the tile dot products: each lane width is
    // deterministic in itself (worker count never matters, bitwise),
    // and its drift against the scalar ascending-order sum stays
    // inside the SIMD_REASSOC tolerance tier — the one place in the
    // engine where reassociation is unavoidable.
    let (reference_seq, reads) = scenario(109, 80, 9);
    for gather in [GatherKind::Csr, GatherKind::DenseTile] {
        let mut scalar_anchor: Option<Vec<f64>> = None;
        for simd in [SimdPolicy::Scalar, SimdPolicy::F32x4, SimdPolicy::F32x8] {
            let mut per_width: Option<(Vec<f64>, Vec<f32>)> = None;
            for n_workers in [1usize, 4] {
                let cfg = TrainConfig {
                    max_iters: 3,
                    tol: 0.0,
                    gather,
                    simd,
                    n_workers,
                    ..Default::default()
                };
                let mut g = Phmm::error_correction(&reference_seq, &EcDesignParams::default())
                    .unwrap();
                let res = train(&mut g, &reads, &cfg).unwrap();
                match &per_width {
                    None => {
                        per_width = Some((res.loglik_history.clone(), g.emissions.clone()))
                    }
                    Some((hist, emissions)) => {
                        assert_eq!(
                            &res.loglik_history, hist,
                            "{gather:?}/{simd:?} not deterministic at {n_workers} workers"
                        );
                        assert_eq!(&g.emissions, emissions, "{gather:?}/{simd:?} x{n_workers}");
                    }
                }
                match (&scalar_anchor, gather) {
                    (None, _) => scalar_anchor = Some(res.loglik_history.clone()),
                    (Some(anchor), GatherKind::Csr) => assert_eq!(
                        &res.loglik_history, anchor,
                        "CSR gather must be lane-width independent ({simd:?})"
                    ),
                    (Some(anchor), _) => {
                        for (&got, &want) in res.loglik_history.iter().zip(anchor.iter()) {
                            testutil::assert_close(
                                got,
                                want,
                                SIMD_REASSOC_RTOL,
                                SIMD_REASSOC_ATOL,
                            );
                        }
                    }
                }
            }
        }
    }
}

#[test]
fn striped_batch_scoring_matches_one_at_a_time() {
    // The striped multi-read kernel contract at the engine boundary:
    // K-read `score_batch` is per-read bit-identical to scoring each
    // read alone, for every gather kind and lane width.
    let (reference_seq, reads) = scenario(113, 70, 10);
    let g = Phmm::error_correction(&reference_seq, &EcDesignParams::default()).unwrap();
    let engine = SparseEngine;
    let prep = engine.prepare(&g).unwrap();
    let refs: Vec<&Sequence> = reads.iter().collect();
    for gather in [GatherKind::Csr, GatherKind::DenseTile, GatherKind::Adaptive] {
        for simd in [SimdPolicy::Scalar, SimdPolicy::F32x4, SimdPolicy::F32x8] {
            let opts =
                ForwardOptions { filter: FilterConfig::None, gather, simd, ..Default::default() };
            let mut batch_scratch = engine.make_scratch(&g);
            let batch = engine.score_batch(&g, &prep, &refs, &opts, &mut batch_scratch);
            assert_eq!(batch.len(), reads.len());
            let mut solo_scratch = engine.make_scratch(&g);
            for (read, got) in reads.iter().zip(&batch) {
                let want = engine.score(&g, &prep, read, &opts, &mut solo_scratch).unwrap();
                let got = got.as_ref().unwrap();
                assert_eq!(
                    want.loglik.to_bits(),
                    got.loglik.to_bits(),
                    "striped scoring diverged from solo under {gather:?}/{simd:?}"
                );
                assert_eq!(got.states_processed, want.states_processed);
                assert_eq!(got.edges_processed, want.edges_processed);
            }
        }
    }
}

#[test]
fn shared_pool_is_bit_identical_to_private_pools_for_any_worker_count() {
    // The pool-determinism guarantee: one shared pool reused across
    // training sessions, a fresh pool per session, and the process
    // global pool all produce byte-identical histories and parameters
    // for every worker count, filters on and off.
    let (reference_seq, reads) = scenario(83, 100, 21); // 3 blocks of 8
    let shared = WorkerPool::new(3);
    for filter in [FilterConfig::None, FilterConfig::histogram_default()] {
        let mut baseline: Option<(Vec<f64>, Vec<f32>, Vec<f32>)> = None;
        for n_workers in [1usize, 2, 4, 5] {
            let cfg = TrainConfig { max_iters: 3, tol: 0.0, filter, n_workers, ..Default::default() };

            let mut g_shared =
                Phmm::error_correction(&reference_seq, &EcDesignParams::default()).unwrap();
            let res_shared = train_in(&mut g_shared, &reads, &cfg, &shared).unwrap();

            let fresh = WorkerPool::new(2);
            let mut g_fresh = Phmm::error_correction(&reference_seq, &EcDesignParams::default())
                .unwrap();
            let res_fresh = train_in(&mut g_fresh, &reads, &cfg, &fresh).unwrap();

            let mut g_global =
                Phmm::error_correction(&reference_seq, &EcDesignParams::default()).unwrap();
            let res_global = train(&mut g_global, &reads, &cfg).unwrap();

            assert_eq!(res_shared.loglik_history, res_fresh.loglik_history);
            assert_eq!(res_shared.loglik_history, res_global.loglik_history);
            assert_eq!(g_shared.out_prob, g_fresh.out_prob);
            assert_eq!(g_shared.out_prob, g_global.out_prob);
            assert_eq!(g_shared.emissions, g_fresh.emissions);
            assert_eq!(g_shared.emissions, g_global.emissions);

            match &baseline {
                None => {
                    baseline = Some((
                        res_shared.loglik_history.clone(),
                        g_shared.out_prob.clone(),
                        g_shared.emissions.clone(),
                    ))
                }
                Some((hist, out_prob, emissions)) => {
                    assert_eq!(
                        &res_shared.loglik_history, hist,
                        "worker count {n_workers} changed the history (filter {filter:?})"
                    );
                    assert_eq!(&g_shared.out_prob, out_prob, "filter {filter:?}");
                    assert_eq!(&g_shared.emissions, emissions, "filter {filter:?}");
                }
            }
        }
    }
}

#[test]
fn checkpointed_scratch_matrix_is_bit_identical_to_full() {
    // The checkpointed-mode acceptance matrix: the √T-checkpoint
    // forward + segment-recompute backward replays the exact kernel
    // sequence from exactly-stored post-filter rows, so histories and
    // trained parameters are bit-identical to the full matrix — for
    // both in-process engines, both gather dispatches, scalar and wide
    // lanes, and any worker count — while the peak forward scratch
    // drops below the full-matrix high-water mark.
    let (reference_seq, reads) = scenario(127, 80, 6);
    for engine in [EngineKind::Sparse, EngineKind::Banded] {
        for gather in [GatherKind::Csr, GatherKind::Adaptive] {
            for simd in [SimdPolicy::Scalar, SimdPolicy::F32x8] {
                for n_workers in [1usize, 4] {
                    let cfg = TrainConfig {
                        max_iters: 2,
                        tol: 0.0,
                        engine,
                        gather,
                        simd,
                        n_workers,
                        ..Default::default()
                    };
                    let mut g_full =
                        Phmm::error_correction(&reference_seq, &EcDesignParams::default())
                            .unwrap();
                    let res_full = train(
                        &mut g_full,
                        &reads,
                        &TrainConfig { scratch_mode: ScratchMode::Full, ..cfg },
                    )
                    .unwrap();
                    let mut g_ckpt =
                        Phmm::error_correction(&reference_seq, &EcDesignParams::default())
                            .unwrap();
                    let res_ckpt = train(
                        &mut g_ckpt,
                        &reads,
                        &TrainConfig { scratch_mode: ScratchMode::Checkpointed, ..cfg },
                    )
                    .unwrap();
                    let tag = format!("{engine:?}/{gather:?}/{simd:?} x{n_workers}");
                    assert_eq!(res_full.loglik_history, res_ckpt.loglik_history, "{tag}");
                    assert_eq!(g_full.out_prob, g_ckpt.out_prob, "{tag}");
                    assert_eq!(g_full.emissions, g_ckpt.emissions, "{tag}");
                    assert!(res_full.peak_scratch_bytes > 0, "{tag}: full peak unaccounted");
                    assert!(res_ckpt.peak_scratch_bytes > 0, "{tag}: ckpt peak unaccounted");
                    assert!(
                        res_ckpt.peak_scratch_bytes < res_full.peak_scratch_bytes,
                        "{tag}: checkpointing did not shrink peak scratch \
                         ({} >= {})",
                        res_ckpt.peak_scratch_bytes,
                        res_full.peak_scratch_bytes
                    );
                }
            }
        }
    }
}

#[test]
fn checkpointed_peak_scratch_under_quarter_of_full_at_10k_timesteps() {
    // The tentpole's memory acceptance bound: at T ≥ 10⁴ the
    // checkpointed high-water mark (⌈√T⌉ checkpoint rows + all scales
    // + one live segment buffer) is under 25% of the full-matrix peak
    // (all T rows + scales) — and the result is still bit-identical.
    let mut rng = XorShift::new(131);
    let genome = aphmm::sim::generate_genome(&mut rng, 10_000);
    let read = aphmm::sim::simulate_ultralong_read(&mut rng, &genome, 0, 10_000, 0).seq;
    assert!(read.len() >= 8_000, "ultralong read came out short: {}", read.len());
    let cfg = TrainConfig {
        max_iters: 1,
        tol: 0.0,
        filter: FilterConfig::histogram_default(),
        ..Default::default()
    };
    let mut g_full = Phmm::error_correction(&genome, &EcDesignParams::default()).unwrap();
    let full = train(
        &mut g_full,
        std::slice::from_ref(&read),
        &TrainConfig { scratch_mode: ScratchMode::Full, ..cfg },
    )
    .unwrap();
    let mut g_ckpt = Phmm::error_correction(&genome, &EcDesignParams::default()).unwrap();
    let ckpt = train(
        &mut g_ckpt,
        std::slice::from_ref(&read),
        &TrainConfig { scratch_mode: ScratchMode::Checkpointed, ..cfg },
    )
    .unwrap();
    assert_eq!(full.loglik_history, ckpt.loglik_history, "long-read bit-identity broke");
    assert_eq!(g_full.emissions, g_ckpt.emissions);
    assert!(
        ckpt.peak_scratch_bytes * 4 < full.peak_scratch_bytes,
        "checkpointed peak {} B is not under 25% of full peak {} B at T={}",
        ckpt.peak_scratch_bytes,
        full.peak_scratch_bytes,
        read.len()
    );
}

#[test]
fn accumulate_batch_mixed_modes_bit_identical_to_solo_and_full() {
    // ROADMAP item 3 (the accumulate_batch asymmetry): only the
    // forward is striped; the backward always consumes one read's own
    // rows.  A checkpointed read cannot ride a stripe (the striped
    // forward materializes every row), so the batch path flushes the
    // stripe and runs it solo — and the accumulated sums must stay
    // bit-identical to per-read accumulation in the same order, and to
    // the all-Full answer, even when `Auto` splits one batch between
    // full-matrix and checkpointed reads.
    let mut rng = XorShift::new(137);
    let reference_seq =
        Sequence::from_symbols("r", testutil::random_seq(&mut rng, 120, 4));
    let mut reads: Vec<Sequence> = Vec::new();
    for i in 0..8 {
        let full = simulate_read(&mut rng, &reference_seq, 0, 120, &ErrorProfile::pacbio(), i).seq;
        // Alternate long and short reads so a budget can split them.
        reads.push(if i % 2 == 0 { full } else { full.slice(0, full.len().min(30)) });
    }
    let g = Phmm::error_correction(&reference_seq, &EcDesignParams::default()).unwrap();
    let engine = SparseEngine;
    let prep = engine.prepare(&g).unwrap();
    let refs: Vec<&Sequence> = reads.iter().collect();
    let sums_of = |opts: &ForwardOptions, batch: bool| -> Vec<u64> {
        let mut scratch = engine.make_scratch(&g);
        let mut acc = engine.make_acc(&g);
        if batch {
            for r in engine.accumulate_batch(&g, &prep, &refs, opts, &mut scratch, &mut acc) {
                r.unwrap();
            }
        } else {
            for read in &reads {
                engine.accumulate_read(&g, &prep, read, opts, &mut scratch, &mut acc).unwrap();
            }
        }
        let mut bits: Vec<u64> = acc.xi.iter().map(|v| v.to_bits()).collect();
        bits.extend(acc.gamma_den.iter().map(|v| v.to_bits()));
        bits.extend(acc.trans_den.iter().map(|v| v.to_bits()));
        bits.extend(acc.e_num.iter().map(|v| v.to_bits()));
        bits
    };
    let full_opts = ForwardOptions {
        filter: FilterConfig::histogram_default(),
        scratch: ScratchMode::Full,
        ..Default::default()
    };
    let ckpt_opts = ForwardOptions { scratch: ScratchMode::Checkpointed, ..full_opts };
    // A budget between the short reads' (~30-step) and the long reads'
    // (~120-step) full-matrix estimates, so Auto genuinely mixes modes
    // within one batch.
    let auto_opts = ForwardOptions {
        scratch: ScratchMode::Auto,
        max_scratch_bytes: 150_000,
        ..full_opts
    };
    assert_eq!(
        ScratchMode::Auto.resolve(reads[0].len(), g.n_states(), auto_opts.max_scratch_bytes),
        ScratchMode::Checkpointed,
        "long reads must checkpoint under the test budget"
    );
    assert_eq!(
        ScratchMode::Auto.resolve(reads[1].len(), g.n_states(), auto_opts.max_scratch_bytes),
        ScratchMode::Full,
        "short reads must stay full-matrix under the test budget"
    );
    let baseline = sums_of(&full_opts, false);
    assert_eq!(sums_of(&full_opts, true), baseline, "full batch vs solo");
    assert_eq!(sums_of(&ckpt_opts, false), baseline, "checkpointed solo vs full");
    assert_eq!(sums_of(&ckpt_opts, true), baseline, "checkpointed batch vs full");
    assert_eq!(sums_of(&auto_opts, true), baseline, "mixed-mode batch vs full");
}

#[test]
fn pool_determinism_holds_for_banded_engine_too() {
    // The deterministic block reduction is engine-agnostic: the banded
    // engine's f32 sums are merged in block order as well.
    let (reference_seq, reads) = scenario(89, 60, 17);
    let shared = WorkerPool::new(3);
    let mut baseline: Option<Vec<f64>> = None;
    for n_workers in [1usize, 3, 5] {
        let cfg = TrainConfig {
            max_iters: 2,
            tol: 0.0,
            engine: EngineKind::Banded,
            n_workers,
            ..Default::default()
        };
        let mut g = Phmm::error_correction(&reference_seq, &EcDesignParams::default()).unwrap();
        let res = train_in(&mut g, &reads, &cfg, &shared).unwrap();
        match &baseline {
            None => baseline = Some(res.loglik_history.clone()),
            Some(hist) => assert_eq!(
                &res.loglik_history, hist,
                "banded E-step not deterministic at {n_workers} workers"
            ),
        }
    }
}
