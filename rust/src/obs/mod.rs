//! Observability: per-request trace timelines, stage-level accounting,
//! and Prometheus text exposition.
//!
//! ApHMM's design was driven by a stage-level profile (paper §3:
//! forward / backward / parameter-update breakdown); this module makes
//! that breakdown observable in the running system instead of an
//! offline analysis.  Three always-compiled pieces:
//!
//! - [`hist`] — the fixed-bucket power-of-two histogram every latency
//!   and stage-time series records into ([`PowHist`]).
//! - [`trace`] — per-request span [`Timeline`]s captured at stage
//!   boundaries, retained in a bounded [`TraceRing`] and emitted as
//!   JSON lines by the `trace-dump` wire command, the serve shutdown
//!   hook, and the slow-request log.
//! - [`prom`] — [`PromWriter`], the Prometheus text renderer behind
//!   the `metrics` wire command.
//!
//! The contract (mirroring the PR-6/7 serving discipline): span and
//! metric capture sits at stage boundaries, never inside kernels or
//! reductions, so results are bit-identical with tracing on or off;
//! the untraced default path costs at most one relaxed atomic per
//! stage, and never touches the trace ring.

pub mod hist;
pub mod prom;
pub mod trace;

pub use hist::{bucket_bound_ns, bucket_of, HistSnapshot, PowHist, HIST_BUCKETS};
pub use prom::PromWriter;
pub use trace::{Stage, Timeline, TraceRing, TRACE_RING_CAPACITY};
