//! Fixed-bucket power-of-two histogram — the one histogram shape used
//! everywhere in the observability layer.
//!
//! Bucket `i` holds values in `[2^(i-1), 2^i)` (bucket 0 holds the
//! value 0; the last bucket holds everything ≥ `2^(HIST_BUCKETS-2)`,
//! ≈ 4.6 min when values are nanoseconds).  Power-of-two bounds keep
//! recording to a `leading_zeros` plus two relaxed atomic increments —
//! cheap enough that the per-stage histogram family in
//! [`crate::coordinator::Metrics`] stays always-on.
//!
//! [`PowHist`] is the shared (lock-free) recorder; [`HistSnapshot`] is
//! a point-in-time copy used for quantiles and Prometheus rendering
//! (cumulative `le` buckets are derived at render time, so the hot
//! path never maintains cumulative counts).

use std::sync::atomic::{AtomicU64, Ordering};

/// Number of buckets in every [`PowHist`].
pub const HIST_BUCKETS: usize = 39;

/// Bucket index for a value (power-of-two buckets; see module docs).
#[inline]
pub fn bucket_of(v: u64) -> usize {
    ((64 - v.leading_zeros()) as usize).min(HIST_BUCKETS - 1)
}

/// Inclusive upper bound of bucket `i` (the largest value that maps to
/// a bucket ≤ `i`; bucket 0 covers only the value 0).
pub fn bucket_bound_ns(i: usize) -> u64 {
    if i == 0 {
        0
    } else {
        1u64 << i
    }
}

/// A lock-free fixed-bucket histogram: per-bucket counts plus a running
/// sum, all relaxed atomics.
#[derive(Debug)]
pub struct PowHist {
    buckets: [AtomicU64; HIST_BUCKETS],
    sum: AtomicU64,
}

impl Default for PowHist {
    fn default() -> Self {
        PowHist {
            buckets: std::array::from_fn(|_| AtomicU64::new(0)),
            sum: AtomicU64::new(0),
        }
    }
}

impl PowHist {
    /// Record one value: one bucket increment plus one sum add.
    #[inline]
    pub fn record(&self, v: u64) {
        self.buckets[bucket_of(v)].fetch_add(1, Ordering::Relaxed);
        self.sum.fetch_add(v, Ordering::Relaxed);
    }

    /// Point-in-time copy for rendering and quantiles.
    pub fn snapshot(&self) -> HistSnapshot {
        HistSnapshot {
            counts: self
                .buckets
                .iter()
                .map(|b| b.load(Ordering::Relaxed))
                .collect(),
            sum: self.sum.load(Ordering::Relaxed),
        }
    }
}

/// A point-in-time copy of a [`PowHist`].
#[derive(Clone, Debug, Default)]
pub struct HistSnapshot {
    /// Per-bucket (non-cumulative) counts, `HIST_BUCKETS` long.
    pub counts: Vec<u64>,
    /// Sum of all recorded values.
    pub sum: u64,
}

impl HistSnapshot {
    /// Total number of recorded values.
    pub fn count(&self) -> u64 {
        self.counts.iter().sum()
    }

    /// Upper bound (in the recorded unit) of the bucket holding the
    /// `q`-quantile, or 0 when nothing was recorded.  Quantiles from
    /// power-of-two buckets are bucket-resolution: the true value lies
    /// within a factor of two below the returned bound.
    pub fn quantile(&self, q: f64) -> u64 {
        let total: u64 = self.count();
        if total == 0 {
            return 0;
        }
        let rank = ((q * total as f64).ceil() as u64).clamp(1, total);
        let mut seen = 0u64;
        for (i, &c) in self.counts.iter().enumerate() {
            seen += c;
            if seen >= rank {
                return bucket_bound_ns(i);
            }
        }
        bucket_bound_ns(HIST_BUCKETS - 1)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bucket_mapping_is_monotone_and_bounded() {
        let mut prev = 0;
        for shift in 0..64 {
            let b = bucket_of(1u64 << shift);
            assert!(b >= prev);
            assert!(b < HIST_BUCKETS);
            prev = b;
        }
        assert_eq!(bucket_of(0), 0);
        assert_eq!(bucket_of(1), 1);
        assert_eq!(bucket_of(2), 2);
        assert_eq!(bucket_of(3), 2);
        assert_eq!(bucket_of(u64::MAX), HIST_BUCKETS - 1);
    }

    #[test]
    fn known_distribution_p50_p99_land_in_expected_buckets() {
        // 98 fast requests at ~1 µs, 2 slow at ~1 ms: p50 must sit in
        // the microsecond bucket, p99 in the millisecond bucket.
        let h = PowHist::default();
        for _ in 0..98 {
            h.record(1_000); // ~2^10
        }
        for _ in 0..2 {
            h.record(1_000_000); // ~2^20
        }
        let s = h.snapshot();
        assert_eq!(s.count(), 100);
        assert_eq!(s.sum, 98 * 1_000 + 2 * 1_000_000);

        let p50 = s.quantile(0.50);
        assert_eq!(p50, bucket_bound_ns(bucket_of(1_000)));
        assert!((512..=2048).contains(&p50), "p50 bound {p50}");

        let p99 = s.quantile(0.99);
        assert_eq!(p99, bucket_bound_ns(bucket_of(1_000_000)));
        assert!((524_288..=2_097_152).contains(&p99), "p99 bound {p99}");
    }

    #[test]
    fn uniform_distribution_quantiles_bracket() {
        // Values 1..=1024: p50 within a factor of two of 512, p99 of
        // 1024 (bucket resolution).
        let h = PowHist::default();
        for v in 1..=1024u64 {
            h.record(v);
        }
        let s = h.snapshot();
        let p50 = s.quantile(0.50);
        assert!((512..=1024).contains(&p50), "p50 bound {p50}");
        let p99 = s.quantile(0.99);
        assert!((1024..=2048).contains(&p99), "p99 bound {p99}");
        assert!(p50 <= p99);
    }

    #[test]
    fn empty_histogram_is_all_zero() {
        let s = PowHist::default().snapshot();
        assert_eq!(s.count(), 0);
        assert_eq!(s.sum, 0);
        assert_eq!(s.quantile(0.5), 0);
        assert_eq!(s.quantile(0.99), 0);
    }
}
