//! Prometheus text-format rendering for the `metrics` wire command.
//!
//! [`PromWriter`] emits the classic text exposition: `# HELP` / `#
//! TYPE` comment pairs followed by `name{labels} value` sample lines,
//! terminated by a `# EOF` line (the OpenMetrics terminator, which the
//! line-oriented wire protocol also uses as the end-of-block
//! delimiter).  Histograms follow the Prometheus convention:
//! cumulative `_bucket{le="..."}` lines, a `+Inf` bucket, `_sum`, and
//! `_count`.  Cumulative counts are derived here at render time from
//! the non-cumulative [`HistSnapshot`] buckets, so the recording hot
//! path stays two atomics.
//!
//! Metric and label names follow the scheme documented in
//! `server/README.md` (`aphmm_` prefix, snake_case, base units of
//! seconds).

use super::hist::{bucket_bound_ns, HistSnapshot};

/// Incremental Prometheus text builder.
#[derive(Debug, Default)]
pub struct PromWriter {
    out: String,
}

fn escape_label(v: &str, out: &mut String) {
    for c in v.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            c => out.push(c),
        }
    }
}

fn push_labels(out: &mut String, labels: &[(&str, &str)]) {
    if labels.is_empty() {
        return;
    }
    out.push('{');
    for (i, (k, v)) in labels.iter().enumerate() {
        if i > 0 {
            out.push(',');
        }
        out.push_str(k);
        out.push_str("=\"");
        escape_label(v, out);
        out.push('"');
    }
    out.push('}');
}

/// Render a float the way Prometheus clients expect: plain decimal,
/// no exponent for the magnitudes we emit, integers without a dot.
fn fmt_value(v: f64) -> String {
    if v == v.trunc() && v.abs() < 1e15 {
        format!("{}", v as i64)
    } else {
        format!("{v}")
    }
}

impl PromWriter {
    /// Emit the `# HELP` / `# TYPE` pair for a metric family.
    /// `kind` is `counter`, `gauge`, or `histogram`.
    pub fn help_type(&mut self, name: &str, help: &str, kind: &str) {
        self.out
            .push_str(&format!("# HELP {name} {help}\n# TYPE {name} {kind}\n"));
    }

    /// Emit one sample line.
    pub fn value(&mut self, name: &str, labels: &[(&str, &str)], v: f64) {
        self.out.push_str(name);
        push_labels(&mut self.out, labels);
        self.out.push(' ');
        self.out.push_str(&fmt_value(v));
        self.out.push('\n');
    }

    /// Emit a full histogram family (`_bucket` cumulative lines,
    /// `+Inf`, `_sum`, `_count`) from a nanosecond-unit snapshot,
    /// converting bounds and sum to seconds.
    pub fn histogram(&mut self, name: &str, labels: &[(&str, &str)], snap: &HistSnapshot) {
        let mut cum = 0u64;
        for (i, &c) in snap.counts.iter().enumerate() {
            cum += c;
            // Skip interior empty buckets to keep the exposition
            // readable, but always emit the first and the running edge
            // so `le` stays monotone where it matters; simplest
            // correct form: emit every bucket whose cumulative count
            // changed, plus the final bucket.
            if c == 0 && i != snap.counts.len() - 1 {
                continue;
            }
            let le = bucket_bound_ns(i) as f64 / 1e9;
            let mut lbls: Vec<(&str, &str)> = labels.to_vec();
            let le_s = fmt_value(le);
            lbls.push(("le", &le_s));
            self.out.push_str(name);
            self.out.push_str("_bucket");
            push_labels(&mut self.out, &lbls);
            self.out.push(' ');
            self.out.push_str(&fmt_value(cum as f64));
            self.out.push('\n');
        }
        let mut lbls: Vec<(&str, &str)> = labels.to_vec();
        lbls.push(("le", "+Inf"));
        self.out.push_str(name);
        self.out.push_str("_bucket");
        push_labels(&mut self.out, &lbls);
        self.out.push(' ');
        self.out.push_str(&fmt_value(cum as f64));
        self.out.push('\n');

        self.out.push_str(name);
        self.out.push_str("_sum");
        push_labels(&mut self.out, labels);
        self.out
            .push_str(&format!(" {}\n", fmt_value(snap.sum as f64 / 1e9)));
        self.out.push_str(name);
        self.out.push_str("_count");
        push_labels(&mut self.out, labels);
        self.out.push_str(&format!(" {}\n", fmt_value(cum as f64)));
    }

    /// Finish the exposition: append the `# EOF` terminator and return
    /// the text.
    pub fn finish(mut self) -> String {
        self.out.push_str("# EOF\n");
        self.out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::obs::hist::PowHist;

    /// A line is valid if it is a `# HELP`/`# TYPE`/`# EOF` comment or
    /// matches `name{labels} value`.
    fn line_is_valid(line: &str) -> bool {
        if line.starts_with("# HELP ") || line.starts_with("# TYPE ") || line == "# EOF" {
            return true;
        }
        let Some((series, value)) = line.rsplit_once(' ') else {
            return false;
        };
        let name_ok = |n: &str| {
            !n.is_empty()
                && n.chars()
                    .all(|c| c.is_ascii_lowercase() || c.is_ascii_digit() || c == '_')
        };
        let series_ok = match series.split_once('{') {
            None => name_ok(series),
            Some((name, rest)) => name_ok(name) && rest.ends_with('}'),
        };
        series_ok && (value.parse::<f64>().is_ok() || value == "+Inf")
    }

    #[test]
    fn every_emitted_line_parses() {
        let mut w = PromWriter::default();
        w.help_type("aphmm_requests_total", "Requests by result.", "counter");
        w.value("aphmm_requests_total", &[("result", "ok")], 12.0);
        w.value("aphmm_uptime_seconds", &[], 1.5);
        let h = PowHist::default();
        h.record(1_000);
        h.record(1_000_000);
        w.help_type("aphmm_stage_seconds", "Stage time.", "histogram");
        w.histogram("aphmm_stage_seconds", &[("stage", "forward")], &h.snapshot());
        let text = w.finish();
        assert!(text.ends_with("# EOF\n"));
        for line in text.lines() {
            assert!(line_is_valid(line), "bad line: {line:?}");
        }
    }

    #[test]
    fn histogram_buckets_are_cumulative_and_count_matches() {
        let h = PowHist::default();
        for v in [1u64, 1, 2, 1_000, 1_000_000] {
            h.record(v);
        }
        let mut w = PromWriter::default();
        w.histogram("x", &[], &h.snapshot());
        let text = w.finish();
        let mut prev = 0u64;
        let mut inf = None;
        for line in text.lines() {
            if let Some(rest) = line.strip_prefix("x_bucket{le=\"") {
                let v: u64 = rest.rsplit_once(' ').unwrap().1.parse().unwrap();
                assert!(v >= prev, "non-cumulative: {line}");
                prev = v;
                if rest.starts_with("+Inf") {
                    inf = Some(v);
                }
            }
        }
        assert_eq!(inf, Some(5));
        assert!(text.contains("x_count 5\n"));
    }

    #[test]
    fn label_values_are_escaped() {
        let mut w = PromWriter::default();
        w.value("m", &[("tenant", "a\"b\\c\nd")], 1.0);
        let text = w.finish();
        assert!(text.contains(r#"m{tenant="a\"b\\c\nd"} 1"#), "{text}");
    }
}
