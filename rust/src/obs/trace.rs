//! Per-request trace timelines and the bounded ring that retains them.
//!
//! A [`Timeline`] is one request's span breakdown — durations of the
//! `admission → queue_wait → cache_freeze → forward → backward →
//! update → respond` pipeline stages — captured at stage *boundaries*
//! by the serving layer, never inside kernels or reductions, so traced
//! and untraced requests produce bit-identical results (pinned by
//! `tracing_on_vs_off_is_bit_identical` in the server integration
//! tests).
//!
//! Traced timelines land in a fixed-capacity [`TraceRing`]: a cursor
//! `fetch_add` picks a slot, a per-slot mutex swaps the timeline in.
//! The ring keeps the last [`TRACE_RING_CAPACITY`] timelines; older
//! entries are overwritten.  Untraced requests never touch the ring,
//! so the default path stays free of trace-side atomics.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

/// How many timelines the serve-side ring retains (`trace-dump` emits
/// at most this many JSON lines, oldest first).
pub const TRACE_RING_CAPACITY: usize = 64;

/// Pipeline stages of one request, in wire order.  `Admission` covers
/// parse/validate up to enqueue; `Respond` covers formatting and
/// reply-channel send (measured by the caller as total minus the rest).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Stage {
    /// Parse + admission control, before the job enters the queue.
    Admission,
    /// Time spent queued before a worker popped the job.
    QueueWait,
    /// Coefficient freeze on a prepared-cache miss (0 on a hit).
    CacheFreeze,
    /// Forward pass (E-step scoring half).
    Forward,
    /// Backward pass fused with expectation accumulation.
    Backward,
    /// Parameter update (M-step), nonzero only for training requests.
    Update,
    /// Response formatting + reply send.
    Respond,
}

impl Stage {
    /// All stages, wire order.
    pub const ALL: [Stage; 7] = [
        Stage::Admission,
        Stage::QueueWait,
        Stage::CacheFreeze,
        Stage::Forward,
        Stage::Backward,
        Stage::Update,
        Stage::Respond,
    ];

    /// Stable snake_case name, used as the `stage` label value in the
    /// Prometheus exposition and the JSON span keys.
    pub fn name(&self) -> &'static str {
        match self {
            Stage::Admission => "admission",
            Stage::QueueWait => "queue_wait",
            Stage::CacheFreeze => "cache_freeze",
            Stage::Forward => "forward",
            Stage::Backward => "backward",
            Stage::Update => "update",
            Stage::Respond => "respond",
        }
    }
}

/// One request's span breakdown.  `started_ns` is a monotonic offset
/// from the server start, so timelines from one process sort and
/// correlate without wall-clock skew.
#[derive(Clone, Debug)]
pub struct Timeline {
    /// Trace id — the job id, echoed on the wire as `trace=<id>`.
    pub trace_id: u64,
    /// Tenant that submitted the request.
    pub tenant: String,
    /// Request kind (`score` / `align` / `search` / `correct`).
    pub kind: &'static str,
    /// Engine that served it.
    pub engine: &'static str,
    /// Whether the request succeeded.
    pub ok: bool,
    /// Monotonic offset of admission from server start, ns.
    pub started_ns: u64,
    /// End-to-end latency, ns.
    pub total_ns: u64,
    /// Per-stage durations, ns, in [`Stage::ALL`] order (absent stages
    /// are 0).
    pub spans: [u64; Stage::ALL.len()],
}

fn escape_json(s: &str, out: &mut String) {
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            c if (c as u32) < 0x20 => {
                out.push_str(&format!("\\u{:04x}", c as u32));
            }
            c => out.push(c),
        }
    }
}

impl Timeline {
    /// One-line JSON rendering, the `trace-dump` / slow-request-log
    /// format.  Tenant is client-controlled and therefore escaped.
    pub fn to_json(&self) -> String {
        let mut s = String::with_capacity(256);
        s.push_str(&format!(
            "{{\"trace_id\":{},\"tenant\":\"",
            self.trace_id
        ));
        escape_json(&self.tenant, &mut s);
        s.push_str(&format!(
            "\",\"kind\":\"{}\",\"engine\":\"{}\",\"ok\":{},\"started_ns\":{},\"total_ns\":{},\"spans\":{{",
            self.kind, self.engine, self.ok, self.started_ns, self.total_ns
        ));
        for (i, stage) in Stage::ALL.iter().enumerate() {
            if i > 0 {
                s.push(',');
            }
            s.push_str(&format!("\"{}\":{}", stage.name(), self.spans[i]));
        }
        s.push_str("}}");
        s
    }
}

/// Bounded ring of the last [`TRACE_RING_CAPACITY`] timelines.
#[derive(Debug)]
pub struct TraceRing {
    slots: Vec<Mutex<Option<Timeline>>>,
    cursor: AtomicU64,
}

impl Default for TraceRing {
    fn default() -> Self {
        TraceRing {
            slots: (0..TRACE_RING_CAPACITY).map(|_| Mutex::new(None)).collect(),
            cursor: AtomicU64::new(0),
        }
    }
}

impl TraceRing {
    /// Retain a timeline, overwriting the oldest when full.  Slot
    /// choice is a single `fetch_add`; the per-slot mutex is held only
    /// for the swap, so concurrent pushes contend per-slot, not
    /// ring-wide.
    pub fn push(&self, t: Timeline) {
        let i = self.cursor.fetch_add(1, Ordering::Relaxed) as usize % self.slots.len();
        *self.slots[i].lock().unwrap() = Some(t);
    }

    /// Snapshot of retained timelines, oldest first.
    pub fn dump(&self) -> Vec<Timeline> {
        let n = self.slots.len();
        let cur = self.cursor.load(Ordering::Relaxed) as usize;
        let mut out = Vec::new();
        for k in 0..n {
            let i = (cur + k) % n;
            if let Some(t) = self.slots[i].lock().unwrap().as_ref() {
                out.push(t.clone());
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn timeline(id: u64) -> Timeline {
        Timeline {
            trace_id: id,
            tenant: "t".into(),
            kind: "score",
            engine: "sparse",
            ok: true,
            started_ns: 10 * id,
            total_ns: 100,
            spans: [1, 2, 3, 4, 5, 6, 7],
        }
    }

    #[test]
    fn ring_retains_last_capacity_timelines_oldest_first() {
        let ring = TraceRing::default();
        assert!(ring.dump().is_empty());
        for id in 0..(TRACE_RING_CAPACITY as u64 + 10) {
            ring.push(timeline(id));
        }
        let dump = ring.dump();
        assert_eq!(dump.len(), TRACE_RING_CAPACITY);
        // The oldest surviving timeline is id 10; dump is oldest-first.
        assert_eq!(dump.first().unwrap().trace_id, 10);
        assert_eq!(
            dump.last().unwrap().trace_id,
            TRACE_RING_CAPACITY as u64 + 9
        );
        for w in dump.windows(2) {
            assert!(w[0].trace_id < w[1].trace_id);
        }
    }

    #[test]
    fn timeline_json_is_one_line_with_all_spans() {
        let j = timeline(7).to_json();
        assert!(!j.contains('\n'));
        assert!(j.starts_with('{') && j.ends_with('}'));
        assert!(j.contains("\"trace_id\":7"));
        for stage in Stage::ALL {
            assert!(j.contains(&format!("\"{}\":", stage.name())), "{j}");
        }
    }

    #[test]
    fn tenant_names_are_json_escaped() {
        let mut t = timeline(1);
        t.tenant = "a\"b\\c\nd".into();
        let j = t.to_json();
        assert!(j.contains("a\\\"b\\\\c\\u000ad"), "{j}");
        assert!(!j.contains('\n'));
    }
}
