//! Silent-state elimination (deletion folding).
//!
//! The compute engines — sparse, banded, and the AOT kernels — require
//! *emitting-only* graphs so every timestep consumes exactly one
//! character (the uniform recurrence of Eq. 1/2 and of the banded
//! kernels).  The traditional design's deletion states are silent, so
//! before compute we fold them away: every path
//! `i -> D -> D -> ... -> j` through silent states becomes a direct edge
//! `i -> j` carrying the product of the path probabilities.  This is the
//! standard silent-state elimination (Durbin et al. §3.4) and is exact up
//! to the configured maximum chain length (long deletion chains carry
//! geometrically vanishing mass; dropped remainders are renormalized
//! away, and `max_chain` bounds the band width of the folded graph).

use super::graph::{GraphBuilder, Phmm, PhmmDesign};
use crate::error::{ApHmmError, Result};

impl Phmm {
    /// Fold silent (deletion) states into direct transitions, returning
    /// an emitting-only graph.  `max_chain` caps the folded deletion
    /// length (the paper's EC design default of 5 is a good choice).
    ///
    /// State indices are remapped (silent states removed); the mapping
    /// preserves topological order, so the folded graph remains banded.
    pub fn fold_silent(&self, max_chain: usize) -> Result<Phmm> {
        if !self.has_silent_states() {
            return Ok(self.clone());
        }
        let n = self.n_states();
        // Remap emitting states to dense indices.
        let mut new_index = vec![u32::MAX; n];
        let mut n_new = 0u32;
        for i in 0..n {
            if !self.kinds[i].is_silent() {
                new_index[i] = n_new;
                n_new += 1;
            }
        }

        let mut b = GraphBuilder::new(PhmmDesign::TraditionalFolded, self.alphabet);
        for i in 0..n {
            if !self.kinds[i].is_silent() {
                b.add_state(self.kinds[i], self.position[i], self.emission_row(i).to_vec());
            }
        }

        // For each emitting source, accumulate direct edges and walk
        // silent chains depth-first with probability products.
        let mut new_init = vec![0.0f32; n_new as usize];
        for i in 0..n {
            if self.kinds[i].is_silent() {
                continue;
            }
            let src = new_index[i];
            let mut acc: Vec<(u32, f32)> = Vec::new();
            self.collect_folded(i, 1.0, 0, max_chain, &mut acc)?;
            // Converging silent paths (possible in externally-loaded
            // graphs) can reach the same emitting target more than
            // once; folding is exact under summation, and parallel
            // edges are a structural error (`Phmm::validate` — the
            // dense lowerings hold one cell per (from, to) pair), so
            // coalesce per target.  First-occurrence order is kept so
            // duplicate-free graphs fold bit-identically to before.
            let mut merged: Vec<(u32, f32)> = Vec::new();
            for (to, p) in acc {
                match merged.iter_mut().find(|e| e.0 == to) {
                    Some(e) => e.1 += p,
                    None => merged.push((to, p)),
                }
            }
            for (to, p) in merged {
                b.add_edge(src, new_index[to as usize], p);
            }
        }
        // Fold f_init mass sitting on silent states (possible for graphs
        // built by external formats) through the same chains.
        for i in 0..n {
            let mass = self.f_init[i];
            if mass == 0.0 {
                continue;
            }
            if !self.kinds[i].is_silent() {
                new_init[new_index[i] as usize] += mass;
            } else {
                let mut acc: Vec<(u32, f32)> = Vec::new();
                self.collect_folded_from_silent(i, mass, 0, max_chain, &mut acc)?;
                for (to, p) in acc {
                    new_init[new_index[to as usize] as usize] += p;
                }
            }
        }
        let s: f32 = new_init.iter().sum();
        if s <= 0.0 {
            return Err(ApHmmError::InvalidGraph("f_init vanished during folding".into()));
        }
        new_init.iter_mut().for_each(|x| *x /= s);
        b.build(new_init)
    }

    /// Accumulate folded edges out of emitting state `i`.
    fn collect_folded(
        &self,
        i: usize,
        weight: f32,
        depth: usize,
        max_chain: usize,
        acc: &mut Vec<(u32, f32)>,
    ) -> Result<()> {
        for (to, p) in self.outgoing(i) {
            let w = weight * p;
            if !self.kinds[to as usize].is_silent() {
                acc.push((to, w));
            } else if depth < max_chain {
                self.collect_folded_from_silent(to as usize, w, depth + 1, max_chain, acc)?;
            }
            // else: drop the vanishing tail; builder renormalizes.
        }
        Ok(())
    }

    /// Walk outward from a silent state, multiplying probabilities.
    fn collect_folded_from_silent(
        &self,
        silent: usize,
        weight: f32,
        depth: usize,
        max_chain: usize,
        acc: &mut Vec<(u32, f32)>,
    ) -> Result<()> {
        for (to, p) in self.outgoing(silent) {
            let w = weight * p;
            if !self.kinds[to as usize].is_silent() {
                acc.push((to, w));
            } else if depth < max_chain {
                self.collect_folded_from_silent(to as usize, w, depth + 1, max_chain, acc)?;
            } else {
                // Chain longer than max_chain: truncate at this depth by
                // dropping the remainder (renormalized by the builder).
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::phmm::{Profile, StateKind, TraditionalParams};
    use crate::seq::{Sequence, DNA};

    fn folded(len: usize) -> (Phmm, Phmm) {
        let seq = Sequence::from_symbols("r", (0..len).map(|i| (i % 4) as u8).collect());
        let profile = Profile::from_sequence(&seq, DNA, 0.9);
        let g = Phmm::traditional(&profile, &TraditionalParams::default()).unwrap();
        let f = g.fold_silent(5).unwrap();
        (g, f)
    }

    #[test]
    fn folding_removes_all_silent_states() {
        let (g, f) = folded(20);
        assert!(g.has_silent_states());
        assert!(!f.has_silent_states());
        assert_eq!(f.n_states(), 40); // M + I per position
        f.validate().unwrap();
    }

    #[test]
    fn folding_preserves_topological_order() {
        let (_, f) = folded(15);
        for i in 0..f.n_states() {
            for (to, _) in f.outgoing(i) {
                assert!(to as usize >= i);
            }
        }
    }

    #[test]
    fn folded_deletion_paths_have_product_probability() {
        // M_0 -> D_1 -> M_2 should appear with prob a_md * a_dm
        // (renormalized only by the negligible truncated tail).
        let (g, f) = folded(10);
        let params = TraditionalParams::default();
        // In the folded graph positions keep order: M_t = 2t, I_t = 2t+1.
        let m0 = 0usize;
        let m2 = 4usize;
        let p: f32 = f
            .outgoing(m0)
            .find(|&(to, _)| to as usize == m2)
            .map(|(_, p)| p)
            .expect("folded skip edge missing");
        let want = params.a_md * params.a_dm;
        assert!((p - want).abs() / want < 0.05, "p={p} want~{want}");
        drop(g);
    }

    #[test]
    fn folding_is_idempotent_on_emitting_graphs() {
        let (_, f) = folded(8);
        let f2 = f.fold_silent(5).unwrap();
        assert_eq!(f.n_states(), f2.n_states());
        assert_eq!(f.out_to, f2.out_to);
    }

    #[test]
    fn ec_design_unchanged_by_folding() {
        let seq = Sequence::from_str("r", "ACGTACGTAC", DNA).unwrap();
        let g = Phmm::error_correction(&seq, &Default::default()).unwrap();
        let f = g.fold_silent(5).unwrap();
        assert_eq!(g.n_states(), f.n_states());
    }

    #[test]
    fn converging_silent_paths_coalesce_into_one_edge() {
        // Two silent chains from the same emitting source converging on
        // the same emitting target (constructible via external formats)
        // must fold into ONE edge carrying the summed path mass —
        // parallel edges are rejected by Phmm::validate, so without
        // coalescing fold_silent would fail on its own output.
        let mut b = GraphBuilder::new(PhmmDesign::Traditional, DNA);
        let m0 = b.add_state(StateKind::Match, 0, vec![0.25; 4]);
        let da = b.add_state(StateKind::Deletion, 1, vec![0.0; 4]);
        let db = b.add_state(StateKind::Deletion, 1, vec![0.0; 4]);
        let m1 = b.add_state(StateKind::Match, 2, vec![0.25; 4]);
        b.add_edge(m0, da, 0.3);
        b.add_edge(m0, db, 0.3);
        b.add_edge(m0, m1, 0.4);
        b.add_edge(da, m1, 1.0);
        b.add_edge(db, m1, 1.0);
        let mut f_init = vec![0.0f32; 4];
        f_init[0] = 1.0;
        let g = b.build(f_init).unwrap();
        assert!(g.has_silent_states());

        let f = g.fold_silent(5).unwrap();
        f.validate().unwrap();
        assert_eq!(f.n_states(), 2);
        let edges: Vec<(u32, f32)> = f.outgoing(0).collect();
        assert_eq!(edges.len(), 1, "converging paths must coalesce: {edges:?}");
        assert_eq!(edges[0].0, 1);
        // 0.4 direct + 0.3 via Da + 0.3 via Db, renormalized to 1.
        assert!((edges[0].1 - 1.0).abs() < 1e-6, "summed mass {edges:?}");
    }

    #[test]
    fn folded_kinds_are_match_and_insertion_only() {
        let (_, f) = folded(12);
        assert!(f.kinds.iter().all(|k| !matches!(k, StateKind::Deletion)));
    }
}
