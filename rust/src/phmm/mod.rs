//! Profile Hidden Markov Model graphs.
//!
//! Two designs, matching the paper's flexibility requirement (§4, key
//! mechanism 1):
//!
//! * **Traditional** (Fig. 1 / Supplemental S1): match, insertion and
//!   *silent* deletion states per represented character, insertion
//!   self-loops.  Built by [`Phmm::traditional`] from a [`Profile`];
//!   silent states are eliminated by [`Phmm::fold_silent`] before the
//!   compute engines run (DESIGN.md §Numerics).
//! * **Error correction** (Apollo/Hercules, §2.3): no deletion states
//!   (deletions become skip transitions) and bounded insertion chains
//!   instead of loops.  Built by [`Phmm::error_correction`].
//!
//! Both lower to the same two compute encodings:
//!
//! * a CSR sparse graph ([`Phmm`]) driving the sparse Baum-Welch engine
//!   with state filtering (the CPU/accelerator-modeled path), and
//! * a banded dense encoding ([`BandedPhmm`]) — states topologically
//!   ordered, every transition a forward hop of `< W` — shared bit-for-
//!   bit with the L2/L1 JAX kernels and the PJRT runtime.

mod banded;
mod design;
mod fold;
mod graph;
mod profile;

pub use banded::BandedPhmm;
pub use design::{EcDesignParams, TraditionalParams};
pub use graph::{Phmm, PhmmDesign, StateKind};
pub use profile::Profile;
