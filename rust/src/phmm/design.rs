//! Builders for the two pHMM designs.

use super::graph::{GraphBuilder, Phmm, PhmmDesign, StateKind};
use super::profile::Profile;
use crate::error::Result;
use crate::seq::{Alphabet, Sequence};

/// Parameters of the Apollo-style error-correction design (§2.3).
///
/// The modified design "avoids loops in the insertion states and uses
/// transitions to account for deletions instead of deletion states".
/// Defaults reproduce the paper's topology statistics: each match state
/// has `1 (match) + 1 (insertion) + max_deletions (skips)` ≈ 7 outgoing
/// transitions, within the reported 3–12 range.
#[derive(Clone, Copy, Debug)]
pub struct EcDesignParams {
    /// Maximum chained insertion states per position (no loops).
    pub max_insertions: usize,
    /// Maximum deletion length representable as skip transitions.
    pub max_deletions: usize,
    /// P(match transition M_t -> M_{t+1}).
    pub t_match: f32,
    /// P(opening an insertion M_t -> I_t^1).
    pub t_ins: f32,
    /// Total deletion probability, split geometrically over skip lengths.
    pub t_del_total: f32,
    /// Geometric decay factor of deletion lengths (del_j ∝ decay^-j).
    pub del_decay: f32,
    /// P(extending an insertion chain I^x -> I^{x+1}).
    pub t_ins_ext: f32,
    /// Emission probability of the represented character in match states.
    pub match_emit: f32,
    /// Initial-state spread: f_init mass decays geometrically over the
    /// first few match states to tolerate fuzzy read anchoring.
    pub init_spread: usize,
}

impl Default for EcDesignParams {
    fn default() -> Self {
        EcDesignParams {
            max_insertions: 3,
            max_deletions: 5,
            t_match: 0.85,
            t_ins: 0.10,
            t_del_total: 0.05,
            del_decay: 2.5,
            t_ins_ext: 0.30,
            match_emit: 0.97,
            init_spread: 3,
        }
    }
}

/// Global transition parameters of the traditional design.
#[derive(Clone, Copy, Debug)]
pub struct TraditionalParams {
    /// M -> M.
    pub a_mm: f32,
    /// M -> I (insertion open).
    pub a_mi: f32,
    /// M -> D (deletion open).
    pub a_md: f32,
    /// I -> M (insertion close).
    pub a_im: f32,
    /// I -> I (self-loop).
    pub a_ii: f32,
    /// D -> M (deletion close).
    pub a_dm: f32,
    /// D -> D (deletion extend).
    pub a_dd: f32,
}

impl Default for TraditionalParams {
    fn default() -> Self {
        TraditionalParams {
            a_mm: 0.90,
            a_mi: 0.05,
            a_md: 0.05,
            a_im: 0.70,
            a_ii: 0.30,
            a_dm: 0.70,
            a_dd: 0.30,
        }
    }
}

/// Emission row concentrated on `target` with probability `peak`.
fn peaked_emission(sigma: usize, target: u8, peak: f32) -> Vec<f32> {
    let rest = (1.0 - peak) / (sigma - 1) as f32;
    let mut row = vec![rest; sigma];
    row[target as usize] = peak;
    row
}

/// Geometrically decaying f_init over the first `spread` match states.
fn spread_init(n_states: usize, match_indices: &[u32], spread: usize) -> Vec<f32> {
    let mut f_init = vec![0.0f32; n_states];
    let k = spread.min(match_indices.len()).max(1);
    let mut mass = 1.0f32;
    for (rank, &mi) in match_indices.iter().take(k).enumerate() {
        let p = if rank + 1 == k { mass } else { mass * 0.75 };
        f_init[mi as usize] = p;
        mass -= p;
    }
    let s: f32 = f_init.iter().sum();
    f_init.iter_mut().for_each(|x| *x /= s);
    f_init
}

impl Phmm {
    /// Build the Apollo-style error-correction pHMM for `reference`.
    ///
    /// State layout per reference position `t`:
    /// `M_t, I_t^1, .., I_t^k` at indices `(k+1)*t ..`, which makes the
    /// graph banded with `W = (1 + max_deletions) * (k+1)` (DESIGN.md).
    pub fn error_correction(reference: &Sequence, params: &EcDesignParams) -> Result<Phmm> {
        let alphabet = crate::seq::DNA;
        Phmm::error_correction_for(reference, params, alphabet)
    }

    /// [`Phmm::error_correction`] generalized over the alphabet.
    pub fn error_correction_for(
        reference: &Sequence,
        params: &EcDesignParams,
        alphabet: Alphabet,
    ) -> Result<Phmm> {
        let l = reference.len();
        let k = params.max_insertions;
        let sigma = alphabet.size();
        let mut b = GraphBuilder::new(PhmmDesign::ErrorCorrection, alphabet);
        let uniform = vec![1.0 / sigma as f32; sigma];

        // States: position-major, match first then its insertion chain.
        let midx = |t: usize| ((k + 1) * t) as u32;
        let iidx = |t: usize, x: usize| ((k + 1) * t + x) as u32; // x in 1..=k
        let mut match_indices = Vec::with_capacity(l);
        for t in 0..l {
            let m = b.add_state(
                StateKind::Match,
                t as u32,
                peaked_emission(sigma, reference.data[t], params.match_emit),
            );
            match_indices.push(m);
            for _x in 1..=k {
                b.add_state(StateKind::Insertion, t as u32, uniform.clone());
            }
        }

        // Deletion skip weights del_j ∝ decay^-j, j = 1..=max_deletions.
        let mut del_w: Vec<f32> =
            (1..=params.max_deletions).map(|j| params.del_decay.powi(-(j as i32))).collect();
        let dw_sum: f32 = del_w.iter().sum();
        del_w.iter_mut().for_each(|w| *w *= params.t_del_total / dw_sum);

        for t in 0..l {
            // The last position is terminal: no insertion chain either,
            // since its insertions could never rejoin a match state and
            // would otherwise pollute the Viterbi consensus.
            if t + 1 >= l {
                break;
            }
            // Match-state row: insertion open, match, deletion skips.
            // Rows are renormalized by the builder after end clamping.
            if k > 0 {
                b.add_edge(midx(t), iidx(t, 1), params.t_ins);
            }
            b.add_edge(midx(t), midx(t + 1), params.t_match);
            for (j, &w) in del_w.iter().enumerate() {
                let target = t + 2 + j; // skip j+1 characters
                if target < l {
                    b.add_edge(midx(t), midx(target), w);
                }
            }
            // Insertion chain: extend or return to the next match.
            for x in 1..=k {
                b.add_edge(iidx(t, x), midx(t + 1), 1.0 - params.t_ins_ext);
                if x < k {
                    b.add_edge(iidx(t, x), iidx(t, x + 1), params.t_ins_ext);
                }
            }
        }

        let n = b.kinds.len();
        let f_init = spread_init(n, &match_indices, params.init_spread);
        b.build(f_init)
    }

    /// Build the traditional M/I/D pHMM from a per-position [`Profile`].
    ///
    /// The returned graph contains silent deletion states; call
    /// [`Phmm::fold_silent`] before running the compute engines.
    pub fn traditional(profile: &Profile, params: &TraditionalParams) -> Result<Phmm> {
        let l = profile.len();
        let alphabet = profile.alphabet;
        let sigma = alphabet.size();
        let mut b = GraphBuilder::new(PhmmDesign::Traditional, alphabet);
        let uniform = vec![1.0 / sigma as f32; sigma];

        // Layout per position: M = 3t, I = 3t+1, D = 3t+2.
        let midx = |t: usize| (3 * t) as u32;
        let iidx = |t: usize| (3 * t + 1) as u32;
        let didx = |t: usize| (3 * t + 2) as u32;
        let mut match_indices = Vec::with_capacity(l);
        for t in 0..l {
            let m = b.add_state(StateKind::Match, t as u32, profile.match_row(t).to_vec());
            match_indices.push(m);
            b.add_state(StateKind::Insertion, t as u32, uniform.clone());
            b.add_state(StateKind::Deletion, t as u32, vec![0.0; sigma]);
        }

        for t in 0..l {
            b.add_edge(midx(t), iidx(t), params.a_mi);
            if t + 1 < l {
                b.add_edge(midx(t), midx(t + 1), params.a_mm);
                b.add_edge(midx(t), didx(t + 1), params.a_md);
                b.add_edge(iidx(t), midx(t + 1), params.a_im);
                b.add_edge(didx(t), midx(t + 1), params.a_dm);
            }
            b.add_edge(iidx(t), iidx(t), params.a_ii);
            if t + 2 < l {
                b.add_edge(didx(t), didx(t + 1), params.a_dd);
            }
        }

        let n = b.kinds.len();
        let f_init = spread_init(n, &match_indices, 1);
        b.build(f_init)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::seq::{DNA, PROTEIN};

    fn ec_graph(len: usize) -> Phmm {
        let seq = Sequence::from_symbols("ref", (0..len).map(|i| (i % 4) as u8).collect());
        Phmm::error_correction(&seq, &EcDesignParams::default()).unwrap()
    }

    #[test]
    fn ec_design_shape() {
        let params = EcDesignParams::default();
        let g = ec_graph(50);
        assert_eq!(g.n_states(), 50 * (params.max_insertions + 1));
        assert!(!g.has_silent_states());
        g.validate().unwrap();
    }

    #[test]
    fn ec_mean_out_degree_in_paper_range() {
        let g = ec_graph(200);
        let d = g.mean_out_degree();
        // Paper: 3-12 distinct transitions per state, ~7 for match states.
        assert!((1.5..12.0).contains(&d), "degree={d}");
    }

    #[test]
    fn ec_match_state_degree() {
        let g = ec_graph(100);
        let params = EcDesignParams::default();
        // An interior match state: ins open + match + max_deletions skips.
        let m10 = (params.max_insertions + 1) * 10;
        let deg = g.outgoing(m10).count();
        assert_eq!(deg, 2 + params.max_deletions);
    }

    #[test]
    fn ec_no_insertion_loops() {
        let g = ec_graph(30);
        for i in 0..g.n_states() {
            for (to, _) in g.outgoing(i) {
                assert_ne!(to as usize, i, "self loop at {i}");
            }
        }
    }

    #[test]
    fn ec_emission_peaked_on_reference() {
        let seq = Sequence::from_str("r", "ACGT", DNA).unwrap();
        let g = Phmm::error_correction(&seq, &EcDesignParams::default()).unwrap();
        let k1 = EcDesignParams::default().max_insertions + 1;
        for (t, &ch) in seq.data.iter().enumerate() {
            let m = t * k1;
            assert!(g.emission(m, ch) > 0.9);
        }
    }

    #[test]
    fn traditional_design_has_silent_states() {
        let profile = Profile::from_sequence(
            &Sequence::from_str("p", "ACDEFGHIKL", PROTEIN).unwrap(),
            PROTEIN,
            0.9,
        );
        let g = Phmm::traditional(&profile, &TraditionalParams::default()).unwrap();
        assert!(g.has_silent_states());
        assert_eq!(g.n_states(), 30);
        g.validate().unwrap();
    }

    #[test]
    fn traditional_insertion_self_loop_present() {
        let profile = Profile::from_sequence(
            &Sequence::from_str("p", "ACGTAC", DNA).unwrap(),
            DNA,
            0.9,
        );
        let g = Phmm::traditional(&profile, &TraditionalParams::default()).unwrap();
        let i0 = 1usize;
        assert!(g.outgoing(i0).any(|(to, _)| to as usize == i0));
    }

    #[test]
    fn tiny_references_build() {
        for len in 1..6 {
            let g = ec_graph(len);
            g.validate().unwrap();
        }
    }
}
