//! Core pHMM graph structure (CSR sparse encoding).

use crate::error::{ApHmmError, Result};
use crate::seq::Alphabet;

/// Role of a state in the pHMM design.
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub enum StateKind {
    /// Match/mismatch state for one represented character.
    Match,
    /// Insertion state (traditional: self-looping; EC design: chained).
    Insertion,
    /// Silent deletion state (traditional design only).
    Deletion,
}

impl StateKind {
    /// Silent states emit no character and must be folded before compute.
    #[inline]
    pub fn is_silent(&self) -> bool {
        matches!(self, StateKind::Deletion)
    }
}

/// Which design produced the graph.
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub enum PhmmDesign {
    /// Traditional M/I/D design (Fig. 1).
    Traditional,
    /// Traditional design after silent-state folding (emitting only).
    TraditionalFolded,
    /// Apollo-style error-correction design (§2.3).
    ErrorCorrection,
}

/// A pHMM graph `G(V, A)` in CSR form.
///
/// Invariants (checked by [`Phmm::validate`]):
/// * transitions only go forward or self (`to >= from`), so states are
///   in topological order;
/// * outgoing probability rows of non-terminal states sum to 1;
/// * emission rows of emitting states sum to 1; silent rows are zero;
/// * `f_init` is a distribution over emitting states.
#[derive(Clone, Debug)]
pub struct Phmm {
    /// Design that produced this graph.
    pub design: PhmmDesign,
    /// Symbol alphabet (Σ).
    pub alphabet: Alphabet,
    /// Per-state kind.
    pub kinds: Vec<StateKind>,
    /// Represented-sequence position of each state.
    pub position: Vec<u32>,
    /// CSR row pointers: outgoing edges of state `i` are
    /// `out_ptr[i]..out_ptr[i+1]` into `out_to` / `out_prob`.
    pub out_ptr: Vec<u32>,
    /// CSR target state of each edge.
    pub out_to: Vec<u32>,
    /// CSR transition probability of each edge (`α_ij`).
    pub out_prob: Vec<f32>,
    /// Dense emission matrix, row-major `[n_states × Σ]` (`e_c(v_i)`).
    pub emissions: Vec<f32>,
    /// Initial state distribution.
    pub f_init: Vec<f32>,
}

impl Phmm {
    /// Number of states `|V|`.
    #[inline]
    pub fn n_states(&self) -> usize {
        self.kinds.len()
    }

    /// Number of transitions `|A|`.
    #[inline]
    pub fn n_transitions(&self) -> usize {
        self.out_to.len()
    }

    /// Alphabet size Σ.
    #[inline]
    pub fn sigma(&self) -> usize {
        self.alphabet.size()
    }

    /// Outgoing edges of state `i` as `(target, probability)` pairs.
    #[inline]
    pub fn outgoing(&self, i: usize) -> impl Iterator<Item = (u32, f32)> + '_ {
        let lo = self.out_ptr[i] as usize;
        let hi = self.out_ptr[i + 1] as usize;
        self.out_to[lo..hi].iter().copied().zip(self.out_prob[lo..hi].iter().copied())
    }

    /// Emission probability `e_c(v_i)`.
    #[inline]
    pub fn emission(&self, i: usize, c: u8) -> f32 {
        self.emissions[i * self.sigma() + c as usize]
    }

    /// Emission row of state `i`.
    #[inline]
    pub fn emission_row(&self, i: usize) -> &[f32] {
        let s = self.sigma();
        &self.emissions[i * s..(i + 1) * s]
    }

    /// States carrying initial probability mass, as `(state, f_init)`
    /// pairs in ascending state order.  The forward kernels snapshot
    /// this once per parameter freeze instead of rescanning `f_init`
    /// on every observation.
    #[inline]
    pub fn init_states(&self) -> impl Iterator<Item = (u32, f32)> + '_ {
        self.f_init
            .iter()
            .enumerate()
            .filter(|&(_, &p)| p > 0.0)
            .map(|(i, &p)| (i as u32, p))
    }

    /// True if the graph contains silent (deletion) states.
    pub fn has_silent_states(&self) -> bool {
        self.kinds.iter().any(|k| k.is_silent())
    }

    /// Mean number of outgoing transitions per non-terminal state
    /// (the paper reports 3–12, average ≈7 for the EC design).
    pub fn mean_out_degree(&self) -> f64 {
        let non_terminal =
            (0..self.n_states()).filter(|&i| self.out_ptr[i + 1] > self.out_ptr[i]).count();
        if non_terminal == 0 {
            return 0.0;
        }
        self.n_transitions() as f64 / non_terminal as f64
    }

    /// Build the reverse (incoming) CSR: for each state, the list of
    /// `(source, edge_index)` pairs.  Used by the in-degree analysis in
    /// the accelerator model and by Fig. 4-style locality statistics.
    pub fn incoming_csr(&self) -> (Vec<u32>, Vec<u32>, Vec<u32>) {
        let n = self.n_states();
        let mut counts = vec![0u32; n + 1];
        for &to in &self.out_to {
            counts[to as usize + 1] += 1;
        }
        for i in 0..n {
            counts[i + 1] += counts[i];
        }
        let in_ptr = counts.clone();
        let mut fill = in_ptr.clone();
        let mut in_from = vec![0u32; self.out_to.len()];
        let mut in_eidx = vec![0u32; self.out_to.len()];
        for from in 0..n {
            for e in self.out_ptr[from] as usize..self.out_ptr[from + 1] as usize {
                let to = self.out_to[e] as usize;
                let slot = fill[to] as usize;
                in_from[slot] = from as u32;
                in_eidx[slot] = e as u32;
                fill[to] += 1;
            }
        }
        (in_ptr, in_from, in_eidx)
    }

    /// Check all structural invariants; returns a descriptive error on
    /// the first violation.
    pub fn validate(&self) -> Result<()> {
        let n = self.n_states();
        let s = self.sigma();
        if self.out_ptr.len() != n + 1 {
            return Err(ApHmmError::InvalidGraph("out_ptr length".into()));
        }
        if self.emissions.len() != n * s {
            return Err(ApHmmError::InvalidGraph("emissions length".into()));
        }
        if self.f_init.len() != n {
            return Err(ApHmmError::InvalidGraph("f_init length".into()));
        }
        for i in 0..n {
            let lo = self.out_ptr[i] as usize;
            let hi = self.out_ptr[i + 1] as usize;
            if lo > hi || hi > self.out_to.len() {
                return Err(ApHmmError::InvalidGraph(format!("bad CSR row {i}")));
            }
            let row_sum: f32 = self.out_prob[lo..hi].iter().sum();
            if hi > lo && (row_sum - 1.0).abs() > 1e-3 {
                return Err(ApHmmError::InvalidGraph(format!(
                    "transition row {i} sums to {row_sum}"
                )));
            }
            for e in lo..hi {
                let to = self.out_to[e] as usize;
                if to >= n {
                    return Err(ApHmmError::InvalidGraph(format!("edge {i}->{to} out of range")));
                }
                if to < i {
                    return Err(ApHmmError::InvalidGraph(format!(
                        "backward edge {i}->{to} violates topological order"
                    )));
                }
                if !(0.0..=1.0 + 1e-6).contains(&self.out_prob[e]) {
                    return Err(ApHmmError::InvalidGraph(format!(
                        "edge {i}->{to} probability {}",
                        self.out_prob[e]
                    )));
                }
                // Rows must be strictly ascending in `to`: parallel
                // edges (duplicate from->to pairs) would be summed by
                // the CSR kernels but silently *overwritten* by the
                // dense lowerings (one band/tile cell per (from, to) in
                // `to_banded` and `baumwelch::tile`), so they are a
                // structural error, not a representable graph.  Every
                // in-crate constructor sorts rows (`GraphBuilder::build`,
                // `read_phmm_str`), so this only fires on duplicates or
                // hand-assembled unsorted CSR arrays.
                if e > lo && self.out_to[e] <= self.out_to[e - 1] {
                    return Err(ApHmmError::InvalidGraph(format!(
                        "row {i}: out_to not strictly ascending at edge {e} \
                         (parallel or unsorted edges)"
                    )));
                }
            }
            let erow = &self.emissions[i * s..(i + 1) * s];
            let esum: f32 = erow.iter().sum();
            if self.kinds[i].is_silent() {
                if esum != 0.0 {
                    return Err(ApHmmError::InvalidGraph(format!("silent state {i} emits")));
                }
            } else if (esum - 1.0).abs() > 1e-3 {
                return Err(ApHmmError::InvalidGraph(format!("emission row {i} sums to {esum}")));
            }
        }
        let init_sum: f32 = self.f_init.iter().sum();
        if (init_sum - 1.0).abs() > 1e-3 {
            return Err(ApHmmError::InvalidGraph(format!("f_init sums to {init_sum}")));
        }
        for (i, &p) in self.f_init.iter().enumerate() {
            if p > 0.0 && self.kinds[i].is_silent() {
                return Err(ApHmmError::InvalidGraph(format!("f_init mass on silent state {i}")));
            }
        }
        Ok(())
    }
}

/// Incremental builder used by the design constructors.
pub(crate) struct GraphBuilder {
    pub design: PhmmDesign,
    pub alphabet: Alphabet,
    pub kinds: Vec<StateKind>,
    pub position: Vec<u32>,
    pub edges: Vec<Vec<(u32, f32)>>,
    pub emissions: Vec<Vec<f32>>,
}

impl GraphBuilder {
    pub fn new(design: PhmmDesign, alphabet: Alphabet) -> Self {
        GraphBuilder {
            design,
            alphabet,
            kinds: Vec::new(),
            position: Vec::new(),
            edges: Vec::new(),
            emissions: Vec::new(),
        }
    }

    /// Add a state; returns its index.
    pub fn add_state(&mut self, kind: StateKind, position: u32, emission: Vec<f32>) -> u32 {
        debug_assert_eq!(emission.len(), self.alphabet.size());
        self.kinds.push(kind);
        self.position.push(position);
        self.edges.push(Vec::new());
        self.emissions.push(emission);
        (self.kinds.len() - 1) as u32
    }

    /// Add a transition edge.
    pub fn add_edge(&mut self, from: u32, to: u32, prob: f32) {
        if prob > 0.0 {
            self.edges[from as usize].push((to, prob));
        }
    }

    /// Normalize every non-empty outgoing row to sum to 1.
    pub fn normalize_rows(&mut self) {
        for row in &mut self.edges {
            let s: f32 = row.iter().map(|&(_, p)| p).sum();
            if s > 0.0 {
                row.iter_mut().for_each(|e| e.1 /= s);
            }
        }
    }

    /// Finish into a validated [`Phmm`].
    pub fn build(mut self, f_init: Vec<f32>) -> Result<Phmm> {
        self.normalize_rows();
        let n = self.kinds.len();
        let mut out_ptr = Vec::with_capacity(n + 1);
        let mut out_to = Vec::new();
        let mut out_prob = Vec::new();
        out_ptr.push(0u32);
        for row in &mut self.edges {
            row.sort_by_key(|&(to, _)| to);
            for &(to, p) in row.iter() {
                out_to.push(to);
                out_prob.push(p);
            }
            out_ptr.push(out_to.len() as u32);
        }
        let sigma = self.alphabet.size();
        let mut emissions = Vec::with_capacity(n * sigma);
        for row in &self.emissions {
            emissions.extend_from_slice(row);
        }
        let phmm = Phmm {
            design: self.design,
            alphabet: self.alphabet,
            kinds: self.kinds,
            position: self.position,
            out_ptr,
            out_to,
            out_prob,
            emissions,
            f_init,
        };
        phmm.validate()?;
        Ok(phmm)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::seq::DNA;

    fn tiny() -> Phmm {
        // 3-state chain: 0 -> 1 -> 2, uniform emissions.
        let mut b = GraphBuilder::new(PhmmDesign::ErrorCorrection, DNA);
        for p in 0..3 {
            b.add_state(StateKind::Match, p, vec![0.25; 4]);
        }
        b.add_edge(0, 1, 1.0);
        b.add_edge(1, 2, 1.0);
        b.build(vec![1.0, 0.0, 0.0]).unwrap()
    }

    #[test]
    fn csr_shape_and_access() {
        let g = tiny();
        assert_eq!(g.n_states(), 3);
        assert_eq!(g.n_transitions(), 2);
        let out0: Vec<_> = g.outgoing(0).collect();
        assert_eq!(out0, vec![(1, 1.0)]);
        assert!(g.outgoing(2).next().is_none());
        assert_eq!(g.emission(1, 2), 0.25);
    }

    #[test]
    fn incoming_csr_inverts_outgoing() {
        let g = tiny();
        let (in_ptr, in_from, in_eidx) = g.incoming_csr();
        assert_eq!(in_ptr, vec![0, 0, 1, 2]);
        assert_eq!(in_from, vec![0, 1]);
        // edge indexes round-trip to the right targets
        for (slot, &e) in in_eidx.iter().enumerate() {
            assert_eq!(g.out_to[e as usize] as usize, if slot == 0 { 1 } else { 2 });
        }
    }

    #[test]
    fn validate_rejects_backward_edge() {
        let mut b = GraphBuilder::new(PhmmDesign::ErrorCorrection, DNA);
        b.add_state(StateKind::Match, 0, vec![0.25; 4]);
        b.add_state(StateKind::Match, 1, vec![0.25; 4]);
        b.add_edge(1, 0, 1.0);
        assert!(b.build(vec![1.0, 0.0]).is_err());
    }

    #[test]
    fn validate_rejects_parallel_edges() {
        // Two edges on the same (from, to) pair: the CSR kernels would
        // sum them but the banded/tile lowerings keep one cell per
        // pair, so validate must reject the graph outright.
        let mut b = GraphBuilder::new(PhmmDesign::ErrorCorrection, DNA);
        b.add_state(StateKind::Match, 0, vec![0.25; 4]);
        b.add_state(StateKind::Match, 1, vec![0.25; 4]);
        b.add_edge(0, 1, 0.5);
        b.add_edge(0, 1, 0.5);
        assert!(b.build(vec![1.0, 0.0]).is_err());
    }

    #[test]
    fn validate_rejects_bad_emission() {
        let mut b = GraphBuilder::new(PhmmDesign::ErrorCorrection, DNA);
        b.add_state(StateKind::Match, 0, vec![0.9, 0.0, 0.0, 0.0]);
        assert!(b.build(vec![1.0]).is_err());
    }

    #[test]
    fn validate_rejects_init_on_silent() {
        let mut b = GraphBuilder::new(PhmmDesign::Traditional, DNA);
        b.add_state(StateKind::Deletion, 0, vec![0.0; 4]);
        b.add_state(StateKind::Match, 0, vec![0.25; 4]);
        b.add_edge(0, 1, 1.0);
        assert!(b.build(vec![1.0, 0.0]).is_err());
    }

    #[test]
    fn builder_normalizes_rows() {
        let mut b = GraphBuilder::new(PhmmDesign::ErrorCorrection, DNA);
        b.add_state(StateKind::Match, 0, vec![0.25; 4]);
        b.add_state(StateKind::Match, 1, vec![0.25; 4]);
        b.add_state(StateKind::Match, 2, vec![0.25; 4]);
        b.add_edge(0, 1, 3.0);
        b.add_edge(0, 2, 1.0);
        let g = b.build(vec![1.0, 0.0, 0.0]).unwrap();
        let probs: Vec<f32> = g.outgoing(0).map(|(_, p)| p).collect();
        assert!((probs[0] - 0.75).abs() < 1e-6);
        assert!((probs[1] - 0.25).abs() < 1e-6);
    }

    #[test]
    fn mean_out_degree_ignores_terminals() {
        let g = tiny();
        assert!((g.mean_out_degree() - 1.0).abs() < 1e-9);
    }
}
