//! Per-position emission profiles (the input of the traditional design).
//!
//! Stands in for `hmmbuild`: a profile is a length-L matrix of match
//! emissions.  It can be built from a single consensus/ancestor sequence
//! with smoothing, or from per-column symbol counts of a set of member
//! sequences anchored at their alignment spine (a simplified column
//! counting, since we build families from a known ancestor).

use crate::seq::{Alphabet, Sequence};

/// A match-emission profile of length L over alphabet Σ.
#[derive(Clone, Debug)]
pub struct Profile {
    /// Alphabet the profile is defined over.
    pub alphabet: Alphabet,
    /// Row-major `[L × Σ]` match emission probabilities.
    pub match_emit: Vec<f32>,
}

impl Profile {
    /// Profile length L (number of match columns).
    pub fn len(&self) -> usize {
        self.match_emit.len() / self.alphabet.size()
    }

    /// True if the profile has no columns.
    pub fn is_empty(&self) -> bool {
        self.match_emit.is_empty()
    }

    /// Emission row of column `t`.
    pub fn match_row(&self, t: usize) -> &[f32] {
        let s = self.alphabet.size();
        &self.match_emit[t * s..(t + 1) * s]
    }

    /// Build from a single sequence: each column emits the sequence
    /// character with probability `peak`, the rest uniformly.
    pub fn from_sequence(seq: &Sequence, alphabet: Alphabet, peak: f32) -> Profile {
        let sigma = alphabet.size();
        let rest = (1.0 - peak) / (sigma - 1) as f32;
        let mut match_emit = Vec::with_capacity(seq.len() * sigma);
        for &c in &seq.data {
            for s in 0..sigma {
                match_emit.push(if s == c as usize { peak } else { rest });
            }
        }
        Profile { alphabet, match_emit }
    }

    /// Build from member sequences column-counted against a spine of
    /// length `len` (member position i contributes to column i while it
    /// exists), with `pseudo` Laplace smoothing.  This approximates what
    /// `hmmbuild` derives from an MSA when members are near-full-length
    /// copies of a common ancestor — exactly our simulated families.
    pub fn from_members(members: &[Sequence], len: usize, alphabet: Alphabet, pseudo: f32) -> Profile {
        let sigma = alphabet.size();
        let mut counts = vec![pseudo; len * sigma];
        for m in members {
            for (i, &c) in m.data.iter().take(len).enumerate() {
                counts[i * sigma + c as usize] += 1.0;
            }
        }
        for t in 0..len {
            let row = &mut counts[t * sigma..(t + 1) * sigma];
            let s: f32 = row.iter().sum();
            row.iter_mut().for_each(|x| *x /= s);
        }
        Profile { alphabet, match_emit: counts }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::seq::{DNA, PROTEIN};

    #[test]
    fn from_sequence_rows_normalized_and_peaked() {
        let seq = Sequence::from_str("s", "ACGT", DNA).unwrap();
        let p = Profile::from_sequence(&seq, DNA, 0.85);
        assert_eq!(p.len(), 4);
        for t in 0..4 {
            let row = p.match_row(t);
            let sum: f32 = row.iter().sum();
            assert!((sum - 1.0).abs() < 1e-6);
            assert!((row[seq.data[t] as usize] - 0.85).abs() < 1e-6);
        }
    }

    #[test]
    fn from_members_counts_dominant_symbol() {
        let members: Vec<Sequence> = (0..5)
            .map(|i| Sequence::from_str(format!("m{i}"), "AAAA", DNA).unwrap())
            .collect();
        let p = Profile::from_members(&members, 4, DNA, 0.5);
        for t in 0..4 {
            assert!(p.match_row(t)[0] > 0.6, "col {t}: {:?}", p.match_row(t));
        }
    }

    #[test]
    fn from_members_handles_short_members() {
        let members =
            vec![Sequence::from_str("m", "AC", PROTEIN).unwrap()];
        let p = Profile::from_members(&members, 5, PROTEIN, 1.0);
        assert_eq!(p.len(), 5);
        // Columns beyond member length are uniform (pure pseudocounts).
        let row = p.match_row(4);
        let first = row[0];
        assert!(row.iter().all(|&x| (x - first).abs() < 1e-6));
    }
}
