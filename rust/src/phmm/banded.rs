//! Banded dense encoding of emitting pHMM graphs.
//!
//! The interchange format between the Rust engine and the AOT-compiled
//! L2/L1 kernels (DESIGN.md §Hardware-Adaptation): states in topological
//! order, `a_band[j, w] = P(j -> j+w)` for `0 <= w < W`.  Both designs
//! produce narrow bands (traditional-folded: W ≈ 2·(max_del+1); EC
//! design: W ≈ (1+max_del)·(1+max_ins)), which is exactly the spatial
//! locality ApHMM's Observation 5 exploits over generic HMMs.

use super::graph::Phmm;
use crate::error::{ApHmmError, Result};

/// Dense banded view of an emitting pHMM.
#[derive(Clone, Debug)]
pub struct BandedPhmm {
    /// Number of states N.
    pub n: usize,
    /// Band width W (max forward hop + 1; self-loop = offset 0).
    pub w: usize,
    /// Alphabet size Σ.
    pub sigma: usize,
    /// Row-major `[N × W]` transition band.
    pub a_band: Vec<f32>,
    /// Row-major `[N × Σ]` emissions.
    pub emit: Vec<f32>,
    /// Initial distribution `[N]`.
    pub f_init: Vec<f32>,
}

impl BandedPhmm {
    /// Band entry `a[j, w]`.
    #[inline]
    pub fn a(&self, j: usize, w: usize) -> f32 {
        self.a_band[j * self.w + w]
    }

    /// Emission entry `e[i, c]`.
    #[inline]
    pub fn e(&self, i: usize, c: usize) -> f32 {
        self.emit[i * self.sigma + c]
    }

    /// Band occupancy: fraction of in-band entries that are nonzero.
    /// This is the Fig. 4 locality statistic — pHMMs concentrate their
    /// dependencies in a narrow neighbourhood while generic HMMs spread
    /// over the full N×N matrix.
    pub fn occupancy(&self) -> f64 {
        let nz = self.a_band.iter().filter(|&&p| p > 0.0).count();
        nz as f64 / self.a_band.len() as f64
    }

    /// Pad to fixed `(n_pad, w_pad)` for a fixed-shape AOT artifact.
    /// Extra rows/offsets are zero; extra `f_init` is zero.
    pub fn pad_to(&self, n_pad: usize, w_pad: usize) -> Result<BandedPhmm> {
        if n_pad < self.n || w_pad < self.w {
            return Err(ApHmmError::Banded(format!(
                "cannot pad ({}, {}) to smaller ({n_pad}, {w_pad})",
                self.n, self.w
            )));
        }
        let mut a_band = vec![0.0f32; n_pad * w_pad];
        for j in 0..self.n {
            a_band[j * w_pad..j * w_pad + self.w]
                .copy_from_slice(&self.a_band[j * self.w..(j + 1) * self.w]);
        }
        let mut emit = vec![0.0f32; n_pad * self.sigma];
        emit[..self.n * self.sigma].copy_from_slice(&self.emit);
        // Padded states must still have valid (normalized) emission rows
        // so the artifact's division guards never see 0/0 on them; they
        // are unreachable (zero band rows, zero f_init), so any
        // distribution works.
        for i in self.n..n_pad {
            let row = &mut emit[i * self.sigma..(i + 1) * self.sigma];
            row.iter_mut().for_each(|x| *x = 1.0 / self.sigma as f32);
        }
        let mut f_init = vec![0.0f32; n_pad];
        f_init[..self.n].copy_from_slice(&self.f_init);
        Ok(BandedPhmm { n: n_pad, w: w_pad, sigma: self.sigma, a_band, emit, f_init })
    }
}

impl Phmm {
    /// Compute the band width W of this graph (1 + max forward hop).
    pub fn band_width(&self) -> usize {
        let mut w = 1usize;
        for i in 0..self.n_states() {
            for (to, _) in self.outgoing(i) {
                w = w.max(to as usize - i + 1);
            }
        }
        w
    }

    /// Lower to the banded dense encoding.  Fails on silent states
    /// (fold first) — backward edges are impossible by construction
    /// ([`Phmm::validate`] enforces topological order).
    pub fn to_banded(&self) -> Result<BandedPhmm> {
        if self.has_silent_states() {
            return Err(ApHmmError::Banded(
                "graph has silent states; call fold_silent() first".into(),
            ));
        }
        let n = self.n_states();
        let w = self.band_width();
        let mut a_band = vec![0.0f32; n * w];
        for j in 0..n {
            for (to, p) in self.outgoing(j) {
                a_band[j * w + (to as usize - j)] = p;
            }
        }
        Ok(BandedPhmm {
            n,
            w,
            sigma: self.sigma(),
            a_band,
            emit: self.emissions.clone(),
            f_init: self.f_init.clone(),
        })
    }

    /// Write banded parameters back into this graph's CSR arrays
    /// (the maximization step of batch EM runs on banded accumulators).
    pub fn update_from_banded(&mut self, banded: &BandedPhmm) -> Result<()> {
        if banded.n < self.n_states() || banded.sigma != self.sigma() {
            return Err(ApHmmError::Banded("shape mismatch in update_from_banded".into()));
        }
        for j in 0..self.n_states() {
            let lo = self.out_ptr[j] as usize;
            let hi = self.out_ptr[j + 1] as usize;
            for e in lo..hi {
                let off = self.out_to[e] as usize - j;
                if off >= banded.w {
                    return Err(ApHmmError::Banded(format!("edge offset {off} exceeds band")));
                }
                self.out_prob[e] = banded.a(j, off);
            }
        }
        let len = self.n_states() * self.sigma();
        self.emissions[..len].copy_from_slice(&banded.emit[..len]);
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::phmm::{EcDesignParams, Profile, TraditionalParams};
    use crate::seq::{Sequence, DNA};

    fn ec(len: usize) -> Phmm {
        let seq = Sequence::from_symbols("r", (0..len).map(|i| (i % 4) as u8).collect());
        Phmm::error_correction(&seq, &EcDesignParams::default()).unwrap()
    }

    #[test]
    fn banded_roundtrips_all_edges() {
        let g = ec(40);
        let b = g.to_banded().unwrap();
        for j in 0..g.n_states() {
            for (to, p) in g.outgoing(j) {
                assert_eq!(b.a(j, to as usize - j), p);
            }
        }
        // Every nonzero band entry corresponds to an edge.
        let n_edges = b.a_band.iter().filter(|&&p| p > 0.0).count();
        assert_eq!(n_edges, g.n_transitions());
    }

    #[test]
    fn ec_band_width_formula() {
        let params = EcDesignParams::default();
        let g = ec(60);
        // Longest hop: M_t -> M_{t + 1 + max_deletions}.
        let expect = (1 + params.max_deletions) * (1 + params.max_insertions) + 1;
        assert_eq!(g.band_width(), expect);
    }

    #[test]
    fn traditional_folded_band_is_narrow() {
        let seq = Sequence::from_str("r", "ACGTACGTACGTACGT", DNA).unwrap();
        let profile = Profile::from_sequence(&seq, DNA, 0.9);
        let g = Phmm::traditional(&profile, &TraditionalParams::default())
            .unwrap()
            .fold_silent(4)
            .unwrap();
        let b = g.to_banded().unwrap();
        assert!(b.w <= 2 * (4 + 2), "W={}", b.w);
        assert!(b.occupancy() > 0.05);
    }

    #[test]
    fn to_banded_rejects_silent_graphs() {
        let seq = Sequence::from_str("r", "ACGT", DNA).unwrap();
        let profile = Profile::from_sequence(&seq, DNA, 0.9);
        let g = Phmm::traditional(&profile, &TraditionalParams::default()).unwrap();
        assert!(g.to_banded().is_err());
    }

    #[test]
    fn pad_to_keeps_prefix_and_zeroes_rest() {
        let g = ec(10);
        let b = g.to_banded().unwrap();
        let p = b.pad_to(128, 32).unwrap();
        assert_eq!(p.n, 128);
        assert_eq!(p.w, 32);
        for j in 0..b.n {
            for w in 0..b.w {
                assert_eq!(p.a(j, w), b.a(j, w));
            }
        }
        assert!(p.a_band[b.n * 32..].iter().all(|&x| x == 0.0));
        assert_eq!(&p.f_init[..b.n], &b.f_init[..]);
        assert!(p.f_init[b.n..].iter().all(|&x| x == 0.0));
    }

    #[test]
    fn pad_to_rejects_shrinking() {
        let b = ec(20).to_banded().unwrap();
        assert!(b.pad_to(4, b.w).is_err());
        assert!(b.pad_to(b.n, 1).is_err());
    }

    #[test]
    fn update_from_banded_roundtrip() {
        let mut g = ec(15);
        let mut b = g.to_banded().unwrap();
        // Perturb and renormalize one row in band space.
        for w in 0..b.w {
            let v = b.a(0, w);
            if v > 0.0 {
                b.a_band[w] = v * 0.5;
            }
        }
        let s: f32 = (0..b.w).map(|w| b.a(0, w)).sum();
        for w in 0..b.w {
            b.a_band[w] /= s;
        }
        g.update_from_banded(&b).unwrap();
        let b2 = g.to_banded().unwrap();
        for w in 0..b.w {
            assert!((b2.a(0, w) - b.a(0, w)).abs() < 1e-6);
        }
        g.validate().unwrap();
    }
}
