//! Configuration files (a TOML subset: `key = value` lines with
//! `[section]` headers, `#` comments) and typed accessors.
//!
//! Used by the CLI so experiments are reproducible from checked-in
//! config files rather than long flag strings; every example ships one.

use std::collections::BTreeMap;
use std::path::Path;

use crate::error::{ApHmmError, Result};

/// A parsed configuration: `section.key -> value` strings with typed
/// getters.
#[derive(Clone, Debug, Default)]
pub struct Config {
    values: BTreeMap<String, String>,
}

impl Config {
    /// Parse configuration text.
    pub fn parse(text: &str, origin: &str) -> Result<Config> {
        let mut values = BTreeMap::new();
        let mut section = String::new();
        for (lineno, raw) in text.lines().enumerate() {
            let line = raw.split('#').next().unwrap_or("").trim();
            if line.is_empty() {
                continue;
            }
            if let Some(name) = line.strip_prefix('[').and_then(|s| s.strip_suffix(']')) {
                section = name.trim().to_string();
                continue;
            }
            let (k, v) = line.split_once('=').ok_or_else(|| ApHmmError::Parse {
                path: origin.into(),
                msg: format!("line {}: expected key = value", lineno + 1),
            })?;
            let key = if section.is_empty() {
                k.trim().to_string()
            } else {
                format!("{section}.{}", k.trim())
            };
            values.insert(key, v.trim().trim_matches('"').to_string());
        }
        Ok(Config { values })
    }

    /// Load from a file.
    pub fn load(path: &Path) -> Result<Config> {
        let text = std::fs::read_to_string(path)?;
        Config::parse(&text, &path.display().to_string())
    }

    /// Overlay `key=value` CLI overrides on top of the file values.
    pub fn override_with(&mut self, pairs: &[(String, String)]) {
        for (k, v) in pairs {
            self.values.insert(k.clone(), v.clone());
        }
    }

    /// Raw string value.
    pub fn get(&self, key: &str) -> Option<&str> {
        self.values.get(key).map(|s| s.as_str())
    }

    /// String with default.
    pub fn str_or(&self, key: &str, default: &str) -> String {
        self.get(key).unwrap_or(default).to_string()
    }

    /// Integer with default.
    pub fn usize_or(&self, key: &str, default: usize) -> Result<usize> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|_| {
                ApHmmError::Config(format!("{key}: expected integer, got {v:?}"))
            }),
        }
    }

    /// Float with default.
    pub fn f64_or(&self, key: &str, default: f64) -> Result<f64> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => {
                v.parse().map_err(|_| ApHmmError::Config(format!("{key}: expected float, got {v:?}")))
            }
        }
    }

    /// Bool with default (`true/false/1/0/yes/no`).
    pub fn bool_or(&self, key: &str, default: bool) -> Result<bool> {
        match self.get(key) {
            None => Ok(default),
            Some("true") | Some("1") | Some("yes") => Ok(true),
            Some("false") | Some("0") | Some("no") => Ok(false),
            Some(v) => Err(ApHmmError::Config(format!("{key}: expected bool, got {v:?}"))),
        }
    }

    /// All keys (diagnostics).
    pub fn keys(&self) -> impl Iterator<Item = &str> {
        self.values.keys().map(|s| s.as_str())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = "\
# top comment
seed = 42
[correction]
chunk_len = 650
filter = \"histogram\"
tol = 1e-3
multithread = yes
";

    #[test]
    fn parses_sections_and_types() {
        let c = Config::parse(SAMPLE, "mem").unwrap();
        assert_eq!(c.usize_or("seed", 0).unwrap(), 42);
        assert_eq!(c.usize_or("correction.chunk_len", 0).unwrap(), 650);
        assert_eq!(c.str_or("correction.filter", ""), "histogram");
        assert!((c.f64_or("correction.tol", 0.0).unwrap() - 1e-3).abs() < 1e-12);
        assert!(c.bool_or("correction.multithread", false).unwrap());
    }

    #[test]
    fn defaults_apply() {
        let c = Config::parse("", "mem").unwrap();
        assert_eq!(c.usize_or("nope", 7).unwrap(), 7);
        assert!(!c.bool_or("nope", false).unwrap());
    }

    #[test]
    fn overrides_win() {
        let mut c = Config::parse(SAMPLE, "mem").unwrap();
        c.override_with(&[("correction.chunk_len".into(), "150".into())]);
        assert_eq!(c.usize_or("correction.chunk_len", 0).unwrap(), 150);
    }

    #[test]
    fn bad_lines_rejected() {
        assert!(Config::parse("no equals sign", "mem").is_err());
        let c = Config::parse("x = abc", "mem").unwrap();
        assert!(c.usize_or("x", 0).is_err());
        assert!(c.bool_or("x", false).is_err());
    }
}
