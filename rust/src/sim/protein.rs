//! Pfam-like protein family generator.
//!
//! Substitutes for the Pfam database (19,632 pHMMs; families such as
//! Mitochondrial carrier PF00153 with 214,393 members, mean length 94.2).
//! Each family is generated as an ancestral sequence plus a per-family
//! mutation process; member sequences are noisy copies of the ancestor.
//! This preserves what drives the paper's protein-search workload:
//! many ~90-residue profiles over a 20-letter alphabet, with members that
//! score far above non-members.

use super::{ErrorProfile, XorShift};
use crate::seq::{Sequence, PROTEIN};

/// A generated protein family: ancestor plus member sequences.
#[derive(Clone, Debug)]
pub struct ProteinFamily {
    /// Family identifier (e.g. "FAM00042").
    pub id: String,
    /// Ancestral (consensus) sequence the family profile represents.
    pub ancestor: Sequence,
    /// Member sequences (mutated copies of the ancestor).
    pub members: Vec<Sequence>,
}

/// Parameters of the family generator.
#[derive(Clone, Copy, Debug)]
pub struct ProteinSimParams {
    /// Number of families to generate.
    pub n_families: usize,
    /// Mean ancestor length (Pfam-like default: 94).
    pub mean_len: usize,
    /// Members generated per family.
    pub members_per_family: usize,
    /// Per-residue divergence of members from the ancestor.
    pub divergence: f64,
}

impl Default for ProteinSimParams {
    fn default() -> Self {
        ProteinSimParams { n_families: 16, mean_len: 94, members_per_family: 8, divergence: 0.15 }
    }
}

/// Generate `params.n_families` independent families.
pub fn generate_families(rng: &mut XorShift, params: &ProteinSimParams) -> Vec<ProteinFamily> {
    (0..params.n_families)
        .map(|f| {
            let len = (params.mean_len as f64 * (0.7 + 0.6 * rng.next_f64())) as usize;
            let ancestor: Vec<u8> =
                (0..len.max(10)).map(|_| rng.below(PROTEIN.size()) as u8).collect();
            let ancestor = Sequence::from_symbols(format!("FAM{f:05}_anc"), ancestor);
            let profile = ErrorProfile {
                sub: params.divergence * 0.7,
                ins: params.divergence * 0.15,
                del: params.divergence * 0.15,
                ins_ext: 0.2,
            };
            let members = (0..params.members_per_family)
                .map(|m| {
                    let mut data = Vec::with_capacity(ancestor.len());
                    for &aa in &ancestor.data {
                        if rng.chance(profile.del) {
                            continue;
                        }
                        if rng.chance(profile.sub) {
                            data.push(rng.below(PROTEIN.size()) as u8);
                        } else {
                            data.push(aa);
                        }
                        if rng.chance(profile.ins) {
                            data.push(rng.below(PROTEIN.size()) as u8);
                        }
                    }
                    Sequence::from_symbols(format!("FAM{f:05}_m{m}"), data)
                })
                .collect();
            ProteinFamily { id: format!("FAM{f:05}"), ancestor, members }
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn family_counts_and_lengths() {
        let mut rng = XorShift::new(8);
        let params = ProteinSimParams::default();
        let fams = generate_families(&mut rng, &params);
        assert_eq!(fams.len(), params.n_families);
        for fam in &fams {
            assert_eq!(fam.members.len(), params.members_per_family);
            assert!(fam.ancestor.len() >= 10);
            for m in &fam.members {
                assert!(m.data.iter().all(|&s| (s as usize) < PROTEIN.size()));
                // Members stay within ~40% length of the ancestor.
                let ratio = m.len() as f64 / fam.ancestor.len() as f64;
                assert!((0.5..1.6).contains(&ratio), "ratio={ratio}");
            }
        }
    }

    #[test]
    fn members_resemble_ancestor() {
        let mut rng = XorShift::new(9);
        let params = ProteinSimParams { divergence: 0.1, ..Default::default() };
        let fams = generate_families(&mut rng, &params);
        let fam = &fams[0];
        // Identity at aligned prefix positions should be far above the
        // 1/20 random baseline.
        let m = &fam.members[0];
        let n = m.len().min(fam.ancestor.len());
        let same = (0..n).filter(|&i| m.data[i] == fam.ancestor.data[i]).count();
        assert!(same as f64 / n as f64 > 0.4);
    }
}
