//! Simulation substrates.
//!
//! The paper evaluates on proprietary-scale real data (PacBio E. coli
//! reads SAMN06173305 assembled with minimap2+miniasm; the Pfam
//! database).  Neither the data nor the tools are available here, so this
//! module provides the synthetic equivalents documented in DESIGN.md:
//! a reference-genome generator, a PacBio-like long-read simulator with
//! realistic substitution/insertion/deletion rates, and a protein-family
//! generator that mimics Pfam-style families (ancestral sequence +
//! per-member mutations).

mod genome;
mod protein;
mod reads;
mod rng;

pub use genome::generate_genome;
pub use protein::{generate_families, ProteinFamily, ProteinSimParams};
pub use reads::{
    simulate_read, simulate_reads, simulate_ultralong_read, ErrorProfile, SimulatedRead,
};
pub use rng::XorShift;
