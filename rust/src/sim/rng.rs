//! Deterministic xorshift* PRNG.
//!
//! The offline registry has no `rand` crate, so the simulator and the
//! property-test helper share this minimal generator.  xorshift64* passes
//! the statistical bar needed here (workload generation, not crypto) and
//! makes every experiment bit-reproducible from its seed.

/// 64-bit xorshift* generator.
#[derive(Clone, Debug)]
pub struct XorShift {
    state: u64,
}

impl XorShift {
    /// Seeded construction; a zero seed is remapped to a fixed constant.
    pub fn new(seed: u64) -> Self {
        XorShift { state: if seed == 0 { 0x9E37_79B9_7F4A_7C15 } else { seed } }
    }

    /// Next raw 64-bit value.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let mut x = self.state;
        x ^= x >> 12;
        x ^= x << 25;
        x ^= x >> 27;
        self.state = x;
        x.wrapping_mul(0x2545_F491_4F6C_DD1D)
    }

    /// Uniform f64 in [0, 1).
    #[inline]
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 / (1u64 << 53) as f64
    }

    /// Uniform f32 in [0, 1).
    #[inline]
    pub fn next_f32(&mut self) -> f32 {
        self.next_f64() as f32
    }

    /// Uniform integer in [0, n).
    #[inline]
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        (self.next_u64() % n as u64) as usize
    }

    /// Uniform integer in [lo, hi).
    #[inline]
    pub fn range(&mut self, lo: usize, hi: usize) -> usize {
        lo + self.below(hi - lo)
    }

    /// Bernoulli trial with probability `p`.
    #[inline]
    pub fn chance(&mut self, p: f64) -> bool {
        self.next_f64() < p
    }

    /// Sample an index from unnormalized non-negative weights.
    pub fn weighted(&mut self, weights: &[f64]) -> usize {
        let total: f64 = weights.iter().sum();
        debug_assert!(total > 0.0);
        let mut x = self.next_f64() * total;
        for (i, &w) in weights.iter().enumerate() {
            x -= w;
            if x <= 0.0 {
                return i;
            }
        }
        weights.len() - 1
    }

    /// Fork a decorrelated child generator (for per-worker streams).
    pub fn fork(&mut self) -> XorShift {
        XorShift::new(self.next_u64() ^ 0xA076_1D64_78BD_642F)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = XorShift::new(42);
        let mut b = XorShift::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = XorShift::new(7);
        for _ in 0..10_000 {
            let x = r.next_f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn below_bounds() {
        let mut r = XorShift::new(9);
        for _ in 0..10_000 {
            assert!(r.below(7) < 7);
        }
    }

    #[test]
    fn chance_rate_roughly_correct() {
        let mut r = XorShift::new(11);
        let hits = (0..100_000).filter(|_| r.chance(0.3)).count();
        assert!((25_000..35_000).contains(&hits), "hits={hits}");
    }

    #[test]
    fn weighted_prefers_heavy_bins() {
        let mut r = XorShift::new(13);
        let mut counts = [0usize; 3];
        for _ in 0..30_000 {
            counts[r.weighted(&[1.0, 2.0, 7.0])] += 1;
        }
        assert!(counts[2] > counts[1] && counts[1] > counts[0], "{counts:?}");
    }

    #[test]
    fn fork_decorrelates() {
        let mut a = XorShift::new(5);
        let mut b = a.fork();
        let same = (0..100).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 5);
    }

    #[test]
    fn zero_seed_is_valid() {
        let mut r = XorShift::new(0);
        assert_ne!(r.next_u64(), 0);
    }
}
