//! PacBio-like long-read simulator.
//!
//! Substitutes for the paper's real sample (SAMN06173305: 163,482 PacBio
//! reads, mean length 5,128, ~10x coverage of E. coli).  The default
//! error profile follows published PacBio CLR statistics: ~15% total
//! error dominated by insertions (sub ≈ 1.5%, ins ≈ 9%, del ≈ 4.5%).
//! Because reads are simulated, their true origin is known exactly —
//! the mapper (`crate::mapper`) is still exercised end-to-end and its
//! output validated against this ground truth in integration tests.

use super::XorShift;
use crate::seq::Sequence;

/// Per-base error rates of the simulated sequencer.
#[derive(Clone, Copy, Debug)]
pub struct ErrorProfile {
    /// Substitution probability per base.
    pub sub: f64,
    /// Insertion-open probability per base.
    pub ins: f64,
    /// Deletion probability per base.
    pub del: f64,
    /// Probability of extending an open insertion.
    pub ins_ext: f64,
}

impl ErrorProfile {
    /// PacBio CLR-like profile (the paper's error-correction input).
    pub fn pacbio() -> Self {
        ErrorProfile { sub: 0.015, ins: 0.09, del: 0.045, ins_ext: 0.3 }
    }

    /// Error-free reads (for accuracy-oracle tests).
    pub fn perfect() -> Self {
        ErrorProfile { sub: 0.0, ins: 0.0, del: 0.0, ins_ext: 0.0 }
    }

    /// Nanopore-like profile: ~12% total error, deletion-dominated
    /// (roughly R9-era ONT statistics: sub ≈ 3%, ins ≈ 3%, del ≈ 6%),
    /// the regime where reads run to hundreds of kilobases and a full
    /// T×states forward matrix stops fitting in memory — the input the
    /// checkpointed scratch mode exists for.
    pub fn nanopore() -> Self {
        ErrorProfile { sub: 0.03, ins: 0.03, del: 0.06, ins_ext: 0.15 }
    }

    /// Total per-base error rate (approximate, ignoring extension).
    pub fn total(&self) -> f64 {
        self.sub + self.ins + self.del
    }
}

/// A simulated read together with its ground-truth origin.
#[derive(Clone, Debug)]
pub struct SimulatedRead {
    /// The (noisy) read sequence.
    pub seq: Sequence,
    /// True start position on the reference.
    pub ref_start: usize,
    /// True end position (exclusive) on the reference.
    pub ref_end: usize,
    /// Number of injected errors.
    pub n_errors: usize,
}

/// Simulate one read of roughly `len` reference bases starting at `start`.
pub fn simulate_read(
    rng: &mut XorShift,
    reference: &Sequence,
    start: usize,
    len: usize,
    profile: &ErrorProfile,
    id: usize,
) -> SimulatedRead {
    let end = (start + len).min(reference.len());
    let mut data = Vec::with_capacity(len + len / 4);
    let mut n_errors = 0usize;
    for pos in start..end {
        let base = reference.data[pos];
        // Deletion: skip the base entirely.
        if rng.chance(profile.del) {
            n_errors += 1;
            continue;
        }
        // Substitution: emit one of the other three bases.
        if rng.chance(profile.sub) {
            let mut b = rng.below(4) as u8;
            if b == base {
                b = (b + 1) % 4;
            }
            data.push(b);
            n_errors += 1;
        } else {
            data.push(base);
        }
        // Insertion burst after the base.
        if rng.chance(profile.ins) {
            loop {
                data.push(rng.below(4) as u8);
                n_errors += 1;
                if !rng.chance(profile.ins_ext) {
                    break;
                }
            }
        }
    }
    SimulatedRead {
        seq: Sequence::from_symbols(format!("read{id}"), data),
        ref_start: start,
        ref_end: end,
        n_errors,
    }
}

/// Simulate reads to a target depth of coverage.
///
/// Read lengths are drawn from a clipped normal-ish distribution around
/// `mean_len` (the paper's sample: mean 5,128) and starts are uniform.
pub fn simulate_reads(
    rng: &mut XorShift,
    reference: &Sequence,
    coverage: f64,
    mean_len: usize,
    profile: &ErrorProfile,
) -> Vec<SimulatedRead> {
    let genome_len = reference.len();
    let target_bases = (genome_len as f64 * coverage) as usize;
    let mut reads = Vec::new();
    let mut emitted = 0usize;
    let mut id = 0usize;
    while emitted < target_bases {
        // Sum of three uniforms ~ triangular-ish around mean_len.
        let jitter: f64 = (0..3).map(|_| rng.next_f64()).sum::<f64>() / 3.0;
        let len = ((mean_len as f64) * (0.5 + jitter)).max(50.0) as usize;
        let start = if genome_len > len { rng.below(genome_len - len) } else { 0 };
        let read = simulate_read(rng, reference, start, len, profile, id);
        emitted += read.seq.len();
        reads.push(read);
        id += 1;
    }
    reads
}

/// Simulate one ultra-long nanopore-like read: `len` reference bases
/// (default nanopore "ultralong" scale is 10⁵) starting at `start`,
/// under [`ErrorProfile::nanopore`].  A convenience wrapper for
/// long-read stress tests and the serve smoke: at 100 kb the full
/// forward matrix of even a small chunk profile is hundreds of
/// megabytes, so these reads exercise [`checkpointed scratch`] rather
/// than fitting the full-matrix path.
///
/// [`checkpointed scratch`]: crate::baumwelch::ScratchMode::Checkpointed
pub fn simulate_ultralong_read(
    rng: &mut XorShift,
    reference: &Sequence,
    start: usize,
    len: usize,
    id: usize,
) -> SimulatedRead {
    simulate_read(rng, reference, start, len, &ErrorProfile::nanopore(), id)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::generate_genome;

    #[test]
    fn perfect_profile_reproduces_reference() {
        let mut rng = XorShift::new(4);
        let genome = generate_genome(&mut rng, 2000);
        let read = simulate_read(&mut rng, &genome, 100, 500, &ErrorProfile::perfect(), 0);
        assert_eq!(read.seq.data, &genome.data[100..600]);
        assert_eq!(read.n_errors, 0);
    }

    #[test]
    fn pacbio_profile_error_rate_in_band() {
        let mut rng = XorShift::new(5);
        let genome = generate_genome(&mut rng, 20_000);
        let read = simulate_read(&mut rng, &genome, 0, 20_000, &ErrorProfile::pacbio(), 0);
        let rate = read.n_errors as f64 / 20_000.0;
        // sub + del + ins/(1-ext) ≈ 0.015 + 0.045 + 0.1286 ≈ 0.19
        assert!((0.12..0.27).contains(&rate), "rate={rate}");
    }

    #[test]
    fn coverage_target_met() {
        let mut rng = XorShift::new(6);
        let genome = generate_genome(&mut rng, 10_000);
        let reads = simulate_reads(&mut rng, &genome, 8.0, 1000, &ErrorProfile::pacbio());
        let total: usize = reads.iter().map(|r| r.seq.len()).sum();
        assert!(total >= 80_000, "total={total}");
        for r in &reads {
            assert!(r.ref_end <= genome.len());
            assert!(r.ref_start < r.ref_end);
        }
    }

    #[test]
    fn nanopore_profile_error_rate_in_band() {
        let mut rng = XorShift::new(8);
        let genome = generate_genome(&mut rng, 30_000);
        let read = simulate_ultralong_read(&mut rng, &genome, 0, 30_000, 0);
        let rate = read.n_errors as f64 / 30_000.0;
        // sub + del + ins/(1-ext) ≈ 0.03 + 0.06 + 0.035 ≈ 0.125
        assert!((0.08..0.18).contains(&rate), "rate={rate}");
        // Deletion-dominated: the read comes out shorter than its span.
        assert!(read.seq.len() < 30_000);
    }

    #[test]
    fn read_clipped_at_genome_end() {
        let mut rng = XorShift::new(7);
        let genome = generate_genome(&mut rng, 300);
        let read = simulate_read(&mut rng, &genome, 250, 500, &ErrorProfile::perfect(), 0);
        assert_eq!(read.ref_end, 300);
        assert_eq!(read.seq.len(), 50);
    }
}
