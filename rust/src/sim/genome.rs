//! Reference genome generation with realistic local structure.

use super::XorShift;
use crate::seq::{Sequence, DNA};

/// Generate a random genome of `len` bases.
///
/// Besides i.i.d. bases, a small fraction of low-complexity repeats is
/// injected (homopolymer runs and short tandem repeats) so that error
/// correction sees the graph topologies that make real assemblies hard —
/// insertion chains in homopolymers are exactly where the EC design's
/// bounded insertion states matter.
pub fn generate_genome(rng: &mut XorShift, len: usize) -> Sequence {
    let mut data = Vec::with_capacity(len);
    while data.len() < len {
        if rng.chance(0.02) {
            // Homopolymer run of 4-12 bases.
            let base = rng.below(4) as u8;
            let run = rng.range(4, 13);
            for _ in 0..run.min(len - data.len()) {
                data.push(base);
            }
        } else if rng.chance(0.01) {
            // Short tandem repeat: unit of 2-5 bases, 3-6 copies.
            let unit: Vec<u8> = (0..rng.range(2, 6)).map(|_| rng.below(4) as u8).collect();
            let copies = rng.range(3, 7);
            for _ in 0..copies {
                for &b in &unit {
                    if data.len() < len {
                        data.push(b);
                    }
                }
            }
        } else {
            data.push(rng.below(4) as u8);
        }
    }
    data.truncate(len);
    debug_assert!(data.iter().all(|&b| (b as usize) < DNA.size()));
    Sequence::from_symbols("genome", data)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn exact_length_and_valid_symbols() {
        let mut rng = XorShift::new(1);
        for len in [0, 1, 100, 5000] {
            let g = generate_genome(&mut rng, len);
            assert_eq!(g.len(), len);
            assert!(g.data.iter().all(|&b| b < 4));
        }
    }

    #[test]
    fn base_composition_roughly_uniform() {
        let mut rng = XorShift::new(2);
        let g = generate_genome(&mut rng, 100_000);
        let mut counts = [0usize; 4];
        for &b in &g.data {
            counts[b as usize] += 1;
        }
        for &c in &counts {
            assert!((15_000..35_000).contains(&c), "{counts:?}");
        }
    }

    #[test]
    fn deterministic_by_seed() {
        let a = generate_genome(&mut XorShift::new(3), 1000);
        let b = generate_genome(&mut XorShift::new(3), 1000);
        assert_eq!(a.data, b.data);
    }
}
