//! `aphmm` — command-line launcher for the ApHMM reproduction.
//!
//! Subcommands:
//!   simulate   generate a synthetic genome + PacBio-like reads (FASTA)
//!   correct    Apollo-style assembly error correction
//!   search     protein family search over a generated family database
//!   align      hmmalign-style MSA against a family profile
//!   serve      long-lived multi-tenant server (stdin or TCP protocol)
//!   accel      query the accelerator model (cycles/energy/area)
//!   runtime    list and smoke-run the AOT artifacts via PJRT
//!
//! Every subcommand accepts `--config <file>` (see `examples/*.toml`)
//! plus `--set key=value` overrides.

use std::path::{Path, PathBuf};
use std::process::ExitCode;
use std::time::Instant;

use aphmm::accel::{self, AccelConfig, Workload};
use aphmm::apps::{self, CorrectionConfig, MsaReport, SearchConfig};
use aphmm::baumwelch::{EngineKind, FilterConfig, ScratchMode, TrainConfig, TrainMode};
use aphmm::config::Config;
use aphmm::error::{ApHmmError, Result};
use aphmm::io;
use aphmm::phmm::{EcDesignParams, Phmm, Profile, TraditionalParams};
use aphmm::seq::{Alphabet, Sequence, DNA, PROTEIN};
use aphmm::server::{
    self, profile_hash, Request, ResponseBody, Server, ServerConfig, SessionEnd, TenantQuota,
};
use aphmm::sim::{self, XorShift};

fn usage() -> String {
    let engines = EngineKind::NAMES.join("|");
    let modes = TrainMode::NAMES.join("|");
    format!(
        "usage: aphmm <simulate|correct|search|align|serve|profile|accel|runtime> \
[--config FILE] [--set k=v ...]
  simulate --out-dir DIR [--set sim.genome_len=N --set sim.coverage=X]
  correct  --assembly A.fasta --reads R.fasta --out C.fasta [--engine {engines}]
  search   [--engine E] [--set search.n_families=N --set search.queries=N]
  align    [--engine E] [--set msa.n_seqs=N]
  serve    [--port N] [--engine E] [--set serve.workers=N --set serve.queue_depth=N
           --set serve.tenant_max_queued=N --set serve.tenant_max_in_flight=N
           --set serve.scratch_mode=full|checkpointed|auto
           --set serve.max_scratch_bytes=N]
           (no --port: newline-delimited protocol on stdin/stdout;
            see rust/src/server/README.md for the request grammar)
  profile  --seq ACGT... | --fasta F.fasta [--out P.aphmm]
           (build an EC-design profile and write it in the .aphmm wire
            format accepted by the serve `register-profile` command)
  accel    [--set accel.pes=N --set accel.chunk=N]
  runtime  --artifacts DIR

  --engine selects the Baum-Welch ExpectationEngine backend, one of
  {engines} (default: sparse for correct/search/serve, banded for
  align; also settable via --set <section>.engine=NAME)

  --mode selects the training schedule, one of {modes} (default:
  batch; auto picks minibatch for large corpora).  The minibatch
  schedule also reads --set <section>.minibatch=N (reads per
  minibatch, default 64) and --set <section>.seed=N (shuffle seed,
  default 1); the same keys are accepted by correct and serve."
    )
}

/// Minimal argument parser: positional subcommand + `--flag value` pairs.
struct Args {
    cmd: String,
    flags: Vec<(String, String)>,
}

impl Args {
    fn parse() -> Option<Args> {
        let mut it = std::env::args().skip(1);
        let cmd = it.next()?;
        let mut flags = Vec::new();
        let mut key: Option<String> = None;
        for tok in it {
            if let Some(k) = tok.strip_prefix("--") {
                if let Some(prev) = key.take() {
                    flags.push((prev, String::new()));
                }
                key = Some(k.to_string());
            } else if let Some(k) = key.take() {
                flags.push((k, tok));
            } else {
                return None;
            }
        }
        if let Some(prev) = key.take() {
            flags.push((prev, String::new()));
        }
        Some(Args { cmd, flags })
    }

    fn get(&self, name: &str) -> Option<&str> {
        self.flags.iter().rev().find(|(k, _)| k == name).map(|(_, v)| v.as_str())
    }

    fn config(&self) -> Result<Config> {
        let mut cfg = match self.get("config") {
            Some(path) => Config::load(Path::new(path))?,
            None => Config::default(),
        };
        let overrides: Vec<(String, String)> = self
            .flags
            .iter()
            .filter(|(k, _)| k == "set")
            .filter_map(|(_, v)| v.split_once('=').map(|(a, b)| (a.to_string(), b.to_string())))
            .collect();
        cfg.override_with(&overrides);
        Ok(cfg)
    }
}

/// Resolve the engine backend: `--engine NAME` wins, then
/// `<section>.engine` from the config file, then `default_kind`.
fn engine_from(
    args: &Args,
    cfg: &Config,
    section: &str,
    default_kind: EngineKind,
) -> Result<EngineKind> {
    let name = match args.get("engine") {
        Some(v) if !v.is_empty() => v.to_string(),
        _ => cfg.str_or(&format!("{section}.engine"), default_kind.name()),
    };
    EngineKind::parse(&name).ok_or_else(|| {
        ApHmmError::Config(format!(
            "unknown engine {name:?} (expected {})",
            EngineKind::NAMES.join(" | ")
        ))
    })
}

/// Resolve the training schedule: `--mode NAME` wins, then
/// `<section>.mode` from the config file, then `default`.
fn mode_from(args: &Args, cfg: &Config, section: &str, default: TrainMode) -> Result<TrainMode> {
    let name = match args.get("mode") {
        Some(v) if !v.is_empty() => v.to_string(),
        _ => cfg.str_or(&format!("{section}.mode"), default.name()),
    };
    TrainMode::parse(&name).ok_or_else(|| {
        ApHmmError::Config(format!(
            "unknown training mode {name:?} (expected {})",
            TrainMode::NAMES.join(" | ")
        ))
    })
}

/// Resolve `<section>.scratch_mode` (full | checkpointed | auto; the
/// engine-internal forward-scratch policy for ultra-long reads).
fn scratch_mode_from(cfg: &Config, section: &str, default: ScratchMode) -> Result<ScratchMode> {
    let name = cfg.str_or(&format!("{section}.scratch_mode"), default.name());
    ScratchMode::parse(&name).ok_or_else(|| {
        ApHmmError::Config(format!(
            "unknown scratch_mode {name:?} (expected {})",
            ScratchMode::NAMES.join(" | ")
        ))
    })
}

fn filter_from(cfg: &Config, section: &str) -> Result<FilterConfig> {
    let kind = cfg.str_or(&format!("{section}.filter"), "histogram");
    let size = cfg.usize_or(&format!("{section}.filter_size"), 500)?;
    // 128 exponent bins, matching FilterConfig::histogram_default: the
    // paper's 16 linear bins collapse under exponent binning (see the
    // baumwelch::filter module docs — everything below 2^-16 of the
    // row max would land in one bin).
    let bins = cfg.usize_or(&format!("{section}.filter_bins"), 128)?;
    let filter = match kind.as_str() {
        "none" => FilterConfig::None,
        "sort" => FilterConfig::Sort { size },
        _ => FilterConfig::Histogram { size, bins },
    };
    // `filter_size = 0` is a clean config error here, not a panic (or
    // an empty keep-set) deep inside training.
    filter.validate()?;
    Ok(filter)
}

fn cmd_simulate(args: &Args) -> Result<()> {
    let cfg = args.config()?;
    let out_dir = PathBuf::from(args.get("out-dir").unwrap_or("simdata"));
    std::fs::create_dir_all(&out_dir)?;
    let seed = cfg.usize_or("sim.seed", 42)? as u64;
    let genome_len = cfg.usize_or("sim.genome_len", 100_000)?;
    let coverage = cfg.f64_or("sim.coverage", 10.0)?;
    let mean_len = cfg.usize_or("sim.read_len", 5128)?;
    let mut rng = XorShift::new(seed);
    let genome = sim::generate_genome(&mut rng, genome_len);
    let reads = sim::simulate_reads(&mut rng, &genome, coverage, mean_len, &sim::ErrorProfile::pacbio());
    let mut gf = std::fs::File::create(out_dir.join("genome.fasta"))?;
    io::write_fasta(&mut gf, &[genome], DNA)?;
    let seqs: Vec<_> = reads.iter().map(|r| r.seq.clone()).collect();
    let mut rf = std::fs::File::create(out_dir.join("reads.fasta"))?;
    io::write_fasta(&mut rf, &seqs, DNA)?;
    println!(
        "wrote {}/genome.fasta ({genome_len} bases) and {}/reads.fasta ({} reads)",
        out_dir.display(),
        out_dir.display(),
        seqs.len()
    );
    Ok(())
}

fn cmd_correct(args: &Args) -> Result<()> {
    let cfg = args.config()?;
    let assembly_path = args.get("assembly").unwrap_or("simdata/genome.fasta").to_string();
    let reads_path = args.get("reads").unwrap_or("simdata/reads.fasta").to_string();
    let out_path = args.get("out").unwrap_or("corrected.fasta").to_string();
    let assemblies = io::read_fasta(Path::new(&assembly_path), DNA)?;
    let reads = io::read_fasta(Path::new(&reads_path), DNA)?;
    let defaults = CorrectionConfig::default();
    let correction = CorrectionConfig {
        chunk_len: cfg.usize_or("correction.chunk_len", 650)?,
        max_iters: cfg.usize_or("correction.max_iters", 2)?,
        filter: filter_from(&cfg, "correction")?,
        engine: engine_from(args, &cfg, "correction", EngineKind::Sparse)?,
        scratch_mode: scratch_mode_from(&cfg, "correction", defaults.scratch_mode)?,
        max_scratch_bytes: cfg
            .usize_or("correction.max_scratch_bytes", defaults.max_scratch_bytes)?,
        mode: mode_from(args, &cfg, "correction", defaults.mode)?,
        seed: cfg.usize_or("correction.seed", defaults.seed as usize)? as u64,
        ..defaults
    };
    let mut corrected = Vec::new();
    for assembly in &assemblies {
        let report = apps::correct_assembly(assembly, &reads, &correction)?;
        println!(
            "{}: {} chunks ({} trained), {} reads mapped, BW fraction {:.1}%",
            assembly.id,
            report.chunks_total,
            report.chunks_trained,
            report.reads_mapped,
            report.timings.bw_fraction() * 100.0
        );
        corrected.push(report.corrected);
    }
    let mut out = std::fs::File::create(Path::new(&out_path))?;
    io::write_fasta(&mut out, &corrected, DNA)?;
    println!("wrote {out_path}");
    Ok(())
}

/// Build a [`ServerConfig`] from a config-file `section` (the serving
/// entry point shared by `search`, `align`, and `serve`).
fn server_config(
    args: &Args,
    cfg: &Config,
    section: &str,
    default_engine: EngineKind,
    alphabet: Alphabet,
) -> Result<ServerConfig> {
    let engine = engine_from(args, cfg, section, default_engine)?;
    if engine == EngineKind::Xla {
        return Err(ApHmmError::Config(
            "the XLA engine is device-backed; the server supports sparse | banded | reference"
                .into(),
        ));
    }
    let defaults = ServerConfig::default();
    // Scoring stays exact unless a filter is explicitly configured
    // (matches the search app's historical FilterConfig::None default).
    let filter = match cfg.get(&format!("{section}.filter")) {
        Some(_) => filter_from(cfg, section)?,
        None => FilterConfig::None,
    };
    let train = TrainConfig {
        max_iters: cfg.usize_or(&format!("{section}.max_iters"), 2)?,
        n_workers: cfg.usize_or(&format!("{section}.estep_workers"), 1)?,
        filter,
        engine,
        // `train.max_scratch_bytes` stays 0 here: `Server::start`
        // propagates the serve-level budget below into it, so one key
        // governs both `auto` resolution and admission refusal.
        scratch_mode: scratch_mode_from(cfg, section, ScratchMode::Full)?,
        mode: mode_from(args, cfg, section, TrainMode::Batch)?,
        minibatch: cfg.usize_or(&format!("{section}.minibatch"), 64)?,
        seed: cfg.usize_or(&format!("{section}.seed"), 1)? as u64,
        ..Default::default()
    };
    let tenant_quota = TenantQuota {
        max_queued: cfg.usize_or(
            &format!("{section}.tenant_max_queued"),
            defaults.tenant_quota.max_queued,
        )?,
        max_in_flight: cfg.usize_or(
            &format!("{section}.tenant_max_in_flight"),
            defaults.tenant_quota.max_in_flight,
        )?,
    };
    // Like filter_size, a zero cap is a clean config error rather than
    // the queue's silent defensive clamp to 1 (a 0 in-flight cap would
    // otherwise deadlock consumers).
    if tenant_quota.max_queued == 0 || tenant_quota.max_in_flight == 0 {
        return Err(ApHmmError::Config(
            "tenant_max_queued / tenant_max_in_flight must be >= 1 \
             (omit the key for unlimited)"
                .into(),
        ));
    }
    let shed_fraction =
        cfg.f64_or(&format!("{section}.shed_fraction"), defaults.shed_fraction)?;
    if !(0.0..=1.0).contains(&shed_fraction) {
        return Err(ApHmmError::Config(
            "shed_fraction must be in [0, 1] (0 disables load shedding)".into(),
        ));
    }
    Ok(ServerConfig {
        n_workers: cfg.usize_or(&format!("{section}.workers"), defaults.n_workers)?,
        queue_depth: cfg.usize_or(&format!("{section}.queue_depth"), defaults.queue_depth)?,
        cache_capacity: cfg
            .usize_or(&format!("{section}.cache_capacity"), defaults.cache_capacity)?,
        microbatch: cfg.usize_or(&format!("{section}.microbatch"), defaults.microbatch)?,
        max_hits: cfg.usize_or(&format!("{section}.max_hits"), defaults.max_hits)?,
        tenant_quota,
        max_profile_bytes: cfg.usize_or(
            &format!("{section}.max_profile_bytes"),
            defaults.max_profile_bytes,
        )?,
        max_profiles: cfg.usize_or(&format!("{section}.max_profiles"), defaults.max_profiles)?,
        max_profiles_per_tenant: cfg.usize_or(
            &format!("{section}.max_profiles_per_tenant"),
            defaults.max_profiles_per_tenant,
        )?,
        shed_fraction,
        read_timeout_ms: cfg
            .usize_or(&format!("{section}.read_timeout_ms"), defaults.read_timeout_ms as usize)?
            as u64,
        idle_timeout_ms: cfg
            .usize_or(&format!("{section}.idle_timeout_ms"), defaults.idle_timeout_ms as usize)?
            as u64,
        slow_request_ms: cfg
            .usize_or(&format!("{section}.slow_request_ms"), defaults.slow_request_ms as usize)?
            as u64,
        max_scratch_bytes: cfg
            .usize_or(&format!("{section}.max_scratch_bytes"), defaults.max_scratch_bytes)?,
        engine,
        train,
        alphabet,
        ..defaults
    })
}

fn cmd_search(args: &Args) -> Result<()> {
    let cfg = args.config()?;
    let seed = cfg.usize_or("search.seed", 7)? as u64;
    let n_families = cfg.usize_or("search.n_families", 64)?;
    let n_queries = cfg.usize_or("search.queries", 16)?;
    let mut rng = XorShift::new(seed);
    let params = sim::ProteinSimParams { n_families, ..Default::default() };
    let families = sim::generate_families(&mut rng, &params);
    let search_cfg = SearchConfig::default();

    // Route through the serving layer: one profile per family in the
    // registry, every query a typed Search request through the bounded
    // queue — repeated queries share the frozen coefficient tables via
    // the cross-request cache.  The hmmsearch screening defaults are
    // restored (k-mer pre-filter + posterior pass on top hits), and the
    // cache is sized to hold every family: Search scans the registry in
    // order, the LRU worst case for an undersized cache.
    let mut scfg = server_config(args, &cfg, "search", EngineKind::Sparse, PROTEIN)?;
    scfg.prefilter_k = search_cfg.prefilter_k;
    scfg.prefilter_min_frac = search_cfg.prefilter_min_frac;
    scfg.posterior_hits = search_cfg.posterior_hits;
    scfg.cache_capacity = scfg.cache_capacity.max(n_families + 4);
    let mut server = Server::start(scfg);
    for fam in &families {
        let profile = Profile::from_members(&fam.members, fam.ancestor.len(), PROTEIN, 0.5);
        let phmm =
            Phmm::traditional(&profile, &search_cfg.params)?.fold_silent(search_cfg.fold_depth)?;
        server.register_profile(&fam.id, phmm);
    }
    let mut correct = 0usize;
    for q in 0..n_queries {
        let fam = &families[q % families.len()];
        let query = &fam.members[q % fam.members.len()];
        let resp = server.submit(None, Request::Search { read: query.clone() })?.wait();
        let (top, scored) = match resp.body {
            ResponseBody::Search { hits, scored } => {
                (hits.first().map(|h| h.profile.clone()).unwrap_or_default(), scored)
            }
            ResponseBody::Error { message } => return Err(ApHmmError::Config(message)),
            _ => unreachable!("search request answered with a non-search body"),
        };
        if top == fam.id {
            correct += 1;
        }
        println!(
            "query {:<16} -> {:<10} (scored {}/{} families)",
            query.id,
            top,
            scored,
            server.registry().len()
        );
    }
    println!("top-1 accuracy: {correct}/{n_queries}");
    let c = server.cache_stats();
    println!(
        "prepared cache: {} hits, {} misses, {} evictions (cross-request reuse)",
        c.hits, c.misses, c.evictions
    );
    server.shutdown(true);
    Ok(())
}

fn cmd_align(args: &Args) -> Result<()> {
    let cfg = args.config()?;
    let seed = cfg.usize_or("msa.seed", 11)? as u64;
    let n_seqs = cfg.usize_or("msa.n_seqs", 24)?;
    let mut rng = XorShift::new(seed);
    let params = sim::ProteinSimParams {
        n_families: 1,
        members_per_family: n_seqs,
        ..Default::default()
    };
    let fam = sim::generate_families(&mut rng, &params).remove(0);

    let mut report = MsaReport::default();
    // Profile construction + registration is the non-Baum-Welch part of
    // the split this command reports.
    let t0 = Instant::now();
    let profile = Profile::from_members(&fam.members, fam.ancestor.len(), PROTEIN, 0.5);
    let phmm = Phmm::traditional(&profile, &TraditionalParams::default())?.fold_silent(4)?;
    report.n_columns = apps::profile_columns(&phmm);

    // Route through the serving layer: the family profile is
    // registered once, each member is a typed Align request, and every
    // decode after the first reuses the cached frozen tables.
    let mut server = Server::start(server_config(args, &cfg, "msa", EngineKind::Banded, PROTEIN)?);
    server.register_profile(&fam.id, phmm);
    report.timings.other_ns += t0.elapsed().as_nanos();

    let tickets: Vec<_> = fam
        .members
        .iter()
        .map(|member| {
            server.submit(None, Request::Align { profile: fam.id.clone(), read: member.clone() })
        })
        .collect::<Result<_>>()?;
    for ticket in tickets {
        let resp = ticket.wait();
        let t1 = Instant::now();
        match resp.body {
            ResponseBody::Align { row, .. } => {
                report.timings.forward_ns += resp.stats.forward_ns;
                report.timings.backward_update_ns += resp.stats.backward_update_ns;
                report.rows.push(row);
            }
            _ => report.skipped += 1,
        }
        report.timings.other_ns += t1.elapsed().as_nanos();
    }
    println!(
        "aligned {}/{} sequences to {} columns; identity {:.1}%; BW fraction {:.1}%",
        report.rows.len(),
        n_seqs,
        report.n_columns,
        apps::msa_identity(&report) * 100.0,
        report.timings.bw_fraction() * 100.0
    );
    let c = server.cache_stats();
    println!("prepared cache: {} hits, {} misses", c.hits, c.misses);
    server.shutdown(true);
    Ok(())
}

fn cmd_serve(args: &Args) -> Result<()> {
    let cfg = args.config()?;
    let alphabet = Alphabet::by_name(&cfg.str_or("serve.alphabet", "dna"))?;
    let scfg = server_config(args, &cfg, "serve", EngineKind::Sparse, alphabet)?;
    let mut server = Server::start(scfg);
    match args.get("port") {
        Some(port) if !port.is_empty() => {
            let port: u16 = port
                .parse()
                .map_err(|_| ApHmmError::Config(format!("invalid port {port:?}")))?;
            eprintln!("aphmm serve: listening on 127.0.0.1:{port} (send `shutdown` to stop)");
            server::serve_tcp(&server, port)?;
        }
        _ => {
            let end = server::serve_stdio(&server)?;
            if end == SessionEnd::Eof {
                eprintln!("aphmm serve: stdin closed, draining");
            }
        }
    }
    server.shutdown(true);
    // Shutdown hook: flush the retained trace timelines so traced
    // sessions leave a post-mortem record even when nobody issued
    // `trace-dump` over the wire.
    for line in server.trace_dump() {
        eprintln!("aphmm trace: {line}");
    }
    eprintln!("aphmm serve: {}", server.stats_line());
    Ok(())
}

/// Build an EC-design profile from a reference sequence and persist it
/// in the `.aphmm` text format — the payload `aphmm serve`'s
/// `register-profile` command accepts, so tenants can register
/// prebuilt profiles instead of raw sequences.
fn cmd_profile(args: &Args) -> Result<()> {
    let cfg = args.config()?;
    let alphabet = Alphabet::by_name(&cfg.str_or("profile.alphabet", "dna"))?;
    let out_path = args.get("out").unwrap_or("profile.aphmm").to_string();
    let reference = match args.get("seq") {
        Some(s) if !s.is_empty() => Sequence::from_str("reference", s, alphabet)?,
        _ => {
            let fasta = args.get("fasta").filter(|p| !p.is_empty()).ok_or_else(|| {
                ApHmmError::Config("profile: pass --seq ASCII or --fasta FILE".into())
            })?;
            io::read_fasta(Path::new(fasta), alphabet)?
                .into_iter()
                .next()
                .ok_or_else(|| ApHmmError::Config(format!("{fasta}: no sequences")))?
        }
    };
    let phmm = Phmm::error_correction_for(&reference, &EcDesignParams::default(), alphabet)?;
    // The server hashes what it parses from the payload, not this
    // in-memory graph: printing f32 parameters at 7 decimals can round
    // them, so report the hash of the round-tripped graph the file
    // actually describes (a parsed graph is a fixed point of the
    // format, so this matches the server's `ok profile ... hash=`).
    let text = io::write_phmm_string(&phmm);
    let canon = io::read_phmm_str(&text, &out_path)?;
    std::fs::write(Path::new(&out_path), &text)?;
    println!(
        "wrote {out_path}: {} states, hash={:016x} (register with: \
         register-profile <name> {} followed by the file bytes)",
        canon.n_states(),
        profile_hash(&canon),
        text.len()
    );
    Ok(())
}

fn cmd_accel(args: &Args) -> Result<()> {
    let cfg = args.config()?;
    let mut acfg = AccelConfig::default();
    acfg = acfg.with_pes(cfg.usize_or("accel.pes", 64)?);
    acfg.n_cores = cfg.usize_or("accel.cores", 4)?;
    let chunk = cfg.usize_or("accel.chunk", 650)?;
    let wl = Workload::synthetic(
        chunk as u64,
        cfg.f64_or("accel.active_states", 500.0)?,
        cfg.f64_or("accel.degree", 7.0)?,
        cfg.usize_or("accel.sigma", 4)?,
        chunk,
        accel::StepKind::Training,
    );
    let bd = accel::cycles(&acfg, &wl);
    let e = accel::energy(&acfg, &wl, &Default::default());
    let ap = accel::area_power(&acfg);
    println!("ApHMM model @ {} PEs, {} ports, chunk {}:", acfg.n_pes, acfg.mem_ports, chunk);
    println!(
        "  cycles: fwd {:.0}  bwd {:.0}  upd {:.0}  total {:.0} ({:.3} ms @1GHz, mem-bound {:.0}%)",
        bd.forward,
        bd.backward,
        bd.update,
        bd.total(),
        bd.seconds(&acfg) * 1e3,
        bd.mem_bound_fraction * 100.0
    );
    println!(
        "  energy: {:.3} mJ (compute {:.3}, sram {:.3}, dram {:.3}, static {:.3})",
        e.total() * 1e3,
        e.compute_j * 1e3,
        e.sram_j * 1e3,
        e.dram_j * 1e3,
        e.static_j * 1e3
    );
    println!(
        "  core: {:.3} mm^2, {:.1} mW; {}-core chip: {:.2} mm^2, {:.2} W",
        ap.core_area_mm2(),
        ap.core_power_mw(),
        acfg.n_cores,
        ap.chip_area_mm2(acfg.n_cores),
        ap.chip_power_w(acfg.n_cores)
    );
    Ok(())
}

fn cmd_runtime(args: &Args) -> Result<()> {
    let dir = PathBuf::from(args.get("artifacts").unwrap_or("artifacts"));
    let store = aphmm::runtime::ArtifactStore::load(&dir)?;
    println!("platform: {}", store.platform());
    for name in store.names() {
        let s = store.spec(name).unwrap();
        println!(
            "  {name}: entry={} N={} W={} sigma={} T={} results={}",
            s.entry, s.n, s.w, s.sigma, s.t, s.results
        );
    }
    Ok(())
}

fn main() -> ExitCode {
    let Some(args) = Args::parse() else {
        eprintln!("{}", usage());
        return ExitCode::from(2);
    };
    let result = match args.cmd.as_str() {
        "simulate" => cmd_simulate(&args),
        "correct" => cmd_correct(&args),
        "search" => cmd_search(&args),
        "align" => cmd_align(&args),
        "serve" => cmd_serve(&args),
        "profile" => cmd_profile(&args),
        "accel" => cmd_accel(&args),
        "runtime" => cmd_runtime(&args),
        other => {
            eprintln!("unknown subcommand {other:?}\n{}", usage());
            return ExitCode::from(2);
        }
    };
    match result {
        Ok(()) => ExitCode::SUCCESS,
        Err(e) => {
            eprintln!("error: {e}");
            ExitCode::FAILURE
        }
    }
}
