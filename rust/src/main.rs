//! `aphmm` — command-line launcher for the ApHMM reproduction.
//!
//! Subcommands:
//!   simulate   generate a synthetic genome + PacBio-like reads (FASTA)
//!   correct    Apollo-style assembly error correction
//!   search     protein family search over a generated family database
//!   align      hmmalign-style MSA against a family profile
//!   accel      query the accelerator model (cycles/energy/area)
//!   runtime    list and smoke-run the AOT artifacts via PJRT
//!
//! Every subcommand accepts `--config <file>` (see `examples/*.toml`)
//! plus `--set key=value` overrides.

use std::path::{Path, PathBuf};
use std::process::ExitCode;

use aphmm::accel::{self, AccelConfig, Workload};
use aphmm::apps::{self, CorrectionConfig, MsaConfig, SearchConfig};
use aphmm::baumwelch::{
    BandedEngine, EngineKind, ExpectationEngine, FilterConfig, ReferenceEngine, SparseEngine,
};
use aphmm::config::Config;
use aphmm::error::{ApHmmError, Result};
use aphmm::io;
use aphmm::phmm::{Phmm, Profile, TraditionalParams};
use aphmm::seq::{DNA, PROTEIN};
use aphmm::sim::{self, XorShift};

fn usage() -> &'static str {
    "usage: aphmm <simulate|correct|search|align|accel|runtime> [--config FILE] [--set k=v ...]
  simulate --out-dir DIR [--set sim.genome_len=N --set sim.coverage=X]
  correct  --assembly A.fasta --reads R.fasta --out C.fasta [--engine sparse|banded|reference]
  search   [--engine E] [--set search.n_families=N --set search.queries=N]
  align    [--engine E] [--set msa.n_seqs=N]
  accel    [--set accel.pes=N --set accel.chunk=N]
  runtime  --artifacts DIR

  --engine selects the Baum-Welch ExpectationEngine backend
  (default: sparse for correct/search, banded for align; also settable
  via --set <section>.engine=NAME)"
}

/// Minimal argument parser: positional subcommand + `--flag value` pairs.
struct Args {
    cmd: String,
    flags: Vec<(String, String)>,
}

impl Args {
    fn parse() -> Option<Args> {
        let mut it = std::env::args().skip(1);
        let cmd = it.next()?;
        let mut flags = Vec::new();
        let mut key: Option<String> = None;
        for tok in it {
            if let Some(k) = tok.strip_prefix("--") {
                if let Some(prev) = key.take() {
                    flags.push((prev, String::new()));
                }
                key = Some(k.to_string());
            } else if let Some(k) = key.take() {
                flags.push((k, tok));
            } else {
                return None;
            }
        }
        if let Some(prev) = key.take() {
            flags.push((prev, String::new()));
        }
        Some(Args { cmd, flags })
    }

    fn get(&self, name: &str) -> Option<&str> {
        self.flags.iter().rev().find(|(k, _)| k == name).map(|(_, v)| v.as_str())
    }

    fn config(&self) -> Result<Config> {
        let mut cfg = match self.get("config") {
            Some(path) => Config::load(Path::new(path))?,
            None => Config::default(),
        };
        let overrides: Vec<(String, String)> = self
            .flags
            .iter()
            .filter(|(k, _)| k == "set")
            .filter_map(|(_, v)| v.split_once('=').map(|(a, b)| (a.to_string(), b.to_string())))
            .collect();
        cfg.override_with(&overrides);
        Ok(cfg)
    }
}

/// Resolve the engine backend: `--engine NAME` wins, then
/// `<section>.engine` from the config file, then `default_kind`.
fn engine_from(
    args: &Args,
    cfg: &Config,
    section: &str,
    default_kind: EngineKind,
) -> Result<EngineKind> {
    let name = match args.get("engine") {
        Some(v) if !v.is_empty() => v.to_string(),
        _ => cfg.str_or(&format!("{section}.engine"), default_kind.name()),
    };
    EngineKind::parse(&name).ok_or_else(|| {
        ApHmmError::Config(format!(
            "unknown engine {name:?} (expected sparse | banded | reference | xla)"
        ))
    })
}

fn filter_from(cfg: &Config, section: &str) -> Result<FilterConfig> {
    let kind = cfg.str_or(&format!("{section}.filter"), "histogram");
    let size = cfg.usize_or(&format!("{section}.filter_size"), 500)?;
    let bins = cfg.usize_or(&format!("{section}.filter_bins"), 16)?;
    Ok(match kind.as_str() {
        "none" => FilterConfig::None,
        "sort" => FilterConfig::Sort { size },
        _ => FilterConfig::Histogram { size, bins },
    })
}

fn cmd_simulate(args: &Args) -> Result<()> {
    let cfg = args.config()?;
    let out_dir = PathBuf::from(args.get("out-dir").unwrap_or("simdata"));
    std::fs::create_dir_all(&out_dir)?;
    let seed = cfg.usize_or("sim.seed", 42)? as u64;
    let genome_len = cfg.usize_or("sim.genome_len", 100_000)?;
    let coverage = cfg.f64_or("sim.coverage", 10.0)?;
    let mean_len = cfg.usize_or("sim.read_len", 5128)?;
    let mut rng = XorShift::new(seed);
    let genome = sim::generate_genome(&mut rng, genome_len);
    let reads = sim::simulate_reads(&mut rng, &genome, coverage, mean_len, &sim::ErrorProfile::pacbio());
    let mut gf = std::fs::File::create(out_dir.join("genome.fasta"))?;
    io::write_fasta(&mut gf, &[genome], DNA)?;
    let seqs: Vec<_> = reads.iter().map(|r| r.seq.clone()).collect();
    let mut rf = std::fs::File::create(out_dir.join("reads.fasta"))?;
    io::write_fasta(&mut rf, &seqs, DNA)?;
    println!(
        "wrote {}/genome.fasta ({genome_len} bases) and {}/reads.fasta ({} reads)",
        out_dir.display(),
        out_dir.display(),
        seqs.len()
    );
    Ok(())
}

fn cmd_correct(args: &Args) -> Result<()> {
    let cfg = args.config()?;
    let assembly_path = args.get("assembly").unwrap_or("simdata/genome.fasta").to_string();
    let reads_path = args.get("reads").unwrap_or("simdata/reads.fasta").to_string();
    let out_path = args.get("out").unwrap_or("corrected.fasta").to_string();
    let assemblies = io::read_fasta(Path::new(&assembly_path), DNA)?;
    let reads = io::read_fasta(Path::new(&reads_path), DNA)?;
    let correction = CorrectionConfig {
        chunk_len: cfg.usize_or("correction.chunk_len", 650)?,
        max_iters: cfg.usize_or("correction.max_iters", 2)?,
        filter: filter_from(&cfg, "correction")?,
        engine: engine_from(args, &cfg, "correction", EngineKind::Sparse)?,
        ..Default::default()
    };
    let mut corrected = Vec::new();
    for assembly in &assemblies {
        let report = apps::correct_assembly(assembly, &reads, &correction)?;
        println!(
            "{}: {} chunks ({} trained), {} reads mapped, BW fraction {:.1}%",
            assembly.id,
            report.chunks_total,
            report.chunks_trained,
            report.reads_mapped,
            report.timings.bw_fraction() * 100.0
        );
        corrected.push(report.corrected);
    }
    let mut out = std::fs::File::create(Path::new(&out_path))?;
    io::write_fasta(&mut out, &corrected, DNA)?;
    println!("wrote {out_path}");
    Ok(())
}

fn cmd_search(args: &Args) -> Result<()> {
    let cfg = args.config()?;
    let seed = cfg.usize_or("search.seed", 7)? as u64;
    let n_families = cfg.usize_or("search.n_families", 64)?;
    let n_queries = cfg.usize_or("search.queries", 16)?;
    let engine = engine_from(args, &cfg, "search", EngineKind::Sparse)?;
    let mut rng = XorShift::new(seed);
    let params = sim::ProteinSimParams { n_families, ..Default::default() };
    let families = sim::generate_families(&mut rng, &params);
    let search_cfg = SearchConfig::default();
    match engine {
        EngineKind::Sparse => run_search(SparseEngine, &families, n_queries, &search_cfg),
        EngineKind::Banded => run_search(BandedEngine, &families, n_queries, &search_cfg),
        EngineKind::Reference => run_search(ReferenceEngine, &families, n_queries, &search_cfg),
        EngineKind::Xla => Err(ApHmmError::Config(
            "the XLA engine is device-backed; search supports sparse | banded | reference".into(),
        )),
    }
}

/// The search loop, generic over the database's engine backend.
fn run_search<E: ExpectationEngine>(
    engine: E,
    families: &[sim::ProteinFamily],
    n_queries: usize,
    search_cfg: &SearchConfig,
) -> Result<()> {
    let db = apps::FamilyDb::build_with(engine, families, PROTEIN, search_cfg)?;
    let mut correct = 0usize;
    for q in 0..n_queries {
        let fam = &families[q % families.len()];
        let query = &fam.members[q % fam.members.len()];
        let report = db.search(query, search_cfg)?;
        let top = report.hits.first().map(|h| h.family.clone()).unwrap_or_default();
        if top == fam.id {
            correct += 1;
        }
        println!(
            "query {:<16} -> {:<10} (scored {}/{} families)",
            query.id, top, report.scored, db.len()
        );
    }
    println!("top-1 accuracy: {correct}/{n_queries}");
    Ok(())
}

fn cmd_align(args: &Args) -> Result<()> {
    let cfg = args.config()?;
    let seed = cfg.usize_or("msa.seed", 11)? as u64;
    let n_seqs = cfg.usize_or("msa.n_seqs", 24)?;
    let mut rng = XorShift::new(seed);
    let params = sim::ProteinSimParams {
        n_families: 1,
        members_per_family: n_seqs,
        ..Default::default()
    };
    let fam = sim::generate_families(&mut rng, &params).remove(0);
    let profile = Profile::from_members(&fam.members, fam.ancestor.len(), PROTEIN, 0.5);
    let phmm = Phmm::traditional(&profile, &TraditionalParams::default())?.fold_silent(4)?;
    let msa_cfg = MsaConfig {
        engine: engine_from(args, &cfg, "msa", EngineKind::Banded)?,
        ..Default::default()
    };
    let report = apps::align_all(&phmm, &fam.members, &msa_cfg)?;
    println!(
        "aligned {}/{} sequences to {} columns; identity {:.1}%; BW fraction {:.1}%",
        report.rows.len(),
        n_seqs,
        report.n_columns,
        apps::msa_identity(&report) * 100.0,
        report.timings.bw_fraction() * 100.0
    );
    Ok(())
}

fn cmd_accel(args: &Args) -> Result<()> {
    let cfg = args.config()?;
    let mut acfg = AccelConfig::default();
    acfg = acfg.with_pes(cfg.usize_or("accel.pes", 64)?);
    acfg.n_cores = cfg.usize_or("accel.cores", 4)?;
    let chunk = cfg.usize_or("accel.chunk", 650)?;
    let wl = Workload::synthetic(
        chunk as u64,
        cfg.f64_or("accel.active_states", 500.0)?,
        cfg.f64_or("accel.degree", 7.0)?,
        cfg.usize_or("accel.sigma", 4)?,
        chunk,
        accel::StepKind::Training,
    );
    let bd = accel::cycles(&acfg, &wl);
    let e = accel::energy(&acfg, &wl, &Default::default());
    let ap = accel::area_power(&acfg);
    println!("ApHMM model @ {} PEs, {} ports, chunk {}:", acfg.n_pes, acfg.mem_ports, chunk);
    println!(
        "  cycles: fwd {:.0}  bwd {:.0}  upd {:.0}  total {:.0} ({:.3} ms @1GHz, mem-bound {:.0}%)",
        bd.forward,
        bd.backward,
        bd.update,
        bd.total(),
        bd.seconds(&acfg) * 1e3,
        bd.mem_bound_fraction * 100.0
    );
    println!(
        "  energy: {:.3} mJ (compute {:.3}, sram {:.3}, dram {:.3}, static {:.3})",
        e.total() * 1e3,
        e.compute_j * 1e3,
        e.sram_j * 1e3,
        e.dram_j * 1e3,
        e.static_j * 1e3
    );
    println!(
        "  core: {:.3} mm^2, {:.1} mW; {}-core chip: {:.2} mm^2, {:.2} W",
        ap.core_area_mm2(),
        ap.core_power_mw(),
        acfg.n_cores,
        ap.chip_area_mm2(acfg.n_cores),
        ap.chip_power_w(acfg.n_cores)
    );
    Ok(())
}

fn cmd_runtime(args: &Args) -> Result<()> {
    let dir = PathBuf::from(args.get("artifacts").unwrap_or("artifacts"));
    let store = aphmm::runtime::ArtifactStore::load(&dir)?;
    println!("platform: {}", store.platform());
    for name in store.names() {
        let s = store.spec(name).unwrap();
        println!(
            "  {name}: entry={} N={} W={} sigma={} T={} results={}",
            s.entry, s.n, s.w, s.sigma, s.t, s.results
        );
    }
    Ok(())
}

fn main() -> ExitCode {
    let Some(args) = Args::parse() else {
        eprintln!("{}", usage());
        return ExitCode::from(2);
    };
    let result = match args.cmd.as_str() {
        "simulate" => cmd_simulate(&args),
        "correct" => cmd_correct(&args),
        "search" => cmd_search(&args),
        "align" => cmd_align(&args),
        "accel" => cmd_accel(&args),
        "runtime" => cmd_runtime(&args),
        _ => {
            eprintln!("{}", usage());
            return ExitCode::from(2);
        }
    };
    match result {
        Ok(()) => ExitCode::SUCCESS,
        Err(e) => {
            eprintln!("error: {e}");
            ExitCode::FAILURE
        }
    }
}
