//! A shared, reusable worker pool with scoped fan-out.
//!
//! Before this module, every parallel region spawned its own scoped
//! threads: `train()` spawned E-step workers per call and the
//! coordinator spawned chunk workers per `run_jobs`, so the two levels
//! of parallelism could not share capacity (ROADMAP perf candidate:
//! "chunk-level + E-step thread-pool sharing in the coordinator").
//! [`WorkerPool`] replaces both: one set of helper threads is created
//! per coordinator/app session (or once per process via
//! [`WorkerPool::global`]) and every fan-out — chunk training, the
//! batch E-step, nested combinations of the two — draws from it.
//!
//! # Execution model
//!
//! [`WorkerPool::scope`]`(participants, f)` runs `f(slot)` on the
//! calling thread (slot 0) plus up to `participants - 1` *currently
//! idle* helper threads (slots 1, 2, ...), and returns once every
//! participant has finished.  Two properties make this safe to nest and
//! share:
//!
//! * **The caller always participates.**  Helpers are enlisted
//!   opportunistically and never waited for, so a scope makes progress
//!   even when every helper is busy — a chunk worker that fans its
//!   E-step out while all helpers are occupied simply runs the E-step
//!   on its own thread.  Deadlock is impossible by construction.
//! * **Work must be self-scheduling.**  `f` receives only a slot index;
//!   participants are expected to pull work items from a shared atomic
//!   cursor.  Results therefore cannot depend on how many helpers
//!   actually joined — the Baum-Welch E-step keeps its bit-identical
//!   guarantee for any worker count because its block reduction merges
//!   in block order, not completion order.
//!
//! Closures are handed to helpers by lifetime-erased pointer; `scope`
//! blocks until the last helper leaves the closure, which is what makes
//! the erasure sound (see the SAFETY notes inline).

use std::collections::VecDeque;
use std::panic::{catch_unwind, resume_unwind, AssertUnwindSafe};
use std::sync::{Arc, Condvar, Mutex, OnceLock};
use std::thread::JoinHandle;

/// Best-effort human-readable message from a caught panic payload
/// (`panic!` with a literal yields `&str`, with a format string
/// `String`; anything else is opaque).  Used by the per-job panic
/// containment in the server and coordinator to build typed `Failed`
/// responses that preserve the original failure message.
pub fn panic_message(payload: &(dyn std::any::Any + Send)) -> String {
    if let Some(s) = payload.downcast_ref::<&str>() {
        (*s).to_string()
    } else if let Some(s) = payload.downcast_ref::<String>() {
        s.clone()
    } else {
        "non-string panic payload".to_string()
    }
}

/// One fan-out region: the closure plus slot/lifecycle accounting.
///
/// `task` is a reference whose lifetime has been transmuted to
/// `'static`; it is only ever called by a helper that claimed a slot
/// while the owning [`WorkerPool::scope`] call was still blocked, and
/// `scope` does not return (or unwind) until every such helper has
/// left the closure — so the reference never actually outlives the
/// closure it points at (see the SAFETY notes in `scope`).
struct ScopeJob {
    task: &'static (dyn Fn(usize) + Sync),
    state: Mutex<ScopeState>,
    done: Condvar,
}

struct ScopeState {
    /// Helper slots handed out so far (slot 0 belongs to the caller).
    claimed: usize,
    /// Maximum helper slots (`participants - 1`).
    max_helpers: usize,
    /// Helpers currently inside the closure.
    running: usize,
    /// Set by the scope owner during teardown; no new claims after.
    closed: bool,
    /// The first helper panic's payload, resumed on the caller after
    /// teardown so the original failure message survives the pool
    /// boundary (later helper panics in the same scope are dropped).
    panic_payload: Option<Box<dyn std::any::Any + Send>>,
}

struct PoolShared {
    queue: Mutex<PoolQueue>,
    work: Condvar,
}

struct PoolQueue {
    jobs: VecDeque<Arc<ScopeJob>>,
    shutdown: bool,
}

/// A reusable pool of helper threads serving [`WorkerPool::scope`]
/// fan-outs.  See the module docs for the execution model.
pub struct WorkerPool {
    shared: Arc<PoolShared>,
    helpers: Vec<JoinHandle<()>>,
}

impl WorkerPool {
    /// A pool with `n_helpers` background threads.  `n_helpers = 0` is
    /// valid: every scope then runs entirely on the calling thread.
    pub fn new(n_helpers: usize) -> WorkerPool {
        let shared = Arc::new(PoolShared {
            queue: Mutex::new(PoolQueue { jobs: VecDeque::new(), shutdown: false }),
            work: Condvar::new(),
        });
        let helpers = (0..n_helpers)
            .map(|_| {
                let shared = Arc::clone(&shared);
                std::thread::spawn(move || helper_loop(&shared))
            })
            .collect();
        WorkerPool { shared, helpers }
    }

    /// Number of background helper threads.
    pub fn n_helpers(&self) -> usize {
        self.helpers.len()
    }

    /// The process-wide shared pool, created on first use with
    /// `available_parallelism - 1` helpers.  Convenience entry points
    /// (`train`, the apps) draw from this one; sessions that want
    /// isolated capacity build their own with [`WorkerPool::new`].
    pub fn global() -> &'static WorkerPool {
        static GLOBAL: OnceLock<WorkerPool> = OnceLock::new();
        GLOBAL.get_or_init(|| {
            let n = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
            WorkerPool::new(n.saturating_sub(1).min(15))
        })
    }

    /// Weak probe on the pool's shared state.  Every helper thread (and
    /// the pool itself) holds a strong reference, so the probe upgrades
    /// exactly while any of them is alive: after the pool is dropped,
    /// `upgrade()` returning `None` *proves* every helper thread exited
    /// (the server shutdown test relies on this).
    pub fn liveness(&self) -> std::sync::Weak<dyn std::any::Any + Send + Sync> {
        let strong: Arc<dyn std::any::Any + Send + Sync> = Arc::clone(&self.shared) as _;
        Arc::downgrade(&strong)
    }

    /// Run `f(slot)` on the calling thread (slot 0) and up to
    /// `participants - 1` idle helpers (slots 1, 2, ...), returning when
    /// every participant has finished.  `f` must be self-scheduling
    /// (pull work from a shared cursor): the number of participants that
    /// actually run is between 1 and `participants`.
    ///
    /// Panics in any participant are propagated to the caller after all
    /// other participants have finished.
    pub fn scope<F: Fn(usize) + Sync>(&self, participants: usize, f: F) {
        let max_helpers = participants.saturating_sub(1);
        if max_helpers == 0 || self.helpers.is_empty() {
            f(0);
            return;
        }
        let task_ref: &(dyn Fn(usize) + Sync) = &f;
        // SAFETY: the 'static lifetime is a lie confined to this call:
        // the reference is only called by helpers that claimed a slot
        // before `closed` is set below, and this function does not
        // return (or unwind) until `running == 0`, so `f` outlives
        // every call through the reference.
        #[allow(clippy::useless_transmute, clippy::missing_transmute_annotations)]
        let task_static: &'static (dyn Fn(usize) + Sync) = unsafe {
            std::mem::transmute::<&(dyn Fn(usize) + Sync), &'static (dyn Fn(usize) + Sync)>(
                task_ref,
            )
        };
        let job = Arc::new(ScopeJob {
            task: task_static,
            state: Mutex::new(ScopeState {
                claimed: 0,
                max_helpers,
                running: 0,
                closed: false,
                panic_payload: None,
            }),
            done: Condvar::new(),
        });
        {
            let mut q = self.shared.queue.lock().unwrap();
            q.jobs.push_back(Arc::clone(&job));
        }
        self.shared.work.notify_all();

        let caller = catch_unwind(AssertUnwindSafe(|| f(0)));

        // Teardown: remove the job so no further helper can claim it
        // (claims happen under the queue lock), then wait out the ones
        // already inside.
        {
            let mut q = self.shared.queue.lock().unwrap();
            if let Some(pos) = q.jobs.iter().position(|j| Arc::ptr_eq(j, &job)) {
                q.jobs.remove(pos);
            }
        }
        let helper_payload = {
            let mut st = job.state.lock().unwrap();
            st.closed = true;
            while st.running > 0 {
                st = job.done.wait(st).unwrap();
            }
            st.panic_payload.take()
        };
        // Caller's own panic wins (it is the closure the user wrote);
        // otherwise re-raise the helper's original payload so the real
        // failure message reaches the caller's `catch_unwind`.
        if let Err(payload) = caller {
            resume_unwind(payload);
        }
        if let Some(payload) = helper_payload {
            resume_unwind(payload);
        }
    }
}

/// Dropping a pool **drains, never aborts**: helpers that are inside a
/// scope closure finish it (a scope cannot outlive its `scope()` call,
/// which blocks until `running == 0`), idle helpers see the shutdown
/// flag and exit, and `drop` joins every helper thread before
/// returning.  There is no mechanism to kill a closure mid-flight — a
/// caller that wants "abort" semantics must make its *work* stop early
/// (the server does this by aborting its job queue, which turns every
/// worker's next `pop()` into `None`), after which the pool drop is
/// prompt.  Consequently no thread ever outlives the pool; see
/// [`WorkerPool::liveness`] for the probe tests use to assert it.
impl Drop for WorkerPool {
    fn drop(&mut self) {
        {
            let mut q = self.shared.queue.lock().unwrap();
            q.shutdown = true;
        }
        self.shared.work.notify_all();
        for h in self.helpers.drain(..) {
            let _ = h.join();
        }
    }
}

fn helper_loop(shared: &PoolShared) {
    loop {
        // Claim a slot while holding the queue lock, so the scope owner
        // (which removes its job under the same lock before closing)
        // can never tear down a job between our pop and our claim.
        let (job, slot) = {
            let mut q = shared.queue.lock().unwrap();
            'find: loop {
                if q.shutdown {
                    return;
                }
                let mut exhausted: Option<usize> = None;
                let mut found: Option<(Arc<ScopeJob>, usize)> = None;
                for (i, job) in q.jobs.iter().enumerate() {
                    let mut st = job.state.lock().unwrap();
                    if !st.closed && st.claimed < st.max_helpers {
                        st.claimed += 1;
                        st.running += 1;
                        let slot = st.claimed; // 1..=max_helpers
                        if st.claimed == st.max_helpers {
                            exhausted = Some(i);
                        }
                        found = Some((Arc::clone(job), slot));
                        break;
                    }
                }
                if let Some(i) = exhausted {
                    q.jobs.remove(i);
                }
                match found {
                    Some(claim) => break 'find claim,
                    None => q = shared.work.wait(q).unwrap(),
                }
            }
        };
        // The slot was claimed before the job closed; the scope owner
        // blocks until `running == 0`, so the closure is alive for the
        // whole call (see the SAFETY note in `scope`).
        let task = job.task;
        let outcome = catch_unwind(AssertUnwindSafe(|| task(slot)));
        let mut st = job.state.lock().unwrap();
        st.running -= 1;
        if let Err(payload) = outcome {
            if st.panic_payload.is_none() {
                st.panic_payload = Some(payload);
            }
        }
        drop(st);
        job.done.notify_all();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    /// Self-scheduling counter workload: participants pull items.
    fn drain_counter(pool: &WorkerPool, participants: usize, items: usize) -> usize {
        let next = AtomicUsize::new(0);
        let done = AtomicUsize::new(0);
        pool.scope(participants, |_slot| loop {
            let i = next.fetch_add(1, Ordering::Relaxed);
            if i >= items {
                break;
            }
            done.fetch_add(1, Ordering::Relaxed);
        });
        done.load(Ordering::Relaxed)
    }

    #[test]
    fn scope_completes_all_items() {
        let pool = WorkerPool::new(3);
        for participants in [1, 2, 4, 9] {
            assert_eq!(drain_counter(&pool, participants, 100), 100);
        }
    }

    #[test]
    fn zero_helper_pool_runs_on_caller() {
        let pool = WorkerPool::new(0);
        assert_eq!(pool.n_helpers(), 0);
        assert_eq!(drain_counter(&pool, 4, 50), 50);
    }

    #[test]
    fn nested_scopes_make_progress() {
        // A scope participant opening an inner scope must never
        // deadlock, even when the pool is smaller than the demand.
        let pool = WorkerPool::new(1);
        let total = AtomicUsize::new(0);
        let outer_next = AtomicUsize::new(0);
        pool.scope(3, |_slot| loop {
            let i = outer_next.fetch_add(1, Ordering::Relaxed);
            if i >= 4 {
                break;
            }
            let inner_next = AtomicUsize::new(0);
            pool.scope(3, |_inner| loop {
                let j = inner_next.fetch_add(1, Ordering::Relaxed);
                if j >= 10 {
                    break;
                }
                total.fetch_add(1, Ordering::Relaxed);
            });
        });
        assert_eq!(total.load(Ordering::Relaxed), 40);
    }

    #[test]
    fn pool_is_reusable_across_scopes() {
        let pool = WorkerPool::new(2);
        for _ in 0..20 {
            assert_eq!(drain_counter(&pool, 3, 17), 17);
        }
    }

    #[test]
    fn global_pool_exists() {
        let done = drain_counter(WorkerPool::global(), 2, 10);
        assert_eq!(done, 10);
    }

    #[test]
    fn helper_panic_payload_reaches_the_caller() {
        use std::sync::atomic::AtomicBool;
        let pool = WorkerPool::new(1);
        let helper_entered = AtomicBool::new(false);
        let outcome = catch_unwind(AssertUnwindSafe(|| {
            pool.scope(2, |slot| {
                if slot == 0 {
                    // Keep the scope open until the helper has joined,
                    // so the panic deterministically comes from a
                    // helper thread, not the caller.
                    while !helper_entered.load(Ordering::Acquire) {
                        std::thread::yield_now();
                    }
                } else {
                    helper_entered.store(true, Ordering::Release);
                    panic!("boom-123");
                }
            });
        }));
        let payload = outcome.expect_err("helper panic must propagate out of scope()");
        assert_eq!(
            payload.downcast_ref::<&str>(),
            Some(&"boom-123"),
            "the helper's original payload must survive, not a generic message"
        );
        // The pool must remain usable after a contained panic.
        assert_eq!(drain_counter(&pool, 2, 25), 25);
    }

    #[test]
    fn drop_joins_all_helpers() {
        let pool = WorkerPool::new(3);
        assert_eq!(drain_counter(&pool, 4, 40), 40);
        let probe = pool.liveness();
        assert!(probe.upgrade().is_some(), "probe must be live while the pool is");
        drop(pool);
        assert!(probe.upgrade().is_none(), "helper threads leaked past drop");
    }
}
