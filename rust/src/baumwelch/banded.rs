//! Dense banded Baum-Welch engine.
//!
//! Rust mirror of the L2 JAX model (`python/compile/model.py`): the same
//! scaled forward scan and fused backward+update scan over the banded
//! encoding, in f32 like the AOT artifacts.  The PJRT runtime
//! (`crate::runtime`) is a drop-in replacement for [`BandedEngine`]
//! (same inputs, same outputs), which is exactly what the parity
//! integration test asserts.
//!
//! Two generations of kernels coexist:
//!
//! * [`BandedEngine::forward`] / [`BandedEngine::bw_sums`] — the
//!   pre-refactor scan that re-gathers `a[j,x] · e(j+x, s)` per band
//!   entry per timestep.  Kept as the parity baseline (the fused tables
//!   are pinned against it) and as the exact mirror of the AOT
//!   artifacts for the XLA parity tests.
//! * [`BandedEngine::forward_with`] / [`BandedEngine::bw_sums_with`] —
//!   the fused-coefficient hot path: [`BandedCoeffs`] memoizes the
//!   per-symbol transition×emission band once per parameter freeze
//!   (paper §4.2–4.3 applied to the dense engine; the ROADMAP's
//!   "coefficient tables for the banded engine" perf candidate), so the
//!   timestep scan performs one multiply-accumulate per band entry with
//!   no emission gather.  The backward scan consumes the same table in
//!   the same association as the old code, so its sums are
//!   bit-identical; the forward fuses the emission into the scatter
//!   (one f32 reassociation per entry, tolerance-pinned by
//!   `tests/engine_matrix.rs`).

use std::time::Instant;

use super::engine::PosteriorDecode;
use super::EPS;
use crate::error::{ApHmmError, Result};
use crate::phmm::BandedPhmm;
use crate::seq::Sequence;

/// Per-symbol fused coefficient tables for the banded engine: one
/// parameter-freeze snapshot of `a[j,x] · e(j+x, s)` per symbol, plus
/// the fused `f_init[i] · e(i, s)` start row.
///
/// Built once per EM iteration (or once per frozen profile for
/// inference); in-crate construction routes through the lowering layer
/// (`lowering::BandedLowering::lower` pairs the banded encoding with
/// these tables — both the banded engine's `prepare` and the sparse
/// engine's posterior-decode cache use it).  Rebuild after any
/// parameter update — the `_with` kernels reject shape mismatches but
/// cannot detect stale values.
pub struct BandedCoeffs {
    n: usize,
    w: usize,
    sigma: usize,
    /// `a[j,x] · e(j+x, s)`, symbol-major `[Σ × N × W]`.
    coef: Vec<f32>,
    /// `f_init[i] · e(i, s)`, symbol-major `[Σ × N]`.
    init_coef: Vec<f32>,
}

impl BandedCoeffs {
    /// Precompute the fused band for the current parameters of `b`.
    /// Cost: `O(Σ · N · W)` multiplies and `4·Σ·N·(W+1)` bytes,
    /// amortized over `T · N · W` band operations per read.
    pub fn new(b: &BandedPhmm) -> BandedCoeffs {
        let (n, w, sigma) = (b.n, b.w, b.sigma);
        let mut coef = vec![0.0f32; sigma * n * w];
        for s in 0..sigma {
            let base = s * n * w;
            for j in 0..n {
                let hi = w.min(n - j);
                for x in 0..hi {
                    let a = b.a_band[j * w + x];
                    if a > 0.0 {
                        coef[base + j * w + x] = a * b.e(j + x, s);
                    }
                }
            }
        }
        let mut init_coef = vec![0.0f32; sigma * n];
        for s in 0..sigma {
            for i in 0..n {
                init_coef[s * n + i] = b.f_init[i] * b.e(i, s);
            }
        }
        BandedCoeffs { n, w, sigma, coef, init_coef }
    }

    /// `(N, W, Σ)` the tables were built for.
    pub fn shape(&self) -> (usize, usize, usize) {
        (self.n, self.w, self.sigma)
    }

    /// The fused band of symbol `s`, row-major `[N × W]`.
    #[inline]
    fn coef_for(&self, s: usize) -> &[f32] {
        &self.coef[s * self.n * self.w..(s + 1) * self.n * self.w]
    }

    /// The fused start row of symbol `s`, `[N]`.
    #[inline]
    fn init_for(&self, s: usize) -> &[f32] {
        &self.init_coef[s * self.n..(s + 1) * self.n]
    }
}

/// Shared input validation of the fused banded kernels.
fn precheck_banded(b: &BandedPhmm, coeffs: &BandedCoeffs, seq: &Sequence) -> Result<()> {
    if coeffs.shape() != (b.n, b.w, b.sigma) {
        return Err(ApHmmError::Banded(
            "banded coefficient tables do not match the graph (stale BandedCoeffs?)".into(),
        ));
    }
    if seq.is_empty() {
        return Err(ApHmmError::Numerical("empty observation sequence".into()));
    }
    if seq.data.iter().any(|&s| (s as usize) >= b.sigma) {
        return Err(ApHmmError::Numerical(format!(
            "sequence {:?} contains a symbol outside the {}-letter alphabet",
            seq.id, b.sigma
        )));
    }
    Ok(())
}

/// Raw update sums in banded layout (mirrors `model.baum_welch_sums`).
#[derive(Clone, Debug)]
pub struct BandedBwSums {
    /// ξ sums `[N × W]`.
    pub xi_band: Vec<f32>,
    /// Eq. 3 denominators `[N]`.
    pub trans_den: Vec<f32>,
    /// Emission numerators `[N × Σ]`.
    pub e_num: Vec<f32>,
    /// Eq. 4 denominators `[N]`.
    pub gamma_den: Vec<f32>,
    /// log P(S | G).
    pub loglik: f32,
}

impl BandedBwSums {
    /// Zeroed sums for accumulating across observations.
    pub fn zeros(n: usize, w: usize, sigma: usize) -> Self {
        BandedBwSums {
            xi_band: vec![0.0; n * w],
            trans_den: vec![0.0; n],
            e_num: vec![0.0; n * sigma],
            gamma_den: vec![0.0; n],
            loglik: 0.0,
        }
    }

    /// Elementwise accumulate (batch EM over many reads).
    pub fn add(&mut self, other: &BandedBwSums) {
        for (a, b) in self.xi_band.iter_mut().zip(&other.xi_band) {
            *a += b;
        }
        for (a, b) in self.trans_den.iter_mut().zip(&other.trans_den) {
            *a += b;
        }
        for (a, b) in self.e_num.iter_mut().zip(&other.e_num) {
            *a += b;
        }
        for (a, b) in self.gamma_den.iter_mut().zip(&other.gamma_den) {
            *a += b;
        }
        self.loglik += other.loglik;
    }

    /// Maximization into a banded parameter set (rows renormalized;
    /// untouched states keep their old parameters).
    pub fn apply(&self, banded: &mut BandedPhmm) {
        let (n, w, sigma) = (banded.n, banded.w, banded.sigma);
        for j in 0..n {
            if self.trans_den[j] <= EPS {
                continue;
            }
            let row = &self.xi_band[j * w..(j + 1) * w];
            let row_sum: f32 = row.iter().sum();
            if row_sum <= EPS {
                continue;
            }
            for x in 0..w {
                // Keep structural zeros: never create new transitions.
                if banded.a_band[j * w + x] > 0.0 {
                    banded.a_band[j * w + x] = row[x] / row_sum;
                }
            }
        }
        for i in 0..n {
            if self.gamma_den[i] <= EPS {
                continue;
            }
            let row = &self.e_num[i * sigma..(i + 1) * sigma];
            let row_sum: f32 = row.iter().sum();
            if row_sum <= EPS {
                continue;
            }
            for c in 0..sigma {
                banded.emit[i * sigma + c] = row[c] / row_sum;
            }
        }
    }
}

/// Checkpointed banded forward product: every ⌈√T⌉-th post-normalize
/// row plus all `T` scales (the banded counterpart of the sparse
/// engine's `CheckpointedForward`).
#[derive(Clone, Debug)]
pub(super) struct BandedCheckpointedForward {
    /// Checkpoint rows at `t = 0, K, 2K, …`, row-major `[n_ckpts × N]`.
    pub(super) ckpt_rows: Vec<f32>,
    /// Per-timestep scale factors — all `T` of them.
    pub(super) scales: Vec<f32>,
    /// Checkpoint interval `K = ⌈√T⌉`.
    pub(super) seg_len: usize,
    /// `log P(S | G)`.
    pub(super) loglik: f64,
    /// State count the rows were built for.
    pub(super) n: usize,
}

impl BandedCheckpointedForward {
    /// Resident bytes of the checkpoint rows + scales.
    pub(super) fn ckpt_bytes(&self) -> u64 {
        (self.ckpt_rows.len() + self.scales.len()) as u64 * 4
    }
}

/// The dense banded compute engine.
pub struct BandedEngine;

impl BandedEngine {
    /// Scaled forward pass; returns `(f_rows [T×N], scales [T], loglik)`.
    pub fn forward(b: &BandedPhmm, seq: &Sequence) -> Result<(Vec<f32>, Vec<f32>, f64)> {
        let (n, w) = (b.n, b.w);
        let t_len = seq.len();
        if t_len == 0 {
            return Err(ApHmmError::Numerical("empty observation sequence".into()));
        }
        let mut f_rows = vec![0.0f32; t_len * n];
        let mut scales = vec![0.0f32; t_len];
        let mut loglik = 0.0f64;
        // t = 0.
        {
            let s0 = seq.data[0] as usize;
            let mut c = 0.0f32;
            for i in 0..n {
                let v = b.f_init[i] * b.e(i, s0);
                f_rows[i] = v;
                c += v;
            }
            if c <= EPS {
                return Err(ApHmmError::Numerical("dead start in banded forward".into()));
            }
            for i in 0..n {
                f_rows[i] /= c;
            }
            scales[0] = c;
            loglik += (c as f64).ln();
        }
        for t in 1..t_len {
            let s_t = seq.data[t] as usize;
            let (prev_rows, cur_rows) = f_rows.split_at_mut(t * n);
            let prev = &prev_rows[(t - 1) * n..];
            let cur = &mut cur_rows[..n];
            // Banded scatter: cur[j + x] += prev[j] * a[j, x].
            for j in 0..n {
                let fj = prev[j];
                if fj == 0.0 {
                    continue;
                }
                let row = &b.a_band[j * w..(j + 1) * w];
                let hi = w.min(n - j);
                for x in 0..hi {
                    cur[j + x] += fj * row[x];
                }
            }
            let mut c = 0.0f32;
            for i in 0..n {
                cur[i] *= b.e(i, s_t);
                c += cur[i];
            }
            if c <= EPS {
                return Err(ApHmmError::Numerical(format!("banded forward died at t={t}")));
            }
            let inv = 1.0 / c;
            for i in 0..n {
                cur[i] *= inv;
            }
            scales[t] = c;
            loglik += (c as f64).ln();
        }
        Ok((f_rows, scales, loglik))
    }

    /// Forward-only score.
    pub fn score(b: &BandedPhmm, seq: &Sequence) -> Result<f64> {
        Ok(Self::forward(b, seq)?.2)
    }

    /// Full expectation pass (mirrors `model.baum_welch_sums`).
    pub fn bw_sums(b: &BandedPhmm, seq: &Sequence) -> Result<BandedBwSums> {
        let (n, w, sigma) = (b.n, b.w, b.sigma);
        let t_len = seq.len();
        let (f_rows, scales, loglik) = Self::forward(b, seq)?;
        let mut sums = BandedBwSums::zeros(n, w, sigma);
        sums.loglik = loglik as f32;

        let mut b_next = vec![1.0f32; n]; // B̂_{T-1} = 1
        let mut b_cur = vec![0.0f32; n];
        // γ at t = T-1.
        {
            let f_last = &f_rows[(t_len - 1) * n..];
            let s_t = seq.data[t_len - 1] as usize;
            for i in 0..n {
                let g = f_last[i];
                sums.gamma_den[i] += g;
                sums.e_num[i * sigma + s_t] += g;
            }
        }
        for t in (0..t_len.saturating_sub(1)).rev() {
            let s_next = seq.data[t + 1] as usize;
            let s_t = seq.data[t] as usize;
            let inv_c = 1.0 / scales[t + 1];
            let f_t = &f_rows[t * n..(t + 1) * n];
            // eb[i] = e(i, s_{t+1}) * B̂_{t+1}(i)
            // fused: m = a[j,x] * eb[j+x]; b_cur[j] = Σ m / c;
            //        xi[j,x] += f_t[j] * m / c.
            for j in 0..n {
                let row = &b.a_band[j * w..(j + 1) * w];
                let hi = w.min(n - j);
                let mut acc = 0.0f32;
                let fj = f_t[j];
                for x in 0..hi {
                    let a = row[x];
                    if a == 0.0 {
                        continue;
                    }
                    let to = j + x;
                    let m = a * b.e(to, s_next) * b_next[to] * inv_c;
                    acc += m;
                    sums.xi_band[j * w + x] += fj * m;
                }
                b_cur[j] = acc;
                let g = fj * acc;
                sums.trans_den[j] += g;
                sums.gamma_den[j] += g;
                sums.e_num[j * sigma + s_t] += g;
            }
            std::mem::swap(&mut b_next, &mut b_cur);
        }
        Ok(sums)
    }

    /// Fused-coefficient scaled forward pass: same recurrences as
    /// [`BandedEngine::forward`], but every band entry is a single
    /// multiply-accumulate against the memoized `a·e` table (no
    /// emission gather, no post-hoc per-state emission multiply).
    pub fn forward_with(
        b: &BandedPhmm,
        coeffs: &BandedCoeffs,
        seq: &Sequence,
    ) -> Result<(Vec<f32>, Vec<f32>, f64)> {
        precheck_banded(b, coeffs, seq)?;
        let (n, w) = (b.n, b.w);
        let t_len = seq.len();
        let mut f_rows = vec![0.0f32; t_len * n];
        let mut scales = vec![0.0f32; t_len];
        let mut loglik = 0.0f64;
        // t = 0: fused init·emission row.
        {
            let init = coeffs.init_for(seq.data[0] as usize);
            let mut c = 0.0f32;
            for i in 0..n {
                let v = init[i];
                f_rows[i] = v;
                c += v;
            }
            if c <= EPS {
                return Err(ApHmmError::Numerical("dead start in banded forward".into()));
            }
            for i in 0..n {
                f_rows[i] /= c;
            }
            scales[0] = c;
            loglik += (c as f64).ln();
        }
        for t in 1..t_len {
            let coef = coeffs.coef_for(seq.data[t] as usize);
            let (prev_rows, cur_rows) = f_rows.split_at_mut(t * n);
            let prev = &prev_rows[(t - 1) * n..];
            let cur = &mut cur_rows[..n];
            // Fused banded scatter: cur[j + x] += prev[j] · (a·e)[j, x].
            for j in 0..n {
                let fj = prev[j];
                if fj == 0.0 {
                    continue;
                }
                let row = &coef[j * w..(j + 1) * w];
                let hi = w.min(n - j);
                for x in 0..hi {
                    cur[j + x] += fj * row[x];
                }
            }
            let mut c = 0.0f32;
            for i in 0..n {
                c += cur[i];
            }
            if c <= EPS {
                return Err(ApHmmError::Numerical(format!("banded forward died at t={t}")));
            }
            let inv = 1.0 / c;
            for i in 0..n {
                cur[i] *= inv;
            }
            scales[t] = c;
            loglik += (c as f64).ln();
        }
        Ok((f_rows, scales, loglik))
    }

    /// Fused-coefficient forward-only score.
    pub fn score_with(b: &BandedPhmm, coeffs: &BandedCoeffs, seq: &Sequence) -> Result<f64> {
        Ok(Self::forward_with(b, coeffs, seq)?.2)
    }

    /// Fused-coefficient full expectation pass.  The backward scan
    /// consumes the memoized `a·e` product in exactly the association
    /// of [`BandedEngine::bw_sums`], so (given the same forward rows)
    /// its sums are bit-identical to the pre-refactor scan.
    pub fn bw_sums_with(
        b: &BandedPhmm,
        coeffs: &BandedCoeffs,
        seq: &Sequence,
    ) -> Result<BandedBwSums> {
        let (f_rows, scales, loglik) = Self::forward_with(b, coeffs, seq)?;
        Self::backward_sums_with(b, coeffs, seq, &f_rows, &scales, loglik)
    }

    /// The fused backward + update scan over precomputed forward rows
    /// (split out so callers can time the two phases separately).
    pub fn backward_sums_with(
        b: &BandedPhmm,
        coeffs: &BandedCoeffs,
        seq: &Sequence,
        f_rows: &[f32],
        scales: &[f32],
        loglik: f64,
    ) -> Result<BandedBwSums> {
        precheck_banded(b, coeffs, seq)?;
        let (n, w, sigma) = (b.n, b.w, b.sigma);
        let t_len = seq.len();
        let mut sums = BandedBwSums::zeros(n, w, sigma);
        sums.loglik = loglik as f32;

        let mut b_next = vec![1.0f32; n]; // B̂_{T-1} = 1
        let mut b_cur = vec![0.0f32; n];
        // γ at t = T-1.
        {
            let f_last = &f_rows[(t_len - 1) * n..];
            let s_t = seq.data[t_len - 1] as usize;
            for i in 0..n {
                let g = f_last[i];
                sums.gamma_den[i] += g;
                sums.e_num[i * sigma + s_t] += g;
            }
        }
        for t in (0..t_len.saturating_sub(1)).rev() {
            let coef = coeffs.coef_for(seq.data[t + 1] as usize);
            let s_t = seq.data[t] as usize;
            let inv_c = 1.0 / scales[t + 1];
            let f_t = &f_rows[t * n..(t + 1) * n];
            // m = (a·e)[j,x] · B̂_{t+1}(j+x) / c — one table gather per
            // band entry instead of a transition read plus an emission
            // gather.
            for j in 0..n {
                let row = &coef[j * w..(j + 1) * w];
                let hi = w.min(n - j);
                let mut acc = 0.0f32;
                let fj = f_t[j];
                for x in 0..hi {
                    let ae = row[x];
                    if ae == 0.0 {
                        continue;
                    }
                    let m = ae * b_next[j + x] * inv_c;
                    acc += m;
                    sums.xi_band[j * w + x] += fj * m;
                }
                b_cur[j] = acc;
                let g = fj * acc;
                sums.trans_den[j] += g;
                sums.gamma_den[j] += g;
                sums.e_num[j * sigma + s_t] += g;
            }
            std::mem::swap(&mut b_next, &mut b_cur);
        }
        Ok(sums)
    }

    /// Checkpointed fused forward (`ScratchMode::Checkpointed` for the
    /// banded engine): identical arithmetic to
    /// [`BandedEngine::forward_with`] — the kept rows, every scale and
    /// the log-likelihood are bit-identical — but only every ⌈√T⌉-th
    /// post-normalize row is stored (`O(√T·N)` instead of `O(T·N)`).
    pub(super) fn forward_checkpointed_with(
        b: &BandedPhmm,
        coeffs: &BandedCoeffs,
        seq: &Sequence,
    ) -> Result<BandedCheckpointedForward> {
        precheck_banded(b, coeffs, seq)?;
        let (n, w) = (b.n, b.w);
        let t_len = seq.len();
        let seg_len = super::sparse::checkpoint_interval(t_len);
        let n_ckpts = (t_len - 1) / seg_len + 1;
        let mut ckpt_rows = vec![0.0f32; n_ckpts * n];
        let mut scales = vec![0.0f32; t_len];
        let mut loglik = 0.0f64;
        let mut prev = vec![0.0f32; n];
        let mut cur = vec![0.0f32; n];
        // t = 0: fused init·emission row (always checkpoint 0).
        {
            let init = coeffs.init_for(seq.data[0] as usize);
            let mut c = 0.0f32;
            for i in 0..n {
                let v = init[i];
                prev[i] = v;
                c += v;
            }
            if c <= EPS {
                return Err(ApHmmError::Numerical("dead start in banded forward".into()));
            }
            for i in 0..n {
                prev[i] /= c;
            }
            scales[0] = c;
            loglik += (c as f64).ln();
            ckpt_rows[..n].copy_from_slice(&prev);
        }
        for t in 1..t_len {
            let coef = coeffs.coef_for(seq.data[t] as usize);
            cur.iter_mut().for_each(|x| *x = 0.0);
            for j in 0..n {
                let fj = prev[j];
                if fj == 0.0 {
                    continue;
                }
                let row = &coef[j * w..(j + 1) * w];
                let hi = w.min(n - j);
                for x in 0..hi {
                    cur[j + x] += fj * row[x];
                }
            }
            let mut c = 0.0f32;
            for i in 0..n {
                c += cur[i];
            }
            if c <= EPS {
                return Err(ApHmmError::Numerical(format!("banded forward died at t={t}")));
            }
            let inv = 1.0 / c;
            for i in 0..n {
                cur[i] *= inv;
            }
            scales[t] = c;
            loglik += (c as f64).ln();
            if t % seg_len == 0 {
                let s = t / seg_len;
                ckpt_rows[s * n..(s + 1) * n].copy_from_slice(&cur);
            }
            std::mem::swap(&mut prev, &mut cur);
        }
        Ok(BandedCheckpointedForward { ckpt_rows, scales, seg_len, loglik, n })
    }

    /// Checkpointed fused backward + update scan: recompute each
    /// segment's forward rows from its checkpoint (replaying the exact
    /// [`BandedEngine::forward_with`] arithmetic), then consume them
    /// with the exact per-timestep arithmetic of
    /// [`BandedEngine::backward_sums_with`] — the sums are bit-identical
    /// to the full-matrix path.  The backward row pair carries across
    /// segment boundaries untouched (every entry is rewritten each
    /// timestep, so no support bookkeeping is needed in the dense
    /// engine).
    ///
    /// Returns the sums plus the peak forward-row scratch in bytes
    /// (checkpoints + scales + the per-segment recompute buffer), the
    /// `O(√T·N)` quantity the scratch accounting reports.
    pub(super) fn backward_sums_checkpointed_with(
        b: &BandedPhmm,
        coeffs: &BandedCoeffs,
        seq: &Sequence,
        ckpt: &BandedCheckpointedForward,
    ) -> Result<(BandedBwSums, u64)> {
        precheck_banded(b, coeffs, seq)?;
        let (n, w, sigma) = (b.n, b.w, b.sigma);
        debug_assert_eq!(n, ckpt.n);
        let t_len = seq.len();
        let k = ckpt.seg_len;
        let n_segs = ckpt.ckpt_rows.len() / n;
        debug_assert_eq!(n_segs, (t_len - 1) / k + 1);
        let mut sums = BandedBwSums::zeros(n, w, sigma);
        sums.loglik = ckpt.loglik as f32;

        let mut b_next = vec![1.0f32; n]; // B̂_{T-1} = 1
        let mut b_cur = vec![0.0f32; n];
        let mut seg = vec![0.0f32; k * n];
        let peak = ckpt.ckpt_bytes() + (k * n) as u64 * 4;
        for s in (0..n_segs).rev() {
            let start = s * k;
            let len = k.min(t_len - start);
            // Recompute the segment rows from checkpoint `s` — the same
            // fused scatter as the forward pass, from an exactly-stored
            // post-normalize row, so every recomputed row (and its
            // recomputed scale) is bit-identical.
            seg[..n].copy_from_slice(&ckpt.ckpt_rows[s * n..(s + 1) * n]);
            for t in start + 1..start + len {
                let coef = coeffs.coef_for(seq.data[t] as usize);
                let off = (t - start) * n;
                let (prev_rows, cur_rows) = seg.split_at_mut(off);
                let prev = &prev_rows[off - n..];
                let cur = &mut cur_rows[..n];
                cur.iter_mut().for_each(|x| *x = 0.0);
                for j in 0..n {
                    let fj = prev[j];
                    if fj == 0.0 {
                        continue;
                    }
                    let row = &coef[j * w..(j + 1) * w];
                    let hi = w.min(n - j);
                    for x in 0..hi {
                        cur[j + x] += fj * row[x];
                    }
                }
                let mut c = 0.0f32;
                for i in 0..n {
                    c += cur[i];
                }
                if c <= EPS {
                    // Unreachable for a read whose forward pass
                    // succeeded; kept as a real error for safety.
                    return Err(ApHmmError::Numerical(format!(
                        "banded forward died at t={t} during recompute"
                    )));
                }
                debug_assert_eq!(
                    c.to_bits(),
                    ckpt.scales[t].to_bits(),
                    "recomputed banded scale diverged at t={t}"
                );
                let inv = 1.0 / c;
                for i in 0..n {
                    cur[i] *= inv;
                }
            }
            // γ at t = T-1 (only the last segment holds that row).
            if s == n_segs - 1 {
                let f_last = &seg[(len - 1) * n..len * n];
                let s_t = seq.data[t_len - 1] as usize;
                for i in 0..n {
                    let g = f_last[i];
                    sums.gamma_den[i] += g;
                    sums.e_num[i * sigma + s_t] += g;
                }
            }
            // Consume the segment, last timestep first — the exact
            // per-timestep arithmetic of `backward_sums_with`.
            let top = (start + len).min(t_len - 1);
            for t in (start..top).rev() {
                let coef = coeffs.coef_for(seq.data[t + 1] as usize);
                let s_t = seq.data[t] as usize;
                let inv_c = 1.0 / ckpt.scales[t + 1];
                let f_t = &seg[(t - start) * n..(t - start + 1) * n];
                for j in 0..n {
                    let row = &coef[j * w..(j + 1) * w];
                    let hi = w.min(n - j);
                    let mut acc = 0.0f32;
                    let fj = f_t[j];
                    for x in 0..hi {
                        let ae = row[x];
                        if ae == 0.0 {
                            continue;
                        }
                        let m = ae * b_next[j + x] * inv_c;
                        acc += m;
                        sums.xi_band[j * w + x] += fj * m;
                    }
                    b_cur[j] = acc;
                    let g = fj * acc;
                    sums.trans_den[j] += g;
                    sums.gamma_den[j] += g;
                    sums.e_num[j * sigma + s_t] += g;
                }
                std::mem::swap(&mut b_next, &mut b_cur);
            }
        }
        Ok((sums, peak))
    }

    /// Posterior best-state decode (hmmalign's alignment rule): forward
    /// plus a backward scan tracking `argmax_i γ_t(i) = F̂_t(i)·B̂_t(i)`
    /// per timestep, both on the fused coefficient tables.  The two
    /// phases are timed separately for the Fig. 2 breakdown.
    pub fn posterior_with(
        b: &BandedPhmm,
        coeffs: &BandedCoeffs,
        seq: &Sequence,
    ) -> Result<PosteriorDecode> {
        let t0 = Instant::now();
        let (f_rows, scales, loglik) = Self::forward_with(b, coeffs, seq)?;
        let forward_ns = t0.elapsed().as_nanos();

        let t1 = Instant::now();
        let (n, w) = (b.n, b.w);
        let t_len = seq.len();
        let mut b_next = vec![1.0f32; n];
        let mut b_cur = vec![0.0f32; n];
        let mut best_state = vec![0u32; t_len];
        {
            let f_last = &f_rows[(t_len - 1) * n..];
            let mut bi = 0usize;
            for i in 1..n {
                if f_last[i] > f_last[bi] {
                    bi = i;
                }
            }
            best_state[t_len - 1] = bi as u32;
        }
        for t in (0..t_len.saturating_sub(1)).rev() {
            let coef = coeffs.coef_for(seq.data[t + 1] as usize);
            let inv_c = 1.0 / scales[t + 1];
            for j in 0..n {
                let row = &coef[j * w..(j + 1) * w];
                let hi = w.min(n - j);
                let mut acc = 0.0f32;
                for (x, &ae) in row.iter().enumerate().take(hi) {
                    if ae > 0.0 {
                        acc += ae * b_next[j + x];
                    }
                }
                b_cur[j] = acc * inv_c;
            }
            let f_t = &f_rows[t * n..(t + 1) * n];
            let mut bi = 0usize;
            let mut bv = -1.0f32;
            for j in 0..n {
                let g = f_t[j] * b_cur[j];
                if g > bv {
                    bv = g;
                    bi = j;
                }
            }
            best_state[t] = bi as u32;
            std::mem::swap(&mut b_next, &mut b_cur);
        }
        let backward_ns = t1.elapsed().as_nanos();
        Ok(PosteriorDecode { best_state, loglik, forward_ns, backward_ns })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::baumwelch::sparse::{forward_sparse, ForwardOptions};
    use crate::baumwelch::update::BwAccumulators;
    use crate::phmm::Phmm;
    use crate::testutil;

    fn setup(rng: &mut crate::sim::XorShift, rl: usize, ol: usize) -> (Phmm, Sequence) {
        let data = testutil::random_seq(rng, rl, 4);
        let g = Phmm::error_correction(&Sequence::from_symbols("r", data), &Default::default())
            .unwrap();
        let obs = Sequence::from_symbols("o", testutil::random_seq(rng, ol, 4));
        (g, obs)
    }

    #[test]
    fn banded_forward_matches_sparse_unfiltered() {
        testutil::check(15, |rng| {
            let __h0 = rng.range(4, 40);
            let __h1 = rng.range(2, 25);
            let (g, obs) = setup(rng, __h0, __h1);
            let banded = g.to_banded().unwrap();
            let sparse_ll = forward_sparse(&g, &obs, &ForwardOptions::default()).unwrap().loglik;
            let banded_ll = BandedEngine::score(&banded, &obs).unwrap();
            testutil::assert_close(banded_ll, sparse_ll, 1e-4, 1e-5);
        });
    }

    #[test]
    fn banded_sums_match_sparse_accumulators() {
        testutil::check(10, |rng| {
            let __h0 = rng.range(4, 25);
            let __h1 = rng.range(3, 15);
            let (g, obs) = setup(rng, __h0, __h1);
            let banded = g.to_banded().unwrap();
            let sums = BandedEngine::bw_sums(&banded, &obs).unwrap();

            let fwd = forward_sparse(&g, &obs, &ForwardOptions::default()).unwrap();
            let mut acc = BwAccumulators::new(&g);
            acc.accumulate(&g, &obs, &fwd).unwrap();

            // Compare xi through the CSR <-> band mapping.
            for j in 0..g.n_states() {
                for e in g.out_ptr[j] as usize..g.out_ptr[j + 1] as usize {
                    let x = g.out_to[e] as usize - j;
                    testutil::assert_close(
                        sums.xi_band[j * banded.w + x] as f64,
                        acc.xi[e],
                        5e-3,
                        1e-5,
                    );
                }
            }
            let gd: Vec<f64> = sums.gamma_den.iter().map(|&x| x as f64).collect();
            testutil::assert_all_close(&gd, &acc.gamma_den, 5e-3, 1e-5);
        });
    }

    #[test]
    fn padding_does_not_change_results() {
        let mut rng = crate::sim::XorShift::new(42);
        let (g, obs) = setup(&mut rng, 20, 12);
        let banded = g.to_banded().unwrap();
        let padded = banded.pad_to(banded.n + 37, banded.w + 5).unwrap();
        let a = BandedEngine::bw_sums(&banded, &obs).unwrap();
        let b = BandedEngine::bw_sums(&padded, &obs).unwrap();
        testutil::assert_close(a.loglik as f64, b.loglik as f64, 1e-5, 1e-6);
        for j in 0..banded.n {
            for x in 0..banded.w {
                testutil::assert_close(
                    a.xi_band[j * banded.w + x] as f64,
                    b.xi_band[j * padded.w + x] as f64,
                    1e-4,
                    1e-6,
                );
            }
        }
        // Padded region stays exactly zero.
        assert!(b.gamma_den[banded.n..].iter().all(|&x| x == 0.0));
    }

    #[test]
    fn apply_then_score_does_not_decrease() {
        testutil::check(8, |rng| {
            let __h0 = rng.range(5, 20);
            let __h1 = rng.range(4, 12);
            let (g, obs) = setup(rng, __h0, __h1);
            let mut banded = g.to_banded().unwrap();
            let ll0 = BandedEngine::score(&banded, &obs).unwrap();
            let sums = BandedEngine::bw_sums(&banded, &obs).unwrap();
            sums.apply(&mut banded);
            let ll1 = BandedEngine::score(&banded, &obs).unwrap();
            assert!(ll1 >= ll0 - 1e-3, "EM decreased loglik {ll0} -> {ll1}");
        });
    }

    #[test]
    fn fused_band_tables_match_direct_products() {
        testutil::check(10, |rng| {
            let __h0 = rng.range(4, 30);
            let (g, _) = setup(rng, __h0, 5);
            let b = g.to_banded().unwrap();
            let c = BandedCoeffs::new(&b);
            assert_eq!(c.shape(), (b.n, b.w, b.sigma));
            for s in 0..b.sigma {
                let band = c.coef_for(s);
                for j in 0..b.n {
                    let hi = b.w.min(b.n - j);
                    for x in 0..hi {
                        let want = b.a_band[j * b.w + x] * b.e(j + x, s);
                        assert_eq!(band[j * b.w + x].to_bits(), want.to_bits(), "j={j} x={x} s={s}");
                    }
                }
                let init = c.init_for(s);
                for i in 0..b.n {
                    assert_eq!(init[i].to_bits(), (b.f_init[i] * b.e(i, s)).to_bits());
                }
            }
        });
    }

    #[test]
    fn fused_forward_matches_prerefactor_scan() {
        // The fused scatter reassociates one f32 multiply per band
        // entry; rows, scales and log-likelihood stay within
        // reassociation noise of the pre-refactor scan.
        testutil::check(12, |rng| {
            let __h0 = rng.range(4, 35);
            let __h1 = rng.range(2, 25);
            let (g, obs) = setup(rng, __h0, __h1);
            let b = g.to_banded().unwrap();
            let c = BandedCoeffs::new(&b);
            let (f_old, s_old, ll_old) = BandedEngine::forward(&b, &obs).unwrap();
            let (f_new, s_new, ll_new) = BandedEngine::forward_with(&b, &c, &obs).unwrap();
            testutil::assert_close(ll_new, ll_old, 1e-4, 1e-6);
            let f_old: Vec<f64> = f_old.iter().map(|&x| x as f64).collect();
            let f_new: Vec<f64> = f_new.iter().map(|&x| x as f64).collect();
            testutil::assert_all_close(&f_new, &f_old, 1e-3, 1e-6);
            let s_old: Vec<f64> = s_old.iter().map(|&x| x as f64).collect();
            let s_new: Vec<f64> = s_new.iter().map(|&x| x as f64).collect();
            testutil::assert_all_close(&s_new, &s_old, 1e-3, 1e-6);
        });
    }

    #[test]
    fn fused_backward_is_bit_identical_given_same_forward_rows() {
        // The backward scan consumes the memoized a·e product in the
        // exact association of the pre-refactor code, so feeding it the
        // pre-refactor forward rows must reproduce bw_sums to the bit.
        testutil::check(10, |rng| {
            let __h0 = rng.range(4, 30);
            let __h1 = rng.range(2, 20);
            let (g, obs) = setup(rng, __h0, __h1);
            let b = g.to_banded().unwrap();
            let c = BandedCoeffs::new(&b);
            let (f_rows, scales, loglik) = BandedEngine::forward(&b, &obs).unwrap();
            let old = BandedEngine::bw_sums(&b, &obs).unwrap();
            let new =
                BandedEngine::backward_sums_with(&b, &c, &obs, &f_rows, &scales, loglik).unwrap();
            assert_eq!(old.loglik.to_bits(), new.loglik.to_bits());
            for (a, b_) in old.xi_band.iter().zip(&new.xi_band) {
                assert_eq!(a.to_bits(), b_.to_bits());
            }
            for (a, b_) in old.gamma_den.iter().zip(&new.gamma_den) {
                assert_eq!(a.to_bits(), b_.to_bits());
            }
            for (a, b_) in old.trans_den.iter().zip(&new.trans_den) {
                assert_eq!(a.to_bits(), b_.to_bits());
            }
            for (a, b_) in old.e_num.iter().zip(&new.e_num) {
                assert_eq!(a.to_bits(), b_.to_bits());
            }
        });
    }

    #[test]
    fn checkpointed_banded_sums_are_bit_identical_to_full() {
        // Checkpointed forward + segment-recompute backward must land
        // the exact bits of the full-matrix fused path.
        testutil::check(10, |rng| {
            let __h0 = rng.range(4, 30);
            let __h1 = rng.range(1, 40);
            let (g, obs) = setup(rng, __h0, __h1);
            let b = g.to_banded().unwrap();
            let c = BandedCoeffs::new(&b);
            let full = BandedEngine::bw_sums_with(&b, &c, &obs).unwrap();

            let ckpt = BandedEngine::forward_checkpointed_with(&b, &c, &obs).unwrap();
            assert_eq!(ckpt.seg_len, super::super::sparse::checkpoint_interval(obs.len()));
            let (chk, peak) =
                BandedEngine::backward_sums_checkpointed_with(&b, &c, &obs, &ckpt).unwrap();
            assert!(peak >= ckpt.ckpt_bytes());

            assert_eq!(full.loglik.to_bits(), chk.loglik.to_bits());
            for (a, b_) in full.xi_band.iter().zip(&chk.xi_band) {
                assert_eq!(a.to_bits(), b_.to_bits());
            }
            for (a, b_) in full.trans_den.iter().zip(&chk.trans_den) {
                assert_eq!(a.to_bits(), b_.to_bits());
            }
            for (a, b_) in full.gamma_den.iter().zip(&chk.gamma_den) {
                assert_eq!(a.to_bits(), b_.to_bits());
            }
            for (a, b_) in full.e_num.iter().zip(&chk.e_num) {
                assert_eq!(a.to_bits(), b_.to_bits());
            }
        });
    }

    #[test]
    fn fused_sums_track_prerefactor_sums() {
        // End-to-end (fused forward + fused backward) the sums stay
        // within forward-reassociation noise of the pre-refactor scan.
        testutil::check(10, |rng| {
            let __h0 = rng.range(4, 25);
            let __h1 = rng.range(3, 15);
            let (g, obs) = setup(rng, __h0, __h1);
            let b = g.to_banded().unwrap();
            let c = BandedCoeffs::new(&b);
            let old = BandedEngine::bw_sums(&b, &obs).unwrap();
            let new = BandedEngine::bw_sums_with(&b, &c, &obs).unwrap();
            testutil::assert_close(new.loglik as f64, old.loglik as f64, 1e-4, 1e-6);
            let o: Vec<f64> = old.gamma_den.iter().map(|&x| x as f64).collect();
            let n_: Vec<f64> = new.gamma_den.iter().map(|&x| x as f64).collect();
            testutil::assert_all_close(&n_, &o, 5e-3, 1e-5);
            let o: Vec<f64> = old.xi_band.iter().map(|&x| x as f64).collect();
            let n_: Vec<f64> = new.xi_band.iter().map(|&x| x as f64).collect();
            testutil::assert_all_close(&n_, &o, 5e-3, 1e-5);
        });
    }

    #[test]
    fn fused_kernels_reject_stale_coeffs() {
        let mut rng = crate::sim::XorShift::new(17);
        let (g, obs) = setup(&mut rng, 20, 10);
        let (g2, _) = setup(&mut rng, 31, 5);
        let b = g.to_banded().unwrap();
        let b2 = g2.to_banded().unwrap();
        let stale = BandedCoeffs::new(&b2);
        assert!(BandedEngine::forward_with(&b, &stale, &obs).is_err());
        assert!(BandedEngine::bw_sums_with(&b, &stale, &obs).is_err());
    }

    #[test]
    fn posterior_decode_tracks_high_probability_states() {
        let mut rng = crate::sim::XorShift::new(23);
        let (g, obs) = setup(&mut rng, 30, 20);
        let b = g.to_banded().unwrap();
        let c = BandedCoeffs::new(&b);
        let dec = BandedEngine::posterior_with(&b, &c, &obs).unwrap();
        assert_eq!(dec.best_state.len(), obs.len());
        let ll = BandedEngine::score(&b, &obs).unwrap();
        testutil::assert_close(dec.loglik, ll, 1e-3, 1e-6);
        assert!(dec.best_state.iter().all(|&s| (s as usize) < b.n));
    }

    #[test]
    fn accumulated_sums_equal_per_read_sums() {
        let mut rng = crate::sim::XorShift::new(5);
        let (g, obs1) = setup(&mut rng, 15, 8);
        let obs2 = Sequence::from_symbols("o2", testutil::random_seq(&mut rng, 6, 4));
        let banded = g.to_banded().unwrap();
        let mut total = BandedBwSums::zeros(banded.n, banded.w, banded.sigma);
        let s1 = BandedEngine::bw_sums(&banded, &obs1).unwrap();
        let s2 = BandedEngine::bw_sums(&banded, &obs2).unwrap();
        total.add(&s1);
        total.add(&s2);
        testutil::assert_close(
            total.loglik as f64,
            (s1.loglik + s2.loglik) as f64,
            1e-6,
            1e-9,
        );
        let g1: f64 = s1.gamma_den.iter().map(|&x| x as f64).sum();
        let g2: f64 = s2.gamma_den.iter().map(|&x| x as f64).sum();
        let gt: f64 = total.gamma_den.iter().map(|&x| x as f64).sum();
        testutil::assert_close(gt, g1 + g2, 1e-6, 1e-9);
    }
}
