//! Dense banded Baum-Welch engine.
//!
//! Rust mirror of the L2 JAX model (`python/compile/model.py`): the same
//! scaled forward scan and fused backward+update scan over the banded
//! encoding, in f32 like the AOT artifacts.  The PJRT runtime
//! (`crate::runtime`) is a drop-in replacement for [`BandedEngine`]
//! (same inputs, same outputs), which is exactly what the parity
//! integration test asserts.

use super::EPS;
use crate::error::{ApHmmError, Result};
use crate::phmm::BandedPhmm;
use crate::seq::Sequence;

/// Raw update sums in banded layout (mirrors `model.baum_welch_sums`).
#[derive(Clone, Debug)]
pub struct BandedBwSums {
    /// ξ sums `[N × W]`.
    pub xi_band: Vec<f32>,
    /// Eq. 3 denominators `[N]`.
    pub trans_den: Vec<f32>,
    /// Emission numerators `[N × Σ]`.
    pub e_num: Vec<f32>,
    /// Eq. 4 denominators `[N]`.
    pub gamma_den: Vec<f32>,
    /// log P(S | G).
    pub loglik: f32,
}

impl BandedBwSums {
    /// Zeroed sums for accumulating across observations.
    pub fn zeros(n: usize, w: usize, sigma: usize) -> Self {
        BandedBwSums {
            xi_band: vec![0.0; n * w],
            trans_den: vec![0.0; n],
            e_num: vec![0.0; n * sigma],
            gamma_den: vec![0.0; n],
            loglik: 0.0,
        }
    }

    /// Elementwise accumulate (batch EM over many reads).
    pub fn add(&mut self, other: &BandedBwSums) {
        for (a, b) in self.xi_band.iter_mut().zip(&other.xi_band) {
            *a += b;
        }
        for (a, b) in self.trans_den.iter_mut().zip(&other.trans_den) {
            *a += b;
        }
        for (a, b) in self.e_num.iter_mut().zip(&other.e_num) {
            *a += b;
        }
        for (a, b) in self.gamma_den.iter_mut().zip(&other.gamma_den) {
            *a += b;
        }
        self.loglik += other.loglik;
    }

    /// Maximization into a banded parameter set (rows renormalized;
    /// untouched states keep their old parameters).
    pub fn apply(&self, banded: &mut BandedPhmm) {
        let (n, w, sigma) = (banded.n, banded.w, banded.sigma);
        for j in 0..n {
            if self.trans_den[j] <= EPS {
                continue;
            }
            let row = &self.xi_band[j * w..(j + 1) * w];
            let row_sum: f32 = row.iter().sum();
            if row_sum <= EPS {
                continue;
            }
            for x in 0..w {
                // Keep structural zeros: never create new transitions.
                if banded.a_band[j * w + x] > 0.0 {
                    banded.a_band[j * w + x] = row[x] / row_sum;
                }
            }
        }
        for i in 0..n {
            if self.gamma_den[i] <= EPS {
                continue;
            }
            let row = &self.e_num[i * sigma..(i + 1) * sigma];
            let row_sum: f32 = row.iter().sum();
            if row_sum <= EPS {
                continue;
            }
            for c in 0..sigma {
                banded.emit[i * sigma + c] = row[c] / row_sum;
            }
        }
    }
}

/// The dense banded compute engine.
pub struct BandedEngine;

impl BandedEngine {
    /// Scaled forward pass; returns `(f_rows [T×N], scales [T], loglik)`.
    pub fn forward(b: &BandedPhmm, seq: &Sequence) -> Result<(Vec<f32>, Vec<f32>, f64)> {
        let (n, w) = (b.n, b.w);
        let t_len = seq.len();
        if t_len == 0 {
            return Err(ApHmmError::Numerical("empty observation sequence".into()));
        }
        let mut f_rows = vec![0.0f32; t_len * n];
        let mut scales = vec![0.0f32; t_len];
        let mut loglik = 0.0f64;
        // t = 0.
        {
            let s0 = seq.data[0] as usize;
            let mut c = 0.0f32;
            for i in 0..n {
                let v = b.f_init[i] * b.e(i, s0);
                f_rows[i] = v;
                c += v;
            }
            if c <= EPS {
                return Err(ApHmmError::Numerical("dead start in banded forward".into()));
            }
            for i in 0..n {
                f_rows[i] /= c;
            }
            scales[0] = c;
            loglik += (c as f64).ln();
        }
        for t in 1..t_len {
            let s_t = seq.data[t] as usize;
            let (prev_rows, cur_rows) = f_rows.split_at_mut(t * n);
            let prev = &prev_rows[(t - 1) * n..];
            let cur = &mut cur_rows[..n];
            // Banded scatter: cur[j + x] += prev[j] * a[j, x].
            for j in 0..n {
                let fj = prev[j];
                if fj == 0.0 {
                    continue;
                }
                let row = &b.a_band[j * w..(j + 1) * w];
                let hi = w.min(n - j);
                for x in 0..hi {
                    cur[j + x] += fj * row[x];
                }
            }
            let mut c = 0.0f32;
            for i in 0..n {
                cur[i] *= b.e(i, s_t);
                c += cur[i];
            }
            if c <= EPS {
                return Err(ApHmmError::Numerical(format!("banded forward died at t={t}")));
            }
            let inv = 1.0 / c;
            for i in 0..n {
                cur[i] *= inv;
            }
            scales[t] = c;
            loglik += (c as f64).ln();
        }
        Ok((f_rows, scales, loglik))
    }

    /// Forward-only score.
    pub fn score(b: &BandedPhmm, seq: &Sequence) -> Result<f64> {
        Ok(Self::forward(b, seq)?.2)
    }

    /// Full expectation pass (mirrors `model.baum_welch_sums`).
    pub fn bw_sums(b: &BandedPhmm, seq: &Sequence) -> Result<BandedBwSums> {
        let (n, w, sigma) = (b.n, b.w, b.sigma);
        let t_len = seq.len();
        let (f_rows, scales, loglik) = Self::forward(b, seq)?;
        let mut sums = BandedBwSums::zeros(n, w, sigma);
        sums.loglik = loglik as f32;

        let mut b_next = vec![1.0f32; n]; // B̂_{T-1} = 1
        let mut b_cur = vec![0.0f32; n];
        // γ at t = T-1.
        {
            let f_last = &f_rows[(t_len - 1) * n..];
            let s_t = seq.data[t_len - 1] as usize;
            for i in 0..n {
                let g = f_last[i];
                sums.gamma_den[i] += g;
                sums.e_num[i * sigma + s_t] += g;
            }
        }
        for t in (0..t_len.saturating_sub(1)).rev() {
            let s_next = seq.data[t + 1] as usize;
            let s_t = seq.data[t] as usize;
            let inv_c = 1.0 / scales[t + 1];
            let f_t = &f_rows[t * n..(t + 1) * n];
            // eb[i] = e(i, s_{t+1}) * B̂_{t+1}(i)
            // fused: m = a[j,x] * eb[j+x]; b_cur[j] = Σ m / c;
            //        xi[j,x] += f_t[j] * m / c.
            for j in 0..n {
                let row = &b.a_band[j * w..(j + 1) * w];
                let hi = w.min(n - j);
                let mut acc = 0.0f32;
                let fj = f_t[j];
                for x in 0..hi {
                    let a = row[x];
                    if a == 0.0 {
                        continue;
                    }
                    let to = j + x;
                    let m = a * b.e(to, s_next) * b_next[to] * inv_c;
                    acc += m;
                    sums.xi_band[j * w + x] += fj * m;
                }
                b_cur[j] = acc;
                let g = fj * acc;
                sums.trans_den[j] += g;
                sums.gamma_den[j] += g;
                sums.e_num[j * sigma + s_t] += g;
            }
            std::mem::swap(&mut b_next, &mut b_cur);
        }
        Ok(sums)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::baumwelch::sparse::{forward_sparse, ForwardOptions};
    use crate::baumwelch::update::BwAccumulators;
    use crate::phmm::Phmm;
    use crate::testutil;

    fn setup(rng: &mut crate::sim::XorShift, rl: usize, ol: usize) -> (Phmm, Sequence) {
        let data = testutil::random_seq(rng, rl, 4);
        let g = Phmm::error_correction(&Sequence::from_symbols("r", data), &Default::default())
            .unwrap();
        let obs = Sequence::from_symbols("o", testutil::random_seq(rng, ol, 4));
        (g, obs)
    }

    #[test]
    fn banded_forward_matches_sparse_unfiltered() {
        testutil::check(15, |rng| {
            let __h0 = rng.range(4, 40);
            let __h1 = rng.range(2, 25);
            let (g, obs) = setup(rng, __h0, __h1);
            let banded = g.to_banded().unwrap();
            let sparse_ll = forward_sparse(&g, &obs, &ForwardOptions::default()).unwrap().loglik;
            let banded_ll = BandedEngine::score(&banded, &obs).unwrap();
            testutil::assert_close(banded_ll, sparse_ll, 1e-4, 1e-5);
        });
    }

    #[test]
    fn banded_sums_match_sparse_accumulators() {
        testutil::check(10, |rng| {
            let __h0 = rng.range(4, 25);
            let __h1 = rng.range(3, 15);
            let (g, obs) = setup(rng, __h0, __h1);
            let banded = g.to_banded().unwrap();
            let sums = BandedEngine::bw_sums(&banded, &obs).unwrap();

            let fwd = forward_sparse(&g, &obs, &ForwardOptions::default()).unwrap();
            let mut acc = BwAccumulators::new(&g);
            acc.accumulate(&g, &obs, &fwd).unwrap();

            // Compare xi through the CSR <-> band mapping.
            for j in 0..g.n_states() {
                for e in g.out_ptr[j] as usize..g.out_ptr[j + 1] as usize {
                    let x = g.out_to[e] as usize - j;
                    testutil::assert_close(
                        sums.xi_band[j * banded.w + x] as f64,
                        acc.xi[e],
                        5e-3,
                        1e-5,
                    );
                }
            }
            let gd: Vec<f64> = sums.gamma_den.iter().map(|&x| x as f64).collect();
            testutil::assert_all_close(&gd, &acc.gamma_den, 5e-3, 1e-5);
        });
    }

    #[test]
    fn padding_does_not_change_results() {
        let mut rng = crate::sim::XorShift::new(42);
        let (g, obs) = setup(&mut rng, 20, 12);
        let banded = g.to_banded().unwrap();
        let padded = banded.pad_to(banded.n + 37, banded.w + 5).unwrap();
        let a = BandedEngine::bw_sums(&banded, &obs).unwrap();
        let b = BandedEngine::bw_sums(&padded, &obs).unwrap();
        testutil::assert_close(a.loglik as f64, b.loglik as f64, 1e-5, 1e-6);
        for j in 0..banded.n {
            for x in 0..banded.w {
                testutil::assert_close(
                    a.xi_band[j * banded.w + x] as f64,
                    b.xi_band[j * padded.w + x] as f64,
                    1e-4,
                    1e-6,
                );
            }
        }
        // Padded region stays exactly zero.
        assert!(b.gamma_den[banded.n..].iter().all(|&x| x == 0.0));
    }

    #[test]
    fn apply_then_score_does_not_decrease() {
        testutil::check(8, |rng| {
            let __h0 = rng.range(5, 20);
            let __h1 = rng.range(4, 12);
            let (g, obs) = setup(rng, __h0, __h1);
            let mut banded = g.to_banded().unwrap();
            let ll0 = BandedEngine::score(&banded, &obs).unwrap();
            let sums = BandedEngine::bw_sums(&banded, &obs).unwrap();
            sums.apply(&mut banded);
            let ll1 = BandedEngine::score(&banded, &obs).unwrap();
            assert!(ll1 >= ll0 - 1e-3, "EM decreased loglik {ll0} -> {ll1}");
        });
    }

    #[test]
    fn accumulated_sums_equal_per_read_sums() {
        let mut rng = crate::sim::XorShift::new(5);
        let (g, obs1) = setup(&mut rng, 15, 8);
        let obs2 = Sequence::from_symbols("o2", testutil::random_seq(&mut rng, 6, 4));
        let banded = g.to_banded().unwrap();
        let mut total = BandedBwSums::zeros(banded.n, banded.w, banded.sigma);
        let s1 = BandedEngine::bw_sums(&banded, &obs1).unwrap();
        let s2 = BandedEngine::bw_sums(&banded, &obs2).unwrap();
        total.add(&s1);
        total.add(&s2);
        testutil::assert_close(
            total.loglik as f64,
            (s1.loglik + s2.loglik) as f64,
            1e-6,
            1e-9,
        );
        let g1: f64 = s1.gamma_den.iter().map(|&x| x as f64).sum();
        let g2: f64 = s2.gamma_den.iter().map(|&x| x as f64).sum();
        let gt: f64 = total.gamma_den.iter().map(|&x| x as f64).sum();
        testutil::assert_close(gt, g1 + g2, 1e-6, 1e-9);
    }
}
