//! Per-window dense tiles of the fused in-window gather coefficients.
//!
//! The CSR gather walks each window target's incoming slots through two
//! levels of indirection (`in_ptr` → `in_from` → dense buffer), which
//! defeats the auto-vectorizer.  Within a band, pHMM transition
//! structure is near-dense (paper §4.2 Observation 5 / Fig. 4 — the
//! same observation CUDAMPF++ uses to pack pHMM rows into dense SIMD
//! lanes), so [`DenseTiles`] re-lowers the *same* fused
//! `α(from→to) · e_s(to)` products into one fixed-width `f32` tile row
//! per target state:
//!
//! ```text
//! coef[s][to][x] = α(to+x−pad → to) · e_s(to)      pad = tile_w − 1
//! ```
//!
//! Column indices are *window-relative* (column `x` is source
//! `to + x − pad`; columns with no edge hold `0.0`), and rows are
//! padded to [`super::lowering::TILE_LANES`], so the gather of one
//! target is a branchless dense dot product against a contiguous slice
//! of the (pad-offset) scratch buffer — no index loads, no tail loop.
//!
//! **Bitwise contract:** ascending columns are ascending sources, the
//! exact order the CSR gather sums its slots in, and every padded
//! column contributes `+0.0` to a non-negative accumulator — so under
//! the scalar lane policy the tile dot product reproduces the CSR
//! gather's sums *bit for bit* (`sparse::tests` and
//! `tests/engine_matrix.rs` assert this); wider [`super::simd`] lane
//! policies reduce the same terms with a fixed lane tree instead
//! (deterministic per width, tolerance-tier vs scalar).  The block
//! summation order of the E-step is therefore preserved no matter
//! which kernel executes each row.  The mapping relies on each `(from,
//! to)` pair owning exactly one tile cell; `Phmm::validate` enforces
//! strictly-ascending rows (no parallel edges), so a slot can never
//! silently overwrite another.
//!
//! [`OutTiles`] is the backward-pass mirror (the PR-4 tail): the fused
//! backward's per-source walk over *outgoing* edges re-lowered into one
//! fixed-width `f64` row per source state, column `x` being target
//! `j + x`, with a parallel edge-index row (`u32::MAX` where no edge
//! exists) so the ξ update still lands on exactly the CSR edge slots.
//! The backward stays `f64` and strictly scalar — ascending columns are
//! ascending targets, i.e. exactly the outgoing-CSR edge order, and
//! no-edge columns contribute `m = 0.0 · β · c⁻¹ = +0.0` to the
//! non-negative `f64` sums — so the out-tile backward is bit-identical
//! to the CSR backward under **every** lane policy.

use super::lowering::Lowering;
use crate::phmm::Phmm;

/// Per-symbol dense tile tables for one parameter freeze, built from
/// the shared [`Lowering`] by [`super::FusedCoeffs`].
pub struct DenseTiles {
    n: usize,
    sigma: usize,
    tile_w: usize,
    /// `α · e_s(to)` tiles, symbol-major `[Σ × N × tile_w]`.
    coef: Vec<f32>,
}

impl DenseTiles {
    /// Build the tiles for the current parameters of `phmm` over the
    /// frozen structure `lowering`.  Cost: `O(Σ · N · tile_w)` bytes and
    /// `O(Σ · |A|)` multiplies — the products are computed exactly as
    /// the CSR tables compute them (same operands, same f32 multiply),
    /// so the two lowerings carry bit-identical coefficients.
    pub(super) fn new(lowering: &Lowering, phmm: &Phmm) -> DenseTiles {
        let (n, sigma, tile_w) = (lowering.n_states, lowering.sigma, lowering.tile_w);
        let pad = tile_w - 1;
        let mut coef = vec![0.0f32; sigma * n * tile_w];
        for to in 0..n {
            let lo = lowering.in_ptr[to] as usize;
            let hi = lowering.in_ptr[to + 1] as usize;
            let emit = &phmm.emissions[to * sigma..(to + 1) * sigma];
            for slot in lo..hi {
                let from = lowering.in_from[slot] as usize;
                let x = pad - (to - from);
                let p = phmm.out_prob[lowering.in_eidx[slot] as usize];
                for (s, &e_s) in emit.iter().enumerate() {
                    coef[s * n * tile_w + to * tile_w + x] = p * e_s;
                }
            }
        }
        DenseTiles { n, sigma, tile_w, coef }
    }

    /// Tile row width (`Lowering::tile_width`).
    #[inline]
    pub fn tile_width(&self) -> usize {
        self.tile_w
    }

    /// `(N, Σ)` the tiles were built for.
    pub fn shape(&self) -> (usize, usize) {
        (self.n, self.sigma)
    }

    /// The tiles of symbol `s`, row-major `[N × tile_w]`.
    #[inline]
    pub(super) fn coef_for(&self, s: usize) -> &[f32] {
        &self.coef[s * self.n * self.tile_w..(s + 1) * self.n * self.tile_w]
    }
}

/// Per-symbol dense *outgoing* tiles for the tile-granular fused
/// backward, built from the shared [`Lowering`] by
/// [`super::FusedCoeffs::out_tiles_for`].
///
/// `coef[s][j][x] = α(j → j+x) · e_s(j+x)` in `f64` (bit-identical to
/// `FusedCoeffs::out_coef` — same operands, same widening multiply) and
/// `eidx[j][x]` is the outgoing-CSR edge index of `j → j+x`, or
/// `u32::MAX` where the band holds no edge (those columns carry
/// `coef = 0.0` and must never touch ξ).
pub struct OutTiles {
    n: usize,
    sigma: usize,
    tile_w: usize,
    /// `α · e_s(j+x)` rows, symbol-major `[Σ × N × tile_w]`, `f64`.
    coef: Vec<f64>,
    /// Outgoing-edge index per tile cell `[N × tile_w]` (`u32::MAX` =
    /// no edge).
    eidx: Vec<u32>,
}

impl OutTiles {
    /// Build the outgoing tiles for the current parameters of `phmm`
    /// over the frozen structure `lowering`.  Cost: `O(Σ · N · tile_w)`
    /// `f64`s plus the `[N × tile_w]` index map.
    pub(super) fn new(lowering: &Lowering, phmm: &Phmm) -> OutTiles {
        let (n, sigma, tile_w) = (lowering.n_states, lowering.sigma, lowering.tile_w);
        let mut coef = vec![0.0f64; sigma * n * tile_w];
        let mut eidx = vec![u32::MAX; n * tile_w];
        for j in 0..n {
            let lo = phmm.out_ptr[j] as usize;
            let hi = phmm.out_ptr[j + 1] as usize;
            for e in lo..hi {
                let to = phmm.out_to[e] as usize;
                let x = to - j;
                debug_assert!(x < tile_w, "edge {j}->{to} exceeds the tile width");
                eidx[j * tile_w + x] = e as u32;
                let p = phmm.out_prob[e] as f64;
                let emit = &phmm.emissions[to * sigma..(to + 1) * sigma];
                for (s, &e_s) in emit.iter().enumerate() {
                    coef[s * n * tile_w + j * tile_w + x] = p * e_s as f64;
                }
            }
        }
        OutTiles { n, sigma, tile_w, coef, eidx }
    }

    /// Tile row width (`Lowering::tile_width`).
    #[inline]
    pub fn tile_width(&self) -> usize {
        self.tile_w
    }

    /// `(N, Σ)` the tiles were built for.
    pub fn shape(&self) -> (usize, usize) {
        (self.n, self.sigma)
    }

    /// The outgoing tile rows of symbol `s`, row-major `[N × tile_w]`.
    #[inline]
    pub(super) fn coef_for(&self, s: usize) -> &[f64] {
        &self.coef[s * self.n * self.tile_w..(s + 1) * self.n * self.tile_w]
    }

    /// The edge-index map `[N × tile_w]` (`u32::MAX` = no edge).
    #[inline]
    pub(super) fn eidx(&self) -> &[u32] {
        &self.eidx
    }
}

#[cfg(test)]
mod tests {
    use super::super::kernels::FusedCoeffs;
    use super::super::lowering::Lowering;
    use super::*;
    use crate::phmm::EcDesignParams;
    use crate::seq::Sequence;
    use crate::sim::XorShift;
    use crate::testutil;

    fn ec_graph(rng: &mut XorShift, len: usize) -> Phmm {
        let data = testutil::random_seq(rng, len, 4);
        Phmm::error_correction(&Sequence::from_symbols("r", data), &EcDesignParams::default())
            .unwrap()
    }

    #[test]
    fn tiles_carry_the_csr_products_bit_for_bit() {
        testutil::check(10, |rng| {
            let len = rng.range(4, 30);
            let g = ec_graph(rng, len);
            let low = Lowering::freeze(&g);
            let tiles = DenseTiles::new(&low, &g);
            assert_eq!(tiles.shape(), (g.n_states(), g.sigma()));
            assert_eq!(tiles.tile_width(), low.tile_width());
            let pad = low.gather_pad();
            let tw = tiles.tile_width();
            for s in 0..g.sigma() {
                let tc = tiles.coef_for(s);
                let mut nz = 0usize;
                for to in 0..g.n_states() {
                    for slot in low.in_ptr[to] as usize..low.in_ptr[to + 1] as usize {
                        let from = low.in_from[slot] as usize;
                        let x = pad - (to - from);
                        let want = g.out_prob[low.in_eidx[slot] as usize]
                            * g.emission(to, s as u8);
                        let got = tc[to * tw + x];
                        assert_eq!(got.to_bits(), want.to_bits(), "to={to} slot={slot} s={s}");
                        if got != 0.0 {
                            nz += 1;
                        }
                    }
                }
                // Every nonzero tile entry corresponds to an edge slot.
                let total_nz = tc.iter().filter(|&&v| v != 0.0).count();
                assert_eq!(total_nz, nz, "stray nonzero tile entries for symbol {s}");
            }
        });
    }

    #[test]
    fn tiles_match_the_fused_csr_tables() {
        // The two lowerings of the same freeze hold bit-identical
        // coefficients slot for slot.
        let mut rng = XorShift::new(29);
        let g = ec_graph(&mut rng, 40);
        let coeffs = FusedCoeffs::new(&g);
        let low = coeffs.lowering();
        let tiles = coeffs.tiles_for(&g);
        assert!(
            std::ptr::eq(tiles, coeffs.tiles_for(&g)),
            "tiles must be cached after the first build"
        );
        let pad = low.gather_pad();
        let tw = tiles.tile_width();
        for s in 0..g.sigma() {
            let csr = coeffs.in_coef_for(s);
            let tc = tiles.coef_for(s);
            for to in 0..g.n_states() {
                for slot in low.in_ptr[to] as usize..low.in_ptr[to + 1] as usize {
                    let from = low.in_from[slot] as usize;
                    let x = pad - (to - from);
                    assert_eq!(csr[slot].to_bits(), tc[to * tw + x].to_bits());
                }
            }
        }
    }

    #[test]
    fn out_tiles_mirror_the_outgoing_tables_bit_for_bit() {
        // The backward's out-tile lowering carries exactly the fused
        // out_coef products (same operands, same f64 widening multiply)
        // at column x = to − j, an edge index everywhere a real edge
        // lives, and strict zeros elsewhere — the three facts the
        // tile-granular backward's bitwise argument rests on.
        let mut rng = XorShift::new(41);
        let g = ec_graph(&mut rng, 35);
        let coeffs = FusedCoeffs::new(&g);
        let ot = OutTiles::new(coeffs.lowering(), &g);
        assert_eq!(ot.shape(), (g.n_states(), g.sigma()));
        let tw = ot.tile_width();
        let mut edges_seen = 0usize;
        for j in 0..g.n_states() {
            for e in g.out_ptr[j] as usize..g.out_ptr[j + 1] as usize {
                let to = g.out_to[e] as usize;
                let x = to - j;
                assert_eq!(ot.eidx()[j * tw + x], e as u32, "edge {j}->{to}");
                edges_seen += 1;
                for s in 0..g.sigma() {
                    assert_eq!(
                        ot.coef_for(s)[j * tw + x].to_bits(),
                        coeffs.out_coef_for(s)[e].to_bits(),
                        "edge {e} symbol {s}"
                    );
                }
            }
        }
        let mapped = ot.eidx().iter().filter(|&&e| e != u32::MAX).count();
        assert_eq!(mapped, edges_seen, "eidx map must cover exactly the edge set");
        for s in 0..g.sigma() {
            let tc = ot.coef_for(s);
            for (i, &e) in ot.eidx().iter().enumerate() {
                if e == u32::MAX {
                    assert_eq!(tc[i].to_bits(), 0.0f64.to_bits(), "cell {i} symbol {s}");
                }
            }
        }
    }

    #[test]
    fn ec_tiles_are_structurally_dense_enough_to_matter() {
        let mut rng = XorShift::new(31);
        let g = ec_graph(&mut rng, 60);
        let low = Lowering::freeze(&g);
        // Fig. 4's point: within the band the structure is far denser
        // than the N×N matrix (occupancy ~ mean in-degree / tile_w).
        assert!(low.tile_occupancy() > 0.1, "occupancy {}", low.tile_occupancy());
    }
}
