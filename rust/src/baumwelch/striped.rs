//! Striped multi-read forward kernels: `K` same-profile reads advance
//! through one pass over the shared [`FusedCoeffs`]/tile tables.
//!
//! The shape follows CUDAMPF++-style register striping on a CPU: the
//! per-read dense gather buffers are interleaved read-minor in one
//! striped buffer (`slot i` of read `r` at `i · K + r`), so the
//! dense-tile dot product loads contiguous `K`-wide spans and
//! broadcasts one coefficient — the layout that vectorizes *across*
//! reads ([`simd::dot_tile_striped`]) — and every coefficient-table
//! cache line fetched for one read is reused by the other `K − 1`.
//!
//! **Reproducibility contract:** per read, the results are
//! *bit-identical* to running that read alone through
//! [`forward_sparse_with`]/[`score_sparse_with`] at the same lane
//! width.  Every per-read decision (window bounds, tile admission,
//! filter, scaling, death) uses the solo formulas on the read's own
//! rows; the striped dot product replicates the solo lane assignment
//! and reduction tree per read; and the CSR fallback stays a scalar
//! ascending-source walk.  Reads are processed in lock-step timestep
//! order with **no reordering**, ragged lengths are tail-masked (a
//! finished or dead read simply stops scattering), and a read that
//! dies mid-pass yields the same `forward died at t=…` error as the
//! solo kernel while the rest of the stripe continues.
//!
//! Callers pass at most [`MAX_STRIPE`] reads per call; the engine's
//! batch entry points chunk larger batches.

use super::filter::FilterStats;
use super::kernels::{ForwardScratch, FusedCoeffs};
use super::lowering::GatherKind;
use super::simd::{self, SimdLanes, MAX_STRIPE};
use super::sparse::{
    apply_filter, init_row, may_dispatch_tiles, precheck, row_admits_tile, ForwardOptions,
    ForwardResult, ScoreResult, SparseRow,
};
use super::EPS;
use crate::error::{ApHmmError, Result};
use crate::phmm::Phmm;
use crate::seq::Sequence;

/// Per-read outcome of one striped timestep.
#[derive(Clone, Copy, Default)]
struct StepOut {
    /// Unscaled row sum `c` (0.0 for masked slots).
    c: f32,
    /// In-window edge count (the workload metric).
    edges: u64,
    /// Whether the tile kernel produced this read's row.
    used_tile: bool,
}

/// Advance every live read by one timestep: scatter the previous rows
/// into the striped buffer, gather each read's window (tile-admitted
/// reads grouped by symbol through [`simd::dot_tile_striped`], the
/// rest through a per-read scalar CSR walk), and restore the buffer to
/// all-zero.  `cur[r]` receives read `r`'s unscaled row.
#[allow(clippy::too_many_arguments)]
fn striped_step<'a>(
    coeffs: &FusedCoeffs,
    striped: &mut [f32],
    k: usize,
    live: &[usize],
    prev_of: impl Fn(usize) -> &'a SparseRow,
    syms: &[usize; MAX_STRIPE],
    n: usize,
    gather: GatherKind,
    lanes: SimdLanes,
    cur: &mut [SparseRow],
) -> [StepOut; MAX_STRIPE] {
    let low = coeffs.lowering();
    let tw = low.tile_width();
    let pad = tw - 1;
    // Scatter: same slot layout as the solo dense buffer, striped by k.
    for &r in live {
        let prev = prev_of(r);
        for (&i, &v) in prev.idx.iter().zip(prev.val.iter()) {
            striped[(i as usize + pad) * k + r] = v;
        }
    }

    let mut out = [StepOut::default(); MAX_STRIPE];
    let mut win_lo = [0usize; MAX_STRIPE];
    let mut win_hi = [0usize; MAX_STRIPE];
    let mut tile = [false; MAX_STRIPE];
    for &r in live {
        let prev = prev_of(r);
        // Solo window formulas (`gather_row`), per read.
        let first = prev.idx.first().map(|&i| i as usize).unwrap_or(0);
        let last = prev.idx.last().map(|&i| i as usize).unwrap_or(0);
        win_lo[r] = first;
        win_hi[r] = if prev.idx.is_empty() { 0 } else { (last + low.band).min(n) };
        tile[r] = row_admits_tile(coeffs, gather, prev, first, last);
        let row = &mut cur[r];
        row.idx.clear();
        row.val.clear();
        row.idx.reserve(win_hi[r].saturating_sub(win_lo[r]));
        row.val.reserve(win_hi[r].saturating_sub(win_lo[r]));
        out[r].edges = (low.in_ptr[win_hi[r]] - low.in_ptr[win_lo[r]]) as u64;
        out[r].used_tile = tile[r];
    }

    // Tile-admitted reads, grouped by symbol (the tile table is
    // per-symbol): one sweep over the group's union window computes all
    // members' dot products per target; each member consumes only the
    // targets inside its own window, in ascending order — the same
    // (value, order) sequence as its solo `gather_tile`.
    let mut grouped = [false; MAX_STRIPE];
    for (gi, &r0) in live.iter().enumerate() {
        if !tile[r0] || grouped[r0] {
            continue;
        }
        let s = syms[r0];
        let mut members = [0usize; MAX_STRIPE];
        let mut m = 0usize;
        let (mut lo, mut hi) = (usize::MAX, 0usize);
        for &r in &live[gi..] {
            if tile[r] && !grouped[r] && syms[r] == s {
                grouped[r] = true;
                members[m] = r;
                m += 1;
                lo = lo.min(win_lo[r]);
                hi = hi.max(win_hi[r]);
            }
        }
        let tiles = coeffs.tile_coef_for(s);
        let mut accs = [0.0f32; MAX_STRIPE];
        for to in lo..hi {
            let row = &tiles[to * tw..(to + 1) * tw];
            simd::dot_tile_striped(&striped[to * k..(to + tw) * k], row, k, lanes, &mut accs[..k]);
            for &r in &members[..m] {
                if to >= win_lo[r] && to < win_hi[r] {
                    let acc = accs[r];
                    if acc > 0.0 {
                        cur[r].idx.push(to as u32);
                        cur[r].val.push(acc);
                        out[r].c += acc;
                    }
                }
            }
        }
    }

    // CSR fallback reads: the solo indexed gather, reading this read's
    // stripe — scalar under every lane policy, so bitwise regardless of
    // width (matching `gather_csr`).
    for &r in live {
        if tile[r] {
            continue;
        }
        let coef = coeffs.in_coef_for(syms[r]);
        let mut c = 0.0f32;
        // SAFETY: same invariants as `gather_csr` — validated incoming
        // CSR, window bounds clamped to n, the striped buffer is sized
        // `(n + pad) · k` by the entry points, and precheck guarantees
        // the symbol is < Σ so `coef` covers every slot index.
        unsafe {
            for to in win_lo[r]..win_hi[r] {
                let lo_e = *low.in_ptr.get_unchecked(to) as usize;
                let hi_e = *low.in_ptr.get_unchecked(to + 1) as usize;
                let mut acc = 0.0f32;
                for e in lo_e..hi_e {
                    let from = *low.in_from.get_unchecked(e) as usize;
                    acc +=
                        *striped.get_unchecked((from + pad) * k + r) * *coef.get_unchecked(e);
                }
                if acc > 0.0 {
                    cur[r].idx.push(to as u32);
                    cur[r].val.push(acc);
                    c += acc;
                }
            }
        }
        out[r].c += c;
    }

    // Restore the all-zero invariant (also for reads that just died —
    // they were scattered above).
    for &r in live {
        let prev = prev_of(r);
        for &i in prev.idx.iter() {
            striped[(i as usize + pad) * k + r] = 0.0;
        }
    }
    out
}

/// Striped multi-read training forward: every read's scaled rows are
/// materialized, per-read bit-identical to [`forward_sparse_with`] at
/// the same lane width.  Per-read errors (precheck failures, dead
/// reads) are reported in the matching output slot; the rest of the
/// stripe completes normally.
pub fn forward_striped_with(
    phmm: &Phmm,
    coeffs: &FusedCoeffs,
    reads: &[&Sequence],
    opts: &ForwardOptions,
    scratch: &mut ForwardScratch,
) -> Vec<Result<ForwardResult>> {
    let k = reads.len();
    assert!(k <= MAX_STRIPE, "striped kernels take at most MAX_STRIPE reads per call");
    if k == 0 {
        return Vec::new();
    }
    let n = phmm.n_states();
    let lanes = opts.simd.resolve();
    scratch.ensure(n + coeffs.gather_pad());
    scratch.ensure_hist(&opts.filter);
    scratch.ensure_striped((n + coeffs.gather_pad()) * k);
    if may_dispatch_tiles(coeffs, opts.gather) {
        coeffs.tiles_for(phmm);
    }

    struct Lane {
        rows: Vec<SparseRow>,
        scales: Vec<f32>,
        loglik: f64,
        stats: FilterStats,
        states_processed: u64,
        edges_processed: u64,
        err: Option<ApHmmError>,
    }

    let mut lanes_state: Vec<Lane> = Vec::with_capacity(k);
    for &read in reads {
        let err = precheck(phmm, coeffs, read).err();
        let mut lane = Lane {
            rows: scratch.take_rows_vec(),
            scales: scratch.take_scales_vec(),
            loglik: 0.0,
            stats: FilterStats::default(),
            states_processed: 0,
            edges_processed: 0,
            err,
        };
        if lane.err.is_none() {
            lane.rows.reserve(read.len());
            lane.scales.reserve(read.len());
        }
        lanes_state.push(lane);
    }

    // t = 0: the solo init row, per read (no striping needed — the
    // initial distribution involves no gather).
    for (r, &read) in reads.iter().enumerate() {
        let lane = &mut lanes_state[r];
        if lane.err.is_some() {
            continue;
        }
        let mut row = scratch.take_row();
        match init_row(phmm, coeffs, read.data[0], &mut row) {
            Ok(c) => {
                let inv = 1.0 / c;
                row.val.iter_mut().for_each(|v| *v *= inv);
                apply_filter(
                    &opts.filter,
                    &mut scratch.hist,
                    &mut row.idx,
                    &mut row.val,
                    &mut lane.stats,
                );
                lane.states_processed += row.len() as u64;
                lane.scales.push(c);
                lane.loglik += (c as f64).ln();
                lane.rows.push(row);
            }
            Err(e) => {
                scratch.put_row(row);
                lane.err = Some(e);
            }
        }
    }

    let max_len = reads.iter().map(|s| s.len()).max().unwrap_or(0);
    let mut striped = std::mem::take(&mut scratch.striped);
    let mut cur: Vec<SparseRow> = (0..k).map(|_| scratch.take_row()).collect();
    let mut syms = [0usize; MAX_STRIPE];
    let mut live: Vec<usize> = Vec::with_capacity(k);
    for t in 1..max_len {
        live.clear();
        for (r, &read) in reads.iter().enumerate() {
            if lanes_state[r].err.is_none() && t < read.len() {
                live.push(r);
                syms[r] = read.data[t] as usize;
            }
        }
        if live.is_empty() {
            break;
        }
        let step = striped_step(
            coeffs,
            &mut striped,
            k,
            &live,
            |r| lanes_state[r].rows.last().expect("live lanes have a previous row"),
            &syms,
            n,
            opts.gather,
            lanes,
            &mut cur,
        );
        for &r in &live {
            let StepOut { c, edges, used_tile } = step[r];
            let lane = &mut lanes_state[r];
            lane.edges_processed += edges;
            if used_tile {
                lane.stats.rows_dense_tile += 1;
            } else {
                lane.stats.rows_csr += 1;
            }
            if c <= EPS {
                lane.err = Some(ApHmmError::Numerical(format!("forward died at t={t}")));
                // The dead lane's partially-built row slot is reused.
                continue;
            }
            let inv = 1.0 / c;
            let row = &mut cur[r];
            row.val.iter_mut().for_each(|v| *v *= inv);
            apply_filter(
                &opts.filter,
                &mut scratch.hist,
                &mut row.idx,
                &mut row.val,
                &mut lane.stats,
            );
            lane.states_processed += row.len() as u64;
            lane.scales.push(c);
            lane.loglik += (c as f64).ln();
            lane.rows.push(std::mem::take(row));
        }
    }
    scratch.striped = striped;
    for row in cur {
        scratch.put_row(row);
    }

    let mut out = Vec::with_capacity(k);
    for lane in lanes_state {
        match lane.err {
            Some(e) => {
                // Return the partial buffers to the pools.
                scratch.recycle(ForwardResult {
                    rows: lane.rows,
                    scales: lane.scales,
                    loglik: 0.0,
                    filter_stats: FilterStats::default(),
                    states_processed: 0,
                    edges_processed: 0,
                });
                out.push(Err(e));
            }
            None => out.push(Ok(ForwardResult {
                rows: lane.rows,
                scales: lane.scales,
                loglik: lane.loglik,
                filter_stats: lane.stats,
                states_processed: lane.states_processed,
                edges_processed: lane.edges_processed,
            })),
        }
    }
    out
}

/// Striped multi-read score fast path: per-read bit-identical to
/// [`score_sparse_with`] at the same lane width, with only two live
/// rows per read — memory stays `O(K · active states)` regardless of
/// read length (the serving layer's Score micro-batch kernel).
pub fn score_striped_with(
    phmm: &Phmm,
    coeffs: &FusedCoeffs,
    reads: &[&Sequence],
    opts: &ForwardOptions,
    scratch: &mut ForwardScratch,
) -> Vec<Result<ScoreResult>> {
    let k = reads.len();
    assert!(k <= MAX_STRIPE, "striped kernels take at most MAX_STRIPE reads per call");
    if k == 0 {
        return Vec::new();
    }
    let n = phmm.n_states();
    let lanes = opts.simd.resolve();
    scratch.ensure(n + coeffs.gather_pad());
    scratch.ensure_hist(&opts.filter);
    scratch.ensure_striped((n + coeffs.gather_pad()) * k);
    if may_dispatch_tiles(coeffs, opts.gather) {
        coeffs.tiles_for(phmm);
    }

    struct Lane {
        loglik: f64,
        stats: FilterStats,
        states_processed: u64,
        edges_processed: u64,
        err: Option<ApHmmError>,
    }

    let mut lanes_state: Vec<Lane> = reads
        .iter()
        .map(|read| Lane {
            loglik: 0.0,
            stats: FilterStats::default(),
            states_processed: 0,
            edges_processed: 0,
            err: precheck(phmm, coeffs, read).err(),
        })
        .collect();

    let mut prev: Vec<SparseRow> = (0..k).map(|_| scratch.take_row()).collect();
    let mut cur: Vec<SparseRow> = (0..k).map(|_| scratch.take_row()).collect();

    for (r, &read) in reads.iter().enumerate() {
        let lane = &mut lanes_state[r];
        if lane.err.is_some() {
            continue;
        }
        match init_row(phmm, coeffs, read.data[0], &mut prev[r]) {
            Ok(c) => {
                let inv = 1.0 / c;
                prev[r].val.iter_mut().for_each(|v| *v *= inv);
                apply_filter(
                    &opts.filter,
                    &mut scratch.hist,
                    &mut prev[r].idx,
                    &mut prev[r].val,
                    &mut lane.stats,
                );
                lane.states_processed += prev[r].len() as u64;
                lane.loglik += (c as f64).ln();
            }
            Err(e) => lane.err = Some(e),
        }
    }

    let max_len = reads.iter().map(|s| s.len()).max().unwrap_or(0);
    let mut striped = std::mem::take(&mut scratch.striped);
    let mut syms = [0usize; MAX_STRIPE];
    let mut live: Vec<usize> = Vec::with_capacity(k);
    for t in 1..max_len {
        live.clear();
        for (r, &read) in reads.iter().enumerate() {
            if lanes_state[r].err.is_none() && t < read.len() {
                live.push(r);
                syms[r] = read.data[t] as usize;
            }
        }
        if live.is_empty() {
            break;
        }
        let step = striped_step(
            coeffs,
            &mut striped,
            k,
            &live,
            |r| &prev[r],
            &syms,
            n,
            opts.gather,
            lanes,
            &mut cur,
        );
        for &r in &live {
            let StepOut { c, edges, used_tile } = step[r];
            let lane = &mut lanes_state[r];
            lane.edges_processed += edges;
            if used_tile {
                lane.stats.rows_dense_tile += 1;
            } else {
                lane.stats.rows_csr += 1;
            }
            if c <= EPS {
                lane.err = Some(ApHmmError::Numerical(format!("forward died at t={t}")));
                continue;
            }
            let inv = 1.0 / c;
            let row = &mut cur[r];
            row.val.iter_mut().for_each(|v| *v *= inv);
            apply_filter(
                &opts.filter,
                &mut scratch.hist,
                &mut row.idx,
                &mut row.val,
                &mut lane.stats,
            );
            lane.states_processed += row.len() as u64;
            lane.loglik += (c as f64).ln();
            std::mem::swap(&mut prev[r], &mut cur[r]);
        }
    }
    scratch.striped = striped;
    for row in prev.into_iter().chain(cur) {
        scratch.put_row(row);
    }

    lanes_state
        .into_iter()
        .map(|lane| match lane.err {
            Some(e) => Err(e),
            None => Ok(ScoreResult {
                loglik: lane.loglik,
                filter_stats: lane.stats,
                states_processed: lane.states_processed,
                edges_processed: lane.edges_processed,
            }),
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::baumwelch::filter::FilterConfig;
    use crate::baumwelch::sparse::{forward_sparse_with, score_sparse_with};
    use crate::baumwelch::SimdPolicy;
    use crate::phmm::EcDesignParams;
    use crate::sim::XorShift;
    use crate::testutil;

    fn ec_graph(rng: &mut XorShift, len: usize) -> Phmm {
        let data = testutil::random_seq(rng, len, 4);
        Phmm::error_correction(&Sequence::from_symbols("r", data), &EcDesignParams::default())
            .unwrap()
    }

    fn ragged_reads(rng: &mut XorShift, lens: &[usize]) -> Vec<Sequence> {
        lens.iter()
            .enumerate()
            .map(|(i, &l)| {
                Sequence::from_symbols(format!("r{i}"), testutil::random_seq(rng, l, 4))
            })
            .collect()
    }

    fn assert_rows_bitwise(a: &ForwardResult, b: &ForwardResult, tag: &str) {
        assert_eq!(a.loglik.to_bits(), b.loglik.to_bits(), "{tag}: loglik");
        assert_eq!(a.rows.len(), b.rows.len(), "{tag}: row count");
        assert_eq!(a.states_processed, b.states_processed, "{tag}");
        assert_eq!(a.edges_processed, b.edges_processed, "{tag}");
        assert_eq!(a.filter_stats.rows_dense_tile, b.filter_stats.rows_dense_tile, "{tag}");
        assert_eq!(a.filter_stats.rows_csr, b.filter_stats.rows_csr, "{tag}");
        for (t, (x, y)) in a.rows.iter().zip(b.rows.iter()).enumerate() {
            assert_eq!(x.idx, y.idx, "{tag}: active set at t={t}");
            for (u, v) in x.val.iter().zip(y.val.iter()) {
                assert_eq!(u.to_bits(), v.to_bits(), "{tag}: value at t={t}");
            }
        }
        for (t, (x, y)) in a.scales.iter().zip(b.scales.iter()).enumerate() {
            assert_eq!(x.to_bits(), y.to_bits(), "{tag}: scale at t={t}");
        }
    }

    #[test]
    fn striped_forward_is_bit_identical_to_solo() {
        // Per read, the striped pass must reproduce the solo pass to
        // the bit — for every gather kind, every lane width, ragged
        // lengths, filters on and off, on both a filter-friendly EC
        // graph and a tile-admitting dense band.
        let mut rng = XorShift::new(41);
        let graphs = [ec_graph(&mut rng, 30), testutil::dense_band_phmm(32)];
        let reads = ragged_reads(&mut rng, &[9, 1, 17, 4, 25, 12, 2, 20]);
        let read_refs: Vec<&Sequence> = reads.iter().collect();
        for g in &graphs {
            for gather in [GatherKind::Csr, GatherKind::DenseTile, GatherKind::Adaptive] {
                for policy in [SimdPolicy::Scalar, SimdPolicy::F32x4, SimdPolicy::F32x8] {
                    for filter in [FilterConfig::None, FilterConfig::Sort { size: 24 }] {
                        let opts =
                            ForwardOptions { filter, gather, simd: policy, ..Default::default() };
                        let coeffs = FusedCoeffs::new(g);
                        let mut scratch = ForwardScratch::new(g);
                        let batch =
                            forward_striped_with(g, &coeffs, &read_refs, &opts, &mut scratch);
                        assert_eq!(batch.len(), reads.len());
                        for (read, got) in reads.iter().zip(batch) {
                            let solo =
                                forward_sparse_with(g, &coeffs, read, &opts, &mut scratch)
                                    .unwrap();
                            let got = got.unwrap();
                            let tag = format!(
                                "{:?}/{:?}/{:?}/{}",
                                gather, policy, filter, read.id
                            );
                            assert_rows_bitwise(&got, &solo, &tag);
                            scratch.recycle(solo);
                            scratch.recycle(got);
                        }
                    }
                }
            }
        }
    }

    #[test]
    fn striped_score_is_bit_identical_to_solo() {
        let mut rng = XorShift::new(43);
        let g = testutil::dense_band_phmm(28);
        let reads = ragged_reads(&mut rng, &[5, 14, 1, 22, 8]);
        let read_refs: Vec<&Sequence> = reads.iter().collect();
        for policy in [SimdPolicy::Scalar, SimdPolicy::F32x4, SimdPolicy::F32x8] {
            let opts = ForwardOptions { simd: policy, ..Default::default() };
            let coeffs = FusedCoeffs::new(&g);
            let mut scratch = ForwardScratch::new(&g);
            let batch = score_striped_with(&g, &coeffs, &read_refs, &opts, &mut scratch);
            for (read, got) in reads.iter().zip(batch) {
                let solo = score_sparse_with(&g, &coeffs, read, &opts, &mut scratch).unwrap();
                let got = got.unwrap();
                assert_eq!(got.loglik.to_bits(), solo.loglik.to_bits(), "{:?}", read.id);
                assert_eq!(got.states_processed, solo.states_processed);
                assert_eq!(got.edges_processed, solo.edges_processed);
            }
        }
    }

    #[test]
    fn per_read_errors_do_not_poison_the_stripe() {
        // An invalid read (symbol outside the alphabet) and an empty
        // read fail in their own slots with the solo error messages;
        // the surviving reads stay bit-identical to solo runs.
        let mut rng = XorShift::new(47);
        let g = ec_graph(&mut rng, 20);
        let good1 = Sequence::from_symbols("g1", testutil::random_seq(&mut rng, 12, 4));
        let bad = Sequence::from_symbols("bad", vec![0, 1, 9, 2]);
        let empty = Sequence::from_symbols("empty", Vec::new());
        let good2 = Sequence::from_symbols("g2", testutil::random_seq(&mut rng, 7, 4));
        let reads: Vec<&Sequence> = vec![&good1, &bad, &empty, &good2];
        let opts = ForwardOptions { simd: SimdPolicy::Scalar, ..Default::default() };
        let coeffs = FusedCoeffs::new(&g);
        let mut scratch = ForwardScratch::new(&g);
        let batch = forward_striped_with(&g, &coeffs, &reads, &opts, &mut scratch);
        assert!(batch[1].is_err(), "alphabet violation must fail its slot");
        assert!(batch[2].is_err(), "empty read must fail its slot");
        for (i, read) in [(0usize, &good1), (3usize, &good2)] {
            let solo = forward_sparse_with(&g, &coeffs, read, &opts, &mut scratch).unwrap();
            let got = batch[i].as_ref().unwrap();
            assert_eq!(got.loglik.to_bits(), solo.loglik.to_bits());
            assert_eq!(got.rows.len(), solo.rows.len());
            scratch.recycle(solo);
        }
    }

    #[test]
    fn empty_batch_is_a_no_op() {
        let mut rng = XorShift::new(53);
        let g = ec_graph(&mut rng, 10);
        let coeffs = FusedCoeffs::new(&g);
        let mut scratch = ForwardScratch::new(&g);
        let opts = ForwardOptions::default();
        assert!(forward_striped_with(&g, &coeffs, &[], &opts, &mut scratch).is_empty());
        assert!(score_striped_with(&g, &coeffs, &[], &opts, &mut scratch).is_empty());
    }
}
