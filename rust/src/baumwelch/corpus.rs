//! Corpus layer: where training reads come from.
//!
//! [`ReadSource`] decouples the training schedule (train.rs) from read
//! residency. Full-batch EM over an in-memory slice and minibatch EM
//! over a streaming million-sequence FASTA drive the same loop; only
//! the source differs. The streaming sources ([`FastaSource`],
//! [`FastqSource`]) hold one open file handle and one record at a time
//! — the scheduler's shuffle window, not the corpus size, bounds
//! resident memory.
//!
//! The module also owns minibatch assembly: a seeded Fisher–Yates
//! shuffle over a bounded window (the streaming analogue of a full
//! permutation, as in TF's shuffle buffer) and length bucketing so the
//! E-step's `MAX_STRIPE`-read blocks carry near-equal-length reads and
//! the striped kernels run well-filled lanes.

use std::io::BufReader;
use std::path::{Path, PathBuf};

use crate::error::Result;
use crate::io::{FastaReader, FastqReader};
use crate::seq::{Alphabet, Sequence};
use crate::sim::XorShift;

/// A rewindable stream of training reads.
///
/// `fill` appends up to `max` records and returns how many it appended
/// (0 = exhausted); `reset` rewinds to the first record for the next
/// epoch. Sources must be deterministic: two passes over the same
/// source yield the same records in the same order, which is what makes
/// seeded minibatch training bit-reproducible.
pub trait ReadSource {
    /// Append up to `max` records to `out`; returns the count appended.
    fn fill(&mut self, max: usize, out: &mut Vec<Sequence>) -> Result<usize>;

    /// Rewind to the first record (start of a new epoch).
    fn reset(&mut self) -> Result<()>;

    /// Total record count when known without consuming the source;
    /// `None` for streaming sources. `TrainMode::Auto` keys off this.
    fn len_hint(&self) -> Option<usize> {
        None
    }
}

/// In-memory source over a slice — the adapter that lets the slice API
/// (`train(&[Sequence], ..)`) run through the source-based schedules.
pub struct MemorySource<'a> {
    reads: &'a [Sequence],
    pos: usize,
}

impl<'a> MemorySource<'a> {
    pub fn new(reads: &'a [Sequence]) -> Self {
        MemorySource { reads, pos: 0 }
    }
}

impl ReadSource for MemorySource<'_> {
    fn fill(&mut self, max: usize, out: &mut Vec<Sequence>) -> Result<usize> {
        let take = max.min(self.reads.len() - self.pos);
        out.extend_from_slice(&self.reads[self.pos..self.pos + take]);
        self.pos += take;
        Ok(take)
    }

    fn reset(&mut self) -> Result<()> {
        self.pos = 0;
        Ok(())
    }

    fn len_hint(&self) -> Option<usize> {
        Some(self.reads.len())
    }
}

/// Streaming FASTA source: one open handle, record-at-a-time decode,
/// `reset` reopens the file. Never materializes the corpus.
pub struct FastaSource {
    path: PathBuf,
    alphabet: Alphabet,
    reader: Option<FastaReader<BufReader<std::fs::File>>>,
}

impl FastaSource {
    /// Open `path` for streaming; a bad path fails here, not mid-epoch.
    pub fn open(path: &Path, alphabet: Alphabet) -> Result<Self> {
        let reader = FastaReader::open(path, alphabet)?;
        Ok(FastaSource { path: path.to_path_buf(), alphabet, reader: Some(reader) })
    }
}

impl ReadSource for FastaSource {
    fn fill(&mut self, max: usize, out: &mut Vec<Sequence>) -> Result<usize> {
        let mut n = 0;
        while n < max {
            let Some(reader) = self.reader.as_mut() else { break };
            match reader.next_record()? {
                Some(seq) => {
                    out.push(seq);
                    n += 1;
                }
                None => self.reader = None,
            }
        }
        Ok(n)
    }

    fn reset(&mut self) -> Result<()> {
        self.reader = Some(FastaReader::open(&self.path, self.alphabet)?);
        Ok(())
    }
}

/// Streaming FASTQ source; qualities are dropped (the pHMM pipeline
/// never consumes them).
pub struct FastqSource {
    path: PathBuf,
    alphabet: Alphabet,
    reader: Option<FastqReader<BufReader<std::fs::File>>>,
}

impl FastqSource {
    /// Open `path` for streaming; a bad path fails here, not mid-epoch.
    pub fn open(path: &Path, alphabet: Alphabet) -> Result<Self> {
        let reader = FastqReader::open(path, alphabet)?;
        Ok(FastqSource { path: path.to_path_buf(), alphabet, reader: Some(reader) })
    }
}

impl ReadSource for FastqSource {
    fn fill(&mut self, max: usize, out: &mut Vec<Sequence>) -> Result<usize> {
        let mut n = 0;
        while n < max {
            let Some(reader) = self.reader.as_mut() else { break };
            match reader.next_record()? {
                Some((seq, _qual)) => {
                    out.push(seq);
                    n += 1;
                }
                None => self.reader = None,
            }
        }
        Ok(n)
    }

    fn reset(&mut self) -> Result<()> {
        self.reader = Some(FastqReader::open(&self.path, self.alphabet)?);
        Ok(())
    }
}

/// RNG for one epoch's shuffle: a distinct, deterministic xorshift
/// stream per `(seed, epoch)` so epochs reshuffle differently while the
/// whole run stays a pure function of the seed.
pub fn epoch_rng(seed: u64, epoch: usize) -> XorShift {
    XorShift::new(seed ^ (epoch as u64 + 1).wrapping_mul(0x9E37_79B9_7F4A_7C15))
}

/// In-place Fisher–Yates over one shuffle window.
pub fn shuffle_window(items: &mut [Sequence], rng: &mut XorShift) {
    for i in (1..items.len()).rev() {
        let j = rng.below(i + 1);
        items.swap(i, j);
    }
}

/// Length-bucket one minibatch: stable sort, longest first, so each
/// `MAX_STRIPE`-read E-step block holds near-equal-length reads and no
/// stripe lane idles behind a long straggler.
pub fn bucket_by_length(batch: &mut [Sequence]) {
    batch.sort_by(|a, b| b.len().cmp(&a.len()));
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::io::write_fasta;
    use crate::seq::DNA;

    fn seqs(lens: &[usize]) -> Vec<Sequence> {
        lens.iter()
            .enumerate()
            .map(|(i, &l)| Sequence::from_symbols(format!("s{i}"), vec![0u8; l]))
            .collect()
    }

    #[test]
    fn memory_source_fills_and_resets() {
        let reads = seqs(&[3, 4, 5, 6, 7]);
        let mut src = MemorySource::new(&reads);
        assert_eq!(src.len_hint(), Some(5));
        let mut out = Vec::new();
        assert_eq!(src.fill(2, &mut out).unwrap(), 2);
        assert_eq!(src.fill(10, &mut out).unwrap(), 3);
        assert_eq!(src.fill(10, &mut out).unwrap(), 0);
        assert_eq!(out, reads);
        src.reset().unwrap();
        let mut again = Vec::new();
        assert_eq!(src.fill(100, &mut again).unwrap(), 5);
        assert_eq!(again, reads);
    }

    #[test]
    fn fasta_source_streams_and_resets() {
        let dir = std::env::temp_dir().join("aphmm_corpus_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("corpus.fa");
        let reads = vec![
            Sequence::from_str("a", "ACGT", DNA).unwrap(),
            Sequence::from_str("b", "TTTTTT", DNA).unwrap(),
            Sequence::from_str("c", "GG", DNA).unwrap(),
        ];
        let mut buf = Vec::new();
        write_fasta(&mut buf, &reads, DNA).unwrap();
        std::fs::write(&path, buf).unwrap();

        let mut src = FastaSource::open(&path, DNA).unwrap();
        assert_eq!(src.len_hint(), None);
        let mut out = Vec::new();
        assert_eq!(src.fill(2, &mut out).unwrap(), 2);
        assert_eq!(src.fill(2, &mut out).unwrap(), 1);
        assert_eq!(src.fill(2, &mut out).unwrap(), 0);
        assert_eq!(out, reads);
        src.reset().unwrap();
        let mut again = Vec::new();
        assert_eq!(src.fill(100, &mut again).unwrap(), 3);
        assert_eq!(again, reads);
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn shuffle_is_seed_deterministic() {
        let mut a = seqs(&[1, 2, 3, 4, 5, 6, 7, 8]);
        let mut b = a.clone();
        let mut c = a.clone();
        shuffle_window(&mut a, &mut epoch_rng(7, 0));
        shuffle_window(&mut b, &mut epoch_rng(7, 0));
        shuffle_window(&mut c, &mut epoch_rng(8, 0));
        assert_eq!(a, b);
        assert_ne!(a, c, "different seeds should permute differently");
        // Same seed, different epoch: a different permutation stream.
        let mut d = seqs(&[1, 2, 3, 4, 5, 6, 7, 8]);
        shuffle_window(&mut d, &mut epoch_rng(7, 1));
        assert_ne!(a, d);
    }

    #[test]
    fn bucketing_sorts_longest_first() {
        let mut batch = seqs(&[2, 9, 4, 9, 1]);
        bucket_by_length(&mut batch);
        let lens: Vec<usize> = batch.iter().map(|s| s.len()).collect();
        assert_eq!(lens, vec![9, 9, 4, 2, 1]);
        // Stable: the two length-9 reads keep their input order.
        assert_eq!(batch[0].id, "s1");
        assert_eq!(batch[1].id, "s3");
    }
}
