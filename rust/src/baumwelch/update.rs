//! Parameter-update accumulators (Eq. 3 and Eq. 4) and the fused
//! backward + update pass.
//!
//! Mirrors ApHMM's *partial compute* optimization (§4.3): backward
//! values are consumed into the transition/emission numerators as they
//! are produced (per timestep), so the full backward matrix is never
//! stored.  Expectation sums accumulate across observation sequences;
//! [`BwAccumulators::apply`] performs the maximization division once.

use super::kernels::{ForwardScratch, FusedCoeffs};
use super::sparse::{self, CheckpointedForward, ForwardOptions, ForwardResult, SparseRow};
use super::tile::OutTiles;
use super::EPS;
use crate::error::{ApHmmError, Result};
use crate::phmm::Phmm;
use crate::seq::Sequence;

/// Raw Baum-Welch expectation sums for one pHMM graph.
#[derive(Clone, Debug)]
pub struct BwAccumulators {
    /// ξ sums per CSR edge (aligned with `phmm.out_prob`).
    pub xi: Vec<f64>,
    /// Σ_t<last γ_t(i) per state (Eq. 3 denominator).
    pub trans_den: Vec<f64>,
    /// Emission numerators `[n_states × Σ]` (Eq. 4 numerator).
    pub e_num: Vec<f64>,
    /// Σ_t γ_t(i) per state (Eq. 4 denominator).
    pub gamma_den: Vec<f64>,
    /// Observation sequences accumulated.
    pub n_observations: u64,
    /// Σ log-likelihood of accumulated observations.
    pub total_loglik: f64,
    sigma: usize,
}

impl BwAccumulators {
    /// Zeroed accumulators shaped for `phmm`.
    pub fn new(phmm: &Phmm) -> Self {
        BwAccumulators {
            xi: vec![0.0; phmm.n_transitions()],
            trans_den: vec![0.0; phmm.n_states()],
            e_num: vec![0.0; phmm.n_states() * phmm.sigma()],
            gamma_den: vec![0.0; phmm.n_states()],
            n_observations: 0,
            total_loglik: 0.0,
            sigma: phmm.sigma(),
        }
    }

    /// Reset to zero (reused across EM iterations).
    pub fn reset(&mut self) {
        self.xi.iter_mut().for_each(|x| *x = 0.0);
        self.trans_den.iter_mut().for_each(|x| *x = 0.0);
        self.e_num.iter_mut().for_each(|x| *x = 0.0);
        self.gamma_den.iter_mut().for_each(|x| *x = 0.0);
        self.n_observations = 0;
        self.total_loglik = 0.0;
    }

    /// Merge accumulators from another worker (batch EM across threads).
    pub fn merge(&mut self, other: &BwAccumulators) {
        debug_assert_eq!(self.xi.len(), other.xi.len());
        for (a, b) in self.xi.iter_mut().zip(&other.xi) {
            *a += b;
        }
        for (a, b) in self.trans_den.iter_mut().zip(&other.trans_den) {
            *a += b;
        }
        for (a, b) in self.e_num.iter_mut().zip(&other.e_num) {
            *a += b;
        }
        for (a, b) in self.gamma_den.iter_mut().zip(&other.gamma_den) {
            *a += b;
        }
        self.n_observations += other.n_observations;
        self.total_loglik += other.total_loglik;
    }

    /// Maximization: write updated probabilities into `phmm`.
    ///
    /// States with no accumulated mass keep their prior parameters;
    /// updated rows are renormalized (filtering truncates small amounts
    /// of probability mass, cf. DESIGN.md §Numerics).
    pub fn apply(&self, phmm: &mut Phmm) -> Result<()> {
        if self.n_observations == 0 {
            return Err(ApHmmError::Numerical("apply() with no accumulated observations".into()));
        }
        let n = phmm.n_states();
        // Transitions (Eq. 3).
        for j in 0..n {
            let lo = phmm.out_ptr[j] as usize;
            let hi = phmm.out_ptr[j + 1] as usize;
            if lo == hi || self.trans_den[j] <= EPS as f64 {
                continue;
            }
            let mut row_sum = 0.0f64;
            for e in lo..hi {
                row_sum += self.xi[e];
            }
            if row_sum <= EPS as f64 || !row_sum.is_finite() {
                continue;
            }
            for e in lo..hi {
                phmm.out_prob[e] = (self.xi[e] / row_sum) as f32;
            }
        }
        // Emissions (Eq. 4).
        let sigma = self.sigma;
        for i in 0..n {
            if self.gamma_den[i] <= EPS as f64 {
                continue;
            }
            let row = &self.e_num[i * sigma..(i + 1) * sigma];
            let row_sum: f64 = row.iter().sum();
            if row_sum <= EPS as f64 || !row_sum.is_finite() {
                continue;
            }
            for c in 0..sigma {
                phmm.emissions[i * sigma + c] = (row[c] / row_sum) as f32;
            }
        }
        phmm.validate()
    }

    /// Bookkeeping shared by every accumulate path: one more observation
    /// with log-likelihood `loglik` folded into the running sums.
    pub(super) fn note_observation(&mut self, loglik: f64) {
        self.n_observations += 1;
        self.total_loglik += loglik;
    }

    /// Fused backward + accumulate pass for one observation (Eq. 2 + the
    /// numerator/denominator sums of Eq. 3/4), restricted to the states
    /// the (possibly filtered) forward pass kept active.
    ///
    /// Convenience wrapper that builds throwaway coefficient tables and
    /// scratch; hot paths should use [`BwAccumulators::accumulate_with`].
    pub fn accumulate(
        &mut self,
        phmm: &Phmm,
        seq: &Sequence,
        fwd: &ForwardResult,
    ) -> Result<()> {
        let coeffs = FusedCoeffs::new(phmm);
        let mut scratch = ForwardScratch::new(phmm);
        self.accumulate_with(phmm, &coeffs, seq, fwd, &mut scratch, &ForwardOptions::default())
    }

    /// Memoized fused backward + accumulate pass (paper §4.2–4.3).
    ///
    /// Identical arithmetic to the pre-memoization kernel (the per-edge
    /// product `α_ij · e_{s_{t+1}}(to)` is precomputed in `f64` per
    /// symbol by [`FusedCoeffs`], so the inner loop is a single table
    /// gather and two multiplies per live edge).  The backward row pair
    /// lives in `scratch` and is left zeroed for the next observation.
    ///
    /// When `opts.gather` can dispatch dense tiles, timesteps whose
    /// `t+1` forward row is dense enough (same admission rule as the
    /// forward, [`sparse::row_admits_tile`]) walk the per-symbol
    /// [`OutTiles`](super::tile::OutTiles) mirror instead of the
    /// outgoing CSR lists: contiguous `tile_w` slabs of coefficients
    /// and backward values, no `out_ptr`/`out_to` indirection.  No-edge
    /// cells carry a `+0.0` coefficient and every backward value is
    /// non-negative, so the tile walk is *bit-identical* to the CSR
    /// walk (ascending `to` equals ascending edge order per CSR
    /// validation) under every SIMD lane policy — the backward stays
    /// scalar `f64` by contract.
    pub fn accumulate_with(
        &mut self,
        phmm: &Phmm,
        coeffs: &FusedCoeffs,
        seq: &Sequence,
        fwd: &ForwardResult,
        scratch: &mut ForwardScratch,
        opts: &ForwardOptions,
    ) -> Result<()> {
        let n = phmm.n_states();
        let t_len = seq.len();
        debug_assert_eq!(fwd.rows.len(), t_len);
        // Shape guards: the unchecked inner loop below relies on the
        // accumulator and the tables being built for this exact graph.
        if self.xi.len() != phmm.n_transitions()
            || self.gamma_den.len() != n
            || self.sigma != phmm.sigma()
            || coeffs.n_edges() != phmm.n_transitions()
            || coeffs.sigma() != phmm.sigma()
        {
            return Err(ApHmmError::InvalidGraph(
                "accumulator/coefficient shapes do not match the graph".into(),
            ));
        }
        let sigma = self.sigma;
        // Out-tile mirror for the tile-granular backward.  Built lazily
        // once per freeze, and only when the gather policy can actually
        // dispatch tiles (CSR-only configurations never pay for it).
        let out_tiles = if sparse::may_dispatch_tiles(coeffs, opts.gather) {
            Some(coeffs.out_tiles_for(phmm))
        } else {
            None
        };
        // Dense backward buffers; only active entries are ever nonzero.
        // f64: scaled backward values on low-forward-probability states
        // reach 1/F̂ magnitudes and overflow f32 on badly matching
        // prefixes (mapping slop); f64 keeps the fused pass robust.
        // The gather pad lets the tile walk read `b_next[j..j + tile_w]`
        // without bounds logic: the pad region is never written, so it
        // stays exactly +0.0 and padded terms are bitwise no-ops.
        scratch.ensure(n + coeffs.gather_pad());
        let (b_next, b_cur) = scratch.backward_bufs();
        let mut b_next: &mut [f64] = b_next;
        let mut b_cur: &mut [f64] = b_cur;

        // t = T-1: B̂ = 1 on active states; emission-only γ terms.
        self.backward_last_row(&fwd.rows[t_len - 1], seq.data[t_len - 1] as usize, b_next);

        for t in (0..t_len - 1).rev() {
            let row = &fwd.rows[t];
            let row_next = &fwd.rows[t + 1];
            self.backward_step(
                phmm,
                coeffs,
                opts,
                out_tiles,
                row,
                row_next,
                seq.data[t] as usize,
                seq.data[t + 1] as usize,
                1.0 / (fwd.scales[t + 1] as f64),
                b_next,
                b_cur,
            );
            // Swap buffers; clear what we wrote at t+1.
            for &i in &row_next.idx {
                b_next[i as usize] = 0.0;
            }
            std::mem::swap(&mut b_next, &mut b_cur);
        }
        // Restore the all-zero scratch invariant: after the loop (or for
        // T = 1 directly after the init block) `b_next` holds the t = 0
        // values.
        for &i in &fwd.rows[0].idx {
            b_next[i as usize] = 0.0;
        }
        self.note_observation(fwd.loglik);
        Ok(())
    }

    /// The `t = T-1` initialization of the fused backward: `B̂ = 1` on
    /// the active states, emission-only γ terms.  Shared by the full
    /// and checkpointed sweeps.
    fn backward_last_row(&mut self, row: &SparseRow, s_t: usize, b_next: &mut [f64]) {
        let sigma = self.sigma;
        for (&i, &f) in row.idx.iter().zip(row.val.iter()) {
            b_next[i as usize] = 1.0;
            let gamma = f as f64;
            self.gamma_den[i as usize] += gamma;
            self.e_num[i as usize * sigma + s_t] += gamma;
        }
    }

    /// One fused backward + update timestep: consume `b_next` (values
    /// at `t+1`) over the support of `row` (the forward row at `t`),
    /// producing `b_cur` and the ξ/γ contributions of timestep `t`.
    /// This is the *single* implementation of the per-timestep
    /// arithmetic — the full-matrix sweep ([`accumulate_with`]) and the
    /// checkpointed sweep ([`accumulate_checkpointed_with`]) both call
    /// it, so the two modes are bit-identical by construction.
    ///
    /// The caller owns the buffer choreography: zeroing `b_next` over
    /// `row_next`'s support afterwards and swapping the pair.
    ///
    /// [`accumulate_with`]: BwAccumulators::accumulate_with
    /// [`accumulate_checkpointed_with`]: BwAccumulators::accumulate_checkpointed_with
    #[allow(clippy::too_many_arguments)]
    #[inline]
    fn backward_step(
        &mut self,
        phmm: &Phmm,
        coeffs: &FusedCoeffs,
        opts: &ForwardOptions,
        out_tiles: Option<&OutTiles>,
        row: &SparseRow,
        row_next: &SparseRow,
        s_t: usize,
        s_next: usize,
        inv_c: f64,
        b_next: &mut [f64],
        b_cur: &mut [f64],
    ) {
        let sigma = self.sigma;
        let oc = coeffs.out_coef_for(s_next);
        // Tile admission mirrors the forward dispatcher: the walk
        // below reads `b_next` over the support of row `t+1`, so
        // that row's density is what decides whether padded slab
        // reads beat the CSR indirection.
        let use_tile = match (out_tiles, row_next.idx.first(), row_next.idx.last()) {
            (Some(_), Some(&first), Some(&last)) => sparse::row_admits_tile(
                coeffs,
                opts.gather,
                row_next,
                first as usize,
                last as usize,
            ),
            _ => false,
        };
        if use_tile {
            let ot = out_tiles.expect("use_tile implies out_tiles");
            let tw = ot.tile_width();
            let oc_t = ot.coef_for(s_next);
            let eix = ot.eidx();
            for (&j, &fj) in row.idx.iter().zip(row.val.iter()) {
                let j = j as usize;
                let fj = fj as f64;
                let base = j * tw;
                let mut bsum = 0.0f64;
                // SAFETY: `oc_t`/`eix` span `n_states × tile_w`
                // for the validated graph, `b_next` is padded to
                // `n + tile_w - 1` above, and stored edge indices
                // are < n_edges by construction (u32::MAX marks
                // no-edge cells).  Cells without an edge carry a
                // +0.0 coefficient: `bsum += +0.0` and skipping
                // the ξ write keep the sums bit-identical to the
                // CSR walk in ascending `to` order.
                unsafe {
                    for x in 0..tw {
                        let m = *oc_t.get_unchecked(base + x)
                            * *b_next.get_unchecked(j + x)
                            * inv_c;
                        bsum += m;
                        let e = *eix.get_unchecked(base + x);
                        if e != u32::MAX {
                            *self.xi.get_unchecked_mut(e as usize) += fj * m;
                        }
                    }
                }
                b_cur[j] = bsum;
                let gamma = fj * bsum;
                self.trans_den[j] += gamma;
                self.gamma_den[j] += gamma;
                self.e_num[j * sigma + s_t] += gamma;
            }
        } else {
            for (&j, &fj) in row.idx.iter().zip(row.val.iter()) {
                let j = j as usize;
                let fj = fj as f64;
                let lo = phmm.out_ptr[j] as usize;
                let hi = phmm.out_ptr[j + 1] as usize;
                let mut bsum = 0.0f64;
                // SAFETY: CSR invariants are checked by Phmm::validate;
                // `oc`, `xi` and the backward buffers all cover every
                // edge/state index of the validated graph, and the
                // accumulator shapes are pinned to the graph in `new`.
                unsafe {
                    for e in lo..hi {
                        let to = *phmm.out_to.get_unchecked(e) as usize;
                        let bn = *b_next.get_unchecked(to);
                        if bn == 0.0 {
                            continue;
                        }
                        // Shared product (memoized):
                        // α_{j,to} · e_{s_{t+1}}(to) · B̂_{t+1}(to) / c_{t+1}
                        let m = *oc.get_unchecked(e) * bn * inv_c;
                        bsum += m;
                        *self.xi.get_unchecked_mut(e) += fj * m;
                    }
                }
                b_cur[j] = bsum;
                let gamma = fj * bsum;
                self.trans_den[j] += gamma;
                self.gamma_den[j] += gamma;
                self.e_num[j * sigma + s_t] += gamma;
            }
        }
    }

    /// Checkpointed fused backward + update sweep
    /// ([`ScratchMode::Checkpointed`](super::ScratchMode)): consume a
    /// [`CheckpointedForward`], recomputing each segment's forward rows
    /// from its checkpoint (last segment first) and feeding them
    /// through the same [`backward_step`] arithmetic as the full-matrix
    /// sweep — the merged sums are bit-identical to
    /// [`accumulate_with`] over a [`ForwardResult`] of the same read.
    ///
    /// The backward value pair carries across segment boundaries
    /// untouched: the `rows[t+1]` support needed at the last timestep
    /// of segment `s` is exactly checkpoint `s + 1` (the first row of
    /// the already-consumed next segment), so no boundary-stitching
    /// state exists beyond the checkpoints themselves.
    ///
    /// Cooperative cancellation (`scratch.cancel`) and the
    /// `engine::segment` failpoint are observed at segment boundaries
    /// only — never inside a reduction — and a cancelled sweep restores
    /// the all-zero backward-buffer invariant before returning
    /// [`ApHmmError::Cancelled`].
    ///
    /// Returns the peak forward-row scratch in bytes: resident
    /// checkpoints + scales plus the largest live segment buffer (the
    /// `O(√T·states)` quantity the scratch accounting reports).
    ///
    /// [`backward_step`]: BwAccumulators::backward_step
    /// [`accumulate_with`]: BwAccumulators::accumulate_with
    pub(super) fn accumulate_checkpointed_with(
        &mut self,
        phmm: &Phmm,
        coeffs: &FusedCoeffs,
        seq: &Sequence,
        ckpt: &CheckpointedForward,
        scratch: &mut ForwardScratch,
        opts: &ForwardOptions,
    ) -> Result<u64> {
        let n = phmm.n_states();
        let t_len = seq.len();
        debug_assert_eq!(ckpt.scales.len(), t_len);
        if self.xi.len() != phmm.n_transitions()
            || self.gamma_den.len() != n
            || self.sigma != phmm.sigma()
            || coeffs.n_edges() != phmm.n_transitions()
            || coeffs.sigma() != phmm.sigma()
        {
            return Err(ApHmmError::InvalidGraph(
                "accumulator/coefficient shapes do not match the graph".into(),
            ));
        }
        let out_tiles = if sparse::may_dispatch_tiles(coeffs, opts.gather) {
            Some(coeffs.out_tiles_for(phmm))
        } else {
            None
        };
        scratch.ensure(n + coeffs.gather_pad());
        scratch.ensure_hist(&opts.filter);
        let cancel = scratch.cancel.clone();
        let k = ckpt.seg_len;
        let n_segs = ckpt.ckpt_rows.len();
        debug_assert_eq!(n_segs, (t_len - 1) / k + 1);
        // `backward_step` swaps the *references* b_next/b_cur, but each
        // segment re-borrows the underlying scratch fields, so track
        // which field currently holds the carried t+1 values.
        let mut flipped = false;
        let mut seg_rows: Vec<SparseRow> = Vec::with_capacity(k);
        let mut peak = ckpt.ckpt_bytes;
        for s in (0..n_segs).rev() {
            // Cancellation (and fault injection) is observed here, at
            // the segment boundary, only — never inside a reduction.
            if let Some(cause) = cancel.check() {
                for row in seg_rows.drain(..) {
                    scratch.put_row(row);
                }
                // Abandoning mid-sweep loses track of which backward
                // entries are live; re-zero the pair wholesale to
                // restore the scratch invariant.
                let (b_next, b_cur) = scratch.backward_bufs();
                b_next.iter_mut().for_each(|x| *x = 0.0);
                b_cur.iter_mut().for_each(|x| *x = 0.0);
                return Err(ApHmmError::Cancelled(cause));
            }
            crate::failpoint!("engine::segment");
            let start = s * k;
            let len = k.min(t_len - start);
            sparse::recompute_segment(
                phmm, coeffs, seq, ckpt, s, start, len, opts, scratch, &mut seg_rows,
            )?;
            let seg_bytes: u64 = seg_rows.iter().map(sparse::row_bytes).sum();
            peak = peak.max(ckpt.ckpt_bytes + seg_bytes);
            {
                let (f0, f1) = scratch.backward_bufs();
                let (mut b_next, mut b_cur): (&mut [f64], &mut [f64]) =
                    if flipped { (f1, f0) } else { (f0, f1) };
                if s == n_segs - 1 {
                    self.backward_last_row(
                        &seg_rows[len - 1],
                        seq.data[t_len - 1] as usize,
                        b_next,
                    );
                }
                let top = (start + len).min(t_len - 1);
                for t in (start..top).rev() {
                    let row = &seg_rows[t - start];
                    let row_next: &SparseRow = if t + 1 < start + len {
                        &seg_rows[t + 1 - start]
                    } else {
                        &ckpt.ckpt_rows[s + 1]
                    };
                    self.backward_step(
                        phmm,
                        coeffs,
                        opts,
                        out_tiles,
                        row,
                        row_next,
                        seq.data[t] as usize,
                        seq.data[t + 1] as usize,
                        1.0 / (ckpt.scales[t + 1] as f64),
                        b_next,
                        b_cur,
                    );
                    for &i in &row_next.idx {
                        b_next[i as usize] = 0.0;
                    }
                    std::mem::swap(&mut b_next, &mut b_cur);
                    flipped = !flipped;
                }
            }
            for row in seg_rows.drain(..) {
                scratch.put_row(row);
            }
        }
        // Restore the all-zero scratch invariant over the t = 0 support
        // (checkpoint 0 *is* row 0).
        {
            let (f0, f1) = scratch.backward_bufs();
            let b_next = if flipped { f1 } else { f0 };
            for &i in &ckpt.ckpt_rows[0].idx {
                b_next[i as usize] = 0.0;
            }
        }
        self.note_observation(ckpt.loglik);
        Ok(peak)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::baumwelch::sparse::{forward_sparse, ForwardOptions};
    use crate::baumwelch::logspace::{log_backward, log_forward};
    use crate::sim::XorShift;
    use crate::testutil;

    fn setup(rng: &mut XorShift, ref_len: usize, obs_len: usize) -> (Phmm, Sequence) {
        let data = testutil::random_seq(rng, ref_len, 4);
        let g = Phmm::error_correction(&Sequence::from_symbols("r", data), &Default::default())
            .unwrap();
        let obs = Sequence::from_symbols("o", testutil::random_seq(rng, obs_len, 4));
        (g, obs)
    }

    /// Independent oracle: compute ξ and γ sums from full log-space
    /// forward/backward matrices.
    fn oracle_sums(phmm: &Phmm, seq: &Sequence) -> (Vec<f64>, Vec<f64>, Vec<f64>, Vec<f64>) {
        let lf = log_forward(phmm, seq);
        let lb = log_backward(phmm, seq);
        let n = phmm.n_states();
        let t_len = seq.len();
        // log P = logsumexp over last row of lf.
        let mut lp = f64::NEG_INFINITY;
        for i in 0..n {
            lp = logadd(lp, lf[(t_len - 1) * n + i]);
        }
        let mut xi = vec![0.0f64; phmm.n_transitions()];
        let mut trans_den = vec![0.0f64; n];
        let mut e_num = vec![0.0f64; n * phmm.sigma()];
        let mut gamma_den = vec![0.0f64; n];
        for t in 0..t_len {
            for i in 0..n {
                let lg = lf[t * n + i] + lb[t * n + i] - lp;
                if lg > -700.0 {
                    let g = lg.exp();
                    gamma_den[i] += g;
                    e_num[i * phmm.sigma() + seq.data[t] as usize] += g;
                    if t + 1 < t_len {
                        trans_den[i] += g;
                    }
                }
            }
            if t + 1 < t_len {
                for j in 0..n {
                    for e in phmm.out_ptr[j] as usize..phmm.out_ptr[j + 1] as usize {
                        let to = phmm.out_to[e] as usize;
                        let le = lf[t * n + j]
                            + (phmm.out_prob[e] as f64).ln()
                            + (phmm.emission(to, seq.data[t + 1]) as f64).ln()
                            + lb[(t + 1) * n + to]
                            - lp;
                        if le > -700.0 {
                            xi[e] += le.exp();
                        }
                    }
                }
            }
        }
        (xi, trans_den, e_num, gamma_den)
    }

    fn logadd(a: f64, b: f64) -> f64 {
        if a == f64::NEG_INFINITY {
            return b;
        }
        if b == f64::NEG_INFINITY {
            return a;
        }
        let m = a.max(b);
        m + ((a - m).exp() + (b - m).exp()).ln()
    }

    #[test]
    fn sums_match_logspace_oracle() {
        testutil::check(10, |rng| {
            let __h0 = rng.range(4, 20);
            let __h1 = rng.range(3, 12);
            let (g, obs) = setup(rng, __h0, __h1);
            let fwd = forward_sparse(&g, &obs, &ForwardOptions::default()).unwrap();
            let mut acc = BwAccumulators::new(&g);
            acc.accumulate(&g, &obs, &fwd).unwrap();
            let (xi_o, td_o, en_o, gd_o) = oracle_sums(&g, &obs);
            testutil::assert_all_close(&acc.xi, &xi_o, 2e-3, 1e-6);
            testutil::assert_all_close(&acc.trans_den, &td_o, 2e-3, 1e-6);
            testutil::assert_all_close(&acc.e_num, &en_o, 2e-3, 1e-6);
            testutil::assert_all_close(&acc.gamma_den, &gd_o, 2e-3, 1e-6);
        });
    }

    #[test]
    fn gamma_rows_sum_to_t() {
        // Σ_i γ_t(i) = 1 per live timestep, so Σ gamma_den = T.
        testutil::check(10, |rng| {
            let __h0 = rng.range(5, 30);
            let __h1 = rng.range(2, 15);
            let (g, obs) = setup(rng, __h0, __h1);
            let fwd = forward_sparse(&g, &obs, &ForwardOptions::default()).unwrap();
            let mut acc = BwAccumulators::new(&g);
            acc.accumulate(&g, &obs, &fwd).unwrap();
            let total: f64 = acc.gamma_den.iter().sum();
            testutil::assert_close(total, obs.len() as f64, 1e-3, 1e-6);
        });
    }

    #[test]
    fn xi_row_sums_equal_trans_den() {
        // Σ_j ξ(i, j) = Σ_{t<T-1} γ_t(i) (Eq. 3 denominator identity).
        testutil::check(10, |rng| {
            let __h0 = rng.range(5, 25);
            let __h1 = rng.range(3, 12);
            let (g, obs) = setup(rng, __h0, __h1);
            let fwd = forward_sparse(&g, &obs, &ForwardOptions::default()).unwrap();
            let mut acc = BwAccumulators::new(&g);
            acc.accumulate(&g, &obs, &fwd).unwrap();
            for j in 0..g.n_states() {
                let row: f64 = (g.out_ptr[j] as usize..g.out_ptr[j + 1] as usize)
                    .map(|e| acc.xi[e])
                    .sum();
                testutil::assert_close(row, acc.trans_den[j], 1e-3, 1e-9);
            }
        });
    }

    #[test]
    fn apply_produces_valid_graph_and_improves_likelihood() {
        testutil::check(8, |rng| {
            let __h0 = rng.range(6, 25);
            let __h1 = rng.range(4, 15);
            let (mut g, obs) = setup(rng, __h0, __h1);
            let before = forward_sparse(&g, &obs, &ForwardOptions::default()).unwrap().loglik;
            let fwd = forward_sparse(&g, &obs, &ForwardOptions::default()).unwrap();
            let mut acc = BwAccumulators::new(&g);
            acc.accumulate(&g, &obs, &fwd).unwrap();
            acc.apply(&mut g).unwrap();
            let after = forward_sparse(&g, &obs, &ForwardOptions::default()).unwrap().loglik;
            assert!(after >= before - 1e-3, "EM decreased loglik: {before} -> {after}");
        });
    }

    #[test]
    fn tile_backward_is_bit_identical_to_csr_backward() {
        use crate::baumwelch::sparse::GatherKind;
        use crate::baumwelch::SimdPolicy;
        // Dense-band graph admits the out-tile walk; one shared forward
        // feeds both backward dispatches so any difference is the
        // backward kernel's own doing.
        let mut rng = XorShift::new(99);
        let g = testutil::dense_band_phmm(24);
        for obs_len in [1usize, 2, 7, 16] {
            let obs = Sequence::from_symbols("o", testutil::random_seq(&mut rng, obs_len, 4));
            let opts_csr = ForwardOptions {
                gather: GatherKind::Csr,
                simd: SimdPolicy::Scalar,
                ..Default::default()
            };
            let opts_tile = ForwardOptions {
                gather: GatherKind::DenseTile,
                simd: SimdPolicy::Scalar,
                ..Default::default()
            };
            let fwd = forward_sparse(&g, &obs, &opts_csr).unwrap();
            let coeffs = FusedCoeffs::new(&g);
            let mut scratch = ForwardScratch::new(&g);

            let mut a_csr = BwAccumulators::new(&g);
            a_csr
                .accumulate_with(&g, &coeffs, &obs, &fwd, &mut scratch, &opts_csr)
                .unwrap();
            let mut a_tile = BwAccumulators::new(&g);
            a_tile
                .accumulate_with(&g, &coeffs, &obs, &fwd, &mut scratch, &opts_tile)
                .unwrap();

            assert_eq!(a_csr.xi, a_tile.xi, "xi diverged at obs_len={obs_len}");
            assert_eq!(a_csr.trans_den, a_tile.trans_den);
            assert_eq!(a_csr.e_num, a_tile.e_num);
            assert_eq!(a_csr.gamma_den, a_tile.gamma_den);
            assert_eq!(a_csr.total_loglik.to_bits(), a_tile.total_loglik.to_bits());
        }
    }

    #[test]
    fn checkpointed_sweep_is_bit_identical_to_full() {
        use crate::baumwelch::sparse::{forward_checkpointed_with, forward_sparse_with};
        use crate::baumwelch::FilterConfig;
        // Same read, same graph: the checkpointed sweep (recompute each
        // segment, consume via the shared backward_step) must land the
        // exact bits of the full-matrix sweep — sums, loglik, counts.
        testutil::check(10, |rng| {
            let ref_len = rng.range(5, 30);
            let obs_len = rng.range(1, 50);
            let (g, obs) = setup(rng, ref_len, obs_len);
            for filter in [FilterConfig::None, FilterConfig::Histogram { size: 40, bins: 64 }] {
                let opts = ForwardOptions { filter, ..Default::default() };
                let coeffs = FusedCoeffs::new(&g);
                let mut scratch = ForwardScratch::new(&g);

                let fwd = forward_sparse_with(&g, &coeffs, &obs, &opts, &mut scratch).unwrap();
                let mut full = BwAccumulators::new(&g);
                full.accumulate_with(&g, &coeffs, &obs, &fwd, &mut scratch, &opts).unwrap();
                scratch.recycle(fwd);

                let ckpt =
                    forward_checkpointed_with(&g, &coeffs, &obs, &opts, &mut scratch).unwrap();
                let mut chk = BwAccumulators::new(&g);
                let peak = chk
                    .accumulate_checkpointed_with(&g, &coeffs, &obs, &ckpt, &mut scratch, &opts)
                    .unwrap();
                assert!(peak >= ckpt.ckpt_bytes);

                assert_eq!(full.xi, chk.xi, "xi diverged (filter {filter:?})");
                assert_eq!(full.trans_den, chk.trans_den);
                assert_eq!(full.e_num, chk.e_num);
                assert_eq!(full.gamma_den, chk.gamma_den);
                assert_eq!(full.n_observations, chk.n_observations);
                assert_eq!(full.total_loglik.to_bits(), chk.total_loglik.to_bits());

                // The backward buffers must be left all-zero for the
                // next read (the scratch invariant both sweeps promise).
                let (b_next, b_cur) = scratch.backward_bufs();
                assert!(b_next.iter().all(|&x| x == 0.0));
                assert!(b_cur.iter().all(|&x| x == 0.0));
            }
        });
    }

    #[test]
    fn merge_equals_sequential_accumulation() {
        let mut rng = XorShift::new(123);
        let (g, obs1) = setup(&mut rng, 20, 10);
        let obs2 = Sequence::from_symbols("o2", testutil::random_seq(&mut rng, 8, 4));
        let f1 = forward_sparse(&g, &obs1, &ForwardOptions::default()).unwrap();
        let f2 = forward_sparse(&g, &obs2, &ForwardOptions::default()).unwrap();

        let mut seq_acc = BwAccumulators::new(&g);
        seq_acc.accumulate(&g, &obs1, &f1).unwrap();
        seq_acc.accumulate(&g, &obs2, &f2).unwrap();

        let mut a = BwAccumulators::new(&g);
        a.accumulate(&g, &obs1, &f1).unwrap();
        let mut b = BwAccumulators::new(&g);
        b.accumulate(&g, &obs2, &f2).unwrap();
        a.merge(&b);

        testutil::assert_all_close(&a.xi, &seq_acc.xi, 1e-12, 1e-12);
        testutil::assert_all_close(&a.gamma_den, &seq_acc.gamma_den, 1e-12, 1e-12);
        assert_eq!(a.n_observations, 2);
    }

    #[test]
    fn apply_without_observations_fails() {
        let mut rng = XorShift::new(7);
        let (mut g, _) = setup(&mut rng, 10, 5);
        let acc = BwAccumulators::new(&g);
        assert!(acc.apply(&mut g).is_err());
    }
}
