//! The Baum-Welch algorithm over pHMM graphs (§2.2), behind one
//! pluggable execution framework.
//!
//! All compute paths implement the [`ExpectationEngine`] trait
//! (prepare frozen coefficients → E-step accumulate → maximize →
//! score/posterior) and are selected by [`EngineKind`]:
//!
//! * [`SparseEngine`] — CSR-based engine with per-timestep *state
//!   filtering* (sort-based, the software baseline; or histogram-based,
//!   ApHMM's hardware mechanism in software form), built on the
//!   memoized per-symbol fused-coefficient tables of [`kernels`] (paper
//!   §4.2–4.3).  This is the faithful reimplementation of what
//!   Apollo/HMMER do on CPU and the workload the accelerator model is
//!   driven by.
//! * [`BandedEngine`] — dense banded engine mirroring the L2 JAX model
//!   (same scaled recurrences, same raw update sums), now with its own
//!   per-symbol fused-coefficient tables ([`BandedCoeffs`]); the PJRT
//!   runtime slots in as a drop-in replacement for its pre-refactor
//!   scan.
//! * [`ReferenceEngine`] — the pre-memoization kernels of
//!   [`reference`], kept as the parity oracle and the speedup baseline.
//! * `coordinator::XlaEngine` — expectation passes shipped to the
//!   shared PJRT device thread (the accelerator's role; stubs unless
//!   built with the `pjrt` feature).
//!
//! Every re-encoding of a graph's transition structure — the incoming
//! CSR, the banded window tables, and the per-window dense tiles of the
//! density-adaptive in-window gather — is owned by the freeze-time
//! [`lowering`] layer ([`Lowering`] / [`BandedLowering`] /
//! [`DenseTiles`]); engines only add parameter-dependent coefficient
//! arrays on top of one shared lowering product.
//!
//! The dense-tile dot product executes through the explicit lane shim
//! of [`simd`] (scalar / f32x4 / f32x8, selected at runtime by
//! [`SimdPolicy`] or the `APHMM_SIMD` override), and batches of
//! same-profile reads can advance in lock-step through the striped
//! multi-read kernels ([`forward_striped_with`] /
//! [`score_striped_with`]) — per read bit-identical to the solo
//! kernels at the same lane width, exposed through the engine batch
//! entry points ([`ExpectationEngine::accumulate_batch`] /
//! [`ExpectationEngine::score_batch`]).
//!
//! Shared numerics: per-timestep scaling (DESIGN.md §Numerics); raw
//! expectation sums accumulated across observation sequences and divided
//! once per EM iteration ([`BwAccumulators`]).  [`logspace`] provides an
//! independent log-space oracle used by the test suite.
//!
//! The training stack ([`train`] / [`train_with_engine`] for slices,
//! [`train_source`] for streaming corpora) is layered: a corpus layer
//! ([`ReadSource`] with in-memory and streaming FASTA/FASTQ sources), a
//! schedule layer ([`TrainMode`] — full-batch, seeded minibatch, or
//! hard-count Viterbi training), and underneath them the engine E-step,
//! fanned out across a shared [`crate::pool::WorkerPool`] with a
//! deterministic block reduction — bit-identical results for any worker
//! count, and under a fixed seed for any schedule.

pub mod banded;
mod corpus;
mod engine;
mod filter;
mod kernels;
pub mod lowering;
mod logspace;
pub mod reference;
mod simd;
mod sparse;
mod striped;
mod tile;
mod train;
mod update;

pub use banded::{BandedBwSums, BandedCoeffs, BandedEngine};
pub use engine::{
    BandedAcc, BandedPrepared, EngineKind, ExpectationEngine, PosteriorDecode, PreparedAny,
    ReadStats, ReferenceEngine, ScratchAny, SparseEngine, SparsePrepared,
};
pub use filter::{FilterConfig, FilterStats, HistogramFilter, SortFilter};
pub use kernels::{ForwardScratch, FusedCoeffs};
pub use logspace::{log_backward, log_forward, log_likelihood};
pub use lowering::{
    BandedLowering, GatherKind, Lowering, DENSE_TILE_MIN_DENSITY, TILE_LANES,
    TILE_MIN_OCCUPANCY,
};
pub use simd::{SimdLanes, SimdPolicy, MAX_STRIPE, SIMD_REASSOC_ATOL, SIMD_REASSOC_RTOL};
pub use sparse::{
    forward_sparse, forward_sparse_with, full_scratch_estimate, score_sparse, score_sparse_with,
    ForwardOptions, ForwardResult, ScoreResult, ScratchMode, SparseRow,
};
pub use striped::{forward_striped_with, score_striped_with};
pub use tile::{DenseTiles, OutTiles};
pub use corpus::{FastaSource, FastqSource, MemorySource, ReadSource};
pub use train::{
    train, train_in, train_in_with, train_source, train_source_in, train_source_in_with,
    train_source_with_engine_with, train_with_engine, train_with_engine_with, TrainConfig,
    TrainMode, TrainResult, AUTO_MINIBATCH_THRESHOLD,
};
pub use update::BwAccumulators;

/// Numerical floor guarding divisions.
pub const EPS: f32 = 1e-30;
