//! The Baum-Welch algorithm over pHMM graphs (§2.2).
//!
//! Two engines with identical semantics:
//!
//! * [`sparse`] — CSR-based engine with per-timestep *state filtering*
//!   (sort-based, the software baseline; or histogram-based, ApHMM's
//!   hardware mechanism in software form).  This is the faithful
//!   reimplementation of what Apollo/HMMER do on CPU and the workload
//!   the accelerator model is driven by.
//! * [`banded`] — dense banded engine mirroring the L2 JAX model
//!   bit-for-bit (same scaled recurrences, same raw update sums); the
//!   PJRT runtime slots in as a drop-in replacement for it.
//!
//! Shared numerics: per-timestep scaling (DESIGN.md §Numerics); raw
//! expectation sums accumulated across observation sequences and divided
//! once per EM iteration ([`BwAccumulators`]).  [`logspace`] provides an
//! independent log-space oracle used by the test suite.

pub mod banded;
mod filter;
mod logspace;
mod sparse;
mod train;
mod update;

pub use banded::{BandedBwSums, BandedEngine};
pub use filter::{FilterConfig, FilterStats, HistogramFilter, SortFilter};
pub use logspace::{log_backward, log_forward, log_likelihood};
pub use sparse::{forward_sparse, score_sparse, ForwardOptions, ForwardResult, SparseRow};
pub use train::{train, TrainConfig, TrainResult};
pub use update::BwAccumulators;

/// Numerical floor guarding divisions.
pub const EPS: f32 = 1e-30;
