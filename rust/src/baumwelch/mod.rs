//! The Baum-Welch algorithm over pHMM graphs (§2.2).
//!
//! Two engines with identical semantics:
//!
//! * [`sparse`] — CSR-based engine with per-timestep *state filtering*
//!   (sort-based, the software baseline; or histogram-based, ApHMM's
//!   hardware mechanism in software form).  This is the faithful
//!   reimplementation of what Apollo/HMMER do on CPU and the workload
//!   the accelerator model is driven by.
//! * [`banded`] — dense banded engine mirroring the L2 JAX model
//!   bit-for-bit (same scaled recurrences, same raw update sums); the
//!   PJRT runtime slots in as a drop-in replacement for it.
//!
//! Shared numerics: per-timestep scaling (DESIGN.md §Numerics); raw
//! expectation sums accumulated across observation sequences and divided
//! once per EM iteration ([`BwAccumulators`]).  [`logspace`] provides an
//! independent log-space oracle used by the test suite.
//!
//! The sparse hot path is built on the memoized per-symbol
//! fused-coefficient tables of [`kernels`] (paper §4.2–4.3): transition ×
//! emission products are computed once per parameter freeze, the forward
//! inner loop is a pure per-symbol CSR SpMV, and the fused backward + ξ
//! update performs a single table gather per live edge.  [`reference`]
//! preserves the pre-memoization kernels for parity tests and speedup
//! measurement, and the training loop fans the batch E-step out across
//! worker threads with a deterministic block reduction.

pub mod banded;
mod filter;
mod kernels;
mod logspace;
pub mod reference;
mod sparse;
mod train;
mod update;

pub use banded::{BandedBwSums, BandedEngine};
pub use filter::{FilterConfig, FilterStats, HistogramFilter, SortFilter};
pub use kernels::{ForwardScratch, FusedCoeffs};
pub use logspace::{log_backward, log_forward, log_likelihood};
pub use sparse::{
    forward_sparse, forward_sparse_with, score_sparse, score_sparse_with, ForwardOptions,
    ForwardResult, ScoreResult, SparseRow,
};
pub use train::{train, TrainConfig, TrainResult};
pub use update::BwAccumulators;

/// Numerical floor guarding divisions.
pub const EPS: f32 = 1e-30;
