//! The pluggable Baum-Welch execution framework (paper §1, §4: one
//! algorithm, many execution substrates).
//!
//! [`ExpectationEngine`] abstracts everything the EM training loop, the
//! three applications and the coordinator need from a Baum-Welch
//! backend:
//!
//! * [`ExpectationEngine::prepare`] — freeze the current parameters
//!   into backend-specific coefficient tables (the software analogue of
//!   ApHMM loading its on-chip coefficient memory);
//! * [`ExpectationEngine::accumulate_read`] — run forward + fused
//!   backward/update of one read into a backend-specific accumulator,
//!   reporting uniform [`ReadStats`] instrumentation;
//! * [`ExpectationEngine::merge`] / [`ExpectationEngine::maximize`] —
//!   the deterministic block reduction and the M-step;
//! * [`ExpectationEngine::score`] — the forward-only inference path
//!   (protein search, MSA pre-screening);
//! * [`ExpectationEngine::posterior`] — posterior best-state decoding
//!   (hmmalign).
//!
//! Four engines implement it: [`SparseEngine`] (the CSR
//! fused-coefficient hot path), [`super::BandedEngine`] (dense banded
//! with its own fused tables), [`ReferenceEngine`] (the pre-memoization
//! parity oracle) and `coordinator::XlaEngine` (expectation passes
//! shipped to the shared PJRT device thread; real execution is gated
//! behind the `xla`/`pjrt` features, stubs otherwise).  Callers select
//! one with [`EngineKind`] (`TrainConfig::engine`, the apps' configs,
//! the `--engine` CLI flag); generic code dispatches through
//! `train_with_engine` and friends.
//!
//! The contract every engine must keep: accumulation is commutative
//! enough that merging block accumulators **in block order** is
//! equivalent to sequential accumulation, which is what makes the
//! shared-[`crate::pool::WorkerPool`] E-step bit-identical for any
//! worker count.

use std::time::Instant;

use super::banded::{BandedBwSums, BandedEngine};
use super::filter::FilterStats;
use super::kernels::{ForwardScratch, FusedCoeffs};
use super::lowering::BandedLowering;
use super::reference;
use super::simd::MAX_STRIPE;
use super::sparse::{
    self, forward_sparse_with, score_sparse_with, ForwardOptions, ScoreResult, ScratchMode,
};
use super::striped;
use super::update::BwAccumulators;
use crate::cancel::CancelToken;
use crate::error::Result;
use crate::phmm::Phmm;
use crate::seq::Sequence;

/// Which [`ExpectationEngine`] backs a session.  Carried by
/// `TrainConfig` and the application configs; plain `Copy` data so the
/// configs stay `Copy` (the XLA device's artifact directory lives in
/// `CoordinatorConfig::artifacts_dir`, not here).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq, Hash)]
pub enum EngineKind {
    /// CSR sparse engine with state filtering and memoized per-symbol
    /// fused-coefficient tables — the software baseline / hot path.
    #[default]
    Sparse,
    /// Dense banded engine (mirror of the L2 JAX model) with its own
    /// fused-coefficient tables.
    Banded,
    /// Pre-memoization reference kernels — the parity oracle.  Slow;
    /// for tests and speedup measurement.
    Reference,
    /// Expectation passes shipped to the shared XLA device thread
    /// (AOT artifacts via PJRT).  Requires a device session: use the
    /// coordinator with `artifacts_dir`, or `train_with_engine` with a
    /// `coordinator::XlaEngine` directly.
    Xla,
}

impl EngineKind {
    /// Canonical names of every engine, for CLI usage text and parse
    /// errors (`reference` also accepts the shorthand `ref`).
    pub const NAMES: &'static [&'static str] = &["sparse", "banded", "reference", "xla"];

    /// Parse a CLI/config name (`sparse | banded | reference | xla`).
    pub fn parse(name: &str) -> Option<EngineKind> {
        match name.trim().to_ascii_lowercase().as_str() {
            "sparse" => Some(EngineKind::Sparse),
            "banded" => Some(EngineKind::Banded),
            "reference" | "ref" => Some(EngineKind::Reference),
            "xla" => Some(EngineKind::Xla),
            _ => None,
        }
    }

    /// Canonical lowercase name.
    pub fn name(self) -> &'static str {
        match self {
            EngineKind::Sparse => "sparse",
            EngineKind::Banded => "banded",
            EngineKind::Reference => "reference",
            EngineKind::Xla => "xla",
        }
    }
}

/// Uniform per-read instrumentation reported by every engine: the
/// Fig. 2 step timings plus the workload counters the accelerator
/// model consumes.
#[derive(Clone, Copy, Debug, Default)]
pub struct ReadStats {
    /// Forward-pass nanoseconds.
    pub forward_ns: u128,
    /// Fused backward + update nanoseconds.
    pub backward_update_ns: u128,
    /// Parameter-update (M-step) nanoseconds.  Nonzero only for
    /// training requests; the serving layer copies
    /// [`crate::baumwelch::TrainResult::maximize_ns`] here so the
    /// observability layer sees the full §3 stage triplet.
    pub update_ns: u128,
    /// Nanoseconds spent freezing prepared tables on a cache miss
    /// (0 on a hit).  Filled by the serving layer, not the engines.
    pub cache_freeze_ns: u128,
    /// State-filter instrumentation (empty for dense engines).
    pub filter_stats: FilterStats,
    /// Σ over timesteps of active states.
    pub states_processed: u64,
    /// Σ over timesteps of traversed edges / band entries.
    pub edges_processed: u64,
    /// Timesteps executed.
    pub timesteps: u64,
    /// Striped multi-read kernel passes this read's chunk contributed
    /// (attributed to the chunk's first read so merged totals count
    /// each pass once; 0 on the unstriped paths).
    pub stripe_passes: u64,
    /// Reads carried by those passes (merged `stripe_reads /
    /// stripe_passes` = mean stripe fill out of
    /// [`crate::baumwelch::MAX_STRIPE`]).
    pub stripe_reads: u64,
    /// Peak forward-row scratch bytes held while processing this read:
    /// all `T` rows + scales under [`ScratchMode::Full`], checkpoint
    /// rows + scales + the largest live segment buffer under
    /// [`ScratchMode::Checkpointed`].  Backward/dense buffers are
    /// excluded — they are identical in both modes.  A high-water
    /// mark: [`ReadStats::merge`] takes the `max`, not the sum.
    pub peak_scratch_bytes: u64,
    /// Training epochs (full corpus passes) this request ran.  Like
    /// [`ReadStats::update_ns`], filled by the serving layer from
    /// [`crate::baumwelch::TrainResult::epochs`]; 0 for inference.
    pub epochs: u64,
    /// Minibatch maximizations this request ran
    /// ([`crate::baumwelch::TrainMode::Minibatch`]; 0 otherwise).
    pub minibatches: u64,
    /// Sequences pulled through a streaming corpus source
    /// ([`crate::baumwelch::ReadSource`]); 0 for slice-fed requests.
    pub sequences_streamed: u64,
}

impl ReadStats {
    /// Fold another read's stats into this aggregate.
    pub fn merge(&mut self, other: &ReadStats) {
        self.forward_ns += other.forward_ns;
        self.backward_update_ns += other.backward_update_ns;
        self.update_ns += other.update_ns;
        self.cache_freeze_ns += other.cache_freeze_ns;
        self.filter_stats.merge(&other.filter_stats);
        self.states_processed += other.states_processed;
        self.edges_processed += other.edges_processed;
        self.timesteps += other.timesteps;
        self.stripe_passes += other.stripe_passes;
        self.stripe_reads += other.stripe_reads;
        self.peak_scratch_bytes = self.peak_scratch_bytes.max(other.peak_scratch_bytes);
        self.epochs += other.epochs;
        self.minibatches += other.minibatches;
        self.sequences_streamed += other.sequences_streamed;
    }
}

/// Output of [`ExpectationEngine::posterior`]: the per-timestep maximum
/// posterior states plus phase timings for the Fig. 2 breakdown.
#[derive(Clone, Debug)]
pub struct PosteriorDecode {
    /// `argmax_i γ_t(i)` per timestep.
    pub best_state: Vec<u32>,
    /// `log P(S | G)`.
    pub loglik: f64,
    /// Forward-pass nanoseconds.
    pub forward_ns: u128,
    /// Backward + argmax nanoseconds.
    pub backward_ns: u128,
}

/// A pluggable Baum-Welch execution backend.  See the module docs for
/// the method contract; `Sync` because one engine instance is shared by
/// all E-step workers of a session.
pub trait ExpectationEngine: Sync {
    /// Frozen per-parameter-freeze state (coefficient tables and
    /// whatever encoding the backend computes on), shared read-only by
    /// every worker.  Owns copies: the graph may be mutably borrowed
    /// again (maximization) while a `Prepared` is alive, but it must be
    /// rebuilt after any parameter update.
    type Prepared: Send + Sync;
    /// Per-worker mutable scratch (buffer pools etc.).
    type Scratch: Send;
    /// Backend-specific expectation accumulator (one per E-step block).
    type Acc: Send;

    /// Canonical engine name for logs and docs.
    fn name(&self) -> &'static str;

    /// Freeze the current parameters of `phmm` into coefficient tables.
    fn prepare(&self, phmm: &Phmm) -> Result<Self::Prepared>;

    /// A fresh per-worker scratch sized for `phmm`.
    fn make_scratch(&self, phmm: &Phmm) -> Self::Scratch;

    /// A zeroed accumulator shaped for `phmm`.
    fn make_acc(&self, phmm: &Phmm) -> Self::Acc;

    /// Install a cooperative cancel token into `scratch`, observed by
    /// long-running accumulate sweeps at safe points (the sparse
    /// engine's checkpointed backward checks it at segment boundaries,
    /// never inside a reduction).  Default: no-op — engines without an
    /// intra-read cancel point ignore it and rely on the per-read
    /// checks of the training loop.
    fn set_cancel(&self, _scratch: &mut Self::Scratch, _cancel: &CancelToken) {}

    /// Forward + fused backward/update of one read into `acc`.
    ///
    /// Errors follow the shared skip rule of the training loop:
    /// `ApHmmError::Numerical` marks a dead read (skipped and counted);
    /// anything else is fatal and aborts the E-step.
    fn accumulate_read(
        &self,
        phmm: &Phmm,
        prep: &Self::Prepared,
        read: &Sequence,
        opts: &ForwardOptions,
        scratch: &mut Self::Scratch,
        acc: &mut Self::Acc,
    ) -> Result<ReadStats>;

    /// Batch form of [`ExpectationEngine::accumulate_read`]: fold a
    /// group of same-profile reads into `acc`, returning one result per
    /// read (same order).  The contract is *bit-identity with the
    /// sequential loop*: the merged sums, and each read's stats
    /// counters, must equal calling `accumulate_read` per read in
    /// order.  The default does exactly that; engines with a
    /// multi-read kernel (the sparse engine's striped forward)
    /// override it.
    fn accumulate_batch(
        &self,
        phmm: &Phmm,
        prep: &Self::Prepared,
        reads: &[&Sequence],
        opts: &ForwardOptions,
        scratch: &mut Self::Scratch,
        acc: &mut Self::Acc,
    ) -> Vec<Result<ReadStats>> {
        reads
            .iter()
            .map(|read| self.accumulate_read(phmm, prep, read, opts, scratch, acc))
            .collect()
    }

    /// Merge a block accumulator into `into` (called in block order).
    fn merge(&self, into: &mut Self::Acc, from: &Self::Acc);

    /// `(Σ log-likelihood, observation count)` accumulated so far.
    fn observations(&self, acc: &Self::Acc) -> (f64, u64);

    /// Maximization: write the re-estimated parameters into `phmm`.
    fn maximize(&self, phmm: &mut Phmm, acc: &Self::Acc) -> Result<()>;

    /// Forward-only score of one read (the inference path).
    fn score(
        &self,
        phmm: &Phmm,
        prep: &Self::Prepared,
        read: &Sequence,
        opts: &ForwardOptions,
        scratch: &mut Self::Scratch,
    ) -> Result<ScoreResult>;

    /// Batch form of [`ExpectationEngine::score`]: score a group of
    /// same-profile reads, one result per read (same order).  Same
    /// bit-identity contract as
    /// [`ExpectationEngine::accumulate_batch`]; the default loops, the
    /// sparse engine runs the striped multi-read score kernel.
    fn score_batch(
        &self,
        phmm: &Phmm,
        prep: &Self::Prepared,
        reads: &[&Sequence],
        opts: &ForwardOptions,
        scratch: &mut Self::Scratch,
    ) -> Vec<Result<ScoreResult>> {
        reads.iter().map(|read| self.score(phmm, prep, read, opts, scratch)).collect()
    }

    /// Posterior best-state decode of one read (hmmalign).  The default
    /// lowers to the banded encoding per call through
    /// [`BandedLowering::lower`] (the reference engine's oracle path);
    /// the banded engine reuses its prepared tables and the sparse
    /// engine's shared [`super::Lowering`] caches the banded lowering
    /// on first use.
    fn posterior(
        &self,
        phmm: &Phmm,
        _prep: &Self::Prepared,
        read: &Sequence,
    ) -> Result<PosteriorDecode> {
        let bl = BandedLowering::lower(phmm)?;
        BandedEngine::posterior_with(&bl.banded, &bl.coeffs, read)
    }
}

// ---------------------------------------------------------------------
// Sparse engine — the CSR fused-coefficient hot path.
// ---------------------------------------------------------------------

/// Today's production engine: CSR sparse forward with state filtering
/// and the memoized per-symbol fused-coefficient kernels of
/// [`super::kernels`].
pub struct SparseEngine;

/// Frozen state of the sparse engine: the per-symbol fused CSR +
/// dense-tile coefficient tables, built on the shared
/// [`super::Lowering`].  The lowering also carries the lazily-built
/// banded encoding for posterior decoding — built at most once per
/// parameter freeze, on first [`ExpectationEngine::posterior`] call, so
/// profiles that are never posterior-decoded pay nothing and profiles
/// decoded `M` times pay once instead of `M` times.
pub struct SparsePrepared {
    /// Per-symbol fused coefficient tables over the shared lowering
    /// (the training/scoring hot path).
    pub coeffs: FusedCoeffs,
}

impl SparseEngine {
    /// One striped forward pass over `chunk` (≤ [`MAX_STRIPE`] reads)
    /// followed by the per-read fused backward/update sweeps, pushing
    /// one result per read onto `out` in chunk order.  The full-matrix
    /// half of [`ExpectationEngine::accumulate_batch`]; no-op on an
    /// empty chunk.
    #[allow(clippy::too_many_arguments)]
    fn accumulate_stripe(
        &self,
        phmm: &Phmm,
        prep: &SparsePrepared,
        chunk: &[&Sequence],
        opts: &ForwardOptions,
        scratch: &mut ForwardScratch,
        acc: &mut BwAccumulators,
        out: &mut Vec<Result<ReadStats>>,
    ) {
        if chunk.is_empty() {
            return;
        }
        let t0 = Instant::now();
        let fwds = striped::forward_striped_with(phmm, &prep.coeffs, chunk, opts, scratch);
        // One striped pass serves the whole chunk; attribute the
        // wall time evenly so aggregated forward_ns stays a usable
        // Fig. 2 proxy.
        let fwd_ns = t0.elapsed().as_nanos() / chunk.len() as u128;
        // Backwards run per read, in chunk order: the accumulator
        // sees the exact += sequence of the sequential loop, so
        // the merged sums stay bit-identical to one-at-a-time.
        let mut first_in_chunk = true;
        for (read, fwd) in chunk.iter().zip(fwds) {
            let fwd = match fwd {
                Ok(f) => f,
                Err(e) => {
                    out.push(Err(e));
                    continue;
                }
            };
            // Stripe accounting rides on the chunk's first
            // surviving read so merged totals count each striped
            // pass exactly once.
            let mut stats = ReadStats {
                forward_ns: fwd_ns,
                filter_stats: fwd.filter_stats,
                states_processed: fwd.states_processed,
                edges_processed: fwd.edges_processed,
                timesteps: fwd.rows.len() as u64,
                stripe_passes: u64::from(first_in_chunk),
                stripe_reads: if first_in_chunk { chunk.len() as u64 } else { 0 },
                peak_scratch_bytes: fwd.rows.iter().map(sparse::row_bytes).sum::<u64>()
                    + fwd.scales.len() as u64 * 4,
                ..Default::default()
            };
            first_in_chunk = false;
            let t1 = Instant::now();
            let res = acc.accumulate_with(phmm, &prep.coeffs, read, &fwd, scratch, opts);
            stats.backward_update_ns = t1.elapsed().as_nanos();
            scratch.recycle(fwd);
            out.push(res.map(|()| stats));
        }
    }
}

impl ExpectationEngine for SparseEngine {
    type Prepared = SparsePrepared;
    type Scratch = ForwardScratch;
    type Acc = BwAccumulators;

    fn name(&self) -> &'static str {
        "sparse"
    }

    fn prepare(&self, phmm: &Phmm) -> Result<SparsePrepared> {
        Ok(SparsePrepared { coeffs: FusedCoeffs::new(phmm) })
    }

    fn make_scratch(&self, phmm: &Phmm) -> ForwardScratch {
        ForwardScratch::new(phmm)
    }

    fn make_acc(&self, phmm: &Phmm) -> BwAccumulators {
        BwAccumulators::new(phmm)
    }

    fn set_cancel(&self, scratch: &mut ForwardScratch, cancel: &CancelToken) {
        scratch.cancel = cancel.clone();
    }

    fn accumulate_read(
        &self,
        phmm: &Phmm,
        prep: &SparsePrepared,
        read: &Sequence,
        opts: &ForwardOptions,
        scratch: &mut ForwardScratch,
        acc: &mut BwAccumulators,
    ) -> Result<ReadStats> {
        let mode = opts.scratch.resolve(read.len(), phmm.n_states(), opts.max_scratch_bytes);
        if mode == ScratchMode::Checkpointed {
            let t0 = Instant::now();
            let ckpt =
                sparse::forward_checkpointed_with(phmm, &prep.coeffs, read, opts, scratch)?;
            let mut stats = ReadStats {
                forward_ns: t0.elapsed().as_nanos(),
                filter_stats: ckpt.filter_stats,
                states_processed: ckpt.states_processed,
                edges_processed: ckpt.edges_processed,
                timesteps: read.len() as u64,
                ..Default::default()
            };
            let t1 = Instant::now();
            let peak =
                acc.accumulate_checkpointed_with(phmm, &prep.coeffs, read, &ckpt, scratch, opts);
            stats.backward_update_ns = t1.elapsed().as_nanos();
            scratch.recycle_checkpointed(ckpt);
            stats.peak_scratch_bytes = peak?;
            return Ok(stats);
        }
        let t0 = Instant::now();
        let fwd = forward_sparse_with(phmm, &prep.coeffs, read, opts, scratch)?;
        let mut stats = ReadStats {
            forward_ns: t0.elapsed().as_nanos(),
            filter_stats: fwd.filter_stats,
            states_processed: fwd.states_processed,
            edges_processed: fwd.edges_processed,
            timesteps: fwd.rows.len() as u64,
            peak_scratch_bytes: fwd.rows.iter().map(sparse::row_bytes).sum::<u64>()
                + fwd.scales.len() as u64 * 4,
            ..Default::default()
        };
        let t1 = Instant::now();
        acc.accumulate_with(phmm, &prep.coeffs, read, &fwd, scratch, opts)?;
        stats.backward_update_ns = t1.elapsed().as_nanos();
        scratch.recycle(fwd);
        Ok(stats)
    }

    fn accumulate_batch(
        &self,
        phmm: &Phmm,
        prep: &SparsePrepared,
        reads: &[&Sequence],
        opts: &ForwardOptions,
        scratch: &mut ForwardScratch,
        acc: &mut BwAccumulators,
    ) -> Vec<Result<ReadStats>> {
        // The striped forward materializes every row of every lane, so
        // it cannot serve reads that resolve to checkpointing.  Walk
        // the batch in order, buffering consecutive full-matrix reads
        // into ≤ MAX_STRIPE stripes and flushing the buffer before
        // each checkpointed read runs through the per-read path — the
        // accumulator still sees the exact += order of the sequential
        // loop, preserving the batch bit-identity contract (see
        // `baumwelch/README.md`, "Memory modes").
        let n_states = phmm.n_states();
        let mut out = Vec::with_capacity(reads.len());
        let mut stripe: Vec<&Sequence> = Vec::with_capacity(MAX_STRIPE.min(reads.len()));
        for read in reads {
            let mode = opts.scratch.resolve(read.len(), n_states, opts.max_scratch_bytes);
            if mode == ScratchMode::Checkpointed {
                self.accumulate_stripe(phmm, prep, &stripe, opts, scratch, acc, &mut out);
                stripe.clear();
                out.push(self.accumulate_read(phmm, prep, read, opts, scratch, acc));
            } else {
                stripe.push(read);
                if stripe.len() == MAX_STRIPE {
                    self.accumulate_stripe(phmm, prep, &stripe, opts, scratch, acc, &mut out);
                    stripe.clear();
                }
            }
        }
        self.accumulate_stripe(phmm, prep, &stripe, opts, scratch, acc, &mut out);
        out
    }

    fn merge(&self, into: &mut BwAccumulators, from: &BwAccumulators) {
        into.merge(from);
    }

    fn observations(&self, acc: &BwAccumulators) -> (f64, u64) {
        (acc.total_loglik, acc.n_observations)
    }

    fn maximize(&self, phmm: &mut Phmm, acc: &BwAccumulators) -> Result<()> {
        acc.apply(phmm)
    }

    fn score(
        &self,
        phmm: &Phmm,
        prep: &SparsePrepared,
        read: &Sequence,
        opts: &ForwardOptions,
        scratch: &mut ForwardScratch,
    ) -> Result<ScoreResult> {
        score_sparse_with(phmm, &prep.coeffs, read, opts, scratch)
    }

    fn score_batch(
        &self,
        phmm: &Phmm,
        prep: &SparsePrepared,
        reads: &[&Sequence],
        opts: &ForwardOptions,
        scratch: &mut ForwardScratch,
    ) -> Vec<Result<ScoreResult>> {
        let mut out = Vec::with_capacity(reads.len());
        for chunk in reads.chunks(MAX_STRIPE) {
            out.extend(striped::score_striped_with(phmm, &prep.coeffs, chunk, opts, scratch));
        }
        out
    }

    fn posterior(
        &self,
        phmm: &Phmm,
        prep: &SparsePrepared,
        read: &Sequence,
    ) -> Result<PosteriorDecode> {
        let bl = prep.coeffs.lowering().banded_for(phmm)?;
        BandedEngine::posterior_with(&bl.banded, &bl.coeffs, read)
    }
}

// ---------------------------------------------------------------------
// Reference engine — the pre-memoization parity oracle.
// ---------------------------------------------------------------------

/// The pre-memoization kernels of [`super::reference`] behind the
/// engine interface: byte-for-byte the original compute, usable as a
/// drop-in oracle by the engine-equivalence matrix tests.
pub struct ReferenceEngine;

impl ExpectationEngine for ReferenceEngine {
    type Prepared = ();
    type Scratch = ();
    type Acc = BwAccumulators;

    fn name(&self) -> &'static str {
        "reference"
    }

    fn prepare(&self, _phmm: &Phmm) -> Result<()> {
        Ok(())
    }

    fn make_scratch(&self, _phmm: &Phmm) {}

    fn make_acc(&self, phmm: &Phmm) -> BwAccumulators {
        BwAccumulators::new(phmm)
    }

    fn accumulate_read(
        &self,
        phmm: &Phmm,
        _prep: &(),
        read: &Sequence,
        opts: &ForwardOptions,
        _scratch: &mut (),
        acc: &mut BwAccumulators,
    ) -> Result<ReadStats> {
        let t0 = Instant::now();
        let fwd = reference::forward_sparse_reference(phmm, read, opts)?;
        let mut stats = ReadStats {
            forward_ns: t0.elapsed().as_nanos(),
            filter_stats: fwd.filter_stats,
            states_processed: fwd.states_processed,
            edges_processed: fwd.edges_processed,
            timesteps: fwd.rows.len() as u64,
            ..Default::default()
        };
        let t1 = Instant::now();
        reference::accumulate_reference(acc, phmm, read, &fwd)?;
        stats.backward_update_ns = t1.elapsed().as_nanos();
        Ok(stats)
    }

    fn merge(&self, into: &mut BwAccumulators, from: &BwAccumulators) {
        into.merge(from);
    }

    fn observations(&self, acc: &BwAccumulators) -> (f64, u64) {
        (acc.total_loglik, acc.n_observations)
    }

    fn maximize(&self, phmm: &mut Phmm, acc: &BwAccumulators) -> Result<()> {
        acc.apply(phmm)
    }

    fn score(
        &self,
        phmm: &Phmm,
        _prep: &(),
        read: &Sequence,
        opts: &ForwardOptions,
        _scratch: &mut (),
    ) -> Result<ScoreResult> {
        let fwd = reference::forward_sparse_reference(phmm, read, opts)?;
        Ok(ScoreResult {
            loglik: fwd.loglik,
            filter_stats: fwd.filter_stats,
            states_processed: fwd.states_processed,
            edges_processed: fwd.edges_processed,
        })
    }
}

// ---------------------------------------------------------------------
// Banded engine — dense banded with fused coefficient tables.
// ---------------------------------------------------------------------

/// Frozen state of the banded engine: the banded lowering product
/// (banded encoding + per-symbol fused coefficient tables), produced by
/// the shared lowering layer.
pub type BandedPrepared = BandedLowering;

/// Banded expectation accumulator: raw update sums plus the observation
/// count the generic loop needs for the mean log-likelihood.
pub struct BandedAcc {
    /// Raw banded update sums.
    pub sums: BandedBwSums,
    /// Σ log-likelihood accumulated in `f64`.  `sums.loglik` mirrors
    /// the f32 artifact layout and loses precision on large batches
    /// (ulp ≈ 0.03 at a batch total of −3e5, enough to cross the
    /// default `tol`); the convergence check reads this field instead.
    pub loglik: f64,
    /// Observations accumulated.
    pub n_observations: u64,
}

impl BandedAcc {
    /// Zeroed accumulator of shape `(n, w, sigma)`.
    pub fn new(n: usize, w: usize, sigma: usize) -> BandedAcc {
        BandedAcc { sums: BandedBwSums::zeros(n, w, sigma), loglik: 0.0, n_observations: 0 }
    }

    /// Elementwise accumulate (shared by the banded and XLA engines).
    pub fn merge(&mut self, other: &BandedAcc) {
        self.sums.add(&other.sums);
        self.loglik += other.loglik;
        self.n_observations += other.n_observations;
    }

    /// Maximization through the banded encoding: apply the sums to a
    /// fresh banded snapshot of `phmm`, then write the parameters back
    /// into the CSR arrays.
    pub fn maximize_into(&self, phmm: &mut Phmm) -> Result<()> {
        let mut banded = phmm.to_banded()?;
        self.sums.apply(&mut banded);
        phmm.update_from_banded(&banded)
    }
}

impl ExpectationEngine for BandedEngine {
    type Prepared = BandedPrepared;
    type Scratch = ();
    type Acc = BandedAcc;

    fn name(&self) -> &'static str {
        "banded"
    }

    fn prepare(&self, phmm: &Phmm) -> Result<BandedPrepared> {
        BandedLowering::lower(phmm)
    }

    fn make_scratch(&self, _phmm: &Phmm) {}

    fn make_acc(&self, phmm: &Phmm) -> BandedAcc {
        BandedAcc::new(phmm.n_states(), phmm.band_width(), phmm.sigma())
    }

    fn accumulate_read(
        &self,
        _phmm: &Phmm,
        prep: &BandedPrepared,
        read: &Sequence,
        opts: &ForwardOptions,
        _scratch: &mut (),
        acc: &mut BandedAcc,
    ) -> Result<ReadStats> {
        let t = read.len() as u64;
        let n = prep.banded.n as u64;
        // The banded rows are dense, so Auto resolves on the exact
        // full-matrix footprint: `T` rows of `n` f32 plus `T` scales —
        // the same quantity `full_scratch_estimate` upper-bounds.
        let mode = opts.scratch.resolve(read.len(), prep.banded.n, opts.max_scratch_bytes);
        if mode == ScratchMode::Checkpointed {
            let t0 = Instant::now();
            let ckpt =
                BandedEngine::forward_checkpointed_with(&prep.banded, &prep.coeffs, read)?;
            let forward_ns = t0.elapsed().as_nanos();
            let t1 = Instant::now();
            let (sums, peak) = BandedEngine::backward_sums_checkpointed_with(
                &prep.banded,
                &prep.coeffs,
                read,
                &ckpt,
            )?;
            acc.sums.add(&sums);
            acc.loglik += ckpt.loglik;
            acc.n_observations += 1;
            let backward_update_ns = t1.elapsed().as_nanos();
            return Ok(ReadStats {
                forward_ns,
                backward_update_ns,
                filter_stats: FilterStats::default(),
                states_processed: n * t,
                edges_processed: n * prep.banded.w as u64 * t.saturating_sub(1),
                timesteps: t,
                peak_scratch_bytes: peak,
                ..Default::default()
            });
        }
        let t0 = Instant::now();
        let (f_rows, scales, loglik) =
            BandedEngine::forward_with(&prep.banded, &prep.coeffs, read)?;
        let forward_ns = t0.elapsed().as_nanos();
        let t1 = Instant::now();
        let sums = BandedEngine::backward_sums_with(
            &prep.banded,
            &prep.coeffs,
            read,
            &f_rows,
            &scales,
            loglik,
        )?;
        acc.sums.add(&sums);
        acc.loglik += loglik;
        acc.n_observations += 1;
        let backward_update_ns = t1.elapsed().as_nanos();
        Ok(ReadStats {
            forward_ns,
            backward_update_ns,
            filter_stats: FilterStats::default(),
            states_processed: n * t,
            edges_processed: n * prep.banded.w as u64 * t.saturating_sub(1),
            timesteps: t,
            peak_scratch_bytes: (f_rows.len() + scales.len()) as u64 * 4,
            ..Default::default()
        })
    }

    fn merge(&self, into: &mut BandedAcc, from: &BandedAcc) {
        into.merge(from);
    }

    fn observations(&self, acc: &BandedAcc) -> (f64, u64) {
        (acc.loglik, acc.n_observations)
    }

    fn maximize(&self, phmm: &mut Phmm, acc: &BandedAcc) -> Result<()> {
        acc.maximize_into(phmm)
    }

    fn score(
        &self,
        _phmm: &Phmm,
        prep: &BandedPrepared,
        read: &Sequence,
        _opts: &ForwardOptions,
        _scratch: &mut (),
    ) -> Result<ScoreResult> {
        let loglik = BandedEngine::score_with(&prep.banded, &prep.coeffs, read)?;
        let t = read.len() as u64;
        let n = prep.banded.n as u64;
        Ok(ScoreResult {
            loglik,
            filter_stats: FilterStats::default(),
            states_processed: n * t,
            edges_processed: n * prep.banded.w as u64 * t.saturating_sub(1),
        })
    }

    fn posterior(
        &self,
        _phmm: &Phmm,
        prep: &BandedPrepared,
        read: &Sequence,
    ) -> Result<PosteriorDecode> {
        BandedEngine::posterior_with(&prep.banded, &prep.coeffs, read)
    }
}

// ---------------------------------------------------------------------
// Type-erased frozen state — the serving layer's cache entry.
// ---------------------------------------------------------------------

/// A frozen coefficient table with the engine choice erased — one
/// variant per in-process engine.  This is what the serving layer's
/// cross-request cache stores: many clients scoring against the same
/// profile share one [`PreparedAny`] (behind an `Arc`) instead of
/// re-freezing per request, extending the paper's per-EM-iteration
/// memoization (§4.2–4.3) across requests.
///
/// Only the read-only inference paths are exposed (`score`,
/// `posterior`): training re-freezes every EM iteration by design, so
/// a cross-request cache of training state would be incoherent.  The
/// XLA engine is device-backed (its "prepared" state lives in the
/// device session), so [`PreparedAny::freeze`] rejects it.
pub enum PreparedAny {
    /// Fused CSR tables (+ lazily cached banded lowering).  Boxed: the
    /// tables are table-sized, the enum travels by `Arc`.
    Sparse(Box<SparsePrepared>),
    /// Banded snapshot + fused `a·e` tables.
    Banded(Box<BandedPrepared>),
    /// The reference engine freezes nothing.
    Reference,
}

/// Per-worker scratch matching a [`PreparedAny`] variant.  Workers keep
/// one across requests; [`PreparedAny::score`] rebuilds it when the
/// cached entry's engine (or profile shape) does not match.
pub enum ScratchAny {
    /// Sparse forward scratch (buffer pools).
    Sparse(Box<ForwardScratch>),
    /// Dense engines need no scratch.
    None,
}

impl PreparedAny {
    /// Freeze the current parameters of `phmm` for `kind` — the entry
    /// point the cross-request cache calls on a miss.
    pub fn freeze(kind: EngineKind, phmm: &Phmm) -> Result<PreparedAny> {
        match kind {
            EngineKind::Sparse => {
                Ok(PreparedAny::Sparse(Box::new(SparseEngine.prepare(phmm)?)))
            }
            EngineKind::Banded => {
                Ok(PreparedAny::Banded(Box::new(BandedEngine.prepare(phmm)?)))
            }
            EngineKind::Reference => Ok(PreparedAny::Reference),
            EngineKind::Xla => Err(crate::error::ApHmmError::Config(
                "the XLA engine is device-backed and cannot be frozen into a shared \
                 cache entry; serve supports sparse | banded | reference"
                    .into(),
            )),
        }
    }

    /// Which engine froze this state.
    pub fn kind(&self) -> EngineKind {
        match self {
            PreparedAny::Sparse(_) => EngineKind::Sparse,
            PreparedAny::Banded(_) => EngineKind::Banded,
            PreparedAny::Reference => EngineKind::Reference,
        }
    }

    /// A scratch sized for `phmm`, matching this variant.
    pub fn make_scratch(&self, phmm: &Phmm) -> ScratchAny {
        match self {
            PreparedAny::Sparse(_) => ScratchAny::Sparse(Box::new(ForwardScratch::new(phmm))),
            _ => ScratchAny::None,
        }
    }

    /// Forward-only score of `read` through the frozen tables.
    /// `scratch` is replaced in place when it does not match the
    /// variant (workers reuse one slot across heterogeneous requests).
    pub fn score(
        &self,
        phmm: &Phmm,
        read: &Sequence,
        opts: &ForwardOptions,
        scratch: &mut ScratchAny,
    ) -> Result<ScoreResult> {
        match self {
            PreparedAny::Sparse(prep) => {
                if !matches!(scratch, ScratchAny::Sparse(_)) {
                    *scratch = ScratchAny::Sparse(Box::new(ForwardScratch::new(phmm)));
                }
                let ScratchAny::Sparse(s) = scratch else { unreachable!() };
                SparseEngine.score(phmm, prep, read, opts, s)
            }
            PreparedAny::Banded(prep) => BandedEngine.score(phmm, prep, read, opts, &mut ()),
            PreparedAny::Reference => ReferenceEngine.score(phmm, &(), read, opts, &mut ()),
        }
    }

    /// Batch score of same-profile reads through the frozen tables —
    /// the serving layer's Score micro-batch entry point.  One result
    /// per read, same order, bit-identical to calling
    /// [`PreparedAny::score`] per read (the sparse variant runs the
    /// striped multi-read kernel; dense engines loop).
    pub fn score_batch(
        &self,
        phmm: &Phmm,
        reads: &[&Sequence],
        opts: &ForwardOptions,
        scratch: &mut ScratchAny,
    ) -> Vec<Result<ScoreResult>> {
        match self {
            PreparedAny::Sparse(prep) => {
                if !matches!(scratch, ScratchAny::Sparse(_)) {
                    *scratch = ScratchAny::Sparse(Box::new(ForwardScratch::new(phmm)));
                }
                let ScratchAny::Sparse(s) = scratch else { unreachable!() };
                SparseEngine.score_batch(phmm, prep, reads, opts, s)
            }
            PreparedAny::Banded(prep) => reads
                .iter()
                .map(|read| BandedEngine.score(phmm, prep, read, opts, &mut ()))
                .collect(),
            PreparedAny::Reference => reads
                .iter()
                .map(|read| ReferenceEngine.score(phmm, &(), read, opts, &mut ()))
                .collect(),
        }
    }

    /// Posterior best-state decode of `read` through the frozen tables.
    pub fn posterior(&self, phmm: &Phmm, read: &Sequence) -> Result<PosteriorDecode> {
        match self {
            PreparedAny::Sparse(prep) => SparseEngine.posterior(phmm, prep, read),
            PreparedAny::Banded(prep) => BandedEngine.posterior(phmm, prep, read),
            PreparedAny::Reference => ReferenceEngine.posterior(phmm, &(), read),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::phmm::EcDesignParams;
    use crate::sim::XorShift;
    use crate::testutil;

    fn setup(rng: &mut XorShift, ref_len: usize, obs_len: usize) -> (Phmm, Sequence) {
        let data = testutil::random_seq(rng, ref_len, 4);
        let g = Phmm::error_correction(
            &Sequence::from_symbols("r", data),
            &EcDesignParams::default(),
        )
        .unwrap();
        let obs = Sequence::from_symbols("o", testutil::random_seq(rng, obs_len, 4));
        (g, obs)
    }

    #[test]
    fn engine_kind_parses_names() {
        assert_eq!(EngineKind::parse("sparse"), Some(EngineKind::Sparse));
        assert_eq!(EngineKind::parse("BANDED"), Some(EngineKind::Banded));
        assert_eq!(EngineKind::parse("ref"), Some(EngineKind::Reference));
        assert_eq!(EngineKind::parse("xla"), Some(EngineKind::Xla));
        assert_eq!(EngineKind::parse("gpu"), None);
        assert_eq!(EngineKind::default(), EngineKind::Sparse);
        assert_eq!(EngineKind::Banded.name(), "banded");
    }

    #[test]
    fn engines_score_within_tolerance_of_each_other() {
        testutil::check(8, |rng| {
            let ref_len = rng.range(5, 30);
            let obs_len = rng.range(3, 20);
            let (g, obs) = setup(rng, ref_len, obs_len);
            let opts = ForwardOptions::default();

            let sparse = SparseEngine;
            let sp = sparse.prepare(&g).unwrap();
            let mut ss = sparse.make_scratch(&g);
            let a = sparse.score(&g, &sp, &obs, &opts, &mut ss).unwrap().loglik;

            let banded = BandedEngine;
            let bp = banded.prepare(&g).unwrap();
            let b = banded.score(&g, &bp, &obs, &opts, &mut ()).unwrap().loglik;

            let reference = ReferenceEngine;
            let c = reference.score(&g, &(), &obs, &opts, &mut ()).unwrap().loglik;

            testutil::assert_close(a, c, 1e-5, 1e-9);
            testutil::assert_close(a, b, 1e-3, 1e-5);
        });
    }

    #[test]
    fn engine_accumulate_and_maximize_improve_likelihood() {
        // One EM step through the trait must not decrease the
        // likelihood, for every in-process engine.
        let mut rng = XorShift::new(97);
        let (g0, obs) = setup(&mut rng, 20, 12);

        fn em_step<E: ExpectationEngine>(engine: &E, g0: &Phmm, obs: &Sequence) -> (f64, f64) {
            let mut g = g0.clone();
            let prep = engine.prepare(&g).unwrap();
            let mut scratch = engine.make_scratch(&g);
            let mut acc = engine.make_acc(&g);
            let opts = ForwardOptions::default();
            let stats = engine
                .accumulate_read(&g, &prep, obs, &opts, &mut scratch, &mut acc)
                .unwrap();
            assert!(stats.timesteps == obs.len() as u64);
            let (ll0, n) = engine.observations(&acc);
            assert_eq!(n, 1);
            engine.maximize(&mut g, &acc).unwrap();
            let prep2 = engine.prepare(&g).unwrap();
            let mut scratch2 = engine.make_scratch(&g);
            let ll1 = engine.score(&g, &prep2, obs, &opts, &mut scratch2).unwrap().loglik;
            (ll0, ll1)
        }

        for (name, (ll0, ll1)) in [
            ("sparse", em_step(&SparseEngine, &g0, &obs)),
            ("banded", em_step(&BandedEngine, &g0, &obs)),
            ("reference", em_step(&ReferenceEngine, &g0, &obs)),
        ] {
            assert!(ll1 >= ll0 - 1e-2, "{name}: EM decreased loglik {ll0} -> {ll1}");
        }
    }

    #[test]
    fn prepared_any_matches_the_concrete_engines() {
        // The type-erased frozen state (what the serving cache stores)
        // must score and decode bit-identically to the engine it wraps.
        let mut rng = XorShift::new(103);
        let (g, obs) = setup(&mut rng, 30, 18);
        let opts = ForwardOptions::default();

        let sparse = SparseEngine;
        let sp = sparse.prepare(&g).unwrap();
        let mut ss = sparse.make_scratch(&g);
        let direct = sparse.score(&g, &sp, &obs, &opts, &mut ss).unwrap().loglik;
        let any = PreparedAny::freeze(EngineKind::Sparse, &g).unwrap();
        assert_eq!(any.kind(), EngineKind::Sparse);
        let mut scratch = any.make_scratch(&g);
        let erased = any.score(&g, &obs, &opts, &mut scratch).unwrap().loglik;
        assert_eq!(direct.to_bits(), erased.to_bits());

        // A worker's scratch slot survives an engine switch in place.
        let banded_any = PreparedAny::freeze(EngineKind::Banded, &g).unwrap();
        let via_switched = banded_any.score(&g, &obs, &opts, &mut scratch).unwrap().loglik;
        let banded = BandedEngine;
        let bp = banded.prepare(&g).unwrap();
        let direct_banded = banded.score(&g, &bp, &obs, &opts, &mut ()).unwrap().loglik;
        assert_eq!(direct_banded.to_bits(), via_switched.to_bits());

        let a = any.posterior(&g, &obs).unwrap();
        let b = banded_any.posterior(&g, &obs).unwrap();
        assert_eq!(a.best_state, b.best_state);

        // The device-backed engine cannot be frozen into a cache entry.
        assert!(PreparedAny::freeze(EngineKind::Xla, &g).is_err());
    }

    #[test]
    fn batch_entry_points_match_sequential_loops() {
        // The batch contract: one result per read, merged sums and
        // log-likelihoods bit-identical to the sequential loop at the
        // same lane width (whatever Auto resolves to here).  Ten reads
        // exercises the MAX_STRIPE chunking.
        let mut rng = XorShift::new(109);
        let (g, _) = setup(&mut rng, 25, 10);
        let reads: Vec<Sequence> = (0..10)
            .map(|i| {
                Sequence::from_symbols(
                    format!("r{i}"),
                    testutil::random_seq(&mut rng, 5 + i, 4),
                )
            })
            .collect();
        let read_refs: Vec<&Sequence> = reads.iter().collect();
        let opts = ForwardOptions::default();
        let engine = SparseEngine;
        let prep = engine.prepare(&g).unwrap();
        let mut scratch = engine.make_scratch(&g);

        let batch = engine.score_batch(&g, &prep, &read_refs, &opts, &mut scratch);
        assert_eq!(batch.len(), reads.len());
        for (read, got) in reads.iter().zip(&batch) {
            let solo = engine.score(&g, &prep, read, &opts, &mut scratch).unwrap();
            assert_eq!(got.as_ref().unwrap().loglik.to_bits(), solo.loglik.to_bits());
        }

        let mut acc_b = engine.make_acc(&g);
        let res = engine.accumulate_batch(&g, &prep, &read_refs, &opts, &mut scratch, &mut acc_b);
        assert!(res.iter().all(|r| r.is_ok()));
        let mut acc_s = engine.make_acc(&g);
        for read in &reads {
            engine.accumulate_read(&g, &prep, read, &opts, &mut scratch, &mut acc_s).unwrap();
        }
        assert_eq!(acc_b.xi, acc_s.xi);
        assert_eq!(acc_b.e_num, acc_s.e_num);
        assert_eq!(acc_b.total_loglik.to_bits(), acc_s.total_loglik.to_bits());
        assert_eq!(acc_b.n_observations, acc_s.n_observations);

        // The type-erased batch entry dispatches to the same kernel.
        let any = PreparedAny::freeze(EngineKind::Sparse, &g).unwrap();
        let mut s_any = any.make_scratch(&g);
        let via_any = any.score_batch(&g, &read_refs, &opts, &mut s_any);
        for (a, b) in via_any.iter().zip(&batch) {
            assert_eq!(
                a.as_ref().unwrap().loglik.to_bits(),
                b.as_ref().unwrap().loglik.to_bits()
            );
        }
    }

    #[test]
    fn sparse_and_banded_posterior_agree() {
        let mut rng = XorShift::new(101);
        let (g, obs) = setup(&mut rng, 25, 15);
        let sparse = SparseEngine;
        let sp = sparse.prepare(&g).unwrap();
        let banded = BandedEngine;
        let bp = banded.prepare(&g).unwrap();
        let a = sparse.posterior(&g, &sp, &obs).unwrap();
        let b = banded.posterior(&g, &bp, &obs).unwrap();
        assert_eq!(a.best_state, b.best_state);
        testutil::assert_close(a.loglik, b.loglik, 1e-9, 1e-12);
    }
}
