//! Sparse (CSR) scaled forward pass with state filtering.
//!
//! This is the faithful CPU implementation of Eq. 1: per timestep the
//! active-state set scatters probability mass along outgoing edges, the
//! row is scaled to sum 1, and the filter truncates the active set.  It
//! is both the "CPU-1" measured baseline of Figs. 10/11 and the workload
//! description the accelerator model consumes.

use super::filter::{FilterConfig, FilterStats, HistogramFilter, SortFilter};
use super::EPS;
use crate::error::{ApHmmError, Result};
use crate::phmm::Phmm;
use crate::seq::Sequence;

/// One scaled forward row: active states and their F̂ values.
#[derive(Clone, Debug, Default)]
pub struct SparseRow {
    /// Active state indices (ascending).
    pub idx: Vec<u32>,
    /// Scaled forward values (aligned with `idx`).
    pub val: Vec<f32>,
}

impl SparseRow {
    /// Number of active states.
    pub fn len(&self) -> usize {
        self.idx.len()
    }

    /// True when the row is empty.
    pub fn is_empty(&self) -> bool {
        self.idx.is_empty()
    }
}

/// Options of the forward pass.
#[derive(Clone, Copy, Debug)]
pub struct ForwardOptions {
    /// State filter policy.
    pub filter: FilterConfig,
}

impl Default for ForwardOptions {
    fn default() -> Self {
        ForwardOptions { filter: FilterConfig::None }
    }
}

/// Output of the forward pass.
#[derive(Clone, Debug)]
pub struct ForwardResult {
    /// Scaled forward rows, one per timestep.
    pub rows: Vec<SparseRow>,
    /// Per-timestep scale factors `c_t`.
    pub scales: Vec<f32>,
    /// `log P(S | G) = Σ log c_t`.
    pub loglik: f64,
    /// Filtering instrumentation.
    pub filter_stats: FilterStats,
    /// Total states processed (Σ_t active states) — the workload metric
    /// consumed by the accelerator model.
    pub states_processed: u64,
    /// Total edges traversed (Σ_t Σ_active out-degree).
    pub edges_processed: u64,
}

/// Scratch buffers reused across timesteps (no allocation in the loop).
struct Scratch {
    dense: Vec<f32>,
    /// Incoming CSR (gather-form forward): row pointers per target.
    in_ptr: Vec<u32>,
    /// Source state of each incoming edge.
    in_from: Vec<u32>,
    /// Transition probability of each incoming edge.
    in_prob: Vec<f32>,
}

impl Scratch {
    fn new(phmm: &Phmm) -> Self {
        let (in_ptr, in_from, in_eidx) = phmm.incoming_csr();
        let in_prob = in_eidx.iter().map(|&e| phmm.out_prob[e as usize]).collect();
        Scratch { dense: vec![0.0; phmm.n_states()], in_ptr, in_from, in_prob }
    }
}

/// Run the scaled, filtered forward pass of `seq` over `phmm`.
pub fn forward_sparse(phmm: &Phmm, seq: &Sequence, opts: &ForwardOptions) -> Result<ForwardResult> {
    if phmm.has_silent_states() {
        return Err(ApHmmError::InvalidGraph("forward_sparse requires an emitting graph".into()));
    }
    if seq.is_empty() {
        return Err(ApHmmError::Numerical("empty observation sequence".into()));
    }
    let n = phmm.n_states();
    let t_len = seq.len();
    let mut scratch = Scratch::new(phmm);
    let mut hist = match opts.filter {
        FilterConfig::Histogram { bins, .. } => Some(HistogramFilter::new(bins)),
        _ => None,
    };
    let mut stats = FilterStats::default();
    let mut rows: Vec<SparseRow> = Vec::with_capacity(t_len);
    let mut scales: Vec<f32> = Vec::with_capacity(t_len);
    let mut loglik = 0.0f64;
    let mut states_processed = 0u64;
    let mut edges_processed = 0u64;

    // t = 0: initial distribution times emission.
    {
        let s0 = seq.data[0];
        let mut idx = Vec::new();
        let mut val = Vec::new();
        for (i, &p) in phmm.f_init.iter().enumerate() {
            if p > 0.0 {
                let v = p * phmm.emission(i, s0);
                if v > 0.0 {
                    idx.push(i as u32);
                    val.push(v);
                }
            }
        }
        let c: f32 = val.iter().sum();
        if c <= 0.0 {
            return Err(ApHmmError::Numerical("dead start: no state emits first char".into()));
        }
        val.iter_mut().for_each(|v| *v /= c);
        apply_filter(&opts.filter, &mut hist, &mut idx, &mut val, &mut stats);
        states_processed += idx.len() as u64;
        scales.push(c);
        loglik += (c as f64).ln();
        rows.push(SparseRow { idx, val });
    }

    // Gather-form forward (§Perf in EXPERIMENTS.md): pHMM topology
    // bounds every timestep's successors to the window
    // [first_active, last_active + band_width), so instead of
    // scattering along outgoing edges (random read-modify-writes) each
    // window target gathers its incoming contributions — sequential
    // reads of the incoming CSR, independent accumulators (better ILP),
    // and no touched-list/sort bookkeeping.
    let band = phmm.band_width();
    let sigma = phmm.sigma();
    for t in 1..t_len {
        let s_t = seq.data[t] as usize;
        let prev = rows.last().unwrap();
        // Write the previous row into the dense buffer.
        for (&i, &v) in prev.idx.iter().zip(prev.val.iter()) {
            scratch.dense[i as usize] = v;
        }
        let win_lo = prev.idx.first().map(|&i| i as usize).unwrap_or(0);
        let win_hi = prev.idx.last().map(|&i| i as usize + band).unwrap_or(0).min(n);
        let mut idx = Vec::with_capacity(win_hi - win_lo);
        let mut val = Vec::with_capacity(win_hi - win_lo);
        let mut c = 0.0f32;
        // SAFETY: incoming-CSR invariants mirror the outgoing CSR
        // (built by incoming_csr from a validated graph); window bounds
        // are clamped to n.
        unsafe {
            for to in win_lo..win_hi {
                let lo = *scratch.in_ptr.get_unchecked(to) as usize;
                let hi = *scratch.in_ptr.get_unchecked(to + 1) as usize;
                let mut acc = 0.0f32;
                for e in lo..hi {
                    let from = *scratch.in_from.get_unchecked(e) as usize;
                    acc += scratch.dense.get_unchecked(from) * scratch.in_prob.get_unchecked(e);
                }
                edges_processed += (hi - lo) as u64;
                if acc > 0.0 {
                    let v = acc * phmm.emissions.get_unchecked(to * sigma + s_t);
                    if v > 0.0 {
                        idx.push(to as u32);
                        val.push(v);
                        c += v;
                    }
                }
            }
        }
        // Clear the dense buffer at the previous row's entries.
        for &i in prev.idx.iter() {
            scratch.dense[i as usize] = 0.0;
        }
        if c <= EPS {
            return Err(ApHmmError::Numerical(format!("forward died at t={t}")));
        }
        let inv = 1.0 / c;
        val.iter_mut().for_each(|v| *v *= inv);
        apply_filter(&opts.filter, &mut hist, &mut idx, &mut val, &mut stats);
        states_processed += idx.len() as u64;
        scales.push(c);
        loglik += (c as f64).ln();
        rows.push(SparseRow { idx, val });
    }

    Ok(ForwardResult { rows, scales, loglik, filter_stats: stats, states_processed, edges_processed })
}

fn apply_filter(
    cfg: &FilterConfig,
    hist: &mut Option<HistogramFilter>,
    idx: &mut Vec<u32>,
    val: &mut Vec<f32>,
    stats: &mut FilterStats,
) {
    match cfg {
        FilterConfig::None => {}
        FilterConfig::Sort { size } => SortFilter::select(idx, val, *size, stats),
        FilterConfig::Histogram { size, .. } => {
            hist.as_mut().unwrap().select(idx, val, *size, stats)
        }
    }
}

/// Forward-only similarity score `log P(S | G)` (the inference path of
/// protein family search / MSA).
pub fn score_sparse(phmm: &Phmm, seq: &Sequence, opts: &ForwardOptions) -> Result<f64> {
    Ok(forward_sparse(phmm, seq, opts)?.loglik)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::baumwelch::logspace::log_likelihood;
    use crate::phmm::EcDesignParams;
    use crate::sim::XorShift;
    use crate::testutil;

    fn ec_graph(rng: &mut XorShift, len: usize) -> Phmm {
        let data = testutil::random_seq(rng, len, 4);
        let seq = Sequence::from_symbols("ref", data);
        Phmm::error_correction(&seq, &EcDesignParams::default()).unwrap()
    }

    #[test]
    fn forward_rows_are_normalized() {
        testutil::check(20, |rng| {
            let __h0 = rng.range(5, 60);
            let g = ec_graph(rng, __h0);
            let __h0 = rng.range(2, 30);
            let obs = Sequence::from_symbols("o", testutil::random_seq(rng, __h0, 4));
            let r = forward_sparse(&g, &obs, &ForwardOptions::default()).unwrap();
            for row in &r.rows {
                let s: f32 = row.val.iter().sum();
                assert!((s - 1.0).abs() < 1e-4, "row sum {s}");
            }
            assert_eq!(r.rows.len(), obs.len());
            assert_eq!(r.scales.len(), obs.len());
        });
    }

    #[test]
    fn loglik_matches_logspace_oracle() {
        testutil::check(20, |rng| {
            let __h0 = rng.range(5, 40);
            let g = ec_graph(rng, __h0);
            let __h0 = rng.range(2, 20);
            let obs = Sequence::from_symbols("o", testutil::random_seq(rng, __h0, 4));
            let got = score_sparse(&g, &obs, &ForwardOptions::default()).unwrap();
            let want = log_likelihood(&g, &obs);
            testutil::assert_close(got, want, 1e-4, 1e-5);
        });
    }

    #[test]
    fn identical_sequence_scores_higher_than_random() {
        let mut rng = XorShift::new(77);
        let data = testutil::random_seq(&mut rng, 50, 4);
        let refseq = Sequence::from_symbols("ref", data.clone());
        let g = Phmm::error_correction(&refseq, &EcDesignParams::default()).unwrap();
        let same = score_sparse(&g, &refseq, &ForwardOptions::default()).unwrap();
        let other =
            Sequence::from_symbols("rnd", testutil::random_seq(&mut rng, 50, 4));
        let diff = score_sparse(&g, &other, &ForwardOptions::default()).unwrap();
        assert!(same > diff + 5.0, "same={same} diff={diff}");
    }

    #[test]
    fn filter_bounds_active_states() {
        let mut rng = XorShift::new(3);
        let g = ec_graph(&mut rng, 300);
        let obs = Sequence::from_symbols("o", testutil::random_seq(&mut rng, 100, 4));
        let opts = ForwardOptions { filter: FilterConfig::Sort { size: 50 } };
        let r = forward_sparse(&g, &obs, &opts).unwrap();
        for row in &r.rows {
            assert!(row.len() <= 50);
        }
        assert!(r.filter_stats.calls > 0);
    }

    #[test]
    fn histogram_filter_close_to_unfiltered_loglik() {
        let mut rng = XorShift::new(5);
        let data = testutil::random_seq(&mut rng, 200, 4);
        let refseq = Sequence::from_symbols("ref", data);
        let g = Phmm::error_correction(&refseq, &EcDesignParams::default()).unwrap();
        // Observation close to the reference so mass is concentrated.
        let exact = score_sparse(&g, &refseq, &ForwardOptions::default()).unwrap();
        let opts = ForwardOptions { filter: FilterConfig::Histogram { size: 500, bins: 16 } };
        let filt = score_sparse(&g, &refseq, &opts).unwrap();
        assert!((exact - filt).abs() / exact.abs() < 0.02, "{exact} vs {filt}");
    }

    #[test]
    fn empty_sequence_rejected() {
        let mut rng = XorShift::new(9);
        let g = ec_graph(&mut rng, 10);
        let obs = Sequence::from_symbols("o", vec![]);
        assert!(forward_sparse(&g, &obs, &ForwardOptions::default()).is_err());
    }

    #[test]
    fn workload_counters_grow_with_sequence() {
        let mut rng = XorShift::new(11);
        let g = ec_graph(&mut rng, 100);
        let short = Sequence::from_symbols("s", testutil::random_seq(&mut rng, 10, 4));
        let long = Sequence::from_symbols("l", testutil::random_seq(&mut rng, 60, 4));
        let r_s = forward_sparse(&g, &short, &ForwardOptions::default()).unwrap();
        let r_l = forward_sparse(&g, &long, &ForwardOptions::default()).unwrap();
        assert!(r_l.states_processed > r_s.states_processed);
        assert!(r_l.edges_processed > r_s.edges_processed);
    }
}
