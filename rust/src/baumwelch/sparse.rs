//! Sparse (CSR) scaled forward pass with state filtering.
//!
//! This is the faithful CPU implementation of Eq. 1: per timestep the
//! active-state set scatters probability mass along outgoing edges, the
//! row is scaled to sum 1, and the filter truncates the active set.  It
//! is both the "CPU-1" measured baseline of Figs. 10/11 and the workload
//! description the accelerator model consumes.
//!
//! Two kernels share one inner loop, both driven by the memoized
//! per-symbol fused-coefficient tables of [`super::kernels`] (paper
//! §4.2–4.3 — the transition×emission products are computed once per
//! parameter freeze, turning the timestep recurrence into a pure
//! per-symbol CSR SpMV):
//!
//! * [`forward_sparse_with`] materializes every scaled row (training —
//!   the fused backward pass needs them);
//! * [`score_sparse_with`] keeps only two rows — `O(active states)`
//!   memory independent of sequence length (the inference path of
//!   protein family search / MSA, after Miklós & Meyer's linear-memory
//!   formulation).
//!
//! The parameterless [`forward_sparse`] / [`score_sparse`] wrappers
//! build throwaway tables and scratch; hot paths build
//! [`FusedCoeffs`]/[`ForwardScratch`] once and call the `_with` forms.

use super::filter::{FilterConfig, FilterStats, HistogramFilter, SortFilter};
use super::kernels::{ForwardScratch, FusedCoeffs};
use super::EPS;
use crate::error::{ApHmmError, Result};
use crate::phmm::Phmm;
use crate::seq::Sequence;

/// One scaled forward row: active states and their F̂ values.
#[derive(Clone, Debug, Default)]
pub struct SparseRow {
    /// Active state indices (ascending).
    pub idx: Vec<u32>,
    /// Scaled forward values (aligned with `idx`).
    pub val: Vec<f32>,
}

impl SparseRow {
    /// Number of active states.
    pub fn len(&self) -> usize {
        self.idx.len()
    }

    /// True when the row is empty.
    pub fn is_empty(&self) -> bool {
        self.idx.is_empty()
    }
}

/// Options of the forward pass.
#[derive(Clone, Copy, Debug)]
pub struct ForwardOptions {
    /// State filter policy.
    pub filter: FilterConfig,
}

impl Default for ForwardOptions {
    fn default() -> Self {
        ForwardOptions { filter: FilterConfig::None }
    }
}

/// Output of the forward pass.
#[derive(Clone, Debug)]
pub struct ForwardResult {
    /// Scaled forward rows, one per timestep.
    pub rows: Vec<SparseRow>,
    /// Per-timestep scale factors `c_t`.
    pub scales: Vec<f32>,
    /// `log P(S | G) = Σ log c_t`.
    pub loglik: f64,
    /// Filtering instrumentation.
    pub filter_stats: FilterStats,
    /// Total states processed (Σ_t active states) — the workload metric
    /// consumed by the accelerator model.
    pub states_processed: u64,
    /// Total edges traversed (Σ_t Σ_active out-degree).
    pub edges_processed: u64,
}

/// Output of the score-only fast path: the likelihood plus the workload
/// counters, but no rows (memory stays `O(active states)`).
#[derive(Clone, Copy, Debug)]
pub struct ScoreResult {
    /// `log P(S | G)`.
    pub loglik: f64,
    /// Filtering instrumentation.
    pub filter_stats: FilterStats,
    /// Total states processed.
    pub states_processed: u64,
    /// Total edges traversed.
    pub edges_processed: u64,
}

/// Validate inputs shared by both kernels.
fn precheck(phmm: &Phmm, coeffs: &FusedCoeffs, seq: &Sequence) -> Result<()> {
    if phmm.has_silent_states() {
        return Err(ApHmmError::InvalidGraph("forward_sparse requires an emitting graph".into()));
    }
    if seq.is_empty() {
        return Err(ApHmmError::Numerical("empty observation sequence".into()));
    }
    if coeffs.n_edges() != phmm.n_transitions()
        || coeffs.sigma() != phmm.sigma()
        || coeffs.in_ptr.len() != phmm.n_states() + 1
    {
        return Err(ApHmmError::InvalidGraph(
            "fused coefficient tables do not match the graph (stale FusedCoeffs?)".into(),
        ));
    }
    let sigma = phmm.sigma() as u32;
    if seq.data.iter().any(|&s| s as u32 >= sigma) {
        return Err(ApHmmError::Numerical(format!(
            "sequence {:?} contains a symbol outside the {}-letter alphabet",
            seq.id, sigma
        )));
    }
    Ok(())
}

/// t = 0 row: initial distribution times emission (unscaled).
fn init_row(phmm: &Phmm, coeffs: &FusedCoeffs, s0: u8, row: &mut SparseRow) -> Result<f32> {
    row.idx.clear();
    row.val.clear();
    for &(i, p) in &coeffs.init {
        let v = p * phmm.emission(i as usize, s0);
        if v > 0.0 {
            row.idx.push(i);
            row.val.push(v);
        }
    }
    let c: f32 = row.val.iter().sum();
    if c <= 0.0 {
        return Err(ApHmmError::Numerical("dead start: no state emits first char".into()));
    }
    Ok(c)
}

/// Gather one timestep: scatter `prev` into the dense buffer, run the
/// per-symbol fused SpMV over the topology window, clear the buffer.
///
/// Returns the unscaled row sum `c` and the number of edges traversed.
/// `out` receives the unscaled row.  The dense buffer is restored to
/// all-zero before returning (also on dead rows), so scratch reuse is
/// safe even on error paths.
#[inline]
fn gather_row(
    coeffs: &FusedCoeffs,
    dense: &mut [f32],
    prev: &SparseRow,
    s_t: usize,
    n: usize,
    out: &mut SparseRow,
) -> (f32, u64) {
    out.idx.clear();
    out.val.clear();
    for (&i, &v) in prev.idx.iter().zip(prev.val.iter()) {
        dense[i as usize] = v;
    }
    // Gather-form forward (§Perf in EXPERIMENTS.md): pHMM topology
    // bounds every timestep's successors to the window
    // [first_active, last_active + band), so each window target gathers
    // its incoming contributions — sequential reads of the incoming
    // CSR, independent accumulators, no scatter bookkeeping.  The fused
    // coefficient already carries the target's emission, so the row
    // value is the raw accumulator.
    let win_lo = prev.idx.first().map(|&i| i as usize).unwrap_or(0);
    let win_hi = prev.idx.last().map(|&i| i as usize + coeffs.band).unwrap_or(0).min(n);
    out.idx.reserve(win_hi.saturating_sub(win_lo));
    out.val.reserve(win_hi.saturating_sub(win_lo));
    let coef = coeffs.in_coef_for(s_t);
    let mut c = 0.0f32;
    let mut edges = 0u64;
    // SAFETY: incoming-CSR invariants mirror the outgoing CSR (built by
    // incoming_csr from a validated graph), the window bounds are
    // clamped to n ≤ dense.len(), and `precheck` guarantees s_t < Σ so
    // `coef` covers every edge index.
    unsafe {
        for to in win_lo..win_hi {
            let lo = *coeffs.in_ptr.get_unchecked(to) as usize;
            let hi = *coeffs.in_ptr.get_unchecked(to + 1) as usize;
            let mut acc = 0.0f32;
            for e in lo..hi {
                let from = *coeffs.in_from.get_unchecked(e) as usize;
                acc += *dense.get_unchecked(from) * *coef.get_unchecked(e);
            }
            edges += (hi - lo) as u64;
            if acc > 0.0 {
                out.idx.push(to as u32);
                out.val.push(acc);
                c += acc;
            }
        }
    }
    for &i in prev.idx.iter() {
        dense[i as usize] = 0.0;
    }
    (c, edges)
}

/// Run the scaled, filtered forward pass of `seq` over `phmm`, reusing
/// the caller's fused tables and scratch (the training hot path).
pub fn forward_sparse_with(
    phmm: &Phmm,
    coeffs: &FusedCoeffs,
    seq: &Sequence,
    opts: &ForwardOptions,
    scratch: &mut ForwardScratch,
) -> Result<ForwardResult> {
    precheck(phmm, coeffs, seq)?;
    let n = phmm.n_states();
    scratch.ensure(n);
    scratch.ensure_hist(&opts.filter);
    let t_len = seq.len();
    let mut stats = FilterStats::default();
    let mut rows = scratch.take_rows_vec();
    let mut scales = scratch.take_scales_vec();
    rows.reserve(t_len);
    scales.reserve(t_len);
    let mut loglik = 0.0f64;
    let mut states_processed = 0u64;
    let mut edges_processed = 0u64;

    {
        let mut row = scratch.take_row();
        let c = init_row(phmm, coeffs, seq.data[0], &mut row)?;
        let inv = 1.0 / c;
        row.val.iter_mut().for_each(|v| *v *= inv);
        apply_filter(&opts.filter, &mut scratch.hist, &mut row.idx, &mut row.val, &mut stats);
        states_processed += row.len() as u64;
        scales.push(c);
        loglik += (c as f64).ln();
        rows.push(row);
    }

    for t in 1..t_len {
        let s_t = seq.data[t] as usize;
        let mut row = scratch.take_row();
        let prev = rows.last().unwrap();
        let (c, edges) = gather_row(coeffs, &mut scratch.dense, prev, s_t, n, &mut row);
        edges_processed += edges;
        if c <= EPS {
            return Err(ApHmmError::Numerical(format!("forward died at t={t}")));
        }
        let inv = 1.0 / c;
        row.val.iter_mut().for_each(|v| *v *= inv);
        apply_filter(&opts.filter, &mut scratch.hist, &mut row.idx, &mut row.val, &mut stats);
        states_processed += row.len() as u64;
        scales.push(c);
        loglik += (c as f64).ln();
        rows.push(row);
    }

    Ok(ForwardResult { rows, scales, loglik, filter_stats: stats, states_processed, edges_processed })
}

/// Run the scaled, filtered forward pass of `seq` over `phmm`.
///
/// Convenience wrapper that builds throwaway tables and scratch; hot
/// paths should use [`forward_sparse_with`].
pub fn forward_sparse(phmm: &Phmm, seq: &Sequence, opts: &ForwardOptions) -> Result<ForwardResult> {
    let coeffs = FusedCoeffs::new(phmm);
    let mut scratch = ForwardScratch::new(phmm);
    forward_sparse_with(phmm, &coeffs, seq, opts, &mut scratch)
}

/// Score-only forward fast path: identical arithmetic to
/// [`forward_sparse_with`] (bit-identical log-likelihood), but only two
/// rows are ever live — memory is `O(active states)` regardless of
/// sequence length.
pub fn score_sparse_with(
    phmm: &Phmm,
    coeffs: &FusedCoeffs,
    seq: &Sequence,
    opts: &ForwardOptions,
    scratch: &mut ForwardScratch,
) -> Result<ScoreResult> {
    precheck(phmm, coeffs, seq)?;
    let n = phmm.n_states();
    scratch.ensure(n);
    scratch.ensure_hist(&opts.filter);
    let t_len = seq.len();
    let mut stats = FilterStats::default();
    let mut prev = scratch.take_row();
    let mut cur = scratch.take_row();
    let mut loglik = 0.0f64;
    let mut states_processed = 0u64;
    let mut edges_processed = 0u64;

    let finish = |scratch: &mut ForwardScratch, prev: SparseRow, cur: SparseRow| {
        scratch.put_row(prev);
        scratch.put_row(cur);
    };

    let c0 = match init_row(phmm, coeffs, seq.data[0], &mut prev) {
        Ok(c) => c,
        Err(e) => {
            finish(scratch, prev, cur);
            return Err(e);
        }
    };
    let inv = 1.0 / c0;
    prev.val.iter_mut().for_each(|v| *v *= inv);
    apply_filter(&opts.filter, &mut scratch.hist, &mut prev.idx, &mut prev.val, &mut stats);
    states_processed += prev.len() as u64;
    loglik += (c0 as f64).ln();

    for t in 1..t_len {
        let s_t = seq.data[t] as usize;
        let (c, edges) = gather_row(coeffs, &mut scratch.dense, &prev, s_t, n, &mut cur);
        edges_processed += edges;
        if c <= EPS {
            finish(scratch, prev, cur);
            return Err(ApHmmError::Numerical(format!("forward died at t={t}")));
        }
        let inv = 1.0 / c;
        cur.val.iter_mut().for_each(|v| *v *= inv);
        apply_filter(&opts.filter, &mut scratch.hist, &mut cur.idx, &mut cur.val, &mut stats);
        states_processed += cur.len() as u64;
        loglik += (c as f64).ln();
        std::mem::swap(&mut prev, &mut cur);
    }

    finish(scratch, prev, cur);
    Ok(ScoreResult { loglik, filter_stats: stats, states_processed, edges_processed })
}

fn apply_filter(
    cfg: &FilterConfig,
    hist: &mut Option<HistogramFilter>,
    idx: &mut Vec<u32>,
    val: &mut Vec<f32>,
    stats: &mut FilterStats,
) {
    match cfg {
        FilterConfig::None => {}
        FilterConfig::Sort { size } => SortFilter::select(idx, val, *size, stats),
        FilterConfig::Histogram { size, .. } => {
            hist.as_mut().unwrap().select(idx, val, *size, stats)
        }
    }
}

/// Forward-only similarity score `log P(S | G)` (the inference path of
/// protein family search / MSA).
///
/// Convenience wrapper over [`score_sparse_with`]; uses the two-row
/// fast path, so memory stays independent of sequence length.
pub fn score_sparse(phmm: &Phmm, seq: &Sequence, opts: &ForwardOptions) -> Result<f64> {
    let coeffs = FusedCoeffs::new(phmm);
    let mut scratch = ForwardScratch::new(phmm);
    Ok(score_sparse_with(phmm, &coeffs, seq, opts, &mut scratch)?.loglik)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::baumwelch::logspace::log_likelihood;
    use crate::phmm::EcDesignParams;
    use crate::sim::XorShift;
    use crate::testutil;

    fn ec_graph(rng: &mut XorShift, len: usize) -> Phmm {
        let data = testutil::random_seq(rng, len, 4);
        let seq = Sequence::from_symbols("ref", data);
        Phmm::error_correction(&seq, &EcDesignParams::default()).unwrap()
    }

    #[test]
    fn forward_rows_are_normalized() {
        testutil::check(20, |rng| {
            let __h0 = rng.range(5, 60);
            let g = ec_graph(rng, __h0);
            let __h0 = rng.range(2, 30);
            let obs = Sequence::from_symbols("o", testutil::random_seq(rng, __h0, 4));
            let r = forward_sparse(&g, &obs, &ForwardOptions::default()).unwrap();
            for row in &r.rows {
                let s: f32 = row.val.iter().sum();
                assert!((s - 1.0).abs() < 1e-4, "row sum {s}");
            }
            assert_eq!(r.rows.len(), obs.len());
            assert_eq!(r.scales.len(), obs.len());
        });
    }

    #[test]
    fn loglik_matches_logspace_oracle() {
        testutil::check(20, |rng| {
            let __h0 = rng.range(5, 40);
            let g = ec_graph(rng, __h0);
            let __h0 = rng.range(2, 20);
            let obs = Sequence::from_symbols("o", testutil::random_seq(rng, __h0, 4));
            let got = score_sparse(&g, &obs, &ForwardOptions::default()).unwrap();
            let want = log_likelihood(&g, &obs);
            testutil::assert_close(got, want, 1e-4, 1e-5);
        });
    }

    #[test]
    fn score_fast_path_matches_full_forward_bitwise() {
        // Same arithmetic, different row lifetime: the two kernels must
        // agree to the last bit, filters on and off.
        testutil::check(15, |rng| {
            let ref_len = rng.range(5, 50);
            let g = ec_graph(rng, ref_len);
            let obs_len = rng.range(2, 40);
            let obs = Sequence::from_symbols("o", testutil::random_seq(rng, obs_len, 4));
            for opts in [
                ForwardOptions::default(),
                ForwardOptions { filter: FilterConfig::Sort { size: 30 } },
                ForwardOptions { filter: FilterConfig::Histogram { size: 30, bins: 64 } },
            ] {
                let full = forward_sparse(&g, &obs, &opts).unwrap();
                let fast = score_sparse(&g, &obs, &opts).unwrap();
                assert_eq!(full.loglik.to_bits(), fast.to_bits(), "filter {:?}", opts.filter);
            }
        });
    }

    #[test]
    fn scratch_reuse_is_transparent() {
        // One coeffs/scratch pair across many reads gives the same
        // results as throwaway buffers, and stops allocating rows once
        // the pool is warm.
        let mut rng = XorShift::new(71);
        let g = ec_graph(&mut rng, 60);
        let coeffs = FusedCoeffs::new(&g);
        let mut scratch = ForwardScratch::new(&g);
        let opts = ForwardOptions::default();
        let mut allocated_after_first = 0;
        for i in 0..5 {
            let obs = Sequence::from_symbols("o", testutil::random_seq(&mut rng, 25, 4));
            let fresh = forward_sparse(&g, &obs, &opts).unwrap();
            let reused = forward_sparse_with(&g, &coeffs, &obs, &opts, &mut scratch).unwrap();
            assert_eq!(fresh.loglik.to_bits(), reused.loglik.to_bits());
            assert_eq!(fresh.states_processed, reused.states_processed);
            assert_eq!(fresh.edges_processed, reused.edges_processed);
            scratch.recycle(reused);
            if i == 0 {
                allocated_after_first = scratch.fresh_rows_allocated();
            }
        }
        assert_eq!(
            scratch.fresh_rows_allocated(),
            allocated_after_first,
            "row pool must absorb equal-length reads without new allocations"
        );
    }

    #[test]
    fn identical_sequence_scores_higher_than_random() {
        let mut rng = XorShift::new(77);
        let data = testutil::random_seq(&mut rng, 50, 4);
        let refseq = Sequence::from_symbols("ref", data.clone());
        let g = Phmm::error_correction(&refseq, &EcDesignParams::default()).unwrap();
        let same = score_sparse(&g, &refseq, &ForwardOptions::default()).unwrap();
        let other =
            Sequence::from_symbols("rnd", testutil::random_seq(&mut rng, 50, 4));
        let diff = score_sparse(&g, &other, &ForwardOptions::default()).unwrap();
        assert!(same > diff + 5.0, "same={same} diff={diff}");
    }

    #[test]
    fn filter_bounds_active_states() {
        let mut rng = XorShift::new(3);
        let g = ec_graph(&mut rng, 300);
        let obs = Sequence::from_symbols("o", testutil::random_seq(&mut rng, 100, 4));
        let opts = ForwardOptions { filter: FilterConfig::Sort { size: 50 } };
        let r = forward_sparse(&g, &obs, &opts).unwrap();
        for row in &r.rows {
            assert!(row.len() <= 50);
        }
        assert!(r.filter_stats.calls > 0);
    }

    #[test]
    fn histogram_filter_close_to_unfiltered_loglik() {
        let mut rng = XorShift::new(5);
        let data = testutil::random_seq(&mut rng, 200, 4);
        let refseq = Sequence::from_symbols("ref", data);
        let g = Phmm::error_correction(&refseq, &EcDesignParams::default()).unwrap();
        // Observation close to the reference so mass is concentrated.
        let exact = score_sparse(&g, &refseq, &ForwardOptions::default()).unwrap();
        let opts = ForwardOptions { filter: FilterConfig::Histogram { size: 500, bins: 16 } };
        let filt = score_sparse(&g, &refseq, &opts).unwrap();
        assert!((exact - filt).abs() / exact.abs() < 0.02, "{exact} vs {filt}");
    }

    #[test]
    fn empty_sequence_rejected() {
        let mut rng = XorShift::new(9);
        let g = ec_graph(&mut rng, 10);
        let obs = Sequence::from_symbols("o", vec![]);
        assert!(forward_sparse(&g, &obs, &ForwardOptions::default()).is_err());
    }

    #[test]
    fn out_of_alphabet_symbol_rejected() {
        let mut rng = XorShift::new(13);
        let g = ec_graph(&mut rng, 10);
        let obs = Sequence::from_symbols("o", vec![0, 1, 200]);
        assert!(forward_sparse(&g, &obs, &ForwardOptions::default()).is_err());
        assert!(score_sparse(&g, &obs, &ForwardOptions::default()).is_err());
    }

    #[test]
    fn workload_counters_grow_with_sequence() {
        let mut rng = XorShift::new(11);
        let g = ec_graph(&mut rng, 100);
        let short = Sequence::from_symbols("s", testutil::random_seq(&mut rng, 10, 4));
        let long = Sequence::from_symbols("l", testutil::random_seq(&mut rng, 60, 4));
        let r_s = forward_sparse(&g, &short, &ForwardOptions::default()).unwrap();
        let r_l = forward_sparse(&g, &long, &ForwardOptions::default()).unwrap();
        assert!(r_l.states_processed > r_s.states_processed);
        assert!(r_l.edges_processed > r_s.edges_processed);
    }
}
