//! Sparse (CSR) scaled forward pass with state filtering and
//! density-adaptive in-window gather dispatch.
//!
//! This is the faithful CPU implementation of Eq. 1: per timestep the
//! active-state set scatters probability mass along outgoing edges, the
//! row is scaled to sum 1, and the filter truncates the active set.  It
//! is both the "CPU-1" measured baseline of Figs. 10/11 and the workload
//! description the accelerator model consumes.
//!
//! Two kernels share one inner loop, both driven by the memoized
//! per-symbol fused-coefficient tables of [`super::kernels`] (paper
//! §4.2–4.3 — the transition×emission products are computed once per
//! parameter freeze, turning the timestep recurrence into a pure
//! per-symbol gather):
//!
//! * [`forward_sparse_with`] materializes every scaled row (training —
//!   the fused backward pass needs them);
//! * [`score_sparse_with`] keeps only two rows — `O(active states)`
//!   memory independent of sequence length (the inference path of
//!   protein family search / MSA, after Miklós & Meyer's linear-memory
//!   formulation).
//!
//! Each forward row is executed by one of two gather kernels over the
//! shared [`super::Lowering`], selected per row by
//! [`ForwardOptions::gather`]:
//!
//! * the **CSR gather** walks each window target's incoming slots
//!   (indexed loads);
//! * the **dense-tile kernel** dot-products each target's fixed-width
//!   tile row ([`super::DenseTiles`]) against a contiguous window of
//!   the scratch buffer — branchless and auto-vectorizable.
//!
//! The default [`GatherKind::Adaptive`] policy picks the tile kernel
//! when the filter-admitted window density reaches
//! [`DENSE_TILE_MIN_DENSITY`] (near-dense unfiltered EC rows) and the
//! CSR gather otherwise.  Under [`SimdPolicy::Scalar`] both kernels sum
//! in ascending-source order so the rows — and everything downstream —
//! are **bit-identical** either way.  Wider lane policies
//! ([`SimdPolicy::F32x4`]/[`SimdPolicy::F32x8`], or whatever `Auto`
//! resolves to) reduce the tile dot product with the fixed lane tree of
//! [`super::simd`]: still fully deterministic for a given width, but a
//! reassociation of the scalar sum — tile-kernel rows then agree with
//! the CSR gather within the pinned
//! [`super::simd::SIMD_REASSOC_RTOL`] tier instead of bitwise (the CSR
//! gather itself is scalar under every policy).  The per-row choice is
//! counted in [`FilterStats::rows_dense_tile`]/[`FilterStats::rows_csr`].
//!
//! The parameterless [`forward_sparse`] / [`score_sparse`] wrappers
//! build throwaway tables and scratch; hot paths build
//! [`FusedCoeffs`]/[`ForwardScratch`] once and call the `_with` forms.

use super::filter::{FilterConfig, FilterStats, HistogramFilter, SortFilter};
use super::kernels::{ForwardScratch, FusedCoeffs};
use super::lowering::{GatherKind, DENSE_TILE_MIN_DENSITY};
use super::simd::{self, SimdLanes, SimdPolicy};
use super::EPS;
use crate::error::{ApHmmError, Result};
use crate::phmm::Phmm;
use crate::seq::Sequence;

/// One scaled forward row: active states and their F̂ values.
#[derive(Clone, Debug, Default)]
pub struct SparseRow {
    /// Active state indices (ascending).
    pub idx: Vec<u32>,
    /// Scaled forward values (aligned with `idx`).
    pub val: Vec<f32>,
}

impl SparseRow {
    /// Number of active states.
    pub fn len(&self) -> usize {
        self.idx.len()
    }

    /// True when the row is empty.
    pub fn is_empty(&self) -> bool {
        self.idx.is_empty()
    }
}

/// Training-path scratch policy: how the E-step stores forward rows
/// between the forward pass and the fused backward/update sweep.
///
/// `Full` materializes every scaled row — `O(T·states)` scratch, no
/// recompute.  `Checkpointed` keeps only every ⌈√T⌉-th post-filter row
/// (plus all `T` scales) and recomputes each segment from its
/// checkpoint during the backward sweep (Miklós & Meyer's linear-memory
/// Baum-Welch): `O(√T·states)` scratch for one extra forward's worth of
/// compute.  Recomputed rows replay the exact forward kernel sequence
/// from an exactly-stored row, so they are **bit-identical** to the
/// full-matrix rows — and so are the E-step sums consuming them.
/// `Auto` resolves per read via [`ScratchMode::resolve`].
///
/// The score paths ([`score_sparse_with`] and friends) already run in
/// `O(active states)` and ignore this knob.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq, Hash)]
pub enum ScratchMode {
    /// Materialize every forward row (the original behavior).
    #[default]
    Full,
    /// √T forward-recomputation checkpointing.
    Checkpointed,
    /// Per read: checkpoint iff the estimated full-matrix footprint
    /// ([`full_scratch_estimate`]) exceeds the scratch budget
    /// (`max_scratch_bytes`; budget 0 = unlimited = `Full`).
    Auto,
}

impl ScratchMode {
    /// Mode names for config parsing / display.
    pub const NAMES: &'static [&'static str] = &["full", "checkpointed", "auto"];

    /// Parse a config-file mode name.
    pub fn parse(name: &str) -> Option<ScratchMode> {
        match name {
            "full" => Some(ScratchMode::Full),
            "checkpointed" => Some(ScratchMode::Checkpointed),
            "auto" => Some(ScratchMode::Auto),
            _ => None,
        }
    }

    /// Canonical name of the mode.
    pub fn name(self) -> &'static str {
        match self {
            ScratchMode::Full => "full",
            ScratchMode::Checkpointed => "checkpointed",
            ScratchMode::Auto => "auto",
        }
    }

    /// Resolve `Auto` for a concrete read: checkpoint when the estimated
    /// full-matrix scratch for `t_len` timesteps over `n_states` exceeds
    /// `budget` bytes.  A budget of 0 means unlimited, so `Auto`
    /// degenerates to `Full`.  Never returns `Auto`.
    pub fn resolve(self, t_len: usize, n_states: usize, budget: usize) -> ScratchMode {
        match self {
            ScratchMode::Auto => {
                if budget > 0 && full_scratch_estimate(t_len, n_states) > budget as u64 {
                    ScratchMode::Checkpointed
                } else {
                    ScratchMode::Full
                }
            }
            m => m,
        }
    }
}

/// Upper-bound estimate of the full-matrix forward scratch for a read:
/// every state active at every timestep, 8 bytes per active state
/// (`u32` index + `f32` value) plus 4 bytes per scale.  Used by
/// [`ScratchMode::Auto`] resolution and server admission — an estimate
/// by construction (filtering makes real rows sparser), chosen as an
/// upper bound so a budget refusal is never optimistic.
pub fn full_scratch_estimate(t_len: usize, n_states: usize) -> u64 {
    t_len as u64 * (n_states as u64 * 8 + 4)
}

/// Checkpoint interval: `K = ⌈√T⌉`, the Miklós & Meyer schedule that
/// balances stored rows (`T/K`) against the recompute buffer (`K`).
pub(super) fn checkpoint_interval(t_len: usize) -> usize {
    ((t_len as f64).sqrt().ceil() as usize).max(1)
}

/// Heap bytes held by one sparse row's index + value vectors.
pub(super) fn row_bytes(row: &SparseRow) -> u64 {
    row.idx.len() as u64 * (4 + 4)
}

/// Options of the forward pass.
#[derive(Clone, Copy, Debug)]
pub struct ForwardOptions {
    /// State filter policy.
    pub filter: FilterConfig,
    /// In-window gather kernel policy (per-row adaptive by default).
    pub gather: GatherKind,
    /// Lane-width policy for the dense-tile dot product (resolved once
    /// per pass; `APHMM_SIMD` overrides it process-wide).
    pub simd: SimdPolicy,
    /// Training-path scratch policy (engines resolve `Auto` per read).
    pub scratch: ScratchMode,
    /// Scratch budget in bytes consumed by [`ScratchMode::Auto`]
    /// resolution (0 = unlimited).
    pub max_scratch_bytes: usize,
}

impl Default for ForwardOptions {
    fn default() -> Self {
        ForwardOptions {
            filter: FilterConfig::None,
            gather: GatherKind::Adaptive,
            simd: SimdPolicy::Auto,
            scratch: ScratchMode::Full,
            max_scratch_bytes: 0,
        }
    }
}

/// Output of the forward pass.
#[derive(Clone, Debug)]
pub struct ForwardResult {
    /// Scaled forward rows, one per timestep.
    pub rows: Vec<SparseRow>,
    /// Per-timestep scale factors `c_t`.
    pub scales: Vec<f32>,
    /// `log P(S | G) = Σ log c_t`.
    pub loglik: f64,
    /// Filtering + gather-dispatch instrumentation.
    pub filter_stats: FilterStats,
    /// Total states processed (Σ_t active states) — the workload metric
    /// consumed by the accelerator model.
    pub states_processed: u64,
    /// Total edges traversed (Σ_t in-window incoming edges) — identical
    /// whichever gather kernel ran, so dispatch never perturbs the
    /// accelerator model's workload counters.
    pub edges_processed: u64,
}

/// Output of the score-only fast path: the likelihood plus the workload
/// counters, but no rows (memory stays `O(active states)`).
#[derive(Clone, Copy, Debug)]
pub struct ScoreResult {
    /// `log P(S | G)`.
    pub loglik: f64,
    /// Filtering + gather-dispatch instrumentation.
    pub filter_stats: FilterStats,
    /// Total states processed.
    pub states_processed: u64,
    /// Total edges traversed.
    pub edges_processed: u64,
}

/// Validate inputs shared by both kernels.
pub(super) fn precheck(phmm: &Phmm, coeffs: &FusedCoeffs, seq: &Sequence) -> Result<()> {
    if phmm.has_silent_states() {
        return Err(ApHmmError::InvalidGraph("forward_sparse requires an emitting graph".into()));
    }
    if seq.is_empty() {
        return Err(ApHmmError::Numerical("empty observation sequence".into()));
    }
    if coeffs.n_edges() != phmm.n_transitions()
        || coeffs.sigma() != phmm.sigma()
        || coeffs.lowering.in_ptr.len() != phmm.n_states() + 1
    {
        return Err(ApHmmError::InvalidGraph(
            "fused coefficient tables do not match the graph (stale FusedCoeffs?)".into(),
        ));
    }
    let sigma = phmm.sigma() as u32;
    if seq.data.iter().any(|&s| s as u32 >= sigma) {
        return Err(ApHmmError::Numerical(format!(
            "sequence {:?} contains a symbol outside the {}-letter alphabet",
            seq.id, sigma
        )));
    }
    Ok(())
}

/// True when some forward row of this (graph, policy) pair may
/// dispatch to the tile kernel — i.e. the lazy tile tables must exist.
/// Mirrors the `use_tile` gates of `gather_row`, minus the per-row
/// density term, so ineligible-graph `Adaptive` workloads (the default
/// EC configuration) never build or hold the tile tables at all.
#[inline]
pub(super) fn may_dispatch_tiles(coeffs: &FusedCoeffs, gather: GatherKind) -> bool {
    match gather {
        GatherKind::Csr => false,
        GatherKind::DenseTile => true,
        GatherKind::Adaptive => coeffs.lowering.tile_eligible,
    }
}

/// t = 0 row: initial distribution times emission (unscaled).
pub(super) fn init_row(
    phmm: &Phmm,
    coeffs: &FusedCoeffs,
    s0: u8,
    row: &mut SparseRow,
) -> Result<f32> {
    row.idx.clear();
    row.val.clear();
    for &(i, p) in &coeffs.lowering.init {
        let v = p * phmm.emission(i as usize, s0);
        if v > 0.0 {
            row.idx.push(i);
            row.val.push(v);
        }
    }
    let c: f32 = row.val.iter().sum();
    if c <= 0.0 {
        return Err(ApHmmError::Numerical("dead start: no state emits first char".into()));
    }
    Ok(c)
}

/// CSR gather over the window `[win_lo, win_hi)`: each target walks its
/// incoming slots (ascending source order).  `dense` carries `pad`
/// leading zeros — state `i` lives at slot `i + pad`.
#[inline]
fn gather_csr(
    coeffs: &FusedCoeffs,
    dense: &[f32],
    pad: usize,
    win_lo: usize,
    win_hi: usize,
    s_t: usize,
    out: &mut SparseRow,
) -> f32 {
    let low = &coeffs.lowering;
    let coef = coeffs.in_coef_for(s_t);
    let mut c = 0.0f32;
    // SAFETY: incoming-CSR invariants mirror the outgoing CSR (built by
    // incoming_csr from a validated graph), the window bounds are
    // clamped to n, `ensure` sized the dense buffer to n + pad, and
    // `precheck` guarantees s_t < Σ so `coef` covers every edge index.
    unsafe {
        for to in win_lo..win_hi {
            let lo = *low.in_ptr.get_unchecked(to) as usize;
            let hi = *low.in_ptr.get_unchecked(to + 1) as usize;
            let mut acc = 0.0f32;
            for e in lo..hi {
                let from = *low.in_from.get_unchecked(e) as usize;
                acc += *dense.get_unchecked(from + pad) * *coef.get_unchecked(e);
            }
            if acc > 0.0 {
                out.idx.push(to as u32);
                out.val.push(acc);
                c += acc;
            }
        }
    }
    c
}

/// Dense-tile gather over the same window: each target dot-products its
/// fixed-width tile row against the contiguous scratch slice
/// `dense[to..to + tile_w]` (tile column `x` is source `to + x − pad`,
/// i.e. scratch slot `to + x`).  Ascending columns are ascending
/// sources and padded columns contribute `+0.0` to a non-negative
/// accumulator, so under `SimdLanes::Scalar` the sums are bit-identical
/// to [`gather_csr`]; wider lanes reduce with the fixed tree of
/// [`super::simd::dot_tile`] (deterministic per width, tolerance-tier
/// vs scalar).  A row is pushed iff its sum is positive — monotone
/// non-negative addition makes that predicate association-independent,
/// so the active set never depends on the lane width.
#[inline]
fn gather_tile(
    coeffs: &FusedCoeffs,
    dense: &[f32],
    win_lo: usize,
    win_hi: usize,
    s_t: usize,
    lanes: SimdLanes,
    out: &mut SparseRow,
) -> f32 {
    let tw = coeffs.lowering.tile_w;
    let tiles = coeffs.tile_coef_for(s_t);
    let mut c = 0.0f32;
    for to in win_lo..win_hi {
        let row = &tiles[to * tw..(to + 1) * tw];
        let win = &dense[to..to + tw];
        let acc = simd::dot_tile(win, row, lanes);
        if acc > 0.0 {
            out.idx.push(to as u32);
            out.val.push(acc);
            c += acc;
        }
    }
    c
}

/// Per-row tile admission: the structural gate first (shared with the
/// entry points' tile-build decision — admission must stay a subset of
/// [`may_dispatch_tiles`] or `tile_coef_for` would panic on missing
/// tables), then the per-row density term: under `Adaptive` the
/// filter-admitted states must nearly fill their window
/// (filter-thinned rows fall back to the indexed gather).  Shared with
/// the striped kernels and (mirrored on the next-row support) the
/// tile-granular backward, so every dispatcher agrees on one formula.
#[inline]
pub(super) fn row_admits_tile(
    coeffs: &FusedCoeffs,
    gather: GatherKind,
    prev: &SparseRow,
    first: usize,
    last: usize,
) -> bool {
    may_dispatch_tiles(coeffs, gather)
        && (gather != GatherKind::Adaptive
            || (!prev.idx.is_empty()
                && prev.len() as f32 >= DENSE_TILE_MIN_DENSITY * (last - first + 1) as f32))
}

/// Gather one timestep: scatter `prev` into the dense buffer, dispatch
/// the window to the CSR or dense-tile kernel per `gather`, clear the
/// buffer.
///
/// Returns the unscaled row sum `c`, the number of in-window edges (the
/// algorithmic workload metric — identical for both kernels, so
/// dispatch never perturbs the accelerator model's counters), and
/// whether the tile kernel ran (for the dispatch counters).  `out`
/// receives the unscaled row.  The dense buffer is restored to all-zero
/// before returning (also on dead rows), so scratch reuse is safe even
/// on error paths.
#[inline]
fn gather_row(
    coeffs: &FusedCoeffs,
    dense: &mut [f32],
    prev: &SparseRow,
    s_t: usize,
    n: usize,
    out: &mut SparseRow,
    gather: GatherKind,
    lanes: SimdLanes,
) -> (f32, u64, bool) {
    out.idx.clear();
    out.val.clear();
    let pad = coeffs.lowering.tile_w - 1;
    for (&i, &v) in prev.idx.iter().zip(prev.val.iter()) {
        dense[i as usize + pad] = v;
    }
    // Gather-form forward (§Perf in EXPERIMENTS.md): pHMM topology
    // bounds every timestep's successors to the window
    // [first_active, last_active + band), so each window target gathers
    // its incoming contributions — independent accumulators, no scatter
    // bookkeeping.  The fused coefficient already carries the target's
    // emission, so the row value is the raw accumulator.
    let first = prev.idx.first().map(|&i| i as usize).unwrap_or(0);
    let last = prev.idx.last().map(|&i| i as usize).unwrap_or(0);
    let win_lo = first;
    let win_hi = if prev.idx.is_empty() { 0 } else { (last + coeffs.lowering.band).min(n) };
    out.idx.reserve(win_hi.saturating_sub(win_lo));
    out.val.reserve(win_hi.saturating_sub(win_lo));
    let use_tile = row_admits_tile(coeffs, gather, prev, first, last);
    let c = if use_tile {
        gather_tile(coeffs, dense, win_lo, win_hi, s_t, lanes, out)
    } else {
        gather_csr(coeffs, dense, pad, win_lo, win_hi, s_t, out)
    };
    for &i in prev.idx.iter() {
        dense[i as usize + pad] = 0.0;
    }
    // Window targets are contiguous, so the in-window edge count is one
    // incoming-CSR pointer difference.
    let edges =
        (coeffs.lowering.in_ptr[win_hi] - coeffs.lowering.in_ptr[win_lo]) as u64;
    (c, edges, use_tile)
}

/// Run the scaled, filtered forward pass of `seq` over `phmm`, reusing
/// the caller's fused tables and scratch (the training hot path).
pub fn forward_sparse_with(
    phmm: &Phmm,
    coeffs: &FusedCoeffs,
    seq: &Sequence,
    opts: &ForwardOptions,
    scratch: &mut ForwardScratch,
) -> Result<ForwardResult> {
    precheck(phmm, coeffs, seq)?;
    let n = phmm.n_states();
    let lanes = opts.simd.resolve();
    scratch.ensure(n + coeffs.gather_pad());
    scratch.ensure_hist(&opts.filter);
    if may_dispatch_tiles(coeffs, opts.gather) {
        // Some row may dispatch to the tile kernel: make sure the lazy
        // tile tables exist before the timestep loop.
        coeffs.tiles_for(phmm);
    }
    let t_len = seq.len();
    let mut stats = FilterStats::default();
    let mut rows = scratch.take_rows_vec();
    let mut scales = scratch.take_scales_vec();
    rows.reserve(t_len);
    scales.reserve(t_len);
    let mut loglik = 0.0f64;
    let mut states_processed = 0u64;
    let mut edges_processed = 0u64;

    {
        let mut row = scratch.take_row();
        let c = init_row(phmm, coeffs, seq.data[0], &mut row)?;
        let inv = 1.0 / c;
        row.val.iter_mut().for_each(|v| *v *= inv);
        apply_filter(&opts.filter, &mut scratch.hist, &mut row.idx, &mut row.val, &mut stats);
        states_processed += row.len() as u64;
        scales.push(c);
        loglik += (c as f64).ln();
        rows.push(row);
    }

    for t in 1..t_len {
        let s_t = seq.data[t] as usize;
        let mut row = scratch.take_row();
        let prev = rows.last().unwrap();
        let (c, edges, used_tile) =
            gather_row(coeffs, &mut scratch.dense, prev, s_t, n, &mut row, opts.gather, lanes);
        edges_processed += edges;
        if used_tile {
            stats.rows_dense_tile += 1;
        } else {
            stats.rows_csr += 1;
        }
        if c <= EPS {
            return Err(ApHmmError::Numerical(format!("forward died at t={t}")));
        }
        let inv = 1.0 / c;
        row.val.iter_mut().for_each(|v| *v *= inv);
        apply_filter(&opts.filter, &mut scratch.hist, &mut row.idx, &mut row.val, &mut stats);
        states_processed += row.len() as u64;
        scales.push(c);
        loglik += (c as f64).ln();
        rows.push(row);
    }

    Ok(ForwardResult { rows, scales, loglik, filter_stats: stats, states_processed, edges_processed })
}

/// Run the scaled, filtered forward pass of `seq` over `phmm`.
///
/// Convenience wrapper that builds throwaway tables and scratch; hot
/// paths should use [`forward_sparse_with`].
pub fn forward_sparse(phmm: &Phmm, seq: &Sequence, opts: &ForwardOptions) -> Result<ForwardResult> {
    let coeffs = FusedCoeffs::new(phmm);
    let mut scratch = ForwardScratch::new(phmm);
    forward_sparse_with(phmm, &coeffs, seq, opts, &mut scratch)
}

/// Checkpointed forward product ([`ScratchMode::Checkpointed`]): every
/// ⌈√T⌉-th post-filter row plus all `T` scales.  Checkpoint `s` is the
/// row at timestep `s · seg_len`, i.e. the *first* row of segment `s` —
/// which is exactly the `rows[t+1]` row the backward sweep needs when
/// it crosses the boundary from segment `s` into segment `s − 1`.
#[derive(Clone, Debug)]
pub(super) struct CheckpointedForward {
    /// Post-filter rows at `t = 0, K, 2K, …` (ascending).
    pub ckpt_rows: Vec<SparseRow>,
    /// Per-timestep scale factors `c_t` — all `T` of them (4 bytes per
    /// timestep; storing them all is what lets recompute skip the
    /// division-order question entirely: scales are never recomputed).
    pub scales: Vec<f32>,
    /// Checkpoint interval `K = ⌈√T⌉`.
    pub seg_len: usize,
    /// `log P(S | G) = Σ log c_t`.
    pub loglik: f64,
    /// Filtering + gather-dispatch instrumentation (forward pass only;
    /// segment recompute does not re-count).
    pub filter_stats: FilterStats,
    /// Total states processed (forward pass only).
    pub states_processed: u64,
    /// Total edges traversed (forward pass only).
    pub edges_processed: u64,
    /// Heap bytes held by the checkpoint rows + scales — the resident
    /// part of the checkpointed footprint (the per-segment recompute
    /// buffer is accounted at sweep time, where its size is known).
    pub ckpt_bytes: u64,
}

/// Checkpointed forward pass: identical arithmetic to
/// [`forward_sparse_with`] (same kernels, same reduction order — the
/// kept rows and every scale are bit-identical), but only every
/// `⌈√T⌉`-th post-filter row is stored.  The fused backward sweep
/// recomputes each segment from its checkpoint via
/// [`recompute_segment`] before consuming it.
pub(super) fn forward_checkpointed_with(
    phmm: &Phmm,
    coeffs: &FusedCoeffs,
    seq: &Sequence,
    opts: &ForwardOptions,
    scratch: &mut ForwardScratch,
) -> Result<CheckpointedForward> {
    precheck(phmm, coeffs, seq)?;
    let n = phmm.n_states();
    let lanes = opts.simd.resolve();
    scratch.ensure(n + coeffs.gather_pad());
    scratch.ensure_hist(&opts.filter);
    if may_dispatch_tiles(coeffs, opts.gather) {
        coeffs.tiles_for(phmm);
    }
    let t_len = seq.len();
    let seg_len = checkpoint_interval(t_len);
    let mut stats = FilterStats::default();
    let mut ckpt_rows = scratch.take_rows_vec();
    ckpt_rows.reserve(t_len / seg_len + 1);
    let mut scales = scratch.take_scales_vec();
    scales.reserve(t_len);
    let mut loglik = 0.0f64;
    let mut states_processed = 0u64;
    let mut edges_processed = 0u64;
    let mut ckpt_bytes = 0u64;

    let mut prev = scratch.take_row();
    let mut cur = scratch.take_row();

    let finish = |scratch: &mut ForwardScratch, prev: SparseRow, cur: SparseRow| {
        scratch.put_row(prev);
        scratch.put_row(cur);
    };

    let c0 = match init_row(phmm, coeffs, seq.data[0], &mut prev) {
        Ok(c) => c,
        Err(e) => {
            finish(scratch, prev, cur);
            return Err(e);
        }
    };
    let inv = 1.0 / c0;
    prev.val.iter_mut().for_each(|v| *v *= inv);
    apply_filter(&opts.filter, &mut scratch.hist, &mut prev.idx, &mut prev.val, &mut stats);
    states_processed += prev.len() as u64;
    scales.push(c0);
    loglik += (c0 as f64).ln();
    ckpt_bytes += row_bytes(&prev);
    ckpt_rows.push(prev.clone()); // t = 0 is always a checkpoint

    for t in 1..t_len {
        let s_t = seq.data[t] as usize;
        let (c, edges, used_tile) =
            gather_row(coeffs, &mut scratch.dense, &prev, s_t, n, &mut cur, opts.gather, lanes);
        edges_processed += edges;
        if used_tile {
            stats.rows_dense_tile += 1;
        } else {
            stats.rows_csr += 1;
        }
        if c <= EPS {
            finish(scratch, prev, cur);
            return Err(ApHmmError::Numerical(format!("forward died at t={t}")));
        }
        let inv = 1.0 / c;
        cur.val.iter_mut().for_each(|v| *v *= inv);
        apply_filter(&opts.filter, &mut scratch.hist, &mut cur.idx, &mut cur.val, &mut stats);
        states_processed += cur.len() as u64;
        scales.push(c);
        loglik += (c as f64).ln();
        if t % seg_len == 0 {
            ckpt_bytes += row_bytes(&cur);
            ckpt_rows.push(cur.clone());
        }
        std::mem::swap(&mut prev, &mut cur);
    }

    finish(scratch, prev, cur);
    ckpt_bytes += scales.len() as u64 * 4;
    Ok(CheckpointedForward {
        ckpt_rows,
        scales,
        seg_len,
        loglik,
        filter_stats: stats,
        states_processed,
        edges_processed,
        ckpt_bytes,
    })
}

/// Recompute the post-filter forward rows of one segment — timesteps
/// `start .. start + len` — from its stored checkpoint row (the row at
/// `start`).  Replays the exact kernel sequence of
/// [`forward_sparse_with`] (`gather_row` → scale → `apply_filter`) from
/// an exactly-stored post-filter row, so the output rows are
/// bit-identical to the full-matrix rows.  Workload/filter counters are
/// deliberately *not* re-counted (the forward pass already did), and
/// scales are taken from `ckpt.scales`, never re-derived: a
/// `debug_assert` pins that the recomputed sum matches the stored scale
/// to the bit.
///
/// `out` rows are drawn from (and should be returned to) the scratch
/// row pool by the caller.
pub(super) fn recompute_segment(
    phmm: &Phmm,
    coeffs: &FusedCoeffs,
    seq: &Sequence,
    ckpt: &CheckpointedForward,
    seg: usize,
    start: usize,
    len: usize,
    opts: &ForwardOptions,
    scratch: &mut ForwardScratch,
    out: &mut Vec<SparseRow>,
) -> Result<()> {
    let n = phmm.n_states();
    let lanes = opts.simd.resolve();
    let mut dummy_stats = FilterStats::default();
    debug_assert!(len >= 1 && start + len <= seq.len());
    {
        let mut first = scratch.take_row();
        first.idx.clear();
        first.val.clear();
        first.idx.extend_from_slice(&ckpt.ckpt_rows[seg].idx);
        first.val.extend_from_slice(&ckpt.ckpt_rows[seg].val);
        out.push(first);
    }
    for t in start + 1..start + len {
        let s_t = seq.data[t] as usize;
        let mut row = scratch.take_row();
        let prev = out.last().unwrap();
        let (c, _edges, _used_tile) =
            gather_row(coeffs, &mut scratch.dense, prev, s_t, n, &mut row, opts.gather, lanes);
        if c <= EPS {
            // Unreachable for a read whose forward pass succeeded (same
            // kernels, same inputs); kept as a real error for safety.
            scratch.put_row(row);
            return Err(ApHmmError::Numerical(format!("forward died at t={t} during recompute")));
        }
        debug_assert_eq!(
            c.to_bits(),
            ckpt.scales[t].to_bits(),
            "recomputed scale diverged at t={t} (checkpoint replay is not bit-identical)"
        );
        let inv = 1.0 / c;
        row.val.iter_mut().for_each(|v| *v *= inv);
        apply_filter(&opts.filter, &mut scratch.hist, &mut row.idx, &mut row.val, &mut dummy_stats);
        out.push(row);
    }
    Ok(())
}

/// Score-only forward fast path: identical arithmetic to
/// [`forward_sparse_with`] (bit-identical log-likelihood), but only two
/// rows are ever live — memory is `O(active states)` regardless of
/// sequence length.
pub fn score_sparse_with(
    phmm: &Phmm,
    coeffs: &FusedCoeffs,
    seq: &Sequence,
    opts: &ForwardOptions,
    scratch: &mut ForwardScratch,
) -> Result<ScoreResult> {
    precheck(phmm, coeffs, seq)?;
    let n = phmm.n_states();
    let lanes = opts.simd.resolve();
    scratch.ensure(n + coeffs.gather_pad());
    scratch.ensure_hist(&opts.filter);
    if may_dispatch_tiles(coeffs, opts.gather) {
        coeffs.tiles_for(phmm);
    }
    let t_len = seq.len();
    let mut stats = FilterStats::default();
    let mut prev = scratch.take_row();
    let mut cur = scratch.take_row();
    let mut loglik = 0.0f64;
    let mut states_processed = 0u64;
    let mut edges_processed = 0u64;

    let finish = |scratch: &mut ForwardScratch, prev: SparseRow, cur: SparseRow| {
        scratch.put_row(prev);
        scratch.put_row(cur);
    };

    let c0 = match init_row(phmm, coeffs, seq.data[0], &mut prev) {
        Ok(c) => c,
        Err(e) => {
            finish(scratch, prev, cur);
            return Err(e);
        }
    };
    let inv = 1.0 / c0;
    prev.val.iter_mut().for_each(|v| *v *= inv);
    apply_filter(&opts.filter, &mut scratch.hist, &mut prev.idx, &mut prev.val, &mut stats);
    states_processed += prev.len() as u64;
    loglik += (c0 as f64).ln();

    for t in 1..t_len {
        let s_t = seq.data[t] as usize;
        let (c, edges, used_tile) =
            gather_row(coeffs, &mut scratch.dense, &prev, s_t, n, &mut cur, opts.gather, lanes);
        edges_processed += edges;
        if used_tile {
            stats.rows_dense_tile += 1;
        } else {
            stats.rows_csr += 1;
        }
        if c <= EPS {
            finish(scratch, prev, cur);
            return Err(ApHmmError::Numerical(format!("forward died at t={t}")));
        }
        let inv = 1.0 / c;
        cur.val.iter_mut().for_each(|v| *v *= inv);
        apply_filter(&opts.filter, &mut scratch.hist, &mut cur.idx, &mut cur.val, &mut stats);
        states_processed += cur.len() as u64;
        loglik += (c as f64).ln();
        std::mem::swap(&mut prev, &mut cur);
    }

    finish(scratch, prev, cur);
    Ok(ScoreResult { loglik, filter_stats: stats, states_processed, edges_processed })
}

pub(super) fn apply_filter(
    cfg: &FilterConfig,
    hist: &mut Option<HistogramFilter>,
    idx: &mut Vec<u32>,
    val: &mut Vec<f32>,
    stats: &mut FilterStats,
) {
    match cfg {
        FilterConfig::None => {}
        FilterConfig::Sort { size } => SortFilter::select(idx, val, *size, stats),
        FilterConfig::Histogram { size, .. } => {
            hist.as_mut().unwrap().select(idx, val, *size, stats)
        }
    }
}

/// Forward-only similarity score `log P(S | G)` (the inference path of
/// protein family search / MSA).
///
/// Convenience wrapper over [`score_sparse_with`]; uses the two-row
/// fast path, so memory stays independent of sequence length.
pub fn score_sparse(phmm: &Phmm, seq: &Sequence, opts: &ForwardOptions) -> Result<f64> {
    let coeffs = FusedCoeffs::new(phmm);
    let mut scratch = ForwardScratch::new(phmm);
    Ok(score_sparse_with(phmm, &coeffs, seq, opts, &mut scratch)?.loglik)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::baumwelch::logspace::log_likelihood;
    use crate::phmm::EcDesignParams;
    use crate::sim::XorShift;
    use crate::testutil;

    fn ec_graph(rng: &mut XorShift, len: usize) -> Phmm {
        let data = testutil::random_seq(rng, len, 4);
        let seq = Sequence::from_symbols("ref", data);
        Phmm::error_correction(&seq, &EcDesignParams::default()).unwrap()
    }

    /// A chain graph whose band is structurally near-dense — the regime
    /// where the adaptive policy's occupancy gate admits the tile
    /// kernel (shared with the hotpath bench via `testutil`).
    fn dense_band_graph() -> Phmm {
        testutil::dense_band_phmm(24)
    }

    #[test]
    fn forward_rows_are_normalized() {
        testutil::check(20, |rng| {
            let __h0 = rng.range(5, 60);
            let g = ec_graph(rng, __h0);
            let __h0 = rng.range(2, 30);
            let obs = Sequence::from_symbols("o", testutil::random_seq(rng, __h0, 4));
            let r = forward_sparse(&g, &obs, &ForwardOptions::default()).unwrap();
            for row in &r.rows {
                let s: f32 = row.val.iter().sum();
                assert!((s - 1.0).abs() < 1e-4, "row sum {s}");
            }
            assert_eq!(r.rows.len(), obs.len());
            assert_eq!(r.scales.len(), obs.len());
        });
    }

    #[test]
    fn loglik_matches_logspace_oracle() {
        testutil::check(20, |rng| {
            let __h0 = rng.range(5, 40);
            let g = ec_graph(rng, __h0);
            let __h0 = rng.range(2, 20);
            let obs = Sequence::from_symbols("o", testutil::random_seq(rng, __h0, 4));
            let got = score_sparse(&g, &obs, &ForwardOptions::default()).unwrap();
            let want = log_likelihood(&g, &obs);
            testutil::assert_close(got, want, 1e-4, 1e-5);
        });
    }

    #[test]
    fn tile_and_csr_rows_are_bit_identical() {
        // Under the scalar lane policy the dense-tile kernel sums each
        // target's contributions in the same (ascending source) order
        // as the CSR gather with only +0.0 padding interleaved, so
        // rows, scales and log-likelihood must agree to the bit —
        // filters on and off.  (Wider lanes trade this for the
        // tolerance tier; see `lane_widths_agree_within_reassoc_tier`.)
        testutil::check(15, |rng| {
            let ref_len = rng.range(5, 50);
            let g = ec_graph(rng, ref_len);
            let obs_len = rng.range(2, 40);
            let obs = Sequence::from_symbols("o", testutil::random_seq(rng, obs_len, 4));
            for filter in [
                FilterConfig::None,
                FilterConfig::Sort { size: 30 },
                FilterConfig::Histogram { size: 30, bins: 64 },
            ] {
                let csr = forward_sparse(
                    &g,
                    &obs,
                    &ForwardOptions {
                        filter,
                        gather: GatherKind::Csr,
                        simd: SimdPolicy::Scalar,
                        ..Default::default()
                    },
                )
                .unwrap();
                let tile = forward_sparse(
                    &g,
                    &obs,
                    &ForwardOptions {
                        filter,
                        gather: GatherKind::DenseTile,
                        simd: SimdPolicy::Scalar,
                        ..Default::default()
                    },
                )
                .unwrap();
                let adaptive = forward_sparse(
                    &g,
                    &obs,
                    &ForwardOptions {
                        filter,
                        gather: GatherKind::Adaptive,
                        simd: SimdPolicy::Scalar,
                        ..Default::default()
                    },
                )
                .unwrap();
                assert_eq!(csr.loglik.to_bits(), tile.loglik.to_bits(), "filter {filter:?}");
                assert_eq!(csr.loglik.to_bits(), adaptive.loglik.to_bits(), "filter {filter:?}");
                assert_eq!(csr.states_processed, tile.states_processed);
                assert_eq!(csr.edges_processed, tile.edges_processed);
                assert_eq!(csr.edges_processed, adaptive.edges_processed);
                for (t, (a, b)) in csr.rows.iter().zip(tile.rows.iter()).enumerate() {
                    assert_eq!(a.idx, b.idx, "active set diverged at t={t}");
                    for (x, y) in a.val.iter().zip(b.val.iter()) {
                        assert_eq!(x.to_bits(), y.to_bits(), "row value diverged at t={t}");
                    }
                }
                for (a, b) in csr.scales.iter().zip(tile.scales.iter()) {
                    assert_eq!(a.to_bits(), b.to_bits());
                }
            }
        });
    }

    #[test]
    fn gather_dispatch_is_instrumented() {
        let mut rng = XorShift::new(21);
        let g = ec_graph(&mut rng, 80);
        let obs = Sequence::from_symbols("o", testutil::random_seq(&mut rng, 40, 4));
        let t_rows = obs.len() as u64 - 1; // t = 0 is the init row, not a gather

        let csr = forward_sparse(
            &g,
            &obs,
            &ForwardOptions { gather: GatherKind::Csr, ..Default::default() },
        )
        .unwrap();
        assert_eq!(csr.filter_stats.rows_csr, t_rows);
        assert_eq!(csr.filter_stats.rows_dense_tile, 0);

        let tile = forward_sparse(
            &g,
            &obs,
            &ForwardOptions { gather: GatherKind::DenseTile, ..Default::default() },
        )
        .unwrap();
        assert_eq!(tile.filter_stats.rows_dense_tile, t_rows);
        assert_eq!(tile.filter_stats.rows_csr, 0);

        // The default EC design is occupancy-gated (in-degree ≈ 7 in a
        // 25-wide band): adaptive dispatch must stay on the CSR gather.
        let coeffs = FusedCoeffs::new(&g);
        assert!(!coeffs.lowering().tile_eligible(), "EC band unexpectedly near-dense");
        let adaptive = forward_sparse(&g, &obs, &ForwardOptions::default()).unwrap();
        assert_eq!(adaptive.filter_stats.rows_csr, t_rows);
        assert_eq!(adaptive.filter_stats.rows_dense_tile, 0);
    }

    #[test]
    fn adaptive_dispatch_tiles_near_dense_bands() {
        // On a structurally near-dense band the occupancy gate opens
        // and unfiltered (density ≈ 1) rows take the tile kernel —
        // bit-identically to the CSR gather.
        let mut rng = XorShift::new(37);
        let g = dense_band_graph();
        let coeffs = FusedCoeffs::new(&g);
        assert!(coeffs.lowering().tile_eligible());
        assert!(coeffs.lowering().tile_occupancy() >= 0.5);
        let obs = Sequence::from_symbols("o", testutil::random_seq(&mut rng, 6, 4));
        let t_rows = obs.len() as u64 - 1;

        // Scalar lanes: the tile-vs-CSR comparison below is bitwise.
        let opts_scalar = ForwardOptions { simd: SimdPolicy::Scalar, ..Default::default() };
        let adaptive = forward_sparse(&g, &obs, &opts_scalar).unwrap();
        assert_eq!(
            adaptive.filter_stats.rows_dense_tile, t_rows,
            "unfiltered near-dense rows must take the tile kernel"
        );
        assert_eq!(adaptive.filter_stats.rows_csr, 0);

        let csr = forward_sparse(
            &g,
            &obs,
            &ForwardOptions {
                gather: GatherKind::Csr,
                simd: SimdPolicy::Scalar,
                ..Default::default()
            },
        )
        .unwrap();
        assert_eq!(adaptive.loglik.to_bits(), csr.loglik.to_bits());
        for (a, b) in adaptive.rows.iter().zip(csr.rows.iter()) {
            assert_eq!(a.idx, b.idx);
            for (x, y) in a.val.iter().zip(b.val.iter()) {
                assert_eq!(x.to_bits(), y.to_bits());
            }
        }
    }

    #[test]
    fn lane_widths_agree_within_reassoc_tier() {
        // The lane-width parity half of the matrix: explicit f32x4 and
        // f32x8 tile forwards against the scalar baseline.  The active
        // sets and scale structure must match exactly (positivity is
        // association-independent for non-negative sums) and every
        // value stays inside the pinned reassociation tier.  Forced
        // lane widths are portable, so this runs on any host — under an
        // `APHMM_SIMD=scalar` override all three collapse to scalar and
        // the assertions hold degenerately.
        let mut rng = XorShift::new(53);
        let g = dense_band_graph();
        let obs = Sequence::from_symbols("o", testutil::random_seq(&mut rng, 12, 4));
        let scalar = forward_sparse(
            &g,
            &obs,
            &ForwardOptions {
                gather: GatherKind::DenseTile,
                simd: SimdPolicy::Scalar,
                ..Default::default()
            },
        )
        .unwrap();
        for simd in [SimdPolicy::F32x4, SimdPolicy::F32x8] {
            let wide = forward_sparse(
                &g,
                &obs,
                &ForwardOptions { gather: GatherKind::DenseTile, simd, ..Default::default() },
            )
            .unwrap();
            testutil::assert_close(
                wide.loglik,
                scalar.loglik,
                simd::SIMD_REASSOC_RTOL,
                simd::SIMD_REASSOC_ATOL,
            );
            assert_eq!(wide.states_processed, scalar.states_processed, "{simd:?}");
            assert_eq!(wide.edges_processed, scalar.edges_processed, "{simd:?}");
            assert_eq!(
                wide.filter_stats.rows_dense_tile, scalar.filter_stats.rows_dense_tile,
                "{simd:?}"
            );
            for (t, (a, b)) in wide.rows.iter().zip(scalar.rows.iter()).enumerate() {
                assert_eq!(a.idx, b.idx, "active set diverged at t={t} under {simd:?}");
                for (x, y) in a.val.iter().zip(b.val.iter()) {
                    testutil::assert_close(
                        *x as f64,
                        *y as f64,
                        simd::SIMD_REASSOC_RTOL,
                        simd::SIMD_REASSOC_ATOL,
                    );
                }
            }
            // Same-width determinism: a second run is bit-identical.
            let again = forward_sparse(
                &g,
                &obs,
                &ForwardOptions { gather: GatherKind::DenseTile, simd, ..Default::default() },
            )
            .unwrap();
            assert_eq!(wide.loglik.to_bits(), again.loglik.to_bits(), "{simd:?}");
        }
    }

    #[test]
    fn tiles_are_only_built_when_dispatch_can_reach_them() {
        // Forced-CSR workloads and occupancy-gated Adaptive workloads
        // (the default EC configuration) must never pay the Σ·N·tile_w
        // tile footprint; the first forward that may actually dispatch
        // to the tile kernel builds the tables once per freeze.
        let mut rng = XorShift::new(23);
        let g = ec_graph(&mut rng, 40);
        let obs = Sequence::from_symbols("o", testutil::random_seq(&mut rng, 20, 4));
        let coeffs = FusedCoeffs::new(&g);
        let mut scratch = ForwardScratch::new(&g);
        let opts = ForwardOptions { gather: GatherKind::Csr, ..Default::default() };
        let fwd = forward_sparse_with(&g, &coeffs, &obs, &opts, &mut scratch).unwrap();
        scratch.recycle(fwd);
        assert!(coeffs.tiles.get().is_none(), "forced-CSR forward built tiles");
        // Adaptive on the (ineligible) EC band: still no tiles.
        let fwd = forward_sparse_with(&g, &coeffs, &obs, &ForwardOptions::default(), &mut scratch)
            .unwrap();
        scratch.recycle(fwd);
        assert!(coeffs.tiles.get().is_none(), "gated adaptive forward built tiles");
        // Forcing the tile kernel builds them.
        let opts = ForwardOptions { gather: GatherKind::DenseTile, ..Default::default() };
        let fwd = forward_sparse_with(&g, &coeffs, &obs, &opts, &mut scratch).unwrap();
        scratch.recycle(fwd);
        assert!(coeffs.tiles.get().is_some(), "forced-tile forward must build tiles");

        // Adaptive on an eligible band builds them too.
        let g2 = dense_band_graph();
        let coeffs2 = FusedCoeffs::new(&g2);
        let obs2 = Sequence::from_symbols("o", testutil::random_seq(&mut rng, 6, 4));
        let fwd =
            forward_sparse_with(&g2, &coeffs2, &obs2, &ForwardOptions::default(), &mut scratch)
                .unwrap();
        scratch.recycle(fwd);
        assert!(coeffs2.tiles.get().is_some(), "eligible adaptive forward must build tiles");
    }

    #[test]
    fn score_fast_path_matches_full_forward_bitwise() {
        // Same arithmetic, different row lifetime: the two kernels must
        // agree to the last bit, filters and gather kernels on and off.
        testutil::check(15, |rng| {
            let ref_len = rng.range(5, 50);
            let g = ec_graph(rng, ref_len);
            let obs_len = rng.range(2, 40);
            let obs = Sequence::from_symbols("o", testutil::random_seq(rng, obs_len, 4));
            for opts in [
                ForwardOptions::default(),
                ForwardOptions { filter: FilterConfig::Sort { size: 30 }, ..Default::default() },
                ForwardOptions {
                    filter: FilterConfig::Histogram { size: 30, bins: 64 },
                    ..Default::default()
                },
                ForwardOptions { gather: GatherKind::Csr, ..Default::default() },
                ForwardOptions { gather: GatherKind::DenseTile, ..Default::default() },
            ] {
                let full = forward_sparse(&g, &obs, &opts).unwrap();
                let fast = score_sparse(&g, &obs, &opts).unwrap();
                assert_eq!(full.loglik.to_bits(), fast.to_bits(), "opts {opts:?}");
            }
        });
    }

    #[test]
    fn scratch_reuse_is_transparent() {
        // One coeffs/scratch pair across many reads gives the same
        // results as throwaway buffers, and stops allocating rows once
        // the pool is warm.
        let mut rng = XorShift::new(71);
        let g = ec_graph(&mut rng, 60);
        let coeffs = FusedCoeffs::new(&g);
        let mut scratch = ForwardScratch::new(&g);
        let opts = ForwardOptions::default();
        let mut allocated_after_first = 0;
        for i in 0..5 {
            let obs = Sequence::from_symbols("o", testutil::random_seq(&mut rng, 25, 4));
            let fresh = forward_sparse(&g, &obs, &opts).unwrap();
            let reused = forward_sparse_with(&g, &coeffs, &obs, &opts, &mut scratch).unwrap();
            assert_eq!(fresh.loglik.to_bits(), reused.loglik.to_bits());
            assert_eq!(fresh.states_processed, reused.states_processed);
            assert_eq!(fresh.edges_processed, reused.edges_processed);
            scratch.recycle(reused);
            if i == 0 {
                allocated_after_first = scratch.fresh_rows_allocated();
            }
        }
        assert_eq!(
            scratch.fresh_rows_allocated(),
            allocated_after_first,
            "row pool must absorb equal-length reads without new allocations"
        );
    }

    #[test]
    fn identical_sequence_scores_higher_than_random() {
        let mut rng = XorShift::new(77);
        let data = testutil::random_seq(&mut rng, 50, 4);
        let refseq = Sequence::from_symbols("ref", data.clone());
        let g = Phmm::error_correction(&refseq, &EcDesignParams::default()).unwrap();
        let same = score_sparse(&g, &refseq, &ForwardOptions::default()).unwrap();
        let other =
            Sequence::from_symbols("rnd", testutil::random_seq(&mut rng, 50, 4));
        let diff = score_sparse(&g, &other, &ForwardOptions::default()).unwrap();
        assert!(same > diff + 5.0, "same={same} diff={diff}");
    }

    #[test]
    fn filter_bounds_active_states() {
        let mut rng = XorShift::new(3);
        let g = ec_graph(&mut rng, 300);
        let obs = Sequence::from_symbols("o", testutil::random_seq(&mut rng, 100, 4));
        let opts = ForwardOptions { filter: FilterConfig::Sort { size: 50 }, ..Default::default() };
        let r = forward_sparse(&g, &obs, &opts).unwrap();
        for row in &r.rows {
            assert!(row.len() <= 50);
        }
        assert!(r.filter_stats.calls > 0);
    }

    #[test]
    fn histogram_filter_close_to_unfiltered_loglik() {
        let mut rng = XorShift::new(5);
        let data = testutil::random_seq(&mut rng, 200, 4);
        let refseq = Sequence::from_symbols("ref", data);
        let g = Phmm::error_correction(&refseq, &EcDesignParams::default()).unwrap();
        // Observation close to the reference so mass is concentrated.
        let exact = score_sparse(&g, &refseq, &ForwardOptions::default()).unwrap();
        let opts = ForwardOptions {
            filter: FilterConfig::Histogram { size: 500, bins: 16 },
            ..Default::default()
        };
        let filt = score_sparse(&g, &refseq, &opts).unwrap();
        assert!((exact - filt).abs() / exact.abs() < 0.02, "{exact} vs {filt}");
    }

    #[test]
    fn empty_sequence_rejected() {
        let mut rng = XorShift::new(9);
        let g = ec_graph(&mut rng, 10);
        let obs = Sequence::from_symbols("o", vec![]);
        assert!(forward_sparse(&g, &obs, &ForwardOptions::default()).is_err());
    }

    #[test]
    fn out_of_alphabet_symbol_rejected() {
        let mut rng = XorShift::new(13);
        let g = ec_graph(&mut rng, 10);
        let obs = Sequence::from_symbols("o", vec![0, 1, 200]);
        assert!(forward_sparse(&g, &obs, &ForwardOptions::default()).is_err());
        assert!(score_sparse(&g, &obs, &ForwardOptions::default()).is_err());
    }

    #[test]
    fn checkpointed_forward_replays_bit_identically() {
        // The checkpointed forward must store bit-identical copies of
        // the full forward's rows at t = 0, K, 2K, … (plus all scales
        // and the loglik), and `recompute_segment` must reproduce every
        // in-between row to the bit — the foundation of the
        // ScratchMode::Checkpointed bit-identity contract.
        testutil::check(10, |rng| {
            let ref_len = rng.range(5, 50);
            let g = ec_graph(rng, ref_len);
            let obs_len = rng.range(2, 60);
            let obs = Sequence::from_symbols("o", testutil::random_seq(rng, obs_len, 4));
            for filter in [FilterConfig::None, FilterConfig::Histogram { size: 40, bins: 64 }] {
                let opts = ForwardOptions { filter, ..Default::default() };
                let coeffs = FusedCoeffs::new(&g);
                let mut scratch = ForwardScratch::new(&g);
                let full = forward_sparse_with(&g, &coeffs, &obs, &opts, &mut scratch).unwrap();
                let ckpt =
                    forward_checkpointed_with(&g, &coeffs, &obs, &opts, &mut scratch).unwrap();

                assert_eq!(full.loglik.to_bits(), ckpt.loglik.to_bits());
                assert_eq!(full.states_processed, ckpt.states_processed);
                assert_eq!(full.edges_processed, ckpt.edges_processed);
                assert_eq!(full.scales.len(), ckpt.scales.len());
                for (a, b) in full.scales.iter().zip(ckpt.scales.iter()) {
                    assert_eq!(a.to_bits(), b.to_bits());
                }
                let k = ckpt.seg_len;
                assert_eq!(k, checkpoint_interval(obs.len()));
                assert_eq!(ckpt.ckpt_rows.len(), (obs.len() - 1) / k + 1);
                for (s, row) in ckpt.ckpt_rows.iter().enumerate() {
                    let t = s * k;
                    assert_eq!(row.idx, full.rows[t].idx, "checkpoint {s} active set");
                    for (x, y) in row.val.iter().zip(full.rows[t].val.iter()) {
                        assert_eq!(x.to_bits(), y.to_bits(), "checkpoint {s} value");
                    }
                }
                // Replay every segment and compare against the full rows.
                let n_segs = ckpt.ckpt_rows.len();
                for s in 0..n_segs {
                    let start = s * k;
                    let len = k.min(obs.len() - start);
                    let mut seg_rows = Vec::new();
                    recompute_segment(
                        &g, &coeffs, &obs, &ckpt, s, start, len, &opts, &mut scratch,
                        &mut seg_rows,
                    )
                    .unwrap();
                    assert_eq!(seg_rows.len(), len);
                    for (off, row) in seg_rows.iter().enumerate() {
                        let t = start + off;
                        assert_eq!(row.idx, full.rows[t].idx, "recomputed active set at t={t}");
                        for (x, y) in row.val.iter().zip(full.rows[t].val.iter()) {
                            assert_eq!(x.to_bits(), y.to_bits(), "recomputed value at t={t}");
                        }
                    }
                    for row in seg_rows {
                        scratch.put_row(row);
                    }
                }
                scratch.recycle(full);
            }
        });
    }

    #[test]
    fn scratch_mode_auto_resolution() {
        // Budget 0 = unlimited: Auto degenerates to Full.
        assert_eq!(ScratchMode::Auto.resolve(100_000, 1000, 0), ScratchMode::Full);
        // Over budget: checkpoint.
        let est = full_scratch_estimate(100_000, 1000);
        assert_eq!(
            ScratchMode::Auto.resolve(100_000, 1000, est as usize - 1),
            ScratchMode::Checkpointed
        );
        // Under budget: full.
        assert_eq!(ScratchMode::Auto.resolve(100_000, 1000, est as usize), ScratchMode::Full);
        // Explicit modes resolve to themselves regardless of budget.
        assert_eq!(ScratchMode::Full.resolve(100_000, 1000, 1), ScratchMode::Full);
        assert_eq!(
            ScratchMode::Checkpointed.resolve(2, 2, usize::MAX),
            ScratchMode::Checkpointed
        );
        for name in ScratchMode::NAMES {
            assert_eq!(ScratchMode::parse(name).unwrap().name(), *name);
        }
        assert!(ScratchMode::parse("bogus").is_none());
    }

    #[test]
    fn workload_counters_grow_with_sequence() {
        let mut rng = XorShift::new(11);
        let g = ec_graph(&mut rng, 100);
        let short = Sequence::from_symbols("s", testutil::random_seq(&mut rng, 10, 4));
        let long = Sequence::from_symbols("l", testutil::random_seq(&mut rng, 60, 4));
        let r_s = forward_sparse(&g, &short, &ForwardOptions::default()).unwrap();
        let r_l = forward_sparse(&g, &long, &ForwardOptions::default()).unwrap();
        assert!(r_l.states_processed > r_s.states_processed);
        assert!(r_l.edges_processed > r_s.edges_processed);
    }
}
