//! Log-space forward/backward — the correctness oracle.
//!
//! Dense, f64, no scaling tricks: numerically robust by construction and
//! structurally independent of the scaled engines it validates.

use crate::phmm::Phmm;
use crate::seq::Sequence;

#[inline]
fn logadd(a: f64, b: f64) -> f64 {
    if a == f64::NEG_INFINITY {
        return b;
    }
    if b == f64::NEG_INFINITY {
        return a;
    }
    let m = a.max(b);
    m + ((a - m).exp() + (b - m).exp()).ln()
}

#[inline]
fn ln(p: f32) -> f64 {
    if p <= 0.0 {
        f64::NEG_INFINITY
    } else {
        (p as f64).ln()
    }
}

/// Full log-forward matrix `[T × N]` (Eq. 1 in log space).
pub fn log_forward(phmm: &Phmm, seq: &Sequence) -> Vec<f64> {
    let n = phmm.n_states();
    let t_len = seq.len();
    let mut lf = vec![f64::NEG_INFINITY; t_len * n];
    for i in 0..n {
        lf[i] = ln(phmm.f_init[i]) + ln(phmm.emission(i, seq.data[0]));
    }
    for t in 1..t_len {
        let (prev, cur) = lf.split_at_mut(t * n);
        let prev = &prev[(t - 1) * n..];
        let cur = &mut cur[..n];
        for j in 0..n {
            if prev[j] == f64::NEG_INFINITY {
                continue;
            }
            for e in phmm.out_ptr[j] as usize..phmm.out_ptr[j + 1] as usize {
                let to = phmm.out_to[e] as usize;
                cur[to] = logadd(cur[to], prev[j] + ln(phmm.out_prob[e]));
            }
        }
        for i in 0..n {
            if cur[i] != f64::NEG_INFINITY {
                cur[i] += ln(phmm.emission(i, seq.data[t]));
            }
        }
    }
    lf
}

/// Full log-backward matrix `[T × N]` (Eq. 2 in log space).
pub fn log_backward(phmm: &Phmm, seq: &Sequence) -> Vec<f64> {
    let n = phmm.n_states();
    let t_len = seq.len();
    let mut lb = vec![f64::NEG_INFINITY; t_len * n];
    for i in 0..n {
        lb[(t_len - 1) * n + i] = 0.0;
    }
    for t in (0..t_len - 1).rev() {
        for j in 0..n {
            let mut acc = f64::NEG_INFINITY;
            for e in phmm.out_ptr[j] as usize..phmm.out_ptr[j + 1] as usize {
                let to = phmm.out_to[e] as usize;
                acc = logadd(
                    acc,
                    ln(phmm.out_prob[e])
                        + ln(phmm.emission(to, seq.data[t + 1]))
                        + lb[(t + 1) * n + to],
                );
            }
            lb[t * n + j] = acc;
        }
    }
    lb
}

/// `log P(S | G)` from the log-forward matrix.
pub fn log_likelihood(phmm: &Phmm, seq: &Sequence) -> f64 {
    let n = phmm.n_states();
    let lf = log_forward(phmm, seq);
    let last = &lf[(seq.len() - 1) * n..];
    last.iter().copied().fold(f64::NEG_INFINITY, logadd)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::phmm::EcDesignParams;
    use crate::testutil;

    #[test]
    fn forward_backward_consistency() {
        // Σ_i F_t(i) B_t(i) = P(S) for every t — the classic identity.
        testutil::check(15, |rng| {
            let __h0 = rng.range(4, 20);
            let data = testutil::random_seq(rng, __h0, 4);
            let g = Phmm::error_correction(
                &crate::seq::Sequence::from_symbols("r", data),
                &EcDesignParams::default(),
            )
            .unwrap();
            let obs_len = rng.range(2, 12);
            let obs = crate::seq::Sequence::from_symbols(
                "o",
                testutil::random_seq(rng, obs_len, 4),
            );
            let lf = log_forward(&g, &obs);
            let lb = log_backward(&g, &obs);
            let n = g.n_states();
            let lp = log_likelihood(&g, &obs);
            for t in 0..obs.len() {
                let mut acc = f64::NEG_INFINITY;
                for i in 0..n {
                    let v = lf[t * n + i] + lb[t * n + i];
                    if v != f64::NEG_INFINITY {
                        acc = super::logadd(acc, v);
                    }
                }
                testutil::assert_close(acc, lp, 1e-9, 1e-12);
            }
        });
    }

    #[test]
    fn single_path_likelihood_is_product() {
        // A 2-state chain with deterministic transitions: P(S) is the
        // product of f_init, transition and emissions along the path.
        use crate::phmm::{Phmm, PhmmDesign, StateKind};
        use crate::seq::DNA;
        let g = Phmm {
            design: PhmmDesign::ErrorCorrection,
            alphabet: DNA,
            kinds: vec![StateKind::Match; 2],
            position: vec![0, 1],
            out_ptr: vec![0, 1, 1],
            out_to: vec![1],
            out_prob: vec![1.0],
            emissions: vec![0.7, 0.1, 0.1, 0.1, 0.1, 0.7, 0.1, 0.1],
            f_init: vec![1.0, 0.0],
        };
        g.validate().unwrap();
        let obs = crate::seq::Sequence::from_symbols("o", vec![0, 1]);
        let lp = log_likelihood(&g, &obs);
        testutil::assert_close(lp, (0.7f64 * 0.7).ln(), 1e-6, 1e-9);
    }
}
