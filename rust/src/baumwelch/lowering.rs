//! The transition-structure lowering layer (paper §4.2, Observation 5).
//!
//! ApHMM's accelerator wins come from exploiting the *predictable data
//! dependency patterns* of pHMM transitions: every engine in this crate
//! runs on some re-encoding ("lowering") of the same [`Phmm`] transition
//! structure, and all of those encodings are frozen together with the
//! parameters once per EM iteration (or once per profile for
//! inference).  Before this layer existed the lowerings were scattered —
//! the incoming CSR lived inside `kernels::FusedCoeffs`, the banded
//! tables were rebuilt by both `BandedEngine::prepare` and
//! `SparsePrepared`'s private posterior-decode cache — so [`Lowering`]
//! now owns every one of them:
//!
//! * **Incoming CSR** (`in_ptr`/`in_from`/`in_eidx`) — the gather-form
//!   forward's window walk, consumed by the fused per-symbol CSR tables
//!   of [`super::FusedCoeffs`].
//! * **Banded window tables** ([`BandedLowering`] = [`BandedPhmm`] +
//!   [`super::BandedCoeffs`]) — the dense banded engine's encoding and
//!   the posterior-decode path of the sparse engine.  Built lazily via
//!   [`Lowering::banded_for`] (profiles that are never
//!   posterior-decoded pay nothing, profiles decoded `M` times pay
//!   once) or eagerly via [`BandedLowering::lower`] (the banded
//!   engine's `prepare`).
//! * **Per-window dense tiles** ([`super::DenseTiles`]) — a new layout
//!   of the same incoming structure: each target state's in-window
//!   sources are packed into an `f32` tile row of fixed width
//!   [`Lowering::tile_width`] with *window-relative* column indices
//!   (column `x` is source `to + x − (tile_w − 1)`), zero-padded where
//!   no edge exists.  The in-window gather over a tile row is a
//!   branchless dense dot product the auto-vectorizer can chew on —
//!   within a band the transition structure is near-dense (Fig. 4), so
//!   a dense compute block beats the indexed CSR gather exactly when
//!   the filter admits a dense window.
//!
//! [`GatherKind`] selects between the CSR gather and the dense-tile
//! kernel per forward row; the default [`GatherKind::Adaptive`] policy
//! picks the tile kernel when the graph passes the structural
//! [`TILE_MIN_OCCUPANCY`] gate *and* the filter-admitted window density
//! reaches [`DENSE_TILE_MIN_DENSITY`], falling back to the CSR gather
//! otherwise.  Under the scalar lane policy both kernels accumulate
//! each target's contributions in ascending-source order with only
//! non-negative terms, so their rows — and therefore the
//! log-likelihoods and every downstream expectation sum — are
//! **bit-identical** (asserted by `tests/engine_matrix.rs`).  Wider
//! `SimdPolicy` lane widths reduce the tile dot product with the fixed
//! lane tree of [`super::simd`] instead: deterministic per width, but
//! reassociated relative to the CSR gather's scalar sum, so cross-kernel
//! and cross-width comparisons then live in the pinned
//! `SIMD_REASSOC_RTOL` tolerance tier.
//!
//! Freezing is strictly parameter-side: a [`Lowering`] never bakes in a
//! [`super::FilterConfig`] or any other runtime decision, which is what
//! lets the serving layer's `PreparedCache` key entries by profile
//! content hash alone (see `server::cache`).

use std::sync::OnceLock;

use super::banded::BandedCoeffs;
use crate::error::Result;
use crate::phmm::{BandedPhmm, Phmm};

/// Dense-tile rows are padded to a multiple of this lane count so the
/// inner loop has a fixed, branch-free trip count.
pub const TILE_LANES: usize = 4;

/// [`GatherKind::Adaptive`] uses the dense-tile kernel for a forward
/// row when `active states / window span` of the (possibly filtered)
/// previous row is at least this threshold — i.e. the admitted window
/// is near-dense.
pub const DENSE_TILE_MIN_DENSITY: f32 = 0.75;

/// Structural gate of the adaptive policy: the tile kernel performs
/// `tile_w` multiply-adds per window target where the CSR gather
/// performs `in-degree`, so adaptive dispatch only considers tiles when
/// the graph's band is structurally dense enough that the padding
/// overhead is bounded: `n_edges / (n_states · tile_w) ≥
/// TILE_MIN_OCCUPANCY`.  The gate was 0.5 when the tile reduction was a
/// serial scalar chain (padded terms were real serial work — the
/// bitwise contract forbade reassociating them).  With the explicit
/// lane-parallel reduction of [`super::simd`], padded terms ride in
/// otherwise-idle vector lanes: the tile row costs ~`tile_w / W` lane
/// steps regardless of padding, which moves the break-even density down.
/// We lower the gate conservatively to 0.45 rather than proportionally
/// to `1/W` because the scalar fallback (and `APHMM_SIMD=scalar` CI
/// runs) still pays per-term cost, and the gate is frozen
/// per-structure, not per-policy.  Low-occupancy bands (the default EC
/// design: in-degree ≈ 7 in a 25-wide band, occupancy ≈ 0.25) still
/// always take the CSR gather under `Adaptive`; narrow near-dense bands
/// (folded traditional profiles) are where the tile kernel wins.
/// `GatherKind::DenseTile` bypasses the gate.  Re-tune from the
/// `simd lanes` / `window gather` rows of `BENCH_hotpath.json` when
/// measured numbers land (ROADMAP perf log).
pub const TILE_MIN_OCCUPANCY: f64 = 0.45;

/// Which in-window gather kernel executes a forward row.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum GatherKind {
    /// Per-row density-adaptive dispatch: the dense-tile kernel when
    /// the graph passes the structural [`TILE_MIN_OCCUPANCY`] gate and
    /// the filter-admitted window density is at least
    /// [`DENSE_TILE_MIN_DENSITY`]; the CSR gather otherwise.
    #[default]
    Adaptive,
    /// Always the indexed CSR gather (the pre-tile hot path).
    Csr,
    /// Always the dense-tile kernel.
    DenseTile,
}

impl GatherKind {
    /// Canonical lowercase name (logs, bench rows).
    pub fn name(self) -> &'static str {
        match self {
            GatherKind::Adaptive => "adaptive",
            GatherKind::Csr => "csr",
            GatherKind::DenseTile => "dense-tile",
        }
    }
}

/// The banded lowering product: the dense banded parameter snapshot
/// plus its per-symbol fused `a·e` tables.  This is the banded engine's
/// frozen state (`BandedPrepared` is an alias) and the sparse engine's
/// posterior-decode encoding.
pub struct BandedLowering {
    /// The banded parameter snapshot.
    pub banded: BandedPhmm,
    /// Fused `a·e` tables built from it.
    pub coeffs: BandedCoeffs,
}

impl BandedLowering {
    /// Lower `phmm` to the banded encoding and build its fused tables —
    /// the single construction point for banded tables in the crate
    /// (both `BandedEngine::prepare` and the sparse engine's lazy
    /// posterior cache route through here).
    pub fn lower(phmm: &Phmm) -> Result<BandedLowering> {
        let banded = phmm.to_banded()?;
        let coeffs = BandedCoeffs::new(&banded);
        Ok(BandedLowering { banded, coeffs })
    }
}

/// Every lowering of one [`Phmm`]'s transition structure, frozen once
/// per parameter freeze (EM iteration or cached profile).
///
/// Owns copies: the graph may be mutably borrowed again (maximization)
/// while a `Lowering` is alive, but it must be re-frozen after any
/// parameter update.
pub struct Lowering {
    pub(super) n_states: usize,
    pub(super) n_edges: usize,
    pub(super) sigma: usize,
    /// Band width W of the graph (1 + max forward hop).
    pub(super) band: usize,
    /// Dense-tile row width: `band` rounded up to [`TILE_LANES`].
    pub(super) tile_w: usize,
    /// Whether [`GatherKind::Adaptive`] may ever dispatch to the tile
    /// kernel (the [`TILE_MIN_OCCUPANCY`] structural gate, frozen once).
    pub(super) tile_eligible: bool,
    /// Incoming-CSR row pointers (per target state).
    pub(super) in_ptr: Vec<u32>,
    /// Source state of each incoming edge.
    pub(super) in_from: Vec<u32>,
    /// Outgoing-edge index of each incoming slot (maps incoming slots
    /// back to `phmm.out_prob`).
    pub(super) in_eidx: Vec<u32>,
    /// Snapshot of the nonzero initial distribution.
    pub(super) init: Vec<(u32, f32)>,
    /// Banded lowering, built at most once, on first demand.
    banded: OnceLock<BandedLowering>,
}

impl Lowering {
    /// Freeze the transition structure (and initial distribution) of
    /// `phmm`.  Cost: one incoming-CSR transpose, `O(|A|)` — paid once
    /// per parameter freeze and shared by every engine.
    pub fn freeze(phmm: &Phmm) -> Lowering {
        let (in_ptr, in_from, in_eidx) = phmm.incoming_csr();
        let band = phmm.band_width();
        let tile_w = band.div_ceil(TILE_LANES) * TILE_LANES;
        let n_states = phmm.n_states();
        let n_edges = phmm.n_transitions();
        let tile_eligible =
            n_edges as f64 >= TILE_MIN_OCCUPANCY * (n_states * tile_w) as f64;
        Lowering {
            n_states,
            n_edges,
            sigma: phmm.sigma(),
            band,
            tile_w,
            tile_eligible,
            in_ptr,
            in_from,
            in_eidx,
            init: phmm.init_states().collect(),
            banded: OnceLock::new(),
        }
    }

    /// Number of states the lowering covers.
    #[inline]
    pub fn n_states(&self) -> usize {
        self.n_states
    }

    /// Number of edges the lowering covers.
    #[inline]
    pub fn n_edges(&self) -> usize {
        self.n_edges
    }

    /// Alphabet size the lowering covers.
    #[inline]
    pub fn sigma(&self) -> usize {
        self.sigma
    }

    /// Band width W (1 + max forward hop).
    #[inline]
    pub fn band(&self) -> usize {
        self.band
    }

    /// Dense-tile row width (`band` rounded up to [`TILE_LANES`]).
    #[inline]
    pub fn tile_width(&self) -> usize {
        self.tile_w
    }

    /// Leading zero-padding of the gather buffer: tile column `0` of
    /// target `to` reads source `to − pad`, so the dense scratch carries
    /// `pad` permanently-zero slots in front of state `0`.
    #[inline]
    pub fn gather_pad(&self) -> usize {
        self.tile_w - 1
    }

    /// Structural tile occupancy: `n_edges / (n_states · tile_w)` —
    /// the fraction of tile arithmetic that touches a real edge.
    pub fn tile_occupancy(&self) -> f64 {
        self.n_edges as f64 / (self.n_states.max(1) * self.tile_w) as f64
    }

    /// Whether [`GatherKind::Adaptive`] may ever dispatch to the tile
    /// kernel on this graph (the [`TILE_MIN_OCCUPANCY`] gate).
    #[inline]
    pub fn tile_eligible(&self) -> bool {
        self.tile_eligible
    }

    /// The banded lowering of the same graph, built at most once per
    /// freeze, on first use (the sparse engine's posterior-decode
    /// path).  `phmm` must be the graph this lowering was frozen from.
    pub fn banded_for(&self, phmm: &Phmm) -> Result<&BandedLowering> {
        if let Some(bl) = self.banded.get() {
            return Ok(bl);
        }
        let built = BandedLowering::lower(phmm)?;
        // A concurrent builder may win the race; its value is used.
        Ok(self.banded.get_or_init(|| built))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::phmm::EcDesignParams;
    use crate::seq::Sequence;
    use crate::sim::XorShift;
    use crate::testutil;

    fn ec_graph(rng: &mut XorShift, len: usize) -> Phmm {
        let data = testutil::random_seq(rng, len, 4);
        Phmm::error_correction(&Sequence::from_symbols("r", data), &EcDesignParams::default())
            .unwrap()
    }

    #[test]
    fn freeze_matches_graph_shape() {
        testutil::check(10, |rng| {
            let len = rng.range(4, 40);
            let g = ec_graph(rng, len);
            let low = Lowering::freeze(&g);
            assert_eq!(low.n_states(), g.n_states());
            assert_eq!(low.n_edges(), g.n_transitions());
            assert_eq!(low.sigma(), g.sigma());
            assert_eq!(low.band(), g.band_width());
            assert!(low.tile_width() >= low.band());
            assert_eq!(low.tile_width() % TILE_LANES, 0);
            assert!(low.tile_width() < low.band() + TILE_LANES);
            assert_eq!(low.gather_pad(), low.tile_width() - 1);
            // The incoming CSR covers every edge exactly once and every
            // slot's source obeys the band bound.
            assert_eq!(low.in_ptr.len(), g.n_states() + 1);
            assert_eq!(low.in_from.len(), g.n_transitions());
            for to in 0..g.n_states() {
                for slot in low.in_ptr[to] as usize..low.in_ptr[to + 1] as usize {
                    let from = low.in_from[slot] as usize;
                    assert!(from <= to, "backward edge {from}->{to}");
                    assert!(to - from < low.band(), "hop {from}->{to} exceeds band");
                    let e = low.in_eidx[slot] as usize;
                    assert_eq!(g.out_to[e] as usize, to);
                }
            }
        });
    }

    #[test]
    fn incoming_slots_are_sorted_by_source() {
        // The bitwise contract between the CSR gather and the tile
        // kernel: within each target the incoming slots ascend by
        // source, which is the order the tile dot product sums in.
        let mut rng = XorShift::new(11);
        let g = ec_graph(&mut rng, 50);
        let low = Lowering::freeze(&g);
        for to in 0..g.n_states() {
            let lo = low.in_ptr[to] as usize;
            let hi = low.in_ptr[to + 1] as usize;
            for pair in low.in_from[lo..hi].windows(2) {
                assert!(pair[0] < pair[1], "incoming slots of {to} not ascending");
            }
        }
    }

    #[test]
    fn banded_lowering_is_built_once_and_shared() {
        let mut rng = XorShift::new(13);
        let g = ec_graph(&mut rng, 20);
        let low = Lowering::freeze(&g);
        let a = low.banded_for(&g).unwrap() as *const BandedLowering;
        let b = low.banded_for(&g).unwrap() as *const BandedLowering;
        assert_eq!(a, b, "banded lowering must be cached after first use");
        let bl = low.banded_for(&g).unwrap();
        assert_eq!(bl.banded.n, g.n_states());
        assert_eq!(bl.coeffs.shape(), (bl.banded.n, bl.banded.w, bl.banded.sigma));
    }

    #[test]
    fn gather_kind_names() {
        assert_eq!(GatherKind::default(), GatherKind::Adaptive);
        assert_eq!(GatherKind::Csr.name(), "csr");
        assert_eq!(GatherKind::DenseTile.name(), "dense-tile");
        assert_eq!(GatherKind::Adaptive.name(), "adaptive");
    }
}
