//! Memoized per-symbol fused-coefficient tables and reusable scratch
//! pools — the software analogue of ApHMM's on-chip coefficient
//! memoization (paper §4.2–4.3).
//!
//! Both Baum-Welch recurrences multiply every traversed edge by the same
//! two parameters: the transition probability `α_ij` and the emission
//! probability `e_s(v_j)` of the edge target for the current symbol.
//! Those parameters are frozen for the whole E-step of an EM iteration,
//! so the products can be computed **once per iteration per symbol**
//! instead of once per edge per timestep per read:
//!
//! * [`FusedCoeffs::in_coef_for`]`(s)[e] = α(e) · e_s(to(e))` over the
//!   *incoming* CSR — the forward pass becomes a pure per-symbol sparse
//!   matrix-vector product (one multiply-accumulate per edge, no
//!   emission gather, no post-hoc emission scale per state).
//! * The same products in the per-window dense-tile layout of
//!   [`super::DenseTiles`] — the branchless vector form the
//!   density-adaptive gather dispatches to on near-dense windows.
//! * [`FusedCoeffs::out_coef_for`]`(s)[e]` is the same product over the
//!   *outgoing* CSR, pre-widened to `f64` — the fused backward + ξ
//!   update touches one table entry per edge instead of performing two
//!   `f32→f64` converts, an emission gather and an extra multiply.
//!
//! The transition *structure* behind the tables (incoming CSR, band
//! width, tile geometry, the lazily-built banded encoding) is owned by
//! the shared [`Lowering`] — one freeze-time product for every engine;
//! this module only adds the parameter-dependent coefficient arrays on
//! top of it.
//!
//! [`ForwardScratch`] complements the tables with reusable buffers: the
//! dense gather buffer (carrying [`Lowering::gather_pad`] leading zeros
//! so tile rows can read a contiguous window), the backward row pair,
//! the histogram-filter state, and a pool of [`SparseRow`]s so the
//! per-timestep `Vec::with_capacity` churn of the original engine
//! disappears (recycle results with [`ForwardScratch::recycle`]).  One
//! scratch per worker thread; the coefficient tables are immutable and
//! shared.

use std::sync::OnceLock;

use super::filter::{FilterConfig, HistogramFilter};
use super::lowering::Lowering;
use super::sparse::{CheckpointedForward, ForwardResult, SparseRow};
use super::tile::{DenseTiles, OutTiles};
use crate::cancel::CancelToken;
use crate::phmm::Phmm;

/// Per-symbol fused coefficient tables for one parameter freeze.
///
/// Built from a [`Phmm`] by [`FusedCoeffs::new`]; the tables *copy* the
/// parameters, so the graph may be mutably borrowed again (e.g. by the
/// maximization step) while the tables are alive — but they must be
/// rebuilt after any parameter update.
pub struct FusedCoeffs {
    /// The shared transition-structure lowering the tables are built on.
    pub(super) lowering: Lowering,
    /// `α · e_s(to)` per incoming edge, symbol-major `[Σ × |A|]`.
    pub(super) in_coef: Vec<f32>,
    /// `α · e_s(to)` per outgoing edge in `f64`, symbol-major `[Σ × |A|]`.
    pub(super) out_coef: Vec<f64>,
    /// The same incoming products in the dense-tile layout — built at
    /// most once per freeze, on the first forward pass that may
    /// dispatch to the tile kernel (`GatherKind::Csr`-only workloads
    /// never pay the `Σ·N·tile_w` footprint), mirroring the lazy
    /// banded lowering beside it.
    pub(super) tiles: OnceLock<DenseTiles>,
    /// The outgoing products in the dense out-tile layout of the
    /// tile-granular fused backward — same lazy once-per-freeze
    /// lifecycle as `tiles` (only backward passes that may dispatch to
    /// the out-tile walk build it).
    pub(super) out_tiles: OnceLock<OutTiles>,
}

impl FusedCoeffs {
    /// Precompute the fused tables for the current parameters of `phmm`.
    ///
    /// Cost: `O(Σ · |A|)` multiplies — negligible next to the
    /// `O(T · |A|)` edge traversals of a single observation, and paid
    /// once per EM iteration (or once per database profile for
    /// inference-only scoring).
    pub fn new(phmm: &Phmm) -> FusedCoeffs {
        FusedCoeffs::from_lowering(Lowering::freeze(phmm), phmm)
    }

    /// Build the coefficient tables over an already-frozen `lowering`
    /// of the same graph.
    pub fn from_lowering(lowering: Lowering, phmm: &Phmm) -> FusedCoeffs {
        assert_eq!(lowering.n_states, phmm.n_states(), "lowering frozen from another graph");
        assert_eq!(lowering.n_edges, phmm.n_transitions(), "lowering frozen from another graph");
        assert_eq!(lowering.sigma, phmm.sigma(), "lowering frozen from another graph");
        let sigma = lowering.sigma;
        let n = lowering.n_states;
        let n_edges = lowering.n_edges;

        let mut in_coef = vec![0.0f32; sigma * n_edges];
        for to in 0..n {
            let lo = lowering.in_ptr[to] as usize;
            let hi = lowering.in_ptr[to + 1] as usize;
            let emit = &phmm.emissions[to * sigma..(to + 1) * sigma];
            for slot in lo..hi {
                let p = phmm.out_prob[lowering.in_eidx[slot] as usize];
                for (s, &e_s) in emit.iter().enumerate() {
                    in_coef[s * n_edges + slot] = p * e_s;
                }
            }
        }

        let mut out_coef = vec![0.0f64; sigma * n_edges];
        for e in 0..n_edges {
            let to = phmm.out_to[e] as usize;
            let p = phmm.out_prob[e] as f64;
            let emit = &phmm.emissions[to * sigma..(to + 1) * sigma];
            for (s, &e_s) in emit.iter().enumerate() {
                out_coef[s * n_edges + e] = p * e_s as f64;
            }
        }

        FusedCoeffs { lowering, in_coef, out_coef, tiles: OnceLock::new(), out_tiles: OnceLock::new() }
    }

    /// The dense-tile mirror of the incoming tables, built at most once
    /// per freeze, on first demand.  `phmm` must be the graph the
    /// tables were frozen from, with unchanged parameters — the same
    /// contract as [`Lowering::banded_for`] (the tile products must be
    /// bit-identical to `in_coef`, which already requires the
    /// parameters not to have moved under a live `FusedCoeffs`).
    pub(super) fn tiles_for(&self, phmm: &Phmm) -> &DenseTiles {
        if let Some(t) = self.tiles.get() {
            return t;
        }
        let built = DenseTiles::new(&self.lowering, phmm);
        // A concurrent builder may win the race; its value is used.
        self.tiles.get_or_init(|| built)
    }

    /// The dense out-tile mirror of the outgoing tables (the
    /// tile-granular backward's lowering), built at most once per
    /// freeze, on first demand — same contract as
    /// [`FusedCoeffs::tiles_for`].
    pub(super) fn out_tiles_for(&self, phmm: &Phmm) -> &OutTiles {
        if let Some(t) = self.out_tiles.get() {
            return t;
        }
        let built = OutTiles::new(&self.lowering, phmm);
        // A concurrent builder may win the race; its value is used.
        self.out_tiles.get_or_init(|| built)
    }

    /// The shared transition-structure lowering behind the tables.
    #[inline]
    pub fn lowering(&self) -> &Lowering {
        &self.lowering
    }

    /// Number of edges the tables cover (sanity checks against a graph).
    #[inline]
    pub fn n_edges(&self) -> usize {
        self.lowering.n_edges
    }

    /// Alphabet size the tables cover.
    #[inline]
    pub fn sigma(&self) -> usize {
        self.lowering.sigma
    }

    /// Leading zero-padding the gather scratch must carry
    /// ([`Lowering::gather_pad`]).
    #[inline]
    pub fn gather_pad(&self) -> usize {
        self.lowering.gather_pad()
    }

    /// Incoming fused coefficients of symbol `s` (incoming-slot order).
    #[inline]
    pub(super) fn in_coef_for(&self, s: usize) -> &[f32] {
        let n_edges = self.lowering.n_edges;
        &self.in_coef[s * n_edges..(s + 1) * n_edges]
    }

    /// Outgoing fused coefficients of symbol `s` (outgoing-edge order).
    #[inline]
    pub(super) fn out_coef_for(&self, s: usize) -> &[f64] {
        let n_edges = self.lowering.n_edges;
        &self.out_coef[s * n_edges..(s + 1) * n_edges]
    }

    /// Dense-tile rows of symbol `s` (`[N × tile_w]`).  The forward
    /// entry points call [`FusedCoeffs::tiles_for`] before any row can
    /// dispatch to the tile kernel, so the tables are always present
    /// here.
    #[inline]
    pub(super) fn tile_coef_for(&self, s: usize) -> &[f32] {
        self.tiles.get().expect("dense tiles not built before tile dispatch").coef_for(s)
    }
}

/// Reusable per-worker buffers for the sparse kernels.
///
/// Sized lazily by [`ForwardScratch::ensure`], so one scratch can be
/// reused across graphs of different sizes (e.g. scoring a whole family
/// database).  All buffers are maintained zeroed/empty between calls.
#[derive(Default)]
pub struct ForwardScratch {
    /// Dense gather buffer (≥ n_states + gather pad; state `i` lives at
    /// slot `i + pad` so tile rows read a contiguous window; zero
    /// outside the active row).
    pub(super) dense: Vec<f32>,
    /// Striped dense gather buffer of the multi-read kernels:
    /// `(n_states + pad) · K` slots, read-minor (`slot i` of read `r`
    /// lives at `i · K + r`); zero outside the scattered rows.
    pub(super) striped: Vec<f32>,
    /// Backward value buffer for timestep t+1 (≥ n_states, zeroed).
    pub(super) b_next: Vec<f64>,
    /// Backward value buffer for timestep t (≥ n_states, zeroed).
    pub(super) b_cur: Vec<f64>,
    /// Histogram-filter state (rebuilt when the bin count changes).
    pub(super) hist: Option<HistogramFilter>,
    /// Cooperative cancel token observed by the checkpointed backward
    /// sweep at segment boundaries (never inside a reduction).  Set per
    /// request via [`super::ExpectationEngine::set_cancel`]; defaults to
    /// the never-cancelled token.
    pub(super) cancel: CancelToken,
    hist_bins: usize,
    row_pool: Vec<SparseRow>,
    rows_vec_pool: Vec<Vec<SparseRow>>,
    scales_pool: Vec<Vec<f32>>,
    fresh_rows: u64,
}

impl ForwardScratch {
    /// Scratch pre-sized for `phmm`.
    pub fn new(phmm: &Phmm) -> ForwardScratch {
        let mut s = ForwardScratch::default();
        s.ensure(phmm.n_states());
        s
    }

    /// Grow the dense/backward buffers to cover `n` slots (the gather
    /// kernels pass `n_states + gather_pad` so the pad region exists).
    pub(super) fn ensure(&mut self, n: usize) {
        if self.dense.len() < n {
            self.dense.resize(n, 0.0);
            self.b_next.resize(n, 0.0);
            self.b_cur.resize(n, 0.0);
        }
    }

    /// Grow the striped gather buffer to cover `len` slots (the striped
    /// kernels pass `(n_states + gather_pad) · k`); maintained all-zero
    /// between calls like `dense`.
    pub(super) fn ensure_striped(&mut self, len: usize) {
        if self.striped.len() < len {
            self.striped.resize(len, 0.0);
        }
    }

    /// The zeroed backward row pair (call [`ForwardScratch::ensure`]
    /// first; the borrower must restore the all-zero invariant).
    pub(super) fn backward_bufs(&mut self) -> (&mut [f64], &mut [f64]) {
        (&mut self.b_next, &mut self.b_cur)
    }

    /// Make the histogram-filter state match `filter`.
    pub(super) fn ensure_hist(&mut self, filter: &FilterConfig) {
        if let FilterConfig::Histogram { bins, .. } = *filter {
            if self.hist.is_none() || self.hist_bins != bins {
                self.hist = Some(HistogramFilter::new(bins));
                self.hist_bins = bins;
            }
        }
    }

    /// Pop a cleared row from the pool (allocating only when empty).
    pub(super) fn take_row(&mut self) -> SparseRow {
        match self.row_pool.pop() {
            Some(mut row) => {
                row.idx.clear();
                row.val.clear();
                row
            }
            None => {
                self.fresh_rows += 1;
                SparseRow::default()
            }
        }
    }

    /// Return a row to the pool.
    pub(super) fn put_row(&mut self, row: SparseRow) {
        self.row_pool.push(row);
    }

    /// Pop a cleared outer rows vector from the pool.
    pub(super) fn take_rows_vec(&mut self) -> Vec<SparseRow> {
        self.rows_vec_pool.pop().unwrap_or_default()
    }

    /// Pop a cleared scales vector from the pool.
    pub(super) fn take_scales_vec(&mut self) -> Vec<f32> {
        self.scales_pool.pop().unwrap_or_default()
    }

    /// Return a finished [`ForwardResult`]'s buffers to the pools so the
    /// next observation reuses them instead of reallocating.
    pub fn recycle(&mut self, mut result: ForwardResult) {
        self.row_pool.append(&mut result.rows);
        self.rows_vec_pool.push(result.rows);
        result.scales.clear();
        self.scales_pool.push(result.scales);
    }

    /// Return a consumed [`CheckpointedForward`]'s buffers to the pools
    /// (the checkpointed counterpart of [`ForwardScratch::recycle`]).
    pub(super) fn recycle_checkpointed(&mut self, mut ckpt: CheckpointedForward) {
        self.row_pool.append(&mut ckpt.ckpt_rows);
        self.rows_vec_pool.push(ckpt.ckpt_rows);
        ckpt.scales.clear();
        self.scales_pool.push(ckpt.scales);
    }

    /// Number of [`SparseRow`]s ever allocated (pool misses).  Used by
    /// the memory-profile tests: the score-only fast path acquires a
    /// constant number of rows regardless of sequence length.
    pub fn fresh_rows_allocated(&self) -> u64 {
        self.fresh_rows
    }

    /// Length of the dense state buffer (memory-profile tests).
    pub fn dense_len(&self) -> usize {
        self.dense.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::phmm::EcDesignParams;
    use crate::seq::Sequence;
    use crate::sim::XorShift;
    use crate::testutil;

    fn ec_graph(rng: &mut XorShift, len: usize) -> Phmm {
        let data = testutil::random_seq(rng, len, 4);
        Phmm::error_correction(&Sequence::from_symbols("r", data), &EcDesignParams::default())
            .unwrap()
    }

    #[test]
    fn fused_tables_match_direct_products() {
        testutil::check(10, |rng| {
            let len = rng.range(4, 30);
            let g = ec_graph(rng, len);
            let c = FusedCoeffs::new(&g);
            assert_eq!(c.n_edges(), g.n_transitions());
            assert_eq!(c.sigma(), g.sigma());
            assert_eq!(c.lowering().band(), g.band_width());
            // Outgoing table: direct check against α · e_s(to).
            for s in 0..g.sigma() {
                let oc = c.out_coef_for(s);
                for e in 0..g.n_transitions() {
                    let to = g.out_to[e] as usize;
                    let want = g.out_prob[e] as f64 * g.emission(to, s as u8) as f64;
                    assert!((oc[e] - want).abs() <= 1e-12, "edge {e} symbol {s}");
                }
            }
            // Incoming table: every incoming slot carries the fused
            // product of its source edge.
            let (in_ptr, _, in_eidx) = g.incoming_csr();
            for to in 0..g.n_states() {
                for slot in in_ptr[to] as usize..in_ptr[to + 1] as usize {
                    let e = in_eidx[slot] as usize;
                    for s in 0..g.sigma() {
                        let want = g.out_prob[e] * g.emission(to, s as u8);
                        let got = c.in_coef_for(s)[slot];
                        assert!((got - want).abs() <= 1e-12, "slot {slot} symbol {s}");
                    }
                }
            }
        });
    }

    #[test]
    fn tiles_are_lazy_and_cached() {
        let mut rng = XorShift::new(23);
        let g = ec_graph(&mut rng, 15);
        let c = FusedCoeffs::new(&g);
        assert!(c.tiles.get().is_none(), "freeze must not build tiles eagerly");
        let t1 = c.tiles_for(&g) as *const DenseTiles;
        let t2 = c.tiles_for(&g) as *const DenseTiles;
        assert_eq!(t1, t2, "tiles must be built at most once per freeze");
    }

    #[test]
    fn from_lowering_panics_on_foreign_graph() {
        let mut rng = XorShift::new(19);
        let g1 = ec_graph(&mut rng, 10);
        let g2 = ec_graph(&mut rng, 25);
        let low = Lowering::freeze(&g1);
        let got = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            FusedCoeffs::from_lowering(low, &g2)
        }));
        assert!(got.is_err(), "mismatched lowering/graph must not build tables");
    }

    #[test]
    fn scratch_pools_reuse_rows() {
        let mut rng = XorShift::new(5);
        let g = ec_graph(&mut rng, 20);
        let mut scratch = ForwardScratch::new(&g);
        assert_eq!(scratch.fresh_rows_allocated(), 0);
        let r1 = scratch.take_row();
        let r2 = scratch.take_row();
        assert_eq!(scratch.fresh_rows_allocated(), 2);
        scratch.put_row(r1);
        scratch.put_row(r2);
        let _r = scratch.take_row();
        assert_eq!(scratch.fresh_rows_allocated(), 2, "pool hit must not allocate");
    }

    #[test]
    fn scratch_grows_to_largest_graph() {
        let mut rng = XorShift::new(6);
        let small = ec_graph(&mut rng, 5);
        let large = ec_graph(&mut rng, 40);
        let mut scratch = ForwardScratch::new(&small);
        let n_small = scratch.dense_len();
        scratch.ensure(large.n_states());
        assert!(scratch.dense_len() >= large.n_states());
        assert!(scratch.dense_len() >= n_small);
    }
}
