//! Portable SIMD shim for the dense-tile gather kernels.
//!
//! The dense tiles of [`super::lowering`] are `TILE_LANES`-padded f32
//! rows built explicitly so the in-window dot product can vectorize,
//! but until this module existed the reduction was a single scalar
//! accumulator — a serial dependency chain the compiler must not
//! reassociate.  This shim gives the kernels an explicit lane-parallel
//! form without nightly `std::simd` or any dependency: fixed-width
//! `[f32; W]` lane accumulators over exact chunks, which LLVM lowers to
//! vector FMAs/adds on every target we build for, plus a scalar
//! fallback that preserves the historic ascending-order sum bit for
//! bit.
//!
//! ## Reproducibility contract
//!
//! * `SimdLanes::Scalar` sums window terms in ascending source order —
//!   **bit-identical** to the pre-SIMD kernel and to the CSR gather.
//! * `SimdLanes::X4` / `SimdLanes::X8` keep W partial sums (term `i`
//!   goes to lane `i % W` of its chunk) and reduce them in a **fixed
//!   binary tree** — `(a0+a1)+(a2+a3)`, and for 8 lanes
//!   `((a0+a1)+(a2+a3))+((a4+a5)+(a6+a7))`.  The result is fully
//!   deterministic for a given lane width on every platform (portable
//!   per-lane f32 ops are exact IEEE), but it is a *reassociation* of
//!   the scalar sum, so cross-width comparisons live in the
//!   [`SIMD_REASSOC_RTOL`]/[`SIMD_REASSOC_ATOL`] tolerance tier rather
//!   than the bitwise tier.
//! * The striped variants replicate, per read, exactly the lane
//!   assignment and reduction tree of the one-read kernel at the same
//!   width — striped results are **bit-identical** to running each
//!   read alone at that width (the acceptance contract of the striped
//!   batch kernels; pinned in `striped::tests` and the engine matrix).
//!
//! ## Selection
//!
//! [`SimdPolicy`] lives on `ForwardOptions`/`TrainConfig`/serve config;
//! `Auto` resolves from the host (AVX2 → 8 lanes, otherwise 4 on
//! x86-64/aarch64, scalar elsewhere).  The `APHMM_SIMD` environment
//! variable (`scalar` | `f32x4` | `f32x8` | `auto`) overrides the
//! configured policy process-wide — that is how CI forces the whole
//! suite down the scalar fallback on any runner.  Unknown values are
//! ignored.

use std::sync::OnceLock;

/// Relative tolerance for comparisons across lane widths (scalar vs
/// f32x4 vs f32x8): the only permitted divergence is f32 reassociation
/// of the in-window dot product, once per gathered cell.
pub const SIMD_REASSOC_RTOL: f64 = 1e-4;
/// Absolute tolerance companion to [`SIMD_REASSOC_RTOL`].
pub const SIMD_REASSOC_ATOL: f64 = 1e-9;

/// Maximum number of reads a striped kernel processes per sweep; the
/// striped accumulators are stack arrays sized by this.
pub const MAX_STRIPE: usize = 8;

/// Lane-width policy for the dense-tile dot product.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum SimdPolicy {
    /// Pick the widest lane count the host supports (the default).
    #[default]
    Auto,
    /// Force the scalar ascending-order fallback (bitwise tier).
    Scalar,
    /// Force 4 lanes (portable: plain `[f32; 4]` arithmetic).
    F32x4,
    /// Force 8 lanes (portable: plain `[f32; 8]` arithmetic).
    F32x8,
}

impl SimdPolicy {
    /// All accepted [`SimdPolicy::parse`] spellings.
    pub const NAMES: [&'static str; 4] = ["auto", "scalar", "f32x4", "f32x8"];

    /// Parse a policy name as used by configs and `APHMM_SIMD`.
    pub fn parse(s: &str) -> Option<SimdPolicy> {
        match s {
            "auto" => Some(SimdPolicy::Auto),
            "scalar" => Some(SimdPolicy::Scalar),
            "f32x4" => Some(SimdPolicy::F32x4),
            "f32x8" => Some(SimdPolicy::F32x8),
            _ => None,
        }
    }

    /// Canonical name (inverse of [`SimdPolicy::parse`]).
    pub fn name(self) -> &'static str {
        match self {
            SimdPolicy::Auto => "auto",
            SimdPolicy::Scalar => "scalar",
            SimdPolicy::F32x4 => "f32x4",
            SimdPolicy::F32x8 => "f32x8",
        }
    }

    /// Resolve the policy to concrete lanes.  The `APHMM_SIMD`
    /// environment override (read once per process) wins over the
    /// configured value so CI can force every code path scalar.
    pub fn resolve(self) -> SimdLanes {
        match env_override().unwrap_or(self) {
            SimdPolicy::Scalar => SimdLanes::Scalar,
            SimdPolicy::F32x4 => SimdLanes::X4,
            SimdPolicy::F32x8 => SimdLanes::X8,
            SimdPolicy::Auto => auto_lanes(),
        }
    }
}

fn env_override() -> Option<SimdPolicy> {
    static OVERRIDE: OnceLock<Option<SimdPolicy>> = OnceLock::new();
    *OVERRIDE
        .get_or_init(|| std::env::var("APHMM_SIMD").ok().and_then(|v| SimdPolicy::parse(v.trim())))
}

fn auto_lanes() -> SimdLanes {
    #[cfg(target_arch = "x86_64")]
    {
        if std::is_x86_feature_detected!("avx2") {
            SimdLanes::X8
        } else {
            SimdLanes::X4
        }
    }
    #[cfg(target_arch = "aarch64")]
    {
        SimdLanes::X4
    }
    #[cfg(not(any(target_arch = "x86_64", target_arch = "aarch64")))]
    {
        SimdLanes::Scalar
    }
}

/// A resolved lane width (what the kernels actually dispatch on).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SimdLanes {
    /// Ascending-order scalar sum (the bitwise-contract fallback).
    Scalar,
    /// 4 partial sums, fixed-tree reduced.
    X4,
    /// 8 partial sums, fixed-tree reduced.
    X8,
}

impl SimdLanes {
    /// Number of f32 lanes.
    pub fn width(self) -> usize {
        match self {
            SimdLanes::Scalar => 1,
            SimdLanes::X4 => 4,
            SimdLanes::X8 => 8,
        }
    }

    /// Display name used by benches and logs.
    pub fn name(self) -> &'static str {
        match self {
            SimdLanes::Scalar => "scalar",
            SimdLanes::X4 => "f32x4",
            SimdLanes::X8 => "f32x8",
        }
    }
}

/// In-window dot product of one dense window against one tile row.
///
/// `win.len() == row.len()` and is a multiple of `TILE_LANES` (= 4) by
/// tile construction, so the 4-lane path has no remainder and the
/// 8-lane remainder is either empty or exactly 4 terms (folded into
/// lanes 0..4 before the tree reduction).
#[inline]
pub(super) fn dot_tile(win: &[f32], row: &[f32], lanes: SimdLanes) -> f32 {
    debug_assert_eq!(win.len(), row.len());
    debug_assert_eq!(win.len() % 4, 0, "tile rows are TILE_LANES-padded");
    match lanes {
        SimdLanes::Scalar => {
            let mut acc = 0.0f32;
            for (&w, &t) in win.iter().zip(row.iter()) {
                acc += w * t;
            }
            acc
        }
        SimdLanes::X4 => {
            let mut acc = [0.0f32; 4];
            for (w, t) in win.chunks_exact(4).zip(row.chunks_exact(4)) {
                for l in 0..4 {
                    acc[l] += w[l] * t[l];
                }
            }
            (acc[0] + acc[1]) + (acc[2] + acc[3])
        }
        SimdLanes::X8 => {
            let mut acc = [0.0f32; 8];
            let main = win.len() - win.len() % 8;
            for (w, t) in win[..main].chunks_exact(8).zip(row[..main].chunks_exact(8)) {
                for l in 0..8 {
                    acc[l] += w[l] * t[l];
                }
            }
            // Remainder (0 or 4 terms): term j folds into lane j.
            for (l, (&w, &t)) in win[main..].iter().zip(row[main..].iter()).enumerate() {
                acc[l] += w * t;
            }
            ((acc[0] + acc[1]) + (acc[2] + acc[3])) + ((acc[4] + acc[5]) + (acc[6] + acc[7]))
        }
    }
}

/// Striped in-window dot product: `k` reads' windows interleaved
/// read-minor (`striped[i * k + r]` is read `r`'s value for window
/// slot `i`), one shared tile row, all `k` accumulators produced in
/// one sweep (`out[r]`).
///
/// Per read, the lane assignment and reduction tree are exactly those
/// of [`dot_tile`] at the same width, so each `out[r]` is bit-identical
/// to `dot_tile(win_r, row, lanes)` — while the inner loops read
/// contiguous `k`-wide spans and broadcast one coefficient, the shape
/// that vectorizes *across* reads.
#[inline]
pub(super) fn dot_tile_striped(
    striped: &[f32],
    row: &[f32],
    k: usize,
    lanes: SimdLanes,
    out: &mut [f32],
) {
    debug_assert!(k >= 1 && k <= MAX_STRIPE);
    debug_assert_eq!(striped.len(), row.len() * k);
    debug_assert_eq!(out.len(), k);
    debug_assert_eq!(row.len() % 4, 0, "tile rows are TILE_LANES-padded");
    const S: usize = MAX_STRIPE;
    match lanes {
        SimdLanes::Scalar => {
            out.iter_mut().for_each(|o| *o = 0.0);
            for (i, &t) in row.iter().enumerate() {
                let base = i * k;
                for r in 0..k {
                    out[r] += striped[base + r] * t;
                }
            }
        }
        SimdLanes::X4 => {
            let mut acc = [0.0f32; 4 * S];
            for (c, t) in row.chunks_exact(4).enumerate() {
                let base = c * 4 * k;
                for l in 0..4 {
                    for r in 0..k {
                        acc[l * S + r] += striped[base + l * k + r] * t[l];
                    }
                }
            }
            for r in 0..k {
                out[r] = (acc[r] + acc[S + r]) + (acc[2 * S + r] + acc[3 * S + r]);
            }
        }
        SimdLanes::X8 => {
            let mut acc = [0.0f32; 8 * S];
            let main = row.len() - row.len() % 8;
            for (c, t) in row[..main].chunks_exact(8).enumerate() {
                let base = c * 8 * k;
                for l in 0..8 {
                    for r in 0..k {
                        acc[l * S + r] += striped[base + l * k + r] * t[l];
                    }
                }
            }
            for (l, &t) in row[main..].iter().enumerate() {
                let base = (main + l) * k;
                for r in 0..k {
                    acc[l * S + r] += striped[base + r] * t;
                }
            }
            for r in 0..k {
                let lo = (acc[r] + acc[S + r]) + (acc[2 * S + r] + acc[3 * S + r]);
                let hi = (acc[4 * S + r] + acc[5 * S + r]) + (acc[6 * S + r] + acc[7 * S + r]);
                out[r] = lo + hi;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn window(n: usize, seed: u32) -> Vec<f32> {
        // Deterministic pseudo-random positive values (no RNG deps).
        let mut x = seed.wrapping_mul(2654435761).wrapping_add(12345);
        (0..n)
            .map(|_| {
                x = x.wrapping_mul(1664525).wrapping_add(1013904223);
                ((x >> 8) as f32 / (1u32 << 24) as f32) * 0.9 + 0.05
            })
            .collect()
    }

    #[test]
    fn policy_names_roundtrip() {
        for name in SimdPolicy::NAMES {
            let p = SimdPolicy::parse(name).unwrap();
            assert_eq!(p.name(), name);
        }
        assert_eq!(SimdPolicy::parse("avx512"), None);
        assert_eq!(SimdPolicy::default(), SimdPolicy::Auto);
    }

    #[test]
    fn scalar_dot_matches_ascending_sum() {
        for len in [4usize, 8, 12, 16, 24] {
            let w = window(len, 1);
            let t = window(len, 2);
            let mut expect = 0.0f32;
            for i in 0..len {
                expect += w[i] * t[i];
            }
            assert_eq!(dot_tile(&w, &t, SimdLanes::Scalar).to_bits(), expect.to_bits());
        }
    }

    #[test]
    fn lane_trees_are_pinned() {
        // The fixed reduction trees, written out longhand: lanes must
        // match them bit for bit (the reproducibility contract).
        let len = 20; // 2 full 8-chunks + a 4-term remainder
        let w = window(len, 3);
        let t = window(len, 4);

        let mut a4 = [0.0f32; 4];
        for c in 0..len / 4 {
            for l in 0..4 {
                a4[l] += w[c * 4 + l] * t[c * 4 + l];
            }
        }
        let expect4 = (a4[0] + a4[1]) + (a4[2] + a4[3]);
        assert_eq!(dot_tile(&w, &t, SimdLanes::X4).to_bits(), expect4.to_bits());

        let mut a8 = [0.0f32; 8];
        for c in 0..len / 8 {
            for l in 0..8 {
                a8[l] += w[c * 8 + l] * t[c * 8 + l];
            }
        }
        for l in 0..len % 8 {
            a8[l] += w[16 + l] * t[16 + l];
        }
        let expect8 =
            ((a8[0] + a8[1]) + (a8[2] + a8[3])) + ((a8[4] + a8[5]) + (a8[6] + a8[7]));
        assert_eq!(dot_tile(&w, &t, SimdLanes::X8).to_bits(), expect8.to_bits());
    }

    #[test]
    fn widths_agree_within_reassoc_tolerance() {
        for len in [8usize, 12, 32, 44] {
            let w = window(len, 5);
            let t = window(len, 6);
            let s = dot_tile(&w, &t, SimdLanes::Scalar) as f64;
            for lanes in [SimdLanes::X4, SimdLanes::X8] {
                let v = dot_tile(&w, &t, lanes) as f64;
                crate::testutil::assert_close(v, s, SIMD_REASSOC_RTOL, SIMD_REASSOC_ATOL);
            }
        }
    }

    #[test]
    fn striped_is_bit_identical_to_solo_at_every_width() {
        let len = 12;
        let row = window(len, 7);
        for k in 1..=MAX_STRIPE {
            // Build k distinct windows and their striped interleave.
            let wins: Vec<Vec<f32>> = (0..k).map(|r| window(len, 100 + r as u32)).collect();
            let mut striped = vec![0.0f32; len * k];
            for i in 0..len {
                for (r, win) in wins.iter().enumerate() {
                    striped[i * k + r] = win[i];
                }
            }
            for lanes in [SimdLanes::Scalar, SimdLanes::X4, SimdLanes::X8] {
                let mut out = vec![0.0f32; k];
                dot_tile_striped(&striped, &row, k, lanes, &mut out);
                for (r, win) in wins.iter().enumerate() {
                    let solo = dot_tile(win, &row, lanes);
                    assert_eq!(
                        out[r].to_bits(),
                        solo.to_bits(),
                        "striped k={k} read {r} diverged from solo at {lanes:?}"
                    );
                }
            }
        }
    }
}
