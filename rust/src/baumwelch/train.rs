//! The EM training loop (expectation over many reads + one maximization
//! per iteration), with step-level timing instrumentation that feeds
//! Fig. 2 (execution-time breakdown) and the accelerator model.
//!
//! The E-step is a **parallel batch reduction**: reads are cut into
//! fixed-size blocks, worker threads (`TrainConfig::n_workers`) pull
//! blocks from a shared counter, each block accumulates into its own
//! [`BwAccumulators`] (with a per-worker [`ForwardScratch`] and the
//! iteration's shared [`FusedCoeffs`] tables), and block accumulators
//! are merged **in block order**.  Because the block structure and the
//! merge order are independent of the worker count, results are
//! bit-identical for any `n_workers` — `n_workers = 1` is literally the
//! same computation on one thread.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::mpsc;
use std::time::Instant;

use super::filter::{FilterConfig, FilterStats};
use super::kernels::{ForwardScratch, FusedCoeffs};
use super::sparse::{forward_sparse_with, ForwardOptions};
use super::update::BwAccumulators;
use crate::error::Result;
use crate::phmm::Phmm;
use crate::seq::Sequence;

/// Reads per E-step block.  The unit of the deterministic reduction:
/// results depend on this constant but never on the worker count.
const ESTEP_BLOCK: usize = 8;

/// Training configuration.
#[derive(Clone, Copy, Debug)]
pub struct TrainConfig {
    /// Maximum EM iterations.
    pub max_iters: usize,
    /// Stop when the mean per-read log-likelihood improves less than
    /// this between iterations.
    pub tol: f64,
    /// State filter used during the forward pass.
    pub filter: FilterConfig,
    /// E-step worker threads (1 = single-threaded).  Any value yields
    /// bit-identical results; see the module docs.
    pub n_workers: usize,
}

impl Default for TrainConfig {
    fn default() -> Self {
        TrainConfig { max_iters: 3, tol: 1e-3, filter: FilterConfig::None, n_workers: 1 }
    }
}

/// Training outcome and instrumentation.
#[derive(Clone, Debug)]
pub struct TrainResult {
    /// Mean per-read log-likelihood after each iteration's E step.
    pub loglik_history: Vec<f64>,
    /// Iterations actually run.
    pub iters: usize,
    /// Time in the forward calculation (Fig. 2's "Forward").  Summed
    /// across E-step workers: CPU time, not wall time.
    pub forward_ns: u128,
    /// Time in the fused backward + update pass ("Backward" + "Updates").
    /// Summed across E-step workers.
    pub backward_update_ns: u128,
    /// Time in the maximization division.
    pub maximize_ns: u128,
    /// Filter instrumentation (subset of `forward_ns`).
    pub filter_stats: FilterStats,
    /// Σ over reads/timesteps of active states (accelerator workload).
    pub states_processed: u64,
    /// Σ over reads/timesteps of traversed edges.
    pub edges_processed: u64,
    /// Total timesteps executed (Σ over reads/iterations of read length).
    pub timesteps: u64,
    /// Reads skipped (empty, or numerically dead under the current
    /// parameters), summed over iterations.  Previously these were
    /// dropped silently; the coordinator surfaces them in its metrics.
    pub reads_skipped: u64,
}

/// Per-block E-step output: one accumulator plus its instrumentation,
/// merged into the iteration totals in block order.
struct BlockOut {
    acc: BwAccumulators,
    forward_ns: u128,
    backward_update_ns: u128,
    filter_stats: FilterStats,
    states_processed: u64,
    edges_processed: u64,
    timesteps: u64,
    reads_skipped: u64,
}

/// Run one block of reads through forward + fused backward/update.
fn process_block(
    phmm: &Phmm,
    coeffs: &FusedCoeffs,
    reads: &[Sequence],
    opts: &ForwardOptions,
    scratch: &mut ForwardScratch,
) -> Result<BlockOut> {
    let mut out = BlockOut {
        acc: BwAccumulators::new(phmm),
        forward_ns: 0,
        backward_update_ns: 0,
        filter_stats: FilterStats::default(),
        states_processed: 0,
        edges_processed: 0,
        timesteps: 0,
        reads_skipped: 0,
    };
    for read in reads {
        if read.is_empty() {
            out.reads_skipped += 1;
            continue;
        }
        let t0 = Instant::now();
        let fwd = match forward_sparse_with(phmm, coeffs, read, opts, scratch) {
            Ok(f) => f,
            Err(_) => {
                // Dead read under the current parameters (e.g. a
                // mis-mapped read whose path probability underflows the
                // filter) — counted, then skipped, matching Apollo.
                out.reads_skipped += 1;
                continue;
            }
        };
        out.forward_ns += t0.elapsed().as_nanos();
        out.filter_stats.merge(&fwd.filter_stats);
        out.states_processed += fwd.states_processed;
        out.edges_processed += fwd.edges_processed;
        out.timesteps += fwd.rows.len() as u64;

        let t1 = Instant::now();
        out.acc.accumulate_with(phmm, coeffs, read, &fwd, scratch)?;
        out.backward_update_ns += t1.elapsed().as_nanos();
        scratch.recycle(fwd);
    }
    Ok(out)
}

/// One E-step over all reads: block-parallel, deterministically reduced.
fn run_estep(
    phmm: &Phmm,
    coeffs: &FusedCoeffs,
    reads: &[Sequence],
    opts: &ForwardOptions,
    n_workers: usize,
) -> Result<Vec<BlockOut>> {
    let blocks: Vec<&[Sequence]> = reads.chunks(ESTEP_BLOCK).collect();
    if blocks.is_empty() {
        return Ok(Vec::new());
    }
    let workers = n_workers.max(1).min(blocks.len());
    if workers == 1 {
        let mut scratch = ForwardScratch::new(phmm);
        return blocks
            .iter()
            .map(|&block| process_block(phmm, coeffs, block, opts, &mut scratch))
            .collect();
    }

    let next = AtomicUsize::new(0);
    let (tx, rx) = mpsc::channel::<(usize, Result<BlockOut>)>();
    std::thread::scope(|scope| {
        for _ in 0..workers {
            let tx = tx.clone();
            let next = &next;
            let blocks = &blocks;
            scope.spawn(move || {
                let mut scratch = ForwardScratch::new(phmm);
                loop {
                    let bi = next.fetch_add(1, Ordering::Relaxed);
                    if bi >= blocks.len() {
                        break;
                    }
                    let out = process_block(phmm, coeffs, blocks[bi], opts, &mut scratch);
                    if tx.send((bi, out)).is_err() {
                        break;
                    }
                }
            });
        }
    });
    drop(tx);
    let mut slots: Vec<Option<Result<BlockOut>>> = Vec::with_capacity(blocks.len());
    slots.resize_with(blocks.len(), || None);
    for (bi, out) in rx {
        slots[bi] = Some(out);
    }
    // Propagate the first error in *block* order (determinism).
    slots.into_iter().map(|s| s.expect("E-step worker dropped a block")).collect()
}

/// Train `phmm` on `reads` with batch EM.
///
/// Reads that become numerically dead under the current parameters (e.g.
/// mis-mapped reads whose path probability underflows the filter) are
/// skipped and counted in [`TrainResult::reads_skipped`], matching
/// Apollo's behaviour.  With `cfg.n_workers > 1` the E-step fans out
/// across scoped threads; results are bit-identical to `n_workers = 1`.
pub fn train(phmm: &mut Phmm, reads: &[Sequence], cfg: &TrainConfig) -> Result<TrainResult> {
    let opts = ForwardOptions { filter: cfg.filter };
    let mut result = TrainResult {
        loglik_history: Vec::new(),
        iters: 0,
        forward_ns: 0,
        backward_update_ns: 0,
        maximize_ns: 0,
        filter_stats: FilterStats::default(),
        states_processed: 0,
        edges_processed: 0,
        timesteps: 0,
        reads_skipped: 0,
    };
    let mut acc = BwAccumulators::new(phmm);
    let mut prev_mean = f64::NEG_INFINITY;
    for _iter in 0..cfg.max_iters {
        acc.reset();
        // Parameters are frozen for the whole E-step: memoize the fused
        // per-symbol coefficient tables once per iteration (§4.2–4.3).
        // The build is charged to the forward phase it accelerates.
        let t0 = Instant::now();
        let coeffs = FusedCoeffs::new(phmm);
        result.forward_ns += t0.elapsed().as_nanos();
        let outs = run_estep(phmm, &coeffs, reads, &opts, cfg.n_workers)?;
        for out in &outs {
            acc.merge(&out.acc);
            result.forward_ns += out.forward_ns;
            result.backward_update_ns += out.backward_update_ns;
            result.filter_stats.merge(&out.filter_stats);
            result.states_processed += out.states_processed;
            result.edges_processed += out.edges_processed;
            result.timesteps += out.timesteps;
            result.reads_skipped += out.reads_skipped;
        }
        if acc.n_observations == 0 {
            break;
        }
        let mean_ll = acc.total_loglik / acc.n_observations as f64;
        result.loglik_history.push(mean_ll);
        result.iters += 1;

        let t2 = Instant::now();
        acc.apply(phmm)?;
        result.maximize_ns += t2.elapsed().as_nanos();

        if (mean_ll - prev_mean).abs() < cfg.tol {
            break;
        }
        prev_mean = mean_ll;
    }
    Ok(result)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::phmm::EcDesignParams;
    use crate::sim::{simulate_read, ErrorProfile, XorShift};
    use crate::testutil;

    fn noisy_reads(
        rng: &mut XorShift,
        reference: &Sequence,
        n: usize,
    ) -> Vec<Sequence> {
        (0..n)
            .map(|i| {
                simulate_read(rng, reference, 0, reference.len(), &ErrorProfile::pacbio(), i).seq
            })
            .collect()
    }

    #[test]
    fn training_improves_mean_loglik() {
        let mut rng = XorShift::new(31);
        let reference =
            Sequence::from_symbols("r", testutil::random_seq(&mut rng, 80, 4));
        let mut g = Phmm::error_correction(&reference, &EcDesignParams::default()).unwrap();
        let reads = noisy_reads(&mut rng, &reference, 6);
        let cfg = TrainConfig { max_iters: 4, tol: 1e-9, ..Default::default() };
        let res = train(&mut g, &reads, &cfg).unwrap();
        assert!(res.iters >= 2);
        let h = &res.loglik_history;
        assert!(
            h.last().unwrap() >= h.first().unwrap(),
            "loglik did not improve: {h:?}"
        );
        g.validate().unwrap();
    }

    #[test]
    fn em_monotone_between_iterations() {
        let mut rng = XorShift::new(37);
        let reference =
            Sequence::from_symbols("r", testutil::random_seq(&mut rng, 50, 4));
        let mut g = Phmm::error_correction(&reference, &EcDesignParams::default()).unwrap();
        let reads = noisy_reads(&mut rng, &reference, 4);
        let cfg = TrainConfig { max_iters: 5, tol: 0.0, ..Default::default() };
        let res = train(&mut g, &reads, &cfg).unwrap();
        for pair in res.loglik_history.windows(2) {
            assert!(pair[1] >= pair[0] - 1e-3, "history {:?}", res.loglik_history);
        }
    }

    #[test]
    fn parallel_estep_is_bit_identical_to_sequential() {
        // The deterministic block reduction makes the worker count
        // unobservable: histories and trained parameters match exactly.
        let mut rng = XorShift::new(53);
        let reference =
            Sequence::from_symbols("r", testutil::random_seq(&mut rng, 100, 4));
        let reads = noisy_reads(&mut rng, &reference, 21); // 3 blocks of 8
        for filter in [FilterConfig::None, FilterConfig::histogram_default()] {
            let mut g1 = Phmm::error_correction(&reference, &Default::default()).unwrap();
            let mut g4 = g1.clone();
            let base = TrainConfig { max_iters: 3, tol: 0.0, filter, n_workers: 1 };
            let res1 = train(&mut g1, &reads, &base).unwrap();
            let res4 =
                train(&mut g4, &reads, &TrainConfig { n_workers: 4, ..base }).unwrap();
            assert_eq!(res1.loglik_history, res4.loglik_history, "filter {filter:?}");
            assert_eq!(g1.out_prob, g4.out_prob, "filter {filter:?}");
            assert_eq!(g1.emissions, g4.emissions, "filter {filter:?}");
            assert_eq!(res1.states_processed, res4.states_processed);
            assert_eq!(res1.edges_processed, res4.edges_processed);
            assert_eq!(res1.reads_skipped, res4.reads_skipped);
        }
    }

    #[test]
    fn skipped_reads_are_counted() {
        let mut rng = XorShift::new(59);
        let reference =
            Sequence::from_symbols("r", testutil::random_seq(&mut rng, 40, 4));
        let mut g = Phmm::error_correction(&reference, &EcDesignParams::default()).unwrap();
        let mut reads = noisy_reads(&mut rng, &reference, 3);
        reads.push(Sequence::from_symbols("empty", vec![]));
        reads.push(Sequence::from_symbols("bad", vec![0, 1, 99])); // dead: symbol outside Σ
        let cfg = TrainConfig { max_iters: 2, tol: 0.0, ..Default::default() };
        let res = train(&mut g, &reads, &cfg).unwrap();
        // Two skip events per iteration, two iterations.
        assert_eq!(res.reads_skipped, 2 * res.iters as u64);
        assert_eq!(res.loglik_history.len(), res.iters);
    }

    #[test]
    fn filtered_training_tracks_unfiltered() {
        let mut rng = XorShift::new(41);
        let reference =
            Sequence::from_symbols("r", testutil::random_seq(&mut rng, 120, 4));
        let reads = noisy_reads(&mut rng, &reference, 5);

        let mut g_exact = Phmm::error_correction(&reference, &Default::default()).unwrap();
        let mut g_filt = g_exact.clone();
        let exact = train(
            &mut g_exact,
            &reads,
            &TrainConfig { max_iters: 2, tol: 0.0, filter: FilterConfig::None, n_workers: 1 },
        )
        .unwrap();
        let filt = train(
            &mut g_filt,
            &reads,
            &TrainConfig {
                max_iters: 2,
                tol: 0.0,
                filter: FilterConfig::histogram_default(),
                n_workers: 1,
            },
        )
        .unwrap();
        let a = exact.loglik_history.last().unwrap();
        let b = filt.loglik_history.last().unwrap();
        assert!((a - b).abs() / a.abs() < 0.05, "exact {a} vs filtered {b}");
        assert!(filt.filter_stats.calls > 0);
    }

    #[test]
    fn timing_counters_populated() {
        let mut rng = XorShift::new(43);
        let reference =
            Sequence::from_symbols("r", testutil::random_seq(&mut rng, 60, 4));
        let mut g = Phmm::error_correction(&reference, &Default::default()).unwrap();
        let reads = noisy_reads(&mut rng, &reference, 3);
        let res = train(&mut g, &reads, &TrainConfig::default()).unwrap();
        assert!(res.forward_ns > 0);
        assert!(res.backward_update_ns > 0);
        assert!(res.states_processed > 0);
        assert_eq!(res.reads_skipped, 0);
    }

    #[test]
    fn empty_read_set_is_noop() {
        let mut rng = XorShift::new(47);
        let reference =
            Sequence::from_symbols("r", testutil::random_seq(&mut rng, 30, 4));
        let mut g = Phmm::error_correction(&reference, &Default::default()).unwrap();
        let res = train(&mut g, &[], &TrainConfig::default()).unwrap();
        assert_eq!(res.iters, 0);
        assert!(res.loglik_history.is_empty());
    }
}
