//! The layered training stack: a **corpus layer** ([`super::corpus`])
//! that yields reads, a **schedule layer** ([`TrainMode`]) that decides
//! when the parameters move, and the engine E-step underneath —
//! generic over the [`ExpectationEngine`] backend, with step-level
//! timing instrumentation that feeds Fig. 2 (execution-time breakdown)
//! and the accelerator model.
//!
//! Three schedules share the one engine hot path (ApHMM's memoized
//! kernels are mode-agnostic, §4.2–4.3):
//!
//! * [`TrainMode::Batch`] — classic full-batch EM: every read
//!   contributes to one accumulator, one maximization per iteration.
//!   Bit-identical to the pre-mode trainer.
//! * [`TrainMode::Minibatch`] — stochastic EM (Lam & Meyer; learnMSA):
//!   a seeded shuffle window streams over the corpus, each
//!   length-bucketed minibatch runs an E-step and an immediate
//!   maximization.  Resident memory is bounded by the shuffle window,
//!   never the corpus, so million-sequence files train through the
//!   streaming sources.
//! * [`TrainMode::Viterbi`] — hard-count training: the single best
//!   path per read ([`crate::viterbi::viterbi_path`]) contributes
//!   indicator counts instead of posterior expectations, re-estimated
//!   through the ordinary [`BwAccumulators::apply`] M-step.
//!
//! The E-step is a **parallel batch reduction**: reads are cut into
//! fixed-size blocks, participants drawn from a shared
//! [`WorkerPool`] pull blocks from a shared counter, each block
//! accumulates into its own engine accumulator (with a per-worker
//! scratch and the iteration's shared frozen coefficient tables), and
//! block accumulators are merged **in block order**.  Because the block
//! structure and the merge order are independent of both the requested
//! worker count and the number of pool helpers that actually join,
//! results are bit-identical for any `n_workers` and any pool —
//! `n_workers = 1` is literally the same computation on one thread.
//!
//! Backend selection: [`TrainConfig::engine`] names an [`EngineKind`];
//! [`train`] / [`train_in`] (slices) and [`train_source`] /
//! [`train_source_in`] (streaming corpora) dispatch to the matching
//! engine, and [`train_with_engine`] accepts any [`ExpectationEngine`]
//! instance directly (the coordinator uses this for the device-backed
//! XLA engine).

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;
use std::time::Instant;

use super::banded::BandedEngine;
use super::corpus::{bucket_by_length, epoch_rng, shuffle_window, MemorySource, ReadSource};
use super::engine::{EngineKind, ExpectationEngine, ReadStats, ReferenceEngine, SparseEngine};
use super::filter::{FilterConfig, FilterStats};
use super::lowering::GatherKind;
use super::simd::{SimdPolicy, MAX_STRIPE};
use super::sparse::{ForwardOptions, ScratchMode};
use super::update::BwAccumulators;
use crate::cancel::CancelToken;
use crate::error::{ApHmmError, Result};
use crate::phmm::Phmm;
use crate::pool::WorkerPool;
use crate::seq::Sequence;
use crate::viterbi::viterbi_path;

/// Reads per E-step block.  The unit of the deterministic reduction:
/// results depend on this constant but never on the worker count.
const ESTEP_BLOCK: usize = 8;

/// Largest in-memory corpus [`TrainMode::Auto`] still trains
/// full-batch; anything larger — or of unknown size, i.e. streaming —
/// goes minibatch.
pub const AUTO_MINIBATCH_THRESHOLD: usize = 1024;

/// Shuffle-window factor: the minibatch scheduler keeps at most
/// `minibatch × SHUFFLE_WINDOW_FACTOR` reads resident and permutes
/// within that window (the streaming analogue of a full-corpus
/// shuffle).  [`TrainResult::peak_resident_reads`] reports the bound
/// actually reached.
const SHUFFLE_WINDOW_FACTOR: usize = 8;

/// Training schedule: when the parameters move relative to the E-step.
///
/// Every mode runs behind every [`EngineKind`] — the schedule layer
/// only decides which reads feed which accumulator and when
/// maximization happens; the per-read expectation math is untouched.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum TrainMode {
    /// Full-batch EM.  One accumulator over every read, one
    /// maximization per iteration; bit-identical to the pre-mode
    /// trainer.  Requires the corpus resident (streaming sources are
    /// materialized first).
    Batch,
    /// Stochastic (minibatch) EM: seeded shuffle window over the
    /// corpus, one maximization per length-bucketed minibatch.  Same
    /// seed ⇒ bit-identical run; resident memory bounded by the
    /// shuffle window.
    Minibatch,
    /// Hard-count Viterbi training (Lam & Meyer): each read's single
    /// best path contributes indicator counts, applied once per epoch.
    /// Engine-independent (the DP runs on the graph directly), so it
    /// works behind every [`EngineKind`] including `Xla`.
    Viterbi,
    /// [`Batch`](TrainMode::Batch) for corpora of known size up to
    /// [`AUTO_MINIBATCH_THRESHOLD`], [`Minibatch`](TrainMode::Minibatch)
    /// for larger or streaming (unknown-size) corpora.
    Auto,
}

impl TrainMode {
    pub const NAMES: &'static [&'static str] = &["batch", "minibatch", "viterbi", "auto"];

    pub fn parse(name: &str) -> Option<TrainMode> {
        match name {
            "batch" => Some(TrainMode::Batch),
            "minibatch" => Some(TrainMode::Minibatch),
            "viterbi" => Some(TrainMode::Viterbi),
            "auto" => Some(TrainMode::Auto),
            _ => None,
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            TrainMode::Batch => "batch",
            TrainMode::Minibatch => "minibatch",
            TrainMode::Viterbi => "viterbi",
            TrainMode::Auto => "auto",
        }
    }

    /// Resolve `Auto` against a corpus-size hint (`None` = streaming).
    pub fn resolve(self, n_reads: Option<usize>) -> TrainMode {
        match self {
            TrainMode::Auto => match n_reads {
                Some(n) if n <= AUTO_MINIBATCH_THRESHOLD => TrainMode::Batch,
                _ => TrainMode::Minibatch,
            },
            mode => mode,
        }
    }
}

/// Training configuration.
#[derive(Clone, Copy, Debug)]
pub struct TrainConfig {
    /// Maximum EM iterations (epochs under the minibatch and Viterbi
    /// schedules — one full pass over the corpus each).
    pub max_iters: usize,
    /// Stop when the mean per-read log-likelihood improves less than
    /// this between iterations/epochs.
    pub tol: f64,
    /// State filter used during the forward pass (sparse engines; the
    /// dense engines ignore it).
    pub filter: FilterConfig,
    /// In-window gather kernel policy of the sparse engine (per-row
    /// density-adaptive by default; every kind is bit-identical under
    /// the scalar lane policy).
    pub gather: GatherKind,
    /// SIMD lane-width policy of the sparse engine's dense-tile dot
    /// product.  Deterministic per width; widths differ only within the
    /// pinned reassociation tolerance on tile-dispatched rows.
    pub simd: SimdPolicy,
    /// E-step worker threads (1 = single-threaded).  Any value yields
    /// bit-identical results; see the module docs.
    pub n_workers: usize,
    /// Forward-scratch memory mode (sparse and banded engines):
    /// [`ScratchMode::Full`] materializes every forward row,
    /// [`ScratchMode::Checkpointed`] keeps only every ⌈√T⌉-th row and
    /// recomputes segments during the backward sweep (bit-identical
    /// results, O(√T·states) row memory), [`ScratchMode::Auto`] picks
    /// checkpointing per read when the full matrix would exceed
    /// [`TrainConfig::max_scratch_bytes`].
    pub scratch_mode: ScratchMode,
    /// Forward-scratch budget in bytes consulted by
    /// [`ScratchMode::Auto`]; `0` means unlimited (Auto resolves to
    /// Full).  Ignored under an explicit mode.
    pub max_scratch_bytes: usize,
    /// Compute backend.  [`EngineKind::Xla`] needs a device session and
    /// is only reachable through the coordinator or
    /// [`train_with_engine`]; the other kinds work everywhere.
    pub engine: EngineKind,
    /// Training schedule (see [`TrainMode`]).  The `Batch` default
    /// keeps every existing caller bit-identical to the pre-mode
    /// trainer.
    pub mode: TrainMode,
    /// Reads per minibatch under [`TrainMode::Minibatch`] (also the
    /// streaming window unit of the Viterbi schedule); `0` falls back
    /// to 64.
    pub minibatch: usize,
    /// Seed of the deterministic minibatch shuffler.  Same seed ⇒
    /// bit-identical run; different seeds reshuffle but converge to the
    /// same solution (asserted by the convergence tests).
    pub seed: u64,
}

impl Default for TrainConfig {
    fn default() -> Self {
        TrainConfig {
            max_iters: 3,
            tol: 1e-3,
            filter: FilterConfig::None,
            gather: GatherKind::Adaptive,
            simd: SimdPolicy::Auto,
            n_workers: 1,
            scratch_mode: ScratchMode::Full,
            max_scratch_bytes: 0,
            engine: EngineKind::Sparse,
            mode: TrainMode::Batch,
            minibatch: 64,
            seed: 1,
        }
    }
}

impl TrainConfig {
    /// Effective minibatch size (`minibatch` with the `0` fallback).
    fn minibatch_len(&self) -> usize {
        if self.minibatch == 0 {
            64
        } else {
            self.minibatch
        }
    }
}

/// Training outcome and instrumentation.
#[derive(Clone, Debug, Default)]
pub struct TrainResult {
    /// Mean per-read log-likelihood after each iteration's E step
    /// (per epoch under the minibatch/Viterbi schedules).
    pub loglik_history: Vec<f64>,
    /// Iterations actually run (== `epochs` for the epoch schedules).
    pub iters: usize,
    /// Time in the forward calculation (Fig. 2's "Forward").  Summed
    /// across E-step workers: CPU time, not wall time.  Viterbi
    /// training charges its DP here.
    pub forward_ns: u128,
    /// Time in the fused backward + update pass ("Backward" + "Updates").
    /// Summed across E-step workers.  Viterbi training charges its
    /// count accumulation here.
    pub backward_update_ns: u128,
    /// Time in the maximization division.
    pub maximize_ns: u128,
    /// Filter instrumentation (subset of `forward_ns`).
    pub filter_stats: FilterStats,
    /// Σ over reads/timesteps of active states (accelerator workload).
    pub states_processed: u64,
    /// Σ over reads/timesteps of traversed edges.
    pub edges_processed: u64,
    /// Total timesteps executed (Σ over reads/iterations of read length).
    pub timesteps: u64,
    /// Reads skipped (empty, or numerically dead under the current
    /// parameters), summed over iterations.  Previously these were
    /// dropped silently; the coordinator surfaces them in its metrics.
    pub reads_skipped: u64,
    /// Striped multi-read kernel passes across all iterations (0 when
    /// the engine runs the unstriped path).
    pub stripe_passes: u64,
    /// Reads carried by those passes (`stripe_reads / stripe_passes`
    /// = mean stripe fill out of [`crate::baumwelch::MAX_STRIPE`]).
    pub stripe_reads: u64,
    /// Peak forward-row scratch bytes of any single read across the
    /// run (a high-water mark, merged via `max` — see
    /// [`ReadStats::peak_scratch_bytes`]).
    pub peak_scratch_bytes: u64,
    /// Full passes over the corpus (== `iters` today; kept separate so
    /// partial-epoch schedules can diverge).
    pub epochs: u64,
    /// Maximizations run by the minibatch schedule (0 for batch and
    /// Viterbi).
    pub minibatches: u64,
    /// Reads pulled from the corpus source across all epochs (each
    /// read counts once per epoch; 0 for the slice-based batch path,
    /// which never streams).
    pub sequences_streamed: u64,
    /// High-water mark of reads resident at once in the scheduler.
    /// For streaming minibatch runs this is bounded by the shuffle
    /// window regardless of corpus size — the memory contract the
    /// streaming smoke test pins.
    pub peak_resident_reads: u64,
}

/// Per-block E-step output: one accumulator plus its instrumentation,
/// merged into the iteration totals in block order.
struct BlockOut<A> {
    acc: A,
    stats: ReadStats,
    reads_skipped: u64,
}

/// One block's result slot in the parallel E-step.
type BlockSlot<A> = Mutex<Option<Result<BlockOut<A>>>>;

/// Run one block of reads through forward + fused backward/update.
///
/// `cancel` is checked at each per-read boundary — the accumulate
/// loop's natural chunk boundary.  A fired token aborts the whole
/// block (and with it the whole request) with
/// [`ApHmmError::Cancelled`]; it never skips individual reads, so a
/// training run that completes is bit-identical to an uncancellable
/// one.
fn process_block<E: ExpectationEngine>(
    engine: &E,
    phmm: &Phmm,
    prep: &E::Prepared,
    reads: &[Sequence],
    opts: &ForwardOptions,
    cancel: &CancelToken,
    scratch: &mut E::Scratch,
) -> Result<BlockOut<E::Acc>> {
    // Drain a buffered stripe through the engine's batch entry point.
    // The batch contract is bit-identity with the sequential loop, so
    // buffering never changes the merged sums; per-read errors follow
    // the shared skip rule (Numerical → skipped, anything else fatal).
    fn flush<E: ExpectationEngine>(
        engine: &E,
        phmm: &Phmm,
        prep: &E::Prepared,
        stripe: &mut Vec<&Sequence>,
        opts: &ForwardOptions,
        scratch: &mut E::Scratch,
        out: &mut BlockOut<E::Acc>,
    ) -> Result<()> {
        if stripe.is_empty() {
            return Ok(());
        }
        for res in engine.accumulate_batch(phmm, prep, stripe, opts, scratch, &mut out.acc) {
            match res {
                Ok(stats) => out.stats.merge(&stats),
                // Dead read under the current parameters (e.g. a
                // mis-mapped read whose path probability underflows
                // the filter) — counted, then skipped, matching
                // Apollo.  Everything else (shape mismatches, device
                // failures) is fatal.
                Err(ApHmmError::Numerical(_)) => out.reads_skipped += 1,
                Err(e) => return Err(e),
            }
        }
        stripe.clear();
        Ok(())
    }

    let mut out = BlockOut {
        acc: engine.make_acc(phmm),
        stats: ReadStats::default(),
        reads_skipped: 0,
    };
    // Hand the token to the engine too: the sparse checkpointed sweep
    // re-checks it at segment boundaries, so a multi-hundred-kilobase
    // read cannot pin a worker for the whole backward pass.
    engine.set_cancel(scratch, cancel);
    // Admission stays at the per-read boundary (cancellation,
    // failpoints, empty-skip all observe every read exactly as the
    // pre-batching loop did); admitted reads are buffered into a
    // stripe so the engine can run its multi-read kernel.
    let mut stripe: Vec<&Sequence> = Vec::with_capacity(MAX_STRIPE);
    for read in reads {
        if let Some(cause) = cancel.check() {
            return Err(ApHmmError::Cancelled(cause));
        }
        crate::failpoint!("engine::accumulate");
        if read.is_empty() {
            out.reads_skipped += 1;
            continue;
        }
        stripe.push(read);
        if stripe.len() == MAX_STRIPE {
            flush(engine, phmm, prep, &mut stripe, opts, scratch, &mut out)?;
        }
    }
    flush(engine, phmm, prep, &mut stripe, opts, scratch, &mut out)?;
    Ok(out)
}

/// One E-step over all reads: block-parallel on the shared pool,
/// deterministically reduced.
#[allow(clippy::too_many_arguments)]
fn run_estep<E: ExpectationEngine>(
    engine: &E,
    phmm: &Phmm,
    prep: &E::Prepared,
    reads: &[Sequence],
    opts: &ForwardOptions,
    n_workers: usize,
    pool: &WorkerPool,
    cancel: &CancelToken,
) -> Result<Vec<BlockOut<E::Acc>>> {
    let blocks: Vec<&[Sequence]> = reads.chunks(ESTEP_BLOCK).collect();
    if blocks.is_empty() {
        return Ok(Vec::new());
    }
    let workers = n_workers.max(1).min(blocks.len());
    if workers == 1 {
        let mut scratch = engine.make_scratch(phmm);
        return blocks
            .iter()
            .map(|&block| process_block(engine, phmm, prep, block, opts, cancel, &mut scratch))
            .collect();
    }

    let next = AtomicUsize::new(0);
    let mut slots: Vec<BlockSlot<E::Acc>> = Vec::with_capacity(blocks.len());
    slots.resize_with(blocks.len(), || Mutex::new(None));
    pool.scope(workers, |_slot| {
        let mut scratch = engine.make_scratch(phmm);
        loop {
            let bi = next.fetch_add(1, Ordering::Relaxed);
            if bi >= blocks.len() {
                break;
            }
            let out = process_block(engine, phmm, prep, blocks[bi], opts, cancel, &mut scratch);
            *slots[bi].lock().unwrap() = Some(out);
        }
    });
    // Collect (and propagate the first error) in *block* order
    // (determinism).
    slots
        .into_iter()
        .map(|s| s.into_inner().unwrap().expect("E-step participant dropped a block"))
        .collect()
}

/// Fold one block's instrumentation into the run totals (identical for
/// every schedule; peak scratch merges via `max`).
fn fold_block_stats<A>(result: &mut TrainResult, out: &BlockOut<A>) {
    result.forward_ns += out.stats.forward_ns;
    result.backward_update_ns += out.stats.backward_update_ns;
    result.filter_stats.merge(&out.stats.filter_stats);
    result.states_processed += out.stats.states_processed;
    result.edges_processed += out.stats.edges_processed;
    result.timesteps += out.stats.timesteps;
    result.reads_skipped += out.reads_skipped;
    result.stripe_passes += out.stats.stripe_passes;
    result.stripe_reads += out.stats.stripe_reads;
    result.peak_scratch_bytes = result.peak_scratch_bytes.max(out.stats.peak_scratch_bytes);
}

fn forward_options(cfg: &TrainConfig) -> ForwardOptions {
    ForwardOptions {
        filter: cfg.filter,
        gather: cfg.gather,
        simd: cfg.simd,
        scratch: cfg.scratch_mode,
        max_scratch_bytes: cfg.max_scratch_bytes,
    }
}

/// Train `phmm` on `reads` under the schedule named by `cfg.mode`,
/// using the engine named by `cfg.engine` and the process-wide shared
/// [`WorkerPool`].
///
/// Reads that become numerically dead under the current parameters (e.g.
/// mis-mapped reads whose path probability underflows the filter) are
/// skipped and counted in [`TrainResult::reads_skipped`], matching
/// Apollo's behaviour.  With `cfg.n_workers > 1` the E-step fans out
/// across pool participants; results are bit-identical to
/// `n_workers = 1`.
pub fn train(phmm: &mut Phmm, reads: &[Sequence], cfg: &TrainConfig) -> Result<TrainResult> {
    train_in(phmm, reads, cfg, WorkerPool::global())
}

/// [`train`] drawing E-step parallelism from a caller-owned pool (the
/// coordinator passes its session pool so chunk-level and E-step
/// parallelism share capacity).
pub fn train_in(
    phmm: &mut Phmm,
    reads: &[Sequence],
    cfg: &TrainConfig,
    pool: &WorkerPool,
) -> Result<TrainResult> {
    train_in_with(phmm, reads, cfg, pool, &CancelToken::none())
}

/// [`train_in`] with a cooperative [`CancelToken`], observed at each
/// per-read E-step boundary (see [`train_with_engine_with`]).
pub fn train_in_with(
    phmm: &mut Phmm,
    reads: &[Sequence],
    cfg: &TrainConfig,
    pool: &WorkerPool,
    cancel: &CancelToken,
) -> Result<TrainResult> {
    match cfg.engine {
        EngineKind::Sparse => {
            train_with_engine_with(&SparseEngine, phmm, reads, cfg, pool, cancel)
        }
        EngineKind::Banded => {
            train_with_engine_with(&BandedEngine, phmm, reads, cfg, pool, cancel)
        }
        EngineKind::Reference => {
            train_with_engine_with(&ReferenceEngine, phmm, reads, cfg, pool, cancel)
        }
        EngineKind::Xla => Err(ApHmmError::Config(
            "EngineKind::Xla needs a device session: use the coordinator with artifacts_dir, \
             or call train_with_engine with a coordinator::XlaEngine"
                .into(),
        )),
    }
}

/// Train from a [`ReadSource`] — the streaming entry point.  Under the
/// minibatch and Viterbi schedules the corpus is never materialized;
/// `Batch` (and `Auto` resolving to it) loads the source first, since
/// full-batch EM needs every read each iteration.
pub fn train_source(
    phmm: &mut Phmm,
    source: &mut dyn ReadSource,
    cfg: &TrainConfig,
) -> Result<TrainResult> {
    train_source_in(phmm, source, cfg, WorkerPool::global())
}

/// [`train_source`] drawing E-step parallelism from a caller-owned pool.
pub fn train_source_in(
    phmm: &mut Phmm,
    source: &mut dyn ReadSource,
    cfg: &TrainConfig,
    pool: &WorkerPool,
) -> Result<TrainResult> {
    train_source_in_with(phmm, source, cfg, pool, &CancelToken::none())
}

/// [`train_source_in`] with a cooperative [`CancelToken`].
pub fn train_source_in_with(
    phmm: &mut Phmm,
    source: &mut dyn ReadSource,
    cfg: &TrainConfig,
    pool: &WorkerPool,
    cancel: &CancelToken,
) -> Result<TrainResult> {
    match cfg.engine {
        EngineKind::Sparse => {
            train_source_with_engine_with(&SparseEngine, phmm, source, cfg, pool, cancel)
        }
        EngineKind::Banded => {
            train_source_with_engine_with(&BandedEngine, phmm, source, cfg, pool, cancel)
        }
        EngineKind::Reference => {
            train_source_with_engine_with(&ReferenceEngine, phmm, source, cfg, pool, cancel)
        }
        EngineKind::Xla => Err(ApHmmError::Config(
            "EngineKind::Xla needs a device session: use the coordinator with artifacts_dir, \
             or call train_with_engine with a coordinator::XlaEngine"
                .into(),
        )),
    }
}

/// The schedule dispatcher over any [`ExpectationEngine`] instance and
/// an in-memory read slice.
///
/// `cfg.mode` picks the schedule ([`TrainMode::Auto`] resolves against
/// the slice length); the minibatch and Viterbi schedules run through
/// the same code as the streaming path via a [`MemorySource`] adapter,
/// so slice and source training are one implementation.
pub fn train_with_engine<E: ExpectationEngine>(
    engine: &E,
    phmm: &mut Phmm,
    reads: &[Sequence],
    cfg: &TrainConfig,
    pool: &WorkerPool,
) -> Result<TrainResult> {
    train_with_engine_with(engine, phmm, reads, cfg, pool, &CancelToken::none())
}

/// [`train_with_engine`] with a cooperative [`CancelToken`].  The token
/// is observed at each per-read boundary of the E-step accumulate loop;
/// a fired token aborts the **whole** training run with
/// [`ApHmmError::Cancelled`] — it never perturbs partial sums, so runs
/// that complete are bit-identical to untokened ones.
pub fn train_with_engine_with<E: ExpectationEngine>(
    engine: &E,
    phmm: &mut Phmm,
    reads: &[Sequence],
    cfg: &TrainConfig,
    pool: &WorkerPool,
    cancel: &CancelToken,
) -> Result<TrainResult> {
    match cfg.mode.resolve(Some(reads.len())) {
        TrainMode::Batch => {
            let mut result = train_batch(engine, phmm, reads, cfg, pool, cancel)?;
            result.peak_resident_reads = reads.len() as u64;
            Ok(result)
        }
        TrainMode::Minibatch => {
            let mut source = MemorySource::new(reads);
            train_minibatch(engine, phmm, &mut source, cfg, pool, cancel)
        }
        TrainMode::Viterbi => {
            let mut source = MemorySource::new(reads);
            train_viterbi(phmm, &mut source, cfg, cancel)
        }
        TrainMode::Auto => unreachable!("resolve() never returns Auto"),
    }
}

/// Schedule dispatcher over a [`ReadSource`] (see
/// [`train_with_engine_with`]; `Auto` resolves against the source's
/// [`len_hint`](ReadSource::len_hint)).
pub fn train_source_with_engine_with<E: ExpectationEngine>(
    engine: &E,
    phmm: &mut Phmm,
    source: &mut dyn ReadSource,
    cfg: &TrainConfig,
    pool: &WorkerPool,
    cancel: &CancelToken,
) -> Result<TrainResult> {
    match cfg.mode.resolve(source.len_hint()) {
        TrainMode::Batch => {
            // Full-batch needs every read per iteration: materialize.
            source.reset()?;
            let mut reads: Vec<Sequence> = Vec::new();
            while source.fill(4096, &mut reads)? > 0 {}
            let mut result = train_batch(engine, phmm, &reads, cfg, pool, cancel)?;
            result.sequences_streamed += reads.len() as u64;
            result.peak_resident_reads = reads.len() as u64;
            Ok(result)
        }
        TrainMode::Minibatch => train_minibatch(engine, phmm, source, cfg, pool, cancel),
        TrainMode::Viterbi => train_viterbi(phmm, source, cfg, cancel),
        TrainMode::Auto => unreachable!("resolve() never returns Auto"),
    }
}

/// The full-batch EM loop (the pre-mode trainer, verbatim).
///
/// Per iteration: freeze the parameters into the engine's coefficient
/// tables ([`ExpectationEngine::prepare`], charged to the forward
/// phase it accelerates, paper §4.2–4.3), fan the batch E-step out over
/// `pool`, merge block accumulators in block order, and run the
/// engine's maximization.
fn train_batch<E: ExpectationEngine>(
    engine: &E,
    phmm: &mut Phmm,
    reads: &[Sequence],
    cfg: &TrainConfig,
    pool: &WorkerPool,
    cancel: &CancelToken,
) -> Result<TrainResult> {
    let opts = forward_options(cfg);
    let mut result = TrainResult::default();
    let mut prev_mean = f64::NEG_INFINITY;
    for _iter in 0..cfg.max_iters {
        // Parameters are frozen for the whole E-step: memoize the fused
        // per-symbol coefficient tables once per iteration (§4.2–4.3).
        // The build is charged to the forward phase it accelerates.
        let t0 = Instant::now();
        let prep = engine.prepare(phmm)?;
        result.forward_ns += t0.elapsed().as_nanos();
        let outs = run_estep(engine, phmm, &prep, reads, &opts, cfg.n_workers, pool, cancel)?;
        let mut acc = engine.make_acc(phmm);
        for out in &outs {
            engine.merge(&mut acc, &out.acc);
            fold_block_stats(&mut result, out);
        }
        let (total_loglik, n_observations) = engine.observations(&acc);
        if n_observations == 0 {
            break;
        }
        let mean_ll = total_loglik / n_observations as f64;
        result.loglik_history.push(mean_ll);
        result.iters += 1;
        result.epochs += 1;

        let t2 = Instant::now();
        engine.maximize(phmm, &acc)?;
        result.maximize_ns += t2.elapsed().as_nanos();

        if (mean_ll - prev_mean).abs() < cfg.tol {
            break;
        }
        prev_mean = mean_ll;
    }
    Ok(result)
}

/// The stochastic-EM loop: stream the corpus through a seeded shuffle
/// window, maximize after every length-bucketed minibatch.
///
/// Determinism: the read order is a pure function of `(source order,
/// cfg.seed)` — the window fill is sequential, the shuffle RNG is a
/// per-`(seed, epoch)` xorshift, and minibatch E-steps reuse the
/// deterministic block reduction — so the same seed gives a
/// bit-identical [`TrainResult`] and trained graph for any worker
/// count.  Convergence is judged per epoch on the running mean
/// log-likelihood (each minibatch's log-odds measured under the
/// parameters it started from).
fn train_minibatch<E: ExpectationEngine>(
    engine: &E,
    phmm: &mut Phmm,
    source: &mut dyn ReadSource,
    cfg: &TrainConfig,
    pool: &WorkerPool,
    cancel: &CancelToken,
) -> Result<TrainResult> {
    let opts = forward_options(cfg);
    let mb = cfg.minibatch_len();
    let window = mb.saturating_mul(SHUFFLE_WINDOW_FACTOR);
    let mut result = TrainResult::default();
    let mut prev_mean = f64::NEG_INFINITY;
    let mut buffer: Vec<Sequence> = Vec::with_capacity(window.min(4096));
    for epoch in 0..cfg.max_iters {
        source.reset()?;
        let mut rng = epoch_rng(cfg.seed, epoch);
        let mut epoch_ll = 0.0f64;
        let mut epoch_obs = 0u64;
        loop {
            // Fill the shuffle window — the residency bound: at most
            // `window` reads live at once, whatever the corpus size.
            while buffer.len() < window {
                if source.fill(window - buffer.len(), &mut buffer)? == 0 {
                    break;
                }
            }
            if buffer.is_empty() {
                break;
            }
            result.sequences_streamed += buffer.len() as u64;
            result.peak_resident_reads = result.peak_resident_reads.max(buffer.len() as u64);
            shuffle_window(&mut buffer, &mut rng);
            let mut start = 0;
            while start < buffer.len() {
                let end = (start + mb).min(buffer.len());
                // Longest-first within the minibatch so its MAX_STRIPE
                // blocks carry near-equal-length reads.
                bucket_by_length(&mut buffer[start..end]);
                // E-step + immediate maximization: the parameters move
                // once per minibatch, so the coefficient tables re-freeze
                // per minibatch as well.
                let t0 = Instant::now();
                let prep = engine.prepare(phmm)?;
                result.forward_ns += t0.elapsed().as_nanos();
                let outs = run_estep(
                    engine,
                    phmm,
                    &prep,
                    &buffer[start..end],
                    &opts,
                    cfg.n_workers,
                    pool,
                    cancel,
                )?;
                let mut acc = engine.make_acc(phmm);
                for out in &outs {
                    engine.merge(&mut acc, &out.acc);
                    fold_block_stats(&mut result, out);
                }
                let (ll, n_obs) = engine.observations(&acc);
                if n_obs > 0 {
                    let t2 = Instant::now();
                    engine.maximize(phmm, &acc)?;
                    result.maximize_ns += t2.elapsed().as_nanos();
                    epoch_ll += ll;
                    epoch_obs += n_obs;
                }
                result.minibatches += 1;
                start = end;
            }
            buffer.clear();
        }
        if epoch_obs == 0 {
            break;
        }
        result.epochs += 1;
        result.iters += 1;
        let mean_ll = epoch_ll / epoch_obs as f64;
        result.loglik_history.push(mean_ll);
        if (mean_ll - prev_mean).abs() < cfg.tol {
            break;
        }
        prev_mean = mean_ll;
    }
    Ok(result)
}

/// Fold one decoded path's hard counts into the shared accumulators —
/// the Viterbi-training E-step (indicator counts in place of posterior
/// expectations; Lam & Meyer).  The accumulator shape is exactly the
/// soft E-step's, so the ordinary [`BwAccumulators::apply`] M-step
/// re-estimates from it unchanged.
fn accumulate_viterbi_counts(
    phmm: &Phmm,
    states: &[u32],
    log_prob: f64,
    read: &Sequence,
    acc: &mut BwAccumulators,
) {
    let sigma = phmm.sigma();
    for (t, &state) in states.iter().enumerate() {
        let i = state as usize;
        acc.gamma_den[i] += 1.0;
        acc.e_num[i * sigma + read.data[t] as usize] += 1.0;
    }
    for w in states.windows(2) {
        let (j, to) = (w[0] as usize, w[1]);
        // CSR rows are strictly ascending in target, so the edge is the
        // unique slot with `out_to == to` in row j.
        let lo = phmm.out_ptr[j] as usize;
        let hi = phmm.out_ptr[j + 1] as usize;
        if let Some(k) = phmm.out_to[lo..hi].iter().position(|&t2| t2 == to) {
            acc.xi[lo + k] += 1.0;
            acc.trans_den[j] += 1.0;
        }
    }
    acc.n_observations += 1;
    acc.total_loglik += log_prob;
}

/// The Viterbi-training loop: per epoch, decode every read's best path
/// ([`viterbi_path`] — deterministic, lowest-index tie-break), fold
/// hard counts into one accumulator, and apply the ordinary M-step
/// once.  Engine-independent: the DP runs on the graph directly, so
/// this schedule works behind every [`EngineKind`].
///
/// Reads whose best path dies under the current parameters (including
/// out-of-alphabet symbols) are counted in
/// [`TrainResult::reads_skipped`] — the same skip rule as the soft
/// E-step.  Convergence is judged on the mean best-path log-probability
/// per epoch.
fn train_viterbi(
    phmm: &mut Phmm,
    source: &mut dyn ReadSource,
    cfg: &TrainConfig,
    cancel: &CancelToken,
) -> Result<TrainResult> {
    let window = cfg.minibatch_len().saturating_mul(SHUFFLE_WINDOW_FACTOR);
    let mut result = TrainResult::default();
    let mut prev_mean = f64::NEG_INFINITY;
    let mut buffer: Vec<Sequence> = Vec::with_capacity(window.min(4096));
    for _epoch in 0..cfg.max_iters {
        source.reset()?;
        let mut acc = BwAccumulators::new(phmm);
        loop {
            let got = source.fill(window, &mut buffer)?;
            if buffer.is_empty() {
                break;
            }
            result.sequences_streamed += buffer.len() as u64;
            result.peak_resident_reads = result.peak_resident_reads.max(buffer.len() as u64);
            for read in &buffer {
                if let Some(cause) = cancel.check() {
                    return Err(ApHmmError::Cancelled(cause));
                }
                crate::failpoint!("engine::accumulate");
                if read.is_empty() {
                    result.reads_skipped += 1;
                    continue;
                }
                let t0 = Instant::now();
                let path = match viterbi_path(phmm, read) {
                    Ok(p) => p,
                    Err(ApHmmError::Numerical(_)) => {
                        result.reads_skipped += 1;
                        continue;
                    }
                    Err(e) => return Err(e),
                };
                result.forward_ns += t0.elapsed().as_nanos();
                let t1 = Instant::now();
                accumulate_viterbi_counts(phmm, &path.states, path.log_prob, read, &mut acc);
                result.backward_update_ns += t1.elapsed().as_nanos();
                // DP workload: every state and edge relaxed per timestep.
                let t = read.len() as u64;
                result.timesteps += t;
                result.states_processed += t * phmm.n_states() as u64;
                result.edges_processed +=
                    t.saturating_sub(1) * phmm.n_transitions() as u64;
            }
            buffer.clear();
            if got == 0 {
                break;
            }
        }
        if acc.n_observations == 0 {
            break;
        }
        let mean_ll = acc.total_loglik / acc.n_observations as f64;
        result.loglik_history.push(mean_ll);
        result.iters += 1;
        result.epochs += 1;
        let t2 = Instant::now();
        acc.apply(phmm)?;
        result.maximize_ns += t2.elapsed().as_nanos();
        if (mean_ll - prev_mean).abs() < cfg.tol {
            break;
        }
        prev_mean = mean_ll;
    }
    Ok(result)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::phmm::EcDesignParams;
    use crate::sim::{simulate_read, ErrorProfile, XorShift};
    use crate::testutil;

    fn noisy_reads(
        rng: &mut XorShift,
        reference: &Sequence,
        n: usize,
    ) -> Vec<Sequence> {
        (0..n)
            .map(|i| {
                simulate_read(rng, reference, 0, reference.len(), &ErrorProfile::pacbio(), i).seq
            })
            .collect()
    }

    #[test]
    fn training_improves_mean_loglik() {
        let mut rng = XorShift::new(31);
        let reference =
            Sequence::from_symbols("r", testutil::random_seq(&mut rng, 80, 4));
        let mut g = Phmm::error_correction(&reference, &EcDesignParams::default()).unwrap();
        let reads = noisy_reads(&mut rng, &reference, 6);
        let cfg = TrainConfig { max_iters: 4, tol: 1e-9, ..Default::default() };
        let res = train(&mut g, &reads, &cfg).unwrap();
        assert!(res.iters >= 2);
        let h = &res.loglik_history;
        assert!(
            h.last().unwrap() >= h.first().unwrap(),
            "loglik did not improve: {h:?}"
        );
        g.validate().unwrap();
    }

    #[test]
    fn em_monotone_between_iterations() {
        let mut rng = XorShift::new(37);
        let reference =
            Sequence::from_symbols("r", testutil::random_seq(&mut rng, 50, 4));
        let mut g = Phmm::error_correction(&reference, &EcDesignParams::default()).unwrap();
        let reads = noisy_reads(&mut rng, &reference, 4);
        let cfg = TrainConfig { max_iters: 5, tol: 0.0, ..Default::default() };
        let res = train(&mut g, &reads, &cfg).unwrap();
        for pair in res.loglik_history.windows(2) {
            assert!(pair[1] >= pair[0] - 1e-3, "history {:?}", res.loglik_history);
        }
    }

    #[test]
    fn parallel_estep_is_bit_identical_to_sequential() {
        // The deterministic block reduction makes the worker count
        // unobservable: histories and trained parameters match exactly.
        let mut rng = XorShift::new(53);
        let reference =
            Sequence::from_symbols("r", testutil::random_seq(&mut rng, 100, 4));
        let reads = noisy_reads(&mut rng, &reference, 21); // 3 blocks of 8
        for filter in [FilterConfig::None, FilterConfig::histogram_default()] {
            let mut g1 = Phmm::error_correction(&reference, &Default::default()).unwrap();
            let mut g4 = g1.clone();
            let base =
                TrainConfig { max_iters: 3, tol: 0.0, filter, ..Default::default() };
            let res1 = train(&mut g1, &reads, &base).unwrap();
            let res4 =
                train(&mut g4, &reads, &TrainConfig { n_workers: 4, ..base }).unwrap();
            assert_eq!(res1.loglik_history, res4.loglik_history, "filter {filter:?}");
            assert_eq!(g1.out_prob, g4.out_prob, "filter {filter:?}");
            assert_eq!(g1.emissions, g4.emissions, "filter {filter:?}");
            assert_eq!(res1.states_processed, res4.states_processed);
            assert_eq!(res1.edges_processed, res4.edges_processed);
            assert_eq!(res1.reads_skipped, res4.reads_skipped);
        }
    }

    #[test]
    fn engine_kinds_train_through_the_same_loop() {
        // Every in-process engine kind trains monotonically through the
        // generic loop and leaves a valid graph behind.
        let mut rng = XorShift::new(61);
        let reference =
            Sequence::from_symbols("r", testutil::random_seq(&mut rng, 60, 4));
        let reads = noisy_reads(&mut rng, &reference, 5);
        for engine in [EngineKind::Sparse, EngineKind::Banded, EngineKind::Reference] {
            let mut g = Phmm::error_correction(&reference, &Default::default()).unwrap();
            let cfg = TrainConfig { max_iters: 2, tol: 0.0, engine, ..Default::default() };
            let res = train(&mut g, &reads, &cfg).unwrap();
            assert_eq!(res.iters, 2, "engine {engine:?}");
            assert!(res.forward_ns > 0, "engine {engine:?}");
            assert!(res.backward_update_ns > 0, "engine {engine:?}");
            assert!(res.states_processed > 0, "engine {engine:?}");
            g.validate().unwrap();
        }
    }

    #[test]
    fn xla_kind_without_device_is_a_config_error() {
        let mut rng = XorShift::new(67);
        let reference =
            Sequence::from_symbols("r", testutil::random_seq(&mut rng, 30, 4));
        let mut g = Phmm::error_correction(&reference, &Default::default()).unwrap();
        let reads = noisy_reads(&mut rng, &reference, 2);
        let cfg = TrainConfig { engine: EngineKind::Xla, ..Default::default() };
        assert!(matches!(train(&mut g, &reads, &cfg), Err(ApHmmError::Config(_))));
    }

    #[test]
    fn skipped_reads_are_counted() {
        let mut rng = XorShift::new(59);
        let reference =
            Sequence::from_symbols("r", testutil::random_seq(&mut rng, 40, 4));
        let mut g = Phmm::error_correction(&reference, &EcDesignParams::default()).unwrap();
        let mut reads = noisy_reads(&mut rng, &reference, 3);
        reads.push(Sequence::from_symbols("empty", vec![]));
        reads.push(Sequence::from_symbols("bad", vec![0, 1, 99])); // dead: symbol outside Σ
        let cfg = TrainConfig { max_iters: 2, tol: 0.0, ..Default::default() };
        let res = train(&mut g, &reads, &cfg).unwrap();
        // Two skip events per iteration, two iterations.
        assert_eq!(res.reads_skipped, 2 * res.iters as u64);
        assert_eq!(res.loglik_history.len(), res.iters);
    }

    #[test]
    fn filtered_training_tracks_unfiltered() {
        let mut rng = XorShift::new(41);
        let reference =
            Sequence::from_symbols("r", testutil::random_seq(&mut rng, 120, 4));
        let reads = noisy_reads(&mut rng, &reference, 5);

        let mut g_exact = Phmm::error_correction(&reference, &Default::default()).unwrap();
        let mut g_filt = g_exact.clone();
        let exact = train(
            &mut g_exact,
            &reads,
            &TrainConfig { max_iters: 2, tol: 0.0, ..Default::default() },
        )
        .unwrap();
        let filt = train(
            &mut g_filt,
            &reads,
            &TrainConfig {
                max_iters: 2,
                tol: 0.0,
                filter: FilterConfig::histogram_default(),
                ..Default::default()
            },
        )
        .unwrap();
        let a = exact.loglik_history.last().unwrap();
        let b = filt.loglik_history.last().unwrap();
        assert!((a - b).abs() / a.abs() < 0.05, "exact {a} vs filtered {b}");
        assert!(filt.filter_stats.calls > 0);
    }

    #[test]
    fn timing_counters_populated() {
        let mut rng = XorShift::new(43);
        let reference =
            Sequence::from_symbols("r", testutil::random_seq(&mut rng, 60, 4));
        let mut g = Phmm::error_correction(&reference, &Default::default()).unwrap();
        let reads = noisy_reads(&mut rng, &reference, 3);
        let res = train(&mut g, &reads, &TrainConfig::default()).unwrap();
        assert!(res.forward_ns > 0);
        assert!(res.backward_update_ns > 0);
        assert!(res.states_processed > 0);
        assert_eq!(res.reads_skipped, 0);
    }

    #[test]
    fn empty_read_set_is_noop() {
        let mut rng = XorShift::new(47);
        let reference =
            Sequence::from_symbols("r", testutil::random_seq(&mut rng, 30, 4));
        let mut g = Phmm::error_correction(&reference, &Default::default()).unwrap();
        let res = train(&mut g, &[], &TrainConfig::default()).unwrap();
        assert_eq!(res.iters, 0);
        assert!(res.loglik_history.is_empty());
    }

    #[test]
    fn mode_names_roundtrip() {
        for (i, name) in TrainMode::NAMES.iter().enumerate() {
            let mode = TrainMode::parse(name).unwrap();
            assert_eq!(mode.name(), *name);
            assert_eq!(TrainMode::NAMES[i], mode.name());
        }
        assert!(TrainMode::parse("bogus").is_none());
    }

    #[test]
    fn auto_resolves_by_corpus_size() {
        assert_eq!(TrainMode::Auto.resolve(Some(10)), TrainMode::Batch);
        assert_eq!(
            TrainMode::Auto.resolve(Some(AUTO_MINIBATCH_THRESHOLD + 1)),
            TrainMode::Minibatch
        );
        assert_eq!(TrainMode::Auto.resolve(None), TrainMode::Minibatch);
        // Explicit modes resolve to themselves regardless of size.
        assert_eq!(TrainMode::Viterbi.resolve(Some(1)), TrainMode::Viterbi);
        assert_eq!(TrainMode::Batch.resolve(None), TrainMode::Batch);
    }

    #[test]
    fn batch_default_mode_reports_epoch_counters() {
        let mut rng = XorShift::new(71);
        let reference =
            Sequence::from_symbols("r", testutil::random_seq(&mut rng, 50, 4));
        let mut g = Phmm::error_correction(&reference, &Default::default()).unwrap();
        let reads = noisy_reads(&mut rng, &reference, 4);
        let cfg = TrainConfig { max_iters: 2, tol: 0.0, ..Default::default() };
        let res = train(&mut g, &reads, &cfg).unwrap();
        assert_eq!(res.epochs, res.iters as u64);
        assert_eq!(res.minibatches, 0);
        assert_eq!(res.sequences_streamed, 0, "slice batch never streams");
        assert_eq!(res.peak_resident_reads, reads.len() as u64);
    }

    #[test]
    fn minibatch_mode_trains_and_counts() {
        let mut rng = XorShift::new(73);
        let reference =
            Sequence::from_symbols("r", testutil::random_seq(&mut rng, 60, 4));
        let mut g = Phmm::error_correction(&reference, &Default::default()).unwrap();
        let reads = noisy_reads(&mut rng, &reference, 10);
        let cfg = TrainConfig {
            max_iters: 2,
            tol: 0.0,
            mode: TrainMode::Minibatch,
            minibatch: 4,
            ..Default::default()
        };
        let res = train(&mut g, &reads, &cfg).unwrap();
        assert_eq!(res.epochs, 2);
        // 10 reads / minibatch 4 → 3 minibatches per epoch.
        assert_eq!(res.minibatches, 6);
        assert_eq!(res.sequences_streamed, 20);
        assert_eq!(res.loglik_history.len(), 2);
        g.validate().unwrap();
    }

    #[test]
    fn viterbi_mode_trains_and_skips_dead_reads() {
        let mut rng = XorShift::new(79);
        let reference =
            Sequence::from_symbols("r", testutil::random_seq(&mut rng, 60, 4));
        let mut g = Phmm::error_correction(&reference, &EcDesignParams::default()).unwrap();
        let mut reads = noisy_reads(&mut rng, &reference, 5);
        reads.push(Sequence::from_symbols("empty", vec![]));
        reads.push(Sequence::from_symbols("bad", vec![0, 1, 99]));
        let cfg = TrainConfig {
            max_iters: 2,
            tol: 0.0,
            mode: TrainMode::Viterbi,
            ..Default::default()
        };
        let res = train(&mut g, &reads, &cfg).unwrap();
        assert_eq!(res.epochs, 2);
        assert_eq!(res.reads_skipped, 2 * res.epochs);
        assert_eq!(res.loglik_history.len(), 2);
        assert!(res.timesteps > 0);
        g.validate().unwrap();
    }
}
