//! The EM training loop (expectation over many reads + one maximization
//! per iteration), with step-level timing instrumentation that feeds
//! Fig. 2 (execution-time breakdown) and the accelerator model.

use std::time::Instant;

use super::filter::{FilterConfig, FilterStats};
use super::sparse::{forward_sparse, ForwardOptions};
use super::update::BwAccumulators;
use crate::error::Result;
use crate::phmm::Phmm;
use crate::seq::Sequence;

/// Training configuration.
#[derive(Clone, Copy, Debug)]
pub struct TrainConfig {
    /// Maximum EM iterations.
    pub max_iters: usize,
    /// Stop when the mean per-read log-likelihood improves less than
    /// this between iterations.
    pub tol: f64,
    /// State filter used during the forward pass.
    pub filter: FilterConfig,
}

impl Default for TrainConfig {
    fn default() -> Self {
        TrainConfig { max_iters: 3, tol: 1e-3, filter: FilterConfig::None }
    }
}

/// Training outcome and instrumentation.
#[derive(Clone, Debug)]
pub struct TrainResult {
    /// Mean per-read log-likelihood after each iteration's E step.
    pub loglik_history: Vec<f64>,
    /// Iterations actually run.
    pub iters: usize,
    /// Time in the forward calculation (Fig. 2's "Forward").
    pub forward_ns: u128,
    /// Time in the fused backward + update pass ("Backward" + "Updates").
    pub backward_update_ns: u128,
    /// Time in the maximization division.
    pub maximize_ns: u128,
    /// Filter instrumentation (subset of `forward_ns`).
    pub filter_stats: FilterStats,
    /// Σ over reads/timesteps of active states (accelerator workload).
    pub states_processed: u64,
    /// Σ over reads/timesteps of traversed edges.
    pub edges_processed: u64,
    /// Total timesteps executed (Σ over reads/iterations of read length).
    pub timesteps: u64,
}

/// Train `phmm` on `reads` with batch EM.
///
/// Reads that become numerically dead under the current parameters (e.g.
/// mis-mapped reads whose path probability underflows the filter) are
/// skipped, matching Apollo's behaviour.
pub fn train(phmm: &mut Phmm, reads: &[Sequence], cfg: &TrainConfig) -> Result<TrainResult> {
    let opts = ForwardOptions { filter: cfg.filter };
    let mut result = TrainResult {
        loglik_history: Vec::new(),
        iters: 0,
        forward_ns: 0,
        backward_update_ns: 0,
        maximize_ns: 0,
        filter_stats: FilterStats::default(),
        states_processed: 0,
        edges_processed: 0,
        timesteps: 0,
    };
    let mut acc = BwAccumulators::new(phmm);
    let mut prev_mean = f64::NEG_INFINITY;
    for _iter in 0..cfg.max_iters {
        acc.reset();
        for read in reads {
            if read.is_empty() {
                continue;
            }
            let t0 = Instant::now();
            let fwd = match forward_sparse(phmm, read, &opts) {
                Ok(f) => f,
                Err(_) => continue, // dead read under current parameters
            };
            result.forward_ns += t0.elapsed().as_nanos();
            result.filter_stats.merge(&fwd.filter_stats);
            result.states_processed += fwd.states_processed;
            result.edges_processed += fwd.edges_processed;
            result.timesteps += fwd.rows.len() as u64;

            let t1 = Instant::now();
            acc.accumulate(phmm, read, &fwd)?;
            result.backward_update_ns += t1.elapsed().as_nanos();
        }
        if acc.n_observations == 0 {
            break;
        }
        let mean_ll = acc.total_loglik / acc.n_observations as f64;
        result.loglik_history.push(mean_ll);
        result.iters += 1;

        let t2 = Instant::now();
        acc.apply(phmm)?;
        result.maximize_ns += t2.elapsed().as_nanos();

        if (mean_ll - prev_mean).abs() < cfg.tol {
            break;
        }
        prev_mean = mean_ll;
    }
    Ok(result)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::phmm::EcDesignParams;
    use crate::sim::{simulate_read, ErrorProfile, XorShift};
    use crate::testutil;

    fn noisy_reads(
        rng: &mut XorShift,
        reference: &Sequence,
        n: usize,
    ) -> Vec<Sequence> {
        (0..n)
            .map(|i| {
                simulate_read(rng, reference, 0, reference.len(), &ErrorProfile::pacbio(), i).seq
            })
            .collect()
    }

    #[test]
    fn training_improves_mean_loglik() {
        let mut rng = XorShift::new(31);
        let reference =
            Sequence::from_symbols("r", testutil::random_seq(&mut rng, 80, 4));
        let mut g = Phmm::error_correction(&reference, &EcDesignParams::default()).unwrap();
        let reads = noisy_reads(&mut rng, &reference, 6);
        let cfg = TrainConfig { max_iters: 4, tol: 1e-9, ..Default::default() };
        let res = train(&mut g, &reads, &cfg).unwrap();
        assert!(res.iters >= 2);
        let h = &res.loglik_history;
        assert!(
            h.last().unwrap() >= h.first().unwrap(),
            "loglik did not improve: {h:?}"
        );
        g.validate().unwrap();
    }

    #[test]
    fn em_monotone_between_iterations() {
        let mut rng = XorShift::new(37);
        let reference =
            Sequence::from_symbols("r", testutil::random_seq(&mut rng, 50, 4));
        let mut g = Phmm::error_correction(&reference, &EcDesignParams::default()).unwrap();
        let reads = noisy_reads(&mut rng, &reference, 4);
        let cfg = TrainConfig { max_iters: 5, tol: 0.0, ..Default::default() };
        let res = train(&mut g, &reads, &cfg).unwrap();
        for pair in res.loglik_history.windows(2) {
            assert!(pair[1] >= pair[0] - 1e-3, "history {:?}", res.loglik_history);
        }
    }

    #[test]
    fn filtered_training_tracks_unfiltered() {
        let mut rng = XorShift::new(41);
        let reference =
            Sequence::from_symbols("r", testutil::random_seq(&mut rng, 120, 4));
        let reads = noisy_reads(&mut rng, &reference, 5);

        let mut g_exact = Phmm::error_correction(&reference, &Default::default()).unwrap();
        let mut g_filt = g_exact.clone();
        let exact = train(
            &mut g_exact,
            &reads,
            &TrainConfig { max_iters: 2, tol: 0.0, filter: FilterConfig::None },
        )
        .unwrap();
        let filt = train(
            &mut g_filt,
            &reads,
            &TrainConfig { max_iters: 2, tol: 0.0, filter: FilterConfig::histogram_default() },
        )
        .unwrap();
        let a = exact.loglik_history.last().unwrap();
        let b = filt.loglik_history.last().unwrap();
        assert!((a - b).abs() / a.abs() < 0.05, "exact {a} vs filtered {b}");
        assert!(filt.filter_stats.calls > 0);
    }

    #[test]
    fn timing_counters_populated() {
        let mut rng = XorShift::new(43);
        let reference =
            Sequence::from_symbols("r", testutil::random_seq(&mut rng, 60, 4));
        let mut g = Phmm::error_correction(&reference, &Default::default()).unwrap();
        let reads = noisy_reads(&mut rng, &reference, 3);
        let res = train(&mut g, &reads, &TrainConfig::default()).unwrap();
        assert!(res.forward_ns > 0);
        assert!(res.backward_update_ns > 0);
        assert!(res.states_processed > 0);
    }

    #[test]
    fn empty_read_set_is_noop() {
        let mut rng = XorShift::new(47);
        let reference =
            Sequence::from_symbols("r", testutil::random_seq(&mut rng, 30, 4));
        let mut g = Phmm::error_correction(&reference, &Default::default()).unwrap();
        let res = train(&mut g, &[], &TrainConfig::default()).unwrap();
        assert_eq!(res.iters, 0);
        assert!(res.loglik_history.is_empty());
    }
}
