//! State filtering (§3.1 Observation 4, §4.2 Histogram Filter).
//!
//! The Baum-Welch state space can grow at every timestep (each state has
//! several successors), so implementations keep the best-*n* states per
//! timestep.  The software baseline sorts by forward value (cost ≈ 8.5 %
//! of training per the paper); ApHMM replaces the sort with a histogram:
//! bins are admitted whole, from the best-value bin down, until the
//! filter size is reached.  The histogram therefore always selects a
//! *superset* of the sort filter's states (bin-granular), which is the
//! paper's accuracy-preservation argument — verified as a property test
//! here.  One deliberate deviation (DESIGN.md §Numerics): we bin on the
//! float *exponent* relative to the row max rather than the paper's 16
//! linear bins over [0,1], because scaled rows are normalized to sum 1
//! and linear absolute bins stop discriminating; exponent comparators
//! are at least as cheap in hardware.

use std::time::Instant;

use crate::error::{ApHmmError, Result};

/// Filtering policy for the sparse engine.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum FilterConfig {
    /// Keep every reached state (exact).
    None,
    /// Sort by scaled forward value, keep the top `size` (software).
    Sort {
        /// Number of states kept.
        size: usize,
    },
    /// ApHMM's histogram filter: admit whole bins from the top until
    /// `size` states are covered.  Bins are *exponent bins* relative to
    /// the row maximum (see [`HistogramFilter::select`]): the paper's 16
    /// linear bins over [0,1] collapse once scaled rows are normalized
    /// to sum 1, so we bin on the float exponent instead — the same
    /// sort-free base-and-offset hardware, keyed on exponent bits.
    Histogram {
        /// Target number of states (bin-granular overshoot allowed).
        size: usize,
        /// Number of exponent bins (128 covers 2^-128 relative value;
        /// one 8-bit counter per bin in hardware).
        bins: usize,
    },
}

impl FilterConfig {
    /// Default hardware configuration: 500 states (the paper's Fig. 3
    /// operating point), 128 exponent bins.
    pub fn histogram_default() -> Self {
        FilterConfig::Histogram { size: 500, bins: 128 }
    }

    /// Reject configurations that cannot mean anything: `size == 0`
    /// (an empty keep-set would kill every forward path — disabling
    /// filtering is spelled `FilterConfig::None`) and `bins == 0`.
    /// Config parsing calls this so a bad `filter_size` is a clean
    /// config error; the filters themselves additionally clamp
    /// defensively (see [`SortFilter::select`]).
    pub fn validate(&self) -> Result<()> {
        match *self {
            FilterConfig::Sort { size: 0 } | FilterConfig::Histogram { size: 0, .. } => {
                Err(ApHmmError::Config(
                    "filter_size must be >= 1 (an empty keep-set would kill every \
                     forward path; use filter = \"none\" to disable filtering)"
                        .into(),
                ))
            }
            FilterConfig::Histogram { bins: 0, .. } => {
                Err(ApHmmError::Config("filter_bins must be >= 1".into()))
            }
            _ => Ok(()),
        }
    }
}

/// Cumulative filtering statistics (instrumentation for Fig. 2/6b),
/// plus the per-row gather-kernel dispatch counters of the
/// density-adaptive hot path (`baumwelch::lowering`): how the filter
/// thins each window decides which kernel executes it, so the two
/// instruments travel together.
#[derive(Clone, Copy, Debug, Default)]
pub struct FilterStats {
    /// Total wall time spent inside filter selection.
    pub time_ns: u128,
    /// Number of filter invocations.
    pub calls: u64,
    /// Total states presented to the filter.
    pub states_in: u64,
    /// Total states admitted.
    pub states_out: u64,
    /// Forward rows executed by the indexed CSR gather.
    pub rows_csr: u64,
    /// Forward rows executed by the dense-tile kernel (the window was
    /// dense enough, or `GatherKind::DenseTile` forced it).
    pub rows_dense_tile: u64,
}

impl FilterStats {
    /// Merge another stats block into this one.
    pub fn merge(&mut self, other: &FilterStats) {
        self.time_ns += other.time_ns;
        self.calls += other.calls;
        self.states_in += other.states_in;
        self.states_out += other.states_out;
        self.rows_csr += other.rows_csr;
        self.rows_dense_tile += other.rows_dense_tile;
    }
}

/// Sort-based best-n selection (the software baseline).
pub struct SortFilter;

impl SortFilter {
    /// Truncate `(idx, val)` pairs to the `keep` largest values.
    /// Uses an O(m) partial selection (`select_nth_unstable`) rather than
    /// a full sort; ties at the cut are broken arbitrarily, matching the
    /// semantics of Apollo's best-n heap.
    ///
    /// `keep == 0` is clamped to 1: an empty keep-set would kill every
    /// forward path (and `keep - 1` below would underflow).
    /// [`FilterConfig::validate`] rejects `size == 0` at config parse,
    /// so the clamp is defense-in-depth for direct callers.
    pub fn select(idx: &mut Vec<u32>, val: &mut Vec<f32>, keep: usize, stats: &mut FilterStats) {
        let t0 = Instant::now();
        stats.calls += 1;
        stats.states_in += idx.len() as u64;
        let keep = keep.max(1);
        if idx.len() > keep {
            let mut pairs: Vec<(f32, u32)> =
                val.iter().copied().zip(idx.iter().copied()).collect();
            pairs.select_nth_unstable_by(keep - 1, |a, b| {
                b.0.partial_cmp(&a.0).unwrap_or(std::cmp::Ordering::Equal)
            });
            pairs.truncate(keep);
            pairs.sort_unstable_by_key(|&(_, i)| i);
            idx.clear();
            val.clear();
            for (v, i) in pairs {
                idx.push(i);
                val.push(v);
            }
        }
        stats.states_out += idx.len() as u64;
        stats.time_ns += t0.elapsed().as_nanos();
    }
}

/// ApHMM's histogram filter (§4.2).
pub struct HistogramFilter {
    bins: usize,
    counts: Vec<u32>,
}

impl HistogramFilter {
    /// Build a filter with `bins` bins over [0, 1].
    pub fn new(bins: usize) -> Self {
        HistogramFilter { bins: bins.max(1), counts: vec![0; bins.max(1)] }
    }

    /// Bin index of value `v` relative to the row maximum: bin 0 holds
    /// values within 2× of the max, bin k values within 2^(k+1)×.
    ///
    /// This is *exponent binning* — the bin is the difference of the
    /// float exponent fields, which in hardware is a subtract of the
    /// exponent bits (cheaper than the linear-range comparators of a
    /// fixed [0,1] histogram, and unlike them it stays discriminative
    /// when scaled rows sum to 1 and all absolute values are tiny).
    #[inline]
    fn bin_of(&self, v: f32, vmax_bits: u32) -> usize {
        let exp_diff = (vmax_bits >> 23).saturating_sub(v.to_bits() >> 23) as usize;
        exp_diff.min(self.bins - 1)
    }

    /// Admit whole bins from the top down until `keep` states are
    /// covered; returns the *value threshold* (lower edge of the last
    /// admitted bin).  States below the threshold are discarded in one
    /// linear pass — no sorting, the base-and-offset addressing of the
    /// hardware design degenerates to this threshold compare in software.
    ///
    /// `keep == 0` is clamped to 1 (same defensive semantics as
    /// [`SortFilter::select`]); bin granularity then admits the whole
    /// top bin.  A dead row (all values zero, `vmax == 0.0`) is left
    /// untouched: there is nothing to rank, and truncating arbitrarily
    /// would mask the numerical failure the caller is about to report.
    pub fn select(
        &mut self,
        idx: &mut Vec<u32>,
        val: &mut Vec<f32>,
        keep: usize,
        stats: &mut FilterStats,
    ) {
        let t0 = Instant::now();
        stats.calls += 1;
        stats.states_in += idx.len() as u64;
        let keep = keep.max(1);
        if idx.len() > keep {
            let vmax = val.iter().copied().fold(0.0f32, f32::max);
            if vmax > 0.0 {
                let vmax_bits = vmax.to_bits();
                self.counts.iter_mut().for_each(|c| *c = 0);
                for &v in val.iter() {
                    let b = self.bin_of(v, vmax_bits);
                    self.counts[b] += 1;
                }
                // Accumulate from the bin holding the largest values
                // (bin 0 in exponent order) downwards.
                let mut cum = 0u32;
                let mut cutoff_bin = self.bins - 1;
                for (b, &c) in self.counts.iter().enumerate() {
                    cum += c;
                    if cum as usize >= keep {
                        cutoff_bin = b;
                        break;
                    }
                }
                let mut out = 0usize;
                for i in 0..idx.len() {
                    if self.bin_of(val[i], vmax_bits) <= cutoff_bin {
                        idx[out] = idx[i];
                        val[out] = val[i];
                        out += 1;
                    }
                }
                idx.truncate(out);
                val.truncate(out);
            }
        }
        stats.states_out += idx.len() as u64;
        stats.time_ns += t0.elapsed().as_nanos();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::XorShift;
    use crate::testutil;

    fn random_case(rng: &mut XorShift, n: usize) -> (Vec<u32>, Vec<f32>) {
        let idx: Vec<u32> = (0..n as u32).collect();
        // Like real scaled forward rows: values sum to 1 (so absolute
        // magnitudes shrink with n — the case the max-relative binning
        // exists for), with a heavy-ish tail.
        let mut val: Vec<f32> = (0..n).map(|_| rng.next_f32().powi(3) + 1e-6).collect();
        let s: f32 = val.iter().sum();
        val.iter_mut().for_each(|v| *v /= s);
        (idx, val)
    }

    #[test]
    fn sort_filter_keeps_exact_top_n() {
        testutil::check(50, |rng| {
            let n = rng.range(1, 400);
            let keep = rng.range(1, 200);
            let (mut idx, mut val) = random_case(rng, n);
            let mut sorted: Vec<f32> = val.clone();
            sorted.sort_by(|a, b| b.partial_cmp(a).unwrap());
            let mut stats = FilterStats::default();
            SortFilter::select(&mut idx, &mut val, keep, &mut stats);
            assert_eq!(idx.len(), n.min(keep));
            // The kept minimum equals the n-th largest overall.
            if n > keep {
                let kept_min = val.iter().cloned().fold(f32::MAX, f32::min);
                assert!((kept_min - sorted[keep - 1]).abs() < 1e-6);
            }
        });
    }

    #[test]
    fn histogram_superset_of_sort_property() {
        // Paper §4.2: "The Histogram Filter can find all the
        // non-negligible states that a filtering technique with a sorting
        // mechanism finds" — i.e. histogram keep-set ⊇ sort keep-set
        // modulo value ties at the cut.
        testutil::check(100, |rng| {
            let n = rng.range(2, 600);
            let keep = rng.range(1, 400);
            let (idx, val) = random_case(rng, n);
            let mut s_idx = idx.clone();
            let mut s_val = val.clone();
            let mut stats = FilterStats::default();
            SortFilter::select(&mut s_idx, &mut s_val, keep, &mut stats);
            let sort_min = s_val.iter().cloned().fold(f32::MAX, f32::min);

            let mut h_idx = idx.clone();
            let mut h_val = val.clone();
            let mut hf = HistogramFilter::new(128);
            hf.select(&mut h_idx, &mut h_val, keep, &mut stats);
            let h_set: std::collections::HashSet<u32> = h_idx.iter().copied().collect();
            for (&i, &v) in s_idx.iter().zip(s_val.iter()) {
                // States strictly above the sort cut must be admitted.
                if v > sort_min {
                    assert!(h_set.contains(&i), "histogram dropped state {i} with value {v}");
                }
            }
            assert!(h_idx.len() >= s_idx.len().min(keep));
        });
    }

    #[test]
    fn histogram_overshoot_is_bin_granular() {
        // All values in one bin -> the whole bin is admitted.
        let mut idx: Vec<u32> = (0..100).collect();
        let mut val = vec![0.5f32; 100];
        let mut hf = HistogramFilter::new(128);
        let mut stats = FilterStats::default();
        hf.select(&mut idx, &mut val, 10, &mut stats);
        assert_eq!(idx.len(), 100);
    }

    #[test]
    fn no_filtering_below_capacity() {
        let mut idx: Vec<u32> = (0..5).collect();
        let mut val = vec![0.1, 0.9, 0.3, 0.2, 0.5];
        let mut stats = FilterStats::default();
        SortFilter::select(&mut idx, &mut val, 10, &mut stats);
        assert_eq!(idx.len(), 5);
        let mut hf = HistogramFilter::new(128);
        hf.select(&mut idx, &mut val, 10, &mut stats);
        assert_eq!(idx.len(), 5);
    }

    #[test]
    fn sort_filter_output_sorted_by_index() {
        let mut idx: Vec<u32> = vec![5, 1, 9, 3, 7];
        let mut val = vec![0.9, 0.8, 0.7, 0.6, 0.5];
        let mut stats = FilterStats::default();
        SortFilter::select(&mut idx, &mut val, 3, &mut stats);
        let mut sorted = idx.clone();
        sorted.sort_unstable();
        assert_eq!(idx, sorted);
    }

    #[test]
    fn keep_zero_is_clamped_not_a_panic() {
        // Regression: `keep - 1` underflowed in SortFilter::select, so
        // `filter_size = 0` in a config crashed a whole training run.
        // The clamp keeps the single best state; the histogram keeps
        // (at least) the whole top bin.
        let mut idx: Vec<u32> = (0..20).collect();
        let mut val: Vec<f32> = (0..20).map(|i| (i as f32 + 1.0) / 20.0).collect();
        let mut stats = FilterStats::default();
        SortFilter::select(&mut idx, &mut val, 0, &mut stats);
        assert_eq!(idx.len(), 1);
        assert_eq!(idx[0], 19, "the clamp must keep the best state");

        let mut idx: Vec<u32> = (0..20).collect();
        let mut val: Vec<f32> = (0..20).map(|i| (i as f32 + 1.0) / 20.0).collect();
        let mut hf = HistogramFilter::new(128);
        hf.select(&mut idx, &mut val, 0, &mut stats);
        assert!(!idx.is_empty(), "histogram must keep at least the top bin");
        assert!(idx.contains(&19));
    }

    #[test]
    fn keep_at_or_above_n_is_a_no_op() {
        for keep in [5usize, 6, 1000] {
            let mut idx: Vec<u32> = (0..5).collect();
            let mut val = vec![0.1, 0.9, 0.3, 0.2, 0.5];
            let mut stats = FilterStats::default();
            SortFilter::select(&mut idx, &mut val, keep, &mut stats);
            assert_eq!(idx.len(), 5, "keep = {keep}");
            let mut hf = HistogramFilter::new(128);
            hf.select(&mut idx, &mut val, keep, &mut stats);
            assert_eq!(idx.len(), 5, "keep = {keep}");
        }
    }

    #[test]
    fn dead_rows_pass_through_the_histogram_unfiltered() {
        // Pinned behavior: when every value is zero (`vmax == 0.0`) the
        // histogram filter deliberately skips selection — a dead row is
        // a numerical failure the forward pass reports itself
        // (`ApHmmError::Numerical`), and truncating it arbitrarily here
        // would mask which states died.
        let mut idx: Vec<u32> = (0..100).collect();
        let mut val = vec![0.0f32; 100];
        let mut hf = HistogramFilter::new(128);
        let mut stats = FilterStats::default();
        hf.select(&mut idx, &mut val, 10, &mut stats);
        assert_eq!(idx.len(), 100, "dead rows must not be truncated");
        assert_eq!(stats.states_out, 100);
        // The sort filter has no vmax gate: it truncates ties
        // arbitrarily, which is also fine — every kept state is as
        // (non-)alive as every dropped one.
        let mut idx: Vec<u32> = (0..100).collect();
        let mut val = vec![0.0f32; 100];
        SortFilter::select(&mut idx, &mut val, 10, &mut stats);
        assert_eq!(idx.len(), 10);
    }

    #[test]
    fn validate_rejects_zero_sizes() {
        assert!(FilterConfig::Sort { size: 0 }.validate().is_err());
        assert!(FilterConfig::Histogram { size: 0, bins: 128 }.validate().is_err());
        assert!(FilterConfig::Histogram { size: 500, bins: 0 }.validate().is_err());
        assert!(FilterConfig::None.validate().is_ok());
        assert!(FilterConfig::Sort { size: 1 }.validate().is_ok());
        assert!(FilterConfig::histogram_default().validate().is_ok());
    }

    #[test]
    fn stats_accumulate() {
        let mut stats = FilterStats::default();
        for _ in 0..3 {
            let mut idx: Vec<u32> = (0..50).collect();
            let mut val = vec![0.5; 50];
            SortFilter::select(&mut idx, &mut val, 10, &mut stats);
        }
        assert_eq!(stats.calls, 3);
        assert_eq!(stats.states_in, 150);
        assert_eq!(stats.states_out, 30);
    }
}
