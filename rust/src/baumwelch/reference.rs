//! Pre-memoization reference kernels.
//!
//! Byte-for-byte the engine as it existed *before* the per-symbol
//! fused-coefficient memoization of [`super::kernels`]: the forward
//! pass rebuilds the incoming CSR per call and multiplies the target
//! emission per state per timestep; the fused backward pass re-gathers
//! `α_ij · e_s(to)` on every edge of every timestep.
//!
//! Kept for two purposes:
//! * the parity property tests (`tests/kernel_parity.rs`) pin the
//!   memoized kernels to this baseline within tight tolerances;
//! * the `hotpath` bench measures the memoization speedup against it
//!   (the acceptance metric of the optimization).
//!
//! Not used by any production path.

use super::filter::{FilterConfig, FilterStats, HistogramFilter, SortFilter};
use super::sparse::{ForwardOptions, ForwardResult, SparseRow};
use super::update::BwAccumulators;
use super::EPS;
use crate::error::{ApHmmError, Result};
use crate::phmm::Phmm;
use crate::seq::Sequence;

/// Per-call scratch of the reference forward (rebuilt every call, as the
/// pre-memoization engine did).
struct RefScratch {
    dense: Vec<f32>,
    in_ptr: Vec<u32>,
    in_from: Vec<u32>,
    in_prob: Vec<f32>,
}

impl RefScratch {
    fn new(phmm: &Phmm) -> Self {
        let (in_ptr, in_from, in_eidx) = phmm.incoming_csr();
        let in_prob = in_eidx.iter().map(|&e| phmm.out_prob[e as usize]).collect();
        RefScratch { dense: vec![0.0; phmm.n_states()], in_ptr, in_from, in_prob }
    }
}

fn apply_filter(
    cfg: &FilterConfig,
    hist: &mut Option<HistogramFilter>,
    idx: &mut Vec<u32>,
    val: &mut Vec<f32>,
    stats: &mut FilterStats,
) {
    match cfg {
        FilterConfig::None => {}
        FilterConfig::Sort { size } => SortFilter::select(idx, val, *size, stats),
        FilterConfig::Histogram { size, .. } => {
            hist.as_mut().unwrap().select(idx, val, *size, stats)
        }
    }
}

/// The pre-memoization scaled, filtered forward pass.
pub fn forward_sparse_reference(
    phmm: &Phmm,
    seq: &Sequence,
    opts: &ForwardOptions,
) -> Result<ForwardResult> {
    if phmm.has_silent_states() {
        return Err(ApHmmError::InvalidGraph("forward_sparse requires an emitting graph".into()));
    }
    if seq.is_empty() {
        return Err(ApHmmError::Numerical("empty observation sequence".into()));
    }
    // Guard the unchecked emission read below (the one behavioral
    // addition over the historical kernel: out-of-alphabet symbols were
    // UB, now an error — the memoized path rejects them identically).
    if seq.data.iter().any(|&s| s as usize >= phmm.sigma()) {
        return Err(ApHmmError::Numerical(format!(
            "sequence {:?} contains a symbol outside the {}-letter alphabet",
            seq.id,
            phmm.sigma()
        )));
    }
    let n = phmm.n_states();
    let t_len = seq.len();
    let mut scratch = RefScratch::new(phmm);
    let mut hist = match opts.filter {
        FilterConfig::Histogram { bins, .. } => Some(HistogramFilter::new(bins)),
        _ => None,
    };
    let mut stats = FilterStats::default();
    let mut rows: Vec<SparseRow> = Vec::with_capacity(t_len);
    let mut scales: Vec<f32> = Vec::with_capacity(t_len);
    let mut loglik = 0.0f64;
    let mut states_processed = 0u64;
    let mut edges_processed = 0u64;

    // t = 0: initial distribution times emission.
    {
        let s0 = seq.data[0];
        let mut idx = Vec::new();
        let mut val = Vec::new();
        for (i, &p) in phmm.f_init.iter().enumerate() {
            if p > 0.0 {
                let v = p * phmm.emission(i, s0);
                if v > 0.0 {
                    idx.push(i as u32);
                    val.push(v);
                }
            }
        }
        let c: f32 = val.iter().sum();
        if c <= 0.0 {
            return Err(ApHmmError::Numerical("dead start: no state emits first char".into()));
        }
        val.iter_mut().for_each(|v| *v /= c);
        apply_filter(&opts.filter, &mut hist, &mut idx, &mut val, &mut stats);
        states_processed += idx.len() as u64;
        scales.push(c);
        loglik += (c as f64).ln();
        rows.push(SparseRow { idx, val });
    }

    let band = phmm.band_width();
    let sigma = phmm.sigma();
    for t in 1..t_len {
        let s_t = seq.data[t] as usize;
        let prev = rows.last().unwrap();
        for (&i, &v) in prev.idx.iter().zip(prev.val.iter()) {
            scratch.dense[i as usize] = v;
        }
        let win_lo = prev.idx.first().map(|&i| i as usize).unwrap_or(0);
        let win_hi = prev.idx.last().map(|&i| i as usize + band).unwrap_or(0).min(n);
        let mut idx = Vec::with_capacity(win_hi - win_lo);
        let mut val = Vec::with_capacity(win_hi - win_lo);
        let mut c = 0.0f32;
        // SAFETY: incoming-CSR invariants mirror the outgoing CSR
        // (built by incoming_csr from a validated graph); window bounds
        // are clamped to n.
        unsafe {
            for to in win_lo..win_hi {
                let lo = *scratch.in_ptr.get_unchecked(to) as usize;
                let hi = *scratch.in_ptr.get_unchecked(to + 1) as usize;
                let mut acc = 0.0f32;
                for e in lo..hi {
                    let from = *scratch.in_from.get_unchecked(e) as usize;
                    acc += scratch.dense.get_unchecked(from) * scratch.in_prob.get_unchecked(e);
                }
                edges_processed += (hi - lo) as u64;
                if acc > 0.0 {
                    let v = acc * phmm.emissions.get_unchecked(to * sigma + s_t);
                    if v > 0.0 {
                        idx.push(to as u32);
                        val.push(v);
                        c += v;
                    }
                }
            }
        }
        for &i in prev.idx.iter() {
            scratch.dense[i as usize] = 0.0;
        }
        if c <= EPS {
            return Err(ApHmmError::Numerical(format!("forward died at t={t}")));
        }
        let inv = 1.0 / c;
        val.iter_mut().for_each(|v| *v *= inv);
        apply_filter(&opts.filter, &mut hist, &mut idx, &mut val, &mut stats);
        states_processed += idx.len() as u64;
        scales.push(c);
        loglik += (c as f64).ln();
        rows.push(SparseRow { idx, val });
    }

    Ok(ForwardResult { rows, scales, loglik, filter_stats: stats, states_processed, edges_processed })
}

/// The pre-memoization fused backward + accumulate pass (per-edge
/// `α · e · B̂ / c` recomputed from the parameter arrays every timestep).
pub fn accumulate_reference(
    acc: &mut BwAccumulators,
    phmm: &Phmm,
    seq: &Sequence,
    fwd: &ForwardResult,
) -> Result<()> {
    let n = phmm.n_states();
    let t_len = seq.len();
    debug_assert_eq!(fwd.rows.len(), t_len);
    let sigma = phmm.sigma();
    let mut b_next = vec![0.0f64; n];
    let mut b_cur = vec![0.0f64; n];

    {
        let row = &fwd.rows[t_len - 1];
        let s_t = seq.data[t_len - 1] as usize;
        for (&i, &f) in row.idx.iter().zip(row.val.iter()) {
            b_next[i as usize] = 1.0;
            let gamma = f as f64;
            acc.gamma_den[i as usize] += gamma;
            acc.e_num[i as usize * sigma + s_t] += gamma;
        }
    }

    for t in (0..t_len - 1).rev() {
        let row = &fwd.rows[t];
        let s_next = seq.data[t + 1];
        let s_t = seq.data[t] as usize;
        let c_next = fwd.scales[t + 1] as f64;
        let inv_c = 1.0 / c_next;
        for (&j, &fj) in row.idx.iter().zip(row.val.iter()) {
            let j = j as usize;
            let fj = fj as f64;
            let lo = phmm.out_ptr[j] as usize;
            let hi = phmm.out_ptr[j + 1] as usize;
            let mut bsum = 0.0f64;
            for e in lo..hi {
                let to = phmm.out_to[e] as usize;
                let bn = b_next[to];
                if bn == 0.0 {
                    continue;
                }
                let m = phmm.out_prob[e] as f64 * phmm.emission(to, s_next) as f64 * bn * inv_c;
                bsum += m;
                acc.xi[e] += fj * m;
            }
            b_cur[j] = bsum;
            let gamma = fj * bsum;
            acc.trans_den[j] += gamma;
            acc.gamma_den[j] += gamma;
            acc.e_num[j * sigma + s_t] += gamma;
        }
        if t + 1 < t_len {
            for &i in &fwd.rows[t + 1].idx {
                b_next[i as usize] = 0.0;
            }
        }
        std::mem::swap(&mut b_next, &mut b_cur);
    }
    acc.note_observation(fwd.loglik);
    Ok(())
}
