//! FASTA reading and writing.
//!
//! Two entry styles share one parser: the slurping readers
//! ([`read_fasta`] / [`read_fasta_str`]) materialize every record, and
//! the streaming [`FastaReader`] yields one record at a time over any
//! `BufRead`, so a million-sequence file never lives in memory at once
//! (the corpus layer's `FastaSource` wraps it for minibatch training).

use std::io::{BufRead, BufReader, Write};
use std::path::Path;

use crate::error::{ApHmmError, Result};
use crate::seq::{Alphabet, Sequence};

/// Record-at-a-time FASTA parser over any [`BufRead`].
///
/// Hostile-input contract (shared with [`FastqReader`]): CRLF line
/// endings parse identically to LF, blank lines between records are
/// skipped, and malformed structure — sequence data before the first
/// header, an empty header, a header with no sequence before the next
/// header or EOF, an out-of-alphabet character — yields a typed
/// [`ApHmmError::Parse`] naming the origin and line, never a panic.
///
/// [`FastqReader`]: crate::io::FastqReader
pub struct FastaReader<R: BufRead> {
    inner: R,
    alphabet: Alphabet,
    origin: String,
    buf: String,
    line_no: usize,
    /// Header token already consumed from the stream (the `>` line that
    /// terminated the previous record).
    pending: Option<String>,
    done: bool,
}

impl FastaReader<BufReader<std::fs::File>> {
    /// Open a FASTA file for streaming; the path names the source in
    /// parse errors.
    pub fn open(path: &Path, alphabet: Alphabet) -> Result<Self> {
        let file = std::fs::File::open(path)?;
        Ok(FastaReader::new(BufReader::new(file), alphabet, &path.display().to_string()))
    }
}

impl<R: BufRead> FastaReader<R> {
    /// Stream records from `inner`; `origin` names the source in errors.
    pub fn new(inner: R, alphabet: Alphabet, origin: &str) -> Self {
        FastaReader {
            inner,
            alphabet,
            origin: origin.to_string(),
            buf: String::new(),
            line_no: 0,
            pending: None,
            done: false,
        }
    }

    fn err(&self, msg: String) -> ApHmmError {
        ApHmmError::Parse { path: self.origin.clone(), msg }
    }

    /// Pull the next raw line into `self.buf`; `false` at EOF.
    fn fill_line(&mut self) -> Result<bool> {
        self.buf.clear();
        if self.inner.read_line(&mut self.buf)? == 0 {
            return Ok(false);
        }
        self.line_no += 1;
        Ok(true)
    }

    fn header_token(&self, header: &str) -> Result<String> {
        let token = header.split_whitespace().next().unwrap_or("");
        if token.is_empty() {
            return Err(self.err(format!("empty FASTA header at line {}", self.line_no)));
        }
        Ok(token.to_string())
    }

    /// Parse the next record, or `Ok(None)` once the input is exhausted.
    pub fn next_record(&mut self) -> Result<Option<Sequence>> {
        if self.done {
            return Ok(None);
        }
        let id = match self.pending.take() {
            Some(id) => id,
            None => loop {
                if !self.fill_line()? {
                    self.done = true;
                    return Ok(None);
                }
                let line = self.buf.trim_end();
                if line.is_empty() {
                    continue;
                }
                let Some(header) = line.strip_prefix('>') else {
                    return Err(self.err(format!(
                        "sequence data before first header at line {}",
                        self.line_no
                    )));
                };
                break self.header_token(header)?;
            },
        };
        let mut data: Vec<u8> = Vec::new();
        loop {
            if !self.fill_line()? {
                self.done = true;
                break;
            }
            let line = self.buf.trim_end();
            if line.is_empty() {
                continue;
            }
            if let Some(header) = line.strip_prefix('>') {
                let token = self.header_token(header)?;
                self.pending = Some(token);
                break;
            }
            let line_no = self.line_no;
            for b in line.bytes() {
                match self.alphabet.encode(b) {
                    Ok(sym) => data.push(sym),
                    Err(e) => return Err(self.err(format!("line {line_no}: {e}"))),
                }
            }
        }
        if data.is_empty() {
            return Err(self.err(format!("record {id}: header with no sequence")));
        }
        Ok(Some(Sequence::from_symbols(id, data)))
    }
}

impl<R: BufRead> Iterator for FastaReader<R> {
    type Item = Result<Sequence>;

    fn next(&mut self) -> Option<Self::Item> {
        self.next_record().transpose()
    }
}

/// Parse FASTA text into encoded sequences.
pub fn read_fasta_str(text: &str, alphabet: Alphabet, origin: &str) -> Result<Vec<Sequence>> {
    FastaReader::new(text.as_bytes(), alphabet, origin).collect()
}

/// Read a FASTA file (fully materialized; use [`FastaReader::open`] or
/// the corpus layer's `FastaSource` to stream instead).
pub fn read_fasta(path: &Path, alphabet: Alphabet) -> Result<Vec<Sequence>> {
    FastaReader::open(path, alphabet)?.collect()
}

/// Write sequences as FASTA (60-column wrapped).
pub fn write_fasta<W: Write>(w: &mut W, seqs: &[Sequence], alphabet: Alphabet) -> Result<()> {
    for s in seqs {
        writeln!(w, ">{}", s.id)?;
        let ascii = s.to_ascii(alphabet);
        for chunk in ascii.as_bytes().chunks(60) {
            w.write_all(chunk)?;
            writeln!(w)?;
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::seq::DNA;

    #[test]
    fn roundtrip() {
        let seqs = vec![
            Sequence::from_str("a", "ACGTACGT", DNA).unwrap(),
            Sequence::from_str("b", "TTTT", DNA).unwrap(),
        ];
        let mut buf = Vec::new();
        write_fasta(&mut buf, &seqs, DNA).unwrap();
        let text = String::from_utf8(buf).unwrap();
        let back = read_fasta_str(&text, DNA, "mem").unwrap();
        assert_eq!(back, seqs);
    }

    #[test]
    fn multiline_and_description_handled() {
        let text = ">read1 some description\nACGT\nACGT\n\n>read2\nTT\n";
        let seqs = read_fasta_str(text, DNA, "mem").unwrap();
        assert_eq!(seqs.len(), 2);
        assert_eq!(seqs[0].id, "read1");
        assert_eq!(seqs[0].to_ascii(DNA), "ACGTACGT");
        assert_eq!(seqs[1].to_ascii(DNA), "TT");
    }

    #[test]
    fn rejects_data_before_header() {
        assert!(read_fasta_str("ACGT\n>x\nACGT\n", DNA, "mem").is_err());
    }

    #[test]
    fn rejects_invalid_characters() {
        assert!(read_fasta_str(">x\nACGN\n", DNA, "mem").is_err());
    }

    #[test]
    fn wraps_long_lines() {
        let long = Sequence::from_symbols("l", vec![0u8; 150]);
        let mut buf = Vec::new();
        write_fasta(&mut buf, &[long], DNA).unwrap();
        let text = String::from_utf8(buf).unwrap();
        let max = text.lines().skip(1).map(|l| l.len()).max().unwrap();
        assert!(max <= 60);
    }

    #[test]
    fn crlf_line_endings_parse_identically() {
        let unix = read_fasta_str(">a desc\nACGT\nAC\n>b\nTT\n", DNA, "mem").unwrap();
        let dos = read_fasta_str(">a desc\r\nACGT\r\nAC\r\n>b\r\nTT\r\n", DNA, "mem").unwrap();
        assert_eq!(unix, dos);
    }

    #[test]
    fn rejects_header_with_no_sequence() {
        // Mid-file: header immediately followed by another header.
        let err = read_fasta_str(">empty\n>b\nACGT\n", DNA, "mem").unwrap_err();
        assert!(err.to_string().contains("header with no sequence"), "{err}");
        // At EOF: header is the last line of the file.
        assert!(read_fasta_str(">a\nACGT\n>trailing\n", DNA, "mem").is_err());
    }

    #[test]
    fn rejects_empty_header() {
        assert!(read_fasta_str(">\nACGT\n", DNA, "mem").is_err());
        assert!(read_fasta_str(">   \nACGT\n", DNA, "mem").is_err());
    }

    #[test]
    fn empty_input_yields_no_records() {
        assert!(read_fasta_str("", DNA, "mem").unwrap().is_empty());
        assert!(read_fasta_str("\n\n\n", DNA, "mem").unwrap().is_empty());
    }

    #[test]
    fn streaming_reader_matches_slurp() {
        let text = ">a\nACGT\nAC\n\n>b name\nTTTT\n>c\nGG\n";
        let slurped = read_fasta_str(text, DNA, "mem").unwrap();
        let mut reader = FastaReader::new(text.as_bytes(), DNA, "mem");
        let mut streamed = Vec::new();
        while let Some(seq) = reader.next_record().unwrap() {
            streamed.push(seq);
        }
        assert_eq!(streamed, slurped);
        // Exhausted reader keeps returning None without error.
        assert!(reader.next_record().unwrap().is_none());
    }

    #[test]
    fn parse_errors_name_the_origin() {
        let err = read_fasta_str("ACGT\n", DNA, "somefile.fa").unwrap_err();
        assert!(err.to_string().contains("somefile.fa"), "{err}");
    }
}
