//! FASTA reading and writing.

use std::io::{BufReader, Write};
use std::path::Path;

use crate::error::{ApHmmError, Result};
use crate::seq::{Alphabet, Sequence};

/// Parse FASTA text into encoded sequences.
pub fn read_fasta_str(text: &str, alphabet: Alphabet, origin: &str) -> Result<Vec<Sequence>> {
    let mut out = Vec::new();
    let mut id: Option<String> = None;
    let mut data: Vec<u8> = Vec::new();
    for (lineno, line) in text.lines().enumerate() {
        let line = line.trim_end();
        if line.is_empty() {
            continue;
        }
        if let Some(header) = line.strip_prefix('>') {
            if let Some(prev) = id.take() {
                out.push(Sequence::from_symbols(prev, std::mem::take(&mut data)));
            }
            let token = header.split_whitespace().next().unwrap_or("");
            if token.is_empty() {
                return Err(ApHmmError::Parse {
                    path: origin.into(),
                    msg: format!("empty FASTA header at line {}", lineno + 1),
                });
            }
            id = Some(token.to_string());
        } else {
            if id.is_none() {
                return Err(ApHmmError::Parse {
                    path: origin.into(),
                    msg: format!("sequence data before first header at line {}", lineno + 1),
                });
            }
            for b in line.bytes() {
                data.push(alphabet.encode(b).map_err(|e| ApHmmError::Parse {
                    path: origin.into(),
                    msg: format!("line {}: {e}", lineno + 1),
                })?);
            }
        }
    }
    if let Some(prev) = id.take() {
        out.push(Sequence::from_symbols(prev, data));
    }
    Ok(out)
}

/// Read a FASTA file.
pub fn read_fasta(path: &Path, alphabet: Alphabet) -> Result<Vec<Sequence>> {
    let mut text = String::new();
    BufReader::new(std::fs::File::open(path)?).read_to_string(&mut text)?;
    read_fasta_str(&text, alphabet, &path.display().to_string())
}

use std::io::Read;

/// Write sequences as FASTA (60-column wrapped).
pub fn write_fasta<W: Write>(w: &mut W, seqs: &[Sequence], alphabet: Alphabet) -> Result<()> {
    for s in seqs {
        writeln!(w, ">{}", s.id)?;
        let ascii = s.to_ascii(alphabet);
        for chunk in ascii.as_bytes().chunks(60) {
            w.write_all(chunk)?;
            writeln!(w)?;
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::seq::DNA;

    #[test]
    fn roundtrip() {
        let seqs = vec![
            Sequence::from_str("a", "ACGTACGT", DNA).unwrap(),
            Sequence::from_str("b", "TTTT", DNA).unwrap(),
        ];
        let mut buf = Vec::new();
        write_fasta(&mut buf, &seqs, DNA).unwrap();
        let text = String::from_utf8(buf).unwrap();
        let back = read_fasta_str(&text, DNA, "mem").unwrap();
        assert_eq!(back, seqs);
    }

    #[test]
    fn multiline_and_description_handled() {
        let text = ">read1 some description\nACGT\nACGT\n\n>read2\nTT\n";
        let seqs = read_fasta_str(text, DNA, "mem").unwrap();
        assert_eq!(seqs.len(), 2);
        assert_eq!(seqs[0].id, "read1");
        assert_eq!(seqs[0].to_ascii(DNA), "ACGTACGT");
        assert_eq!(seqs[1].to_ascii(DNA), "TT");
    }

    #[test]
    fn rejects_data_before_header() {
        assert!(read_fasta_str("ACGT\n>x\nACGT\n", DNA, "mem").is_err());
    }

    #[test]
    fn rejects_invalid_characters() {
        assert!(read_fasta_str(">x\nACGN\n", DNA, "mem").is_err());
    }

    #[test]
    fn wraps_long_lines() {
        let long = Sequence::from_symbols("l", vec![0u8; 150]);
        let mut buf = Vec::new();
        write_fasta(&mut buf, &[long], DNA).unwrap();
        let text = String::from_utf8(buf).unwrap();
        let max = text.lines().skip(1).map(|l| l.len()).max().unwrap();
        assert!(max <= 60);
    }
}
