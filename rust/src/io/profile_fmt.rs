//! `.aphmm` — a line-oriented text format persisting pHMM graphs
//! (trained models, family databases).  Plays the role HMMER's `.hmm`
//! format plays for hmmsearch.
//!
//! ```text
//! APHMM 1
//! design <traditional|traditional_folded|error_correction>
//! alphabet <dna|protein>
//! states <n>
//! state <idx> <M|I|D> <position> <emission probs ...>
//! trans <from> <to> <prob>
//! init <idx> <prob>
//! END
//! ```

use std::io::{Read, Write};
use std::path::Path;

use crate::error::{ApHmmError, Result};
use crate::phmm::{Phmm, PhmmDesign, StateKind};
use crate::seq::Alphabet;

fn design_name(d: PhmmDesign) -> &'static str {
    match d {
        PhmmDesign::Traditional => "traditional",
        PhmmDesign::TraditionalFolded => "traditional_folded",
        PhmmDesign::ErrorCorrection => "error_correction",
    }
}

fn design_from(name: &str) -> Option<PhmmDesign> {
    match name {
        "traditional" => Some(PhmmDesign::Traditional),
        "traditional_folded" => Some(PhmmDesign::TraditionalFolded),
        "error_correction" => Some(PhmmDesign::ErrorCorrection),
        _ => None,
    }
}

/// Serialize a pHMM to the `.aphmm` text format.
pub fn write_phmm_string(phmm: &Phmm) -> String {
    let mut out = String::new();
    out.push_str("APHMM 1\n");
    out.push_str(&format!("design {}\n", design_name(phmm.design)));
    out.push_str(&format!("alphabet {}\n", phmm.alphabet.name()));
    out.push_str(&format!("states {}\n", phmm.n_states()));
    for i in 0..phmm.n_states() {
        let kind = match phmm.kinds[i] {
            StateKind::Match => "M",
            StateKind::Insertion => "I",
            StateKind::Deletion => "D",
        };
        out.push_str(&format!("state {i} {kind} {}", phmm.position[i]));
        for &e in phmm.emission_row(i) {
            out.push_str(&format!(" {e:.7}"));
        }
        out.push('\n');
    }
    for i in 0..phmm.n_states() {
        for (to, p) in phmm.outgoing(i) {
            out.push_str(&format!("trans {i} {to} {p:.7}\n"));
        }
    }
    for (i, &p) in phmm.f_init.iter().enumerate() {
        if p > 0.0 {
            out.push_str(&format!("init {i} {p:.7}\n"));
        }
    }
    out.push_str("END\n");
    out
}

/// Write a pHMM to a file.
pub fn write_phmm(path: &Path, phmm: &Phmm) -> Result<()> {
    let mut f = std::fs::File::create(path)?;
    f.write_all(write_phmm_string(phmm).as_bytes())?;
    Ok(())
}

/// Parse a pHMM from `.aphmm` text.
pub fn read_phmm_str(text: &str, origin: &str) -> Result<Phmm> {
    let err = |msg: String| ApHmmError::Parse { path: origin.into(), msg };
    let mut lines = text.lines();
    let header = lines.next().ok_or_else(|| err("empty file".into()))?;
    if header.trim() != "APHMM 1" {
        return Err(err(format!("bad magic {header:?}")));
    }
    let mut design = None;
    let mut alphabet: Option<Alphabet> = None;
    let mut n_states = 0usize;
    let mut kinds: Vec<StateKind> = Vec::new();
    let mut position: Vec<u32> = Vec::new();
    let mut emissions: Vec<f32> = Vec::new();
    let mut edges: Vec<Vec<(u32, f32)>> = Vec::new();
    let mut f_init: Vec<f32> = Vec::new();
    let mut saw_end = false;

    for (lineno, line) in lines.enumerate() {
        let line = line.trim();
        if line.is_empty() {
            continue;
        }
        let mut it = line.split_whitespace();
        let tag = it.next().unwrap();
        let ctx = |m: &str| err(format!("line {}: {m}", lineno + 2));
        match tag {
            "design" => {
                let name = it.next().ok_or_else(|| ctx("missing design"))?;
                design = Some(design_from(name).ok_or_else(|| ctx("unknown design"))?);
            }
            "alphabet" => {
                let name = it.next().ok_or_else(|| ctx("missing alphabet"))?;
                alphabet = Some(Alphabet::by_name(name).map_err(|e| ctx(&e.to_string()))?);
            }
            "states" => {
                n_states = it
                    .next()
                    .and_then(|s| s.parse().ok())
                    .ok_or_else(|| ctx("bad state count"))?;
                edges = vec![Vec::new(); n_states];
                f_init = vec![0.0; n_states];
            }
            "state" => {
                let sigma = alphabet.ok_or_else(|| ctx("state before alphabet"))?.size();
                let idx: usize =
                    it.next().and_then(|s| s.parse().ok()).ok_or_else(|| ctx("bad index"))?;
                if idx != kinds.len() {
                    return Err(ctx("states out of order"));
                }
                let kind = match it.next() {
                    Some("M") => StateKind::Match,
                    Some("I") => StateKind::Insertion,
                    Some("D") => StateKind::Deletion,
                    _ => return Err(ctx("bad state kind")),
                };
                let pos: u32 =
                    it.next().and_then(|s| s.parse().ok()).ok_or_else(|| ctx("bad position"))?;
                kinds.push(kind);
                position.push(pos);
                for _ in 0..sigma {
                    let e: f32 = it
                        .next()
                        .and_then(|s| s.parse().ok())
                        .ok_or_else(|| ctx("missing emission"))?;
                    // Reject here, not in Phmm::validate: a NaN poisons
                    // the row-sum check there into silently passing,
                    // and validate only checks the row SUM — a hostile
                    // `1.5 -0.5 ...` row sums to 1 yet would feed
                    // negative probabilities into the forward pass.
                    // Tolerance above 1 mirrors validate's edge check.
                    if !(0.0..=1.0 + 1e-6).contains(&e) {
                        return Err(ctx("emission out of [0, 1]"));
                    }
                    emissions.push(e);
                }
            }
            "trans" => {
                let from: usize =
                    it.next().and_then(|s| s.parse().ok()).ok_or_else(|| ctx("bad from"))?;
                let to: u32 =
                    it.next().and_then(|s| s.parse().ok()).ok_or_else(|| ctx("bad to"))?;
                let p: f32 =
                    it.next().and_then(|s| s.parse().ok()).ok_or_else(|| ctx("bad prob"))?;
                if !p.is_finite() {
                    return Err(ctx("non-finite prob"));
                }
                if from >= n_states {
                    return Err(ctx("from out of range"));
                }
                edges[from].push((to, p));
            }
            "init" => {
                let idx: usize =
                    it.next().and_then(|s| s.parse().ok()).ok_or_else(|| ctx("bad index"))?;
                let p: f32 =
                    it.next().and_then(|s| s.parse().ok()).ok_or_else(|| ctx("bad prob"))?;
                // Per-element range check (covers NaN too): validate
                // only checks the init SUM, so a negative entry
                // balanced by an oversized one would slip through.
                if !(0.0..=1.0 + 1e-6).contains(&p) {
                    return Err(ctx("init prob out of [0, 1]"));
                }
                if idx >= n_states {
                    return Err(ctx("init out of range"));
                }
                f_init[idx] = p;
            }
            "END" => {
                saw_end = true;
                break;
            }
            other => return Err(ctx(&format!("unknown tag {other:?}"))),
        }
    }
    if !saw_end {
        return Err(err("missing END terminator (truncated file?)".into()));
    }
    if kinds.len() != n_states {
        return Err(err(format!("expected {n_states} states, found {}", kinds.len())));
    }
    let mut out_ptr = Vec::with_capacity(n_states + 1);
    let mut out_to = Vec::new();
    let mut out_prob = Vec::new();
    out_ptr.push(0u32);
    for row in &mut edges {
        row.sort_by_key(|&(to, _)| to);
        for &(to, p) in row.iter() {
            out_to.push(to);
            out_prob.push(p);
        }
        out_ptr.push(out_to.len() as u32);
    }
    let phmm = Phmm {
        design: design.ok_or_else(|| err("missing design".into()))?,
        alphabet: alphabet.ok_or_else(|| err("missing alphabet".into()))?,
        kinds,
        position,
        out_ptr,
        out_to,
        out_prob,
        emissions,
        f_init,
    };
    phmm.validate()?;
    Ok(phmm)
}

/// Read a pHMM file.
pub fn read_phmm(path: &Path) -> Result<Phmm> {
    let mut text = String::new();
    std::fs::File::open(path)?.read_to_string(&mut text)?;
    read_phmm_str(&text, &path.display().to_string())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::phmm::EcDesignParams;
    use crate::seq::Sequence;
    use crate::testutil;

    #[test]
    fn roundtrip_preserves_graph() {
        testutil::check(5, |rng| {
            let len = rng.range(3, 30);
            let data = testutil::random_seq(rng, len, 4);
            let g = Phmm::error_correction(
                &Sequence::from_symbols("r", data),
                &EcDesignParams::default(),
            )
            .unwrap();
            let text = write_phmm_string(&g);
            let back = read_phmm_str(&text, "mem").unwrap();
            assert_eq!(back.n_states(), g.n_states());
            assert_eq!(back.out_to, g.out_to);
            assert_eq!(back.kinds, g.kinds);
            for (a, b) in back.out_prob.iter().zip(&g.out_prob) {
                assert!((a - b).abs() < 1e-5);
            }
            for (a, b) in back.emissions.iter().zip(&g.emissions) {
                assert!((a - b).abs() < 1e-5);
            }
        });
    }

    #[test]
    fn roundtrip_is_byte_identical_for_all_designs_and_alphabets() {
        // write -> read -> write is exactly the identity on the text:
        // probabilities are printed with 7 decimals, f32 parsing is the
        // nearest float (within half an ulp < 5e-8 for values ≤ 1), so
        // re-printing recovers the same 7-decimal string; edges are
        // written in (sorted) CSR order on both sides.
        use crate::phmm::{EcDesignParams, Profile, TraditionalParams};
        use crate::seq::{DNA, PROTEIN};
        let dna_seq = Sequence::from_str("r", "ACGTACGTTGCAACGTAC", DNA).unwrap();
        let protein_seq = Sequence::from_str("r", "ACDEFGHIKLMNPQRSTVWY", PROTEIN).unwrap();
        let mut graphs: Vec<(String, Phmm)> = Vec::new();
        for (alph, seq) in [(DNA, &dna_seq), (PROTEIN, &protein_seq)] {
            graphs.push((
                format!("error_correction/{}", alph.name()),
                Phmm::error_correction_for(seq, &EcDesignParams::default(), alph).unwrap(),
            ));
            let profile = Profile::from_sequence(seq, alph, 0.9);
            let traditional = Phmm::traditional(&profile, &TraditionalParams::default()).unwrap();
            graphs.push((
                format!("traditional_folded/{}", alph.name()),
                traditional.fold_silent(4).unwrap(),
            ));
            graphs.push((format!("traditional/{}", alph.name()), traditional));
        }
        assert_eq!(graphs.len(), 6, "three designs x two alphabets");
        for (name, g) in &graphs {
            let text1 = write_phmm_string(g);
            let back = read_phmm_str(&text1, "mem").unwrap();
            assert_eq!(back.design, g.design, "{name}");
            assert_eq!(back.alphabet.name(), g.alphabet.name(), "{name}");
            assert_eq!(back.n_states(), g.n_states(), "{name}");
            let text2 = write_phmm_string(&back);
            assert_eq!(text1, text2, "write->read->write not byte-identical for {name}");
        }
    }

    #[test]
    fn rejects_bad_magic() {
        assert!(read_phmm_str("NOPE\n", "mem").is_err());
    }

    #[test]
    fn malformed_inputs_error_instead_of_panicking() {
        let valid = write_phmm_string(
            &Phmm::error_correction(
                &Sequence::from_str("r", "ACGTAC", crate::seq::DNA).unwrap(),
                &EcDesignParams::default(),
            )
            .unwrap(),
        );

        // Unknown design name.
        let bad_design = valid.replacen("design error_correction", "design quantum", 1);
        assert!(read_phmm_str(&bad_design, "mem").is_err());

        // Truncated `state` line: fewer emissions than the alphabet.
        let text = "APHMM 1\ndesign error_correction\nalphabet dna\nstates 1\n\
                    state 0 M 0 0.25 0.25\nEND\n";
        assert!(read_phmm_str(text, "mem").is_err());

        // Missing END: a file cut off mid-transfer must not parse as a
        // (possibly truncated) graph.
        let truncated = valid.replacen("END\n", "", 1);
        assert!(
            read_phmm_str(&truncated, "mem").is_err(),
            "a file without END must be rejected"
        );

        // Duplicate trans lines (parallel edges) survive the stable
        // per-row sort but are rejected by Phmm::validate — the dense
        // lowerings keep one band/tile cell per (from, to) pair, so a
        // parallel edge cannot be represented faithfully.
        let dup = valid.replacen("trans 0 1 ", "trans 0 1 0.0100000\ntrans 0 1 ", 1);
        assert!(
            dup.contains("trans 0 1 0.0100000\ntrans 0 1 "),
            "fixture assumption broken: no `trans 0 1` line to duplicate"
        );
        assert!(read_phmm_str(&dup, "mem").is_err(), "parallel edges must be rejected");

        // Structurally hostile lines: out-of-range indices, tags before
        // their prerequisites — errors, never panics.
        for text in [
            "APHMM 1\ntrans 3 4 0.5\nEND\n",
            "APHMM 1\ninit 9 0.5\nEND\n",
            "APHMM 1\nstate 0 M 0 0.25 0.25 0.25 0.25\nEND\n",
            "APHMM 1\ndesign error_correction\nalphabet dna\nstates 1\nstate 1 M 0\nEND\n",
            "APHMM 1\nwhat 1 2 3\nEND\n",
            "APHMM 1\n",
        ] {
            assert!(read_phmm_str(text, "mem").is_err(), "accepted malformed input {text:?}");
        }
    }

    #[test]
    fn rejects_non_finite_probabilities() {
        // `f32::parse` happily accepts "inf" and "NaN", and a NaN
        // emission row defeats Phmm::validate's row-sum check (NaN
        // comparisons are false), so the parser must reject non-finite
        // values outright — these payloads arrive over the wire from
        // untrusted tenants via `register-profile`.
        let valid = write_phmm_string(
            &Phmm::error_correction(
                &Sequence::from_str("r", "ACGTAC", crate::seq::DNA).unwrap(),
                &EcDesignParams::default(),
            )
            .unwrap(),
        );
        let first_trans = valid
            .lines()
            .find(|l| l.starts_with("trans "))
            .expect("fixture has a trans line")
            .to_string();
        let toks: Vec<&str> = first_trans.split_whitespace().collect();
        for hostile in ["inf", "-inf", "NaN", "nan"] {
            let bad_trans = valid.replacen(
                &first_trans,
                &format!("trans {} {} {hostile}", toks[1], toks[2]),
                1,
            );
            assert!(
                read_phmm_str(&bad_trans, "mem").is_err(),
                "accepted trans prob {hostile}"
            );
        }
        let bad_init = valid.replacen("init 0 ", "init 0 NaN #", 1);
        if bad_init != valid {
            assert!(read_phmm_str(&bad_init, "mem").is_err(), "accepted init NaN");
        }
        let text = "APHMM 1\ndesign error_correction\nalphabet dna\nstates 1\n\
                    state 0 M 0 NaN 0.25 0.25 0.25\nEND\n";
        assert!(read_phmm_str(text, "mem").is_err(), "accepted NaN emission");
        let text = "APHMM 1\ndesign error_correction\nalphabet dna\nstates 1\n\
                    state 0 M 0 inf 0.25 0.25 0.25\nEND\n";
        assert!(read_phmm_str(text, "mem").is_err(), "accepted inf emission");

        // Negative probabilities hidden behind a valid SUM: validate
        // only checks row/init sums, so the per-element range check in
        // the parser is what stops `1.5 -0.5` rows (which would feed
        // negative probabilities into the forward pass) and negative
        // init mass balanced by an oversized entry.
        let text = "APHMM 1\ndesign error_correction\nalphabet dna\nstates 1\n\
                    state 0 M 0 1.5 -0.5 0.0 0.0\nEND\n";
        assert!(read_phmm_str(text, "mem").is_err(), "accepted negative emission");
        let text = "APHMM 1\ndesign error_correction\nalphabet dna\nstates 2\n\
                    state 0 M 0 0.25 0.25 0.25 0.25\n\
                    state 1 M 1 0.25 0.25 0.25 0.25\n\
                    trans 0 1 1.0\ninit 0 1.5\ninit 1 -0.5\nEND\n";
        assert!(read_phmm_str(text, "mem").is_err(), "accepted negative init prob");
    }

    #[test]
    fn rejects_truncated_states() {
        let text = "APHMM 1\ndesign error_correction\nalphabet dna\nstates 2\nstate 0 M 0 0.25 0.25 0.25 0.25\nEND\n";
        assert!(read_phmm_str(text, "mem").is_err());
    }

    #[test]
    fn file_roundtrip() {
        let g = Phmm::error_correction(
            &Sequence::from_str("r", "ACGTAC", crate::seq::DNA).unwrap(),
            &EcDesignParams::default(),
        )
        .unwrap();
        let dir = std::env::temp_dir().join("aphmm_test_profile");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("g.aphmm");
        write_phmm(&path, &g).unwrap();
        let back = read_phmm(&path).unwrap();
        assert_eq!(back.n_states(), g.n_states());
        std::fs::remove_dir_all(&dir).ok();
    }
}
