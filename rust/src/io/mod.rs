//! Sequence and profile I/O.
//!
//! FASTA/FASTQ readers and writers (the formats of the paper's input
//! data) plus the `.aphmm` text profile format used to persist trained
//! pHMM graphs and family databases.

mod fasta;
mod fastq;
mod profile_fmt;

pub use fasta::{read_fasta, read_fasta_str, write_fasta, FastaReader};
pub use fastq::{read_fastq, read_fastq_str, write_fastq, FastqReader};
pub use profile_fmt::{read_phmm, read_phmm_str, write_phmm, write_phmm_string};
