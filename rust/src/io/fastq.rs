//! FASTQ reading and writing (qualities preserved but unused by the
//! pHMM pipeline, as in Apollo).

use std::io::{Read, Write};
use std::path::Path;

use crate::error::{ApHmmError, Result};
use crate::seq::{Alphabet, Sequence};

/// Parse FASTQ text; returns `(sequence, quality-string)` pairs.
pub fn read_fastq_str(
    text: &str,
    alphabet: Alphabet,
    origin: &str,
) -> Result<Vec<(Sequence, String)>> {
    let mut out = Vec::new();
    let mut lines = text.lines().enumerate().peekable();
    while let Some((lineno, header)) = lines.next() {
        if header.trim().is_empty() {
            continue;
        }
        let parse_err = |msg: String| ApHmmError::Parse { path: origin.into(), msg };
        let id = header
            .strip_prefix('@')
            .ok_or_else(|| parse_err(format!("line {}: expected '@'", lineno + 1)))?
            .split_whitespace()
            .next()
            .unwrap_or("")
            .to_string();
        let (_, seq_line) =
            lines.next().ok_or_else(|| parse_err("truncated record (no sequence)".into()))?;
        let (_, plus) =
            lines.next().ok_or_else(|| parse_err("truncated record (no '+')".into()))?;
        if !plus.starts_with('+') {
            return Err(parse_err(format!("line {}: expected '+'", lineno + 3)));
        }
        let (_, qual) =
            lines.next().ok_or_else(|| parse_err("truncated record (no quality)".into()))?;
        if qual.len() != seq_line.len() {
            return Err(parse_err(format!("record {id}: quality length mismatch")));
        }
        let data = alphabet
            .encode_str(seq_line.trim_end())
            .map_err(|e| parse_err(format!("record {id}: {e}")))?;
        out.push((Sequence::from_symbols(id, data), qual.to_string()));
    }
    Ok(out)
}

/// Read a FASTQ file.
pub fn read_fastq(path: &Path, alphabet: Alphabet) -> Result<Vec<(Sequence, String)>> {
    let mut text = String::new();
    std::fs::File::open(path)?.read_to_string(&mut text)?;
    read_fastq_str(&text, alphabet, &path.display().to_string())
}

/// Write FASTQ records; `quals` may be shorter (missing → 'I' = Q40).
pub fn write_fastq<W: Write>(
    w: &mut W,
    seqs: &[Sequence],
    quals: &[String],
    alphabet: Alphabet,
) -> Result<()> {
    for (i, s) in seqs.iter().enumerate() {
        let ascii = s.to_ascii(alphabet);
        let q = quals.get(i).cloned().unwrap_or_else(|| "I".repeat(ascii.len()));
        writeln!(w, "@{}", s.id)?;
        writeln!(w, "{ascii}")?;
        writeln!(w, "+")?;
        writeln!(w, "{q}")?;
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::seq::DNA;

    #[test]
    fn roundtrip() {
        let seqs = vec![Sequence::from_str("r1", "ACGT", DNA).unwrap()];
        let quals = vec!["IIII".to_string()];
        let mut buf = Vec::new();
        write_fastq(&mut buf, &seqs, &quals, DNA).unwrap();
        let text = String::from_utf8(buf).unwrap();
        let back = read_fastq_str(&text, DNA, "mem").unwrap();
        assert_eq!(back.len(), 1);
        assert_eq!(back[0].0, seqs[0]);
        assert_eq!(back[0].1, "IIII");
    }

    #[test]
    fn rejects_length_mismatch() {
        assert!(read_fastq_str("@x\nACGT\n+\nII\n", DNA, "mem").is_err());
    }

    #[test]
    fn rejects_missing_plus() {
        assert!(read_fastq_str("@x\nACGT\nII\nIIII\n", DNA, "mem").is_err());
    }

    #[test]
    fn default_quality_fill() {
        let seqs = vec![Sequence::from_str("r", "ACG", DNA).unwrap()];
        let mut buf = Vec::new();
        write_fastq(&mut buf, &seqs, &[], DNA).unwrap();
        assert!(String::from_utf8(buf).unwrap().contains("III"));
    }
}
