//! FASTQ reading and writing (qualities preserved but unused by the
//! pHMM pipeline, as in Apollo).
//!
//! [`FastqReader`] streams one record at a time over any `BufRead`;
//! [`read_fastq`] / [`read_fastq_str`] collect it. The hostile-input
//! contract matches [`FastaReader`]: CRLF endings, empty records,
//! and mid-record EOF all produce typed [`ApHmmError::Parse`] errors,
//! never panics.
//!
//! [`FastaReader`]: crate::io::FastaReader

use std::io::{BufRead, BufReader, Write};
use std::path::Path;

use crate::error::{ApHmmError, Result};
use crate::seq::{Alphabet, Sequence};

/// Record-at-a-time FASTQ parser (4-line records) over any [`BufRead`].
pub struct FastqReader<R: BufRead> {
    inner: R,
    alphabet: Alphabet,
    origin: String,
    buf: String,
    line_no: usize,
    done: bool,
}

impl FastqReader<BufReader<std::fs::File>> {
    /// Open a FASTQ file for streaming; the path names the source in
    /// parse errors.
    pub fn open(path: &Path, alphabet: Alphabet) -> Result<Self> {
        let file = std::fs::File::open(path)?;
        Ok(FastqReader::new(BufReader::new(file), alphabet, &path.display().to_string()))
    }
}

impl<R: BufRead> FastqReader<R> {
    /// Stream records from `inner`; `origin` names the source in errors.
    pub fn new(inner: R, alphabet: Alphabet, origin: &str) -> Self {
        FastqReader {
            inner,
            alphabet,
            origin: origin.to_string(),
            buf: String::new(),
            line_no: 0,
            done: false,
        }
    }

    fn err(&self, msg: String) -> ApHmmError {
        ApHmmError::Parse { path: self.origin.clone(), msg }
    }

    /// Pull the next raw line into `self.buf`; `false` at EOF.
    fn fill_line(&mut self) -> Result<bool> {
        self.buf.clear();
        if self.inner.read_line(&mut self.buf)? == 0 {
            return Ok(false);
        }
        self.line_no += 1;
        Ok(true)
    }

    /// Parse the next record, or `Ok(None)` once the input is exhausted.
    pub fn next_record(&mut self) -> Result<Option<(Sequence, String)>> {
        if self.done {
            return Ok(None);
        }
        // Header line; blank lines between records are tolerated.
        let id = loop {
            if !self.fill_line()? {
                self.done = true;
                return Ok(None);
            }
            let line = self.buf.trim_end();
            if line.is_empty() {
                continue;
            }
            let Some(header) = line.strip_prefix('@') else {
                return Err(self.err(format!("line {}: expected '@'", self.line_no)));
            };
            let token = header.split_whitespace().next().unwrap_or("");
            if token.is_empty() {
                return Err(self.err(format!("empty FASTQ header at line {}", self.line_no)));
            }
            break token.to_string();
        };
        // Sequence line; EOF here is a truncated record, not end of input.
        if !self.fill_line()? {
            self.done = true;
            return Err(self.err(format!("record {id}: truncated record (no sequence)")));
        }
        let seq_ascii = self.buf.trim_end().to_string();
        if seq_ascii.is_empty() {
            return Err(self.err(format!("record {id}: empty sequence")));
        }
        // '+' separator line.
        if !self.fill_line()? {
            self.done = true;
            return Err(self.err(format!("record {id}: truncated record (no '+')")));
        }
        if !self.buf.starts_with('+') {
            return Err(self.err(format!("line {}: expected '+'", self.line_no)));
        }
        // Quality line. Both sides have their line terminators trimmed,
        // so the length check is ending-agnostic (CRLF == LF).
        if !self.fill_line()? {
            self.done = true;
            return Err(self.err(format!("record {id}: truncated record (no quality)")));
        }
        let qual = self.buf.trim_end().to_string();
        if qual.len() != seq_ascii.len() {
            return Err(self.err(format!("record {id}: quality length mismatch")));
        }
        let data = self
            .alphabet
            .encode_str(&seq_ascii)
            .map_err(|e| self.err(format!("record {id}: {e}")))?;
        Ok(Some((Sequence::from_symbols(id, data), qual)))
    }
}

impl<R: BufRead> Iterator for FastqReader<R> {
    type Item = Result<(Sequence, String)>;

    fn next(&mut self) -> Option<Self::Item> {
        self.next_record().transpose()
    }
}

/// Parse FASTQ text; returns `(sequence, quality-string)` pairs.
pub fn read_fastq_str(
    text: &str,
    alphabet: Alphabet,
    origin: &str,
) -> Result<Vec<(Sequence, String)>> {
    FastqReader::new(text.as_bytes(), alphabet, origin).collect()
}

/// Read a FASTQ file (fully materialized; use [`FastqReader::open`] or
/// the corpus layer's `FastqSource` to stream instead).
pub fn read_fastq(path: &Path, alphabet: Alphabet) -> Result<Vec<(Sequence, String)>> {
    FastqReader::open(path, alphabet)?.collect()
}

/// Write FASTQ records; `quals` may be shorter (missing → 'I' = Q40).
pub fn write_fastq<W: Write>(
    w: &mut W,
    seqs: &[Sequence],
    quals: &[String],
    alphabet: Alphabet,
) -> Result<()> {
    for (i, s) in seqs.iter().enumerate() {
        let ascii = s.to_ascii(alphabet);
        let q = quals.get(i).cloned().unwrap_or_else(|| "I".repeat(ascii.len()));
        writeln!(w, "@{}", s.id)?;
        writeln!(w, "{ascii}")?;
        writeln!(w, "+")?;
        writeln!(w, "{q}")?;
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::seq::DNA;

    #[test]
    fn roundtrip() {
        let seqs = vec![Sequence::from_str("r1", "ACGT", DNA).unwrap()];
        let quals = vec!["IIII".to_string()];
        let mut buf = Vec::new();
        write_fastq(&mut buf, &seqs, &quals, DNA).unwrap();
        let text = String::from_utf8(buf).unwrap();
        let back = read_fastq_str(&text, DNA, "mem").unwrap();
        assert_eq!(back.len(), 1);
        assert_eq!(back[0].0, seqs[0]);
        assert_eq!(back[0].1, "IIII");
    }

    #[test]
    fn rejects_length_mismatch() {
        assert!(read_fastq_str("@x\nACGT\n+\nII\n", DNA, "mem").is_err());
    }

    #[test]
    fn rejects_missing_plus() {
        assert!(read_fastq_str("@x\nACGT\nII\nIIII\n", DNA, "mem").is_err());
    }

    #[test]
    fn default_quality_fill() {
        let seqs = vec![Sequence::from_str("r", "ACG", DNA).unwrap()];
        let mut buf = Vec::new();
        write_fastq(&mut buf, &seqs, &[], DNA).unwrap();
        assert!(String::from_utf8(buf).unwrap().contains("III"));
    }

    #[test]
    fn crlf_line_endings_parse_identically() {
        // The pre-streaming parser compared an untrimmed quality line
        // against an untrimmed sequence line, so CRLF input tripped the
        // length check even for well-formed records.
        let unix = read_fastq_str("@r desc\nACGT\n+\nIIII\n@s\nTT\n+\n!!\n", DNA, "mem").unwrap();
        let dos =
            read_fastq_str("@r desc\r\nACGT\r\n+\r\nIIII\r\n@s\r\nTT\r\n+\r\n!!\r\n", DNA, "mem")
                .unwrap();
        assert_eq!(unix, dos);
        assert_eq!(unix.len(), 2);
        assert_eq!(unix[1].1, "!!");
    }

    #[test]
    fn rejects_mid_record_eof() {
        let cases = ["@x\n", "@x\nACGT\n", "@x\nACGT\n+\n"];
        for text in cases {
            let err = read_fastq_str(text, DNA, "mem").unwrap_err();
            assert!(err.to_string().contains("truncated record"), "{text:?}: {err}");
        }
    }

    #[test]
    fn rejects_empty_record() {
        assert!(read_fastq_str("@x\n\n+\n\n", DNA, "mem").is_err());
        assert!(read_fastq_str("@\nACGT\n+\nIIII\n", DNA, "mem").is_err());
    }

    #[test]
    fn empty_input_yields_no_records() {
        assert!(read_fastq_str("", DNA, "mem").unwrap().is_empty());
        assert!(read_fastq_str("\n\n", DNA, "mem").unwrap().is_empty());
    }

    #[test]
    fn streaming_reader_matches_slurp() {
        let text = "@a\nACGT\n+\nIIII\n@b\nTT\n+\n##\n";
        let slurped = read_fastq_str(text, DNA, "mem").unwrap();
        let mut reader = FastqReader::new(text.as_bytes(), DNA, "mem");
        let mut streamed = Vec::new();
        while let Some(rec) = reader.next_record().unwrap() {
            streamed.push(rec);
        }
        assert_eq!(streamed, slurped);
        assert!(reader.next_record().unwrap().is_none());
    }
}
