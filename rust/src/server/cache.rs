//! Cross-request cache of frozen per-profile coefficient tables.
//!
//! ApHMM's core insight (§4.2–4.3) is that pHMM coefficients are
//! frozen for a whole EM iteration and therefore worth memoizing in
//! on-chip memory.  A serving layer extends the same insight **across
//! requests**: many clients scoring/aligning against the same profile
//! should share one frozen [`PreparedAny`] instead of re-freezing per
//! request.  [`PreparedCache`] is that share point — an LRU map from
//! `(profile content hash, engine kind)` to `Arc<PreparedAny>` with
//! hit/miss/evict counters, so the serving tests can *prove* the
//! second request for a profile skipped the freeze.
//!
//! # Keying
//!
//! Entries are keyed by [`profile_hash`] — an FNV-1a digest of the
//! full parameter content of the graph (design, alphabet, state kinds
//! and positions, CSR structure, transition probabilities, emissions,
//! initial distribution) — plus the [`EngineKind`] that froze the
//! tables.  Content addressing means two tenants registering the same
//! profile under different names share one entry, and any parameter
//! change (retraining) produces a new key instead of serving stale
//! coefficients.
//!
//! # Filter/train independence
//!
//! [`profile_hash`] is a pure function of the *graph* — it has no
//! `FilterConfig`, `GatherKind` or `TrainConfig` input, and must never
//! grow one.  The invariant this encodes: a frozen [`PreparedAny`]
//! (the `baumwelch::lowering` products plus coefficient tables) bakes
//! in **parameters only**; state filtering and gather-kernel dispatch
//! are strictly runtime-side (`ForwardOptions`), so one cached entry
//! serves every filter/gather configuration bit-identically to a fresh
//! freeze (asserted by `prepared_tables_are_filter_agnostic` below).
//! If frozen tables ever started depending on a runtime config, this
//! keying would silently serve wrong coefficients across tenants with
//! different configs.
//!
//! # Concurrency
//!
//! Lookups take a short mutex; freezing happens **outside** the lock so
//! a slow freeze of one profile never blocks hits on others.  Two
//! racing misses for the same key may both freeze; the first insert
//! wins and the loser's table is dropped (counted as a miss each —
//! `misses` counts freezes performed, `hits` counts freezes avoided).

use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

use crate::baumwelch::{EngineKind, PreparedAny};
use crate::error::Result;
use crate::phmm::{Phmm, PhmmDesign, StateKind};

/// Cache key: profile content hash + the engine that froze the tables.
pub type CacheKey = (u64, EngineKind);

/// FNV-1a content hash of every parameter of `phmm`.  Stable across
/// clones and re-registrations; changes whenever any probability,
/// emission, or structural array changes.
///
/// Every field is **domain-separated**: a per-field tag byte plus the
/// element count prefix the field's bytes.  Without them, two
/// structurally different graphs whose concatenated byte streams
/// coincide (e.g. a trailing `position` element re-read as the first
/// `out_ptr` element) would collide — in a multi-tenant cache that is
/// one tenant receiving another profile's frozen coefficient tables.
/// The regression test `hash_separates_adjacent_field_boundaries`
/// below pins the property; it also pins that this PR deliberately
/// changed hash values relative to the unprefixed scheme (see
/// `server/README.md` — the cache is in-memory only, so old keys
/// simply miss once and re-freeze).
pub fn profile_hash(phmm: &Phmm) -> u64 {
    const FNV_OFFSET: u64 = 0xcbf2_9ce4_8422_2325;
    const FNV_PRIME: u64 = 0x0000_0100_0000_01b3;
    fn eat(h: &mut u64, byte: u8) {
        *h ^= byte as u64;
        *h = h.wrapping_mul(FNV_PRIME);
    }
    fn eat_u32(h: &mut u64, v: u32) {
        for b in v.to_le_bytes() {
            eat(h, b);
        }
    }
    // Open field `tag` holding `len` elements: the (tag, len) pair is
    // what makes adjacent variable-length fields unambiguous.
    fn eat_field(h: &mut u64, tag: u8, len: usize) {
        eat(h, tag);
        eat_u32(h, len as u32);
    }
    let mut h = FNV_OFFSET;
    eat_field(&mut h, 1, 1);
    match phmm.design {
        PhmmDesign::Traditional => eat(&mut h, 0),
        PhmmDesign::TraditionalFolded => eat(&mut h, 1),
        PhmmDesign::ErrorCorrection => eat(&mut h, 2),
    }
    eat_field(&mut h, 2, phmm.alphabet.name().len());
    for b in phmm.alphabet.name().bytes() {
        eat(&mut h, b);
    }
    eat_field(&mut h, 3, phmm.kinds.len());
    for k in &phmm.kinds {
        eat(
            &mut h,
            match k {
                StateKind::Match => 0,
                StateKind::Insertion => 1,
                StateKind::Deletion => 2,
            },
        );
    }
    eat_field(&mut h, 4, phmm.position.len());
    for &p in &phmm.position {
        eat_u32(&mut h, p);
    }
    eat_field(&mut h, 5, phmm.out_ptr.len());
    for &p in &phmm.out_ptr {
        eat_u32(&mut h, p);
    }
    eat_field(&mut h, 6, phmm.out_to.len());
    for &t in &phmm.out_to {
        eat_u32(&mut h, t);
    }
    eat_field(&mut h, 7, phmm.out_prob.len());
    for &p in &phmm.out_prob {
        eat_u32(&mut h, p.to_bits());
    }
    eat_field(&mut h, 8, phmm.emissions.len());
    for &e in &phmm.emissions {
        eat_u32(&mut h, e.to_bits());
    }
    eat_field(&mut h, 9, phmm.f_init.len());
    for &f in &phmm.f_init {
        eat_u32(&mut h, f.to_bits());
    }
    h
}

/// Counter snapshot of the cache.
#[derive(Clone, Copy, Debug, Default)]
pub struct CacheStats {
    /// Lookups served from a cached entry (freeze skipped).
    pub hits: u64,
    /// Lookups that had to freeze (including both sides of a racing
    /// double-freeze).
    pub misses: u64,
    /// Entries evicted by the LRU policy.
    pub evictions: u64,
    /// Entries currently resident.
    pub entries: u64,
    /// Cumulative nanoseconds spent freezing tables on misses — the
    /// work the cache exists to amortize (exposed as
    /// `aphmm_cache_freeze_seconds_total`).
    pub freeze_ns: u64,
}

struct LruState {
    map: HashMap<CacheKey, Arc<PreparedAny>>,
    /// Keys in recency order: least-recently-used at the front.
    order: Vec<CacheKey>,
}

impl LruState {
    fn touch(&mut self, key: CacheKey) {
        if let Some(pos) = self.order.iter().position(|k| *k == key) {
            self.order.remove(pos);
        }
        self.order.push(key);
    }
}

/// LRU cache of frozen per-profile coefficient tables.  See the module
/// docs for keying and concurrency semantics.
pub struct PreparedCache {
    inner: Mutex<LruState>,
    capacity: usize,
    hits: AtomicU64,
    misses: AtomicU64,
    evictions: AtomicU64,
    freeze_ns: AtomicU64,
}

impl PreparedCache {
    /// A cache holding at most `capacity` frozen tables (clamped ≥ 1).
    pub fn new(capacity: usize) -> PreparedCache {
        PreparedCache {
            inner: Mutex::new(LruState { map: HashMap::new(), order: Vec::new() }),
            capacity: capacity.max(1),
            hits: AtomicU64::new(0),
            misses: AtomicU64::new(0),
            evictions: AtomicU64::new(0),
            freeze_ns: AtomicU64::new(0),
        }
    }

    /// Configured capacity.
    pub fn capacity(&self) -> usize {
        self.capacity
    }

    /// Fetch the frozen tables for (`hash`, `kind`), freezing from
    /// `phmm` on a miss.  Returns the shared entry plus `true` when it
    /// was served from cache.
    pub fn get_or_freeze(
        &self,
        hash: u64,
        kind: EngineKind,
        phmm: &Phmm,
    ) -> Result<(Arc<PreparedAny>, bool)> {
        let key = (hash, kind);
        {
            let mut inner = self.inner.lock().unwrap();
            if let Some(entry) = inner.map.get(&key).cloned() {
                inner.touch(key);
                self.hits.fetch_add(1, Ordering::Relaxed);
                return Ok((entry, true));
            }
        }
        // Freeze outside the lock: a slow freeze must not block hits on
        // other profiles.
        self.misses.fetch_add(1, Ordering::Relaxed);
        // Fault-injection site on the miss path: an `Error` action maps
        // to a freeze failure, a `Panic` action exercises the serving
        // layer's per-job panic containment.
        crate::failpoint!("cache::insert", |msg: String| {
            crate::error::ApHmmError::Runtime(format!("failpoint cache::insert: {msg}"))
        });
        let t0 = std::time::Instant::now();
        let fresh = Arc::new(PreparedAny::freeze(kind, phmm)?);
        self.freeze_ns.fetch_add(t0.elapsed().as_nanos() as u64, Ordering::Relaxed);
        let mut inner = self.inner.lock().unwrap();
        let entry = match inner.map.get(&key) {
            // A racing freeze for the same key won the insert; share it
            // and drop ours.
            Some(existing) => Arc::clone(existing),
            None => {
                inner.map.insert(key, Arc::clone(&fresh));
                fresh
            }
        };
        inner.touch(key);
        while inner.map.len() > self.capacity {
            let victim = inner.order.remove(0);
            inner.map.remove(&victim);
            self.evictions.fetch_add(1, Ordering::Relaxed);
        }
        Ok((entry, false))
    }

    /// Drop every entry (used when a tenant re-registers profiles and
    /// wants a cold cache; counters are preserved).
    pub fn clear(&self) {
        let mut inner = self.inner.lock().unwrap();
        inner.map.clear();
        inner.order.clear();
    }

    /// Snapshot the counters.
    pub fn stats(&self) -> CacheStats {
        CacheStats {
            hits: self.hits.load(Ordering::Relaxed),
            misses: self.misses.load(Ordering::Relaxed),
            evictions: self.evictions.load(Ordering::Relaxed),
            entries: self.inner.lock().unwrap().map.len() as u64,
            freeze_ns: self.freeze_ns.load(Ordering::Relaxed),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::phmm::EcDesignParams;
    use crate::seq::Sequence;
    use crate::sim::XorShift;
    use crate::testutil;

    fn ec_graph(seed: u64, len: usize) -> Phmm {
        let mut rng = XorShift::new(seed);
        let reference =
            Sequence::from_symbols("r", testutil::random_seq(&mut rng, len, 4));
        Phmm::error_correction(&reference, &EcDesignParams::default()).unwrap()
    }

    #[test]
    fn hash_is_content_addressed() {
        let a = ec_graph(1, 30);
        let b = a.clone();
        let c = ec_graph(2, 30);
        assert_eq!(profile_hash(&a), profile_hash(&b), "clones must collide");
        assert_ne!(profile_hash(&a), profile_hash(&c), "different content must differ");
        // A single parameter nudge changes the key.
        let mut d = a.clone();
        d.out_prob[0] = (d.out_prob[0] * 0.5).max(1e-6);
        assert_ne!(profile_hash(&a), profile_hash(&d));
    }

    #[test]
    fn hash_separates_adjacent_field_boundaries() {
        // Regression for the unprefixed hash: all graph arrays were
        // fed back-to-back, so shifting one element across a field
        // boundary left the concatenated byte stream — and therefore
        // the cache key — unchanged.  In a multi-tenant cache that is
        // one tenant being served another profile's frozen tables.
        // profile_hash reads fields only, so the fixtures need not be
        // valid graphs.
        fn raw(position: Vec<u32>, out_ptr: Vec<u32>, out_to: Vec<u32>, out_prob: Vec<f32>) -> Phmm {
            Phmm {
                design: PhmmDesign::ErrorCorrection,
                alphabet: crate::seq::DNA,
                kinds: Vec::new(),
                position,
                out_ptr,
                out_to,
                out_prob,
                emissions: Vec::new(),
                f_init: Vec::new(),
            }
        }
        // position | out_ptr boundary: [1,2]+[3] vs [1]+[2,3] — the
        // concatenated u32 stream is [1,2,3] both times.
        let a = raw(vec![1, 2], vec![3], Vec::new(), Vec::new());
        let b = raw(vec![1], vec![2, 3], Vec::new(), Vec::new());
        assert_ne!(
            profile_hash(&a),
            profile_hash(&b),
            "shifting an element across position/out_ptr must change the hash"
        );
        // out_to | out_prob boundary: 1.0f32 has the same bit pattern
        // as the u32 1065353216, so the unprefixed streams coincide.
        let c = raw(Vec::new(), Vec::new(), vec![7, 1.0f32.to_bits()], Vec::new());
        let d = raw(Vec::new(), Vec::new(), vec![7], vec![1.0]);
        assert_ne!(
            profile_hash(&c),
            profile_hash(&d),
            "shifting an element across out_to/out_prob must change the hash"
        );
    }

    #[test]
    fn second_lookup_is_a_hit() {
        let g = ec_graph(3, 25);
        let h = profile_hash(&g);
        let cache = PreparedCache::new(4);
        let (_, hit0) = cache.get_or_freeze(h, EngineKind::Sparse, &g).unwrap();
        let (_, hit1) = cache.get_or_freeze(h, EngineKind::Sparse, &g).unwrap();
        assert!(!hit0);
        assert!(hit1);
        let s = cache.stats();
        assert_eq!(s.hits, 1);
        assert_eq!(s.misses, 1);
        assert_eq!(s.entries, 1);
        // The same profile under a different engine is its own entry.
        let (_, hit2) = cache.get_or_freeze(h, EngineKind::Banded, &g).unwrap();
        assert!(!hit2);
        assert_eq!(cache.stats().entries, 2);
    }

    #[test]
    fn lru_evicts_the_coldest_entry() {
        let g1 = ec_graph(4, 20);
        let g2 = ec_graph(5, 20);
        let g3 = ec_graph(6, 20);
        let cache = PreparedCache::new(2);
        cache.get_or_freeze(profile_hash(&g1), EngineKind::Sparse, &g1).unwrap();
        cache.get_or_freeze(profile_hash(&g2), EngineKind::Sparse, &g2).unwrap();
        // Touch g1 so g2 is the LRU victim.
        cache.get_or_freeze(profile_hash(&g1), EngineKind::Sparse, &g1).unwrap();
        cache.get_or_freeze(profile_hash(&g3), EngineKind::Sparse, &g3).unwrap();
        let s = cache.stats();
        assert_eq!(s.evictions, 1);
        assert_eq!(s.entries, 2);
        // g1 survived (hit), g2 was evicted (miss re-freezes).
        let (_, hit) = cache.get_or_freeze(profile_hash(&g1), EngineKind::Sparse, &g1).unwrap();
        assert!(hit);
        let (_, hit) = cache.get_or_freeze(profile_hash(&g2), EngineKind::Sparse, &g2).unwrap();
        assert!(!hit);
    }

    #[test]
    fn xla_kind_is_rejected() {
        let g = ec_graph(7, 20);
        let cache = PreparedCache::new(2);
        assert!(cache.get_or_freeze(profile_hash(&g), EngineKind::Xla, &g).is_err());
    }

    #[test]
    fn prepared_tables_are_filter_agnostic() {
        // The module-doc invariant: profile_hash has no FilterConfig /
        // GatherKind / TrainConfig input, frozen tables bake in
        // parameters only, and therefore ONE cached entry must serve
        // every runtime filter/gather configuration bit-identically to
        // a table frozen fresh for that configuration.
        use crate::baumwelch::{FilterConfig, ForwardOptions, GatherKind};
        let g = ec_graph(11, 60);
        let mut rng = XorShift::new(12);
        let read = Sequence::from_symbols("o", testutil::random_seq(&mut rng, 40, 4));
        let h = profile_hash(&g);

        let cache = PreparedCache::new(2);
        let (entry, _) = cache.get_or_freeze(h, EngineKind::Sparse, &g).unwrap();
        // Exercising the frozen tables (including the lazy banded
        // lowering built by posterior decode) must not perturb the
        // content hash: the hash reads the graph, never the tables.
        entry.posterior(&g, &read).unwrap();
        assert_eq!(h, profile_hash(&g), "freezing/decoding changed the profile hash");

        let mut scratch = entry.make_scratch(&g);
        for filter in [
            FilterConfig::None,
            FilterConfig::Sort { size: 50 },
            FilterConfig::histogram_default(),
        ] {
            for gather in [GatherKind::Adaptive, GatherKind::Csr, GatherKind::DenseTile] {
                let opts = ForwardOptions { filter, gather, ..Default::default() };
                // A fresh freeze performed "for" this runtime config...
                let fresh = PreparedAny::freeze(EngineKind::Sparse, &g).unwrap();
                let mut fs = fresh.make_scratch(&g);
                let want = fresh.score(&g, &read, &opts, &mut fs).unwrap();
                // ...is indistinguishable from the one shared entry.
                let got = entry.score(&g, &read, &opts, &mut scratch).unwrap();
                assert_eq!(
                    want.loglik.to_bits(),
                    got.loglik.to_bits(),
                    "cached entry diverged under {filter:?}/{gather:?}"
                );
                // And every configuration maps to the same cache key:
                // the second lookup is a hit, never a re-freeze.
                let (_, hit) = cache.get_or_freeze(h, EngineKind::Sparse, &g).unwrap();
                assert!(hit, "runtime config must not influence the cache key");
            }
        }
    }

    #[test]
    fn cached_tables_score_identically_to_fresh_ones() {
        use crate::baumwelch::ForwardOptions;
        let g = ec_graph(8, 40);
        let mut rng = XorShift::new(9);
        let read = Sequence::from_symbols("o", testutil::random_seq(&mut rng, 30, 4));
        let cache = PreparedCache::new(2);
        let h = profile_hash(&g);
        for kind in [EngineKind::Sparse, EngineKind::Banded] {
            let fresh = PreparedAny::freeze(kind, &g).unwrap();
            let mut s1 = fresh.make_scratch(&g);
            let a = fresh.score(&g, &read, &ForwardOptions::default(), &mut s1).unwrap();
            let (cached, _) = cache.get_or_freeze(h, kind, &g).unwrap();
            let (cached2, hit) = cache.get_or_freeze(h, kind, &g).unwrap();
            assert!(hit);
            assert!(Arc::ptr_eq(&cached, &cached2));
            let mut s2 = cached2.make_scratch(&g);
            let b = cached2.score(&g, &read, &ForwardOptions::default(), &mut s2).unwrap();
            assert_eq!(a.loglik.to_bits(), b.loglik.to_bits(), "{kind:?}");
        }
    }
}
