//! The streaming multi-tenant serving subsystem.
//!
//! This module turns the batch pipeline into a long-lived service (the
//! ROADMAP's "production-scale system serving heavy traffic" north
//! star):
//!
//! * [`queue`] — a bounded blocking MPMC job queue with real admission
//!   control: producers block or get `Busy` when `queue_depth` jobs are
//!   pending, so backpressure finally governs I/O-bound producers.  The
//!   coordinator streams its chunk jobs through the same queue type —
//!   one producer among many rather than a parallel code path.
//! * [`cache`] — an LRU cache of frozen per-profile coefficient tables
//!   ([`crate::baumwelch::PreparedAny`]) keyed by profile content hash,
//!   with hit/miss/evict counters.  ApHMM memoizes frozen coefficients
//!   per EM iteration (§4.2–4.3); the cache extends the same reuse
//!   **across requests**: every client scoring against the same profile
//!   shares one frozen table.
//! * [`session`] — typed requests/responses, the multi-tenant profile
//!   registry, and the newline-delimited wire protocol (stdin or TCP).
//! * [`Server`] (here) — owns one [`WorkerPool`], drains the queue with
//!   `n_workers` participants, micro-batches same-profile `Score`
//!   requests for locality, and reports per-request
//!   [`ReadStats`]/latency plus queue/cache/latency-histogram metrics
//!   through [`crate::coordinator::Metrics`].
//!
//! # Shutdown: drain vs abort
//!
//! [`Server::shutdown`]`(drain = true)` closes the queue gracefully:
//! admitted requests complete, then workers exit.  `drain = false`
//! aborts: the backlog is discarded and every queued request receives
//! an `Error` response.  Dropping a `Server` aborts — a drop mid-stream
//! must not hang on an arbitrary backlog.  Both paths join the
//! dispatcher and (via [`WorkerPool`]'s own drop) every helper thread:
//! no threads outlive the server (asserted by
//! `tests/server_integration.rs`).

pub mod cache;
pub mod queue;
pub mod session;

pub use cache::{profile_hash, CacheStats, PreparedCache};
pub use queue::{JobQueue, PushError, QueueStats};
pub use session::{
    serve_connection, serve_stdio, serve_tcp, ProfileEntry, ProfileRegistry, RankedHit, Request,
    Response, ResponseBody, SessionEnd,
};

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{mpsc, Arc};
use std::thread::JoinHandle;
use std::time::Instant;

use crate::baumwelch::{EngineKind, ReadStats, ScratchAny, TrainConfig};
use crate::coordinator::{Metrics, MetricsSummary};
use crate::error::{ApHmmError, Result};
use crate::phmm::{EcDesignParams, Phmm};
use crate::pool::WorkerPool;
use crate::seq::Alphabet;

use session::ExecCtx;

/// Serving configuration.
#[derive(Clone, Copy, Debug)]
pub struct ServerConfig {
    /// Queue-draining worker participants (the dispatcher thread plus
    /// `n_workers - 1` pool helpers).
    pub n_workers: usize,
    /// Bounded queue depth: the admission-control backpressure bound.
    pub queue_depth: usize,
    /// Frozen-coefficient cache capacity (entries).
    pub cache_capacity: usize,
    /// Default engine for requests that don't name one.
    pub engine: EngineKind,
    /// Training parameters for `Correct` requests (`engine` is
    /// overridden per request; `filter` also governs scoring).
    pub train: TrainConfig,
    /// EC design parameters for `Correct` requests and `register`ed
    /// profiles.
    pub design: EcDesignParams,
    /// Maximum same-profile `Score` requests fused into one worker
    /// turn (1 disables micro-batching).
    pub microbatch: usize,
    /// `Search` responses report at most this many hits.
    pub max_hits: usize,
    /// k-mer size of the `Search` pre-filter (k-mers are taken from
    /// each profile's decoded consensus at registration time).
    pub prefilter_k: usize,
    /// Minimum shared-k-mer fraction for a profile to be forward-scored
    /// by `Search` (0 disables the pre-filter and scores every
    /// profile — the safe default; the `search` CLI sets the hmmsearch
    /// screening default).
    pub prefilter_min_frac: f64,
    /// Run posterior decoding on this many top `Search` hits (the
    /// hmmsearch domain post-processing stage; 0 disables it).
    pub posterior_hits: usize,
    /// Alphabet of the wire protocol's sequences.
    pub alphabet: Alphabet,
}

impl Default for ServerConfig {
    fn default() -> Self {
        ServerConfig {
            n_workers: 4,
            queue_depth: 16,
            cache_capacity: 64,
            engine: EngineKind::Sparse,
            train: TrainConfig { max_iters: 2, ..Default::default() },
            design: EcDesignParams::default(),
            microbatch: 8,
            max_hits: 10,
            prefilter_k: 3,
            prefilter_min_frac: 0.0,
            posterior_hits: 0,
            alphabet: crate::seq::DNA,
        }
    }
}

/// One admitted request: the typed body plus its reply channel and
/// admission timestamp (per-request latency is measured from here).
struct Job {
    id: u64,
    engine: EngineKind,
    body: Request,
    reply: mpsc::Sender<Response>,
    enqueued: Instant,
}

/// Handle to one submitted request.
pub struct Ticket {
    /// Request id (echoed in the [`Response`]).
    pub id: u64,
    engine: EngineKind,
    rx: mpsc::Receiver<Response>,
}

impl Ticket {
    /// Block until the response arrives.  If the server aborted before
    /// the request ran, a synthesized `Error` response is returned —
    /// waiting never hangs.
    pub fn wait(self) -> Response {
        match self.rx.recv() {
            Ok(resp) => resp,
            Err(_) => Response {
                id: self.id,
                engine: self.engine,
                latency_ns: 0,
                stats: ReadStats::default(),
                body: ResponseBody::Error {
                    message: "request dropped: server aborted".into(),
                },
            },
        }
    }

    /// Non-blocking poll; `None` while the request is still in flight.
    pub fn try_wait(&self) -> Option<Response> {
        self.rx.try_recv().ok()
    }
}

struct Shared {
    cfg: ServerConfig,
    queue: JobQueue<Job>,
    registry: ProfileRegistry,
    cache: PreparedCache,
    pool: WorkerPool,
    metrics: Metrics,
    next_id: AtomicU64,
    started: Instant,
}

/// A long-lived multi-tenant server: one shared [`WorkerPool`], one
/// bounded [`JobQueue`], one cross-request [`PreparedCache`].  See the
/// module docs for the execution model and shutdown semantics.
pub struct Server {
    shared: Arc<Shared>,
    dispatcher: Option<JoinHandle<()>>,
}

impl Server {
    /// Start the server: spawns the dispatcher thread, which fans out
    /// over `cfg.n_workers` pool participants draining the queue.
    pub fn start(cfg: ServerConfig) -> Server {
        let workers = cfg.n_workers.max(1);
        let estep = cfg.train.n_workers.max(1);
        // The dispatcher occupies participant slot 0; helpers cover the
        // other worker slots plus each worker's E-step fan-out.
        let helpers = (workers - 1) + workers * (estep - 1);
        let shared = Arc::new(Shared {
            queue: JobQueue::new(cfg.queue_depth),
            registry: ProfileRegistry::default(),
            cache: PreparedCache::new(cfg.cache_capacity),
            pool: WorkerPool::new(helpers),
            metrics: Metrics::default(),
            next_id: AtomicU64::new(0),
            started: Instant::now(),
            cfg,
        });
        let dispatcher = {
            let shared = Arc::clone(&shared);
            std::thread::spawn(move || {
                let s: &Shared = &shared;
                s.pool.scope(s.cfg.n_workers.max(1), |_slot| worker_loop(s));
            })
        };
        Server { shared, dispatcher: Some(dispatcher) }
    }

    /// The configuration the server was started with.
    pub fn config(&self) -> &ServerConfig {
        &self.shared.cfg
    }

    /// Register (or replace) a named profile; returns its content hash.
    /// For `Search`-heavy workloads size `cache_capacity` at or above
    /// the number of registered profiles: `Search` scans every profile
    /// in registration order, which is the LRU worst case when the
    /// cache is smaller than the registry (every lookup evicts the
    /// next-needed entry).
    pub fn register_profile(&self, name: &str, phmm: Phmm) -> u64 {
        self.shared.registry.register(name, phmm, self.shared.cfg.prefilter_k)
    }

    /// The profile registry (shared by every session).
    pub fn registry(&self) -> &ProfileRegistry {
        &self.shared.registry
    }

    fn make_job(&self, engine: Option<EngineKind>, body: Request) -> (Job, Ticket) {
        let engine = engine.unwrap_or(self.shared.cfg.engine);
        let id = self.shared.next_id.fetch_add(1, Ordering::Relaxed);
        let (tx, rx) = mpsc::channel();
        (
            Job { id, engine, body, reply: tx, enqueued: Instant::now() },
            Ticket { id, engine, rx },
        )
    }

    /// Submit a request, **blocking while the queue is full** (the
    /// admission-control path for streaming clients).  Fails only once
    /// the server is shut down.
    pub fn submit(&self, engine: Option<EngineKind>, body: Request) -> Result<Ticket> {
        let (job, ticket) = self.make_job(engine, body);
        self.shared.queue.push(job).map_err(|job| {
            ApHmmError::Coordinator(format!(
                "server is shut down: {} request refused",
                job.body.kind_name()
            ))
        })?;
        Ok(ticket)
    }

    /// Submit without blocking: [`PushError::Busy`] hands the request
    /// back when the queue is at `queue_depth` (the caller may retry,
    /// shed load, or block on [`Server::submit`]).
    pub fn try_submit(
        &self,
        engine: Option<EngineKind>,
        body: Request,
    ) -> std::result::Result<Ticket, PushError<Request>> {
        let (job, ticket) = self.make_job(engine, body);
        match self.shared.queue.try_push(job) {
            Ok(()) => Ok(ticket),
            Err(PushError::Busy(job)) => Err(PushError::Busy(job.body)),
            Err(PushError::Closed(job)) => Err(PushError::Closed(job.body)),
        }
    }

    /// Queue gauges (depth, high-water, producer blocks, totals).
    pub fn queue_stats(&self) -> QueueStats {
        self.shared.queue.stats()
    }

    /// Cross-request cache counters.
    pub fn cache_stats(&self) -> CacheStats {
        self.shared.cache.stats()
    }

    /// Metrics snapshot over the server's lifetime so far (queue gauges
    /// folded in).
    pub fn metrics_summary(&self) -> MetricsSummary {
        let qs = self.shared.queue.stats();
        self.shared.metrics.absorb_queue(qs.depth, qs.high_water, qs.producer_blocks);
        self.shared.metrics.summary(self.shared.started.elapsed().as_secs_f64())
    }

    /// One-line `stats` response for the wire protocol.
    pub fn stats_line(&self) -> String {
        let m = self.metrics_summary();
        let c = self.cache_stats();
        format!(
            "stats jobs_done={} jobs_failed={} p50_ms={:.3} p99_ms={:.3} queue_depth={} \
             queue_high_water={} producer_blocks={} cache_hits={} cache_misses={} \
             cache_evictions={} profiles={}",
            m.jobs_done,
            m.jobs_failed,
            m.latency_p50_ms,
            m.latency_p99_ms,
            m.queue_depth,
            m.queue_high_water,
            m.producer_blocks,
            c.hits,
            c.misses,
            c.evictions,
            self.shared.registry.len(),
        )
    }

    /// Weak probe on the pool's shared state: upgradeable only while
    /// the pool or one of its helper threads is alive.  Tests use it to
    /// prove no thread leaks after the server is dropped.
    pub fn pool_liveness(&self) -> std::sync::Weak<dyn std::any::Any + Send + Sync> {
        self.shared.pool.liveness()
    }

    /// Stop the server.  `drain = true`: complete every admitted
    /// request, then stop (graceful).  `drain = false`: discard the
    /// backlog, sending each queued request an `Error` response
    /// (abort).  Idempotent; joins the dispatcher either way.
    pub fn shutdown(&mut self, drain: bool) {
        if drain {
            self.shared.queue.close();
        } else {
            for job in self.shared.queue.abort() {
                let _ = job.reply.send(Response {
                    id: job.id,
                    engine: job.engine,
                    latency_ns: job.enqueued.elapsed().as_nanos() as u64,
                    stats: ReadStats::default(),
                    body: ResponseBody::Error {
                        message: "request aborted: server shutting down".into(),
                    },
                });
            }
        }
        if let Some(handle) = self.dispatcher.take() {
            let _ = handle.join();
        }
    }
}

impl Drop for Server {
    /// Dropping aborts (see the module docs): a drop mid-stream must
    /// not hang on an arbitrary backlog.  Call
    /// [`Server::shutdown`]`(true)` first for a graceful drain.
    fn drop(&mut self) {
        self.shutdown(false);
    }
}

/// One queue-draining participant: pop, micro-batch compatible `Score`
/// requests, execute, respond, repeat until the queue reports
/// exhaustion.
fn worker_loop(shared: &Shared) {
    let mut scratch = ScratchAny::None;
    while let Some(job) = shared.queue.pop() {
        if let Request::Score { profile, .. } = &job.body {
            // Micro-batch: pull further Score requests for the same
            // (profile, engine) so they run back-to-back through one
            // frozen table and a warm scratch, instead of interleaving
            // with unrelated profiles across workers.
            let name = profile.clone();
            let engine = job.engine;
            let mut batch = vec![job];
            while batch.len() < shared.cfg.microbatch.max(1) {
                let more = shared.queue.try_pop_where(|j| {
                    j.engine == engine
                        && matches!(&j.body, Request::Score { profile: p, .. } if *p == name)
                });
                match more {
                    Some(j) => batch.push(j),
                    None => break,
                }
            }
            for j in batch {
                process_one(shared, j, &mut scratch);
            }
        } else {
            process_one(shared, job, &mut scratch);
        }
    }
}

fn process_one(shared: &Shared, job: Job, scratch: &mut ScratchAny) {
    let ctx = ExecCtx {
        registry: &shared.registry,
        cache: &shared.cache,
        pool: &shared.pool,
        cfg: &shared.cfg,
    };
    let (body, stats) = match session::execute(&ctx, job.engine, &job.body, scratch) {
        Ok(done) => done,
        Err(e) => {
            shared.metrics.record_failure();
            (ResponseBody::Error { message: e.to_string() }, ReadStats::default())
        }
    };
    let latency_ns = job.enqueued.elapsed().as_nanos() as u64;
    if !matches!(body, ResponseBody::Error { .. }) {
        shared.metrics.record(latency_ns, stats.timesteps, stats.states_processed);
    }
    // A dropped ticket just means the client stopped waiting.
    let _ = job.reply.send(Response {
        id: job.id,
        engine: job.engine,
        latency_ns,
        stats,
        body,
    });
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::seq::Sequence;
    use crate::sim::{simulate_read, ErrorProfile, XorShift};
    use crate::testutil;

    fn dna(rng: &mut XorShift, len: usize) -> Sequence {
        Sequence::from_symbols("s", testutil::random_seq(rng, len, 4))
    }

    #[test]
    fn score_round_trip_hits_the_cache_second_time() {
        let mut rng = XorShift::new(71);
        let reference = dna(&mut rng, 60);
        let read = simulate_read(&mut rng, &reference, 0, 60, &ErrorProfile::pacbio(), 0).seq;
        let mut server = Server::start(ServerConfig::default());
        let phmm = Phmm::error_correction(&reference, &EcDesignParams::default()).unwrap();
        server.register_profile("chr1", phmm);

        let r1 = server
            .submit(None, Request::Score { profile: "chr1".into(), read: read.clone() })
            .unwrap()
            .wait();
        let r2 = server
            .submit(None, Request::Score { profile: "chr1".into(), read })
            .unwrap()
            .wait();
        let (ll1, hit1) = match r1.body {
            ResponseBody::Score { loglik, cache_hit, .. } => (loglik, cache_hit),
            other => panic!("unexpected response {other:?}"),
        };
        let (ll2, hit2) = match r2.body {
            ResponseBody::Score { loglik, cache_hit, .. } => (loglik, cache_hit),
            other => panic!("unexpected response {other:?}"),
        };
        assert_eq!(ll1.to_bits(), ll2.to_bits());
        assert!(!hit1, "first request must freeze");
        assert!(hit2, "second request must reuse the frozen tables");
        let c = server.cache_stats();
        assert_eq!(c.misses, 1);
        assert_eq!(c.hits, 1);
        assert!(r1.latency_ns > 0);
        server.shutdown(true);
    }

    #[test]
    fn unknown_profile_is_an_error_response_not_a_crash() {
        let mut rng = XorShift::new(72);
        let read = dna(&mut rng, 20);
        let mut server = Server::start(ServerConfig::default());
        let resp = server
            .submit(None, Request::Score { profile: "nope".into(), read })
            .unwrap()
            .wait();
        assert!(matches!(resp.body, ResponseBody::Error { .. }));
        assert_eq!(server.metrics_summary().jobs_failed, 1);
        server.shutdown(true);
        // The server still answers nothing after shutdown.
        assert!(server
            .submit(None, Request::Search { read: dna(&mut rng, 10) })
            .is_err());
    }

    #[test]
    fn graceful_shutdown_completes_admitted_requests() {
        let mut rng = XorShift::new(73);
        let reference = dna(&mut rng, 50);
        let reads: Vec<_> = (0..4)
            .map(|i| simulate_read(&mut rng, &reference, 0, 50, &ErrorProfile::pacbio(), i).seq)
            .collect();
        let mut server = Server::start(ServerConfig {
            n_workers: 2,
            queue_depth: 8,
            ..Default::default()
        });
        let tickets: Vec<_> = (0..6)
            .map(|_| {
                server
                    .submit(
                        None,
                        Request::Correct {
                            reference: reference.clone(),
                            reads: reads.clone(),
                        },
                    )
                    .unwrap()
            })
            .collect();
        server.shutdown(true);
        for t in tickets {
            let resp = t.wait();
            match resp.body {
                ResponseBody::Correct { consensus, .. } => assert!(!consensus.is_empty()),
                other => panic!("drain lost a request: {other:?}"),
            }
        }
    }

    #[test]
    fn search_ranks_registered_profiles() {
        let mut rng = XorShift::new(74);
        let a = dna(&mut rng, 60);
        let b = dna(&mut rng, 60);
        let mut server = Server::start(ServerConfig::default());
        server.register_profile(
            "a",
            Phmm::error_correction(&a, &EcDesignParams::default()).unwrap(),
        );
        server.register_profile(
            "b",
            Phmm::error_correction(&b, &EcDesignParams::default()).unwrap(),
        );
        let query = simulate_read(&mut rng, &a, 0, 60, &ErrorProfile::pacbio(), 0).seq;
        let resp = server.submit(None, Request::Search { read: query }).unwrap().wait();
        match resp.body {
            ResponseBody::Search { hits, scored } => {
                assert_eq!(scored, 2);
                assert_eq!(hits[0].profile, "a", "query from profile a must rank a first");
            }
            other => panic!("unexpected response {other:?}"),
        }
        server.shutdown(true);
    }
}
