//! The streaming multi-tenant serving subsystem.
//!
//! This module turns the batch pipeline into a long-lived service (the
//! ROADMAP's "production-scale system serving heavy traffic" north
//! star):
//!
//! * [`queue`] — a bounded blocking MPMC job queue with real admission
//!   control: producers block or get `Busy` when `queue_depth` jobs are
//!   pending, so backpressure finally governs I/O-bound producers.  The
//!   coordinator streams its chunk jobs through the same queue type —
//!   one producer among many rather than a parallel code path.  The
//!   server itself runs on the tenant-aware [`TenantQueue`] layer:
//!   per-tenant queued/in-flight quotas (an at-quota tenant is refused
//!   with [`AdmitError::AtQuota`] while others keep admitting) and
//!   priority classes popped high-first.
//! * [`cache`] — an LRU cache of frozen per-profile coefficient tables
//!   ([`crate::baumwelch::PreparedAny`]) keyed by profile content hash,
//!   with hit/miss/evict counters.  ApHMM memoizes frozen coefficients
//!   per EM iteration (§4.2–4.3); the cache extends the same reuse
//!   **across requests**: every client scoring against the same profile
//!   shares one frozen table.
//! * [`session`] — typed requests/responses, the multi-tenant profile
//!   registry, and the newline-delimited wire protocol (stdin or TCP).
//! * [`Server`] (here) — owns one [`WorkerPool`], drains the queue with
//!   `n_workers` participants, micro-batches same-profile `Score`
//!   requests for locality, and reports per-request
//!   [`ReadStats`]/latency plus queue/cache/latency-histogram metrics
//!   through [`crate::coordinator::Metrics`].
//!
//! # Shutdown: drain vs abort
//!
//! [`Server::shutdown`]`(drain = true)` closes the queue gracefully:
//! admitted requests complete, then workers exit.  `drain = false`
//! aborts: the backlog is discarded and every queued request receives
//! an `Error` response.  Dropping a `Server` aborts — a drop mid-stream
//! must not hang on an arbitrary backlog.  Both paths join the
//! dispatcher and (via [`WorkerPool`]'s own drop) every helper thread:
//! no threads outlive the server (asserted by
//! `tests/server_integration.rs`).

pub mod cache;
pub mod queue;
pub mod session;

pub use cache::{profile_hash, CacheStats, PreparedCache};
pub use queue::{
    AdmitError, JobQueue, Priority, PushError, QueueStats, TenantQueue, TenantQuota, TenantStats,
};
pub use session::{
    serve_connection, serve_stdio, serve_tcp, ProfileEntry, ProfileRegistry, RankedHit, Request,
    Response, ResponseBody, SessionEnd,
};

pub use crate::cancel::CancelToken;
pub use crate::coordinator::FailureCause;

/// Tenant id used by submissions that don't name one (the single-tenant
/// Rust API paths and wire sessions before a `tenant` command).
pub const DEFAULT_TENANT: &str = "default";

/// Reserved owner of profiles registered through the trusted in-process
/// API ([`Server::register_profile`]).  Wire sessions can never assume
/// it — the `tenant` command rejects the reserved `__`-prefixed
/// namespace — so an anonymous connection cannot replace an
/// operator-registered profile (ownership-checked replacement requires
/// the owner id).
pub const OPERATOR_TENANT: &str = "__operator__";

use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{mpsc, Arc};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use crate::baumwelch::{
    full_scratch_estimate, EngineKind, ReadStats, ScratchAny, ScratchMode, TrainConfig, MAX_STRIPE,
};
use crate::coordinator::{Metrics, MetricsSummary, StageTimes};
use crate::error::{ApHmmError, CancelCause, Result};
use crate::obs::{PromWriter, Stage, Timeline, TraceRing};
use crate::phmm::{EcDesignParams, Phmm};
use crate::pool::{panic_message, WorkerPool};
use crate::seq::{Alphabet, Sequence};

use session::ExecCtx;

/// Serving configuration.
#[derive(Clone, Copy, Debug)]
pub struct ServerConfig {
    /// Queue-draining worker participants (the dispatcher thread plus
    /// `n_workers - 1` pool helpers).
    pub n_workers: usize,
    /// Bounded queue depth: the admission-control backpressure bound.
    pub queue_depth: usize,
    /// Frozen-coefficient cache capacity (entries).
    pub cache_capacity: usize,
    /// Default engine for requests that don't name one.
    pub engine: EngineKind,
    /// Training parameters for `Correct` requests (`engine` is
    /// overridden per request; `filter` also governs scoring).
    pub train: TrainConfig,
    /// EC design parameters for `Correct` requests and `register`ed
    /// profiles.
    pub design: EcDesignParams,
    /// Maximum same-profile `Score` requests fused into one worker
    /// turn (1 disables micro-batching).
    pub microbatch: usize,
    /// `Search` responses report at most this many hits.
    pub max_hits: usize,
    /// k-mer size of the `Search` pre-filter (k-mers are taken from
    /// each profile's decoded consensus at registration time).
    pub prefilter_k: usize,
    /// Minimum shared-k-mer fraction for a profile to be forward-scored
    /// by `Search` (0 disables the pre-filter and scores every
    /// profile — the safe default; the `search` CLI sets the hmmsearch
    /// screening default).
    pub prefilter_min_frac: f64,
    /// Run posterior decoding on this many top `Search` hits (the
    /// hmmsearch domain post-processing stage; 0 disables it).
    pub posterior_hits: usize,
    /// Alphabet of the wire protocol's sequences.
    pub alphabet: Alphabet,
    /// Per-tenant admission caps (identical for every tenant —
    /// including the shared `default` tenant of anonymous sessions and
    /// the tenant-less Rust API; the default is unlimited, i.e.
    /// single-tenant behavior).
    pub tenant_quota: TenantQuota,
    /// Upper bound on one `register-profile` wire payload, checked
    /// before any payload byte is read or allocated.
    pub max_profile_bytes: usize,
    /// Registry bound for **untrusted wire registrations**: total
    /// profiles across all tenants.  Each entry stores a full graph +
    /// k-mer set and costs a consensus decode to build, so an
    /// unbounded registry is a one-connection memory/CPU DoS.  The
    /// trusted in-process path is exempt.
    pub max_profiles: usize,
    /// Registry bound for untrusted wire registrations: profiles owned
    /// by one tenant (so one tenant can't consume the whole
    /// `max_profiles` budget).
    pub max_profiles_per_tenant: usize,
    /// Load-shedding high-water fraction of `queue_depth`: once the
    /// backlog reaches `ceil(shed_fraction * queue_depth)` items,
    /// non-blocking low-priority submissions are refused early with
    /// [`AdmitError::Shed`] instead of crowding the queue.  `0.0`
    /// (default) disables shedding; blocking submissions are never
    /// shed.
    pub shed_fraction: f64,
    /// Per-session socket read/write timeout (ms) for TCP sessions, so
    /// an abandoned connection cannot pin its session thread forever.
    /// `0` (default) keeps blocking sockets.
    pub read_timeout_ms: u64,
    /// Idle-session reaping for TCP sessions: a session that has not
    /// completed a command for this long is closed.  Requires
    /// `read_timeout_ms > 0` to take effect (the reaping check runs on
    /// read-timeout wakeups).  `0` (default) never reaps.
    pub idle_timeout_ms: u64,
    /// Slow-request threshold (ms): a request whose end-to-end latency
    /// exceeds this gets its full span timeline logged to stderr as one
    /// JSON line (and retained in the trace ring).  `0` (default)
    /// disables the slow-request log.
    pub slow_request_ms: u64,
    /// Memory-budget admission control (bytes): a `Correct` request
    /// whose estimated full-matrix forward scratch exceeds this bound
    /// *and* whose resolved scratch mode is [`ScratchMode::Full`] is
    /// refused at admission with [`AdmitError::OverMemoryBudget`]
    /// instead of being allowed to OOM a worker.  Requests that would
    /// run checkpointed (explicit `checkpointed`, or `auto` resolving
    /// under the budget) are always admitted — their peak scratch is
    /// O(√T·states) regardless of read length.  `0` (default) disables
    /// the check.  When `train.max_scratch_bytes` is 0,
    /// [`Server::start`] propagates this budget there so
    /// `scratch_mode = auto` resolves against the same bound the
    /// admission check uses.
    pub max_scratch_bytes: usize,
}

impl Default for ServerConfig {
    fn default() -> Self {
        ServerConfig {
            n_workers: 4,
            queue_depth: 16,
            cache_capacity: 64,
            engine: EngineKind::Sparse,
            train: TrainConfig { max_iters: 2, ..Default::default() },
            design: EcDesignParams::default(),
            microbatch: 8,
            max_hits: 10,
            prefilter_k: 3,
            prefilter_min_frac: 0.0,
            posterior_hits: 0,
            alphabet: crate::seq::DNA,
            tenant_quota: TenantQuota::default(),
            max_profile_bytes: 8 << 20,
            max_profiles: 4096,
            max_profiles_per_tenant: 256,
            shed_fraction: 0.0,
            read_timeout_ms: 0,
            idle_timeout_ms: 0,
            slow_request_ms: 0,
            max_scratch_bytes: 0,
        }
    }
}

/// One admitted request: the typed body plus its reply channel,
/// admission timestamp (per-request latency is measured from here),
/// and the cancellation token shared with the submitter's [`Ticket`].
struct Job {
    id: u64,
    engine: EngineKind,
    body: Request,
    reply: mpsc::Sender<Response>,
    enqueued: Instant,
    cancel: CancelToken,
    /// Whether this request's span timeline is retained in the trace
    /// ring (set by a `trace on` session or [`Server::submit_traced`]).
    /// The untraced default never touches the ring.
    trace: bool,
    /// When a worker popped the job (`popped - enqueued` =
    /// queue-wait).  `None` until popped.
    popped: Option<Instant>,
}

/// Handle to one submitted request.
pub struct Ticket {
    /// Request id (echoed in the [`Response`]).
    pub id: u64,
    engine: EngineKind,
    rx: mpsc::Receiver<Response>,
    cancel: CancelToken,
}

impl Ticket {
    /// Cooperatively cancel the request.  The server observes the flag
    /// at its next cancellation point (queue pop, or a chunk boundary
    /// inside the engine) and answers a typed
    /// [`ResponseBody::Failure`] with [`FailureCause::Cancelled`]
    /// instead of a result.  Requests that already completed are
    /// unaffected — cancellation aborts whole requests, never partial
    /// sums, so completed responses stay bit-identical.
    pub fn cancel(&self) {
        self.cancel.cancel();
    }
    /// Block until the response arrives.  If the server aborted before
    /// the request ran, a synthesized `Error` response is returned —
    /// waiting never hangs.
    pub fn wait(self) -> Response {
        match self.rx.recv() {
            Ok(resp) => resp,
            Err(_) => Response {
                id: self.id,
                engine: self.engine,
                latency_ns: 0,
                stats: ReadStats::default(),
                body: ResponseBody::Error {
                    message: "request dropped: server aborted".into(),
                },
            },
        }
    }

    /// Non-blocking poll; `None` while the request is still in flight.
    pub fn try_wait(&self) -> Option<Response> {
        self.rx.try_recv().ok()
    }
}

struct Shared {
    cfg: ServerConfig,
    queue: TenantQueue<Job>,
    registry: ProfileRegistry,
    cache: PreparedCache,
    pool: WorkerPool,
    metrics: Metrics,
    traces: TraceRing,
    next_id: AtomicU64,
    started: Instant,
}

/// A long-lived multi-tenant server: one shared [`WorkerPool`], one
/// bounded tenant-aware [`TenantQueue`], one cross-request
/// [`PreparedCache`].  See the module docs for the execution model and
/// shutdown semantics.
pub struct Server {
    shared: Arc<Shared>,
    dispatcher: Option<JoinHandle<()>>,
}

impl Server {
    /// Start the server: spawns the dispatcher thread, which fans out
    /// over `cfg.n_workers` pool participants draining the queue.
    pub fn start(mut cfg: ServerConfig) -> Server {
        // One budget, two consumers: the admission estimate here and
        // the engine's per-read `ScratchMode::resolve`.  Propagating
        // the serve-level budget into the train config (when the
        // latter doesn't set its own) keeps them in agreement, so
        // `scratch_mode = auto` checkpoints exactly the reads the
        // admission check would otherwise have to refuse.
        if cfg.train.max_scratch_bytes == 0 {
            cfg.train.max_scratch_bytes = cfg.max_scratch_bytes;
        }
        let workers = cfg.n_workers.max(1);
        let estep = cfg.train.n_workers.max(1);
        // The dispatcher occupies participant slot 0; helpers cover the
        // other worker slots plus each worker's E-step fan-out.
        let helpers = (workers - 1) + workers * (estep - 1);
        // High-water mark for load shedding: a fraction of the queue
        // depth, at least 1 slot when enabled, never above the depth
        // itself (beyond which the plain Busy refusal already fires).
        let shed_limit = if cfg.shed_fraction > 0.0 {
            ((cfg.queue_depth as f64 * cfg.shed_fraction).ceil() as usize)
                .clamp(1, cfg.queue_depth.max(1))
        } else {
            0
        };
        let shared = Arc::new(Shared {
            queue: TenantQueue::new_with_shed(cfg.queue_depth, cfg.tenant_quota, shed_limit),
            registry: ProfileRegistry::default(),
            cache: PreparedCache::new(cfg.cache_capacity),
            pool: WorkerPool::new(helpers),
            metrics: Metrics::default(),
            traces: TraceRing::default(),
            next_id: AtomicU64::new(0),
            started: Instant::now(),
            cfg,
        });
        let dispatcher = {
            let shared = Arc::clone(&shared);
            std::thread::spawn(move || {
                let s: &Shared = &shared;
                s.pool.scope(s.cfg.n_workers.max(1), |_slot| worker_loop(s));
            })
        };
        Server { shared, dispatcher: Some(dispatcher) }
    }

    /// The configuration the server was started with.
    pub fn config(&self) -> &ServerConfig {
        &self.shared.cfg
    }

    /// Register (or replace) a named profile; returns its content hash.
    /// This is the **trusted in-process/operator path**: it replaces
    /// unconditionally and owns the profile as [`OPERATOR_TENANT`] — a
    /// reserved id wire sessions cannot assume, so remote clients can
    /// never replace an operator-registered profile.  Untrusted wire
    /// registrations go through [`Server::register_profile_for`], which
    /// enforces ownership.
    /// For `Search`-heavy workloads size `cache_capacity` at or above
    /// the number of registered profiles: `Search` scans every profile
    /// in registration order, which is the LRU worst case when the
    /// cache is smaller than the registry (every lookup evicts the
    /// next-needed entry).
    pub fn register_profile(&self, name: &str, phmm: Phmm) -> u64 {
        self.shared.registry.register(name, OPERATOR_TENANT, phmm, self.shared.cfg.prefilter_k)
    }

    /// Ownership-checked registration on behalf of a (wire) tenant:
    /// same-content re-uploads always succeed; fresh names succeed
    /// while the registry is under `max_profiles` (total) and
    /// `max_profiles_per_tenant` (owned by this tenant); replacing an
    /// existing name with different content is allowed only for its
    /// owner.  See `ProfileRegistry::register_checked`.
    pub fn register_profile_for(&self, tenant: &str, name: &str, phmm: Phmm) -> Result<u64> {
        let cfg = &self.shared.cfg;
        self.shared.registry.register_checked(
            name,
            tenant,
            phmm,
            cfg.prefilter_k,
            cfg.max_profiles,
            cfg.max_profiles_per_tenant,
        )
    }

    /// The profile registry (shared by every session).
    pub fn registry(&self) -> &ProfileRegistry {
        &self.shared.registry
    }

    fn make_job(
        &self,
        engine: Option<EngineKind>,
        body: Request,
        deadline: Option<Duration>,
        trace: bool,
    ) -> (Job, Ticket) {
        let engine = engine.unwrap_or(self.shared.cfg.engine);
        let id = self.shared.next_id.fetch_add(1, Ordering::Relaxed);
        let (tx, rx) = mpsc::channel();
        let cancel = CancelToken::with_deadline(deadline.map(|d| Instant::now() + d));
        (
            Job {
                id,
                engine,
                body,
                reply: tx,
                enqueued: Instant::now(),
                cancel: cancel.clone(),
                trace,
                popped: None,
            },
            Ticket { id, engine, rx, cancel },
        )
    }

    /// Memory-budget admission estimate: `Some(reason)` when `body` is
    /// a `Correct` request holding a read whose full forward matrix
    /// would blow `cfg.max_scratch_bytes` *and* the train config would
    /// actually materialize that matrix ([`ScratchMode::Full`] after
    /// per-read resolution).  Reads that resolve to checkpointed
    /// scratch never refuse — that is the whole point of the mode.
    /// The state count is estimated from the EC design topology
    /// (match/insert/delete per reference base) without building the
    /// profile; like [`full_scratch_estimate`] it deliberately errs
    /// high, so the refusal is conservative in the safe direction.
    fn scratch_refusal(&self, body: &Request) -> Option<String> {
        let budget = self.shared.cfg.max_scratch_bytes;
        if budget == 0 {
            return None;
        }
        let Request::Correct { reference, reads } = body else {
            return None;
        };
        let n_states = 3 * reference.len() + 3;
        let train = &self.shared.cfg.train;
        for read in reads {
            let est = full_scratch_estimate(read.len(), n_states);
            if est > budget as u64
                && train.scratch_mode.resolve(read.len(), n_states, train.max_scratch_bytes)
                    == ScratchMode::Full
            {
                return Some(format!(
                    "estimated full-matrix scratch {est} B for a {} bp read exceeds \
                     max_scratch_bytes={budget} with checkpointing disabled \
                     (train.scratch_mode={}); re-submit with scratch_mode checkpointed \
                     or auto, or raise the budget",
                    read.len(),
                    train.scratch_mode.name(),
                ));
            }
        }
        None
    }

    /// Submit a request as the default tenant at normal priority,
    /// **blocking while the queue is full** (the admission-control path
    /// for streaming clients).  Fails only once the server is shut
    /// down.
    pub fn submit(&self, engine: Option<EngineKind>, body: Request) -> Result<Ticket> {
        self.submit_for(DEFAULT_TENANT, Priority::Normal, engine, body)
    }

    /// Submit a request on behalf of `tenant` at `priority`, blocking
    /// while the queue is globally full **or** the tenant is at its
    /// queued quota (quota pressure becomes backpressure; sheddable
    /// producers use [`Server::try_submit_for`]).
    pub fn submit_for(
        &self,
        tenant: &str,
        priority: Priority,
        engine: Option<EngineKind>,
        body: Request,
    ) -> Result<Ticket> {
        self.submit_with_deadline(tenant, priority, engine, body, None)
    }

    /// [`Server::submit_for`] with an optional per-request deadline
    /// (measured from submission).  A request that exceeds its budget
    /// — whether still queued or mid-compute — answers a typed
    /// [`ResponseBody::Failure`] with
    /// [`FailureCause::DeadlineExceeded`]; requests that finish in
    /// time are byte-for-byte identical to undeadlined runs.
    pub fn submit_with_deadline(
        &self,
        tenant: &str,
        priority: Priority,
        engine: Option<EngineKind>,
        body: Request,
        deadline: Option<Duration>,
    ) -> Result<Ticket> {
        self.submit_traced(tenant, priority, engine, body, deadline, false)
    }

    /// [`Server::submit_with_deadline`] plus per-request tracing: with
    /// `trace = true` the request's span timeline is retained in the
    /// server's trace ring ([`Server::trace_dump`], the `trace-dump`
    /// wire command).  Tracing never changes results — spans are
    /// captured at stage boundaries only, so traced responses are
    /// bit-identical to untraced ones.
    pub fn submit_traced(
        &self,
        tenant: &str,
        priority: Priority,
        engine: Option<EngineKind>,
        body: Request,
        deadline: Option<Duration>,
        trace: bool,
    ) -> Result<Ticket> {
        // The blocking path refuses over-budget work with an error
        // (there is no job to hand back); the non-blocking path
        // answers the typed [`AdmitError::OverMemoryBudget`].
        if let Some(reason) = self.scratch_refusal(&body) {
            self.shared.metrics.record_over_memory_refusal();
            return Err(ApHmmError::Coordinator(format!("over memory budget: {reason}")));
        }
        let (job, ticket) = self.make_job(engine, body, deadline, trace);
        self.shared.queue.push(tenant, priority, job).map_err(|job| {
            ApHmmError::Coordinator(format!(
                "server is shut down: {} request refused",
                job.body.kind_name()
            ))
        })?;
        Ok(ticket)
    }

    /// Submit without blocking: [`PushError::Busy`] hands the request
    /// back when admission is refused (the caller may retry, shed
    /// load, or block on [`Server::submit`]).  Uses the shared
    /// `default` tenant, which is subject to the configured
    /// [`TenantQuota`] like any other — a quota refusal is folded into
    /// `Busy` because this legacy two-variant signature has no quota
    /// case; callers that need to distinguish "server full" from "your
    /// quota" use [`Server::try_submit_for`].
    pub fn try_submit(
        &self,
        engine: Option<EngineKind>,
        body: Request,
    ) -> std::result::Result<Ticket, PushError<Request>> {
        match self.try_submit_for(DEFAULT_TENANT, Priority::Normal, engine, body) {
            Ok(ticket) => Ok(ticket),
            Err(AdmitError::Busy(body))
            | Err(AdmitError::AtQuota(body))
            | Err(AdmitError::Shed(body))
            | Err(AdmitError::OverMemoryBudget(body)) => Err(PushError::Busy(body)),
            Err(AdmitError::Closed(body)) => Err(PushError::Closed(body)),
        }
    }

    /// Submit on behalf of `tenant` without blocking.  The typed
    /// refusal distinguishes a globally full queue
    /// ([`AdmitError::Busy`]) from this tenant being at its quota
    /// ([`AdmitError::AtQuota`]) and from load shedding
    /// ([`AdmitError::Shed`]: the backlog crossed the configured
    /// high-water fraction and `priority` is [`Priority::Low`]) —
    /// at-quota/shed tenants are refused while other work still admits.
    pub fn try_submit_for(
        &self,
        tenant: &str,
        priority: Priority,
        engine: Option<EngineKind>,
        body: Request,
    ) -> std::result::Result<Ticket, AdmitError<Request>> {
        // Pre-queue memory-budget estimate: over-budget full-matrix
        // work is refused here, before it holds a queue slot.
        if let Some(_reason) = self.scratch_refusal(&body) {
            self.shared.metrics.record_over_memory_refusal();
            return Err(AdmitError::OverMemoryBudget(body));
        }
        let (job, ticket) = self.make_job(engine, body, None, false);
        match self.shared.queue.try_push(tenant, priority, job) {
            Ok(()) => Ok(ticket),
            Err(AdmitError::Busy(job)) => Err(AdmitError::Busy(job.body)),
            Err(AdmitError::AtQuota(job)) => Err(AdmitError::AtQuota(job.body)),
            Err(AdmitError::Shed(job)) => {
                self.shared.metrics.record_shed();
                Err(AdmitError::Shed(job.body))
            }
            // Unreachable from the queue (the estimate runs above, not
            // in `try_push`), kept for exhaustiveness.
            Err(AdmitError::OverMemoryBudget(job)) => Err(AdmitError::OverMemoryBudget(job.body)),
            Err(AdmitError::Closed(job)) => Err(AdmitError::Closed(job.body)),
        }
    }

    /// Queue gauges (depth, high-water, producer blocks, totals).
    pub fn queue_stats(&self) -> QueueStats {
        self.shared.queue.stats()
    }

    /// Cross-request cache counters.
    pub fn cache_stats(&self) -> CacheStats {
        self.shared.cache.stats()
    }

    /// Per-tenant admission gauges (queued, in-flight, admitted, quota
    /// refusals), sorted by tenant id.
    pub fn tenant_stats(&self) -> Vec<(String, TenantStats)> {
        self.shared.queue.tenant_stats()
    }

    /// Metrics snapshot over the server's lifetime so far (queue and
    /// per-tenant gauges folded in).
    pub fn metrics_summary(&self) -> MetricsSummary {
        let qs = self.shared.queue.stats();
        self.shared.metrics.absorb_queue(qs.depth, qs.high_water, qs.producer_blocks);
        let tstats = self.shared.queue.tenant_stats();
        for (tenant, ts) in &tstats {
            self.shared.metrics.absorb_tenant(
                tenant,
                ts.admitted,
                ts.quota_refusals,
                ts.queued,
                ts.in_flight,
                ts.shed,
            );
        }
        // Bound the metrics-side tenant map with the queue's current
        // tenant set (fresh gauges just absorbed), never with stale
        // mirrors alone.
        let active: Vec<&str> = tstats.iter().map(|(name, _)| name.as_str()).collect();
        self.shared.metrics.evict_stale_tenants(&active);
        // Wall time is derived inside Metrics from its own start
        // Instant (created with the server), so `stats`, `tenants`,
        // and `metrics` all rate against the same clock.
        self.shared.metrics.summary()
    }

    /// The retained trace timelines (oldest first) as JSON lines — the
    /// `trace-dump` wire command and the `aphmm serve` shutdown hook.
    pub fn trace_dump(&self) -> Vec<String> {
        self.shared.traces.dump().iter().map(Timeline::to_json).collect()
    }

    /// Full Prometheus text exposition — the `metrics` wire command.
    /// Naming scheme (documented in `server/README.md`): `aphmm_`
    /// prefix, snake_case, base unit seconds; per-stage histograms are
    /// one `aphmm_stage_seconds{stage="..."}` family.
    pub fn metrics_text(&self) -> String {
        let m = self.metrics_summary();
        let c = self.cache_stats();
        let metrics = &self.shared.metrics;
        let mut w = PromWriter::default();

        w.help_type("aphmm_uptime_seconds", "Seconds since the server started.", "gauge");
        w.value("aphmm_uptime_seconds", &[], m.wall_seconds);

        w.help_type(
            "aphmm_requests_total",
            "Completed requests by result (shed requests are counted in aphmm_shed_total).",
            "counter",
        );
        w.value("aphmm_requests_total", &[("result", "ok")], m.jobs_done as f64);
        let plain_errors =
            m.jobs_failed.saturating_sub(m.deadline_exceeded + m.cancelled + m.pool_panics);
        w.value("aphmm_requests_total", &[("result", "error")], plain_errors as f64);
        w.value(
            "aphmm_requests_total",
            &[("result", "deadline_exceeded")],
            m.deadline_exceeded as f64,
        );
        w.value("aphmm_requests_total", &[("result", "cancelled")], m.cancelled as f64);
        w.value("aphmm_requests_total", &[("result", "panicked")], m.pool_panics as f64);
        w.help_type(
            "aphmm_shed_total",
            "Requests refused by load shedding at admission.",
            "counter",
        );
        w.value("aphmm_shed_total", &[], m.shed as f64);
        w.help_type(
            "aphmm_over_memory_refusals_total",
            "Requests refused at admission for exceeding max_scratch_bytes with checkpointing disabled.",
            "counter",
        );
        w.value("aphmm_over_memory_refusals_total", &[], m.over_memory_refusals as f64);
        w.help_type(
            "aphmm_scratch_bytes",
            "Highest per-read forward-row scratch observed (bytes; checkpointed reads stay O(sqrt(T)*states)).",
            "gauge",
        );
        w.value("aphmm_scratch_bytes", &[], m.peak_scratch_bytes as f64);
        w.help_type(
            "aphmm_train_epochs_total",
            "Training epochs completed (full-batch iterations and minibatch/Viterbi epochs).",
            "counter",
        );
        w.value("aphmm_train_epochs_total", &[], m.epochs as f64);
        w.help_type(
            "aphmm_train_minibatches_total",
            "Minibatches processed by the minibatch training schedule.",
            "counter",
        );
        w.value("aphmm_train_minibatches_total", &[], m.minibatches as f64);
        w.help_type(
            "aphmm_sequences_streamed_total",
            "Sequences pulled through streaming read sources during training.",
            "counter",
        );
        w.value("aphmm_sequences_streamed_total", &[], m.sequences_streamed as f64);

        w.help_type(
            "aphmm_request_seconds",
            "End-to-end request latency (success and failure).",
            "histogram",
        );
        w.histogram("aphmm_request_seconds", &[], &metrics.request_hist_snapshot());
        w.help_type(
            "aphmm_stage_seconds",
            "Per-stage time within a request (only requests that ran the stage).",
            "histogram",
        );
        for (stage, snap) in metrics.stage_snapshots() {
            w.histogram("aphmm_stage_seconds", &[("stage", stage)], &snap);
        }

        w.help_type(
            "aphmm_rows_total",
            "Sparse-gather rows by dispatch path (csr vs dense_tile).",
            "counter",
        );
        w.value("aphmm_rows_total", &[("kind", "csr")], m.rows_csr as f64);
        w.value("aphmm_rows_total", &[("kind", "dense_tile")], m.rows_dense_tile as f64);
        w.help_type(
            "aphmm_filter_states_total",
            "States offered to (in) and admitted by (out) the state filter.",
            "counter",
        );
        w.value("aphmm_filter_states_total", &[("dir", "in")], m.filter_states_in as f64);
        w.value("aphmm_filter_states_total", &[("dir", "out")], m.filter_states_out as f64);
        w.help_type("aphmm_filter_calls_total", "State-filter invocations.", "counter");
        w.value("aphmm_filter_calls_total", &[], m.filter_calls as f64);

        w.help_type(
            "aphmm_stripe_passes_total",
            "Striped multi-read kernel passes.",
            "counter",
        );
        w.value("aphmm_stripe_passes_total", &[], m.stripe_passes as f64);
        w.help_type(
            "aphmm_stripe_reads_total",
            "Reads carried by striped passes (reads/passes = mean fill).",
            "counter",
        );
        w.value("aphmm_stripe_reads_total", &[], m.stripe_reads as f64);
        w.help_type(
            "aphmm_stripe_fill_passes_total",
            "Striped score passes by exact fill (reads per pass out of MAX_STRIPE).",
            "counter",
        );
        for (i, count) in metrics.stripe_fill_counts().into_iter().enumerate() {
            let fill = (i + 1).to_string();
            w.value("aphmm_stripe_fill_passes_total", &[("fill", &fill)], count as f64);
        }

        w.help_type("aphmm_cache_ops_total", "Prepared-cache operations.", "counter");
        w.value("aphmm_cache_ops_total", &[("op", "hit")], c.hits as f64);
        w.value("aphmm_cache_ops_total", &[("op", "miss")], c.misses as f64);
        w.value("aphmm_cache_ops_total", &[("op", "evict")], c.evictions as f64);
        w.help_type("aphmm_cache_entries", "Prepared-cache resident entries.", "gauge");
        w.value("aphmm_cache_entries", &[], c.entries as f64);
        w.help_type(
            "aphmm_cache_freeze_seconds_total",
            "Total time spent freezing prepared tables on cache misses.",
            "counter",
        );
        w.value("aphmm_cache_freeze_seconds_total", &[], c.freeze_ns as f64 / 1e9);

        w.help_type("aphmm_queue_depth", "Job-queue depth (last snapshot).", "gauge");
        w.value("aphmm_queue_depth", &[], m.queue_depth as f64);
        w.help_type("aphmm_queue_high_water", "Highest job-queue depth observed.", "gauge");
        w.value("aphmm_queue_high_water", &[], m.queue_high_water as f64);
        w.help_type(
            "aphmm_producer_blocks_total",
            "Producer admissions refused/blocked by a full queue.",
            "counter",
        );
        w.value("aphmm_producer_blocks_total", &[], m.producer_blocks as f64);

        w.help_type("aphmm_timesteps_total", "Baum-Welch timesteps processed.", "counter");
        w.value("aphmm_timesteps_total", &[], m.timesteps as f64);
        w.help_type("aphmm_states_total", "States processed.", "counter");
        w.value("aphmm_states_total", &[], m.states as f64);
        w.help_type(
            "aphmm_reads_skipped_total",
            "Reads skipped during training (empty or numerically dead).",
            "counter",
        );
        w.value("aphmm_reads_skipped_total", &[], m.reads_skipped as f64);

        w.help_type("aphmm_profiles", "Registered profiles.", "gauge");
        w.value("aphmm_profiles", &[], self.shared.registry.len() as f64);
        w.help_type(
            "aphmm_simd_lane_width",
            "SIMD lane width the configured policy resolves to on this host.",
            "gauge",
        );
        w.value(
            "aphmm_simd_lane_width",
            &[],
            self.shared.cfg.train.simd.resolve().width() as f64,
        );

        w.help_type(
            "aphmm_tenant_requests_total",
            "Per-tenant completed requests by result.",
            "counter",
        );
        for t in &m.tenants {
            w.value(
                "aphmm_tenant_requests_total",
                &[("tenant", &t.tenant), ("result", "ok")],
                t.completed as f64,
            );
            w.value(
                "aphmm_tenant_requests_total",
                &[("tenant", &t.tenant), ("result", "failed")],
                t.failed as f64,
            );
        }
        // One family at a time: Prometheus text format keeps a
        // family's samples contiguous under its HELP/TYPE pair.
        w.help_type("aphmm_tenant_queued", "Per-tenant queued requests.", "gauge");
        for t in &m.tenants {
            w.value("aphmm_tenant_queued", &[("tenant", &t.tenant)], t.queued as f64);
        }
        w.help_type("aphmm_tenant_in_flight", "Per-tenant in-flight requests.", "gauge");
        for t in &m.tenants {
            w.value("aphmm_tenant_in_flight", &[("tenant", &t.tenant)], t.in_flight as f64);
        }
        w.help_type(
            "aphmm_tenant_admitted_total",
            "Per-tenant admitted requests.",
            "counter",
        );
        for t in &m.tenants {
            w.value("aphmm_tenant_admitted_total", &[("tenant", &t.tenant)], t.admitted as f64);
        }
        w.help_type(
            "aphmm_tenant_quota_refusals_total",
            "Per-tenant admissions refused by quota.",
            "counter",
        );
        for t in &m.tenants {
            w.value(
                "aphmm_tenant_quota_refusals_total",
                &[("tenant", &t.tenant)],
                t.quota_refusals as f64,
            );
        }
        w.help_type(
            "aphmm_tenant_shed_total",
            "Per-tenant admissions refused by load shedding.",
            "counter",
        );
        for t in &m.tenants {
            w.value("aphmm_tenant_shed_total", &[("tenant", &t.tenant)], t.shed as f64);
        }
        w.help_type(
            "aphmm_tenant_scratch_bytes",
            "Per-tenant highest per-read forward-row scratch observed (bytes).",
            "gauge",
        );
        for t in &m.tenants {
            w.value(
                "aphmm_tenant_scratch_bytes",
                &[("tenant", &t.tenant)],
                t.peak_scratch_bytes as f64,
            );
        }

        w.finish()
    }

    /// One-line `stats` response for the wire protocol.
    pub fn stats_line(&self) -> String {
        let m = self.metrics_summary();
        let c = self.cache_stats();
        format!(
            "stats jobs_done={} jobs_failed={} p50_ms={:.3} p99_ms={:.3} queue_depth={} \
             queue_high_water={} producer_blocks={} cache_hits={} cache_misses={} \
             cache_evictions={} profiles={} tenants={} deadline_exceeded={} cancelled={} \
             pool_panics={} shed={} over_memory_refusals={} peak_scratch_bytes={} epochs={} \
             minibatches={} sequences_streamed={}",
            m.jobs_done,
            m.jobs_failed,
            m.latency_p50_ms,
            m.latency_p99_ms,
            m.queue_depth,
            m.queue_high_water,
            m.producer_blocks,
            c.hits,
            c.misses,
            c.evictions,
            self.shared.registry.len(),
            m.tenants.len(),
            m.deadline_exceeded,
            m.cancelled,
            m.pool_panics,
            m.shed,
            m.over_memory_refusals,
            m.peak_scratch_bytes,
            m.epochs,
            m.minibatches,
            m.sequences_streamed,
        )
    }

    /// One-line `tenants` response for the wire protocol: one
    /// space-separated block per tenant, sorted by tenant id.
    pub fn tenants_line(&self) -> String {
        let m = self.metrics_summary();
        if m.tenants.is_empty() {
            return "tenants -".to_string();
        }
        let blocks: Vec<String> = m
            .tenants
            .iter()
            .map(|t| {
                format!(
                    "{}:admitted={},completed={},failed={},refused={},queued={},in_flight={},\
                     deadline_exceeded={},cancelled={},panicked={},shed={},peak_scratch_bytes={}",
                    t.tenant,
                    t.admitted,
                    t.completed,
                    t.failed,
                    t.quota_refusals,
                    t.queued,
                    t.in_flight,
                    t.deadline_exceeded,
                    t.cancelled,
                    t.panicked,
                    t.shed,
                    t.peak_scratch_bytes
                )
            })
            .collect();
        format!("tenants {}", blocks.join(" "))
    }

    /// Weak probe on the pool's shared state: upgradeable only while
    /// the pool or one of its helper threads is alive.  Tests use it to
    /// prove no thread leaks after the server is dropped.
    pub fn pool_liveness(&self) -> std::sync::Weak<dyn std::any::Any + Send + Sync> {
        self.shared.pool.liveness()
    }

    /// Stop the server.  `drain = true`: complete every admitted
    /// request, then stop (graceful).  `drain = false`: discard the
    /// backlog, sending each queued request an `Error` response
    /// (abort).  Idempotent; joins the dispatcher either way.
    pub fn shutdown(&mut self, drain: bool) {
        if drain {
            self.shared.queue.close();
        } else {
            for (_tenant, job) in self.shared.queue.abort() {
                let _ = job.reply.send(Response {
                    id: job.id,
                    engine: job.engine,
                    latency_ns: job.enqueued.elapsed().as_nanos() as u64,
                    stats: ReadStats::default(),
                    body: ResponseBody::Error {
                        message: "request aborted: server shutting down".into(),
                    },
                });
            }
        }
        if let Some(handle) = self.dispatcher.take() {
            let _ = handle.join();
        }
    }
}

impl Drop for Server {
    /// Dropping aborts (see the module docs): a drop mid-stream must
    /// not hang on an arbitrary backlog.  Call
    /// [`Server::shutdown`]`(true)` first for a graceful drain.
    fn drop(&mut self) {
        self.shutdown(false);
    }
}

/// One queue-draining participant: pop, micro-batch compatible `Score`
/// requests, execute, respond, finish (releasing the tenant's
/// in-flight slot), repeat until the queue reports exhaustion.
fn worker_loop(shared: &Shared) {
    let mut scratch = ScratchAny::None;
    while let Some((tenant, mut job)) = shared.queue.pop() {
        job.popped = Some(Instant::now());
        if let Request::Score { profile, .. } = &job.body {
            // Micro-batch: pull further Score requests for the same
            // (profile, engine) so they run together through one frozen
            // table — as one striped multi-read pass when more than one
            // job is pulled (see `process_score_batch`), with a warm
            // scratch either way.  The pull goes through the same
            // tenant accounting as pop: every batched item charges (and
            // must release) its own tenant's in-flight slot, and items
            // of at-cap tenants are skipped.
            let name = profile.clone();
            let engine = job.engine;
            let mut batch = vec![(tenant, job)];
            while batch.len() < shared.cfg.microbatch.max(1) {
                let more = shared.queue.try_pop_where(|j| {
                    j.engine == engine
                        && matches!(&j.body, Request::Score { profile: p, .. } if *p == name)
                });
                match more {
                    Some((t, mut j)) => {
                        j.popped = Some(Instant::now());
                        batch.push((t, j));
                    }
                    None => break,
                }
            }
            if batch.len() == 1 {
                let (tenant, j) = batch.pop().unwrap();
                process_one(shared, &tenant, j, &mut scratch);
                shared.queue.finish(&tenant);
            } else {
                process_score_batch(shared, &name, engine, batch, &mut scratch);
            }
        } else {
            process_one(shared, &tenant, job, &mut scratch);
            shared.queue.finish(&tenant);
        }
    }
}

/// Execute a micro-batch of same-(profile, engine) `Score` jobs in one
/// striped multi-read pass ([`session::execute_score_batch`]).  Per-job
/// semantics match running [`process_one`] on each job in batch order:
/// queue-side cancellation is checked per job before execution (an
/// expired job answers a typed `Failure` and never runs — jobs
/// cancelled *mid-pass* still complete, same as mid-`execute`
/// cancellation of a solo `Score`, which has no in-engine cancellation
/// point either); one read's numerical death is that job's `Error`
/// alone; a panic answers every in-pass job with
/// [`FailureCause::Panicked`] and drops the worker's scratch, and the
/// worker survives.  Per-job results are bit-identical to solo
/// execution at the same lane width (the striped kernel contract).
fn process_score_batch(
    shared: &Shared,
    profile: &str,
    engine: EngineKind,
    batch: Vec<(String, Job)>,
    scratch: &mut ScratchAny,
) {
    let mut live: Vec<(String, Job)> = Vec::with_capacity(batch.len());
    for (tenant, job) in batch {
        if let Some(cause) = job.cancel.check() {
            respond(
                shared,
                &tenant,
                job,
                ResponseBody::Failure {
                    cause: failure_cause_of(cause),
                    message: format!("{cause} before execution started"),
                },
                ReadStats::default(),
            );
            shared.queue.finish(&tenant);
        } else {
            live.push((tenant, job));
        }
    }
    if live.is_empty() {
        return;
    }
    let ctx = ExecCtx {
        registry: &shared.registry,
        cache: &shared.cache,
        pool: &shared.pool,
        cfg: &shared.cfg,
    };
    // Same fault-isolation stance as `process_one`: the striped pass
    // runs under `catch_unwind`, and an unwind condemns only this
    // batch, never the worker.
    let outcome = {
        let reads: Vec<&Sequence> = live
            .iter()
            .map(|(_, j)| match &j.body {
                Request::Score { read, .. } => read,
                _ => unreachable!("score micro-batch holds only Score jobs"),
            })
            .collect();
        catch_unwind(AssertUnwindSafe(|| {
            session::execute_score_batch(&ctx, engine, profile, &reads, scratch)
        }))
    };
    match outcome {
        Ok(results) => {
            // Stripe-fill accounting: the striped kernel chunks the
            // batch by MAX_STRIPE, so the pass fills are fully
            // determined by the batch size.  Recorded here (a stage
            // boundary), never inside the kernel.
            let n = live.len();
            for _ in 0..(n / MAX_STRIPE) {
                shared.metrics.record_stripe_fill(MAX_STRIPE);
            }
            if n % MAX_STRIPE > 0 {
                shared.metrics.record_stripe_fill(n % MAX_STRIPE);
            }
            for ((tenant, job), res) in live.into_iter().zip(results) {
                let (body, stats) = match res {
                    Ok(done) => done,
                    Err(ApHmmError::Cancelled(cause)) => (
                        ResponseBody::Failure {
                            cause: failure_cause_of(cause),
                            message: cause.to_string(),
                        },
                        ReadStats::default(),
                    ),
                    Err(e) => {
                        (ResponseBody::Error { message: e.to_string() }, ReadStats::default())
                    }
                };
                respond(shared, &tenant, job, body, stats);
                shared.queue.finish(&tenant);
            }
        }
        Err(payload) => {
            // The unwound pass may have left the warm scratch
            // half-updated; drop it before the next request.
            *scratch = ScratchAny::None;
            let message = panic_message(payload.as_ref());
            for (tenant, job) in live {
                respond(
                    shared,
                    &tenant,
                    job,
                    ResponseBody::Failure {
                        cause: FailureCause::Panicked,
                        message: message.clone(),
                    },
                    ReadStats::default(),
                );
                shared.queue.finish(&tenant);
            }
        }
    }
}

fn failure_cause_of(cause: CancelCause) -> FailureCause {
    match cause {
        CancelCause::Cancelled => FailureCause::Cancelled,
        CancelCause::DeadlineExceeded => FailureCause::DeadlineExceeded,
    }
}

fn process_one(shared: &Shared, tenant: &str, job: Job, scratch: &mut ScratchAny) {
    // Queue-side cancellation point: a request whose deadline expired
    // (or that was cancelled) while waiting is answered without
    // executing at all.
    let (body, stats) = if let Some(cause) = job.cancel.check() {
        (
            ResponseBody::Failure {
                cause: failure_cause_of(cause),
                message: format!("{cause} before execution started"),
            },
            ReadStats::default(),
        )
    } else {
        let ctx = ExecCtx {
            registry: &shared.registry,
            cache: &shared.cache,
            pool: &shared.pool,
            cfg: &shared.cfg,
        };
        // Per-job fault isolation: a panicking request must not take
        // down its worker (and with it the queue, the cache, and every
        // other tenant).  `AssertUnwindSafe` is sound here because the
        // shared structures are lock-protected (poisoning surfaces as
        // an error, not corruption) and the per-worker scratch is reset
        // below before reuse.
        match catch_unwind(AssertUnwindSafe(|| {
            session::execute(&ctx, job.engine, &job.body, &job.cancel, scratch)
        })) {
            Ok(Ok(done)) => done,
            Ok(Err(ApHmmError::Cancelled(cause))) => (
                ResponseBody::Failure {
                    cause: failure_cause_of(cause),
                    message: cause.to_string(),
                },
                ReadStats::default(),
            ),
            Ok(Err(e)) => {
                (ResponseBody::Error { message: e.to_string() }, ReadStats::default())
            }
            Err(payload) => {
                // The unwound job may have left the warm scratch
                // half-updated; drop it so the next request on this
                // worker re-derives a clean one.
                *scratch = ScratchAny::None;
                (
                    ResponseBody::Failure {
                        cause: FailureCause::Panicked,
                        message: panic_message(payload.as_ref()),
                    },
                    ReadStats::default(),
                )
            }
        }
    };
    respond(shared, tenant, job, body, stats);
}

/// Record metrics for one completed job and send its reply.  The
/// shared tail of [`process_one`] and [`process_score_batch`], and the
/// one place span/stage capture happens — a stage boundary by
/// construction, so tracing never perturbs kernel execution and
/// results are bit-identical with tracing on or off.
fn respond(shared: &Shared, tenant: &str, job: Job, body: ResponseBody, stats: ReadStats) {
    let latency_ns = job.enqueued.elapsed().as_nanos() as u64;
    let ok = match &body {
        ResponseBody::Error { .. } => {
            shared.metrics.record_failed_request(latency_ns, None);
            shared.metrics.record_tenant_failure(tenant, None);
            false
        }
        ResponseBody::Failure { cause, .. } => {
            shared.metrics.record_failed_request(latency_ns, Some(*cause));
            shared.metrics.record_tenant_failure(tenant, Some(*cause));
            false
        }
        _ => {
            shared.metrics.record(latency_ns, stats.timesteps, stats.states_processed);
            shared.metrics.record_tenant_done(tenant, true);
            true
        }
    };
    // Stage accounting (always-on): the durations were measured by the
    // execution path at its own stage boundaries; folding them into the
    // histogram family costs a handful of relaxed atomics per request.
    let queue_wait_ns = job
        .popped
        .map(|p| p.saturating_duration_since(job.enqueued).as_nanos() as u64)
        .unwrap_or(0);
    let times = StageTimes {
        queue_wait_ns,
        cache_freeze_ns: stats.cache_freeze_ns as u64,
        forward_ns: stats.forward_ns as u64,
        backward_ns: stats.backward_update_ns as u64,
        update_ns: stats.update_ns as u64,
    };
    shared.metrics.record_stages(&times);
    shared.metrics.absorb_read_stats(&stats);
    // Per-tenant scratch attribution (the process-wide gauge is fed by
    // `absorb_read_stats` above): a high-water mark, so a tenant's
    // longest read defines its reading.
    if stats.peak_scratch_bytes > 0 {
        shared.metrics.record_tenant_scratch(tenant, stats.peak_scratch_bytes);
    }

    // Timeline capture: only traced requests reach the ring; the slow-
    // request log additionally captures any request over the
    // configured threshold.
    let slow = shared.cfg.slow_request_ms > 0
        && latency_ns >= shared.cfg.slow_request_ms.saturating_mul(1_000_000);
    if job.trace || slow {
        let accounted = times.queue_wait_ns
            + times.cache_freeze_ns
            + times.forward_ns
            + times.backward_ns
            + times.update_ns;
        let mut spans = [0u64; Stage::ALL.len()];
        spans[Stage::QueueWait as usize] = times.queue_wait_ns;
        spans[Stage::CacheFreeze as usize] = times.cache_freeze_ns;
        spans[Stage::Forward as usize] = times.forward_ns;
        spans[Stage::Backward as usize] = times.backward_ns;
        spans[Stage::Update as usize] = times.update_ns;
        // Respond absorbs the unattributed residual (dispatch overhead,
        // formatting, reply send), so the spans sum to total_ns.
        spans[Stage::Respond as usize] = latency_ns.saturating_sub(accounted);
        let timeline = Timeline {
            trace_id: job.id,
            tenant: tenant.to_string(),
            kind: job.body.kind_name(),
            engine: job.engine.name(),
            ok,
            started_ns: job
                .enqueued
                .saturating_duration_since(shared.started)
                .as_nanos() as u64,
            total_ns: latency_ns,
            spans,
        };
        if slow {
            eprintln!("aphmm slow-request: {}", timeline.to_json());
        }
        if job.trace {
            shared.traces.push(timeline);
        }
    }
    // A dropped ticket just means the client stopped waiting.
    let _ = job.reply.send(Response {
        id: job.id,
        engine: job.engine,
        latency_ns,
        stats,
        body,
    });
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::seq::Sequence;
    use crate::sim::{simulate_read, ErrorProfile, XorShift};
    use crate::testutil;

    fn dna(rng: &mut XorShift, len: usize) -> Sequence {
        Sequence::from_symbols("s", testutil::random_seq(rng, len, 4))
    }

    #[test]
    fn score_round_trip_hits_the_cache_second_time() {
        let mut rng = XorShift::new(71);
        let reference = dna(&mut rng, 60);
        let read = simulate_read(&mut rng, &reference, 0, 60, &ErrorProfile::pacbio(), 0).seq;
        let mut server = Server::start(ServerConfig::default());
        let phmm = Phmm::error_correction(&reference, &EcDesignParams::default()).unwrap();
        server.register_profile("chr1", phmm);

        let r1 = server
            .submit(None, Request::Score { profile: "chr1".into(), read: read.clone() })
            .unwrap()
            .wait();
        let r2 = server
            .submit(None, Request::Score { profile: "chr1".into(), read })
            .unwrap()
            .wait();
        let (ll1, hit1) = match r1.body {
            ResponseBody::Score { loglik, cache_hit, .. } => (loglik, cache_hit),
            other => panic!("unexpected response {other:?}"),
        };
        let (ll2, hit2) = match r2.body {
            ResponseBody::Score { loglik, cache_hit, .. } => (loglik, cache_hit),
            other => panic!("unexpected response {other:?}"),
        };
        assert_eq!(ll1.to_bits(), ll2.to_bits());
        assert!(!hit1, "first request must freeze");
        assert!(hit2, "second request must reuse the frozen tables");
        let c = server.cache_stats();
        assert_eq!(c.misses, 1);
        assert_eq!(c.hits, 1);
        assert!(r1.latency_ns > 0);
        server.shutdown(true);
    }

    #[test]
    fn unknown_profile_is_an_error_response_not_a_crash() {
        let mut rng = XorShift::new(72);
        let read = dna(&mut rng, 20);
        let mut server = Server::start(ServerConfig::default());
        let resp = server
            .submit(None, Request::Score { profile: "nope".into(), read })
            .unwrap()
            .wait();
        assert!(matches!(resp.body, ResponseBody::Error { .. }));
        assert_eq!(server.metrics_summary().jobs_failed, 1);
        server.shutdown(true);
        // The server still answers nothing after shutdown.
        assert!(server
            .submit(None, Request::Search { read: dna(&mut rng, 10) })
            .is_err());
    }

    #[test]
    fn graceful_shutdown_completes_admitted_requests() {
        let mut rng = XorShift::new(73);
        let reference = dna(&mut rng, 50);
        let reads: Vec<_> = (0..4)
            .map(|i| simulate_read(&mut rng, &reference, 0, 50, &ErrorProfile::pacbio(), i).seq)
            .collect();
        let mut server = Server::start(ServerConfig {
            n_workers: 2,
            queue_depth: 8,
            ..Default::default()
        });
        let tickets: Vec<_> = (0..6)
            .map(|_| {
                server
                    .submit(
                        None,
                        Request::Correct {
                            reference: reference.clone(),
                            reads: reads.clone(),
                        },
                    )
                    .unwrap()
            })
            .collect();
        server.shutdown(true);
        for t in tickets {
            let resp = t.wait();
            match resp.body {
                ResponseBody::Correct { consensus, .. } => assert!(!consensus.is_empty()),
                other => panic!("drain lost a request: {other:?}"),
            }
        }
    }

    #[test]
    fn over_budget_full_matrix_work_is_refused_not_oomed() {
        let mut rng = XorShift::new(75);
        let reference = dna(&mut rng, 60);
        let read = simulate_read(&mut rng, &reference, 0, 60, &ErrorProfile::pacbio(), 0).seq;
        // A budget far below the ~88 kB full matrix of even this small
        // request, with checkpointing disabled (default Full mode).
        let mut server = Server::start(ServerConfig {
            max_scratch_bytes: 1024,
            ..Default::default()
        });
        let body =
            Request::Correct { reference: reference.clone(), reads: vec![read.clone()] };
        match server.try_submit_for(DEFAULT_TENANT, Priority::Normal, None, body) {
            Err(AdmitError::OverMemoryBudget(_)) => {}
            Err(_) => panic!("wrong admission refusal"),
            Ok(_) => panic!("over-budget request must not be admitted"),
        }
        // The blocking path refuses with an error instead of queueing.
        let body =
            Request::Correct { reference: reference.clone(), reads: vec![read.clone()] };
        assert!(server.submit(None, body).is_err());
        assert_eq!(server.metrics_summary().over_memory_refusals, 2);
        // Scoring is unaffected by the budget (the estimate is scoped
        // to training requests).
        server.shutdown(true);

        // The same request under `auto` admits and completes
        // checkpointed (the propagated budget resolves it there).
        let mut server = Server::start(ServerConfig {
            max_scratch_bytes: 1024,
            train: TrainConfig {
                max_iters: 2,
                scratch_mode: ScratchMode::Auto,
                ..Default::default()
            },
            ..Default::default()
        });
        let resp = server
            .submit(None, Request::Correct { reference, reads: vec![read] })
            .unwrap()
            .wait();
        match resp.body {
            ResponseBody::Correct { consensus, .. } => assert!(!consensus.is_empty()),
            other => panic!("auto-mode request must complete: {other:?}"),
        }
        assert!(resp.stats.peak_scratch_bytes > 0, "scratch accounting must be attributed");
        let m = server.metrics_summary();
        assert_eq!(m.over_memory_refusals, 0);
        assert!(m.peak_scratch_bytes > 0);
        assert!(server.tenants_line().contains("peak_scratch_bytes="));
        server.shutdown(true);
    }

    #[test]
    fn search_ranks_registered_profiles() {
        let mut rng = XorShift::new(74);
        let a = dna(&mut rng, 60);
        let b = dna(&mut rng, 60);
        let mut server = Server::start(ServerConfig::default());
        server.register_profile(
            "a",
            Phmm::error_correction(&a, &EcDesignParams::default()).unwrap(),
        );
        server.register_profile(
            "b",
            Phmm::error_correction(&b, &EcDesignParams::default()).unwrap(),
        );
        let query = simulate_read(&mut rng, &a, 0, 60, &ErrorProfile::pacbio(), 0).seq;
        let resp = server.submit(None, Request::Search { read: query }).unwrap().wait();
        match resp.body {
            ResponseBody::Search { hits, scored } => {
                assert_eq!(scored, 2);
                assert_eq!(hits[0].profile, "a", "query from profile a must rank a first");
            }
            other => panic!("unexpected response {other:?}"),
        }
        server.shutdown(true);
    }
}
