//! Typed requests/responses, the multi-tenant profile registry, and
//! the newline-delimited wire protocol of the serving layer.
//!
//! # Request model
//!
//! A tenant registers named profiles ([`ProfileRegistry`]) and then
//! submits typed requests against them: [`Request::Score`] (forward
//! log-likelihood, the hmmsearch inner loop), [`Request::Align`]
//! (posterior best-state decode mapped onto profile columns, the
//! hmmalign rule), [`Request::Search`] (score against every registered
//! profile, ranked by length-normalized log-odds), and
//! [`Request::Correct`] (build + Baum-Welch-train + decode one EC
//! chunk, the Apollo primitive).  Each request is tagged with an
//! [`EngineKind`]; the read-only requests flow through the
//! cross-request [`PreparedCache`](super::PreparedCache), so repeated
//! requests against one profile share a single frozen coefficient
//! table.
//!
//! # Wire protocol
//!
//! One request per line, one response line per request, in request
//! order (see `server/README.md` for the full grammar):
//!
//! ```text
//! tenant <id> [low|normal|high]
//! deadline <ms|off>
//! register <name> <sequence>
//! register-profile <name> <nbytes>
//! <nbytes bytes of io::profile_fmt (.aphmm) text>
//! score <profile> <read> [engine]
//! align <profile> <read> [engine]
//! search <read> [engine]
//! correct <reference> <read1,read2,...> [engine]
//! trace <on|off>
//! stats | tenants | metrics | trace-dump | quit | shutdown
//! ```
//!
//! `tenant` sets the session's tenant id and priority class for every
//! later submission (default: tenant `"default"`, priority `normal`);
//! admission quotas are per tenant (see [`super::TenantQuota`]).
//! `register-profile` is the prebuilt-profile path: the command line
//! declares the payload length in bytes, then exactly that many bytes
//! of `.aphmm` text ([`crate::io::read_phmm_str`]) follow — a length
//! prefix rather than an in-band terminator, so hostile payloads can't
//! smuggle protocol lines.  Registered profiles flow through the same
//! [`ProfileRegistry`] → content hash → `PreparedCache` pipeline as
//! in-process ones, so two tenants uploading the same profile text
//! share one frozen coefficient table.
//!
//! [`serve_stdio`] speaks it over stdin/stdout; [`serve_tcp`] accepts
//! concurrent connections on a local port (std threads only — `tokio`
//! is not in the offline registry, matching the coordinator's stance).

use std::io::{BufRead, BufReader, Read, Write};
use std::net::TcpListener;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex, RwLock};
use std::time::{Duration, Instant};

use crate::apps::{self, AlignedRow};
use crate::baumwelch::{EngineKind, ForwardOptions, ReadStats, ScratchAny, MAX_STRIPE};
use crate::cancel::CancelToken;
use crate::coordinator::FailureCause;
use crate::error::{ApHmmError, Result};
use crate::phmm::Phmm;
use crate::seq::Sequence;

use super::cache::profile_hash;
use super::queue::Priority;
use super::{Server, ServerConfig, DEFAULT_TENANT};

/// A typed request against the serving layer.
#[derive(Clone, Debug)]
pub enum Request {
    /// Forward log-likelihood of `read` under a registered profile.
    Score {
        /// Registered profile name.
        profile: String,
        /// Read to score.
        read: Sequence,
    },
    /// Posterior best-state alignment of `read` to a registered
    /// profile (hmmalign).
    Align {
        /// Registered profile name.
        profile: String,
        /// Read to align.
        read: Sequence,
    },
    /// Score `read` against every registered profile, ranked by
    /// length-normalized log-odds (hmmsearch).
    Search {
        /// Query read.
        read: Sequence,
    },
    /// Build an EC-design pHMM for `reference`, train it on `reads`,
    /// and decode the corrected consensus (Apollo).
    Correct {
        /// Chunk reference sequence.
        reference: Sequence,
        /// Read segments mapped to the chunk.
        reads: Vec<Sequence>,
    },
}

impl Request {
    /// Request kind, for logs and the usage line.
    pub fn kind_name(&self) -> &'static str {
        match self {
            Request::Score { .. } => "score",
            Request::Align { .. } => "align",
            Request::Search { .. } => "search",
            Request::Correct { .. } => "correct",
        }
    }
}

/// One ranked hit of a [`Request::Search`].
#[derive(Clone, Debug)]
pub struct RankedHit {
    /// Registered profile name.
    pub profile: String,
    /// Length-normalized log-odds score.
    pub log_odds: f64,
}

/// Typed response payload.
#[derive(Clone, Debug)]
pub enum ResponseBody {
    /// Answer to [`Request::Score`].
    Score {
        /// Profile the read was scored against.
        profile: String,
        /// `log P(read | profile)`.
        loglik: f64,
        /// Length-normalized log-odds vs the uniform null model.
        log_odds: f64,
        /// True when the frozen coefficient tables came from the
        /// cross-request cache (no re-freeze).
        cache_hit: bool,
    },
    /// Answer to [`Request::Align`].
    Align {
        /// Profile the read was aligned to.
        profile: String,
        /// Aligned row (columns + insertion count + loglik).
        row: AlignedRow,
    },
    /// Answer to [`Request::Search`].
    Search {
        /// Ranked hits, best first.
        hits: Vec<RankedHit>,
        /// Profiles scored.
        scored: usize,
    },
    /// Answer to [`Request::Correct`].
    Correct {
        /// Decoded consensus of the trained chunk graph.
        consensus: Sequence,
        /// Mean per-read log-likelihood after training.
        mean_loglik: f64,
        /// EM iterations run.
        iters: usize,
    },
    /// The request failed; the queue and the other tenants are
    /// unaffected.
    Error {
        /// Human-readable failure description.
        message: String,
    },
    /// The request was terminated by the serving layer itself — its
    /// deadline expired, it was cancelled, or it panicked — rather
    /// than by an input error.  The cause is typed so clients and
    /// metrics can distinguish the failure modes; the worker, queue,
    /// cache, and other tenants are unaffected.
    Failure {
        /// Why the serving layer terminated the request.
        cause: FailureCause,
        /// Human-readable detail.
        message: String,
    },
}

/// A completed request: payload plus uniform per-request
/// instrumentation.
#[derive(Clone, Debug)]
pub struct Response {
    /// Request id assigned at submission.
    pub id: u64,
    /// Engine that served the request.
    pub engine: EngineKind,
    /// Wall latency from admission to completion (ns).
    pub latency_ns: u64,
    /// Engine instrumentation (timings, workload counters).
    pub stats: ReadStats,
    /// Payload.
    pub body: ResponseBody,
}

/// A registered profile: the graph plus its content hash (the cache
/// key component), the owning tenant, and the pre-filter k-mer set of
/// its decoded consensus.
pub struct ProfileEntry {
    /// Tenant-chosen name.
    pub name: String,
    /// Tenant that registered the profile (ownership check for wire
    /// re-registrations; the trusted in-process API registers as the
    /// reserved [`super::OPERATOR_TENANT`], which wire sessions can
    /// never claim).
    pub owner: String,
    /// The profile graph.
    pub phmm: Phmm,
    /// Content hash (see [`profile_hash`]).
    pub hash: u64,
    /// k-mers of the profile's Viterbi consensus (the `Search`
    /// pre-filter screen); empty when the graph has no decodable
    /// consensus, in which case the profile is always forward-scored.
    kmers: std::collections::HashSet<u64>,
}

/// Named profiles shared by every session of a server.  Registration
/// order is preserved so `Search` responses are deterministic.
#[derive(Default)]
pub struct ProfileRegistry {
    entries: RwLock<Vec<Arc<ProfileEntry>>>,
}

impl ProfileRegistry {
    fn make_entry(
        name: &str,
        owner: &str,
        phmm: Phmm,
        prefilter_k: usize,
    ) -> (Arc<ProfileEntry>, u64) {
        let hash = profile_hash(&phmm);
        // Silent-state graphs have no decodable consensus: leave the
        // set empty so the profile is never screened out.
        let kmers = crate::viterbi::consensus(&phmm)
            .map(|c| apps::kmer_set(&c.consensus.data, prefilter_k, phmm.sigma()))
            .unwrap_or_default();
        let entry = Arc::new(ProfileEntry {
            name: name.to_string(),
            owner: owner.to_string(),
            phmm,
            hash,
            kmers,
        });
        (entry, hash)
    }

    /// Register (or unconditionally replace) `name` as `owner`,
    /// returning the profile content hash.  Replacing keeps the
    /// original registration order slot.  `prefilter_k` sizes the
    /// consensus k-mer set used by the `Search` pre-filter.  This is
    /// the **trusted** (in-process/operator) path; untrusted wire
    /// registrations go through [`ProfileRegistry::register_checked`].
    pub fn register(&self, name: &str, owner: &str, phmm: Phmm, prefilter_k: usize) -> u64 {
        let (entry, hash) = Self::make_entry(name, owner, phmm, prefilter_k);
        let mut entries = self.entries.write().unwrap();
        match entries.iter_mut().find(|e| e.name == name) {
            Some(slot) => *slot = entry,
            None => entries.push(entry),
        }
        hash
    }

    /// Fast admission decision for [`ProfileRegistry::register_checked`]
    /// from the content hash alone: `Ok(true)` = identical content
    /// already registered (idempotent, nothing to do), `Ok(false)` =
    /// go ahead and build/insert, `Err` = the name belongs to another
    /// tenant with different content, or a fresh name would push the
    /// registry past its caps (entries store full graphs — untrusted
    /// registration must be bounded).
    fn check_replace(
        entries: &[Arc<ProfileEntry>],
        name: &str,
        owner: &str,
        hash: u64,
        max_profiles: usize,
        max_per_tenant: usize,
    ) -> Result<bool> {
        match entries.iter().find(|e| e.name == name) {
            None => {
                if entries.len() >= max_profiles.max(1) {
                    return Err(ApHmmError::Config(format!(
                        "profile registry is full ({} profiles; serve.max_profiles)",
                        entries.len()
                    )));
                }
                let owned = entries.iter().filter(|e| e.owner == owner).count();
                if owned >= max_per_tenant.max(1) {
                    return Err(ApHmmError::Config(format!(
                        "tenant {owner:?} already owns {owned} profiles \
                         (serve.max_profiles_per_tenant)"
                    )));
                }
                Ok(false)
            }
            Some(e) if e.hash == hash => Ok(true),
            Some(e) if e.owner == owner => Ok(false),
            Some(e) => Err(ApHmmError::Config(format!(
                "profile {name:?} is owned by tenant {:?}; registering \
                 different content under that name is not allowed",
                e.owner
            ))),
        }
    }

    /// Ownership-checked registration for untrusted (wire) tenants.
    /// Registering a fresh name succeeds; re-registering an existing
    /// name succeeds when the caller owns it (profile update) or when
    /// the content hash is identical (idempotent re-upload — the entry
    /// and its owner are left untouched, which is what lets two
    /// tenants share one frozen table by uploading the same text).  A
    /// different tenant replacing a name with **different** content is
    /// refused — that would silently redirect the owner's subsequent
    /// requests onto foreign parameters.
    ///
    /// The refusal/idempotence decision needs only the content hash,
    /// so it runs **before** the expensive part of entry construction
    /// (Viterbi consensus decode + k-mer set): refused uploads cost an
    /// attacker-controlled hash, not a decode.  The check is repeated
    /// under the write lock — the cheap first pass is an early-out,
    /// not the authority — so concurrent registrations can't interleave
    /// past it.
    pub fn register_checked(
        &self,
        name: &str,
        owner: &str,
        phmm: Phmm,
        prefilter_k: usize,
        max_profiles: usize,
        max_per_tenant: usize,
    ) -> Result<u64> {
        let hash = profile_hash(&phmm);
        if Self::check_replace(
            &self.entries.read().unwrap(),
            name,
            owner,
            hash,
            max_profiles,
            max_per_tenant,
        )? {
            return Ok(hash); // idempotent: identical content
        }
        // Build outside the lock: the consensus decode must not block
        // other sessions' lookups.
        let (entry, _) = Self::make_entry(name, owner, phmm, prefilter_k);
        let mut entries = self.entries.write().unwrap();
        if Self::check_replace(&entries, name, owner, hash, max_profiles, max_per_tenant)? {
            return Ok(hash);
        }
        match entries.iter_mut().find(|e| e.name == name) {
            Some(slot) => *slot = entry,
            None => entries.push(entry),
        }
        Ok(hash)
    }

    /// Look up a profile by name.
    pub fn get(&self, name: &str) -> Option<Arc<ProfileEntry>> {
        self.entries.read().unwrap().iter().find(|e| e.name == name).cloned()
    }

    /// All profiles, in registration order.
    pub fn all(&self) -> Vec<Arc<ProfileEntry>> {
        self.entries.read().unwrap().clone()
    }

    /// Number of registered profiles.
    pub fn len(&self) -> usize {
        self.entries.read().unwrap().len()
    }

    /// True when no profile is registered.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

/// Everything a worker needs to execute one request.
pub(crate) struct ExecCtx<'a> {
    pub registry: &'a ProfileRegistry,
    pub cache: &'a super::PreparedCache,
    pub pool: &'a crate::pool::WorkerPool,
    pub cfg: &'a ServerConfig,
}

impl ExecCtx<'_> {
    fn resolve(&self, name: &str) -> Result<Arc<ProfileEntry>> {
        self.registry.get(name).ok_or_else(|| {
            ApHmmError::Config(format!("unknown profile {name:?} (register it first)"))
        })
    }

    fn opts(&self) -> ForwardOptions {
        ForwardOptions {
            filter: self.cfg.train.filter,
            gather: self.cfg.train.gather,
            simd: self.cfg.train.simd,
            scratch: self.cfg.train.scratch_mode,
            max_scratch_bytes: self.cfg.train.max_scratch_bytes,
        }
    }
}

/// Execute a micro-batch of `Score` requests against **one** profile on
/// the calling worker, in one striped pass over the frozen coefficient
/// tables (see [`crate::baumwelch::score_striped_with`]).
///
/// Per-read results are bit-identical to executing each request alone
/// through [`execute`] at the same lane width: the batch contract of
/// [`crate::baumwelch::ExpectationEngine::score_batch`] guarantees the
/// numerics, and this function reproduces `execute`'s per-request
/// response assembly (log-odds, stats, `cache_hit`) slot by slot.  One
/// `Err` slot (e.g. a numerically dead read) does not poison the other
/// slots.  `forward_ns` is the striped wall time attributed evenly
/// across the batch — per-read forward time is not separable inside a
/// striped pass.
pub(crate) fn execute_score_batch(
    ctx: &ExecCtx<'_>,
    engine: EngineKind,
    profile: &str,
    reads: &[&Sequence],
    scratch: &mut ScratchAny,
) -> Vec<Result<(ResponseBody, ReadStats)>> {
    let entry = match ctx.resolve(profile) {
        Ok(entry) => entry,
        Err(e) => {
            return reads
                .iter()
                .map(|_| Err(ApHmmError::Config(e.to_string())))
                .collect()
        }
    };
    let tf = Instant::now();
    let (prepared, cache_hit) = match ctx.cache.get_or_freeze(entry.hash, engine, &entry.phmm)
    {
        Ok(pair) => pair,
        Err(e) => {
            return reads
                .iter()
                .map(|_| Err(ApHmmError::Config(e.to_string())))
                .collect()
        }
    };
    // The freeze (if any) happened once, before the pass; charge it to
    // the first slot so merged cache_freeze_ns counts it once.
    let freeze_ns = if cache_hit { 0 } else { tf.elapsed().as_nanos() };
    let t0 = Instant::now();
    let results = prepared.score_batch(&entry.phmm, reads, &ctx.opts(), scratch);
    let per_read_ns = t0.elapsed().as_nanos() / reads.len().max(1) as u128;
    let n = reads.len();
    results
        .into_iter()
        .zip(reads)
        .enumerate()
        .map(|(i, (res, read))| {
            let res = res?;
            // Stripe accounting mirrors the kernel's chunks(MAX_STRIPE)
            // split: each chunk's first slot carries one pass.
            let chunk_lead = i % MAX_STRIPE == 0;
            let stats = ReadStats {
                forward_ns: per_read_ns,
                cache_freeze_ns: if i == 0 { freeze_ns } else { 0 },
                filter_stats: res.filter_stats,
                states_processed: res.states_processed,
                edges_processed: res.edges_processed,
                timesteps: read.len() as u64,
                stripe_passes: u64::from(chunk_lead),
                stripe_reads: if chunk_lead {
                    (n - i).min(MAX_STRIPE) as u64
                } else {
                    0
                },
                ..Default::default()
            };
            let log_odds = apps::log_odds_score(res.loglik, read.len(), entry.phmm.sigma());
            // The first slot of a batch pays the freeze on a cold
            // cache; later slots always hit, exactly as a sequential
            // loop would report.
            Ok((
                ResponseBody::Score {
                    profile: entry.name.clone(),
                    loglik: res.loglik,
                    log_odds,
                    cache_hit: cache_hit || i > 0,
                },
                stats,
            ))
        })
        .collect()
}

/// Execute one request on the calling worker.  Read-only requests pull
/// their frozen coefficient tables from the cross-request cache;
/// `Correct` trains through the shared worker pool.
///
/// `cancel` is observed at coarse boundaries — between profiles in
/// `Search`, between reads inside `Correct`'s E-step — and always
/// aborts the **whole** request with [`ApHmmError::Cancelled`]; a
/// request that runs to completion is bit-identical whether or not a
/// token was attached.
pub(crate) fn execute(
    ctx: &ExecCtx<'_>,
    engine: EngineKind,
    req: &Request,
    cancel: &CancelToken,
    scratch: &mut ScratchAny,
) -> Result<(ResponseBody, ReadStats)> {
    match req {
        Request::Score { profile, read } => {
            let entry = ctx.resolve(profile)?;
            let tf = Instant::now();
            let (prepared, cache_hit) =
                ctx.cache.get_or_freeze(entry.hash, engine, &entry.phmm)?;
            let freeze_ns = if cache_hit { 0 } else { tf.elapsed().as_nanos() };
            let t0 = Instant::now();
            let res = prepared.score(&entry.phmm, read, &ctx.opts(), scratch)?;
            let stats = ReadStats {
                forward_ns: t0.elapsed().as_nanos(),
                cache_freeze_ns: freeze_ns,
                filter_stats: res.filter_stats,
                states_processed: res.states_processed,
                edges_processed: res.edges_processed,
                timesteps: read.len() as u64,
                ..Default::default()
            };
            let log_odds = apps::log_odds_score(res.loglik, read.len(), entry.phmm.sigma());
            Ok((
                ResponseBody::Score {
                    profile: entry.name.clone(),
                    loglik: res.loglik,
                    log_odds,
                    cache_hit,
                },
                stats,
            ))
        }
        Request::Align { profile, read } => {
            let entry = ctx.resolve(profile)?;
            let tf = Instant::now();
            let (prepared, cache_hit) =
                ctx.cache.get_or_freeze(entry.hash, engine, &entry.phmm)?;
            let freeze_ns = if cache_hit { 0 } else { tf.elapsed().as_nanos() };
            let dec = prepared.posterior(&entry.phmm, read)?;
            let n_columns = apps::profile_columns(&entry.phmm);
            let (columns, insertions) =
                apps::posterior_columns(&entry.phmm, n_columns, read, &dec.best_state);
            let stats = ReadStats {
                forward_ns: dec.forward_ns,
                backward_update_ns: dec.backward_ns,
                cache_freeze_ns: freeze_ns,
                timesteps: read.len() as u64,
                ..Default::default()
            };
            let row = AlignedRow {
                id: read.id.clone(),
                columns,
                insertions,
                loglik: dec.loglik,
            };
            Ok((ResponseBody::Align { profile: entry.name.clone(), row }, stats))
        }
        Request::Search { read } => {
            let mut stats = ReadStats::default();
            let mut hits = Vec::new();
            let mut scored = 0usize;
            // MSV/SSV-style screen (the non-Baum-Welch part of Fig. 2's
            // hmmsearch profile): only profiles sharing enough consensus
            // k-mers with the query pay for a forward pass.
            let min_frac = ctx.cfg.prefilter_min_frac;
            let qk = apps::kmer_set(&read.data, ctx.cfg.prefilter_k, ctx.cfg.alphabet.size());
            let entries = ctx.registry.all();
            for entry in &entries {
                // Per-profile cancellation point: a deadline that
                // expires mid-scan aborts the whole request (partial
                // rankings are never returned).
                if let Some(cause) = cancel.check() {
                    return Err(ApHmmError::Cancelled(cause));
                }
                if min_frac > 0.0 && !entry.kmers.is_empty() {
                    let shared = qk.intersection(&entry.kmers).count();
                    if (shared as f64 / qk.len().max(1) as f64) < min_frac {
                        continue;
                    }
                }
                let tf = Instant::now();
                let (prepared, cache_hit) =
                    ctx.cache.get_or_freeze(entry.hash, engine, &entry.phmm)?;
                if !cache_hit {
                    stats.cache_freeze_ns += tf.elapsed().as_nanos();
                }
                let t0 = Instant::now();
                let res = match prepared.score(&entry.phmm, read, &ctx.opts(), scratch) {
                    Ok(res) => res,
                    // A numerically dead (profile, read) pair is not a
                    // request failure; the profile simply doesn't hit.
                    Err(_) => {
                        stats.forward_ns += t0.elapsed().as_nanos();
                        continue;
                    }
                };
                stats.forward_ns += t0.elapsed().as_nanos();
                stats.filter_stats.merge(&res.filter_stats);
                stats.states_processed += res.states_processed;
                stats.edges_processed += res.edges_processed;
                stats.timesteps += read.len() as u64;
                scored += 1;
                hits.push(RankedHit {
                    profile: entry.name.clone(),
                    log_odds: apps::log_odds_score(res.loglik, read.len(), entry.phmm.sigma()),
                });
            }
            hits.sort_by(|a, b| b.log_odds.partial_cmp(&a.log_odds).unwrap());
            hits.truncate(ctx.cfg.max_hits.max(1));
            // hmmsearch's domain post-processing: a posterior (Backward)
            // pass over the reported top hits.
            for hit in hits.iter().take(ctx.cfg.posterior_hits) {
                let Some(entry) = entries.iter().find(|e| e.name == hit.profile) else {
                    continue;
                };
                let (prepared, _) =
                    ctx.cache.get_or_freeze(entry.hash, engine, &entry.phmm)?;
                if let Ok(dec) = prepared.posterior(&entry.phmm, read) {
                    stats.forward_ns += dec.forward_ns;
                    stats.backward_update_ns += dec.backward_ns;
                }
            }
            Ok((ResponseBody::Search { hits, scored }, stats))
        }
        Request::Correct { reference, reads } => {
            let train_cfg =
                crate::baumwelch::TrainConfig { engine, ..ctx.cfg.train };
            let out = apps::train_chunk_with(
                reference,
                reads,
                &ctx.cfg.design,
                ctx.cfg.alphabet,
                &train_cfg,
                ctx.pool,
                cancel,
            )?;
            let stats = ReadStats {
                forward_ns: out.train.forward_ns,
                backward_update_ns: out.train.backward_update_ns,
                update_ns: out.train.maximize_ns,
                filter_stats: out.train.filter_stats,
                states_processed: out.train.states_processed,
                edges_processed: out.train.edges_processed,
                timesteps: out.train.timesteps,
                stripe_passes: out.train.stripe_passes,
                stripe_reads: out.train.stripe_reads,
                peak_scratch_bytes: out.train.peak_scratch_bytes,
                epochs: out.train.epochs,
                minibatches: out.train.minibatches,
                sequences_streamed: out.train.sequences_streamed,
                ..Default::default()
            };
            let mean_loglik =
                out.train.loglik_history.last().copied().unwrap_or(f64::NEG_INFINITY);
            Ok((
                ResponseBody::Correct {
                    consensus: out.consensus,
                    mean_loglik,
                    iters: out.train.iters,
                },
                stats,
            ))
        }
    }
}

// ---------------------------------------------------------------------
// Wire protocol.
// ---------------------------------------------------------------------

/// Why a protocol session ended.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SessionEnd {
    /// Client sent `quit` (or an equivalent polite close).
    Quit,
    /// Client sent `shutdown`: stop accepting connections and drain.
    Shutdown,
    /// The input stream ended.
    Eof,
}

fn parse_engine(tok: Option<&str>, default: EngineKind) -> std::result::Result<EngineKind, String> {
    match tok {
        None => Ok(default),
        Some(name) => EngineKind::parse(name).ok_or_else(|| {
            format!("unknown engine {name:?} (expected {})", EngineKind::NAMES.join(" | "))
        }),
    }
}

/// Parse one request line.  `Ok(None)` means the line was blank or a
/// comment.
fn parse_line(
    cfg: &ServerConfig,
    line: &str,
) -> std::result::Result<Option<Command>, String> {
    let line = line.trim();
    if line.is_empty() || line.starts_with('#') {
        return Ok(None);
    }
    let mut toks = line.split_whitespace();
    let cmd = toks.next().unwrap();
    let seq = |tok: Option<&str>, what: &str| -> std::result::Result<Sequence, String> {
        let s = tok.ok_or_else(|| format!("{cmd}: missing {what}"))?;
        Sequence::from_str(what, s, cfg.alphabet).map_err(|e| e.to_string())
    };
    let command = match cmd {
        "tenant" => {
            let name = toks.next().ok_or("tenant: missing tenant id")?.to_string();
            // `__`-prefixed ids are reserved for in-process principals
            // (see `OPERATOR_TENANT`): a wire session must not be able
            // to assume the operator's profile ownership.
            if name.starts_with("__") {
                return Err(format!("tenant: id {name:?} is reserved (`__` prefix)"));
            }
            let priority = match toks.next() {
                None => Priority::Normal,
                Some(p) => Priority::parse(p).ok_or_else(|| {
                    format!("tenant: unknown priority {p:?} (expected low | normal | high)")
                })?,
            };
            Command::Tenant { name, priority }
        }
        "deadline" => {
            let tok = toks.next().ok_or("deadline: missing budget (ms or `off`)")?;
            let ms = if tok == "off" {
                None
            } else {
                let ms: u64 = tok
                    .parse()
                    .map_err(|_| "deadline: budget must be milliseconds or `off`")?;
                if ms == 0 {
                    None
                } else {
                    Some(ms)
                }
            };
            Command::Deadline { ms }
        }
        "register" => {
            let name = toks.next().ok_or("register: missing profile name")?.to_string();
            let reference = seq(toks.next(), "reference")?;
            Command::Register { name, reference }
        }
        "register-profile" => {
            let name =
                toks.next().ok_or("register-profile: missing profile name")?.to_string();
            let nbytes: usize = toks
                .next()
                .ok_or("register-profile: missing payload byte count")?
                .parse()
                .map_err(|_| "register-profile: payload byte count must be an integer")?;
            Command::RegisterProfile { name, nbytes }
        }
        "score" | "align" => {
            let profile = toks.next().ok_or_else(|| format!("{cmd}: missing profile name"))?;
            let read = seq(toks.next(), "read")?;
            let engine = parse_engine(toks.next(), cfg.engine)?;
            let body = if cmd == "score" {
                Request::Score { profile: profile.to_string(), read }
            } else {
                Request::Align { profile: profile.to_string(), read }
            };
            Command::Submit { engine, body }
        }
        "search" => {
            let read = seq(toks.next(), "read")?;
            let engine = parse_engine(toks.next(), cfg.engine)?;
            Command::Submit { engine, body: Request::Search { read } }
        }
        "correct" => {
            let reference = seq(toks.next(), "reference")?;
            let reads_tok = toks.next().ok_or("correct: missing comma-separated reads")?;
            let mut reads = Vec::new();
            for (i, r) in reads_tok.split(',').filter(|r| !r.is_empty()).enumerate() {
                reads.push(
                    Sequence::from_str(format!("read{i}"), r, cfg.alphabet)
                        .map_err(|e| e.to_string())?,
                );
            }
            let engine = parse_engine(toks.next(), cfg.engine)?;
            Command::Submit { engine, body: Request::Correct { reference, reads } }
        }
        "stats" => Command::Stats,
        "tenants" => Command::Tenants,
        "metrics" => Command::Metrics,
        "trace" => {
            let tok = toks.next().ok_or("trace: missing mode (`on` or `off`)")?;
            let on = match tok {
                "on" => true,
                "off" => false,
                other => {
                    return Err(format!("trace: unknown mode {other:?} (expected on | off)"))
                }
            };
            Command::Trace { on }
        }
        "trace-dump" => Command::TraceDump,
        "quit" | "exit" => Command::Quit,
        "shutdown" => Command::Shutdown,
        other => {
            return Err(format!(
                "unknown command {other:?} (expected tenant | deadline | register | \
                 register-profile | score | align | search | correct | stats | tenants | \
                 metrics | trace | trace-dump | quit | shutdown)"
            ))
        }
    };
    if let Some(extra) = toks.next() {
        return Err(format!("{cmd}: unexpected trailing token {extra:?}"));
    }
    Ok(Some(command))
}

enum Command {
    Tenant { name: String, priority: Priority },
    Deadline { ms: Option<u64> },
    Register { name: String, reference: Sequence },
    RegisterProfile { name: String, nbytes: usize },
    Submit { engine: EngineKind, body: Request },
    Stats,
    Tenants,
    Metrics,
    Trace { on: bool },
    TraceDump,
    Quit,
    Shutdown,
}

/// Render a completed response as one protocol line.
fn format_response(cfg: &ServerConfig, resp: &Response) -> String {
    let latency_us = resp.latency_ns / 1_000;
    match &resp.body {
        ResponseBody::Score { profile, loglik, log_odds, cache_hit } => format!(
            "score {profile} loglik={loglik:.6} odds={log_odds:.6} cache={} engine={} latency_us={latency_us}",
            if *cache_hit { "hit" } else { "miss" },
            resp.engine.name(),
        ),
        ResponseBody::Align { profile, row } => {
            let ascii: String = row
                .columns
                .iter()
                .map(|c| match c {
                    Some(sym) => cfg.alphabet.decode(*sym) as char,
                    None => '-',
                })
                .collect();
            format!(
                "align {profile} loglik={:.6} insertions={} row={ascii} latency_us={latency_us}",
                row.loglik, row.insertions
            )
        }
        ResponseBody::Search { hits, scored } => {
            let ranked: Vec<String> = hits
                .iter()
                .map(|h| format!("{}:{:.4}", h.profile, h.log_odds))
                .collect();
            format!(
                "search scored={scored} hits={} latency_us={latency_us}",
                if ranked.is_empty() { "-".to_string() } else { ranked.join(",") }
            )
        }
        ResponseBody::Correct { consensus, mean_loglik, iters } => format!(
            "corrected len={} mean_loglik={mean_loglik:.4} iters={iters} seq={} latency_us={latency_us}",
            consensus.len(),
            consensus.to_ascii(cfg.alphabet),
        ),
        ResponseBody::Error { message } => format!("err {message}"),
        ResponseBody::Failure { cause, message } => {
            format!("err {}: {message} latency_us={latency_us}", cause.name())
        }
    }
}

/// Read a `register-profile` payload: exactly `nbytes` of UTF-8
/// `.aphmm` text.  The byte count is validated against the configured
/// cap **before** any byte is consumed, so an oversized length prefix
/// is a refused request, not an allocation.  `Err((message, fatal))`:
/// `fatal` means the session must end after the error reply — both an
/// oversized prefix (the client may already have written the payload
/// we are not going to read, so the stream cannot be resynchronized)
/// and a truncated payload leave the stream unusable.
fn read_profile_payload<R: BufRead>(
    input: &mut R,
    nbytes: usize,
    cap: usize,
) -> std::result::Result<String, (String, bool)> {
    if nbytes > cap {
        return Err((
            format!(
                "register-profile: payload of {nbytes} bytes exceeds the \
                 {cap}-byte cap (serve.max_profile_bytes); closing session"
            ),
            true,
        ));
    }
    let mut buf = vec![0u8; nbytes];
    if let Err(e) = input.read_exact(&mut buf) {
        return Err((format!("register-profile: truncated payload ({e})"), true));
    }
    String::from_utf8(buf)
        .map_err(|_| ("register-profile: payload is not UTF-8".to_string(), false))
}

/// Handle a `register-profile` payload that was read successfully:
/// parse, cross-check the alphabet, register under the session tenant
/// (ownership-checked — see [`Server::register_profile_for`]).
fn register_profile_text(server: &Server, tenant: &str, name: &str, text: &str) -> String {
    let cfg = server.config();
    match crate::io::read_phmm_str(text, "wire") {
        Ok(phmm) if phmm.alphabet.name() != cfg.alphabet.name() => format!(
            "err register-profile: profile alphabet {} does not match server alphabet {}",
            phmm.alphabet.name(),
            cfg.alphabet.name()
        ),
        Ok(phmm) => {
            let states = phmm.n_states();
            match server.register_profile_for(tenant, name, phmm) {
                Ok(hash) => format!("ok profile {name} states={states} hash={hash:016x}"),
                Err(e) => format!("err {e}"),
            }
        }
        Err(e) => format!("err {e}"),
    }
}

/// Serve one protocol session: read request lines from `input`, write
/// one response line per request (in request order) to `out`.
///
/// Admission control is the blocking kind: when the job queue is full
/// — or this session's tenant is at its quota — the session stalls
/// until capacity frees up, which is exactly the backpressure a
/// streaming client should feel (load-shedding clients use the typed
/// [`Server::try_submit_for`] API instead).
pub fn serve_connection<R: BufRead, W: Write>(
    server: &Server,
    mut input: R,
    mut out: W,
) -> Result<SessionEnd> {
    let mut tenant = DEFAULT_TENANT.to_string();
    let mut priority = Priority::Normal;
    let mut deadline: Option<Duration> = None;
    // Per-session tracing flag (`trace on|off`): traced submissions
    // carry their span timeline into the server's trace ring and echo
    // `trace=<id>` on the response line.  Results are bit-identical
    // either way (span capture sits at stage boundaries only).
    let mut trace = false;
    let mut line = String::new();
    // Idle reaping: a session that completes no command for
    // `serve.idle_timeout_ms` is closed.  The check only fires on
    // read-timeout wakeups, so it requires `serve.read_timeout_ms > 0`
    // on the underlying socket (serve_tcp sets this); with blocking
    // reads (stdio, in-memory tests) the behavior is unchanged.
    let idle_timeout = Duration::from_millis(server.config().idle_timeout_ms);
    let mut idle_since = Instant::now();
    loop {
        crate::failpoint!("wire::io", |msg: String| {
            ApHmmError::Coordinator(format!("failpoint wire::io: {msg}"))
        });
        line.clear();
        // Retry loop for socket read timeouts.  `read_line` may have
        // appended a partial line to `line` before timing out, so the
        // buffer must persist across retries — clearing it would
        // corrupt a slow writer's command.
        loop {
            match input.read_line(&mut line) {
                Ok(0) => return Ok(SessionEnd::Eof),
                Ok(_) => break,
                Err(e)
                    if matches!(
                        e.kind(),
                        std::io::ErrorKind::WouldBlock | std::io::ErrorKind::TimedOut
                    ) =>
                {
                    if !idle_timeout.is_zero() && idle_since.elapsed() >= idle_timeout {
                        return Ok(SessionEnd::Eof); // reap idle session
                    }
                }
                Err(_) => return Ok(SessionEnd::Eof), // client went away mid-line
            }
        }
        idle_since = Instant::now();
        let reply = match parse_line(server.config(), &line) {
            Ok(None) => continue,
            Err(msg) => {
                // A malformed register-profile command line may have a
                // payload already in flight behind it; like the
                // over-cap case, the stream cannot be resynchronized —
                // leaving it open would parse the payload as commands.
                if line.trim_start().starts_with("register-profile") {
                    let _ = writeln!(out, "err {msg}; closing session");
                    let _ = out.flush();
                    return Ok(SessionEnd::Eof);
                }
                format!("err {msg}")
            }
            Ok(Some(Command::Tenant { name, priority: p })) => {
                tenant = name;
                priority = p;
                format!("ok tenant {tenant} priority={}", priority.name())
            }
            Ok(Some(Command::Deadline { ms })) => {
                deadline = ms.map(Duration::from_millis);
                match ms {
                    Some(ms) => format!("ok deadline {ms}ms"),
                    None => "ok deadline off".to_string(),
                }
            }
            Ok(Some(Command::Register { name, reference })) => {
                let cfg = server.config();
                match Phmm::error_correction_for(&reference, &cfg.design, cfg.alphabet) {
                    Ok(phmm) => {
                        let states = phmm.n_states();
                        match server.register_profile_for(&tenant, &name, phmm) {
                            Ok(hash) => {
                                format!("ok profile {name} states={states} hash={hash:016x}")
                            }
                            Err(e) => format!("err {e}"),
                        }
                    }
                    Err(e) => format!("err {e}"),
                }
            }
            Ok(Some(Command::RegisterProfile { name, nbytes })) => {
                let cap = server.config().max_profile_bytes;
                match read_profile_payload(&mut input, nbytes, cap) {
                    Ok(text) => register_profile_text(server, &tenant, &name, &text),
                    Err((msg, fatal)) => {
                        let _ = writeln!(out, "err {msg}");
                        let _ = out.flush();
                        if fatal {
                            return Ok(SessionEnd::Eof);
                        }
                        continue;
                    }
                }
            }
            Ok(Some(Command::Submit { engine, body })) => {
                match server.submit_traced(&tenant, priority, Some(engine), body, deadline, trace)
                {
                    Ok(ticket) => {
                        let id = ticket.id;
                        let mut reply = format_response(server.config(), &ticket.wait());
                        // Traced sessions see the trace id on every
                        // response line — the key into `trace-dump`.
                        if trace {
                            reply.push_str(&format!(" trace={id}"));
                        }
                        reply
                    }
                    Err(e) => format!("err {e}"),
                }
            }
            Ok(Some(Command::Stats)) => server.stats_line(),
            Ok(Some(Command::Tenants)) => server.tenants_line(),
            Ok(Some(Command::Metrics)) => {
                // Multi-line block: Prometheus text exposition, using
                // its own `# EOF` terminator as the end-of-block
                // delimiter on the line protocol.
                let text = server.metrics_text();
                if write!(out, "{text}").is_err() || out.flush().is_err() {
                    return Ok(SessionEnd::Eof);
                }
                continue;
            }
            Ok(Some(Command::Trace { on })) => {
                trace = on;
                format!("ok trace {}", if on { "on" } else { "off" })
            }
            Ok(Some(Command::TraceDump)) => {
                // Last-N retained timelines, one JSON line each,
                // oldest first, then the `ok` summary line.
                let dump = server.trace_dump();
                let n = dump.len();
                for l in &dump {
                    if writeln!(out, "{l}").is_err() {
                        return Ok(SessionEnd::Eof);
                    }
                }
                format!("ok trace-dump n={n}")
            }
            Ok(Some(Command::Quit)) => {
                let _ = writeln!(out, "ok bye");
                let _ = out.flush();
                return Ok(SessionEnd::Quit);
            }
            Ok(Some(Command::Shutdown)) => {
                let _ = writeln!(out, "ok shutdown");
                let _ = out.flush();
                return Ok(SessionEnd::Shutdown);
            }
        };
        if writeln!(out, "{reply}").is_err() || out.flush().is_err() {
            return Ok(SessionEnd::Eof);
        }
    }
}

/// Serve the protocol over stdin/stdout until EOF, `quit`, or
/// `shutdown`.
pub fn serve_stdio(server: &Server) -> Result<SessionEnd> {
    let stdin = std::io::stdin();
    let stdout = std::io::stdout();
    serve_connection(server, stdin.lock(), stdout.lock())
}

/// Serve the protocol on a local TCP port, one thread per connection,
/// until a client sends `shutdown`.  On shutdown every still-open
/// session socket is closed (its blocked read sees EOF), so this
/// returns promptly even with idle clients connected.
pub fn serve_tcp(server: &Server, port: u16) -> Result<()> {
    let listener = TcpListener::bind(("127.0.0.1", port))?;
    listener.set_nonblocking(true)?;
    let stop = AtomicBool::new(false);
    // One tracking clone per accepted socket: the accept loop uses
    // these to force idle sessions off their blocking reads when a
    // client requests shutdown.
    let sessions: Mutex<Vec<std::net::TcpStream>> = Mutex::new(Vec::new());
    std::thread::scope(|scope| -> Result<()> {
        loop {
            if stop.load(Ordering::Relaxed) {
                for s in sessions.lock().unwrap().iter() {
                    let _ = s.shutdown(std::net::Shutdown::Both);
                }
                return Ok(());
            }
            match listener.accept() {
                Ok((stream, _peer)) => {
                    // Accepted sockets may inherit the listener's
                    // non-blocking mode on some platforms; sessions
                    // want blocking reads.
                    let _ = stream.set_nonblocking(false);
                    // Per-session socket timeouts: an abandoned or
                    // wedged client cannot pin its session thread on a
                    // blocking read/write forever.  Zero keeps fully
                    // blocking sockets (today's behavior).
                    let timeout_ms = server.config().read_timeout_ms;
                    if timeout_ms > 0 {
                        let t = Some(Duration::from_millis(timeout_ms));
                        let _ = stream.set_read_timeout(t);
                        let _ = stream.set_write_timeout(t);
                    }
                    if let Ok(track) = stream.try_clone() {
                        sessions.lock().unwrap().push(track);
                    }
                    let stop = &stop;
                    scope.spawn(move || {
                        let Ok(reader) = stream.try_clone() else { return };
                        match serve_connection(server, BufReader::new(reader), stream) {
                            Ok(SessionEnd::Shutdown) => stop.store(true, Ordering::Relaxed),
                            Ok(_) => {}
                            Err(e) => eprintln!("serve: session error: {e}"),
                        }
                    });
                }
                Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                    std::thread::sleep(std::time::Duration::from_millis(20));
                }
                Err(e) => return Err(e.into()),
            }
        }
    })
}
